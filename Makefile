# z-SignFedAvg reproduction — top-level build entry points.
#
#   make build     release build of the coordinator (lib + zsfa binary)
#   make test      full Rust test suite (tier-1 verify = build + test)
#   make bench     run every registered micro/round bench
#   make fmt       rustfmt check (what CI enforces)
#   make lint      clippy with warnings denied (what CI enforces)
#   make python    editable-install the compile package + kernel tests
#   make artifacts AOT-lower the L2/L1 stack to HLO text (needs jax)
#   make ci        everything CI runs, locally

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-build fmt lint python artifacts ci clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	$(CARGO) bench

bench-build:
	$(CARGO) bench --no-run

fmt:
	$(CARGO) fmt --all -- --check

lint:
	$(CARGO) clippy --all-targets -- -D warnings

python:
	$(PYTHON) -m pip install -e python
	$(PYTHON) -m pytest python/tests -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

ci: build test fmt lint bench-build python
