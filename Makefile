# z-SignFedAvg reproduction — top-level build entry points.
#
#   make build     release build of the coordinator (lib + zsfa binary)
#   make test      full Rust test suite (tier-1 verify = build + test)
#   make bench     run every registered micro/round bench
#   make bench-smoke every registered bench with a tiny iteration budget
#                    (catches bench rot; bench-compile alone doesn't execute)
#   make bench-json  perf trajectory -> BENCH_compress.json (fused vs scalar
#                    sign kernels), BENCH_aggregate.json (CSA vs scalar vote
#                    add), BENCH_dense_reduce.json (streamed vs buffered)
#   make determinism parallelism-1 vs -8 scenario CSV byte-diff (what CI runs)
#   make spec-smoke  `zsfa run` example spec vs equivalent fig1 driver CSV
#                    byte-diff at parallelism 1 and 8 (what CI runs)
#   make service-smoke networked-service equivalence: engine vs loopback vs
#                    a real TCP serve/join round trip, CSV byte-diff (CI)
#   make fmt       rustfmt check (what CI enforces)
#   make lint      clippy with warnings denied (what CI enforces)
#   make python    editable-install the compile package + kernel tests
#   make artifacts AOT-lower the L2/L1 stack to HLO text (needs jax)
#   make ci        everything CI runs, locally

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-build bench-smoke bench-json determinism spec-smoke service-smoke fmt lint python artifacts ci clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	$(CARGO) bench

bench-build:
	$(CARGO) bench --no-run

# Execute every registered bench with a tiny iteration budget (release
# mode). The timings are meaningless; the point is that the bench *code*
# runs on every PR, which `cargo bench --no-run` cannot guarantee.
bench-smoke:
	$(CARGO) bench -- --smoke

# Machine-readable perf trajectory at the repo root (CI uploads these as
# artifacts): fused-vs-scalar compress throughput, CSA-vs-scalar vote
# accumulation at m in {64, 512, 4096}, and the streamed-vs-buffered dense
# reduce. Paths are absolute because cargo runs benches from rust/.
bench-json:
	$(CARGO) bench --bench bench_compress -- --json $(CURDIR)/BENCH_compress.json
	$(CARGO) bench --bench bench_aggregate -- --json $(CURDIR)/BENCH_aggregate.json
	$(CARGO) bench --bench bench_dense_reduce -- --json $(CURDIR)/BENCH_dense_reduce.json

# Reduce-order regression smoke: one scenario config at parallelism 1 and 8
# must produce byte-identical CSVs (raw CSVs carry wall-clock, so excluded).
# --reduce-lanes 3 < cohort forces multi-slot lanes, so the streamed in-lane
# fold (not its m <= L degenerate form) is what gets diffed. Runs in scratch
# dirs so ./results is never touched.
determinism: build
	rm -rf results_det_p1 results_det_p8
	mkdir -p results_det_p1 results_det_p8
	cd results_det_p1 && ../target/release/zsfa scenarios --rounds 30 \
	  --byz-rounds 30 --clients 24 --dim 1000 --repeats 1 \
	  --sim_target_cohort 8 --reduce-lanes 3 --parallelism 1
	cd results_det_p8 && ../target/release/zsfa scenarios --rounds 30 \
	  --byz-rounds 30 --clients 24 --dim 1000 --repeats 1 \
	  --sim_target_cohort 8 --reduce-lanes 3 --parallelism 8
	diff -r -x '*_raw.csv' results_det_p1 results_det_p8
	@echo "determinism: parallelism 1 vs 8 CSVs are byte-identical"

# Spec-vs-driver equivalence smoke: `zsfa run examples/quickstart.json`
# must reproduce the fig1 driver's CSVs byte-for-byte (aggregated files
# exactly; raw files modulo the measured wall_ms column, which is
# wall-clock — same rationale as the determinism target), at parallelism
# 1 AND 8. Extends the determinism-job pattern to the new run surface.
spec-smoke: build
	rm -rf results_spec_driver results_spec_run_p1 results_spec_run_p8
	mkdir -p results_spec_driver results_spec_run_p1 results_spec_run_p8
	cd results_spec_driver && ../target/release/zsfa fig1 \
	  --dims 50 --clients 8 --rounds 40 --repeats 2 --parallelism 1
	cd results_spec_run_p1 && ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 1
	cd results_spec_run_p8 && ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 8
	diff -r -x '*_raw.csv' results_spec_driver results_spec_run_p1
	diff -r -x '*_raw.csv' results_spec_driver results_spec_run_p8
	@set -e; for f in results_spec_driver/results/fig1_d50/*_raw.csv; do \
	  b=$$(basename $$f); \
	  awk -F, -v OFS=, '{$$9="-"; print}' $$f > results_spec_driver/$$b.norm; \
	  for alt in results_spec_run_p1 results_spec_run_p8; do \
	    awk -F, -v OFS=, '{$$9="-"; print}' $$alt/results/fig1_d50/$$b > $$alt/$$b.norm; \
	    cmp results_spec_driver/$$b.norm $$alt/$$b.norm; \
	  done; \
	done
	@echo "spec-smoke: zsfa run CSVs byte-identical to the fig1 driver at parallelism 1 and 8"

# Networked-service equivalence smoke (DESIGN.md §5): the example spec run
# three ways — in-process engine, the loopback service stack (full protocol
# encode/decode, 4 workers), and a real TCP coordinator with two joined
# participants on localhost — must produce byte-identical CSV trees
# (aggregated files exactly; raw files modulo the measured wall_ms column,
# same rationale as spec-smoke). `timeout` bounds the TCP leg so a
# deadlocked round fails the job instead of hanging it.
service-smoke: build
	rm -rf results_svc_engine results_svc_loop results_svc_tcp
	mkdir -p results_svc_engine results_svc_loop results_svc_tcp
	cd results_svc_engine && ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 1
	cd results_svc_loop && ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --transport loopback --parallelism 4
	diff -r -x '*_raw.csv' results_svc_engine results_svc_loop
	@set -e; cd results_svc_tcp; \
	  timeout 180 ../target/release/zsfa serve ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7443 --min-participants 2 & srv=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7443 --patience-s 60 & j1=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7443 --patience-s 60 & j2=$$!; \
	  wait $$srv && wait $$j1 && wait $$j2
	diff -r -x '*_raw.csv' results_svc_engine results_svc_tcp
	@set -e; for f in results_svc_engine/results/fig1_d50/*_raw.csv; do \
	  b=$$(basename $$f); \
	  awk -F, -v OFS=, '{$$9="-"; print}' $$f > results_svc_engine/$$b.norm; \
	  for alt in results_svc_loop results_svc_tcp; do \
	    awk -F, -v OFS=, '{$$9="-"; print}' $$alt/results/fig1_d50/$$b > $$alt/$$b.norm; \
	    cmp results_svc_engine/$$b.norm $$alt/$$b.norm; \
	  done; \
	done
	@echo "service-smoke: engine, loopback and TCP serve/join CSVs are byte-identical"

fmt:
	$(CARGO) fmt --all -- --check

lint:
	$(CARGO) clippy --all-targets -- -D warnings

python:
	$(PYTHON) -m pip install -e python
	$(PYTHON) -m pytest python/tests -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

ci: build test fmt lint bench-build bench-smoke python
