# z-SignFedAvg reproduction — top-level build entry points.
#
#   make build     release build of the coordinator (lib + zsfa binary)
#   make test      full Rust test suite (tier-1 verify = build + test)
#   make bench     run every registered micro/round bench
#   make bench-json  streamed-vs-buffered aggregation bench -> BENCH_aggregate.json
#   make determinism parallelism-1 vs -8 scenario CSV byte-diff (what CI runs)
#   make fmt       rustfmt check (what CI enforces)
#   make lint      clippy with warnings denied (what CI enforces)
#   make python    editable-install the compile package + kernel tests
#   make artifacts AOT-lower the L2/L1 stack to HLO text (needs jax)
#   make ci        everything CI runs, locally

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-build bench-json determinism fmt lint python artifacts ci clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	$(CARGO) bench

bench-build:
	$(CARGO) bench --no-run

# Machine-readable aggregation-perf trajectory (streamed vs buffered dense
# reduce at m in {64, 512, 4096}).
bench-json:
	$(CARGO) bench --bench bench_dense_reduce -- --json BENCH_aggregate.json

# Reduce-order regression smoke: one scenario config at parallelism 1 and 8
# must produce byte-identical CSVs (raw CSVs carry wall-clock, so excluded).
# --reduce-lanes 3 < cohort forces multi-slot lanes, so the streamed in-lane
# fold (not its m <= L degenerate form) is what gets diffed. Runs in scratch
# dirs so ./results is never touched.
determinism: build
	rm -rf results_det_p1 results_det_p8
	mkdir -p results_det_p1 results_det_p8
	cd results_det_p1 && ../target/release/zsfa scenarios --rounds 30 \
	  --byz-rounds 30 --clients 24 --dim 1000 --repeats 1 \
	  --sim_target_cohort 8 --reduce-lanes 3 --parallelism 1
	cd results_det_p8 && ../target/release/zsfa scenarios --rounds 30 \
	  --byz-rounds 30 --clients 24 --dim 1000 --repeats 1 \
	  --sim_target_cohort 8 --reduce-lanes 3 --parallelism 8
	diff -r -x '*_raw.csv' results_det_p1 results_det_p8
	@echo "determinism: parallelism 1 vs 8 CSVs are byte-identical"

fmt:
	$(CARGO) fmt --all -- --check

lint:
	$(CARGO) clippy --all-targets -- -D warnings

python:
	$(PYTHON) -m pip install -e python
	$(PYTHON) -m pytest python/tests -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

ci: build test fmt lint bench-build python
