# z-SignFedAvg reproduction — top-level build entry points.
#
#   make build     release build of the coordinator (lib + zsfa binary)
#   make test      full Rust test suite (tier-1 verify = build + test)
#   make bench     run every registered micro/round bench
#   make bench-smoke every registered bench with a tiny iteration budget,
#                    run twice: default SIMD dispatch and ZSFA_SIMD=off
#                    (catches bench rot; bench-compile alone doesn't execute)
#   make bench-json  perf trajectory -> BENCH_compress.json (fused vs scalar
#                    sign kernels, A/B'd across SIMD backends),
#                    BENCH_aggregate.json (CSA vs scalar vote add, ditto),
#                    BENCH_dense_reduce.json (streamed vs buffered)
#   make determinism parallelism-1 vs -8 scenario CSV byte-diff (what CI runs)
#   make spec-smoke  `zsfa run` example spec vs equivalent fig1 driver CSV
#                    byte-diff at parallelism 1 and 8 (what CI runs)
#   make service-smoke networked-service equivalence: engine vs loopback vs
#                    a real TCP serve/join round trip, CSV byte-diff (CI)
#   make metrics-smoke telemetry end-to-end: scrape GET /metrics during a
#                    TCP session, check families + monotone counters, render
#                    one `zsfa watch` frame, byte-diff vs telemetry-off (CI)
#   make ckpt-smoke  crash recovery end-to-end: TCP serve/join with
#                    --checkpoint-every, kill -9 the coordinator once a
#                    snapshot lands, `zsfa resume` it with a fresh cohort,
#                    byte-diff the result tree vs an uninterrupted run (CI)
#   make chaos-smoke fault-tolerance end-to-end: TCP serve/join with two
#                    chaos-transport participants (seeded drops, dups,
#                    resets, corrupt frames) plus one scripted straggler
#                    that holds a work order forever, byte-diff the result
#                    tree vs a clean fixed-clock run (CI)
#
# The smoke targets export ZSFA_FIXED_CLOCK=0 (telemetry::Clock) so wall_ms
# is pinned and whole result trees — raw CSVs included — byte-diff cleanly.
#   make fmt       rustfmt check (what CI enforces)
#   make lint      clippy with warnings denied (what CI enforces)
#   make python    editable-install the compile package + kernel tests
#   make artifacts AOT-lower the L2/L1 stack to HLO text (needs jax)
#   make ci        everything CI runs, locally

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-build bench-smoke bench-json determinism spec-smoke service-smoke metrics-smoke ckpt-smoke chaos-smoke fmt lint python artifacts ci clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	$(CARGO) bench

bench-build:
	$(CARGO) bench --no-run

# Execute every registered bench with a tiny iteration budget (release
# mode). The timings are meaningless; the point is that the bench *code*
# runs on every PR, which `cargo bench --no-run` cannot guarantee. Runs
# twice — default dispatch and ZSFA_SIMD=off — so both the SIMD and the
# scalar kernel paths execute (each run's in-bench exactness cross-checks
# then pin every available backend against the scalar reference).
bench-smoke:
	$(CARGO) bench -- --smoke
	ZSFA_SIMD=off $(CARGO) bench -- --smoke

# Machine-readable perf trajectory at the repo root (CI uploads these as
# artifacts): fused-vs-scalar compress throughput, CSA-vs-scalar vote
# accumulation at m in {64, 512, 4096}, and the streamed-vs-buffered dense
# reduce. Paths are absolute because cargo runs benches from rust/.
bench-json:
	$(CARGO) bench --bench bench_compress -- --json $(CURDIR)/BENCH_compress.json
	$(CARGO) bench --bench bench_aggregate -- --json $(CURDIR)/BENCH_aggregate.json
	$(CARGO) bench --bench bench_dense_reduce -- --json $(CURDIR)/BENCH_dense_reduce.json

# Reduce-order regression smoke: one scenario config at parallelism 1 and 8
# must produce byte-identical CSVs — raw CSVs included, because the fixed
# clock pins the wall_ms column. --reduce-lanes 3 < cohort forces
# multi-slot lanes, so the streamed in-lane fold (not its m <= L degenerate
# form) is what gets diffed. Runs in scratch dirs so ./results is never
# touched.
determinism: build
	rm -rf results_det_p1 results_det_p8
	mkdir -p results_det_p1 results_det_p8
	cd results_det_p1 && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa scenarios --rounds 30 \
	  --byz-rounds 30 --clients 24 --dim 1000 --repeats 1 \
	  --sim_target_cohort 8 --reduce-lanes 3 --parallelism 1
	cd results_det_p8 && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa scenarios --rounds 30 \
	  --byz-rounds 30 --clients 24 --dim 1000 --repeats 1 \
	  --sim_target_cohort 8 --reduce-lanes 3 --parallelism 8
	diff -r results_det_p1 results_det_p8
	@echo "determinism: parallelism 1 vs 8 CSVs are byte-identical (raw CSVs included)"

# Spec-vs-driver equivalence smoke: `zsfa run examples/quickstart.json`
# must reproduce the fig1 driver's CSVs byte-for-byte — raw files included,
# since ZSFA_FIXED_CLOCK pins the wall_ms column — at parallelism 1 AND 8.
# Extends the determinism-job pattern to the new run surface.
spec-smoke: build
	rm -rf results_spec_driver results_spec_run_p1 results_spec_run_p8
	mkdir -p results_spec_driver results_spec_run_p1 results_spec_run_p8
	cd results_spec_driver && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa fig1 \
	  --dims 50 --clients 8 --rounds 40 --repeats 2 --parallelism 1
	cd results_spec_run_p1 && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 1
	cd results_spec_run_p8 && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 8
	diff -r results_spec_driver results_spec_run_p1
	diff -r results_spec_driver results_spec_run_p8
	@echo "spec-smoke: zsfa run CSVs byte-identical to the fig1 driver at parallelism 1 and 8"

# Networked-service equivalence smoke (DESIGN.md §5): the example spec run
# three ways — in-process engine, the loopback service stack (full protocol
# encode/decode, 4 workers), and a real TCP coordinator with two joined
# participants on localhost — must produce byte-identical CSV trees, raw
# files included (ZSFA_FIXED_CLOCK pins wall_ms in every process).
# `timeout` bounds the TCP leg so a deadlocked round fails the job instead
# of hanging it.
service-smoke: build
	rm -rf results_svc_engine results_svc_loop results_svc_tcp
	mkdir -p results_svc_engine results_svc_loop results_svc_tcp
	cd results_svc_engine && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 1
	cd results_svc_loop && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --transport loopback --parallelism 4
	diff -r results_svc_engine results_svc_loop
	@set -e; cd results_svc_tcp; \
	  ZSFA_FIXED_CLOCK=0 timeout 180 ../target/release/zsfa serve ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7443 --min-participants 2 & srv=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7443 --patience-s 60 & j1=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7443 --patience-s 60 & j2=$$!; \
	  wait $$srv && wait $$j1 && wait $$j2
	diff -r results_svc_engine results_svc_tcp
	@echo "service-smoke: engine, loopback and TCP serve/join CSVs are byte-identical"

# Telemetry end-to-end smoke (DESIGN.md §6): one TCP serve/join session
# with --telemetry must (1) answer GET /metrics on the coordinator port
# with every required metric family while the session is live, (2) write a
# final --dump-metrics snapshot whose rounds_total is positive and >= the
# live scrape (counters are monotone), (3) render one `zsfa watch` frame
# from the endpoint, and (4) leave result CSVs byte-identical to a
# telemetry-off run — observability is strictly read-only.
metrics-smoke: build
	rm -rf results_metrics_off results_metrics_on metrics_scrape.txt metrics_dump.txt
	mkdir -p results_metrics_off results_metrics_on
	cd results_metrics_off && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 1
	@set -e; cd results_metrics_on; \
	  ZSFA_FIXED_CLOCK=0 timeout 180 ../target/release/zsfa serve ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7444 --min-participants 2 --telemetry \
	    --dump-metrics ../metrics_dump.txt & srv=$$!; \
	  for i in $$(seq 1 50); do \
	    ../target/release/zsfa metrics --addr 127.0.0.1:7444 \
	      > ../metrics_scrape.txt 2>/dev/null && break || sleep 0.2; \
	  done; \
	  ../target/release/zsfa watch --addr 127.0.0.1:7444 --once; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7444 --patience-s 60 & j1=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7444 --patience-s 60 & j2=$$!; \
	  wait $$srv && wait $$j1 && wait $$j2
	@set -e; for fam in zsfa_rounds_total zsfa_round_current zsfa_objective zsfa_sigma \
	  zsfa_bits_up_total zsfa_bits_down_total zsfa_clients_arrived_total \
	  zsfa_clients_selected_total zsfa_coord_replies_total zsfa_simd_path \
	  zsfa_checkpoints_total zsfa_resume_total \
	  zsfa_retries_total zsfa_faults_injected_total zsfa_timeouts_total \
	  zsfa_degraded_rounds_total zsfa_degraded_round_last \
	  zsfa_phase_ms zsfa_round_ms; do \
	  grep -q "^# TYPE $$fam " metrics_scrape.txt || { echo "scrape missing $$fam"; exit 1; }; \
	  grep -q "^# TYPE $$fam " metrics_dump.txt || { echo "dump missing $$fam"; exit 1; }; \
	done
	@set -e; s=$$(awk '$$1=="zsfa_rounds_total"{print $$2}' metrics_scrape.txt); \
	  d=$$(awk '$$1=="zsfa_rounds_total"{print $$2}' metrics_dump.txt); \
	  echo "metrics-smoke: rounds_total scrape=$$s dump=$$d"; \
	  test -n "$$s" && test -n "$$d" && test "$$d" -ge "$$s" && test "$$d" -gt 0
	diff -r results_metrics_off/results results_metrics_on/results
	@echo "metrics-smoke: families served, counters monotone, watch rendered, results byte-identical"

# Crash-recovery smoke (DESIGN.md §7): a TCP serve/join session with
# --checkpoint-every is kill -9'd once the first snapshot lands, then
# `zsfa resume <ckpt>` re-serves the embedded spec on the same address
# (the snapshot IS the spec — no drift possible), a fresh cohort joins,
# and the finished result tree must byte-diff clean against an
# uninterrupted fixed-clock run. The kill is deliberately untimed beyond
# "a snapshot exists": recovery must converge to the identical tree no
# matter where between round boundaries the SIGKILL lands (latest-wins
# snapshots + whole-file CSV writes at series end make this safe).
# quickstart.json's algorithms are stateless client-side, so a brand-new
# cohort resumes exactly (participant-held EF state is covered by
# rust/tests/integration_ckpt.rs instead).
ckpt-smoke: build
	rm -rf results_ckpt_ref results_ckpt_tcp ckpts_smoke
	mkdir -p results_ckpt_ref results_ckpt_tcp
	cd results_ckpt_ref && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 1
	@set -e; cd results_ckpt_tcp; \
	  ZSFA_FIXED_CLOCK=0 ../target/release/zsfa serve ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7445 --min-participants 2 \
	    --checkpoint-every 10 --checkpoint-dir ../ckpts_smoke & srv=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7445 --patience-s 60 & j1=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7445 --patience-s 60 & j2=$$!; \
	  for i in $$(seq 1 300); do \
	    test -f ../ckpts_smoke/fig1_d50.ckpt && break || sleep 0.2; \
	  done; \
	  test -f ../ckpts_smoke/fig1_d50.ckpt || { echo "no snapshot appeared"; \
	    kill -9 $$srv $$j1 $$j2 2>/dev/null; exit 1; }; \
	  kill -9 $$srv 2>/dev/null || true; \
	  echo "ckpt-smoke: coordinator kill -9'd after first snapshot"; \
	  wait $$j1 || true; wait $$j2 || true; wait $$srv || true
	@set -e; cd results_ckpt_tcp; \
	  ZSFA_FIXED_CLOCK=0 timeout 180 ../target/release/zsfa resume \
	    ../ckpts_smoke/fig1_d50.ckpt & srv=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7445 --patience-s 60 & j1=$$!; \
	  timeout 180 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7445 --patience-s 60 & j2=$$!; \
	  wait $$srv && wait $$j1 && wait $$j2
	diff -r results_ckpt_ref/results results_ckpt_tcp/results
	@echo "ckpt-smoke: killed-and-resumed TCP session byte-identical to the uninterrupted run"

# Chaos / graceful-degradation smoke (DESIGN.md §5.6): serve the example
# spec over TCP while two participants join through seeded fault-injecting
# transports (drops, duplicates, delays, resets, corrupt frames — the
# aggressive profile) and a third scripted straggler (`join --stall`)
# pulls one work order and never submits it. The coordinator must ride
# its round-deadline reclaim path (the chaos joiners repair the freed
# slot, so no round actually degrades), and the finished result tree must
# byte-diff clean against a clean fixed-clock engine run: fault handling
# is not allowed to change one byte of science. The straggler is reaped
# with `|| true` — it exits as soon as it observes Finished, but the
# coordinator owes it nothing after the run is over.
chaos-smoke: build
	rm -rf results_chaos_ref results_chaos_tcp
	mkdir -p results_chaos_ref results_chaos_tcp
	cd results_chaos_ref && ZSFA_FIXED_CLOCK=0 ../target/release/zsfa run \
	  ../rust/examples/quickstart.json --parallelism 1
	@set -e; cd results_chaos_tcp; \
	  ZSFA_FIXED_CLOCK=0 timeout 240 ../target/release/zsfa serve ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7446 --min-participants 2 --round-deadline-ms 2000 & srv=$$!; \
	  timeout 240 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7446 --patience-s 120 --chaos-seed 1001 & j1=$$!; \
	  timeout 240 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7446 --patience-s 120 --chaos-seed 2002 & j2=$$!; \
	  timeout 240 ../target/release/zsfa join ../rust/examples/quickstart.json \
	    --addr 127.0.0.1:7446 --patience-s 120 --stall & j3=$$!; \
	  wait $$srv && wait $$j1 && wait $$j2; wait $$j3 || true
	diff -r results_chaos_ref/results results_chaos_tcp/results
	@echo "chaos-smoke: chaos-transport TCP session byte-identical to the clean engine run"

fmt:
	$(CARGO) fmt --all -- --check

lint:
	$(CARGO) clippy --all-targets -- -D warnings

python:
	$(PYTHON) -m pip install -e python
	$(PYTHON) -m pytest python/tests -q

artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../artifacts

ci: build test fmt lint bench-build bench-smoke python
