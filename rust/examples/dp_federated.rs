//! DP-SignFedAvg (Algorithm 2) in practice: calibrate the Gaussian noise for
//! a target privacy budget with the RDP accountant, then run the clipped,
//! perturbed, sign-compressed pipeline and compare against uncompressed
//! DP-FedAvg — the sign step is free post-processing under DP.
//!
//!     cargo run --release --example dp_federated

use zsignfedavg::dp::{calibrate_noise, eps_for_noise};
use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::problems::logistic::Logistic;

fn main() {
    // Accounting setup: 200 clients, 20 sampled per round, 300 rounds.
    let (n, m, rounds) = (200usize, 20usize, 300usize);
    let q = m as f64 / n as f64;
    let delta = 1.0 / n as f64;

    println!("subsampled-Gaussian RDP accounting: q={q}, T={rounds}, delta={delta:.1e}\n");
    println!("{:>6} {:>12} {:>14}", "eps", "sigma(noise)", "check eps");
    let mut sigmas = Vec::new();
    for eps in [1.0f64, 2.0, 4.0, 8.0] {
        let sigma = calibrate_noise(q, rounds as u64, delta, eps);
        let back = eps_for_noise(q, sigma, rounds as u64, delta);
        println!("{eps:>6.1} {sigma:>12.3} {back:>14.3}");
        sigmas.push((eps, sigma));
    }

    println!("\nrunning DP-SignFedAvg vs DP-FedAvg on 200-client logistic regression");
    println!("{:>6} {:>22} {:>22}", "eps", "DP-SignFedAvg f(x)", "DP-FedAvg f(x)");
    let clip = 0.1f32;
    for &(eps, sigma) in &sigmas {
        let mut finals = Vec::new();
        for algo in [
            AlgorithmConfig::dp_signfedavg(clip, sigma as f32, 3).with_lrs(0.05, 0.5),
            AlgorithmConfig::dp_fedavg(clip, sigma as f32, 3).with_lrs(0.05, 5.0),
        ] {
            let mut b = AnalyticBackend::new(Logistic::generate(n, 50, 30, 0.5, 0.01, 5))
                .stochastic();
            let cfg = ServerConfig {
                rounds,
                clients_per_round: Some(m),
                eval_every: rounds / 5,
                ..Default::default()
            };
            let run = run_experiment(&mut b, &algo, &cfg);
            finals.push(run.final_objective());
        }
        println!("{eps:>6.1} {:>22.4} {:>22.4}", finals[0], finals[1]);
    }
    println!("\nThe sign column should track the dense column within a small gap at");
    println!("every eps, using 32x fewer uplink bits — Appendix F's headline.");
}
