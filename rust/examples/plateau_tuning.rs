//! The Plateau criterion (§4.4) without any grid search: start with a tiny
//! noise scale, let the controller grow σ whenever the objective stalls, and
//! compare against (a) the stall you get with σ fixed too small and (b) a
//! hand-tuned σ.
//!
//!     cargo run --release --example plateau_tuning

use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::plateau::PlateauConfig;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::problems::AnalyticProblem;
use zsignfedavg::rng::ZParam;

fn main() {
    let dim = 500;
    let f_star = Consensus::gaussian(10, dim, 3).optimal_value().unwrap();
    println!("consensus n=10 d={dim}, f* = {f_star:.4}\n");

    let rounds = 1200;
    let runs: Vec<(&str, f32, Option<PlateauConfig>)> = vec![
        ("sigma = 0.05 (too small, stalls)", 0.05, None),
        ("sigma = 3.0  (hand-tuned)", 3.0, None),
        (
            "plateau: 0.05 -> x1.5 on 20-round stall",
            0.05,
            Some(PlateauConfig { sigma_init: 0.05, sigma_bound: 16.0, kappa: 20, beta: 1.5 }),
        ),
    ];

    println!("{:<42} {:>12} {:>12} {:>10}", "schedule", "f-f* @ mid", "f-f* @ end", "final sigma");
    for (label, sigma, plateau) in runs {
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), sigma).with_lrs(0.01, 1.0);
        let cfg = ServerConfig {
            rounds,
            eval_every: 20,
            plateau,
            ..Default::default()
        };
        let mut b = AnalyticBackend::new(Consensus::gaussian(10, dim, 3));
        let run = run_experiment(&mut b, &algo, &cfg);
        let mid = run.records[run.records.len() / 2].objective - f_star;
        let end = run.final_objective() - f_star;
        let final_sigma = run.records.last().unwrap().sigma;
        println!("{label:<42} {mid:>12.5} {end:>12.5} {final_sigma:>10.3}");
    }
    println!("\nThe plateau schedule should land near the hand-tuned row without");
    println!("anyone having swept sigma — the paper's Fig. 6 in miniature.");
}
