//! The paper's §1 counterexample, live: why naive SignSGD diverges under
//! heterogeneous data, and how much noise fixes it (Theorem 2's threshold).
//!
//! Problem: min (x−A)² + (x+A)², A = 4, x0 = 2. For any x ∈ (−A, A) the two
//! clients' gradient signs cancel and vanilla SignSGD never moves. Uniform
//! noise below the σ > E(G+Q∞) threshold cannot flip the signs either
//! (Remark 2); Gaussian noise always can.
//!
//!     cargo run --release --example consensus_divergence

use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::problems::AnalyticProblem;
use zsignfedavg::rng::ZParam;

fn trajectory(algo: &AlgorithmConfig, rounds: usize) -> Vec<f64> {
    let mut b = AnalyticBackend::new(Consensus::counterexample(4.0));
    b.x0 = vec![2.0];
    let cfg = ServerConfig { rounds, eval_every: rounds / 10, ..Default::default() };
    run_experiment(&mut b, algo, &cfg).records.iter().map(|r| r.objective).collect()
}

fn main() {
    let f_star = Consensus::counterexample(4.0).optimal_value().unwrap();
    println!("min (x-4)^2 + (x+4)^2   from x0 = 2    (f* = {f_star})\n");
    let cases = vec![
        ("SignSGD (no noise)", AlgorithmConfig::signsgd().with_lrs(0.02, 1.0)),
        (
            "inf-SignSGD, sigma=1  (< threshold!)",
            AlgorithmConfig::z_signsgd(ZParam::Inf, 1.0).with_lrs(0.02, 1.0),
        ),
        (
            "inf-SignSGD, sigma=20 (> threshold)",
            AlgorithmConfig::z_signsgd(ZParam::Inf, 20.0).with_lrs(0.05, 1.0),
        ),
        (
            "1-SignSGD,   sigma=5  (Gaussian: unbounded support)",
            AlgorithmConfig::z_signsgd(ZParam::Finite(1), 5.0).with_lrs(0.05, 1.0),
        ),
    ];
    println!("{:<52} objective trajectory (f - f*)", "");
    for (label, algo) in cases {
        let traj = trajectory(&algo, 1000);
        let s: Vec<String> =
            traj.iter().step_by(2).map(|f| format!("{:7.3}", f - f_star)).collect();
        println!("{label:<52} {}", s.join(" "));
    }
    println!("\nRows 1-2 are pinned at the initial gap: the sign votes cancel exactly.");
    println!("Rows 3-4 decay towards 0: the stochastic sign is asymptotically unbiased.");
}
