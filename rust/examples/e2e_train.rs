//! END-TO-END VALIDATION DRIVER (DESIGN.md §8): the full three-layer stack
//! on a real small workload.
//!
//! Trains the paper's 2-conv CNN on synthMNIST federated across 10
//! label-skewed clients with **1-SignFedAvg (E = 5 local steps)** for a few
//! hundred rounds, entirely through the production path:
//!
//!   Rust coordinator (this binary)
//!     └─ PJRT CPU client (xla crate)
//!          ├─ mnist_cnn_local_update_e5.hlo.txt   (L2 scan of 5 SGD steps,
//!          │                                       L1 fused-axpy kernel inside)
//!          ├─ mnist_cnn_compress_z1.hlo.txt       (L1 Pallas stochastic-sign)
//!          └─ mnist_cnn_eval_step.hlo.txt
//!
//! Logs the loss curve, test accuracy and exact uplink bits; compares
//! against uncompressed FedAvg at equal round budget. Recorded in
//! EXPERIMENTS.md §End-to-end.
//!
//!     make artifacts && cargo run --release --example e2e_train [rounds]

use std::path::Path;
use zsignfedavg::data::{partition, synth};
use zsignfedavg::fl::backend::TrainBackend;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::rng::ZParam;
use zsignfedavg::runtime::{ModelRuntime, XlaBackend};
use zsignfedavg::telemetry::Clock;

fn build_backend() -> XlaBackend {
    let dir = Path::new("artifacts");
    assert!(
        dir.join("manifest.json").exists(),
        "artifacts/ missing — run `make artifacts` first"
    );
    let rt = ModelRuntime::open(dir, "mnist_cnn").expect("opening mnist_cnn artifacts");
    let init = rt.load_init().expect("loading init params");
    let eval_batch = rt.eval_batch;
    let (train, test) = synth::train_test(synth::SynthSpec::mnist(), 2000, 2 * eval_batch);
    let fed = partition::by_label(train, 10); // one digit per client (§4.2)
    XlaBackend::new(rt, fed, test, init)
}

fn main() {
    let rounds: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let e = 5;
    println!("e2e: mnist_cnn, 10 label-skewed clients, E={e}, {rounds} rounds\n");

    for algo in [
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 0.05, e).with_lrs(0.05, 0.4),
        AlgorithmConfig::fedavg(e).with_lrs(0.05, 1.0),
    ] {
        let mut backend = build_backend();
        let d = backend.dim();
        println!("-- {} (d = {d}) --", algo.name);
        let cfg = ServerConfig { rounds, eval_every: (rounds / 20).max(1), ..Default::default() };
        let t = Clock::Monotonic.start();
        let run = run_experiment(&mut backend, &algo, &cfg);
        let secs = t.elapsed_secs();
        println!("round   loss     acc      cumulative uplink");
        for r in run.records.iter().step_by((run.records.len() / 10).max(1)) {
            println!(
                "{:>5} {:>8.4} {:>7.2}% {:>12.2} Mbit",
                r.round,
                r.objective,
                100.0 * r.accuracy.unwrap_or(f64::NAN),
                r.bits_up as f64 / 1e6
            );
        }
        let last = run.records.last().unwrap();
        println!(
            "final: loss {:.4}, accuracy {:.2}%, uplink {:.2} Mbit, {:.1}s wall, {} PJRT execs\n",
            last.objective,
            100.0 * last.accuracy.unwrap(),
            last.bits_up as f64 / 1e6,
            secs,
            backend.runtime.engine.num_executions,
        );
    }
    println!("Shape check: 1-SignFedAvg should reach FedAvg-level accuracy with");
    println!("32x fewer uplink bits — the paper's headline result end to end.");
}
