//! Quickstart: 60 lines from zero to a converged sign-compressed federated
//! run.
//!
//! Builds a 10-client heterogeneous consensus problem, runs uncompressed
//! GD, vanilla SignSGD and the paper's 1-SignSGD side by side, and prints
//! objective + exact uplink bits — the paper's pitch in one screen.
//!
//!     cargo run --release --example quickstart

use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::problems::AnalyticProblem;
use zsignfedavg::rng::ZParam;

fn main() {
    // A 10-client, 1000-dimensional consensus problem — each client pulls
    // the model toward its own Gaussian target (maximal heterogeneity).
    let dim = 1000;
    let problem = Consensus::gaussian(10, dim, 7);
    let f_star = problem.optimal_value().unwrap();
    println!("consensus problem: n=10, d={dim}, f* = {f_star:.4}\n");

    let algorithms = vec![
        // Uncompressed baseline: 32 bits per coordinate on the uplink.
        AlgorithmConfig::gd().with_lrs(0.01, 1.0),
        // Naive 1-bit signs: stalls under heterogeneity (paper §1).
        AlgorithmConfig::signsgd().with_lrs(0.01, 1.0),
        // The paper's fix: perturb with Gaussian noise before the sign.
        AlgorithmConfig::z_signsgd(ZParam::Finite(1), 3.0).with_lrs(0.01, 1.0),
    ];

    println!(
        "{:<22} {:>14} {:>14} {:>12}",
        "algorithm", "final f - f*", "uplink (Mbit)", "bits/coord"
    );
    for algo in &algorithms {
        let rounds = 2000;
        let mut backend = AnalyticBackend::new(Consensus::gaussian(10, dim, 7));
        let cfg = ServerConfig { rounds, eval_every: 100, ..Default::default() };
        let run = run_experiment(&mut backend, algo, &cfg);
        let gap = run.final_objective() - f_star;
        let bits = run.total_bits();
        let per_coord = bits as f64 / (rounds as f64 * 10.0 * dim as f64);
        println!(
            "{:<22} {:>14.6} {:>14.2} {:>12.0}",
            algo.name,
            gap,
            bits as f64 / 1e6,
            per_coord
        );
    }
    println!("\n1-SignSGD matches GD at 1/32 of the uplink — that's the paper.");
}
