//! Cross-module integration tests on analytic workloads: experiment-level
//! behaviours that single-module unit tests can't see (paper-shape
//! assertions, seeds-to-CSV plumbing, property tests over the whole round
//! loop).

use zsignfedavg::compress::pack::PackedSigns;
use zsignfedavg::compress::sign::{SigmaRule, StochasticSign};
use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::metrics::aggregate;
use zsignfedavg::fl::server::{run_experiment, Participation, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::problems::least_squares::LeastSquares;
use zsignfedavg::problems::logistic::Logistic;
use zsignfedavg::problems::AnalyticProblem;
use zsignfedavg::rng::{Pcg64, ZParam};
use zsignfedavg::sim::{ByzantineMode, FleetPreset, ScenarioConfig};
use zsignfedavg::testutil::{gen_vec_f32, prop_check, PropConfig};

/// Fig. 1 shape: at high dimension, Sto-SignSGD's input-dependent noise
/// scale (sigma = ||delta||_2 grows like sqrt(d)) makes it much slower than
/// 1-SignSGD with a fixed sigma.
#[test]
fn sto_sign_suffers_at_high_dimension() {
    let d = 2000;
    let rounds = 300;
    let cfg = ServerConfig { rounds, eval_every: rounds - 1, ..Default::default() };
    let f_star = Consensus::gaussian(10, d, 5).optimal_value().unwrap();
    let gap = |algo: &AlgorithmConfig| {
        let mut b = AnalyticBackend::new(Consensus::gaussian(10, d, 5));
        run_experiment(&mut b, algo, &cfg).final_objective() - f_star
    };
    let fixed = gap(&AlgorithmConfig::z_signsgd(ZParam::Finite(1), 3.0).with_lrs(0.01, 1.0));
    let input_dep = gap(&AlgorithmConfig::sto_signsgd().with_lrs(0.01, 1.0));
    assert!(
        fixed * 3.0 < input_dep,
        "fixed-sigma gap {fixed} should beat input-dependent {input_dep} by >3x at d={d}"
    );
}

/// Fig. 1 shape: vanilla SignSGD's floor is far above 1-SignSGD's.
#[test]
fn noise_beats_vanilla_sign_on_heterogeneous_problem() {
    // The sign drift rate is ~ gamma/(eta_1*sigma) per round, so the run
    // needs O(eta_1*sigma/gamma) rounds to contract: 1500 @ gamma=0.01,sigma=3.
    let d = 500;
    let rounds = 1500;
    let cfg = ServerConfig { rounds, eval_every: rounds - 1, ..Default::default() };
    let f_star = Consensus::gaussian(10, d, 5).optimal_value().unwrap();
    let gap = |algo: &AlgorithmConfig| {
        let mut b = AnalyticBackend::new(Consensus::gaussian(10, d, 5));
        run_experiment(&mut b, algo, &cfg).final_objective() - f_star
    };
    let vanilla = gap(&AlgorithmConfig::signsgd().with_lrs(0.01, 1.0));
    let stochastic = gap(&AlgorithmConfig::z_signsgd(ZParam::Finite(1), 3.0).with_lrs(0.01, 1.0));
    assert!(
        stochastic * 5.0 < vanilla,
        "1-SignSGD gap {stochastic} should beat vanilla {vanilla} by >5x"
    );
}

/// Theorem 1's linear-speedup flavour: more clients reduce the stochastic
/// floor — the sign-vote mean has variance 1/n, so the stationary optimality
/// gap of 1-SignSGD on consensus scales like 1/n (theory: OU floor
/// gamma^2/(n·2k), k = gamma·2·phi(0)/sigma).
#[test]
fn more_clients_lower_floor() {
    let rounds = 1500;
    let cfg = ServerConfig { rounds, eval_every: rounds - 1, ..Default::default() };
    let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 4.0).with_lrs(0.02, 1.0);
    let floor = |n: usize| {
        let mut b = AnalyticBackend::new(Consensus::gaussian(n, 100, 3));
        let f_star = b.problem.optimal_value().unwrap();
        let run = run_experiment(&mut b, &algo, &cfg);
        run.final_objective() - f_star
    };
    let few = floor(2);
    let many = floor(32);
    assert!(
        many * 4.0 < few,
        "n=32 floor {many} should be ~16x below n=2 floor {few}"
    );
}

/// E local steps reduce rounds-to-accuracy (the FedAvg benefit, Fig. 5).
#[test]
fn local_steps_accelerate_per_round() {
    let cfg = ServerConfig { rounds: 60, eval_every: 59, ..Default::default() };
    let f_star = Consensus::gaussian(8, 100, 9).optimal_value().unwrap();
    let gap = |e: usize| {
        let mut b = AnalyticBackend::new(Consensus::gaussian(8, 100, 9));
        let algo = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 6.0, e).with_lrs(0.02, 1.0);
        run_experiment(&mut b, &algo, &cfg).final_objective() - f_star
    };
    let e1 = gap(1);
    let e5 = gap(5);
    assert!(e5 < e1, "E=5 gap {e5} should beat E=1 gap {e1} at equal rounds");
}

/// QSGD uses more bits per round than sign compression at every s (Fig. 16's
/// x-axis), with exact accounting.
#[test]
fn qsgd_bits_exceed_sign_bits() {
    let d = 97;
    let cfg = ServerConfig { rounds: 5, eval_every: 4, ..Default::default() };
    let bits = |algo: &AlgorithmConfig| {
        let mut b = AnalyticBackend::new(Consensus::gaussian(4, d, 1));
        run_experiment(&mut b, algo, &cfg).total_bits()
    };
    let sign = bits(&AlgorithmConfig::signsgd().with_lrs(0.01, 1.0));
    assert_eq!(sign, 5 * 4 * d as u64);
    let mut prev = sign;
    for s in [1u32, 2, 4, 8] {
        let q = bits(&AlgorithmConfig::qsgd(s).with_lrs(0.01, 1.0));
        assert!(q > prev, "QSGD(s={s}) bits {q} should exceed {prev}");
        prev = q;
    }
}

/// Whole-loop property: for any seed/params the aggregated sign update has
/// every |coordinate| <= eta*gamma (votes are means of +-1) and params stay
/// finite — the coordinator can't blow up no matter the compression noise.
#[test]
fn prop_round_loop_bounded_updates() {
    prop_check(
        PropConfig { cases: 20, max_size: 60, seed: 0xfed },
        |rng, size| {
            let d = 2 + size;
            let n = 2 + (rng.below(6) as usize);
            let sigma = rng.uniform_in(0.0, 10.0) as f32;
            let seed = rng.next_u64();
            (d, n, sigma, seed)
        },
        |&(d, n, sigma, seed)| {
            let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, seed));
            let algo =
                AlgorithmConfig::z_signsgd(ZParam::Finite(1), sigma).with_lrs(0.05, 1.0);
            let cfg = ServerConfig { rounds: 20, eval_every: 1, seed, ..Default::default() };
            let run = run_experiment(&mut b, &algo, &cfg);
            for rec in &run.records {
                if !rec.objective.is_finite() {
                    return Err(format!("objective diverged: {}", rec.objective));
                }
            }
            // Objective can increase transiently but must stay bounded by
            // f(x0) + T * (max per-round increase = L * (eta*gamma*sqrt(d))...)
            let f0 = run.records.first().unwrap().objective;
            let fmax = run.records.iter().map(|r| r.objective).fold(0.0, f64::max);
            let bound = f0 + 20.0 * 0.05 * 0.05 * (d as f64) * 10.0 + 10.0;
            if fmax > bound {
                return Err(format!("objective exploded: {fmax} > {bound}"));
            }
            Ok(())
        },
    );
}

/// Property: Rust StochasticSign as used by the server always produces
/// packable +-1 vectors whose packed form round-trips (codec invariant over
/// the *actual* compressor output, not synthetic signs).
#[test]
fn prop_compressor_output_packs_exactly() {
    prop_check(
        PropConfig { cases: 50, max_size: 3000, seed: 0xc0dec },
        |rng, size| {
            let x = gen_vec_f32(rng, size.max(1), 5.0);
            let sigma = rng.uniform_in(0.0, 3.0) as f32;
            let z = if rng.below(2) == 0 { ZParam::Finite(1) } else { ZParam::Inf };
            let seed = rng.next_u64();
            (x, sigma, z, seed)
        },
        |(x, sigma, z, seed)| {
            let mut rng = Pcg64::seeded(*seed);
            let mut c = StochasticSign::new(*z, SigmaRule::Fixed(*sigma));
            let mut signs = vec![0i8; x.len()];
            c.compress_into(x, &mut rng, &mut signs);
            if !signs.iter().all(|&s| s == 1 || s == -1) {
                return Err("non +-1 sign".into());
            }
            let packed = PackedSigns::from_signs(&signs);
            let mut back = vec![0i8; x.len()];
            packed.unpack_into(&mut back);
            if back != signs {
                return Err("pack round-trip mismatch".into());
            }
            Ok(())
        },
    );
}

/// Repeat aggregation: mean curve of identical seeds has zero std; distinct
/// seeds have nonzero std (the mean±std machinery behind every figure).
#[test]
fn repeats_aggregate_sanely() {
    let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.02, 1.0);
    let mk = || AnalyticBackend::new(Consensus::gaussian(5, 50, 2));
    let run_seed = |seed| {
        let cfg = ServerConfig { rounds: 30, eval_every: 5, seed, ..Default::default() };
        run_experiment(&mut mk(), &algo, &cfg)
    };
    let same = aggregate(&[run_seed(1), run_seed(1)]);
    assert!(same.objective_std.iter().all(|&s| s == 0.0));
    let diff = aggregate(&[run_seed(1), run_seed(2), run_seed(3)]);
    assert!(diff.objective_std.iter().skip(1).any(|&s| s > 0.0));
}

/// The round engine's cross-module contract: the `parallelism` knob never
/// changes the result — here with stochastic minibatch gradients, E > 1
/// local steps *and* partial participation in the mix, the adversarial case
/// for any hidden execution-order dependence.
#[test]
fn parallelism_never_changes_results() {
    let algo = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 3).with_lrs(0.02, 1.0);
    let run = |par: usize| {
        let mut b =
            AnalyticBackend::new(LeastSquares::generate(12, 40, 15, 0.5, 0.5, 3)).stochastic();
        let cfg = ServerConfig {
            rounds: 10,
            eval_every: 2,
            seed: 21,
            parallelism: par,
            clients_per_round: Some(6),
            ..Default::default()
        };
        run_experiment(&mut b, &algo, &cfg)
    };
    let base = run(1);
    assert!(base.final_objective().is_finite());
    for par in [2usize, 8] {
        let r = run(par);
        assert_eq!(base.records.len(), r.records.len());
        for (a, b) in base.records.iter().zip(&r.records) {
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "par={par}");
            assert_eq!(
                a.grad_norm_sq.map(f64::to_bits),
                b.grad_norm_sq.map(f64::to_bits),
                "par={par}"
            );
            assert_eq!(a.bits_up, b.bits_up, "par={par}");
        }
    }
}

/// A full-strength byzantine scenario: every selected client reports
/// (uniform fleet, no deadline pressure), a seed-pinned subset lies.
fn byz_scenario(n: usize, frac: f32, mode: ByzantineMode) -> ScenarioConfig {
    ScenarioConfig {
        target_cohort: n,
        overselect: 1.0,
        deadline_s: 1e6,
        round_latency_s: 0.0,
        dropout_prob: 0.0,
        byzantine_frac: frac,
        byzantine_mode: mode,
        fleet: FleetPreset::Uniform,
    }
}

/// Final optimality gap of `algo` on consensus under a byzantine scenario.
fn byz_gap(algo: &AlgorithmConfig, n: usize, frac: f32, mode: ByzantineMode) -> f64 {
    let mut b = AnalyticBackend::new(Consensus::gaussian(n, 30, 5));
    let f_star = b.problem.optimal_value().unwrap();
    let cfg = ServerConfig {
        rounds: 300,
        eval_every: 299,
        seed: 11,
        participation: Participation::Simulated(byz_scenario(n, frac, mode)),
        ..Default::default()
    };
    run_experiment(&mut b, algo, &cfg).final_objective() - f_star
}

/// The scenario subsystem's acceptance claim (Jin et al.; Xiang & Su):
/// majority-vote sign aggregation degrades more gracefully than the dense
/// mean under ≥10% byzantine sign-flippers — each attacker is worth ±1 per
/// coordinate, while the dense mean inherits whatever it reports.
#[test]
fn sign_votes_degrade_more_gracefully_under_byzantine_clients() {
    let n = 20; // 10% => exactly 2 seed-pinned sign-flippers
    let sign = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0);
    let dense = AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0);

    // Relative degradation vs each algorithm's own byzantine-free floor.
    let flip = ByzantineMode::SignFlip;
    let deg_sign = byz_gap(&sign, n, 0.1, flip) / byz_gap(&sign, n, 0.0, flip).max(1e-12);
    let deg_dense = byz_gap(&dense, n, 0.1, flip) / byz_gap(&dense, n, 0.0, flip).max(1e-12);
    assert!(
        deg_sign < deg_dense,
        "sign degradation {deg_sign:.3e} should be below dense {deg_dense:.3e}"
    );

    // Magnitude attack: a 10x-boosted negated gradient flips the dense
    // mean's direction outright; the sign vote clips it to one vote.
    let boost = ByzantineMode::GradNegate { boost: 10.0 };
    let g_sign = byz_gap(&sign, n, 0.1, boost);
    let g_dense = byz_gap(&dense, n, 0.1, boost);
    assert!(g_sign.is_finite());
    assert!(
        !g_dense.is_finite() || g_sign < g_dense,
        "boosted attack: sign gap {g_sign:.3e} vs dense {g_dense:.3e}"
    );
}

/// Scenario runs (stragglers + dropouts + byzantine clients) keep the
/// engine's cross-module contract: `parallelism` never changes the result,
/// including the new lifecycle fields.
#[test]
fn scenario_parallelism_never_changes_results() {
    let algo = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 3).with_lrs(0.02, 1.0);
    let sc = ScenarioConfig {
        target_cohort: 6,
        overselect: 1.5,
        deadline_s: 0.5,
        round_latency_s: 0.1,
        dropout_prob: 0.2,
        byzantine_frac: 0.2,
        byzantine_mode: ByzantineMode::SignFlip,
        fleet: FleetPreset::CrossDevice,
    };
    let run = |par: usize| {
        let mut b =
            AnalyticBackend::new(LeastSquares::generate(12, 40, 15, 0.5, 0.5, 3)).stochastic();
        let cfg = ServerConfig {
            rounds: 10,
            eval_every: 2,
            seed: 21,
            parallelism: par,
            participation: Participation::Simulated(sc.clone()),
            ..Default::default()
        };
        run_experiment(&mut b, &algo, &cfg)
    };
    let base = run(1);
    assert!(base.final_objective().is_finite());
    for par in [2usize, 8] {
        let r = run(par);
        assert_eq!(base.records.len(), r.records.len());
        for (a, b) in base.records.iter().zip(&r.records) {
            assert_eq!(a.objective.to_bits(), b.objective.to_bits(), "par={par}");
            assert_eq!(a.bits_up, b.bits_up, "par={par}");
            assert_eq!(a.sim_time_s.to_bits(), b.sim_time_s.to_bits(), "par={par}");
            assert_eq!(a.arrived, b.arrived, "par={par}");
            assert_eq!(a.selected, b.selected, "par={par}");
        }
    }
}

/// The streamed lane reduce, cross-module: with `reduce_lanes` far below
/// the cohort (multi-slot lanes — the fold the unified aggregator actually
/// streams), stochastic minibatch gradients and a lifecycle scenario in the
/// mix, the result is still a pure function of the plan — bit-identical
/// across `parallelism`, for a sign and a dense family member alike.
#[test]
fn streamed_lane_reduce_is_parallelism_invariant_end_to_end() {
    let sc = ScenarioConfig {
        target_cohort: 10,
        overselect: 1.4,
        deadline_s: 0.6,
        round_latency_s: 0.1,
        dropout_prob: 0.15,
        byzantine_frac: 0.1,
        byzantine_mode: ByzantineMode::SignFlip,
        fleet: FleetPreset::CrossDevice,
    };
    for algo in [
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.02, 1.0),
        AlgorithmConfig::qsgd(2).with_lrs(0.02, 1.0),
    ] {
        let run = |par: usize| {
            let mut b = AnalyticBackend::new(LeastSquares::generate(16, 40, 15, 0.5, 0.5, 3))
                .stochastic();
            let cfg = ServerConfig {
                rounds: 8,
                eval_every: 1,
                seed: 33,
                parallelism: par,
                reduce_lanes: 3,
                participation: Participation::Simulated(sc.clone()),
                ..Default::default()
            };
            run_experiment(&mut b, &algo, &cfg)
        };
        let base = run(1);
        assert!(base.final_objective().is_finite());
        for par in [2usize, 3, 8] {
            let r = run(par);
            assert_eq!(base.records.len(), r.records.len());
            for (a, b) in base.records.iter().zip(&r.records) {
                assert_eq!(
                    a.objective.to_bits(),
                    b.objective.to_bits(),
                    "{} par={par}",
                    algo.name
                );
                assert_eq!(a.bits_up, b.bits_up, "{} par={par}", algo.name);
                assert_eq!(a.arrived, b.arrived, "{} par={par}", algo.name);
            }
        }
    }
}

/// DP pipeline on a convex problem: smaller noise (=> larger eps) gives a
/// better objective; the clip keeps updates finite even with huge noise.
#[test]
fn dp_sign_noise_hurts_monotonically() {
    let rounds = 200;
    let cfg = ServerConfig { rounds, eval_every: rounds - 1, ..Default::default() };
    let obj = |noise: f32| {
        let mut b = AnalyticBackend::new(Logistic::generate(20, 30, 20, 0.3, 0.01, 7));
        let algo = AlgorithmConfig::dp_signfedavg(0.5, noise, 2).with_lrs(0.05, 0.5);
        run_experiment(&mut b, &algo, &cfg).final_objective()
    };
    let low_noise = obj(0.1);
    let high_noise = obj(8.0);
    assert!(low_noise < high_noise, "noise 0.1 -> {low_noise}, noise 8 -> {high_noise}");
    assert!(high_noise.is_finite());
}
