//! Checkpoint/resume integration: crash-tolerant sessions with
//! byte-identical recovery (`ckpt::Snapshot` + `Session::resume`).
//!
//! The headline harness runs every algorithm preset to a round boundary,
//! snapshots, simulates the crash (CSVs missing, a torn JSONL line),
//! rebuilds a fresh session from the snapshot and `diff -r`s the full
//! result tree against an uninterrupted reference — across engine and
//! loopback transports at parallelism 1 and 8. A TCP coordinator is
//! additionally killed (panic mid-loop, host dropped) at a round boundary
//! and replaced, with the surviving participant re-rendezvousing — the
//! error-feedback residuals it privately holds are the state the
//! replacement cannot reconstruct, which is exactly what the test pins.
//!
//! Everything runs under `ZSFA_FIXED_CLOCK` so `wall_ms` (a CSV/JSONL
//! column) is deterministic; metrics dumps are excluded from the byte
//! diff because `zsfa_checkpoints_total`/`zsfa_resume_total` differ
//! between an interrupted and an uninterrupted run *by design* — those
//! counters are asserted directly instead.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::mpsc;
use std::time::Duration;

use zsignfedavg::api::{
    CsvSink, ExperimentSpec, JsonlSink, Session, TransportSpec, WorkloadSpec,
};
use zsignfedavg::ckpt::{CheckpointPolicy, Snapshot};
use zsignfedavg::error::ErrorKind;
use zsignfedavg::fl::engine::{CkptHook, EngineCkpt};
use zsignfedavg::fl::{run_experiment, AlgorithmConfig, RunResult};
use zsignfedavg::rng::ZParam;
use zsignfedavg::service::{Participant, ServiceHost, TcpTransport};
use zsignfedavg::telemetry::{Telemetry, FIXED_CLOCK_ENV};

/// Pin the wall clock for the whole process. Every test calls this first;
/// concurrent calls store the same value, so the race is benign.
fn fixed_clock() {
    std::env::set_var(FIXED_CLOCK_ENV, "0");
}

/// The twelve algorithm presets of the service byte-identity suite.
fn families() -> Vec<AlgorithmConfig> {
    vec![
        AlgorithmConfig::gd().with_lrs(0.05, 1.0),
        AlgorithmConfig::fedavg(3).with_lrs(0.05, 1.0),
        AlgorithmConfig::signsgd().with_lrs(0.05, 1.0),
        AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0).with_lrs(0.05, 1.0),
        AlgorithmConfig::z_signsgd(ZParam::Inf, 2.0).with_lrs(0.05, 1.0),
        AlgorithmConfig::sto_signsgd().with_lrs(0.05, 1.0),
        AlgorithmConfig::ef_signsgd().with_lrs(0.05, 1.0),
        AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0),
        AlgorithmConfig::topk(0.25, 1).with_lrs(0.05, 1.0),
        AlgorithmConfig::sparse_sign(0.25, ZParam::Finite(1), 1.0, 1).with_lrs(0.05, 1.0),
        AlgorithmConfig::dp_signfedavg(0.5, 1.0, 2).with_lrs(0.05, 0.5),
        AlgorithmConfig::dp_fedavg(0.5, 1.0, 2).with_lrs(0.05, 0.5),
    ]
}

fn spec_for(
    algo: AlgorithmConfig,
    name: &str,
    out: &Path,
    transport: TransportSpec,
    parallelism: usize,
) -> ExperimentSpec {
    ExperimentSpec::new(name, WorkloadSpec::consensus(16, 37, 1234))
        .rounds(8)
        .eval_every(2)
        .repeats(2)
        .seed(13)
        .reduce_lanes(3)
        .parallelism(parallelism)
        .transport(transport)
        .output_dir(out)
        .series(algo)
}

/// The observer stack both the original run and the resume must share
/// (same order — the snapshot's observer marks are positional).
fn session_for(dir: &Path, append: bool) -> Session {
    let events = dir.join("events.jsonl");
    let sink = if append {
        JsonlSink::append(&events)
    } else {
        JsonlSink::create(&events)
    }
    .unwrap();
    Session::new().with(CsvSink::new()).with(sink)
}

/// Read a directory tree into relative-path → bytes.
fn tree(root: &Path) -> BTreeMap<String, Vec<u8>> {
    fn walk(base: &Path, dir: &Path, out: &mut BTreeMap<String, Vec<u8>>) {
        for e in std::fs::read_dir(dir).unwrap() {
            let p = e.unwrap().path();
            if p.is_dir() {
                walk(base, &p, out);
            } else {
                let rel = p.strip_prefix(base).unwrap().to_string_lossy().to_string();
                out.insert(rel, std::fs::read(&p).unwrap());
            }
        }
    }
    let mut out = BTreeMap::new();
    walk(root, root, &mut out);
    out
}

/// `diff -r`, in-process: same file set, same bytes.
fn assert_trees_identical(a: &Path, b: &Path, what: &str) {
    let (ta, tb) = (tree(a), tree(b));
    let ka: Vec<&String> = ta.keys().collect();
    let kb: Vec<&String> = tb.keys().collect();
    assert_eq!(ka, kb, "{what}: file sets differ");
    for (k, va) in &ta {
        assert_eq!(va, &tb[k], "{what}: {k} differs");
    }
}

fn assert_records_identical(want: &RunResult, got: &RunResult, what: &str) {
    assert_eq!(want.records.len(), got.records.len(), "{what}: record count");
    for (x, y) in want.records.iter().zip(&got.records) {
        // Full equality including wall_ms — the fixed clock pins it.
        assert_eq!(x, y, "{what}: round {}", x.round);
    }
}

#[test]
fn every_preset_resumes_to_a_byte_identical_result_tree() {
    fixed_clock();
    let base = std::env::temp_dir().join("zsfa_ckpt_tree_test");
    std::fs::remove_dir_all(&base).ok();
    for (i, algo) in families().into_iter().enumerate() {
        let name = format!("ckpt{i}");
        // The uninterrupted reference (engine transport, parallelism 1);
        // every crashed-and-resumed tree below must match it byte for
        // byte, which simultaneously pins the transport/parallelism
        // determinism contract and the resume path.
        let dir_a = base.join(format!("{name}_ref"));
        let spec_a = spec_for(algo.clone(), &name, &dir_a, TransportSpec::Engine, 1);
        session_for(&dir_a, false).run(&spec_a).unwrap();

        for (transport, tlabel) in
            [(TransportSpec::Engine, "engine"), (TransportSpec::Loopback, "loopback")]
        {
            for parallelism in [1usize, 8] {
                let what = format!("{name} {tlabel} p{parallelism}");
                let dir_b = base.join(format!("{name}_{tlabel}_{parallelism}"));
                let ckpt_dir = base.join(format!("{name}_{tlabel}_{parallelism}_ckpt"));
                let spec_b =
                    spec_for(algo.clone(), &name, &dir_b, transport.clone(), parallelism);
                let policy = CheckpointPolicy::every(&ckpt_dir, 3);
                session_for(&dir_b, false).run_with_checkpoints(&spec_b, &policy).unwrap();

                // Simulate the crash at the last capture (series 0,
                // repeat 1, round 6): at that moment no CSVs existed yet
                // (they are written at series end) and the event log held
                // only the pre-checkpoint lines — plus whatever torn
                // partial line the dying process managed to emit. The
                // JSONL rollback to the observer mark happens inside
                // resume; the CSV subtree we remove by hand.
                let snap = Snapshot::load(&policy.path_for(&name)).unwrap();
                assert_eq!(
                    (snap.series, snap.repeat, snap.engine.next_round),
                    (0, 1, 6),
                    "{what}"
                );
                std::fs::remove_dir_all(dir_b.join(&name)).unwrap();
                {
                    use std::io::Write as _;
                    let mut f = std::fs::OpenOptions::new()
                        .append(true)
                        .open(dir_b.join("events.jsonl"))
                        .unwrap();
                    write!(f, "{{\"event\":\"round\",\"torn").unwrap();
                }

                session_for(&dir_b, true)
                    .resume(&spec_b, &snap, &CheckpointPolicy::off())
                    .unwrap();
                assert_trees_identical(&dir_a, &dir_b, &what);
            }
        }
    }
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn tcp_coordinator_killed_at_a_round_boundary_resumes_bit_identical() {
    fixed_clock();
    // EF-SignSGD is the hard case: over TCP the residuals live *only* in
    // the participant process, so recovery depends on the participant
    // outliving the coordinator and re-rendezvousing with its state
    // intact. A single participant keeps the client→pid affinity trivially
    // stable across the replacement.
    for algo in [
        AlgorithmConfig::ef_signsgd().with_lrs(0.05, 1.0),
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0),
    ] {
        let spec = ExperimentSpec::new("tcpckpt", WorkloadSpec::consensus(10, 13, 2024))
            .rounds(6)
            .seed(11)
            .reduce_lanes(3)
            .series(algo);
        let algo = spec.expanded_series()[0].algorithm.clone();
        let cfg = spec.server_config(0);
        let mut backend = spec.workload.build_backend().unwrap();
        let want = run_experiment(backend.as_mut(), &algo, &cfg);

        let host = ServiceHost::tcp("127.0.0.1:0", 500, 30_000, 1, &Telemetry::disabled())
            .unwrap();
        let addr1 = host.local_addr().unwrap().to_string();
        let (ck_tx, ck_rx) = mpsc::channel::<(EngineCkpt, Vec<(u64, u64)>)>();
        let (addr_tx, addr_rx) = mpsc::channel::<String>();

        // The participant outlives the coordinator: it works for host 1
        // until the crash, keeps its residuals, then joins the
        // replacement.
        let spec_p = spec.clone();
        let worker = std::thread::spawn(move || {
            let mut p = Participant::new(spec_p);
            let mut t = TcpTransport::connect(&addr1, Duration::from_secs(10)).unwrap();
            let _ = p.run(&mut t); // ends (Ok or transport error) when host 1 dies
            let addr2 = addr_rx.recv().unwrap();
            let mut t2 = TcpTransport::connect(&addr2, Duration::from_secs(10)).unwrap();
            p.run(&mut t2)
        });

        // Host 1 "crashes" at the round-4 boundary: the capture hook
        // panics, unwinding out of the round loop before round 4 is ever
        // offered — the same cut point as a kill -9 between rounds — and
        // the host is dropped.
        struct KillAt(u64, mpsc::Sender<(EngineCkpt, Vec<(u64, u64)>)>, Vec<(u64, u64)>);
        impl CkptHook for KillAt {
            fn want(&mut self, next_round: u64) -> bool {
                next_round == self.0
            }
            fn store_pins(&mut self, pins: Vec<(u64, u64)>) {
                self.2 = pins;
            }
            fn store(&mut self, ck: EngineCkpt) {
                self.1.send((ck, std::mem::take(&mut self.2))).unwrap();
                panic!("simulated coordinator crash");
            }
        }
        let spec_c = spec.clone();
        let algo_c = algo.clone();
        let cfg_c = cfg.clone();
        let crash = std::thread::spawn(move || {
            let mut host = host;
            let mut backend = spec_c.workload.build_backend().unwrap();
            let mut hook = KillAt(4, ck_tx, Vec::new());
            let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                host.run_one_resumable(
                    backend.as_mut(),
                    &algo_c,
                    &cfg_c,
                    0,
                    0,
                    &mut |_| {},
                    None,
                    Some(&mut hook),
                )
            }));
            assert!(r.is_err(), "the crash hook must abort the run");
            drop(host);
        });
        crash.join().unwrap();
        let (ck, pins) = ck_rx.recv().unwrap();
        assert_eq!(ck.next_round, 4);

        let mut host2 = ServiceHost::tcp("127.0.0.1:0", 500, 30_000, 1, &Telemetry::disabled())
            .unwrap();
        host2.restore_pins(&pins);
        addr_tx.send(host2.local_addr().unwrap().to_string()).unwrap();
        let mut backend2 = spec.workload.build_backend().unwrap();
        let got = host2
            .run_one_resumable(backend2.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}, Some(&ck), None)
            .unwrap();
        host2.shutdown().unwrap();
        worker.join().unwrap().unwrap();
        assert_records_identical(&want, &got, &format!("tcp killed {}", want.algorithm));
    }
}

#[test]
fn tcp_resume_with_a_fresh_cohort_is_identical_for_stateless_presets() {
    fixed_clock();
    // Coordinator crash where the participants also died: a brand-new
    // cohort re-rendezvouses against the restored pins (whose holders no
    // longer exist, so the slots are stolen at PullRound). Correct for
    // every algorithm whose participants hold no cross-round state.
    let spec = ExperimentSpec::new("tcpfresh", WorkloadSpec::consensus(10, 13, 7))
        .rounds(6)
        .seed(3)
        .reduce_lanes(3)
        .series(AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0));
    let algo = spec.expanded_series()[0].algorithm.clone();
    let cfg = spec.server_config(0);

    struct At(u64, Option<EngineCkpt>, Vec<(u64, u64)>);
    impl CkptHook for At {
        fn want(&mut self, next_round: u64) -> bool {
            next_round == self.0
        }
        fn store_pins(&mut self, pins: Vec<(u64, u64)>) {
            self.2 = pins;
        }
        fn store(&mut self, ck: EngineCkpt) {
            self.1 = Some(ck);
        }
    }

    let join_cohort = |addr: String, n: usize| {
        (0..n)
            .map(|_| {
                let spec = spec.clone();
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut t = TcpTransport::connect(&addr, Duration::from_secs(10)).unwrap();
                    Participant::new(spec).run(&mut t)
                })
            })
            .collect::<Vec<_>>()
    };

    let mut host = ServiceHost::tcp("127.0.0.1:0", 500, 30_000, 2, &Telemetry::disabled())
        .unwrap();
    let joiners = join_cohort(host.local_addr().unwrap().to_string(), 2);
    let mut backend = spec.workload.build_backend().unwrap();
    let mut hook = At(3, None, Vec::new());
    let want = host
        .run_one_resumable(backend.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}, None, Some(&mut hook))
        .unwrap();
    host.shutdown().unwrap();
    for j in joiners {
        j.join().unwrap().unwrap();
    }
    let ck = hook.1.expect("capture at round 3");
    assert!(!hook.2.is_empty(), "sticky pins captured");

    let mut host2 = ServiceHost::tcp("127.0.0.1:0", 500, 30_000, 2, &Telemetry::disabled())
        .unwrap();
    host2.restore_pins(&hook.2);
    let joiners2 = join_cohort(host2.local_addr().unwrap().to_string(), 2);
    let mut backend2 = spec.workload.build_backend().unwrap();
    let got = host2
        .run_one_resumable(backend2.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}, Some(&ck), None)
        .unwrap();
    host2.shutdown().unwrap();
    for j in joiners2 {
        j.join().unwrap().unwrap();
    }
    assert_records_identical(&want, &got, "tcp fresh cohort");
}

#[test]
fn corrupted_or_truncated_snapshots_fail_with_structured_errors() {
    fixed_clock();
    let base = std::env::temp_dir().join("zsfa_ckpt_corrupt_test");
    std::fs::remove_dir_all(&base).ok();
    let spec = spec_for(
        AlgorithmConfig::gd().with_lrs(0.05, 1.0),
        "corrupt",
        &base.join("out"),
        TransportSpec::Engine,
        1,
    );
    let policy = CheckpointPolicy::every(base.join("ckpt"), 3);
    session_for(&base.join("out"), false).run_with_checkpoints(&spec, &policy).unwrap();
    let path = policy.path_for("corrupt");
    let bytes = std::fs::read(&path).unwrap();

    // Truncation at any length: a structured error, never a panic.
    for cut in [0usize, 1, 8, bytes.len() / 2, bytes.len() - 1] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        let err = Snapshot::load(&path).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Checkpoint, "cut at {cut}: {err}");
    }
    // Bit rot anywhere in the frame.
    let mut bad = bytes.clone();
    bad[bytes.len() / 3] ^= 0x40;
    std::fs::write(&path, &bad).unwrap();
    assert_eq!(Snapshot::load(&path).unwrap_err().kind(), ErrorKind::Checkpoint);

    // A healthy snapshot under a *modified* spec: refused up front by the
    // fingerprint rule rather than silently diverging.
    std::fs::write(&path, &bytes).unwrap();
    let snap = Snapshot::load(&path).unwrap();
    let changed = spec.clone().rounds(9);
    let err = Session::new()
        .resume(&changed, &snap, &CheckpointPolicy::off())
        .unwrap_err();
    assert_eq!(err.kind(), ErrorKind::Checkpoint);
    assert!(err.to_string().contains("fingerprint"), "{err}");
    std::fs::remove_dir_all(&base).ok();
}

#[test]
fn checkpoints_and_resumes_are_counted_by_telemetry() {
    fixed_clock();
    let base = std::env::temp_dir().join("zsfa_ckpt_counter_test");
    std::fs::remove_dir_all(&base).ok();
    let spec = spec_for(
        AlgorithmConfig::gd().with_lrs(0.05, 1.0),
        "counted",
        &base.join("out"),
        TransportSpec::Engine,
        1,
    );
    let policy = CheckpointPolicy::every(base.join("ckpt"), 3);
    let tele = Telemetry::with_capacity(64);
    Session::new()
        .with_telemetry(tele.clone())
        .run_with_checkpoints(&spec, &policy)
        .unwrap();
    // rounds 8, k = 3, 2 repeats: captures at next_round 3 and 6 each.
    assert_eq!(tele.metrics().unwrap().checkpoints_total.get(), 4);
    assert_eq!(tele.metrics().unwrap().resume_total.get(), 0);

    let snap = Snapshot::load(&policy.path_for("counted")).unwrap();
    let tele2 = Telemetry::with_capacity(64);
    Session::new()
        .with_telemetry(tele2.clone())
        .resume(&spec, &snap, &CheckpointPolicy::off())
        .unwrap();
    assert_eq!(tele2.metrics().unwrap().resume_total.get(), 1);
    assert_eq!(tele2.metrics().unwrap().checkpoints_total.get(), 0);
    std::fs::remove_dir_all(&base).ok();
}
