//! Integration tests over the PJRT runtime + AOT artifacts (the full
//! L3→L2→L1 stack). These need `make artifacts`; they skip politely when the
//! manifest is absent so `cargo test` stays green on a fresh checkout.

use std::path::Path;
use zsignfedavg::compress::pack::PackedSigns;
use zsignfedavg::data::{partition, synth};
use zsignfedavg::fl::backend::TrainBackend;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::rng::{Pcg64, ZParam};
use zsignfedavg::runtime::{Engine, ModelRuntime, XlaBackend};
use zsignfedavg::tensor;

fn artifacts_dir() -> Option<&'static Path> {
    let p = Path::new("artifacts");
    if p.join("manifest.json").exists() {
        Some(p)
    } else {
        eprintln!("skipping runtime integration test: no artifacts/ (run `make artifacts`)");
        None
    }
}

#[test]
fn manifest_loads_and_lists_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let engine = Engine::open(dir).unwrap();
    assert!(engine.manifest.artifacts.len() >= 8);
    assert!(!engine.manifest.by_kind("train_step").is_empty());
    assert!(!engine.manifest.by_kind("compress").is_empty());
}

#[test]
fn compress_artifact_sigma_zero_matches_rust_sign() {
    // With sigma = 0 the Pallas kernel must agree bit-for-bit with the Rust
    // reference Sign (the noise multiplies away) — the cross-language
    // correctness anchor for the L1 kernel.
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::open(dir).unwrap();
    let d = 4096;
    let mut rng = Pcg64::seeded(3);
    let delta: Vec<f32> = (0..d).map(|_| (rng.normal() * 2.0) as f32).collect();
    let outs = engine
        .run(
            "test_compress_d4096_z1",
            &[
                zsignfedavg::runtime::Arg::F32(&delta),
                zsignfedavg::runtime::Arg::U32(&[1, 2]),
                zsignfedavg::runtime::Arg::ScalarF32(0.0),
            ],
        )
        .unwrap();
    let kernel_signs = outs[0].to_vec::<i8>().unwrap();
    let mut want = vec![0i8; d];
    tensor::sign_into(&delta, &mut want);
    assert_eq!(kernel_signs, want);
}

#[test]
fn compress_artifact_statistics_match_theory() {
    // For sigma >> |x|, P[sign = +1] ≈ 1/2 + x·p_z(0)/sigma: check the
    // kernel's randomness is actually the z-distribution, not garbage.
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::open(dir).unwrap();
    let d = 4096;
    let x0 = 1.0f32;
    let sigma = 10.0f32;
    let delta = vec![x0; d];
    for (name, z) in [
        ("test_compress_d4096_z1", ZParam::Finite(1)),
        ("test_compress_d4096_z0", ZParam::Inf),
        ("test_compress_d4096_z2", ZParam::Finite(2)),
    ] {
        let mut plus = 0usize;
        let reps = 8;
        for k in 0..reps {
            let outs = engine
                .run(
                    name,
                    &[
                        zsignfedavg::runtime::Arg::F32(&delta),
                        zsignfedavg::runtime::Arg::U32(&[k, 99]),
                        zsignfedavg::runtime::Arg::ScalarF32(sigma),
                    ],
                )
                .unwrap();
            plus += outs[0].to_vec::<i8>().unwrap().iter().filter(|&&s| s == 1).count();
        }
        let n = (reps as usize * d) as f64;
        let frac = plus as f64 / n;
        // P[+1] = 1/2 + x/(2·eta_z·sigma) + O(sigma^-3)
        let want = 0.5 + (x0 / sigma) as f64 / (2.0 * z.eta());
        let tol = 4.0 * (0.25 / n).sqrt() + 2e-3;
        assert!((frac - want).abs() < tol, "{name}: frac={frac:.4} want={want:.4}");
    }
}

#[test]
fn train_step_decreases_loss_on_real_batches() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ModelRuntime::open(dir, "mnist_mlp").unwrap();
    let mut params = rt.load_init().unwrap();
    let (train, _) = synth::train_test(synth::SynthSpec::mnist(), 64, 10);
    let b = rt.train_batch;
    let l = train.sample_len();
    let mut x = vec![0.0f32; b * l];
    let mut y = vec![0i32; b];
    let idx: Vec<usize> = (0..b).collect();
    train.gather_into(&idx, &mut x, &mut y);
    let first = rt.train_step(&mut params, &x, &y, 0.05).unwrap();
    let mut last = first;
    for _ in 0..30 {
        last = rt.train_step(&mut params, &x, &y, 0.05).unwrap();
    }
    assert!(last < first * 0.7, "loss {first} -> {last}");
}

#[test]
fn fused_local_update_matches_unrolled_steps() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ModelRuntime::open(dir, "mnist_mlp").unwrap();
    assert!(rt.fused_local_steps.contains(&5));
    let init = rt.load_init().unwrap();
    let (train, _) = synth::train_test(synth::SynthSpec::mnist(), 200, 10);
    let b = rt.train_batch;
    let l = train.sample_len();
    let e = 5;
    let mut xs = vec![0.0f32; e * b * l];
    let mut ys = vec![0i32; e * b];
    let mut rng = Pcg64::seeded(0);
    for s in 0..e {
        let idx: Vec<usize> =
            (0..b).map(|_| rng.below(train.n as u64) as usize).collect();
        train.gather_into(&idx, &mut xs[s * b * l..(s + 1) * b * l], &mut ys[s * b..(s + 1) * b]);
    }
    let mut p_fused = init.clone();
    let mean_loss = rt.local_update_fused(&mut p_fused, e, &xs, &ys, 0.05).unwrap();
    let mut p_loop = init;
    let mut losses = Vec::new();
    for s in 0..e {
        let (xb, yb) = (&xs[s * b * l..(s + 1) * b * l], &ys[s * b..(s + 1) * b]);
        losses.push(rt.train_step(&mut p_loop, xb, yb, 0.05).unwrap());
    }
    let max_diff = p_fused
        .iter()
        .zip(&p_loop)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-4, "max param diff {max_diff}");
    let mean_unrolled = losses.iter().sum::<f64>() / e as f64;
    assert!((mean_loss - mean_unrolled).abs() < 1e-4);
}

#[test]
fn eval_step_counts_and_loss_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = ModelRuntime::open(dir, "mnist_mlp").unwrap();
    let params = rt.load_init().unwrap();
    let be = rt.eval_batch;
    let (_, test) = synth::train_test(synth::SynthSpec::mnist(), 10, be);
    let l = test.sample_len();
    let mut x = vec![0.0f32; be * l];
    let mut y = vec![0i32; be];
    test.gather_into(&(0..be).collect::<Vec<_>>(), &mut x, &mut y);
    let (sum_loss, correct) = rt.eval_step(&params, &x, &y).unwrap();
    assert!(correct <= be);
    // Untrained 10-class model: loss near ln(10) per sample.
    let per = sum_loss / be as f64;
    assert!(per > 1.0 && per < 4.0, "per-sample loss {per}");
}

#[test]
fn full_stack_fl_round_trip_mnist_mlp() {
    // The end-to-end smoke: Rust coordinator → PJRT train/eval/compress
    // artifacts (Pallas sign kernel on the compression path) for a few
    // rounds of 1-SignSGD on non-iid synthMNIST.
    let Some(dir) = artifacts_dir() else { return };
    let rt = ModelRuntime::open(dir, "mnist_mlp").unwrap();
    let init = rt.load_init().unwrap();
    let eval_batch = rt.eval_batch;
    let (train, test) = synth::train_test(synth::SynthSpec::mnist(), 400, eval_batch);
    let fed = partition::by_label(train, 10);
    let mut backend = XlaBackend::new(rt, fed, test, init);
    let n_exec_before = backend.runtime.engine.num_executions;
    let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.05).with_lrs(0.05, 1.0);
    let cfg = ServerConfig { rounds: 6, eval_every: 1, ..Default::default() };
    let run = run_experiment(&mut backend, &algo, &cfg);
    assert_eq!(run.records.len(), 6);
    // The kernel-compress path must actually have been exercised:
    // per round, 10 train_steps + 10 compress + 1 eval (2 batches = 2 execs).
    let execs = backend.runtime.engine.num_executions - n_exec_before;
    assert!(execs >= 6 * (10 + 10 + 1) as u64, "execs={execs}");
    // Objective must drop from the untrained ~ln(10).
    let first = run.records.first().unwrap().objective;
    let last = run.records.last().unwrap().objective;
    assert!(last < first, "objective {first} -> {last}");
    // Exact uplink accounting: d bits per client per round.
    assert_eq!(run.total_bits(), 6 * 10 * backend.dim() as u64);
}

#[test]
fn packed_compress_artifact_matches_int8_artifact() {
    // Same (delta, key, sigma) through the int8 and the bit-packed compress
    // artifacts must produce identical sign vectors — the threefry stream is
    // a function of the key alone.
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::open(dir).unwrap();
    if engine.manifest.get("test_compress_packed_d4096_z1").is_err() {
        eprintln!("skipping: packed artifact not built (re-run `make artifacts`)");
        return;
    }
    let d = 4096;
    let mut rng = Pcg64::seeded(77);
    let delta: Vec<f32> = (0..d).map(|_| (rng.normal() * 1.5) as f32).collect();
    let key = [123u32, 456];
    let args = [
        zsignfedavg::runtime::Arg::F32(&delta),
        zsignfedavg::runtime::Arg::U32(&key),
        zsignfedavg::runtime::Arg::ScalarF32(0.8),
    ];
    let signs = engine.run("test_compress_d4096_z1", &args).unwrap()[0]
        .to_vec::<i8>()
        .unwrap();
    let words = engine.run("test_compress_packed_d4096_z1", &args).unwrap()[0]
        .to_vec::<u32>()
        .unwrap();
    let packed = PackedSigns::from_u32_words(&words, d);
    let mut unpacked = vec![0i8; d];
    packed.unpack_into(&mut unpacked);
    assert_eq!(signs, unpacked);
}

#[test]
fn packed_signs_roundtrip_from_kernel_output() {
    let Some(dir) = artifacts_dir() else { return };
    let mut engine = Engine::open(dir).unwrap();
    let d = 4096;
    let delta: Vec<f32> = (0..d).map(|i| (i as f32 - 2048.0) / 100.0).collect();
    let outs = engine
        .run(
            "test_compress_d4096_z0",
            &[
                zsignfedavg::runtime::Arg::F32(&delta),
                zsignfedavg::runtime::Arg::U32(&[5, 6]),
                zsignfedavg::runtime::Arg::ScalarF32(1.0),
            ],
        )
        .unwrap();
    let signs = outs[0].to_vec::<i8>().unwrap();
    let packed = PackedSigns::from_signs(&signs);
    let mut back = vec![0i8; d];
    packed.unpack_into(&mut back);
    assert_eq!(signs, back);
}
