//! Integration tests for the experiment API (DESIGN.md §4.5):
//!
//! * spec ↔ JSON round-trips are lossless for every compression family,
//!   `ZParam`, participation, plateau, workload and sweep variant;
//! * the golden spec files under `tests/specs/` exercise `from_json` /
//!   `validate()` error messages;
//! * `examples/quickstart.json` is pinned to the fig1 driver preset;
//! * a `Session` with a `CsvSink` reproduces the pre-API driver plumbing
//!   byte-for-byte at parallelism 1 and 8 on a pinned scenario;
//! * observers stream in the documented order;
//! * no repro driver constructs a `ServerConfig` literal anymore.

use std::cell::RefCell;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use zsignfedavg::api::{
    seed_for_repeat, CsvSink, ExperimentSpec, JsonlSink, MemorySink, RoundObserver, SeriesCtx,
    Session, SweepSpec, WorkloadSpec,
};
use zsignfedavg::compress::agg::RobustRule;
use zsignfedavg::compress::sign::SigmaRule;
use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::metrics::{
    aggregate, safe_series_name, write_csv, write_runs_csv, Aggregated, RoundRecord, RunResult,
};
use zsignfedavg::fl::plateau::PlateauConfig;
use zsignfedavg::fl::server::{run_experiment, Participation, ServerConfig};
use zsignfedavg::fl::{AlgorithmConfig, Compression};
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::problems::AnalyticProblem;
use zsignfedavg::rng::ZParam;
use zsignfedavg::sim::{ByzantineMode, FleetPreset, ScenarioConfig};
use zsignfedavg::util::json::Json;

fn specs_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/specs")
}

fn roundtrip(spec: &ExperimentSpec) {
    let json = spec.to_json();
    let back = ExperimentSpec::from_json(&json).unwrap_or_else(|e| {
        panic!("reparse failed for {json}: {e}");
    });
    assert_eq!(&back, spec, "lossy round-trip via {json}");
}

// ---------------------------------------------------------------------------
// Round-trips
// ---------------------------------------------------------------------------

#[test]
fn json_roundtrip_every_compression_and_server_opt() {
    let algos = vec![
        AlgorithmConfig::gd(),
        AlgorithmConfig::sgdwm(0.9),
        AlgorithmConfig::fedavg(5).with_lrs(0.05, 0.5),
        AlgorithmConfig::signsgd(),
        AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.05),
        AlgorithmConfig::z_signsgd(ZParam::Inf, 3.0),
        AlgorithmConfig::z_signfedavg(ZParam::Finite(2), 0.01, 5).with_server_adam(),
        AlgorithmConfig::sign_fedavg(3),
        AlgorithmConfig::sto_signsgd().with_momentum(0.9),
        AlgorithmConfig::ef_signsgd(),
        AlgorithmConfig::qsgd(4),
        AlgorithmConfig::fedpaq(2, 5),
        AlgorithmConfig::dp_signfedavg(0.01, 1.1, 2),
        AlgorithmConfig::dp_fedavg(0.01, 1.1, 2),
        AlgorithmConfig::topk(0.25, 1),
        AlgorithmConfig::sparse_sign(0.1, ZParam::Inf, 0.5, 2),
        // The InfNorm sigma rule has no named preset; cover it explicitly.
        AlgorithmConfig {
            name: "infnorm-ablation".into(),
            compression: Compression::ZSign {
                z: ZParam::Finite(3),
                sigma: SigmaRule::InfNorm,
            },
            client_lr: 0.02,
            server_lr: 0.7,
            server_opt: zsignfedavg::fl::algorithms::ServerOpt::Sgd,
            local_steps: 4,
            robust: RobustRule::None,
        },
        // Robust trimmed-majority vote rides the spec round-trip too.
        AlgorithmConfig::signsgd().with_robust(RobustRule::TrimmedMajority { frac: 0.2 }),
        AlgorithmConfig::dp_signfedavg(0.01, 1.1, 2)
            .with_robust(RobustRule::TrimmedMajority { frac: 0.1 }),
    ];
    for algo in algos {
        let spec = ExperimentSpec::new("rt", WorkloadSpec::consensus(8, 16, 99))
            .rounds(10)
            .series(algo);
        roundtrip(&spec);
    }
}

#[test]
fn json_roundtrip_workloads_participation_plateau_downlink_sweep() {
    let workloads = vec![
        WorkloadSpec::consensus(10, 100, 7),
        WorkloadSpec::Counterexample { a: 4.0, x0: 2.0 },
        WorkloadSpec::LeastSquares {
            clients: 8,
            dim: 50,
            rows_per_client: 20,
            heterogeneity: 0.5,
            noise: 0.5,
            problem_seed: 11,
            stochastic: true,
        },
        WorkloadSpec::Neural(zsignfedavg::api::NeuralSpec {
            dataset: zsignfedavg::api::Dataset::Emnist,
            clients: 358,
            train_samples: 3580,
            test_samples: Some(64),
            paper_scale: false,
            artifacts: PathBuf::from("artifacts"),
        }),
    ];
    for w in workloads {
        let spec = ExperimentSpec::new("rt", w)
            .rounds(5)
            .series(AlgorithmConfig::gd());
        roundtrip(&spec);
    }

    let participations = vec![
        Participation::Uniform,
        Participation::Simulated(ScenarioConfig::default()),
        Participation::Simulated(ScenarioConfig {
            target_cohort: 32,
            overselect: 2.0,
            deadline_s: 1.5,
            round_latency_s: 0.0,
            dropout_prob: 0.2,
            byzantine_frac: 0.1,
            byzantine_mode: ByzantineMode::GradNegate { boost: 5.0 },
            fleet: FleetPreset::Uniform,
        }),
        Participation::Simulated(ScenarioConfig {
            byzantine_mode: ByzantineMode::SignFlip,
            fleet: FleetPreset::CrossDevice,
            ..ScenarioConfig::default()
        }),
    ];
    for p in participations {
        let spec = ExperimentSpec::new("rt", WorkloadSpec::consensus(40, 8, 99))
            .rounds(5)
            .participation(p)
            .series(AlgorithmConfig::gd());
        roundtrip(&spec);
    }

    for plateau in [PlateauConfig::mnist(), PlateauConfig::emnist(), PlateauConfig::cifar()] {
        let spec = ExperimentSpec::new("rt", WorkloadSpec::consensus(4, 8, 99))
            .rounds(5)
            .plateau(plateau)
            .downlink_sign(ZParam::Inf, 0.5)
            .series(AlgorithmConfig::signsgd());
        roundtrip(&spec);
    }

    let spec = ExperimentSpec::new("rt", WorkloadSpec::consensus(4, 8, 99))
        .rounds(5)
        .seed(12345)
        .repeats(3)
        .clients_per_round(Some(2))
        .parallelism(8)
        .reduce_lanes(3)
        .output_dir("elsewhere")
        .subtract_optimal(true)
        .series_labeled("lbl", "display name", AlgorithmConfig::gd())
        .sweep(SweepSpec {
            zs: vec![ZParam::Finite(1), ZParam::Inf],
            local_steps: vec![1, 5],
            sigmas: vec![0.0, 0.5, 2.0],
            client_lr: 0.05,
            server_lr: 0.3,
        });
    roundtrip(&spec);
}

// ---------------------------------------------------------------------------
// Golden files
// ---------------------------------------------------------------------------

#[test]
fn quickstart_spec_is_pinned_to_the_fig1_preset() {
    let parsed = ExperimentSpec::from_json_file(
        &Path::new(env!("CARGO_MANIFEST_DIR")).join("examples/quickstart.json"),
    )
    .unwrap();
    let preset = zsignfedavg::repro::fig1_consensus::spec_for_dim(8, 50, 40, 2, 0.01, 3.0);
    assert_eq!(parsed, preset, "examples/quickstart.json drifted from the fig1 preset");
    assert!(parsed.validate().is_ok());
    roundtrip(&parsed);
}

#[test]
fn golden_valid_spec_parses_validates_and_roundtrips() {
    let spec = ExperimentSpec::from_json_file(&specs_dir().join("scenario_sweep.json")).unwrap();
    assert!(spec.validate().is_ok(), "{:?}", spec.validate());
    // 1 explicit series + 2 zs × 2 Es × 2 sigmas.
    assert_eq!(spec.expanded_series().len(), 9);
    assert!(matches!(spec.participation, Participation::Simulated(_)));
    assert!(spec.plateau.is_some() && spec.downlink_sign.is_some());
    roundtrip(&spec);
}

#[test]
fn golden_error_messages_are_pinned() {
    let dir = specs_dir();
    let err = ExperimentSpec::from_json_file(&dir.join("bad_missing_workload.json"))
        .unwrap_err();
    assert_eq!(err.at, "workload");
    assert!(err.reason.contains("missing required field"), "{err}");

    let err = ExperimentSpec::from_json_file(&dir.join("bad_unknown_compression.json"))
        .unwrap_err();
    assert_eq!(err.at, "series[0].algorithm.compression.kind");
    assert!(err.reason.contains("unknown compression kind \"zip\""), "{err}");

    let err = ExperimentSpec::from_json_file(&dir.join("bad_unknown_key.json")).unwrap_err();
    assert_eq!(err.at, "rouns");
    assert!(err.reason.contains("unknown field"), "{err}");

    let spec =
        ExperimentSpec::from_json_file(&dir.join("bad_zero_rounds.json")).unwrap();
    let errs = spec.validate().unwrap_err();
    let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
    assert!(msgs.iter().any(|m| m == "rounds: must be >= 1"), "{msgs:?}");
    assert!(msgs.iter().any(|m| m == "eval_every: must be >= 1"), "{msgs:?}");

    let spec = ExperimentSpec::from_json_file(&dir.join("bad_ef_partial.json")).unwrap();
    let errs = spec.validate().unwrap_err();
    assert!(
        errs.iter().any(|e| e.reason.contains("EF-SignSGD")),
        "{errs:?}"
    );
}

// ---------------------------------------------------------------------------
// CSV byte-compatibility with the pre-API plumbing
// ---------------------------------------------------------------------------

/// The pinned scenario of the acceptance bar: a simulated cross-device
/// cohort with multi-slot reduce lanes, two algorithm families (packed
/// sign votes + dense), two repeats.
fn pinned_spec(out: &Path, parallelism: usize) -> ExperimentSpec {
    ExperimentSpec::new("pinned", WorkloadSpec::consensus(16, 64, 99))
        .rounds(12)
        .eval_every(3)
        .seed(5)
        .repeats(2)
        .reduce_lanes(3)
        .parallelism(parallelism)
        .participation(Participation::Simulated(ScenarioConfig {
            target_cohort: 6,
            ..ScenarioConfig::default()
        }))
        .subtract_optimal(true)
        .series(AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 1.0, 2).with_lrs(0.05, 1.0))
        .series(AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0))
        .output_dir(out)
}

/// Blank the measured `wall_ms` column (index 8) — it is wall-clock time
/// and can never be reproducible; everything else must match exactly.
fn normalize_raw(body: &str) -> String {
    body.lines()
        .map(|l| {
            let mut parts: Vec<&str> = l.split(',').collect();
            if parts.len() >= 9 {
                parts[8] = "-";
            }
            parts.join(",")
        })
        .collect::<Vec<_>>()
        .join("\n")
}

/// Replicate the retired `repro::common` plumbing (repeat loop + CSV
/// naming) exactly as it was before the API redesign.
fn legacy_reference(out: &Path) {
    let f_star = Consensus::gaussian(16, 64, 99).optimal_value().unwrap();
    for algo in [
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 1.0, 2).with_lrs(0.05, 1.0),
        AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0),
    ] {
        let mut runs = Vec::new();
        for r in 0..2usize {
            let mut backend = AnalyticBackend::new(Consensus::gaussian(16, 64, 99));
            let cfg = ServerConfig {
                rounds: 12,
                clients_per_round: None,
                eval_every: 3,
                seed: 5u64.wrapping_add(1000 * r as u64),
                plateau: None,
                downlink_sign: None,
                parallelism: 1,
                reduce_lanes: 3,
                participation: Participation::Simulated(ScenarioConfig {
                    target_cohort: 6,
                    ..ScenarioConfig::default()
                }),
            };
            runs.push(run_experiment(&mut backend, &algo, &cfg));
        }
        let mut agg = aggregate(&runs);
        for v in agg.objective_mean.iter_mut() {
            *v -= f_star;
        }
        let dir = out.join("pinned");
        let safe = safe_series_name(&algo.name);
        write_csv(&dir.join(format!("{safe}.csv")), &agg).unwrap();
        write_runs_csv(&dir.join(format!("{safe}_raw.csv")), &runs).unwrap();
    }
}

#[test]
fn session_csvs_match_legacy_plumbing_at_parallelism_1_and_8() {
    let root = std::env::temp_dir().join("zsfa_api_pinned_csv");
    std::fs::remove_dir_all(&root).ok();
    let (legacy, p1, p8) = (root.join("legacy"), root.join("p1"), root.join("p8"));

    legacy_reference(&legacy);
    Session::new().with(CsvSink::new()).run(&pinned_spec(&p1, 1)).unwrap();
    Session::new().with(CsvSink::new()).run(&pinned_spec(&p8, 8)).unwrap();

    for stem in ["1-SignFedAvg", "FedAvg"] {
        for (kind, normalize) in [("", false), ("_raw", true)] {
            let name = format!("pinned/{stem}{kind}.csv");
            let want = std::fs::read_to_string(legacy.join(&name)).unwrap();
            for alt in [&p1, &p8] {
                let got = std::fs::read_to_string(alt.join(&name)).unwrap();
                if normalize {
                    assert_eq!(
                        normalize_raw(&got),
                        normalize_raw(&want),
                        "{name} differs (modulo wall_ms) in {alt:?}"
                    );
                } else {
                    assert_eq!(got, want, "{name} differs in {alt:?}");
                }
            }
        }
    }
    std::fs::remove_dir_all(&root).ok();
}

// ---------------------------------------------------------------------------
// Observer contract
// ---------------------------------------------------------------------------

#[derive(Clone, Default)]
struct Trace(Rc<RefCell<Vec<String>>>);

impl RoundObserver for Trace {
    fn on_round(&mut self, _ctx: &SeriesCtx, repeat: usize, rec: &RoundRecord) {
        self.0.borrow_mut().push(format!("round:{repeat}:{}", rec.round));
    }

    fn on_run_end(&mut self, _ctx: &SeriesCtx, repeat: usize, _run: &RunResult) {
        self.0.borrow_mut().push(format!("run_end:{repeat}"));
    }

    fn on_series_end(&mut self, ctx: &SeriesCtx, _agg: &Aggregated, _runs: &[RunResult]) {
        self.0.borrow_mut().push(format!("series_end:{}", ctx.label));
    }
}

#[test]
fn observers_stream_rounds_in_order_then_run_end_then_series_end() {
    let trace = Trace::default();
    let spec = ExperimentSpec::new("obs", WorkloadSpec::consensus(4, 8, 99))
        .rounds(6)
        .eval_every(2)
        .repeats(2)
        .series(AlgorithmConfig::gd().with_lrs(0.1, 1.0));
    Session::new().with(trace.clone()).run(&spec).unwrap();
    // Evaluated rounds: 0, 2, 4 and the forced final round 5.
    let want: Vec<String> = [
        "round:0:0", "round:0:2", "round:0:4", "round:0:5", "run_end:0",
        "round:1:0", "round:1:2", "round:1:4", "round:1:5", "run_end:1",
        "series_end:GD",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    assert_eq!(*trace.0.borrow(), want);
}

#[test]
fn memory_sink_collects_and_jsonl_sink_emits_valid_json() {
    let root = std::env::temp_dir().join("zsfa_api_jsonl");
    std::fs::remove_dir_all(&root).ok();
    let events = root.join("events.jsonl");

    let mem = MemorySink::new();
    let spec = ExperimentSpec::new("sink", WorkloadSpec::consensus(4, 8, 99))
        .rounds(4)
        .eval_every(2)
        .repeats(2)
        .series(AlgorithmConfig::gd().with_lrs(0.1, 1.0))
        .series(AlgorithmConfig::signsgd().with_lrs(0.1, 1.0));
    Session::new()
        .with(mem.clone())
        .with(JsonlSink::create(&events).unwrap())
        .run(&spec)
        .unwrap();

    let collected = mem.take();
    assert_eq!(collected.len(), 2);
    assert_eq!(collected[0].label, "GD");
    assert_eq!(collected[0].runs.len(), 2);

    let body = std::fs::read_to_string(&events).unwrap();
    // Per series: 3 records × 2 repeats + 2 run_end + 1 series_end = 9.
    assert_eq!(body.lines().count(), 18);
    for line in body.lines() {
        let j = Json::parse(line).unwrap();
        assert!(j.get("event").is_some(), "{line}");
    }
    std::fs::remove_dir_all(&root).ok();
}

// ---------------------------------------------------------------------------
// The acceptance bar: drivers are spec factories
// ---------------------------------------------------------------------------

#[test]
fn repro_drivers_construct_no_server_config_literals() {
    // Every run must flow through ExperimentSpec/Session; a ServerConfig
    // literal in a driver is a regression to hand-rolled plumbing.
    let repro = Path::new(env!("CARGO_MANIFEST_DIR")).join("src/repro");
    for entry in std::fs::read_dir(&repro).unwrap() {
        let path = entry.unwrap().path();
        if path.extension().map(|e| e != "rs").unwrap_or(true) {
            continue;
        }
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(
            !body.contains("ServerConfig {") && !body.contains("ServerConfig{"),
            "{path:?} constructs a ServerConfig literal"
        );
    }
}

#[test]
fn session_seed_convention_matches_exported_helper() {
    let spec = ExperimentSpec::new("seeds", WorkloadSpec::consensus(2, 2, 1))
        .seed(7)
        .series(AlgorithmConfig::gd());
    assert_eq!(spec.seed_for_repeat(0), 7);
    assert_eq!(spec.seed_for_repeat(3), seed_for_repeat(7, 3));
    assert_eq!(spec.seed_for_repeat(3), 3007);
}
