//! Steady-state allocation regression for the round hot path.
//!
//! The round engine's `RoundScratch` pool plus the fused kernels are
//! supposed to make the per-client work allocation-free: once buffers are
//! warm, a whole experiment run allocates only run-scoped state (the
//! initial iterate, participation plans, round records, eval temporaries) —
//! never an O(d) buffer per client. This test pins that with a counting
//! global allocator (same technique as `benches/bench_dense_reduce.rs`): a
//! second run on a warmed engine must allocate far less than one d-sized
//! buffer per client per round, for every compressor family.
//!
//! Kept to a single #[test] so no concurrent test thread pollutes the
//! counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};
use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::engine::RoundEngine;
use zsignfedavg::fl::server::ServerConfig;
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::rng::ZParam;
use zsignfedavg::telemetry::Telemetry;

struct CountingAlloc;

/// Monotonic total bytes ever allocated (reallocs count the new size).
static TOTAL: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            TOTAL.fetch_add(layout.size(), Ordering::Relaxed);
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            TOTAL.fetch_add(layout.size(), Ordering::Relaxed);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            TOTAL.fetch_add(new_size, Ordering::Relaxed);
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_round_loop_has_no_per_client_allocation() {
    let d = 8192usize;
    let n = 16usize;
    let rounds = 6usize;
    let algos = vec![
        AlgorithmConfig::gd().with_lrs(0.05, 1.0),
        AlgorithmConfig::signsgd().with_lrs(0.05, 1.0),
        AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0),
        AlgorithmConfig::z_signsgd(ZParam::Inf, 1.0).with_lrs(0.05, 1.0),
        AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0),
        AlgorithmConfig::topk(0.1, 1).with_lrs(0.05, 1.0),
        AlgorithmConfig::sparse_sign(0.1, ZParam::Finite(1), 1.0, 1).with_lrs(0.05, 1.0),
        AlgorithmConfig::dp_signfedavg(0.5, 1.0, 2).with_lrs(0.05, 0.5),
    ];
    // What the old path would burn per run: >= 3 d-sized buffers per client
    // per round (iterate clone, gradient, delta) plus per-client message
    // allocations. The budget is ~20x below that and ~3x above the real
    // run-scoped costs (init_params clone, 3 evals with O(d) temporaries,
    // O(n) participation plans per round).
    let old_path_floor = rounds * n * 3 * d * 4; // = 9.4 MB
    let budget = 600_000usize;
    assert!(budget * 10 < old_path_floor, "budget must separate the regimes");

    for algo in &algos {
        let cfg = ServerConfig {
            rounds,
            seed: 7,
            eval_every: 4, // evals at t = 0, 4 and the final round
            parallelism: 1,
            ..Default::default()
        };
        let mut engine = RoundEngine::new(algo, &cfg, d, n);
        // Warm-up run: lanes, scratch pool, vote planes, records all grow.
        let mut b1 = AnalyticBackend::new(Consensus::gaussian(n, d, 3));
        engine.run(&mut b1);
        // Steady-state run on the warmed engine.
        let mut b2 = AnalyticBackend::new(Consensus::gaussian(n, d, 3));
        let before = TOTAL.load(Ordering::Relaxed);
        engine.run(&mut b2);
        let grown = TOTAL.load(Ordering::Relaxed) - before;
        assert!(
            grown < budget,
            "{}: steady-state run allocated {grown} B (budget {budget} B, \
             old-path floor {old_path_floor} B)",
            algo.name
        );
    }

    // Telemetry-enabled variant, same budget: an enabled handle records
    // spans, counters and ring events every round, but all of it lands in
    // preallocated storage (atomics, fixed histogram buckets, the event
    // ring built before warm-up) — enabling observability must not buy
    // back per-round allocation.
    let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
    let cfg = ServerConfig {
        rounds,
        seed: 7,
        eval_every: 4,
        parallelism: 1,
        ..Default::default()
    };
    let mut engine = RoundEngine::new(&algo, &cfg, d, n);
    let tele = Telemetry::with_capacity(4096);
    engine.set_telemetry(tele.clone());
    let mut b1 = AnalyticBackend::new(Consensus::gaussian(n, d, 3));
    engine.run(&mut b1);
    let mut b2 = AnalyticBackend::new(Consensus::gaussian(n, d, 3));
    let before = TOTAL.load(Ordering::Relaxed);
    engine.run(&mut b2);
    let grown = TOTAL.load(Ordering::Relaxed) - before;
    assert!(
        grown < budget,
        "telemetry-enabled steady-state run allocated {grown} B (budget {budget} B)"
    );
    // And it actually observed both runs.
    assert_eq!(tele.metrics().unwrap().rounds_total.get(), 2 * rounds as u64);
}
