//! Pinned schema for the JSONL event stream (`api::observer::JsonlSink`).
//!
//! External consumers (`zsfa watch --jsonl`, dashboards, ad-hoc scripts)
//! parse these lines, so the schema is a compatibility surface:
//!
//! * the golden fixture `tests/fixtures/events.jsonl` is written in the
//!   exact compact form `util::json` emits (sorted keys, integers without
//!   a decimal point) — every line must round-trip byte-for-byte;
//! * the per-event key sets are pinned constants here; the fixture AND a
//!   freshly generated stream must both match them;
//! * the telemetry extension is strictly additive: with telemetry on, a
//!   `round` line restricted to the base keys is byte-identical to the
//!   telemetry-off line (observability never perturbs results).

use std::collections::BTreeSet;
use std::path::Path;

use zsignfedavg::api::{ExperimentSpec, JsonlSink, Session, WorkloadSpec};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::telemetry::{Phase, Telemetry};
use zsignfedavg::util::json::Json;

/// Keys of every `round` event, telemetry on or off.
const ROUND_KEYS: [&str; 11] = [
    "accuracy",
    "arrived",
    "bits_up",
    "event",
    "experiment",
    "objective",
    "repeat",
    "round",
    "series",
    "sigma",
    "sim_time_s",
];

/// Extra `round` keys present exactly when telemetry is enabled.
const ROUND_TELEMETRY_KEYS: [&str; 4] = ["bits_down", "phase_ms", "selected", "wall_ms"];

const RUN_END_KEYS: [&str; 6] =
    ["event", "experiment", "final_objective", "records", "repeat", "series"];

const SERIES_END_KEYS: [&str; 5] =
    ["event", "experiment", "final_objective_mean", "repeats", "series"];

fn fixture() -> String {
    let path = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/events.jsonl");
    std::fs::read_to_string(path).expect("reading golden fixture")
}

fn keys(j: &Json) -> BTreeSet<&str> {
    match j {
        Json::Obj(m) => m.keys().map(|k| k.as_str()).collect(),
        other => panic!("event line is not an object: {other:?}"),
    }
}

fn key_set(names: &[&'static str]) -> BTreeSet<&'static str> {
    names.iter().copied().collect()
}

fn event_kind(j: &Json) -> String {
    j.get("event").and_then(Json::as_str).expect("event key").to_string()
}

#[test]
fn golden_fixture_round_trips_byte_exactly() {
    for (i, line) in fixture().lines().enumerate() {
        let j = Json::parse(line).unwrap_or_else(|e| panic!("fixture line {i}: {e}"));
        assert_eq!(j.to_string_compact(), line, "fixture line {i} is not in canonical form");
    }
}

#[test]
fn golden_fixture_pins_every_event_schema() {
    let body = fixture();
    let lines: Vec<Json> = body.lines().map(|l| Json::parse(l).unwrap()).collect();
    assert_eq!(lines.len(), 4, "fixture covers base round, telemetry round, run_end, series_end");

    assert_eq!(event_kind(&lines[0]), "round");
    assert_eq!(keys(&lines[0]), key_set(&ROUND_KEYS));

    assert_eq!(event_kind(&lines[1]), "round");
    let mut extended = key_set(&ROUND_KEYS);
    extended.extend(key_set(&ROUND_TELEMETRY_KEYS));
    assert_eq!(keys(&lines[1]), extended);
    let phase = lines[1].get("phase_ms").expect("telemetry round has phase_ms");
    let want: BTreeSet<&str> = Phase::ALL.iter().map(|p| p.label()).collect();
    assert_eq!(keys(phase), want, "phase_ms carries one entry per round phase");

    assert_eq!(event_kind(&lines[2]), "run_end");
    assert_eq!(keys(&lines[2]), key_set(&RUN_END_KEYS));

    assert_eq!(event_kind(&lines[3]), "series_end");
    assert_eq!(keys(&lines[3]), key_set(&SERIES_END_KEYS));
}

/// Strip the telemetry-only keys from a round event and re-serialize.
fn project_to_base(j: &Json) -> String {
    let Json::Obj(m) = j else { panic!("not an object") };
    let base: std::collections::BTreeMap<String, Json> = m
        .iter()
        .filter(|(k, _)| !ROUND_TELEMETRY_KEYS.contains(&k.as_str()))
        .map(|(k, v)| (k.clone(), v.clone()))
        .collect();
    Json::Obj(base).to_string_compact()
}

#[test]
fn generated_stream_matches_the_pinned_schema_and_telemetry_is_additive() {
    let root = std::env::temp_dir().join("zsfa_jsonl_schema");
    std::fs::remove_dir_all(&root).ok();
    let plain_path = root.join("plain.jsonl");
    let tele_path = root.join("tele.jsonl");

    let spec = ExperimentSpec::new("schema", WorkloadSpec::consensus(4, 8, 99))
        .rounds(4)
        .eval_every(2)
        .series(AlgorithmConfig::gd().with_lrs(0.1, 1.0));

    Session::new().with(JsonlSink::create(&plain_path).unwrap()).run(&spec).unwrap();
    let tele = Telemetry::with_capacity(64);
    Session::new()
        .with(JsonlSink::create(&tele_path).unwrap().with_telemetry(tele.clone()))
        .with_telemetry(tele)
        .run(&spec)
        .unwrap();

    let plain = std::fs::read_to_string(&plain_path).unwrap();
    let with_tele = std::fs::read_to_string(&tele_path).unwrap();
    assert_eq!(plain.lines().count(), with_tele.lines().count());
    assert!(plain.lines().count() >= 5, "3 rounds + run_end + series_end");

    let mut extended = key_set(&ROUND_KEYS);
    extended.extend(key_set(&ROUND_TELEMETRY_KEYS));
    for (p_line, t_line) in plain.lines().zip(with_tele.lines()) {
        let p = Json::parse(p_line).unwrap();
        let t = Json::parse(t_line).unwrap();
        match event_kind(&p).as_str() {
            "round" => {
                assert_eq!(keys(&p), key_set(&ROUND_KEYS), "{p_line}");
                assert_eq!(keys(&t), extended, "{t_line}");
                // The extension is additive: base projection is identical.
                assert_eq!(project_to_base(&t), p_line);
                let want: BTreeSet<&str> = Phase::ALL.iter().map(|ph| ph.label()).collect();
                assert_eq!(keys(t.get("phase_ms").unwrap()), want);
            }
            "run_end" => {
                assert_eq!(keys(&p), key_set(&RUN_END_KEYS));
                assert_eq!(t_line, p_line, "run_end is telemetry-independent");
            }
            "series_end" => {
                assert_eq!(keys(&p), key_set(&SERIES_END_KEYS));
                assert_eq!(t_line, p_line, "series_end is telemetry-independent");
            }
            other => panic!("unexpected event {other:?}"),
        }
    }
    std::fs::remove_dir_all(&root).ok();
}
