//! Bit-exactness property tests for the fused hot-path kernels.
//!
//! The fused client kernel (`compress::kernel`), the carry-save vote
//! accumulator (`compress::pack::VoteAccumulator`) and the fused
//! dense-family absorb paths (`compress::agg`) all replace scalar reference
//! implementations that every seeded experiment in the repo depends on.
//! These tests pin each of them byte-identical to the reference across
//! boundary lengths, all `ZParam` families and all `SigmaRule`s — the "RNG
//! stream contract" of DESIGN.md.
//!
//! The kernels dispatch through `compress::simd` at runtime (AVX2 / NEON /
//! scalar). CI runs this whole suite twice (`ZSFA_SIMD=off` and default
//! dispatch); in addition, the `*_across_simd_paths` tests below force each
//! available backend explicitly and assert byte-identical words, counts and
//! f32 bit patterns, so a backend divergence fails even in a single run.

use std::sync::Mutex;
use zsignfedavg::compress::agg::{
    AbsorbCtx, Aggregator, LaneAcc, QsgdAgg, SparseSignAgg, TopKAgg, ZSignAgg,
};
use zsignfedavg::compress::kernel;
use zsignfedavg::compress::pack::{PackedSigns, VoteAccumulator};
use zsignfedavg::compress::qsgd::Qsgd;
use zsignfedavg::compress::sign::{SigmaRule, StochasticSign};
use zsignfedavg::compress::simd::{self, SimdPath};
use zsignfedavg::compress::sparsify::{SparseSign, TopK};
use zsignfedavg::compress::{Compressor, Message};
use zsignfedavg::rng::{Pcg64, ZParam};
use zsignfedavg::tensor;

/// Unaligned tails around every lane width the SIMD backends use (4- and
/// 8-wide groups, 64-bit words, plus a 4096+3 page-ish slab).
const BOUNDARY_DIMS: [usize; 11] = [0, 1, 63, 64, 65, 127, 128, 255, 256, 1000, 4099];

/// Serializes the tests that re-point the global kernel dispatch. Tests
/// *not* holding this lock are unaffected by a concurrent re-point: every
/// backend is bit-identical, so a racing reader only ever sees equivalent
/// kernels — but the forcing tests themselves must not race each other, or
/// they could compare a backend against itself.
static DISPATCH: Mutex<()> = Mutex::new(());

fn gen_vec(rng: &mut Pcg64, d: usize) -> Vec<f32> {
    (0..d).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect()
}

/// The fused client kernel must be bit-identical to the scalar reference
/// path (compress_into + from_signs) — output *and* RNG stream — across
/// boundary lengths, every z family and all three sigma rules.
#[test]
fn fused_kernel_bit_identical_to_scalar_reference() {
    let zs = [ZParam::Finite(1), ZParam::Finite(2), ZParam::Finite(3), ZParam::Inf];
    let rules = [
        SigmaRule::Fixed(0.0),
        SigmaRule::Fixed(0.7),
        SigmaRule::L2Norm,
        SigmaRule::InfNorm,
    ];
    let mut data_rng = Pcg64::seeded(0xfeed);
    for &d in &BOUNDARY_DIMS {
        let x = gen_vec(&mut data_rng, d);
        for z in zs {
            for rule in rules {
                let mut ra = Pcg64::new(17, d as u64);
                // Odd warm-up draw so the Gaussian spare cache is engaged.
                ra.normal();
                let mut rb = ra.clone();

                let mut comp = StochasticSign::new(z, rule);
                let mut signs = vec![0i8; d];
                comp.compress_into(&x, &mut ra, &mut signs);
                let want = PackedSigns::from_signs(&signs);

                // Resolve sigma exactly as the aggregation seam does.
                let sigma = match rule {
                    SigmaRule::Fixed(s) => s,
                    SigmaRule::L2Norm => tensor::norm2(&x) as f32,
                    SigmaRule::InfNorm => tensor::norm_inf(&x) as f32,
                };
                assert_eq!(sigma.to_bits(), comp.last_sigma.to_bits(), "sigma resolution");
                let mut got = PackedSigns::zeroed(0);
                kernel::stochastic_sign_packed(&x, z, sigma, &mut rb, &mut got);

                assert_eq!(got, want, "z={z} rule={rule:?} d={d}");
                // Stream continuation: both generators in identical states.
                assert_eq!(
                    ra.normal().to_bits(),
                    rb.normal().to_bits(),
                    "z={z} rule={rule:?} d={d} spare"
                );
                assert_eq!(ra.next_u64(), rb.next_u64(), "z={z} rule={rule:?} d={d} state");
            }
        }
    }
}

/// CSA vote counts equal the naive per-coordinate sums for cohort sizes up
/// to 3× the spill batch, across boundary dimensions, including shard
/// merges at arbitrary pending fill levels.
#[test]
fn csa_accumulator_equals_naive_votes() {
    let batch = VoteAccumulator::SPILL_BATCH as usize;
    let mut rng = Pcg64::seeded(0xc5a);
    for &d in &BOUNDARY_DIMS {
        let cohorts = [1usize, 2, batch - 1, batch, batch + 1, 2 * batch, 3 * batch];
        for &n in cohorts.iter().filter(|&&n| n >= 1) {
            let signs: Vec<Vec<i8>> = (0..n)
                .map(|_| {
                    (0..d)
                        .map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 })
                        .collect()
                })
                .collect();
            let mut naive = vec![0i32; d];
            for s in &signs {
                for (c, &v) in naive.iter_mut().zip(s) {
                    *c += v as i32;
                }
            }
            // Sequential.
            let mut acc = VoteAccumulator::new(d);
            for s in &signs {
                acc.add(&PackedSigns::from_signs(s));
            }
            assert_eq!(acc.counts(), &naive[..], "sequential d={d} n={n}");
            assert_eq!(acc.num_votes(), n as u32);
            // Sharded: split at every prefix length, merge, compare.
            for split in [n / 3, n / 2, n.saturating_sub(1)] {
                let mut a = VoteAccumulator::new(d);
                let mut b = VoteAccumulator::new(d);
                for s in &signs[..split] {
                    a.add(&PackedSigns::from_signs(s));
                }
                for s in &signs[split..] {
                    b.add(&PackedSigns::from_signs(s));
                }
                a.merge(&b);
                assert_eq!(a.counts(), &naive[..], "merged d={d} n={n} split={split}");
                assert_eq!(a.num_votes(), n as u32);
            }
        }
    }
}

/// majority() built from counts must match the i8 definition (ties → +1).
#[test]
fn majority_matches_signwise_definition() {
    let mut rng = Pcg64::seeded(0x3a30);
    for &d in &[1usize, 64, 65, 513] {
        let mut acc = VoteAccumulator::new(d);
        for _ in 0..7 {
            let s: Vec<i8> =
                (0..d).map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 }).collect();
            acc.add(&PackedSigns::from_signs(&s));
        }
        let counts = acc.counts().to_vec();
        let m = acc.majority();
        assert_eq!(m.len(), d);
        for (j, &c) in counts.iter().enumerate() {
            assert_eq!(m.get(j), if c >= 0 { 1 } else { -1 }, "d={d} j={j}");
        }
    }
}

fn absorb_one(agg: &dyn Aggregator, x: &[f32], rng: &mut Pcg64, d: usize) -> (Vec<f32>, u64) {
    let lanes = vec![Mutex::new(LaneAcc::new(d))];
    let mut scratch = zsignfedavg::compress::agg::Scratch::new(d);
    let mut delta = x.to_vec();
    let ctx = AbsorbCtx { rng, round_sigma: 0.6, inv_m: 1.0, ef: None, hook: None };
    agg.absorb(&mut delta, 0.0, ctx, &mut lanes[0].lock().unwrap(), &mut scratch);
    let bits = lanes[0].lock().unwrap().bits();
    let mut update = vec![0.0f32; d];
    agg.reduce(&lanes, &mut update);
    (update, bits)
}

/// The fused dense-family absorb paths (QSGD, top-k, sparse-sign) must
/// reproduce compress → decode of the wire compressors bit for bit, wire
/// bits included.
#[test]
fn fused_dense_absorbs_match_wire_compress_decode() {
    let mut data_rng = Pcg64::seeded(0xab5);
    for &d in &[1usize, 64, 65, 200, 1000] {
        let x = gen_vec(&mut data_rng, d);

        // QSGD.
        for s in [1u32, 4] {
            let mut ra = Pcg64::new(3, d as u64);
            let mut rb = ra.clone();
            let msg = Qsgd::new(s).compress(&x, &mut ra);
            let mut want = vec![0.0f32; d];
            Qsgd::new(s).decode_into(&msg, &mut want);
            let (got, bits) = absorb_one(&QsgdAgg { s }, &x, &mut rb, d);
            assert_eq!(bits, msg.bits_on_wire(), "qsgd s={s} d={d} bits");
            for (a, w) in got.iter().zip(&want) {
                assert_eq!(a.to_bits(), w.to_bits(), "qsgd s={s} d={d}");
            }
        }

        // Top-k.
        let mut ra = Pcg64::new(4, d as u64);
        let mut rb = ra.clone();
        let mut topk = TopK::new(0.1);
        let msg = topk.compress(&x, &mut ra);
        let mut want = vec![0.0f32; d];
        topk.decode_into(&msg, &mut want);
        let (got, bits) = absorb_one(&TopKAgg { frac: 0.1 }, &x, &mut rb, d);
        assert_eq!(bits, msg.bits_on_wire(), "topk d={d} bits");
        for (a, w) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), w.to_bits(), "topk d={d}");
        }

        // Sparse-sign (draws z-noise per kept coordinate: check the stream
        // stays aligned too).
        let mut ra = Pcg64::new(5, d as u64);
        let mut rb = ra.clone();
        let mut ss = SparseSign::new(0.1, ZParam::Finite(1), 0.6);
        let msg = ss.compress(&x, &mut ra);
        let mut want = vec![0.0f32; d];
        ss.decode_into(&msg, &mut want);
        let agg = SparseSignAgg { frac: 0.1, z: ZParam::Finite(1), sigma: 0.6 };
        let (got, bits) = absorb_one(&agg, &x, &mut rb, d);
        assert_eq!(bits, msg.bits_on_wire(), "sparse-sign d={d} bits");
        for (a, w) in got.iter().zip(&want) {
            assert_eq!(a.to_bits(), w.to_bits(), "sparse-sign d={d}");
        }
        assert_eq!(ra.next_u64(), rb.next_u64(), "sparse-sign d={d} stream");
    }
}

/// The sign family absorb (fused kernel + CSA votes) must equal the scalar
/// reference chain: compress_into → from_signs → per-coordinate counts.
#[test]
fn sign_absorb_chain_matches_scalar_chain() {
    let d = 321;
    let m = 23; // crosses one CSA spill boundary
    let mut data_rng = Pcg64::seeded(0x51c);
    let deltas: Vec<Vec<f32>> = (0..m).map(|_| gen_vec(&mut data_rng, d)).collect();
    let agg = ZSignAgg {
        z: ZParam::Finite(1),
        sigma: SigmaRule::Fixed(0.6),
        robust: zsignfedavg::compress::agg::RobustRule::None,
    };

    // Reference: scalar compressor + naive vote counts.
    let mut counts = vec![0i32; d];
    for (i, x) in deltas.iter().enumerate() {
        let mut rng = Pcg64::new(9, i as u64);
        let mut comp = StochasticSign::new(ZParam::Finite(1), SigmaRule::Fixed(0.6));
        let mut signs = vec![0i8; d];
        comp.compress_into(x, &mut rng, &mut signs);
        for (c, &s) in counts.iter_mut().zip(&signs) {
            *c += s as i32;
        }
    }
    let want: Vec<f32> = counts.iter().map(|&c| 1.0 / m as f32 * c as f32).collect();

    // Fused: one lane, absorb all m clients, reduce.
    let lanes = vec![Mutex::new(LaneAcc::new(d))];
    let mut scratch = zsignfedavg::compress::agg::Scratch::new(d);
    for (i, x) in deltas.iter().enumerate() {
        let mut rng = Pcg64::new(9, i as u64);
        let mut delta = x.clone();
        let ctx = AbsorbCtx { rng: &mut rng, round_sigma: 0.6, inv_m: 0.0, ef: None, hook: None };
        agg.absorb(&mut delta, 0.0, ctx, &mut lanes[0].lock().unwrap(), &mut scratch);
    }
    let mut got = vec![0.0f32; d];
    agg.reduce(&lanes, &mut got);
    for (j, (g, w)) in got.iter().zip(&want).enumerate() {
        assert_eq!(g.to_bits(), w.to_bits(), "j={j}");
    }
}

/// The full fused-kernel matrix — unaligned-tail d sweep × every `ZParam`
/// × every `SigmaRule` — forced through each available SIMD backend in
/// turn: packed words, trailing-bit invariant and the continued RNG stream
/// must be byte-identical to the scalar backend.
#[test]
fn fused_kernel_identical_across_simd_paths() {
    let _g = DISPATCH.lock().unwrap_or_else(|e| e.into_inner());
    let zs = [ZParam::Finite(1), ZParam::Finite(2), ZParam::Finite(3), ZParam::Inf];
    let rules = [
        SigmaRule::Fixed(0.0),
        SigmaRule::Fixed(0.7),
        SigmaRule::L2Norm,
        SigmaRule::InfNorm,
    ];
    let paths = simd::available();
    assert_eq!(paths[0], SimdPath::Scalar);
    let mut data_rng = Pcg64::seeded(0x51d);
    for &d in &BOUNDARY_DIMS {
        let x = gen_vec(&mut data_rng, d);
        for z in zs {
            for rule in rules {
                let sigma = match rule {
                    SigmaRule::Fixed(s) => s,
                    SigmaRule::L2Norm => tensor::norm2(&x) as f32,
                    SigmaRule::InfNorm => tensor::norm_inf(&x) as f32,
                };
                let mut per_path: Vec<(Vec<u64>, u64)> = Vec::new();
                for &path in &paths {
                    assert!(simd::set_path(path), "{path:?} unavailable");
                    let mut rng = Pcg64::new(23, d as u64);
                    rng.normal(); // engage the Gaussian spare cache
                    let mut p = PackedSigns::zeroed(0);
                    kernel::stochastic_sign_packed(&x, z, sigma, &mut rng, &mut p);
                    per_path.push((p.words().to_vec(), rng.next_u64()));
                }
                for (i, r) in per_path.iter().enumerate().skip(1) {
                    let p = paths[i];
                    assert_eq!(r, &per_path[0], "{p:?} vs scalar z={z} rule={rule:?} d={d}");
                }
            }
        }
    }
    simd::set_path(simd::detected_best());
}

/// Satellite of the vote pipeline: the merge-associativity and
/// slot-permutation properties, plus majority + scaled decode, run under
/// each available SIMD backend — counts, packed words and decoded f32 bit
/// patterns must be byte-identical across backends (and the properties
/// must hold within each).
#[test]
fn vote_merge_properties_identical_across_simd_paths() {
    let _g = DISPATCH.lock().unwrap_or_else(|e| e.into_inner());
    let d = 517;
    let mut rng = Pcg64::seeded(0xb07e);
    let signs: Vec<PackedSigns> = (0..19)
        .map(|_| {
            let v: Vec<i8> =
                (0..d).map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 }).collect();
            PackedSigns::from_signs(&v)
        })
        .collect();

    // Everything the vote pipeline produces under one dispatch path:
    // associativity counts both ways, slot-permuted counts, majority words
    // and a scaled decode of the majority.
    let run = |signs: &[PackedSigns]| {
        let mk = |range: std::ops::Range<usize>| {
            let mut acc = VoteAccumulator::new(d);
            for s in &signs[range] {
                acc.add(s);
            }
            acc
        };
        let (a, b, c) = (mk(0..3), mk(3..8), mk(8..19));
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        let left_counts = left.counts().to_vec();
        let right_counts = right.counts().to_vec();
        // Slot permutation: the same votes in reversed add order.
        let mut rev = VoteAccumulator::new(d);
        for s in signs.iter().rev() {
            rev.add(s);
        }
        let rev_counts = rev.counts().to_vec();
        let majority = left.majority();
        let majority_words = majority.words().to_vec();
        let mut decoded = vec![0.0f32; d];
        majority.decode_scaled_into(0.37, &mut decoded);
        let decoded_bits: Vec<u32> = decoded.iter().map(|f| f.to_bits()).collect();
        (left_counts, right_counts, rev_counts, majority_words, decoded_bits)
    };

    let mut per_path = Vec::new();
    for path in simd::available() {
        assert!(simd::set_path(path), "{path:?} unavailable");
        per_path.push((path, run(&signs)));
    }
    simd::set_path(simd::detected_best());

    let (_, base) = &per_path[0];
    assert_eq!(base.0, base.1, "merge associativity under the scalar backend");
    assert_eq!(base.0, base.2, "slot-permutation invariance under the scalar backend");
    for (path, r) in &per_path[1..] {
        assert_eq!(r, base, "{path:?} diverges from the scalar backend");
    }
}

/// Sanity: the fused deterministic sign (σ = 0) equals Message-level
/// compression through the Compressor trait, which also routes the kernel.
#[test]
fn compressor_trait_routes_through_fused_kernel() {
    let mut data_rng = Pcg64::seeded(2);
    let x = gen_vec(&mut data_rng, 777);
    let mut c = StochasticSign::deterministic();
    let mut rng = Pcg64::seeded(5);
    let msg = c.compress(&x, &mut rng);
    assert_eq!(msg.bits_on_wire(), 777);
    match msg {
        Message::Signs(p) => {
            for (j, &xi) in x.iter().enumerate() {
                assert_eq!(p.get(j), if xi >= 0.0 { 1 } else { -1 });
            }
        }
        _ => panic!("expected packed signs"),
    }
}
