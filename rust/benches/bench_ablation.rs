//! Ablation bench: the design choices DESIGN.md calls out, measured.
//!
//! * compressor bit-efficiency at equal rounds (sign vs QSGD vs top-k vs
//!   sparse-sign vs dense) — bits to reach a fixed optimality gap;
//! * downlink compression on/off — total traffic and final gap;
//! * server optimizer (SGD vs momentum vs FedAdam) at equal rounds;
//! * simulated time-to-target on a cross-device link (net::LinkModel).

use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::net::{arrival_loads, replay, LinkModel};
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::problems::AnalyticProblem;
use zsignfedavg::rng::ZParam;

fn main() {
    let smoke = zsignfedavg::bench::smoke_mode();
    let n = 10;
    let d = if smoke { 200 } else { 2000 };
    let rounds = if smoke { 50 } else { 1200 };
    let f_star = Consensus::gaussian(n, d, 21).optimal_value().unwrap();
    let cfg = ServerConfig { rounds, eval_every: 25, ..Default::default() };
    let link = LinkModel::cross_device();

    println!("== ablation: compressors on consensus n={n} d={d}, {rounds} rounds ==");
    // Time-to-target threshold: above the sign-methods' variance floor
    // (~3 at this sigma/d) so every convergent algorithm registers a time.
    let target_gap = 5.0;
    println!(
        "{:<26} {:>12} {:>14} {:>16} {:>18}",
        "algorithm", "final gap", "uplink Mbit", "bits/coord/rnd", "sim t@gap<5 (s)"
    );
    let algos = vec![
        AlgorithmConfig::gd().with_lrs(0.02, 1.0),
        AlgorithmConfig::z_signsgd(ZParam::Finite(1), 3.0).with_lrs(0.02, 1.0),
        AlgorithmConfig::z_signsgd(ZParam::Inf, 3.0).with_lrs(0.02, 1.0),
        AlgorithmConfig::qsgd(1).with_lrs(0.02, 1.0),
        AlgorithmConfig::qsgd(4).with_lrs(0.02, 1.0),
        AlgorithmConfig::topk(0.05, 1).with_lrs(0.02, 1.0),
        AlgorithmConfig::sparse_sign(0.05, ZParam::Finite(1), 3.0, 1).with_lrs(0.02, 1.0),
    ];
    for algo in &algos {
        let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, 21));
        let run = run_experiment(&mut b, algo, &cfg);
        let gap = run.final_objective() - f_star;
        let bits = run.total_bits();
        let per_coord = bits as f64 / (rounds * n * d) as f64;
        // Simulated time until gap < 1.0 under the cross-device link,
        // billed per the aggregator's recorded arrivals (== the uniform
        // split here: full participation, fixed-rate compressors).
        let timeline = replay(&run, &link, &arrival_loads(&run));
        let t_hit = timeline
            .iter()
            .find(|t| t.record.objective - f_star < target_gap)
            .map(|t| format!("{:.1}", t.sim_time_s))
            .unwrap_or_else(|| "-".into());
        println!(
            "{:<26} {:>12.4} {:>14.2} {:>16.2} {:>18}",
            algo.name,
            gap,
            bits as f64 / 1e6,
            per_coord,
            t_hit
        );
    }

    println!("\n== ablation: downlink compression (1-SignSGD) ==");
    // The downlink payload is the mean-vote vector (entries in [-1, 1]), so
    // its noise scale is matched to that magnitude, not the gradients'.
    let downlinks =
        [("dense downlink", None), ("sign downlink", Some((ZParam::Finite(1), 0.5f32)))];
    for (label, downlink) in downlinks {
        let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, 21));
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 3.0).with_lrs(0.02, 1.0);
        let c = ServerConfig { downlink_sign: downlink, ..cfg.clone() };
        let run = run_experiment(&mut b, &algo, &c);
        let last = run.records.last().unwrap();
        println!(
            "  {label:<18} final gap {:>9.4}   up {:>8.2} Mbit   down {:>8.2} Mbit",
            last.objective - f_star,
            last.bits_up as f64 / 1e6,
            last.bits_down as f64 / 1e6
        );
    }

    println!("\n== ablation: server optimizer (1-SignFedAvg E=2) ==");
    // Momentum/Adam act on constant-magnitude sign votes, so their server
    // stepsizes are scaled down accordingly (momentum amplifies ~1/(1-β)).
    for algo in [
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 4.0, 2).with_lrs(0.02, 1.0),
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 4.0, 2)
            .with_lrs(0.02, 0.1)
            .with_momentum(0.9),
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 4.0, 2)
            .with_lrs(0.02, 0.3)
            .with_server_adam(),
    ] {
        let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, 21));
        let run = run_experiment(&mut b, &algo, &cfg);
        println!("  {:<28} final gap {:>9.4}", algo.name, run.final_objective() - f_star);
    }
}
