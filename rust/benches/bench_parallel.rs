//! Bench: RoundEngine thread scaling — the wall-clock side of the parallel
//! determinism contract (the bit-exactness side lives in `fl::engine`
//! tests).
//!
//! Measures whole coordinator rounds on an n = 64-client consensus problem
//! across `parallelism` ∈ {1, 2, 4, 8}, for the two compressor families the
//! unified aggregator folds differently: the z = 1 stochastic sign (lane
//! vote accumulators, z-noise sampling dominates per-client cost) and QSGD
//! (dense lane fold under the fixed reduce-lanes topology). Expected shape:
//! near-linear speedup up to the machine's core count, with the sign path
//! scaling best because its per-client work is heaviest relative to the
//! serial reduce.
//!
//! Run with `cargo bench --bench bench_parallel`.

use zsignfedavg::bench::{bench, BenchConfig};
use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::rng::ZParam;

fn main() {
    let smoke = zsignfedavg::bench::smoke_mode();
    let cfg = if smoke {
        BenchConfig::smoke()
    } else {
        BenchConfig { warmup_time_s: 0.3, samples: 12, min_batch_time_s: 0.05 }
    };
    let n = 64;
    let cores = std::thread::available_parallelism().map(|c| c.get()).unwrap_or(1);
    println!("== parallel round engine: n = {n} clients, {cores} cores available ==");

    let cases = [
        (
            "1-SignFedAvg(E=2)",
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 1.0, 2).with_lrs(0.01, 1.0),
        ),
        ("QSGD(s=4)", AlgorithmConfig::qsgd(4).with_lrs(0.01, 1.0)),
    ];
    let dims: &[usize] = if smoke { &[4096] } else { &[16_384, 131_072] };
    for &d in dims {
        for (label, algo) in &cases {
            let mut base_median = f64::NAN;
            for &par in &[1usize, 2, 4, 8] {
                let sc = ServerConfig {
                    rounds: 1,
                    eval_every: 1000,
                    parallelism: par,
                    ..Default::default()
                };
                let mut backend = AnalyticBackend::new(Consensus::gaussian(n, d, 7));
                let r = bench(&format!("round/{label}/d={d}/par={par}"), cfg, || {
                    std::hint::black_box(run_experiment(&mut backend, algo, &sc));
                });
                let med = r.median_s();
                if par == 1 {
                    base_median = med;
                }
                println!("{}   speedup {:.2}x", r.report(), base_median / med);
            }
            println!();
        }
    }
    println!("(results are bit-identical across par — see fl::engine tests)");
}
