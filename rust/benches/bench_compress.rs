//! Bench: the uplink compression hot path — fused one-pass kernel vs the
//! scalar reference path, with the fused kernel A/B'd across SIMD backends.
//!
//! The scalar path is what production ran before the fused kernels landed:
//! `StochasticSign::compress_into` (one z-noise draw per coordinate into an
//! i8 buffer) followed by `PackedSigns::from_signs` (a second walk that
//! packs and allocates). The fused path (`compress::kernel`) draws noise in
//! 64-coordinate blocks and sets bits branchlessly straight into reused
//! packed words; its compare→pack inner loop dispatches through
//! `compress::simd`, so the fused rows are measured twice — dispatch forced
//! to the scalar backend and to the best detected backend (AVX2/NEON) —
//! with a bit-exactness cross-check across every available backend before
//! any timing. Output is bit-identical on all paths (pinned by
//! `tests/hotpath_exactness.rs`); measured per z family at
//! d ∈ {4096, 262144, 1M}.
//!
//! `--json PATH` writes the machine-readable perf trajectory (`make
//! bench-json` → `BENCH_compress.json` at the repo root). The JSON header
//! records the dispatched kernel path and the detected CPU features so
//! trajectory entries are comparable across machines. `--smoke` runs a
//! tiny-budget pass for CI (`make bench-smoke`).

use std::collections::BTreeMap;
use zsignfedavg::bench::{bench, smoke_mode, BenchConfig};
use zsignfedavg::compress::kernel;
use zsignfedavg::compress::pack::PackedSigns;
use zsignfedavg::compress::qsgd::Qsgd;
use zsignfedavg::compress::sign::{SigmaRule, StochasticSign};
use zsignfedavg::compress::simd;
use zsignfedavg::rng::{Pcg64, ZParam};
use zsignfedavg::testutil::gen_vec_f32;
use zsignfedavg::util::json::Json;

/// The pre-PR production path: scalar compress into i8, then pack. Does
/// not dispatch — this is the fixed reference on every machine.
fn scalar_pack(
    comp: &mut StochasticSign,
    x: &[f32],
    rng: &mut Pcg64,
    buf: &mut [i8],
) -> PackedSigns {
    comp.compress_into(x, rng, buf);
    PackedSigns::from_signs(buf)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let smoke = smoke_mode();
    let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::default() };
    let dims: &[usize] = if smoke { &[4096] } else { &[4096, 262_144, 1_048_576] };

    // What this process dispatched to (honors ZSFA_SIMD), recorded in the
    // JSON header; the A/B rows below re-point dispatch explicitly.
    let dispatched = simd::active().label();
    let best = simd::detected_best();
    let paths = simd::available();
    println!(
        "== fused sign kernel vs scalar reference path ==\n\
         dispatched={dispatched} best={} cpu={}",
        best.label(),
        simd::cpu_features()
    );

    // (label, z, sigma): sigma = 0 is the deterministic SignSGD floor.
    let variants: &[(&str, ZParam, f32)] = &[
        ("sign_det", ZParam::Finite(1), 0.0),
        ("z1", ZParam::Finite(1), 0.5),
        ("z2", ZParam::Finite(2), 0.5),
        ("zinf", ZParam::Inf, 0.5),
    ];

    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    for &d in dims {
        let mut rng = Pcg64::seeded(42);
        let x = gen_vec_f32(&mut rng, d, 1.0);
        let mut i8buf = vec![0i8; d];
        let mut packed = PackedSigns::zeroed(d);

        for &(label, z, sigma) in variants {
            // Bit-exactness cross-check: the scalar reference path vs the
            // fused kernel under *every* available backend, on identical
            // RNG clones. Runs in smoke mode too.
            {
                let mut ra = Pcg64::new(7, 1);
                let mut comp = StochasticSign::new(z, SigmaRule::Fixed(sigma));
                let want = scalar_pack(&mut comp, &x, &mut ra, &mut i8buf);
                for &p in &paths {
                    assert!(simd::set_path(p), "backend {p:?} unavailable");
                    let mut rb = Pcg64::new(7, 1);
                    kernel::stochastic_sign_packed(&x, z, sigma, &mut rb, &mut packed);
                    assert_eq!(
                        packed,
                        want,
                        "fused[{}] / scalar-reference divergence: {label} d={d}",
                        p.label()
                    );
                }
            }

            let mut comp = StochasticSign::new(z, SigmaRule::Fixed(sigma));
            let scalar = bench(&format!("scalar/{label}/d={d}"), cfg, || {
                let p = scalar_pack(&mut comp, std::hint::black_box(&x), &mut rng, &mut i8buf);
                std::hint::black_box(&p);
            });
            println!("{}", scalar.report_throughput(d as f64, "elem"));

            // Fused kernel, dispatch forced to the scalar backend...
            simd::set_path(simd::SimdPath::Scalar);
            let fused_scalar = bench(&format!("fused[scalar]/{label}/d={d}"), cfg, || {
                kernel::stochastic_sign_packed(
                    std::hint::black_box(&x),
                    z,
                    sigma,
                    &mut rng,
                    &mut packed,
                );
                std::hint::black_box(&packed);
            });
            println!("{}", fused_scalar.report_throughput(d as f64, "elem"));

            // ...and to the best detected backend (the scalar-vs-SIMD row).
            simd::set_path(best);
            let fused = bench(&format!("fused[{}]/{label}/d={d}", best.label()), cfg, || {
                kernel::stochastic_sign_packed(
                    std::hint::black_box(&x),
                    z,
                    sigma,
                    &mut rng,
                    &mut packed,
                );
                std::hint::black_box(&packed);
            });
            let speedup = scalar.median_s() / fused.median_s();
            let simd_speedup = fused_scalar.median_s() / fused.median_s();
            println!(
                "{}   ({speedup:.2}x vs reference, {simd_speedup:.2}x vs fused-scalar)",
                fused.report_throughput(d as f64, "elem")
            );

            let mut entry = BTreeMap::new();
            entry.insert("d".into(), Json::Num(d as f64));
            entry.insert("scalar_elems_per_s".into(), Json::Num(scalar.throughput(d as f64)));
            entry.insert(
                "fused_scalar_elems_per_s".into(),
                Json::Num(fused_scalar.throughput(d as f64)),
            );
            entry.insert("fused_elems_per_s".into(), Json::Num(fused.throughput(d as f64)));
            entry.insert("speedup".into(), Json::Num(speedup));
            entry.insert("simd_speedup".into(), Json::Num(simd_speedup));
            results.insert(format!("{label}_d{d}"), Json::Obj(entry));
        }

        // Context rows: packing/unpacking primitives, the downlink decode
        // A/B'd across backends, and QSGD.
        let r = bench(&format!("pack/d={d}"), cfg, || {
            std::hint::black_box(PackedSigns::from_signs(&i8buf));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));
        let p = PackedSigns::from_signs(&i8buf);
        let mut unpacked = vec![0i8; d];
        let r = bench(&format!("unpack/d={d}"), cfg, || {
            p.unpack_into(std::hint::black_box(&mut unpacked));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));

        let mut fout = vec![0.0f32; d];
        let mut decode_entry = BTreeMap::new();
        decode_entry.insert("d".into(), Json::Num(d as f64));
        let mut decode_rates = Vec::new();
        for &path in &paths {
            simd::set_path(path);
            let r = bench(&format!("decode_scaled[{}]/d={d}", path.label()), cfg, || {
                p.decode_scaled_into(0.5, std::hint::black_box(&mut fout));
            });
            println!("{}", r.report_throughput(d as f64, "elem"));
            decode_entry.insert(
                format!("{}_elems_per_s", path.label()),
                Json::Num(r.throughput(d as f64)),
            );
            decode_rates.push(r.median_s());
        }
        if let (Some(&first), Some(&last)) = (decode_rates.first(), decode_rates.last()) {
            decode_entry.insert("simd_speedup".into(), Json::Num(first / last));
        }
        results.insert(format!("decode_d{d}"), Json::Obj(decode_entry));
        simd::set_path(best);

        for s in [1u32, 4] {
            let q = Qsgd::new(s);
            let mut out = vec![0.0f32; d];
            let r = bench(&format!("qsgd_fused_s{s}/d={d}"), cfg, || {
                q.quantize_dequantize_into(std::hint::black_box(&x), &mut rng, &mut out);
            });
            println!("{}", r.report_throughput(d as f64, "elem"));
        }
        println!();
    }

    if let Some(path) = json_path {
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("compress".into()));
        doc.insert("smoke".into(), Json::Num(if smoke { 1.0 } else { 0.0 }));
        doc.insert("simd_path".into(), Json::Str(dispatched.into()));
        doc.insert("simd_best".into(), Json::Str(best.label().into()));
        doc.insert("cpu_features".into(), Json::Str(simd::cpu_features()));
        doc.insert("results".into(), Json::Obj(results));
        std::fs::write(&path, Json::Obj(doc).to_string_compact()).expect("writing bench json");
        println!("wrote {path}");
    }
}
