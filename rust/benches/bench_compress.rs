//! Bench: the uplink compression hot path — fused one-pass kernel vs the
//! scalar reference path.
//!
//! The scalar path is what production ran before the fused kernels landed:
//! `StochasticSign::compress_into` (one z-noise draw per coordinate into an
//! i8 buffer) followed by `PackedSigns::from_signs` (a second walk that
//! packs and allocates). The fused path (`compress::kernel`) draws noise in
//! 64-coordinate blocks and sets bits branchlessly straight into reused
//! packed words — bit-identical output (cross-checked here and pinned by
//! `tests/hotpath_exactness.rs`), measured side by side per z family at
//! d ∈ {4096, 262144, 1M}.
//!
//! `--json PATH` writes the machine-readable perf trajectory (`make
//! bench-json` → `BENCH_compress.json` at the repo root); `--smoke` runs a
//! tiny-budget pass for CI (`make bench-smoke`).

use std::collections::BTreeMap;
use zsignfedavg::bench::{bench, smoke_mode, BenchConfig};
use zsignfedavg::compress::kernel;
use zsignfedavg::compress::pack::PackedSigns;
use zsignfedavg::compress::qsgd::Qsgd;
use zsignfedavg::compress::sign::{SigmaRule, StochasticSign};
use zsignfedavg::rng::{Pcg64, ZParam};
use zsignfedavg::testutil::gen_vec_f32;
use zsignfedavg::util::json::Json;

/// The pre-PR production path: scalar compress into i8, then pack.
fn scalar_pack(
    comp: &mut StochasticSign,
    x: &[f32],
    rng: &mut Pcg64,
    buf: &mut [i8],
) -> PackedSigns {
    comp.compress_into(x, rng, buf);
    PackedSigns::from_signs(buf)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let smoke = smoke_mode();
    let cfg = if smoke { BenchConfig::smoke() } else { BenchConfig::default() };
    let dims: &[usize] = if smoke { &[4096] } else { &[4096, 262_144, 1_048_576] };

    // (label, z, sigma): sigma = 0 is the deterministic SignSGD floor.
    let variants: &[(&str, ZParam, f32)] = &[
        ("sign_det", ZParam::Finite(1), 0.0),
        ("z1", ZParam::Finite(1), 0.5),
        ("z2", ZParam::Finite(2), 0.5),
        ("zinf", ZParam::Inf, 0.5),
    ];

    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    println!("== fused sign kernel vs scalar reference path ==");
    for &d in dims {
        let mut rng = Pcg64::seeded(42);
        let x = gen_vec_f32(&mut rng, d, 1.0);
        let mut i8buf = vec![0i8; d];
        let mut packed = PackedSigns::zeroed(d);

        for &(label, z, sigma) in variants {
            // Bit-exactness cross-check on identical RNG clones.
            {
                let mut ra = Pcg64::new(7, 1);
                let mut rb = ra.clone();
                let mut comp = StochasticSign::new(z, SigmaRule::Fixed(sigma));
                let want = scalar_pack(&mut comp, &x, &mut ra, &mut i8buf);
                kernel::stochastic_sign_packed(&x, z, sigma, &mut rb, &mut packed);
                assert_eq!(packed, want, "fused/scalar divergence: {label} d={d}");
            }

            let mut comp = StochasticSign::new(z, SigmaRule::Fixed(sigma));
            let scalar = bench(&format!("scalar/{label}/d={d}"), cfg, || {
                let p = scalar_pack(&mut comp, std::hint::black_box(&x), &mut rng, &mut i8buf);
                std::hint::black_box(&p);
            });
            println!("{}", scalar.report_throughput(d as f64, "elem"));

            let fused = bench(&format!("fused/{label}/d={d}"), cfg, || {
                kernel::stochastic_sign_packed(
                    std::hint::black_box(&x),
                    z,
                    sigma,
                    &mut rng,
                    &mut packed,
                );
                std::hint::black_box(&packed);
            });
            let speedup = scalar.median_s() / fused.median_s();
            println!("{}   ({speedup:.2}x)", fused.report_throughput(d as f64, "elem"));

            let mut entry = BTreeMap::new();
            entry.insert("d".into(), Json::Num(d as f64));
            entry.insert("scalar_elems_per_s".into(), Json::Num(scalar.throughput(d as f64)));
            entry.insert("fused_elems_per_s".into(), Json::Num(fused.throughput(d as f64)));
            entry.insert("speedup".into(), Json::Num(speedup));
            results.insert(format!("{label}_d{d}"), Json::Obj(entry));
        }

        // Context rows: the packing/unpacking primitives and QSGD.
        let r = bench(&format!("pack/d={d}"), cfg, || {
            std::hint::black_box(PackedSigns::from_signs(&i8buf));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));
        let p = PackedSigns::from_signs(&i8buf);
        let mut unpacked = vec![0i8; d];
        let r = bench(&format!("unpack/d={d}"), cfg, || {
            p.unpack_into(std::hint::black_box(&mut unpacked));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));
        for s in [1u32, 4] {
            let q = Qsgd::new(s);
            let mut out = vec![0.0f32; d];
            let r = bench(&format!("qsgd_fused_s{s}/d={d}"), cfg, || {
                q.quantize_dequantize_into(std::hint::black_box(&x), &mut rng, &mut out);
            });
            println!("{}", r.report_throughput(d as f64, "elem"));
        }
        println!();
    }

    if let Some(path) = json_path {
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("compress".into()));
        doc.insert("smoke".into(), Json::Num(if smoke { 1.0 } else { 0.0 }));
        doc.insert("results".into(), Json::Obj(results));
        std::fs::write(&path, Json::Obj(doc).to_string_compact()).expect("writing bench json");
        println!("wrote {path}");
    }
}
