//! Bench: the uplink compression hot path (Rust reference implementations).
//!
//! Regenerates the per-coordinate cost rows behind the paper's Table 2
//! bits-per-round column: stochastic sign (z = 1, z = ∞, z = 2), vanilla
//! sign, 1-bit packing, and the QSGD quantizer across problem dimensions.
//! Run with `cargo bench --bench bench_compress`.

use zsignfedavg::bench::{bench, BenchConfig};
use zsignfedavg::compress::pack::PackedSigns;
use zsignfedavg::compress::qsgd::Qsgd;
use zsignfedavg::compress::sign::{SigmaRule, StochasticSign};
use zsignfedavg::rng::{Pcg64, ZParam};
use zsignfedavg::testutil::gen_vec_f32;

fn main() {
    let cfg = BenchConfig::default();
    println!("== compression hot path ==");
    for &d in &[65_536usize, 1_048_576] {
        let mut rng = Pcg64::seeded(42);
        let x = gen_vec_f32(&mut rng, d, 1.0);
        let mut out = vec![0i8; d];

        // Vanilla sign (sigma = 0): the floor.
        let mut det = StochasticSign::deterministic();
        let r = bench(&format!("sign_det/d={d}"), cfg, || {
            det.compress_into(std::hint::black_box(&x), &mut rng, &mut out);
        });
        println!("{}", r.report_throughput(d as f64, "elem"));

        for z in [ZParam::Finite(1), ZParam::Inf, ZParam::Finite(2)] {
            let mut c = StochasticSign::new(z, SigmaRule::Fixed(0.5));
            let r = bench(&format!("stoch_sign_z{z}/d={d}"), cfg, || {
                c.compress_into(std::hint::black_box(&x), &mut rng, &mut out);
            });
            println!("{}", r.report_throughput(d as f64, "elem"));
        }

        // 1-bit packing + unpack round trip.
        let r = bench(&format!("pack/d={d}"), cfg, || {
            std::hint::black_box(PackedSigns::from_signs(&out));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));
        let packed = PackedSigns::from_signs(&out);
        let mut unpacked = vec![0i8; d];
        let r = bench(&format!("unpack/d={d}"), cfg, || {
            packed.unpack_into(std::hint::black_box(&mut unpacked));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));

        // QSGD quantize (s = 1 and s = 4).
        for s in [1u32, 4] {
            let q = Qsgd::new(s);
            let r = bench(&format!("qsgd_s{s}/d={d}"), cfg, || {
                std::hint::black_box(q.quantize(&x, &mut rng));
            });
            println!("{}", r.report_throughput(d as f64, "elem"));
        }
        println!();
    }
}
