//! Bench: scenario-planning throughput — events/second through the
//! deterministic discrete-event queue when a round over-selects a
//! 13k-candidate cohort (10k target) from a 20k-device fleet.
//!
//! Each planned round pushes every reachable candidate through the
//! download → compute → upload chain (3 events) plus dropout events, so a
//! round is ~40k scheduler operations. This is the coordinator-side cost
//! of scenario participation; it must stay negligible next to the clients'
//! local updates.
//!
//! Run with `cargo bench --bench bench_sim`.

use zsignfedavg::bench::{bench, BenchConfig};
use zsignfedavg::fl::engine::ParticipationPolicy;
use zsignfedavg::rng::Pcg64;
use zsignfedavg::sim::{ByzantineMode, FleetPreset, ScenarioConfig, ScenarioPolicy};

fn main() {
    let smoke = zsignfedavg::bench::smoke_mode();
    let cfg = if smoke {
        BenchConfig::smoke()
    } else {
        BenchConfig { warmup_time_s: 0.3, samples: 12, min_batch_time_s: 0.05 }
    };
    let n = if smoke { 2_000 } else { 20_000 };
    let sc = ScenarioConfig {
        target_cohort: if smoke { 1_000 } else { 10_000 },
        overselect: 1.3,
        deadline_s: 10.0,
        round_latency_s: 0.3,
        dropout_prob: 0.1,
        byzantine_frac: 0.1,
        byzantine_mode: ByzantineMode::SignFlip,
        fleet: FleetPreset::CrossDevice,
    };
    let root = Pcg64::new(7, 0xa11ce);
    // 100 kbit sign uplink, 3.2 Mbit dense downlink (d = 100k coords).
    let mut policy = ScenarioPolicy::new(sc, n, 2, 100_000, 3_200_000, &root);
    let mut rounds = 0usize;
    let r = bench("sim/plan_round/10k-cohort", cfg, || {
        let plan = policy.plan_round(rounds, &root);
        std::hint::black_box(plan.participants.len());
        rounds += 1;
    });
    let events_per_round = policy.events_processed() as f64 / rounds.max(1) as f64;
    println!("{}", r.report_throughput(events_per_round, "events"));
    println!(
        "({events_per_round:.0} events per planned round; cohort 13000 of {n} devices)"
    );
}
