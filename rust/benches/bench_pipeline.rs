//! Bench: whole coordinator rounds, end to end.
//!
//! * Analytic consensus rounds at d = 10^5 (the Fig. 1/2 workload) — pure-L3
//!   cost: local updates + Rust compression + vote aggregation + server step.
//! * One full XLA-backed round of 1-SignSGD on synthMNIST (train_step ×10 +
//!   Pallas compress ×10 + vote aggregation) — L3 overhead should be a small
//!   fraction of this (the §Perf target).

use std::path::Path;
use zsignfedavg::bench::{bench, BenchConfig};
use zsignfedavg::data::{partition, synth};
use zsignfedavg::fl::server::{run_experiment, ServerConfig};
use zsignfedavg::fl::AlgorithmConfig;
use zsignfedavg::fl::backend::AnalyticBackend;
use zsignfedavg::problems::consensus::Consensus;
use zsignfedavg::rng::ZParam;
use zsignfedavg::runtime::{ModelRuntime, XlaBackend};

fn main() {
    let smoke = zsignfedavg::bench::smoke_mode();
    let cfg = if smoke {
        BenchConfig::smoke()
    } else {
        BenchConfig { warmup_time_s: 0.5, samples: 15, min_batch_time_s: 0.05 }
    };
    println!("== end-to-end coordinator rounds ==");

    // Analytic path: 10 clients, d = 100k, 1-SignSGD, one round per iter.
    let dims: &[usize] = if smoke { &[2_000] } else { &[10_000, 100_000] };
    for &d in dims {
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.01, 1.0);
        let sc = ServerConfig { rounds: 1, eval_every: 1000, ..Default::default() };
        let mut backend = AnalyticBackend::new(Consensus::gaussian(10, d, 1));
        let r = bench(&format!("analytic_round/1-SignSGD/d={d}"), cfg, || {
            std::hint::black_box(run_experiment(&mut backend, &algo, &sc));
        });
        println!("{}", r.report());

        let algo_gd = AlgorithmConfig::gd().with_lrs(0.01, 1.0);
        let r = bench(&format!("analytic_round/GD/d={d}"), cfg, || {
            std::hint::black_box(run_experiment(&mut backend, &algo_gd, &sc));
        });
        println!("{}", r.report());
    }

    // XLA path.
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("(skipping XLA round bench: run `make artifacts`)");
        return;
    }
    let rt = ModelRuntime::open(dir, "mnist_mlp").unwrap();
    let init = rt.load_init().unwrap();
    let eval_batch = rt.eval_batch;
    let (train, test) = synth::train_test(synth::SynthSpec::mnist(), 400, eval_batch);
    let fed = partition::by_label(train, 10);
    let mut backend = XlaBackend::new(rt, fed, test, init);
    let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.05).with_lrs(0.05, 1.0);
    let sc = ServerConfig { rounds: 1, eval_every: 1000, ..Default::default() };
    let r = bench("xla_round/1-SignSGD/mnist_mlp/10cl", cfg, || {
        std::hint::black_box(run_experiment(&mut backend, &algo, &sc));
    });
    println!("{}", r.report());
    let algo_fedavg = AlgorithmConfig::fedavg(1).with_lrs(0.05, 1.0);
    let r = bench("xla_round/FedAvg/mnist_mlp/10cl", cfg, || {
        std::hint::black_box(run_experiment(&mut backend, &algo_fedavg, &sc));
    });
    println!("{}", r.report());
}
