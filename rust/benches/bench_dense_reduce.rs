//! Streamed vs. buffered dense aggregation — the memory cliff, measured.
//!
//! The historical round reduce buffered one decoded vector **per client**
//! until end-of-round (m·d floats at the high-water mark); the unified
//! aggregation seam (`compress::agg`) streams each contribution into L =
//! `reduce_lanes` lane accumulators instead (L·d floats, independent of
//! m). This bench measures both sides of that trade at m ∈ {64, 512,
//! 4096}:
//!
//! * **throughput** — folded coordinates/second for one full round
//!   aggregation (decode + fold), buffered vs. streamed;
//! * **peak resident delta** — bytes of live heap above the pre-round
//!   baseline during one aggregation pass, via a counting global
//!   allocator.
//!
//! `--json PATH` additionally writes machine-readable results (see `make
//! bench-json`, which emits `BENCH_aggregate.json` for the perf
//! trajectory).

use std::alloc::{GlobalAlloc, Layout, System};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use zsignfedavg::bench::{bench, BenchConfig};
use zsignfedavg::compress::agg::{AbsorbCtx, Aggregator, LaneAcc, ReduceTopology, Scratch};
use zsignfedavg::fl::server::DEFAULT_REDUCE_LANES;
use zsignfedavg::fl::Compression;
use zsignfedavg::rng::Pcg64;
use zsignfedavg::tensor;
use zsignfedavg::util::json::Json;

// --- counting allocator -----------------------------------------------------

struct CountingAlloc;

static LIVE: AtomicUsize = AtomicUsize::new(0);
static PEAK: AtomicUsize = AtomicUsize::new(0);

fn note_alloc(size: usize) {
    let live = LIVE.fetch_add(size, Ordering::Relaxed) + size;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            note_alloc(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            note_alloc(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
        System.dealloc(ptr, layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            LIVE.fetch_sub(layout.size(), Ordering::Relaxed);
            note_alloc(new_size);
        }
        p
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Live-heap high-water mark of `f()` relative to entry, in bytes.
fn peak_delta(mut f: impl FnMut()) -> usize {
    let base = LIVE.load(Ordering::Relaxed);
    PEAK.store(base, Ordering::Relaxed);
    f();
    PEAK.load(Ordering::Relaxed).saturating_sub(base)
}

// --- the two reduction strategies -------------------------------------------

/// A synthetic "client": its decoded dense contribution, generated on the
/// fly from its own stream (mirrors the engine: the decoded vector is
/// transient in both strategies; what differs is the aggregation state).
fn client_delta(seed: u64, slot: usize, d: usize) -> Vec<f32> {
    let mut rng = Pcg64::new(seed, slot as u64);
    (0..d).map(|_| rng.uniform_in(-1.0, 1.0) as f32).collect()
}

/// The historical reduce: park every client's vector, fold at end-of-round
/// in slot order. High-water: m·d floats.
fn buffered_round(seed: u64, m: usize, d: usize, out: &mut [f32]) {
    let inv_m = 1.0 / m as f32;
    let mut parked: Vec<Vec<f32>> = Vec::with_capacity(m);
    for slot in 0..m {
        parked.push(client_delta(seed, slot, d));
    }
    out.iter_mut().for_each(|x| *x = 0.0);
    for v in &parked {
        tensor::axpy(inv_m, v, out);
    }
}

/// The streamed reduce: absorb each vector into its lane the moment it is
/// produced, fold lanes at end-of-round. High-water: L·d floats.
fn streamed_round(
    agg: &dyn Aggregator,
    lanes: &[Mutex<LaneAcc>],
    scratch: &mut Scratch,
    seed: u64,
    m: usize,
    d: usize,
    out: &mut [f32],
) {
    let inv_m = 1.0 / m as f32;
    let topo = ReduceTopology::new(lanes.len(), m);
    for lane in lanes {
        lane.lock().unwrap().reset();
    }
    for lane_i in 0..topo.lanes() {
        let mut lane = lanes[lane_i].lock().unwrap();
        for slot in topo.lane_slots(lane_i) {
            let mut delta = client_delta(seed, slot, d);
            let mut rng = Pcg64::new(seed ^ 0xabc, slot as u64);
            let ctx = AbsorbCtx { rng: &mut rng, round_sigma: 0.0, inv_m, ef: None, hook: None };
            agg.absorb(&mut delta, 0.0, ctx, &mut lane, scratch);
        }
    }
    agg.reduce(&lanes[..topo.lanes()], out);
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let d = 1024usize;
    let lanes_n = DEFAULT_REDUCE_LANES;
    let agg = Compression::None.aggregator(1.0);
    let smoke = zsignfedavg::bench::smoke_mode();
    let cfg = if smoke {
        BenchConfig::smoke()
    } else {
        BenchConfig { warmup_time_s: 0.2, samples: 15, min_batch_time_s: 0.01 }
    };
    let ms: &[usize] = if smoke { &[64] } else { &[64, 512, 4096] };
    let mut results: BTreeMap<String, Json> = BTreeMap::new();

    println!("== dense round reduce: buffered (m·d) vs streamed ({lanes_n} lanes) — d={d} ==");
    for &m in ms {
        let coords = (m * d) as f64;
        let mut out = vec![0.0f32; d];

        // Correctness cross-check at full lane width (L >= m the fold is
        // identical; beyond that the topologies differ by design).
        if m <= lanes_n {
            let mut out2 = vec![0.0f32; d];
            let lanes: Vec<Mutex<LaneAcc>> =
                (0..lanes_n.min(m)).map(|_| Mutex::new(LaneAcc::new(d))).collect();
            let mut scratch = Scratch::new(d);
            buffered_round(7, m, d, &mut out);
            streamed_round(&*agg, &lanes, &mut scratch, 7, m, d, &mut out2);
            assert!(
                out.iter().zip(&out2).all(|(a, b)| a.to_bits() == b.to_bits()),
                "m={m}: streamed fold must match the historical fold when L >= m"
            );
        }

        let buf = bench(&format!("buffered m={m}"), cfg, || {
            buffered_round(7, m, d, &mut out);
            std::hint::black_box(&out);
        });
        let lanes: Vec<Mutex<LaneAcc>> =
            (0..lanes_n.min(m)).map(|_| Mutex::new(LaneAcc::new(d))).collect();
        let mut scratch = Scratch::new(d);
        let stream = bench(&format!("streamed m={m}"), cfg, || {
            streamed_round(&*agg, &lanes, &mut scratch, 7, m, d, &mut out);
            std::hint::black_box(&out);
        });

        // Peak resident, measured outside the timing loop. Streamed lanes
        // are warm (allocated) at this point — exactly the engine's steady
        // state — so its delta is the transient per-client vector only.
        let peak_buf = peak_delta(|| buffered_round(7, m, d, &mut out));
        let peak_stream =
            peak_delta(|| streamed_round(&*agg, &lanes, &mut scratch, 7, m, d, &mut out));
        let lane_state_bytes: usize =
            lanes.iter().map(|l| l.lock().unwrap().dense_floats() * 4).sum();

        println!("{}", buf.report_throughput(coords, "coord"));
        println!("{}", stream.report_throughput(coords, "coord"));
        println!(
            "  peak resident delta: buffered {:>12} B   streamed {:>8} B (+{} B lane state)",
            peak_buf, peak_stream, lane_state_bytes
        );
        assert!(
            peak_stream + lane_state_bytes < peak_buf || m <= lanes_n,
            "streamed high-water must beat buffered once m >> lanes"
        );

        let mut entry = BTreeMap::new();
        entry.insert("m".into(), Json::Num(m as f64));
        entry.insert("d".into(), Json::Num(d as f64));
        entry.insert("lanes".into(), Json::Num(lanes_n.min(m) as f64));
        entry.insert("buffered_median_s".into(), Json::Num(buf.median_s()));
        entry.insert("streamed_median_s".into(), Json::Num(stream.median_s()));
        entry.insert("buffered_coords_per_s".into(), Json::Num(buf.throughput(coords)));
        entry.insert("streamed_coords_per_s".into(), Json::Num(stream.throughput(coords)));
        entry.insert("buffered_peak_bytes".into(), Json::Num(peak_buf as f64));
        entry.insert("streamed_peak_bytes".into(), Json::Num(peak_stream as f64));
        entry.insert("streamed_lane_state_bytes".into(), Json::Num(lane_state_bytes as f64));
        results.insert(format!("m{m}"), Json::Obj(entry));
    }

    if let Some(path) = json_path {
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("dense_reduce".into()));
        doc.insert("dim".into(), Json::Num(d as f64));
        doc.insert("reduce_lanes".into(), Json::Num(lanes_n as f64));
        doc.insert("results".into(), Json::Obj(results));
        std::fs::write(&path, Json::Obj(doc).to_string_compact())
            .expect("writing bench json");
        println!("wrote {path}");
    }
}
