//! Bench: server-side sign-vote aggregation — the L3 hot path that scales
//! with n·d per round (Algorithm 1 line 15).
//!
//! Compares the packed word-walking `VoteAccumulator` against a naive
//! unpack-and-add baseline, plus the final dequantize (`mean_into`) and the
//! dense-mean path used by FedAvg/QSGD.

use zsignfedavg::bench::{bench, BenchConfig};
use zsignfedavg::compress::pack::{PackedSigns, VoteAccumulator};
use zsignfedavg::rng::Pcg64;
use zsignfedavg::tensor;
use zsignfedavg::testutil::{gen_signs, gen_vec_f32};

fn main() {
    let cfg = BenchConfig::default();
    println!("== sign-vote aggregation (per-round server cost) ==");
    for &(n, d) in &[(10usize, 1_048_576usize), (100, 65_536)] {
        let mut rng = Pcg64::seeded(7);
        let packed: Vec<PackedSigns> = (0..n)
            .map(|_| PackedSigns::from_signs(&gen_signs(&mut rng, d)))
            .collect();
        let mut acc = VoteAccumulator::new(d);

        let r = bench(&format!("votes_packed/n={n},d={d}"), cfg, || {
            acc.reset();
            for p in &packed {
                acc.add(std::hint::black_box(p));
            }
        });
        println!("{}", r.report_throughput((n * d) as f64, "vote"));

        // Naive baseline: unpack to i8 then add per coordinate.
        let mut signs = vec![0i8; d];
        let mut counts = vec![0i32; d];
        let r = bench(&format!("votes_naive/n={n},d={d}"), cfg, || {
            counts.iter_mut().for_each(|c| *c = 0);
            for p in &packed {
                p.unpack_into(&mut signs);
                for (c, &s) in counts.iter_mut().zip(&signs) {
                    *c += s as i32;
                }
            }
        });
        println!("{}", r.report_throughput((n * d) as f64, "vote"));

        let mut update = vec![0.0f32; d];
        let r = bench(&format!("mean_into/d={d}"), cfg, || {
            acc.mean_into(0.01, std::hint::black_box(&mut update));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));

        // Dense aggregation baseline (FedAvg path): n axpys.
        let dense: Vec<Vec<f32>> = (0..n).map(|_| gen_vec_f32(&mut rng, d, 1.0)).collect();
        let mut agg = vec![0.0f32; d];
        let r = bench(&format!("dense_mean/n={n},d={d}"), cfg, || {
            agg.iter_mut().for_each(|v| *v = 0.0);
            for v in &dense {
                tensor::axpy(1.0 / n as f32, std::hint::black_box(v), &mut agg);
            }
        });
        println!("{}", r.report_throughput((n * d) as f64, "elem"));
        println!();
    }
}
