//! Bench: server-side sign-vote aggregation — the hot path that scales
//! with m·d per round (Algorithm 1 line 15).
//!
//! Compares the carry-save (Harley–Seal) bit-sliced `VoteAccumulator`
//! against the pre-CSA implementation (blanket per-client decrement plus a
//! `trailing_zeros` walk of the set bits, reproduced locally below as the
//! frozen baseline) and a naive unpack-and-add floor, at cohort sizes
//! m ∈ {64, 512, 4096}. The CSA plane add + spill dispatches through
//! `compress::simd`, so the CSA row is measured twice — forced to the
//! scalar backend and to the best detected backend — with an exactness
//! cross-check on the resulting counts across every available backend
//! before any timing. Also measures the final dequantize (`mean_into`)
//! and the dense-mean path used by FedAvg/QSGD.
//!
//! `--json PATH` writes the machine-readable perf trajectory (`make
//! bench-json` → `BENCH_aggregate.json` at the repo root); the JSON header
//! records the dispatched kernel path and detected CPU features. `--smoke`
//! runs a tiny-budget pass for CI (`make bench-smoke`).

use std::collections::BTreeMap;
use zsignfedavg::bench::{bench, smoke_mode, BenchConfig};
use zsignfedavg::compress::pack::{PackedSigns, VoteAccumulator};
use zsignfedavg::compress::simd;
use zsignfedavg::rng::Pcg64;
use zsignfedavg::tensor;
use zsignfedavg::testutil::{gen_signs, gen_vec_f32};
use zsignfedavg::util::json::Json;

/// The pre-CSA accumulator, frozen here as the bench baseline: a blanket
/// `counts[j] -= 1` per client plus `+= 2` at every set bit.
struct ScalarVoteAccumulator {
    counts: Vec<i32>,
}

impl ScalarVoteAccumulator {
    fn new(len: usize) -> Self {
        ScalarVoteAccumulator { counts: vec![0; len] }
    }

    fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
    }

    fn add(&mut self, signs: &PackedSigns) {
        assert_eq!(signs.len(), self.counts.len());
        for c in self.counts.iter_mut() {
            *c -= 1;
        }
        for (wi, &w) in signs.words().iter().enumerate() {
            let mut bits = w;
            let base = wi * 64;
            while bits != 0 {
                let j = bits.trailing_zeros() as usize;
                self.counts[base + j] += 2;
                bits &= bits - 1;
            }
        }
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned();
    let smoke = smoke_mode();
    let cfg = if smoke {
        BenchConfig::smoke()
    } else {
        BenchConfig { warmup_time_s: 0.3, samples: 10, min_batch_time_s: 0.05 }
    };
    // (m, d): d shrinks at the largest cohort to bound bench wall time.
    let cases: &[(usize, usize)] =
        if smoke { &[(8, 4096)] } else { &[(64, 262_144), (512, 262_144), (4096, 65_536)] };

    // Dispatched path (honors ZSFA_SIMD) for the JSON header; the CSA rows
    // below re-point dispatch explicitly for the A/B comparison.
    let dispatched = simd::active().label();
    let best = simd::detected_best();
    let paths = simd::available();

    let mut results: BTreeMap<String, Json> = BTreeMap::new();
    println!(
        "== sign-vote aggregation (per-round server cost) ==\n\
         dispatched={dispatched} best={} cpu={}",
        best.label(),
        simd::cpu_features()
    );
    for &(m, d) in cases {
        let mut rng = Pcg64::seeded(7);
        let packed: Vec<PackedSigns> =
            (0..m).map(|_| PackedSigns::from_signs(&gen_signs(&mut rng, d))).collect();
        let mut acc = VoteAccumulator::new(d);
        let mut scalar_acc = ScalarVoteAccumulator::new(d);

        // Exactness cross-check: CSA (under every available SIMD backend)
        // == pre-CSA == naive, for this cohort.
        {
            scalar_acc.reset();
            let mut naive = vec![0i32; d];
            for p in &packed {
                scalar_acc.add(p);
            }
            let mut signs = vec![0i8; d];
            for p in &packed {
                p.unpack_into(&mut signs);
                for (c, &s) in naive.iter_mut().zip(&signs) {
                    *c += s as i32;
                }
            }
            for &path in &paths {
                assert!(simd::set_path(path), "backend {path:?} unavailable");
                acc.reset();
                for p in &packed {
                    acc.add(p);
                }
                let label = path.label();
                assert_eq!(
                    acc.counts(),
                    &scalar_acc.counts[..],
                    "CSA[{label}] vs scalar m={m} d={d}"
                );
                assert_eq!(acc.counts(), &naive[..], "CSA[{label}] vs naive m={m} d={d}");
            }
        }

        // CSA accumulate, plane add + spill forced to the scalar backend...
        simd::set_path(simd::SimdPath::Scalar);
        let csa_scalar = bench(&format!("votes_csa[scalar]/m={m},d={d}"), cfg, || {
            acc.reset();
            for p in &packed {
                acc.add(std::hint::black_box(p));
            }
            std::hint::black_box(acc.counts());
        });
        println!("{}", csa_scalar.report_throughput((m * d) as f64, "vote"));

        // ...and to the best detected backend (the scalar-vs-SIMD row).
        simd::set_path(best);
        let csa = bench(&format!("votes_csa[{}]/m={m},d={d}", best.label()), cfg, || {
            acc.reset();
            for p in &packed {
                acc.add(std::hint::black_box(p));
            }
            std::hint::black_box(acc.counts());
        });
        let simd_speedup = csa_scalar.median_s() / csa.median_s();
        println!(
            "{}   ({simd_speedup:.2}x vs csa-scalar)",
            csa.report_throughput((m * d) as f64, "vote")
        );

        let scalar = bench(&format!("votes_scalar/m={m},d={d}"), cfg, || {
            scalar_acc.reset();
            for p in &packed {
                scalar_acc.add(std::hint::black_box(p));
            }
        });
        let speedup = scalar.median_s() / csa.median_s();
        println!(
            "{}   (csa {speedup:.2}x)",
            scalar.report_throughput((m * d) as f64, "vote")
        );

        // Naive floor: unpack to i8 then add per coordinate.
        let mut signs = vec![0i8; d];
        let mut counts = vec![0i32; d];
        let naive = bench(&format!("votes_naive/m={m},d={d}"), cfg, || {
            counts.iter_mut().for_each(|c| *c = 0);
            for p in &packed {
                p.unpack_into(&mut signs);
                for (c, &s) in counts.iter_mut().zip(&signs) {
                    *c += s as i32;
                }
            }
        });
        println!("{}", naive.report_throughput((m * d) as f64, "vote"));

        let mut update = vec![0.0f32; d];
        let r = bench(&format!("mean_into/d={d}"), cfg, || {
            acc.mean_into(0.01, std::hint::black_box(&mut update));
        });
        println!("{}", r.report_throughput(d as f64, "elem"));

        // Dense aggregation baseline (FedAvg path): axpys over a small
        // synthetic cohort (kept at 16 vectors so memory stays bounded).
        let dn = 16.min(m);
        let dense: Vec<Vec<f32>> = (0..dn).map(|_| gen_vec_f32(&mut rng, d, 1.0)).collect();
        let mut agg = vec![0.0f32; d];
        let r = bench(&format!("dense_mean/m={dn},d={d}"), cfg, || {
            agg.iter_mut().for_each(|v| *v = 0.0);
            for v in &dense {
                tensor::axpy(1.0 / dn as f32, std::hint::black_box(v), &mut agg);
            }
        });
        println!("{}", r.report_throughput((dn * d) as f64, "elem"));
        println!();

        let mut entry = BTreeMap::new();
        entry.insert("m".into(), Json::Num(m as f64));
        entry.insert("d".into(), Json::Num(d as f64));
        entry.insert("csa_votes_per_s".into(), Json::Num(csa.throughput((m * d) as f64)));
        entry.insert(
            "csa_scalar_votes_per_s".into(),
            Json::Num(csa_scalar.throughput((m * d) as f64)),
        );
        entry.insert(
            "scalar_votes_per_s".into(),
            Json::Num(scalar.throughput((m * d) as f64)),
        );
        entry.insert("naive_votes_per_s".into(), Json::Num(naive.throughput((m * d) as f64)));
        entry.insert("speedup".into(), Json::Num(speedup));
        entry.insert("simd_speedup".into(), Json::Num(simd_speedup));
        results.insert(format!("m{m}"), Json::Obj(entry));
    }

    if let Some(path) = json_path {
        let mut doc = BTreeMap::new();
        doc.insert("bench".into(), Json::Str("aggregate".into()));
        doc.insert("smoke".into(), Json::Num(if smoke { 1.0 } else { 0.0 }));
        doc.insert("simd_path".into(), Json::Str(dispatched.into()));
        doc.insert("simd_best".into(), Json::Str(best.label().into()));
        doc.insert("cpu_features".into(), Json::Str(simd::cpu_features()));
        doc.insert("results".into(), Json::Obj(results));
        std::fs::write(&path, Json::Obj(doc).to_string_compact()).expect("writing bench json");
        println!("wrote {path}");
    }
}
