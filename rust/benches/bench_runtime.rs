//! Bench: PJRT execute latency for each artifact kind — the L2 cost model
//! per coordinator round (train_step, fused local_update, eval, compress).
//! Skips politely when `artifacts/` is missing.

use std::path::Path;
use zsignfedavg::bench::{bench, BenchConfig};
use zsignfedavg::data::synth;
use zsignfedavg::rng::{Pcg64, ZParam};
use zsignfedavg::runtime::ModelRuntime;

fn main() {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.json").exists() {
        println!("bench_runtime: no artifacts/ — run `make artifacts` first");
        return;
    }
    let cfg = if zsignfedavg::bench::smoke_mode() {
        BenchConfig::smoke()
    } else {
        BenchConfig { warmup_time_s: 1.0, samples: 20, min_batch_time_s: 0.05 }
    };
    for model in ["mnist_mlp", "mnist_cnn", "cifar_cnn"] {
        let Ok(mut rt) = ModelRuntime::open(dir, model) else {
            println!("skipping {model}: artifacts missing");
            continue;
        };
        let d = rt.param_count;
        println!("== {model} (d = {d}) ==");
        let mut params = rt.load_init().unwrap();
        let spec = if model == "cifar_cnn" {
            synth::SynthSpec::cifar()
        } else {
            synth::SynthSpec::mnist()
        };
        let (train, _) = synth::train_test(spec, 256, 8);
        let b = rt.train_batch;
        let l = train.sample_len();
        let mut x = vec![0.0f32; b * l];
        let mut y = vec![0i32; b];
        train.gather_into(&(0..b).collect::<Vec<_>>(), &mut x, &mut y);

        let r = bench(&format!("train_step/{model}"), cfg, || {
            rt.train_step(&mut params, &x, &y, 0.01).unwrap();
        });
        println!("{}", r.report());

        if rt.fused_local_steps.contains(&5) {
            let mut xs = vec![0.0f32; 5 * b * l];
            let mut ys = vec![0i32; 5 * b];
            for s in 0..5 {
                xs[s * b * l..(s + 1) * b * l].copy_from_slice(&x);
                ys[s * b..(s + 1) * b].copy_from_slice(&y);
            }
            let r = bench(&format!("local_update_e5/{model}"), cfg, || {
                rt.local_update_fused(&mut params, 5, &xs, &ys, 0.01).unwrap();
            });
            println!("{}  ({} per step)", r.report(),
                zsignfedavg::bench::fmt_time(r.median_s() / 5.0));
        }

        let be = rt.eval_batch;
        let mut xe = vec![0.0f32; be * l];
        let mut ye = vec![0i32; be];
        for k in 0..be {
            let i = k % train.n;
            xe[k * l..(k + 1) * l].copy_from_slice(train.image(i));
            ye[k] = train.y[i];
        }
        let r = bench(&format!("eval_step/{model}"), cfg, || {
            rt.eval_step(&params, &xe, &ye).unwrap();
        });
        println!("{}", r.report());

        // Compression through the AOT Pallas kernel: int8 output vs the
        // bit-packed u32 output (8x smaller PJRT transfer).
        let mut rng = Pcg64::seeded(1);
        let delta: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
        for z in [ZParam::Finite(1), ZParam::Inf] {
            let r = bench(&format!("compress_kernel_z{z}/{model}"), cfg, || {
                rt.compress(&delta, z, 0.05, &mut rng).unwrap();
            });
            println!("{}", r.report_throughput(d as f64, "elem"));
        }
        if rt.compress_packed(&delta, ZParam::Finite(1), 0.05, &mut rng).is_ok() {
            let r = bench(&format!("compress_packed_z1/{model}"), cfg, || {
                rt.compress_packed(&delta, ZParam::Finite(1), 0.05, &mut rng).unwrap();
            });
            println!("{}", r.report_throughput(d as f64, "elem"));
        }
        println!();
    }
}
