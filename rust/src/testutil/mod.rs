//! Mini property-testing framework (the vendor set has no proptest).
//!
//! `prop_check` runs a property over `n` randomized cases drawn from a
//! generator; on failure it retries with progressively "smaller" inputs from
//! the generator's shrink hints and reports the seed so the case can be
//! replayed. Generators are plain closures over [`Pcg64`]; the size
//! parameter grows over the run so small cases are tried first (cheap
//! shrinking by construction).

use crate::rng::Pcg64;

/// Configuration for a property run.
#[derive(Debug, Clone, Copy)]
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
    /// Maximum size hint passed to the generator (grows linearly over cases).
    pub max_size: usize,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 100, seed: 0xf00d, max_size: 1024 }
    }
}

/// Check `prop` over `cfg.cases` inputs produced by `gen(rng, size)`.
///
/// `prop` returns `Err(message)` to fail. Panics with the failing case's
/// debug representation, its case index and the RNG seed.
pub fn prop_check<T, G, P>(cfg: PropConfig, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Pcg64, usize) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let mut rng = Pcg64::seeded(cfg.seed);
    for case in 0..cfg.cases {
        // Sizes ramp from 1 to max_size so the smallest failing scale is
        // found first (generation-time shrinking).
        let size = 1 + (cfg.max_size - 1) * case / cfg.cases.max(1);
        let input = gen(&mut rng, size);
        if let Err(msg) = prop(&input) {
            panic!(
                "property failed at case {case}/{} (seed {:#x}, size {size}):\n  \
                 {msg}\n  input: {input:?}",
                cfg.cases, cfg.seed
            );
        }
    }
}

/// Convenience: random f32 vector with entries ~ N(0, scale²).
pub fn gen_vec_f32(rng: &mut Pcg64, len: usize, scale: f32) -> Vec<f32> {
    (0..len).map(|_| (rng.normal() as f32) * scale).collect()
}

/// Convenience: random ±1 sign vector.
pub fn gen_signs(rng: &mut Pcg64, len: usize) -> Vec<i8> {
    (0..len).map(|_| if rng.next_u64() & 1 == 0 { 1 } else { -1 }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        prop_check(
            PropConfig { cases: 50, ..Default::default() },
            |rng, size| gen_vec_f32(rng, size, 1.0),
            |v| {
                if v.iter().all(|x| x.is_finite()) {
                    Ok(())
                } else {
                    Err("non-finite".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failures() {
        prop_check(
            PropConfig { cases: 50, max_size: 64, ..Default::default() },
            |rng, size| gen_vec_f32(rng, size.max(8), 1.0),
            |v| {
                if v.len() < 4 {
                    Ok(())
                } else {
                    Err("too long".into())
                }
            },
        );
    }

    #[test]
    fn sizes_ramp_up() {
        let mut max_seen = 0usize;
        let mut min_seen = usize::MAX;
        prop_check(
            PropConfig { cases: 100, max_size: 512, ..Default::default() },
            |_rng, size| size,
            |&s| {
                max_seen = max_seen.max(s);
                min_seen = min_seen.min(s);
                Ok(())
            },
        );
        assert_eq!(min_seen, 1);
        assert!(max_seen > 400);
    }
}
