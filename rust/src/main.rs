//! `zsfa` — the z-SignFedAvg coordinator CLI.
//!
//! Subcommands:
//!   run <spec.json>     execute any ExperimentSpec without recompiling
//!   run --config f.cfg  config-driven experiment (legacy key=value format)
//!   serve <spec.json>   host the rounds over TCP (networked coordinator)
//!   join <spec.json>    work for a coordinator as a TCP participant
//!   resume <file.ckpt>  continue a checkpointed run (byte-identical)
//!   watch               live telemetry dashboard (endpoint or JSONL tail)
//!   metrics             scrape a coordinator's Prometheus endpoint
//!   fig1 fig2 fig3 fig5 fig6 fig16 fig17 table2
//!                       reproduce the paper's figures/tables (DESIGN.md §8)
//!   scenarios           client-lifecycle simulation: deadlines, dropouts,
//!                       byzantine robustness (DESIGN.md §2.5)
//!   inspect             list artifacts from the manifest
//!   version             print version
//!
//! Every experiment — drivers included — flows through the typed
//! `api::ExperimentSpec` + `api::Session` surface (DESIGN.md §4.5).

use zsignfedavg::api::{Dataset, ExperimentSpec, Session, TransportSpec, WorkloadSpec};
use zsignfedavg::cli::Args;
use zsignfedavg::error::{anyhow, bail, Result};
use zsignfedavg::repro;

fn main() -> Result<()> {
    let args = Args::from_env().map_err(|e| anyhow!(e))?;
    match args.subcommand.as_deref() {
        Some("fig1") => repro::fig1_consensus::run(&args),
        Some("fig2") => repro::fig2_noise::run(&args),
        Some("fig3") | Some("fig7") => repro::fig3_mnist::run(&args),
        Some("fig5") | Some("fig8") => repro::fig5_fedavg::run(&args),
        Some("fig6") => repro::fig6_plateau::run(&args),
        Some("fig16") => repro::fig16_qsgd::run(&args),
        Some("fig17") => repro::fig17_dp::run(&args),
        Some("table2") => repro::table2_rates::run(&args),
        Some("scenarios") => repro::figx_scenarios::run(&args),
        Some("run") => run_cmd(&args),
        Some("serve") => serve_cmd(&args),
        Some("join") => join_cmd(&args),
        Some("resume") => resume_cmd(&args),
        Some("watch") => watch_cmd(&args),
        Some("metrics") => metrics_cmd(&args),
        Some("inspect") => inspect(&args),
        Some("version") => {
            println!("zsfa {}", zsignfedavg::version());
            Ok(())
        }
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            print_help();
            Ok(())
        }
    }
}

fn print_help() {
    println!(
        "zsfa {} — z-SignFedAvg federated-learning coordinator (AAAI'24 reproduction)

USAGE: zsfa <subcommand> [--key value ...]

SUBCOMMANDS
  run     execute an experiment spec: zsfa run spec.json
          (typed JSON: workload, algorithm series/sweep, scenario,
           repeats — see rust/examples/quickstart.json and DESIGN.md §4.5;
           --parallelism/--reduce-lanes/--out override execution knobs)
          legacy key=value configs still work: --config configs/<f>.cfg
          (set sim = true + sim_* keys for scenario participation)
          --transport engine|loopback|tcp selects where rounds execute
  serve   host a spec's rounds over TCP:  zsfa serve spec.json --addr :7070
          (--heartbeat-ms/--round-deadline-ms/--min-participants tune
           liveness; results are bit-identical to `zsfa run`; with
           --telemetry the coordinator port also answers GET /metrics)
  join    work for a coordinator:  zsfa join spec.json --addr host:7070
          (same spec file on both sides; exits when the run finishes;
           --chaos-seed N injects seeded transport faults — results stay
           byte-identical; --stall holds one work order forever to force
           the coordinator's deadline/reclaim/quorum path)
  resume  continue a crashed/checkpointed run:  zsfa resume file.ckpt
          (the snapshot embeds its spec; the continued run is
           byte-identical to one that never stopped; --jsonl FILE
           re-attaches the event log in append mode, and the
           --checkpoint-* flags keep snapshotting the resumed run)
  watch   live dashboard:  zsfa watch --addr host:7070  (poll endpoint)
                           zsfa watch --jsonl events.jsonl  (tail a log)
          (--interval-ms N refresh rate, --once prints one frame)
  metrics scrape Prometheus text:  zsfa metrics --addr host:7070
          (--json fetches the /metrics.json registry snapshot instead)
  fig1    consensus problem across dimensions (+ §1 counterexample)
  fig2    noise-scale bias/variance trade-off
  fig3    non-iid MNIST sign-method comparison   (--sweep-sigma => fig7)
  fig5    FedAvg vs z-SignFedAvg                 (--dataset emnist => fig8,
                                                  --sweep => figs 9-13)
  fig6    plateau criterion  (--dataset mnist|emnist|cifar)
  fig16   sign vs QSGD/FedPAQ accuracy-per-bit
  fig17   DP-SignFedAvg vs DP-FedAvg across privacy budgets
  table2  rate summary + empirical rate fit
  scenarios client-lifecycle sim: stragglers/dropouts (time-to-target) and
          byzantine robustness curves (--sim_* flags, see sim/)
  inspect list AOT artifacts

COMMON FLAGS (run/serve)
  --telemetry (enable the metrics registry + event ring; results stay
               byte-identical — telemetry is read-only, DESIGN.md §6)
  --dump-metrics FILE (write a Prometheus snapshot at exit; implies
                       --telemetry)
  --jsonl FILE (stream round events as JSON lines; carries phase
                timings when telemetry is on)
  --checkpoint-every N (snapshot the full run state every N rounds to
                        <dir>/<experiment>.ckpt; recover with
                        `zsfa resume`)
  --checkpoint-on-signal (also snapshot at the next round boundary after
                          SIGUSR1)
  --checkpoint-dir DIR (where snapshots land; default: checkpoints)

COMMON FLAGS
  --rounds N --repeats N --seed N --paper-scale
  --parallelism N (client worker threads; bit-identical results for any N)
  --reduce-lanes L (fixed reduction topology; reproducibility knob like
                    --seed — results identical across --parallelism for
                    any fixed L; default 64)
  --artifacts DIR (default: artifacts)
  figures 3-17 need `make artifacts` first",
        zsignfedavg::version()
    );
}

fn inspect(args: &Args) -> Result<()> {
    let dir = std::path::Path::new(args.str_or("artifacts", "artifacts"));
    let man = zsignfedavg::runtime::manifest::Manifest::load(dir).map_err(|e| anyhow!(e))?;
    if let Some(name) = args.flag("hlo") {
        // Op-count / FLOP audit of one artifact (L2 perf tooling).
        let info = man.get(name).map_err(|e| anyhow!(e))?;
        let audit = zsignfedavg::runtime::hlo_audit::audit_file(&info.file)?;
        println!("HLO audit for {name}:\n{}", audit.report());
        return Ok(());
    }
    println!("{} artifacts in {dir:?}:", man.artifacts.len());
    for a in man.artifacts.values() {
        let ins: Vec<String> = a
            .inputs
            .iter()
            .map(|t| format!("{}:{:?}{:?}", t.name, t.dtype, t.shape))
            .collect();
        println!("  {:<40} kind={:<14} inputs=[{}]",
            a.name,
            a.meta_str("kind").unwrap_or("?"),
            ins.join(", "));
    }
    Ok(())
}

/// `zsfa run`: a spec file when a positional path is given, the legacy
/// config format otherwise.
fn run_cmd(args: &Args) -> Result<()> {
    match args.positional.first() {
        Some(path) => run_spec(args, path),
        None => run_config(args),
    }
}

/// Apply the observability flags (`--telemetry`, `--dump-metrics`,
/// `--jsonl`) to `spec` and build the driver session. The JSONL sink and
/// the session share one telemetry handle so phase timings reach both the
/// event log and the endpoint/dump exporters.
fn console_session(args: &Args, spec: &mut ExperimentSpec) -> Result<Session> {
    use zsignfedavg::api::{JsonlSink, TelemetrySpec};
    if args.has("telemetry") || args.has("dump-metrics") {
        let mut t =
            if spec.telemetry.enabled { spec.telemetry.clone() } else { TelemetrySpec::on() };
        if let Some(path) = args.flag("dump-metrics") {
            t.dump_path = Some(path.to_string());
        }
        spec.telemetry = t;
    }
    let tele = spec.telemetry.handle();
    let mut session = Session::console().with_telemetry(tele.clone());
    if let Some(path) = args.flag("jsonl") {
        let sink = JsonlSink::create(std::path::Path::new(path))?.with_telemetry(tele);
        session = session.with(sink);
    }
    Ok(session)
}

/// The `--checkpoint-every` / `--checkpoint-on-signal` /
/// `--checkpoint-dir` flags shared by `run`, `serve` and `resume`. Off
/// unless one of the trigger flags is present.
fn checkpoint_policy(args: &Args) -> Result<zsignfedavg::ckpt::CheckpointPolicy> {
    use zsignfedavg::ckpt::CheckpointPolicy;
    let every = args.u64_or("checkpoint-every", 0)?;
    let on_signal = args.has("checkpoint-on-signal");
    if every == 0 && !on_signal {
        return Ok(CheckpointPolicy::off());
    }
    let dir = args.str_or("checkpoint-dir", "checkpoints");
    Ok(CheckpointPolicy {
        dir: dir.into(),
        every: if every > 0 { Some(every) } else { None },
        on_signal,
    })
}

/// `zsfa resume`: continue a checkpointed run. The snapshot embeds the
/// canonical spec it was captured under, so no spec file is needed — and
/// none is accepted: any spec change would make the continuation diverge
/// from the uninterrupted run, which is exactly what the fingerprint
/// check refuses. `--jsonl` re-attaches the event log in append mode
/// (the sink is rolled back to its checkpoint mark before new lines are
/// written); the `--checkpoint-*` flags keep snapshotting the resumed
/// run.
fn resume_cmd(args: &Args) -> Result<()> {
    use zsignfedavg::api::JsonlSink;
    use zsignfedavg::ckpt::Snapshot;
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: zsfa resume <file.ckpt> [--jsonl events.jsonl]"))?;
    let snap = Snapshot::load(std::path::Path::new(path))?;
    let spec = ExperimentSpec::from_json(&snap.spec_json)?;
    // Observers must be re-attached in the same order they were captured
    // in: the console pair first (as `run`/`serve` build them), then the
    // optional JSONL sink.
    let tele = spec.telemetry.handle();
    let mut session = Session::console().with_telemetry(tele.clone());
    if let Some(p) = args.flag("jsonl") {
        let sink = JsonlSink::append(std::path::Path::new(p))?.with_telemetry(tele);
        session = session.with(sink);
    }
    println!(
        "resume: {} — series {} repeat {} round {} (of {})",
        spec.name, snap.series, snap.repeat, snap.engine.next_round, spec.rounds
    );
    log_simd_path();
    session.resume(&spec, &snap, &checkpoint_policy(args)?)?;
    Ok(())
}

/// `zsfa watch`: the live terminal dashboard (DESIGN.md §6.4).
fn watch_cmd(args: &Args) -> Result<()> {
    use zsignfedavg::telemetry::watch::{self, WatchOpts};
    let opts = WatchOpts {
        addr: args.flag("addr").map(String::from),
        jsonl: args.flag("jsonl").map(String::from),
        interval_ms: args.u64_or("interval-ms", 1_000)?,
        once: args.has("once"),
    };
    if opts.addr.is_none() && opts.jsonl.is_none() {
        bail!("usage: zsfa watch --addr host:port | --jsonl events.jsonl [--once]");
    }
    watch::run(&opts).map_err(|e| anyhow!("watch: {e}"))
}

/// `zsfa metrics`: one-shot scrape of a serving coordinator's metrics
/// endpoint (Prometheus text, or the JSON registry snapshot with
/// `--json`). The coordinator must be running `serve --telemetry`.
fn metrics_cmd(args: &Args) -> Result<()> {
    let addr = args
        .flag("addr")
        .ok_or_else(|| anyhow!("usage: zsfa metrics --addr host:port [--json]"))?;
    let path = if args.has("json") { "/metrics.json" } else { "/metrics" };
    let timeout_ms = args.u64_or("timeout-ms", 2_000)?;
    let body = zsignfedavg::telemetry::watch::http_get(addr, path, timeout_ms)
        .map_err(|e| anyhow!("metrics: {e}"))?;
    print!("{body}");
    Ok(())
}

/// Execute an `ExperimentSpec` JSON file. Execution knobs (and only those)
/// can be overridden from the CLI: `--parallelism` and `--reduce-lanes`
/// never change *what* the experiment is (determinism contract /
/// reproducibility knob), `--out` only moves the results tree.
fn run_spec(args: &Args, path: &str) -> Result<()> {
    let mut spec = ExperimentSpec::from_json_file(std::path::Path::new(path))?;
    spec = zsignfedavg::repro::common::apply_execution_flags(spec, args)?;
    if let Some(dir) = args.flag("out") {
        spec = spec.output_dir(dir);
    }
    // The transport is an execution knob too: every transport is
    // bit-identical to the engine when all work is submitted.
    if let Some(t) = args.flag("transport") {
        spec = spec.transport(match t {
            "engine" => TransportSpec::Engine,
            "loopback" => TransportSpec::Loopback,
            "tcp" => TransportSpec::tcp(args.str_or("addr", "127.0.0.1:7070")),
            other => bail!("unknown transport {other:?} (expected engine|loopback|tcp)"),
        });
    }
    let mut session = console_session(args, &mut spec)?;
    println!(
        "run: {} — {} series x {} repeats, {} rounds",
        spec.name,
        spec.expanded_series().len(),
        spec.repeats,
        spec.rounds
    );
    log_simd_path();
    session.run_with_checkpoints(&spec, &checkpoint_policy(args)?)?;
    Ok(())
}

/// One-line record of which SIMD backend the hot kernels dispatched to
/// (also exported as the `zsfa_simd_path` telemetry gauge). Results are
/// bit-identical on every path; the line is for perf triage and A/B runs.
fn log_simd_path() {
    use zsignfedavg::compress::simd;
    println!(
        "simd: {} kernels on {} ({}=off|avx2|neon overrides)",
        simd::active().label(),
        simd::cpu_features(),
        simd::SIMD_ENV,
    );
}

/// `zsfa serve`: host an experiment's rounds over TCP. The spec's TCP
/// settings (when present) are the baseline; `--addr`, `--heartbeat-ms`,
/// `--round-deadline-ms` and `--min-participants` override them.
fn serve_cmd(args: &Args) -> Result<()> {
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: zsfa serve <spec.json> [--addr host:port]"))?;
    let mut spec = ExperimentSpec::from_json_file(std::path::Path::new(path))?;
    spec = zsignfedavg::repro::common::apply_execution_flags(spec, args)?;
    if let Some(dir) = args.flag("out") {
        spec = spec.output_dir(dir);
    }
    let (mut addr, d_hb, d_dl, d_min) = match spec.transport.clone() {
        TransportSpec::Tcp { addr, heartbeat_ms, round_deadline_ms, min_participants } => {
            (addr, heartbeat_ms, round_deadline_ms, min_participants)
        }
        _ => {
            let TransportSpec::Tcp { addr, heartbeat_ms, round_deadline_ms, min_participants } =
                TransportSpec::tcp("127.0.0.1:7070")
            else {
                unreachable!()
            };
            (addr, heartbeat_ms, round_deadline_ms, min_participants)
        }
    };
    if let Some(a) = args.flag("addr") {
        addr = a.to_string();
    }
    spec = spec.transport(TransportSpec::Tcp {
        addr,
        heartbeat_ms: args.u64_or("heartbeat-ms", d_hb)?,
        round_deadline_ms: args.u64_or("round-deadline-ms", d_dl)?,
        min_participants: args.usize_or("min-participants", d_min)?,
    });
    let mut session = console_session(args, &mut spec)?;
    println!(
        "serve: {} — {} series x {} repeats, {} rounds",
        spec.name,
        spec.expanded_series().len(),
        spec.repeats,
        spec.rounds
    );
    log_simd_path();
    session.run_with_checkpoints(&spec, &checkpoint_policy(args)?)?;
    Ok(())
}

/// `zsfa join`: work for a coordinator as a TCP participant until the
/// experiment finishes. Both sides must load the same spec file — that is
/// how they agree on the workload, series algorithms and repeat seeds.
///
/// `--chaos-seed N` wraps the connection in a seeded fault-injecting
/// transport (the chaos-smoke harness; results must stay byte-identical).
/// `--stall` joins, pulls one work order and never submits it — a
/// scripted straggler for exercising the coordinator's deadline/reclaim/
/// quorum degradation path.
fn join_cmd(args: &Args) -> Result<()> {
    use zsignfedavg::service::{
        ChaosConfig, ChaosTransport, FaultPlan, Participant, RetryPolicy, TcpTransport, Transport,
    };
    let path = args
        .positional
        .first()
        .ok_or_else(|| anyhow!("usage: zsfa join <spec.json> --addr host:port"))?;
    let spec = ExperimentSpec::from_json_file(std::path::Path::new(path))?;
    let addr = match (args.flag("addr"), &spec.transport) {
        (Some(a), _) => a.to_string(),
        (None, TransportSpec::Tcp { addr, .. }) => addr.clone(),
        (None, _) => bail!("join needs --addr (or a tcp transport in the spec)"),
    };
    let patience = std::time::Duration::from_secs(args.u64_or("patience-s", 30)?);
    println!("join: working for coordinator at {addr}");
    log_simd_path();
    let tcp = TcpTransport::connect(&addr, patience)?;
    let chaos_seed =
        if args.has("chaos-seed") { Some(args.u64_or("chaos-seed", 0)?) } else { None };
    let mut transport: Box<dyn Transport> = match chaos_seed {
        Some(seed) => {
            println!("join: chaos transport on (aggressive profile, seed {seed})");
            Box::new(ChaosTransport::new(tcp, FaultPlan::new(ChaosConfig::aggressive(), seed)))
        }
        None => Box::new(tcp),
    };
    let retry = match chaos_seed {
        Some(seed) => RetryPolicy { seed, ..RetryPolicy::default() },
        None => RetryPolicy::default(),
    };
    if args.has("stall") {
        stall(transport.as_mut(), retry, patience)?;
    } else {
        let mut p = Participant::new(spec).with_retry(retry).with_rendezvous_patience(patience);
        p.run(transport.as_mut())?;
    }
    println!("join: coordinator finished, exiting");
    Ok(())
}

/// The `join --stall` loop: rendezvous, pull one work order, hold it
/// without submitting, heartbeat until the coordinator reports
/// `Finished`. The held slot forces the coordinator through its
/// round-deadline reclaim (and, if nobody repairs it, a quorum close).
fn stall(
    transport: &mut dyn Transport,
    retry: zsignfedavg::service::RetryPolicy,
    patience: std::time::Duration,
) -> Result<()> {
    use zsignfedavg::service::participant::{rendezvous_retrying, request_with_retry};
    use zsignfedavg::service::protocol::{PhaseReply, Reply, Request, RoundReply};
    use zsignfedavg::telemetry::Telemetry;
    let tele = Telemetry::disabled();
    let pid = loop {
        match rendezvous_retrying(transport, retry, patience, &tele)? {
            Some(pid) => break pid,
            None => retry.sleep(0),
        }
    };
    println!("stall: joined as pid {pid}; will hold the first work order");
    let mut holding = false;
    loop {
        if !holding {
            if let Reply::Round(RoundReply::Work(w)) =
                request_with_retry(transport, &Request::PullRound { pid }, retry, &tele)?
            {
                println!("stall: holding round {} (never submitting)", w.round);
                holding = true;
            }
        }
        if let Reply::Heartbeat(PhaseReply::Finished) =
            request_with_retry(transport, &Request::Heartbeat { pid }, retry, &tele)?
        {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
}

/// Config-driven experiment runner (see `configs/*.cfg`), routed through
/// the same spec/session seam as everything else.
fn run_config(args: &Args) -> Result<()> {
    use zsignfedavg::config::Config;
    use zsignfedavg::fl::server::Participation;
    use zsignfedavg::fl::AlgorithmConfig;
    use zsignfedavg::repro::common::neural_spec_from_args;
    use zsignfedavg::rng::ZParam;

    let mut cfg = Config::new();
    if let Some(path) = args.flag("config") {
        cfg = Config::load(std::path::Path::new(path)).map_err(|e| anyhow!(e))?;
    }
    args.apply_overrides(&mut cfg);

    let dataset = Dataset::parse(cfg.str_or("dataset", "mnist"))
        .ok_or_else(|| anyhow!("dataset must be mnist|emnist|cifar"))?;
    let algo_name = cfg.str_or("algorithm", "1-signfedavg").to_string();
    let sigma = cfg.f32_or("sigma", 0.05)?;
    let e = cfg.usize_or("local_steps", 1)?;
    let algo = match algo_name.as_str() {
        "fedavg" => AlgorithmConfig::fedavg(e),
        "signsgd" => AlgorithmConfig::signsgd(),
        "sign-fedavg" => AlgorithmConfig::sign_fedavg(e),
        "1-signfedavg" => AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e),
        "inf-signfedavg" => AlgorithmConfig::z_signfedavg(ZParam::Inf, sigma, e),
        "sto-signsgd" => AlgorithmConfig::sto_signsgd(),
        "ef-signsgd" => AlgorithmConfig::ef_signsgd(),
        "qsgd" => AlgorithmConfig::qsgd(cfg.usize_or("qsgd_levels", 2)? as u32),
        other => bail!("unknown algorithm {other:?}"),
    }
    .with_lrs(cfg.f32_or("client_lr", 0.01)?, cfg.f32_or("server_lr", 1.0)?)
    .with_momentum(cfg.f32_or("momentum", 0.0)?);

    let participation = if cfg.bool_or("sim", false)? {
        Participation::Simulated(zsignfedavg::sim::ScenarioConfig::from_config(&cfg)?)
    } else {
        Participation::Uniform
    };
    let spec = ExperimentSpec::new(
        "run",
        WorkloadSpec::Neural(neural_spec_from_args(dataset, args)?),
    )
    .rounds(cfg.usize_or("rounds", 100)?)
    .clients_per_round(cfg.opt_usize("clients_per_round")?)
    .eval_every(cfg.usize_or("eval_every", 5)?)
    .seed(cfg.u64_or("seed", 0)?)
    .repeats(cfg.usize_or("repeats", 1)?)
    .parallelism(cfg.parallelism_or(1)?)
    .reduce_lanes(cfg.reduce_lanes_or(zsignfedavg::fl::server::DEFAULT_REDUCE_LANES)?)
    .participation(participation)
    .series(algo);

    println!(
        "run: {} on {dataset:?} — rounds={} E={e} repeats={}",
        spec.series[0].algorithm.name, spec.rounds, spec.repeats
    );
    log_simd_path();
    Session::console().run(&spec)?;
    for k in cfg.unused_keys() {
        eprintln!("warning: unused config key {k:?}");
    }
    Ok(())
}
