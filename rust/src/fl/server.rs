//! Algorithm 1's round loop — the coordinator proper.
//!
//! Responsibilities per communication round t:
//!   1. sample the participant set (full or uniform partial participation);
//!   2. orchestrate each participant's E local SGD steps via the backend;
//!   3. apply the configured uplink compressor to each client's update
//!      direction `(x_{t-1} − x^i_{t-1,E})/γ` and account the exact bits;
//!   4. aggregate: packed-sign **vote accumulation** for the sign family
//!      (the hot path — see `compress::pack::VoteAccumulator`), dense mean
//!      otherwise;
//!   5. server step `x_t = x_{t-1} − η·γ·agg` (Alg. 1 line 15), with
//!      optional server momentum (the paper's "wM" baselines) and the DP
//!      variant's γ-free step (Alg. 2 line 15);
//!   6. feed the plateau controller and periodically evaluate.
//!
//! Determinism: every (round, client) pair gets its own PCG stream derived
//! from the experiment seed, so runs are bit-reproducible regardless of
//! participant order.

use super::algorithms::{AlgorithmConfig, Compression, ServerOpt};
use super::backend::TrainBackend;
use super::metrics::{RoundRecord, RunResult};
use super::plateau::{PlateauConfig, PlateauController};
use crate::compress::error_feedback::EfState;
use crate::compress::pack::{PackedSigns, VoteAccumulator};
use crate::compress::qsgd::Qsgd;
use crate::compress::sign::{SigmaRule, StochasticSign};
use crate::compress::sparsify::{SparseSign, TopK};
use crate::compress::{Compressor, Message};
use crate::rng::{Pcg64, ZParam};
use crate::tensor;
use crate::util::Timer;

/// Server-side experiment configuration (everything that is not the
/// algorithm itself).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Communication rounds T.
    pub rounds: usize,
    /// Clients sampled per round (None = full participation).
    pub clients_per_round: Option<usize>,
    /// Evaluate every k rounds (records are emitted only on eval rounds).
    pub eval_every: usize,
    /// Experiment seed (repeats vary this).
    pub seed: u64,
    /// Optional §4.4 plateau controller for the noise scale.
    pub plateau: Option<PlateauConfig>,
    /// Optional downlink compression: broadcast the *server update* as a
    /// stochastic sign with scale σ_d (the [27]/[12] bidirectional setting).
    /// The server applies the compressed update itself, so server and
    /// clients stay consistent; downlink costs d bits per client per round.
    pub downlink_sign: Option<(ZParam, f32)>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            rounds: 100,
            clients_per_round: None,
            eval_every: 1,
            seed: 0,
            plateau: None,
            downlink_sign: None,
        }
    }
}

/// Run one experiment; returns the evaluated round records.
pub fn run_experiment(
    backend: &mut dyn TrainBackend,
    algo: &AlgorithmConfig,
    cfg: &ServerConfig,
) -> RunResult {
    let d = backend.dim();
    let n = backend.num_clients();
    let m_per_round = cfg.clients_per_round.unwrap_or(n).min(n);
    assert!(m_per_round >= 1);
    if matches!(algo.compression, Compression::ErrorFeedback) {
        assert!(
            m_per_round == n,
            "EF-SignSGD cannot track residuals under partial participation (paper §1.1)"
        );
    }

    let mut params = backend.init_params();
    assert_eq!(params.len(), d);
    let root = Pcg64::new(cfg.seed, 0xa11ce);

    // Server state.
    let mut momentum_buf = vec![0.0f32; d];
    let mut adam_v = vec![0.0f32; d];
    let mut adam_t = 0u32;
    let mut plateau = cfg.plateau.map(PlateauController::new);
    let mut ef_states: Vec<EfState> = match algo.compression {
        Compression::ErrorFeedback => (0..n).map(|_| EfState::new(d)).collect(),
        _ => Vec::new(),
    };

    // Scratch buffers reused across rounds (no allocation on the hot loop).
    let mut votes = VoteAccumulator::new(d);
    let mut dense_acc = vec![0.0f32; d];
    let mut update = vec![0.0f32; d];
    let mut signs_buf = vec![0i8; d];
    let mut decode_buf = vec![0.0f32; d];

    let mut bits_up: u64 = 0;
    let mut bits_down: u64 = 0;
    let mut records = Vec::new();

    for t in 0..cfg.rounds {
        let timer = Timer::start();
        // 1. Participant sampling (uniform, without replacement).
        let mut sample_rng = root.split(t as u64 * 2 + 1);
        let participants: Vec<usize> = if m_per_round == n {
            (0..n).collect()
        } else {
            sample_rng.sample_without_replacement(n, m_per_round)
        };

        // Effective sigma this round (plateau overrides the fixed value).
        let round_sigma = effective_sigma(algo, plateau.as_ref());

        votes.reset();
        dense_acc.iter_mut().for_each(|v| *v = 0.0);
        let inv_m = 1.0f32 / participants.len() as f32;
        let mut loss_sum = 0.0f64;

        // 2–3. Local updates + compression.
        for &client in &participants {
            let mut crng = root.split(((t as u64) << 20) ^ (client as u64) ^ 0x5eed);
            let outcome =
                backend.local_update(client, &params, algo.local_steps, algo.client_lr, &mut crng);
            loss_sum += outcome.mean_loss;
            match &algo.compression {
                Compression::None => {
                    tensor::axpy(inv_m, &outcome.delta, &mut dense_acc);
                    bits_up += 32 * d as u64;
                }
                Compression::ZSign { z, sigma } => {
                    let s = match sigma {
                        SigmaRule::Fixed(_) => round_sigma,
                        SigmaRule::L2Norm => tensor::norm2(&outcome.delta) as f32,
                        SigmaRule::InfNorm => tensor::norm_inf(&outcome.delta) as f32,
                    };
                    // Prefer the backend's AOT Pallas kernel; fall back to
                    // the Rust reference compressor (analytic problems).
                    let packed = match backend.compress_hook(&outcome.delta, *z, s, &mut crng) {
                        Some(packed) => packed,
                        None => {
                            let mut comp = StochasticSign::new(*z, SigmaRule::Fixed(s));
                            comp.compress_into(&outcome.delta, &mut crng, &mut signs_buf);
                            PackedSigns::from_signs(&signs_buf)
                        }
                    };
                    votes.add(&packed);
                    bits_up += d as u64;
                }
                Compression::ErrorFeedback => {
                    // EF compresses the stepsize-scaled update γ·Σg.
                    let mut scaled = outcome.delta.clone();
                    tensor::scale(algo.client_lr, &mut scaled);
                    let msg = ef_states[client].step(&scaled);
                    bits_up += msg.bits_on_wire();
                    msg.decode_into(&mut decode_buf);
                    // Undo the γ scaling so the server step stays η·γ·agg.
                    tensor::axpy(inv_m / algo.client_lr, &decode_buf, &mut dense_acc);
                }
                Compression::Qsgd { s } => {
                    let q = Qsgd::new(*s).quantize(&outcome.delta, &mut crng);
                    bits_up += q.bits_on_wire();
                    q.decode_into(&mut decode_buf);
                    tensor::axpy(inv_m, &decode_buf, &mut dense_acc);
                }
                Compression::DpSign { clip, noise_mult } => {
                    // Alg. 2 line 11: clip the *model diff*, perturb, sign.
                    let mut diff = outcome.delta.clone();
                    tensor::scale(algo.client_lr, &mut diff); // γ·Σg = x_{t-1} − x_E
                    tensor::clip_l2(&mut diff, *clip as f64);
                    let noise_std = noise_mult * clip;
                    for v in diff.iter_mut() {
                        *v += noise_std * crng.normal() as f32;
                    }
                    votes.add(&PackedSigns::from_f32_signs(&diff));
                    bits_up += d as u64;
                }
                Compression::DpDense { clip, noise_mult } => {
                    let mut diff = outcome.delta.clone();
                    tensor::scale(algo.client_lr, &mut diff);
                    tensor::clip_l2(&mut diff, *clip as f64);
                    let noise_std = noise_mult * clip;
                    for v in diff.iter_mut() {
                        *v += noise_std * crng.normal() as f32;
                    }
                    tensor::axpy(inv_m, &diff, &mut dense_acc);
                    bits_up += 32 * d as u64;
                }
                Compression::TopK { frac } => {
                    let msg = TopK::new(*frac).compress(&outcome.delta, &mut crng);
                    bits_up += msg.bits_on_wire();
                    if let Message::Sparse(s) = &msg {
                        s.decode_into(&mut decode_buf);
                    }
                    tensor::axpy(inv_m, &decode_buf, &mut dense_acc);
                }
                Compression::SparseSign { frac, z, sigma } => {
                    let msg =
                        SparseSign::new(*frac, *z, *sigma).compress(&outcome.delta, &mut crng);
                    bits_up += msg.bits_on_wire();
                    if let Message::Sparse(s) = &msg {
                        s.decode_into(&mut decode_buf);
                    }
                    tensor::axpy(inv_m, &decode_buf, &mut dense_acc);
                }
            }
        }

        // 4–5. Aggregate + server step.
        let step_scale = match &algo.compression {
            // Alg. 2 applies η to the mean sign of *model diffs* (no γ).
            Compression::DpSign { .. } => algo.server_lr,
            // DP-FedAvg likewise averages model diffs directly.
            Compression::DpDense { .. } => algo.server_lr,
            // Alg. 1 line 15: η·γ·mean(Δ).
            _ => algo.server_lr * algo.client_lr,
        };
        if algo.compression.is_sign() {
            votes.mean_into(1.0, &mut update);
        } else {
            update.copy_from_slice(&dense_acc);
        }
        // Optional downlink compression: broadcast the update itself as a
        // dequantized stochastic sign (applied server-side too, so the
        // global iterate equals what the clients reconstruct).
        if let Some((z, sigma_d)) = cfg.downlink_sign {
            let mut drng = root.split((t as u64) | 0x4000_0000_0000_0000);
            let mut comp = StochasticSign::new(z, SigmaRule::Fixed(sigma_d));
            comp.compress_into(&update.clone(), &mut drng, &mut signs_buf);
            let scale = (z.eta() as f32) * sigma_d;
            for (u, &s) in update.iter_mut().zip(&signs_buf) {
                *u = scale * s as f32;
            }
            bits_down += (participants.len() * d) as u64;
        } else {
            bits_down += (participants.len() * d * 32) as u64;
        }
        match algo.server_opt {
            ServerOpt::Sgd => tensor::axpy(-step_scale, &update, &mut params),
            ServerOpt::Momentum(beta) => {
                // Server momentum: m ← β·m + agg; x ← x − scale·m.
                for (mb, &u) in momentum_buf.iter_mut().zip(&update) {
                    *mb = beta * *mb + u;
                }
                tensor::axpy(-step_scale, &momentum_buf, &mut params);
            }
            ServerOpt::Adam { beta1, beta2, eps } => {
                // FedAdam (Reddi et al. '20) with bias correction.
                adam_t += 1;
                let bc1 = 1.0 - beta1.powi(adam_t as i32);
                let bc2 = 1.0 - beta2.powi(adam_t as i32);
                for ((p, mb), (vb, &u)) in params
                    .iter_mut()
                    .zip(momentum_buf.iter_mut())
                    .zip(adam_v.iter_mut().zip(&update))
                {
                    *mb = beta1 * *mb + (1.0 - beta1) * u;
                    *vb = beta2 * *vb + (1.0 - beta2) * u * u;
                    let mhat = *mb / bc1;
                    let vhat = *vb / bc2;
                    *p -= step_scale * mhat / (vhat.sqrt() + eps);
                }
            }
        }

        // 6. Plateau + evaluation.
        let mean_local_loss = loss_sum / participants.len() as f64;
        if let Some(p) = plateau.as_mut() {
            p.observe(mean_local_loss);
        }
        if t % cfg.eval_every == 0 || t + 1 == cfg.rounds {
            let eval = backend.evaluate(&params);
            records.push(RoundRecord {
                round: t,
                objective: eval.objective,
                accuracy: eval.accuracy,
                grad_norm_sq: eval.grad_norm_sq,
                bits_up,
                bits_down,
                sigma: round_sigma,
                wall_ms: timer.elapsed_ms(),
            });
        }
    }

    RunResult { algorithm: algo.name.clone(), records }
}

fn effective_sigma(algo: &AlgorithmConfig, plateau: Option<&PlateauController>) -> f32 {
    match (&algo.compression, plateau) {
        (Compression::ZSign { sigma: SigmaRule::Fixed(_), .. }, Some(p)) => p.sigma(),
        (Compression::ZSign { sigma: SigmaRule::Fixed(s), .. }, None) => *s,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::backend::AnalyticBackend;
    use crate::problems::consensus::Consensus;
    use crate::problems::AnalyticProblem;
    use crate::rng::ZParam;

    fn consensus_backend(n: usize, d: usize) -> AnalyticBackend<Consensus> {
        AnalyticBackend::new(Consensus::gaussian(n, d, 99))
    }

    #[test]
    fn gd_converges_on_consensus() {
        let mut b = consensus_backend(10, 20);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::gd().with_lrs(0.1, 1.0);
        let cfg = ServerConfig { rounds: 200, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        assert!(run.final_objective() - f_star < 1e-6, "gap={}", run.final_objective() - f_star);
    }

    #[test]
    fn signsgd_stalls_on_counterexample() {
        // The §1 counterexample: vanilla SignSGD never moves from x0 in (−A, A).
        let mut b = AnalyticBackend::new(Consensus::counterexample(4.0));
        b.x0 = vec![2.0];
        let algo = AlgorithmConfig::signsgd().with_lrs(0.01, 1.0);
        let cfg = ServerConfig { rounds: 100, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let first = run.records.first().unwrap().objective;
        let last = run.records.last().unwrap().objective;
        assert!((first - last).abs() < 1e-9, "SignSGD moved: {first} -> {last}");
    }

    #[test]
    fn stochastic_sign_escapes_counterexample() {
        // 1-SignSGD (Gaussian noise) does make progress on the same instance.
        // f* = 16 for A = 4 (the objective is x^2 + 16).
        let mut b = AnalyticBackend::new(Consensus::counterexample(4.0));
        let f_star = b.problem.optimal_value().unwrap();
        b.x0 = vec![2.0];
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 5.0).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 400, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.records.last().unwrap().objective - f_star;
        assert!(gap < gap0 * 0.3, "gap {gap0} -> {gap}");
    }

    #[test]
    fn inf_sign_threshold_behaviour() {
        // Theorem 2 / Remark 2: with sigma below the gradient range, inf-sign
        // cannot converge; with sigma above it, it does.
        let a = 4.0f32;
        for (sigma, should_move) in [(1.0f32, false), (20.0, true)] {
            let mut b = AnalyticBackend::new(Consensus::counterexample(a));
            let f_star = b.problem.optimal_value().unwrap();
            b.x0 = vec![2.0];
            let algo = AlgorithmConfig::z_signsgd(ZParam::Inf, sigma).with_lrs(0.05, 1.0);
            let cfg = ServerConfig { rounds: 800, ..Default::default() };
            let run = run_experiment(&mut b, &algo, &cfg);
            let first = run.records.first().unwrap().objective;
            let last = run.records.last().unwrap().objective;
            if should_move {
                let (gap0, gap) = (first - f_star, last - f_star);
                assert!(gap < gap0 * 0.5, "sigma={sigma}: gap {gap0} -> {gap}");
            } else {
                // Gradients at x0=2: f1' = 2(x−4) = −4, f2' = 2(x+4) = 12.
                // With sigma=1 < 4 the signs are deterministic and cancel.
                assert!((first - last).abs() < 1e-9, "sigma={sigma} moved");
            }
        }
    }

    #[test]
    fn z_signfedavg_with_local_steps_converges() {
        // E = 5 local steps: the compressed quantity is a sum of 5 gradients,
        // so sigma must scale with E (Theorem 1's threshold grows with E).
        let mut b = consensus_backend(10, 30);
        let f_star = b.problem.optimal_value().unwrap();
        let algo =
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 5.0, 5).with_lrs(0.02, 1.0);
        let cfg = ServerConfig { rounds: 600, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        assert!(gap < gap0 * 0.1, "gap {gap0} -> {gap}");
    }

    #[test]
    fn ef_signsgd_converges_full_participation() {
        let mut b = consensus_backend(8, 16);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::ef_signsgd().with_lrs(0.1, 1.0);
        let cfg = ServerConfig { rounds: 800, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        // EF oscillates at its scaled-sign floor (~2-3% of the initial gap
        // on this instance); assert order-of-magnitude contraction.
        assert!(gap < gap0 * 0.05, "gap {gap0} -> {gap}");
    }

    #[test]
    #[should_panic(expected = "partial participation")]
    fn ef_rejects_partial_participation() {
        let mut b = consensus_backend(8, 4);
        let algo = AlgorithmConfig::ef_signsgd();
        let cfg =
            ServerConfig { rounds: 1, clients_per_round: Some(4), ..Default::default() };
        run_experiment(&mut b, &algo, &cfg);
    }

    #[test]
    fn qsgd_converges() {
        let mut b = consensus_backend(6, 12);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::qsgd(4).with_lrs(0.1, 1.0);
        let cfg = ServerConfig { rounds: 300, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        assert!(run.final_objective() - f_star < 1e-2);
    }

    #[test]
    fn partial_participation_still_converges() {
        let mut b = consensus_backend(20, 10);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0);
        let cfg = ServerConfig {
            rounds: 400,
            clients_per_round: Some(5),
            ..Default::default()
        };
        let run = run_experiment(&mut b, &algo, &cfg);
        assert!(run.final_objective() - f_star < 0.05);
    }

    #[test]
    fn bits_accounting_exact() {
        let d = 33;
        let n = 4;
        let mut b = consensus_backend(n, d);
        let rounds = 3;
        let cfg = ServerConfig { rounds, ..Default::default() };
        // Sign: d bits per client per round.
        let run =
            run_experiment(&mut b, &AlgorithmConfig::signsgd().with_lrs(0.01, 1.0), &cfg);
        assert_eq!(run.total_bits(), (rounds * n * d) as u64);
        // Dense: 32·d bits.
        let mut b2 = consensus_backend(n, d);
        let run2 = run_experiment(&mut b2, &AlgorithmConfig::gd().with_lrs(0.01, 1.0), &cfg);
        assert_eq!(run2.total_bits(), (rounds * n * 32 * d) as u64);
    }

    #[test]
    fn runs_are_deterministic_in_seed() {
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.5).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 50, seed: 7, ..Default::default() };
        let mut b1 = consensus_backend(5, 8);
        let mut b2 = consensus_backend(5, 8);
        let r1 = run_experiment(&mut b1, &algo, &cfg);
        let r2 = run_experiment(&mut b2, &algo, &cfg);
        for (a, b) in r1.records.iter().zip(&r2.records) {
            assert_eq!(a.objective, b.objective);
        }
        // Different seed diverges.
        let cfg2 = ServerConfig { seed: 8, ..cfg };
        let mut b3 = consensus_backend(5, 8);
        let r3 = run_experiment(&mut b3, &algo, &cfg2);
        assert!(r1.records.last().unwrap().objective != r3.records.last().unwrap().objective);
    }

    #[test]
    fn plateau_sigma_grows_during_run() {
        let mut b = AnalyticBackend::new(Consensus::counterexample(2.0));
        b.x0 = vec![1.0];
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.0).with_lrs(0.01, 1.0);
        let plateau = PlateauConfig { sigma_init: 0.01, sigma_bound: 8.0, kappa: 5, beta: 2.0 };
        let cfg = ServerConfig { rounds: 300, plateau: Some(plateau), ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let first_sigma = run.records.first().unwrap().sigma;
        let last_sigma = run.records.last().unwrap().sigma;
        assert!(last_sigma > first_sigma, "{first_sigma} -> {last_sigma}");
        // And the grown sigma lets it escape the stall.
        let first = run.records.first().unwrap().objective;
        let last = run.records.last().unwrap().objective;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn topk_and_sparse_sign_converge() {
        // The conclusion's combination must still optimize and must cost
        // fewer bits than dense signs at small k.
        let d = 64;
        let mut b = consensus_backend(6, d);
        let f_star = b.problem.optimal_value().unwrap();
        // Top-k without error feedback only touches k coords per round, so
        // give it proportionally more rounds.
        let rounds = 2500;
        let cfg = ServerConfig { rounds, ..Default::default() };
        for algo in [
            AlgorithmConfig::topk(0.25, 1).with_lrs(0.05, 1.0),
            AlgorithmConfig::sparse_sign(0.25, ZParam::Finite(1), 1.0, 1).with_lrs(0.05, 1.0),
        ] {
            let run = run_experiment(&mut b, &algo, &cfg);
            let gap0 = run.records.first().unwrap().objective - f_star;
            let gap = run.final_objective() - f_star;
            // Top-k without error feedback is biased (the masked-gradient
            // fixed point is not the optimum), so a residual floor at a
            // fraction of the initial gap is the *expected* behaviour — we
            // assert clear improvement, not convergence to f*.
            assert!(gap < gap0 * 0.6, "{}: gap {gap0} -> {gap}", algo.name);
            // Bits: k(32+32) or k·33+32 per client per round, both < 32d.
            assert!(run.total_bits() < (rounds * 6 * 32 * d) as u64);
        }
    }

    #[test]
    fn downlink_compression_tracks_bits_and_converges() {
        let d = 50;
        let mut b = consensus_backend(8, d);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 3.0).with_lrs(0.02, 1.0);
        let rounds = 1200;
        // The downlink payload is the *mean vote* vector (entries in [-1,1]),
        // so its noise scale must match that magnitude, not the gradient's.
        let cfg = ServerConfig {
            rounds,
            downlink_sign: Some((ZParam::Finite(1), 0.5)),
            ..Default::default()
        };
        let run = run_experiment(&mut b, &algo, &cfg);
        // Downlink is d bits per client per round under compression.
        assert_eq!(run.records.last().unwrap().bits_down, (rounds * 8 * d) as u64);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        assert!(gap < gap0 * 0.5, "gap {gap0} -> {gap}");
        // Uncompressed downlink accounts 32d.
        let mut b2 = consensus_backend(8, d);
        let cfg2 = ServerConfig { rounds: 3, ..Default::default() };
        let run2 = run_experiment(&mut b2, &algo, &cfg2);
        assert_eq!(run2.records.last().unwrap().bits_down, (3 * 8 * 32 * d) as u64);
    }

    #[test]
    fn server_adam_converges() {
        let mut b = consensus_backend(8, 40);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 3.0, 1)
            .with_lrs(0.02, 0.3)
            .with_server_adam();
        let cfg = ServerConfig { rounds: 800, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        assert!(gap < gap0 * 0.5, "gap {gap0} -> {gap}");
        assert!(run.final_objective().is_finite());
    }

    #[test]
    fn sgdwm_momentum_accelerates_consensus() {
        let cfg = ServerConfig { rounds: 60, ..Default::default() };
        let mut b1 = consensus_backend(10, 20);
        let f_star = b1.problem.optimal_value().unwrap();
        let plain = run_experiment(&mut b1, &AlgorithmConfig::gd().with_lrs(0.05, 1.0), &cfg);
        let mut b2 = consensus_backend(10, 20);
        let wm = run_experiment(
            &mut b2,
            &AlgorithmConfig::sgdwm(0.9).with_lrs(0.05, 1.0),
            &cfg,
        );
        assert!(
            wm.final_objective() - f_star < plain.final_objective() - f_star,
            "momentum should accelerate the quadratic"
        );
    }
}
