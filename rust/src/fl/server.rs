//! Algorithm 1's coordinator entry point: experiment configuration plus
//! [`run_experiment`].
//!
//! Responsibilities per communication round t (executed by
//! [`super::engine::RoundEngine`] — this module is the stable public API):
//!
//!   1. sample the participant set (full or uniform partial participation);
//!   2. orchestrate each participant's E local SGD steps via the backend,
//!      fanning clients across worker threads when the backend allows it;
//!   3. apply the configured uplink compressor to each client's update
//!      direction `(x_{t-1} − x^i_{t-1,E})/γ` and account the exact bits;
//!   4. aggregate through the unified `compress::agg::Aggregator` seam:
//!      every family streams client messages into lane-sharded state
//!      (packed-sign votes merged exactly; dense payloads folded under the
//!      fixed `reduce_lanes` topology — nothing buffered per client);
//!   5. server step `x_t = x_{t-1} − η·γ·agg` (Alg. 1 line 15), with
//!      optional server momentum (the paper's "wM" baselines) and the DP
//!      variant's γ-free step (Alg. 2 line 15);
//!   6. feed the plateau controller and periodically evaluate.
//!
//! Determinism: every (round, client) pair gets its own PCG stream derived
//! from the experiment seed, and the engine reduces client messages in a
//! thread-count-independent order, so runs are bit-reproducible regardless
//! of participant order *and* of [`ServerConfig::parallelism`].

use super::algorithms::AlgorithmConfig;
use super::backend::TrainBackend;
use super::engine::RoundEngine;
use super::metrics::{RoundRecord, RunResult};
use super::plateau::PlateauConfig;
use crate::rng::ZParam;
use crate::sim::ScenarioConfig;
use crate::telemetry::Telemetry;

/// How each round's participants are chosen (see
/// `fl::engine::ParticipationPolicy`).
#[derive(Debug, Clone, Default, PartialEq)]
pub enum Participation {
    /// The historical sampler: `clients_per_round` uniformly without
    /// replacement (everyone when unset), every report arrives.
    #[default]
    Uniform,
    /// Client-lifecycle simulation (`sim::ScenarioPolicy`): heterogeneous
    /// devices, report deadlines, dropouts and byzantine clients. The
    /// cohort size comes from `ScenarioConfig::target_cohort`;
    /// `clients_per_round` is ignored.
    Simulated(ScenarioConfig),
}

/// Server-side experiment configuration (everything that is not the
/// algorithm itself).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Communication rounds T.
    pub rounds: usize,
    /// Clients sampled per round (None = full participation). Only
    /// consulted by `Participation::Uniform`.
    pub clients_per_round: Option<usize>,
    /// Evaluate every k rounds (records are emitted only on eval rounds).
    pub eval_every: usize,
    /// Experiment seed (repeats vary this).
    pub seed: u64,
    /// Optional §4.4 plateau controller for the noise scale.
    pub plateau: Option<PlateauConfig>,
    /// Optional downlink compression: broadcast the *server update* as a
    /// stochastic sign with scale σ_d (the [27]/[12] bidirectional setting).
    /// The server applies the compressed update itself, so server and
    /// clients stay consistent; downlink costs d bits per client per round.
    pub downlink_sign: Option<(ZParam, f32)>,
    /// Worker threads for per-client work (local update + compression).
    ///
    /// Determinism contract: for any backend exposing a parallel view
    /// (`TrainBackend::as_parallel` — all analytic backends), the
    /// `RunResult` is bit-identical for every value of this knob. Stateful
    /// backends (the PJRT runtime) serialize and ignore it. 0 means 1.
    pub parallelism: usize,
    /// Lanes L of the fixed reduction topology (see `compress::agg`):
    /// participant slot `s` folds into lane `s mod L`, in increasing slot
    /// order within a lane, and lanes fold in lane order. Like the seed,
    /// this is part of the reproducibility contract — changing it changes
    /// dense-family trajectories (a different, equally valid fold tree),
    /// and with the plateau controller on it can also shift sign-family
    /// runs at m > L (the f64 loss fold feeding the controller is
    /// lane-grouped) — but for any fixed value the result is bit-identical
    /// across `parallelism`. Effective worker threads are capped at L.
    /// Peak dense aggregation memory is O(min(L, m)·d). With m ≤ L the
    /// fold equals the historical slot-ordered reduce bit for bit.
    /// 0 means 1.
    pub reduce_lanes: usize,
    /// Participant selection: the uniform shuffle, or the `sim/` scenario
    /// engine. Bit-identical across `parallelism` either way.
    pub participation: Participation,
}

/// Default lane count: wide enough that every default-scale experiment
/// (m ≤ 64) keeps its historical slot-ordered fold bit for bit, and that
/// up to 64 workers stay busy on the dense path. (`--paper-scale` EMNIST
/// samples m = 100 > L and therefore adopts the lane fold tree.)
pub const DEFAULT_REDUCE_LANES: usize = 64;

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            rounds: 100,
            clients_per_round: None,
            eval_every: 1,
            seed: 0,
            plateau: None,
            downlink_sign: None,
            parallelism: 1,
            reduce_lanes: DEFAULT_REDUCE_LANES,
            participation: Participation::Uniform,
        }
    }
}

/// Run one experiment; returns the evaluated round records.
pub fn run_experiment(
    backend: &mut dyn TrainBackend,
    algo: &AlgorithmConfig,
    cfg: &ServerConfig,
) -> RunResult {
    run_experiment_observed(backend, algo, cfg, &mut |_| {})
}

/// Like [`run_experiment`], streaming each evaluated round record to
/// `on_record` while the run executes (the `api::Session` observer seam).
pub fn run_experiment_observed(
    backend: &mut dyn TrainBackend,
    algo: &AlgorithmConfig,
    cfg: &ServerConfig,
    on_record: &mut dyn FnMut(&RoundRecord),
) -> RunResult {
    run_experiment_instrumented(backend, algo, cfg, &Telemetry::disabled(), on_record)
}

/// Like [`run_experiment_observed`], with an attached telemetry recorder
/// (phase spans, round/bit counters, eval gauges — see [`crate::telemetry`]).
/// Telemetry is read-only with respect to the run: for any handle the
/// `RunResult` is bit-identical to [`run_experiment_observed`]'s.
pub fn run_experiment_instrumented(
    backend: &mut dyn TrainBackend,
    algo: &AlgorithmConfig,
    cfg: &ServerConfig,
    tele: &Telemetry,
    on_record: &mut dyn FnMut(&RoundRecord),
) -> RunResult {
    run_experiment_resumable(backend, algo, cfg, tele, on_record, None, None)
}

/// The full experiment entry point: like [`run_experiment_instrumented`]
/// plus the checkpoint/resume seam. `resume` restarts the run from a
/// captured round boundary (replayed records do **not** re-fire
/// `on_record`); `hook` is offered a capture at every round boundary it
/// asks for. A resumed run is bit-identical to the uninterrupted one —
/// per-round RNG streams are pure splits of the root (DESIGN.md §2.6), so
/// nothing beyond the engine capture is needed.
pub fn run_experiment_resumable(
    backend: &mut dyn TrainBackend,
    algo: &AlgorithmConfig,
    cfg: &ServerConfig,
    tele: &Telemetry,
    on_record: &mut dyn FnMut(&RoundRecord),
    resume: Option<&super::engine::EngineCkpt>,
    hook: Option<&mut dyn super::engine::CkptHook>,
) -> RunResult {
    let d = backend.dim();
    let n = backend.num_clients();
    let mut engine = RoundEngine::new(algo, cfg, d, n);
    engine.set_telemetry(tele.clone());
    engine.run_resumable(backend, on_record, resume, hook)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::backend::AnalyticBackend;
    use crate::problems::consensus::Consensus;
    use crate::problems::AnalyticProblem;
    use crate::rng::ZParam;

    fn consensus_backend(n: usize, d: usize) -> AnalyticBackend<Consensus> {
        AnalyticBackend::new(Consensus::gaussian(n, d, 99))
    }

    #[test]
    fn gd_converges_on_consensus() {
        let mut b = consensus_backend(10, 20);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::gd().with_lrs(0.1, 1.0);
        let cfg = ServerConfig { rounds: 200, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        assert!(run.final_objective() - f_star < 1e-6, "gap={}", run.final_objective() - f_star);
    }

    #[test]
    fn signsgd_stalls_on_counterexample() {
        // The §1 counterexample: vanilla SignSGD never moves from x0 in (−A, A).
        let mut b = AnalyticBackend::new(Consensus::counterexample(4.0));
        b.x0 = vec![2.0];
        let algo = AlgorithmConfig::signsgd().with_lrs(0.01, 1.0);
        let cfg = ServerConfig { rounds: 100, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let first = run.records.first().unwrap().objective;
        let last = run.records.last().unwrap().objective;
        assert!((first - last).abs() < 1e-9, "SignSGD moved: {first} -> {last}");
    }

    #[test]
    fn stochastic_sign_escapes_counterexample() {
        // 1-SignSGD (Gaussian noise) does make progress on the same instance.
        // f* = 16 for A = 4 (the objective is x^2 + 16).
        let mut b = AnalyticBackend::new(Consensus::counterexample(4.0));
        let f_star = b.problem.optimal_value().unwrap();
        b.x0 = vec![2.0];
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 5.0).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 400, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.records.last().unwrap().objective - f_star;
        assert!(gap < gap0 * 0.3, "gap {gap0} -> {gap}");
    }

    #[test]
    fn inf_sign_threshold_behaviour() {
        // Theorem 2 / Remark 2: with sigma below the gradient range, inf-sign
        // cannot converge; with sigma above it, it does.
        let a = 4.0f32;
        for (sigma, should_move) in [(1.0f32, false), (20.0, true)] {
            let mut b = AnalyticBackend::new(Consensus::counterexample(a));
            let f_star = b.problem.optimal_value().unwrap();
            b.x0 = vec![2.0];
            let algo = AlgorithmConfig::z_signsgd(ZParam::Inf, sigma).with_lrs(0.05, 1.0);
            let cfg = ServerConfig { rounds: 800, ..Default::default() };
            let run = run_experiment(&mut b, &algo, &cfg);
            let first = run.records.first().unwrap().objective;
            let last = run.records.last().unwrap().objective;
            if should_move {
                let (gap0, gap) = (first - f_star, last - f_star);
                assert!(gap < gap0 * 0.5, "sigma={sigma}: gap {gap0} -> {gap}");
            } else {
                // Gradients at x0=2: f1' = 2(x−4) = −4, f2' = 2(x+4) = 12.
                // With sigma=1 < 4 the signs are deterministic and cancel.
                assert!((first - last).abs() < 1e-9, "sigma={sigma} moved");
            }
        }
    }

    #[test]
    fn z_signfedavg_with_local_steps_converges() {
        // E = 5 local steps: the compressed quantity is a sum of 5 gradients,
        // so sigma must scale with E (Theorem 1's threshold grows with E).
        let mut b = consensus_backend(10, 30);
        let f_star = b.problem.optimal_value().unwrap();
        let algo =
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 5.0, 5).with_lrs(0.02, 1.0);
        let cfg = ServerConfig { rounds: 600, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        assert!(gap < gap0 * 0.1, "gap {gap0} -> {gap}");
    }

    #[test]
    fn ef_signsgd_converges_full_participation() {
        let mut b = consensus_backend(8, 16);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::ef_signsgd().with_lrs(0.1, 1.0);
        let cfg = ServerConfig { rounds: 800, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        // EF oscillates at its scaled-sign floor (~2-3% of the initial gap
        // on this instance); assert order-of-magnitude contraction.
        assert!(gap < gap0 * 0.05, "gap {gap0} -> {gap}");
    }

    #[test]
    #[should_panic(expected = "partial participation")]
    fn ef_rejects_partial_participation() {
        let mut b = consensus_backend(8, 4);
        let algo = AlgorithmConfig::ef_signsgd();
        let cfg =
            ServerConfig { rounds: 1, clients_per_round: Some(4), ..Default::default() };
        run_experiment(&mut b, &algo, &cfg);
    }

    #[test]
    fn qsgd_converges() {
        let mut b = consensus_backend(6, 12);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::qsgd(4).with_lrs(0.1, 1.0);
        let cfg = ServerConfig { rounds: 300, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        assert!(run.final_objective() - f_star < 1e-2);
    }

    #[test]
    fn partial_participation_still_converges() {
        let mut b = consensus_backend(20, 10);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0);
        let cfg = ServerConfig {
            rounds: 400,
            clients_per_round: Some(5),
            ..Default::default()
        };
        let run = run_experiment(&mut b, &algo, &cfg);
        assert!(run.final_objective() - f_star < 0.05);
    }

    #[test]
    fn bits_accounting_exact() {
        let d = 33;
        let n = 4;
        let mut b = consensus_backend(n, d);
        let rounds = 3;
        let cfg = ServerConfig { rounds, ..Default::default() };
        // Sign: d bits per client per round.
        let run =
            run_experiment(&mut b, &AlgorithmConfig::signsgd().with_lrs(0.01, 1.0), &cfg);
        assert_eq!(run.total_bits(), (rounds * n * d) as u64);
        // Dense: 32·d bits.
        let mut b2 = consensus_backend(n, d);
        let run2 = run_experiment(&mut b2, &AlgorithmConfig::gd().with_lrs(0.01, 1.0), &cfg);
        assert_eq!(run2.total_bits(), (rounds * n * 32 * d) as u64);
    }

    #[test]
    fn runs_are_deterministic_in_seed() {
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.5).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 50, seed: 7, ..Default::default() };
        let mut b1 = consensus_backend(5, 8);
        let mut b2 = consensus_backend(5, 8);
        let r1 = run_experiment(&mut b1, &algo, &cfg);
        let r2 = run_experiment(&mut b2, &algo, &cfg);
        for (a, b) in r1.records.iter().zip(&r2.records) {
            assert_eq!(a.objective, b.objective);
        }
        // Different seed diverges.
        let cfg2 = ServerConfig { seed: 8, ..cfg };
        let mut b3 = consensus_backend(5, 8);
        let r3 = run_experiment(&mut b3, &algo, &cfg2);
        assert!(r1.records.last().unwrap().objective != r3.records.last().unwrap().objective);
    }

    #[test]
    fn plateau_sigma_grows_during_run() {
        let mut b = AnalyticBackend::new(Consensus::counterexample(2.0));
        b.x0 = vec![1.0];
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.0).with_lrs(0.01, 1.0);
        let plateau = PlateauConfig { sigma_init: 0.01, sigma_bound: 8.0, kappa: 5, beta: 2.0 };
        let cfg = ServerConfig { rounds: 300, plateau: Some(plateau), ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let first_sigma = run.records.first().unwrap().sigma;
        let last_sigma = run.records.last().unwrap().sigma;
        assert!(last_sigma > first_sigma, "{first_sigma} -> {last_sigma}");
        // And the grown sigma lets it escape the stall.
        let first = run.records.first().unwrap().objective;
        let last = run.records.last().unwrap().objective;
        assert!(last < first, "{first} -> {last}");
    }

    #[test]
    fn topk_and_sparse_sign_converge() {
        // The conclusion's combination must still optimize and must cost
        // fewer bits than dense signs at small k.
        let d = 64;
        let mut b = consensus_backend(6, d);
        let f_star = b.problem.optimal_value().unwrap();
        // Top-k without error feedback only touches k coords per round, so
        // give it proportionally more rounds.
        let rounds = 2500;
        let cfg = ServerConfig { rounds, ..Default::default() };
        for algo in [
            AlgorithmConfig::topk(0.25, 1).with_lrs(0.05, 1.0),
            AlgorithmConfig::sparse_sign(0.25, ZParam::Finite(1), 1.0, 1).with_lrs(0.05, 1.0),
        ] {
            let run = run_experiment(&mut b, &algo, &cfg);
            let gap0 = run.records.first().unwrap().objective - f_star;
            let gap = run.final_objective() - f_star;
            // Top-k without error feedback is biased (the masked-gradient
            // fixed point is not the optimum), so a residual floor at a
            // fraction of the initial gap is the *expected* behaviour — we
            // assert clear improvement, not convergence to f*.
            assert!(gap < gap0 * 0.6, "{}: gap {gap0} -> {gap}", algo.name);
            // Bits: k(32+32) or k·33+32 per client per round, both < 32d.
            assert!(run.total_bits() < (rounds * 6 * 32 * d) as u64);
        }
    }

    #[test]
    fn downlink_compression_tracks_bits_and_converges() {
        let d = 50;
        let mut b = consensus_backend(8, d);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 3.0).with_lrs(0.02, 1.0);
        let rounds = 1200;
        // The downlink payload is the *mean vote* vector (entries in [-1,1]),
        // so its noise scale must match that magnitude, not the gradient's.
        let cfg = ServerConfig {
            rounds,
            downlink_sign: Some((ZParam::Finite(1), 0.5)),
            ..Default::default()
        };
        let run = run_experiment(&mut b, &algo, &cfg);
        // Downlink is d bits per client per round under compression.
        assert_eq!(run.records.last().unwrap().bits_down, (rounds * 8 * d) as u64);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        assert!(gap < gap0 * 0.5, "gap {gap0} -> {gap}");
        // Uncompressed downlink accounts 32d.
        let mut b2 = consensus_backend(8, d);
        let cfg2 = ServerConfig { rounds: 3, ..Default::default() };
        let run2 = run_experiment(&mut b2, &algo, &cfg2);
        assert_eq!(run2.records.last().unwrap().bits_down, (3 * 8 * 32 * d) as u64);
    }

    #[test]
    fn server_adam_converges() {
        let mut b = consensus_backend(8, 40);
        let f_star = b.problem.optimal_value().unwrap();
        let algo = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 3.0, 1)
            .with_lrs(0.02, 0.3)
            .with_server_adam();
        let cfg = ServerConfig { rounds: 800, ..Default::default() };
        let run = run_experiment(&mut b, &algo, &cfg);
        let gap0 = run.records.first().unwrap().objective - f_star;
        let gap = run.final_objective() - f_star;
        assert!(gap < gap0 * 0.5, "gap {gap0} -> {gap}");
        assert!(run.final_objective().is_finite());
    }

    #[test]
    fn resumable_entry_point_matches_uninterrupted_run() {
        use crate::fl::engine::{CkptHook, EngineCkpt};

        struct At(u64, Option<EngineCkpt>);
        impl CkptHook for At {
            fn want(&mut self, next_round: u64) -> bool {
                next_round == self.0
            }
            fn store(&mut self, ck: EngineCkpt) {
                self.1 = Some(ck);
            }
        }

        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 12, seed: 11, ..Default::default() };
        let mut b = consensus_backend(6, 10);
        let whole = run_experiment(&mut b, &algo, &cfg);

        let mut b2 = consensus_backend(6, 10);
        let mut hook = At(5, None);
        let tele = Telemetry::disabled();
        run_experiment_resumable(&mut b2, &algo, &cfg, &tele, &mut |_| {}, None, Some(&mut hook));
        let ck = hook.1.expect("capture at round 5");

        let mut b3 = consensus_backend(6, 10);
        let resumed =
            run_experiment_resumable(&mut b3, &algo, &cfg, &tele, &mut |_| {}, Some(&ck), None);
        assert_eq!(whole.records.len(), resumed.records.len());
        for (a, b) in whole.records.iter().zip(&resumed.records) {
            // Everything but wall_ms (real wall-clock under the default
            // monotonic clock) must be bit-identical.
            let (mut a, mut b) = (*a, *b);
            a.wall_ms = 0.0;
            b.wall_ms = 0.0;
            assert_eq!(a, b);
        }
    }

    #[test]
    fn sgdwm_momentum_accelerates_consensus() {
        let cfg = ServerConfig { rounds: 60, ..Default::default() };
        let mut b1 = consensus_backend(10, 20);
        let f_star = b1.problem.optimal_value().unwrap();
        let plain = run_experiment(&mut b1, &AlgorithmConfig::gd().with_lrs(0.05, 1.0), &cfg);
        let mut b2 = consensus_backend(10, 20);
        let wm = run_experiment(
            &mut b2,
            &AlgorithmConfig::sgdwm(0.9).with_lrs(0.05, 1.0),
            &cfg,
        );
        assert!(
            wm.final_objective() - f_star < plain.final_objective() - f_star,
            "momentum should accelerate the quadratic"
        );
    }
}
