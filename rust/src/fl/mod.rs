//! The federated-learning coordinator (the paper's L3 contribution).
//!
//! * [`algorithms`] — named presets for every algorithm in the paper's
//!   experiment section (Table 2 + §4): FedAvg, GD/SGDwM, SignSGD,
//!   1-/∞-SignSGD, z-SignFedAvg, Sto-SignSGD(wM), EF-SignSGDwM, QSGD,
//!   FedPAQ, and the DP variants.
//! * [`backend`] — the `TrainBackend` abstraction: analytic problems
//!   (Fig. 1/2) vs. AOT-compiled neural workloads over PJRT (Fig. 3–17),
//!   plus the `ParallelBackend` view for Sync-safe per-client work.
//! * [`server`] — the experiment configuration and `run_experiment` entry
//!   point (client sampling cadence, plateau, downlink, parallelism knob,
//!   participation mode).
//! * [`engine`] — the round loop proper: per-client tasks fanned across a
//!   scoped thread pool, every compressor family streamed through the
//!   unified `compress::agg::Aggregator` seam under a fixed lane-sharded
//!   reduction topology (bit-identical results for every thread count, no
//!   per-client buffering), and the `ParticipationPolicy` seam the `sim/`
//!   scenario engine plugs into.
//! * [`plateau`] — §4.4's Plateau criterion for the adaptive noise scale.
//! * [`metrics`] — round records, repeat aggregation (mean ± std), CSV.

pub mod algorithms;
pub mod backend;
pub mod engine;
pub mod metrics;
pub mod plateau;
pub mod server;

pub use algorithms::{AlgorithmConfig, Compression};
pub use backend::{EvalResult, LocalOutcome, LocalScratch, ParallelBackend, TrainBackend};
pub use engine::{ClientOutcome, ClientTask, ParticipationPolicy, RoundEngine, RoundPlan};
pub use metrics::{RoundRecord, RunResult};
pub use server::{run_experiment, Participation, ServerConfig};
