//! The `TrainBackend` abstraction: what a "client doing E local SGD steps"
//! means for a given workload.
//!
//! * [`AnalyticBackend`] — closed-form problems (Fig. 1/2, integration
//!   tests): exact or minibatch gradients from `problems::AnalyticProblem`.
//! * `runtime::XlaBackend` — neural workloads over AOT-compiled PJRT
//!   artifacts (Fig. 3–17); lives in `runtime/` because it owns the PJRT
//!   engine, but implements this same trait.

use crate::problems::AnalyticProblem;
use crate::rng::{Pcg64, ZParam};
use crate::tensor;

/// Result of one client's local work for a round.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    /// The accumulated update direction `(x_start − x_E)/γ = Σ_s g_s`
    /// (Algorithm 1 line 11 compresses exactly this).
    pub delta: Vec<f32>,
    /// Mean local training loss over the E steps (diagnostics only).
    pub mean_loss: f64,
}

/// Periodic evaluation of the global model.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    /// Global objective (train loss for neural workloads, f(x) for analytic).
    pub objective: f64,
    /// Test accuracy, when the workload has one.
    pub accuracy: Option<f64>,
    /// ‖∇f(x)‖² (the paper's convergence metric), when computable exactly.
    pub grad_norm_sq: Option<f64>,
}

/// A training workload as seen by the FL server.
pub trait TrainBackend {
    /// Flat parameter dimension d.
    fn dim(&self) -> usize;

    /// Total number of clients n.
    fn num_clients(&self) -> usize;

    /// The initial global iterate x_0.
    fn init_params(&mut self) -> Vec<f32>;

    /// Run E local SGD steps for `client` starting at `params`, stepsize γ.
    fn local_update(
        &mut self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
    ) -> LocalOutcome;

    /// Evaluate the global model.
    fn evaluate(&mut self, params: &[f32]) -> EvalResult;

    /// Optional accelerated compression path (the XLA backend routes this
    /// through the AOT-compiled Pallas kernel, preferring the bit-packed
    /// artifact variant; analytic backends return `None` and the server
    /// falls back to the Rust reference compressor).
    ///
    /// Contract: the hook is honored only on the engine's sequential path.
    /// A backend that returns `Some` from [`TrainBackend::as_parallel`]
    /// must NOT also override this hook — on the parallel path the engine
    /// always uses the Rust reference compressor, so an overridden hook
    /// would be silently ignored (and its different RNG consumption would
    /// change seeded results between the two paths).
    fn compress_hook(
        &mut self,
        _delta: &[f32],
        _z: ZParam,
        _sigma: f32,
        _rng: &mut Pcg64,
    ) -> Option<crate::compress::pack::PackedSigns> {
        None
    }

    /// Sync-safe view for concurrent per-client work, when the backend
    /// supports it.
    ///
    /// Backends whose per-client update is a pure function of `(client,
    /// params, rng)` — the analytic problems — return `Some`, and
    /// `fl::engine::RoundEngine` fans client tasks across worker threads.
    /// Stateful backends (the PJRT runtime with its executable cache and
    /// scratch buffers) keep the default `None` and run sequentially; either
    /// way the round results are bit-identical for every `parallelism`
    /// setting (see `ServerConfig::parallelism`).
    fn as_parallel(&self) -> Option<&dyn ParallelBackend> {
        None
    }
}

/// Shared-state per-client entry point used by the parallel round engine.
///
/// Implementors must be safe to call from many threads at once: `rng` is the
/// caller-owned per-(round, client) stream, so a correct implementation
/// draws randomness only from it and mutates nothing shared.
pub trait ParallelBackend: Sync {
    /// Exactly [`TrainBackend::local_update`], through a shared reference.
    fn local_update_shared(
        &self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
    ) -> LocalOutcome;
}

/// Backend over an analytic problem. `stochastic` switches the gradient
/// oracle from full gradients (Fig. 1/2's setting) to single-sample
/// minibatches.
pub struct AnalyticBackend<P: AnalyticProblem> {
    pub problem: P,
    pub stochastic: bool,
    /// Initial iterate (the paper's §4.1 uses the zero vector).
    pub x0: Vec<f32>,
}

impl<P: AnalyticProblem> AnalyticBackend<P> {
    pub fn new(problem: P) -> Self {
        let d = problem.dim();
        AnalyticBackend { problem, stochastic: false, x0: vec![0.0; d] }
    }

    pub fn stochastic(mut self) -> Self {
        self.stochastic = true;
        self
    }

    /// The E-step local SGD body. Pure given `rng` (the problem is immutable
    /// data), which is what makes the parallel view below sound.
    fn local_update_impl(
        &self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
    ) -> LocalOutcome {
        let d = params.len();
        let mut x = params.to_vec();
        let mut g = vec![0.0f32; d];
        for _ in 0..local_steps {
            self.problem.grad_into(
                client,
                &x,
                &mut g,
                if self.stochastic { Some(rng) } else { None },
            );
            tensor::axpy(-gamma, &g, &mut x);
        }
        // delta = (params - x_E) / gamma = sum of the local gradients.
        let mut delta = vec![0.0f32; d];
        for ((dl, &p), &xe) in delta.iter_mut().zip(params).zip(&x) {
            *dl = (p - xe) / gamma;
        }
        LocalOutcome { delta, mean_loss: self.problem.objective(&x) }
    }
}

impl<P: AnalyticProblem> ParallelBackend for AnalyticBackend<P> {
    fn local_update_shared(
        &self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
    ) -> LocalOutcome {
        self.local_update_impl(client, params, local_steps, gamma, rng)
    }
}

impl<P: AnalyticProblem> TrainBackend for AnalyticBackend<P> {
    fn dim(&self) -> usize {
        self.problem.dim()
    }

    fn num_clients(&self) -> usize {
        self.problem.num_clients()
    }

    fn init_params(&mut self) -> Vec<f32> {
        self.x0.clone()
    }

    fn local_update(
        &mut self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
    ) -> LocalOutcome {
        self.local_update_impl(client, params, local_steps, gamma, rng)
    }

    fn as_parallel(&self) -> Option<&dyn ParallelBackend> {
        Some(self)
    }

    fn evaluate(&mut self, params: &[f32]) -> EvalResult {
        EvalResult {
            objective: self.problem.objective(params),
            accuracy: None,
            grad_norm_sq: Some(self.problem.grad_norm_sq(params)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::consensus::Consensus;

    #[test]
    fn delta_is_sum_of_gradients_single_step() {
        let p = Consensus::gaussian(3, 4, 1);
        let mut b = AnalyticBackend::new(p);
        let x = vec![0.5f32; 4];
        let mut rng = Pcg64::seeded(0);
        let out = b.local_update(1, &x, 1, 0.1, &mut rng);
        let mut g = vec![0.0f32; 4];
        b.problem.grad_into(1, &x, &mut g, None);
        for (a, w) in out.delta.iter().zip(&g) {
            assert!((a - w).abs() < 1e-5);
        }
    }

    #[test]
    fn multiple_steps_accumulate() {
        let p = Consensus::gaussian(2, 3, 2);
        let mut b = AnalyticBackend::new(p);
        let x = vec![1.0f32; 3];
        let mut rng = Pcg64::seeded(0);
        let gamma = 0.05f32;
        let e = 4usize;
        let out = b.local_update(0, &x, e, gamma, &mut rng);
        // Replay manually.
        let mut xi = x.clone();
        let mut g = vec![0.0f32; 3];
        let mut acc = vec![0.0f32; 3];
        for _ in 0..e {
            b.problem.grad_into(0, &xi, &mut g, None);
            tensor::axpy(1.0, &g, &mut acc);
            tensor::axpy(-gamma, &g, &mut xi);
        }
        for (a, w) in out.delta.iter().zip(&acc) {
            assert!((a - w).abs() < 1e-3, "{a} vs {w}");
        }
    }

    #[test]
    fn evaluate_reports_grad_norm() {
        let p = Consensus::gaussian(3, 4, 1);
        let mut b = AnalyticBackend::new(p);
        let opt = {
            let p2 = Consensus::gaussian(3, 4, 1);
            p2.optimum()
        };
        let r = b.evaluate(&opt);
        assert!(r.grad_norm_sq.unwrap() < 1e-10);
        assert!(r.accuracy.is_none());
    }
}
