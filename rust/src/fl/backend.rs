//! The `TrainBackend` abstraction: what a "client doing E local SGD steps"
//! means for a given workload.
//!
//! * [`AnalyticBackend`] — closed-form problems (Fig. 1/2, integration
//!   tests): exact or minibatch gradients from `problems::AnalyticProblem`.
//! * `runtime::XlaBackend` — neural workloads over AOT-compiled PJRT
//!   artifacts (Fig. 3–17); lives in `runtime/` because it owns the PJRT
//!   engine, but implements this same trait.

use crate::problems::AnalyticProblem;
use crate::rng::{Pcg64, ZParam};
use crate::tensor;

/// Result of one client's local work for a round.
#[derive(Debug, Clone)]
pub struct LocalOutcome {
    /// The accumulated update direction `(x_start − x_E)/γ = Σ_s g_s`
    /// (Algorithm 1 line 11 compresses exactly this).
    pub delta: Vec<f32>,
    /// Mean local training loss over the E steps (diagnostics only).
    pub mean_loss: f64,
}

/// Reusable per-worker buffers for the `*_into` local-update entry points
/// (the iterate and gradient of the E-step loop). Owned by the round
/// engine's `RoundScratch` pool with round lifetime, so the steady-state
/// round loop performs no per-client heap allocation.
#[derive(Debug, Default)]
pub struct LocalScratch {
    x: Vec<f32>,
    g: Vec<f32>,
}

impl LocalScratch {
    pub fn new() -> LocalScratch {
        LocalScratch::default()
    }

    /// Both buffers sized to `d` (allocating only on growth).
    fn xg(&mut self, d: usize) -> (&mut [f32], &mut [f32]) {
        if self.x.len() != d {
            self.x.resize(d, 0.0);
        }
        if self.g.len() != d {
            self.g.resize(d, 0.0);
        }
        (&mut self.x[..], &mut self.g[..])
    }
}

/// Periodic evaluation of the global model.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    /// Global objective (train loss for neural workloads, f(x) for analytic).
    pub objective: f64,
    /// Test accuracy, when the workload has one.
    pub accuracy: Option<f64>,
    /// ‖∇f(x)‖² (the paper's convergence metric), when computable exactly.
    pub grad_norm_sq: Option<f64>,
}

/// A training workload as seen by the FL server.
pub trait TrainBackend {
    /// Flat parameter dimension d.
    fn dim(&self) -> usize;

    /// Total number of clients n.
    fn num_clients(&self) -> usize;

    /// The initial global iterate x_0.
    fn init_params(&mut self) -> Vec<f32>;

    /// Run E local SGD steps for `client` starting at `params`, stepsize γ.
    fn local_update(
        &mut self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
    ) -> LocalOutcome;

    /// [`TrainBackend::local_update`] into a caller-owned `delta` buffer,
    /// returning the mean local loss. The round engine's hot path: backends
    /// that override this (the analytic problems do) run the whole local
    /// update out of `scratch` with zero heap allocation. The default
    /// delegates to `local_update` — identical values, one transient
    /// allocation — so stateful backends (PJRT) need no change.
    #[allow(clippy::too_many_arguments)]
    fn local_update_into(
        &mut self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
        delta: &mut [f32],
        _scratch: &mut LocalScratch,
    ) -> f64 {
        let out = self.local_update(client, params, local_steps, gamma, rng);
        delta.copy_from_slice(&out.delta);
        out.mean_loss
    }

    /// Evaluate the global model.
    fn evaluate(&mut self, params: &[f32]) -> EvalResult;

    /// Optional accelerated compression path (the XLA backend routes this
    /// through the AOT-compiled Pallas kernel, preferring the bit-packed
    /// artifact variant; analytic backends return `None` and the server
    /// falls back to the Rust reference compressor).
    ///
    /// Contract: the hook is honored only on the engine's sequential path.
    /// A backend that returns `Some` from [`TrainBackend::as_parallel`]
    /// must NOT also override this hook — on the parallel path the engine
    /// always uses the Rust reference compressor, so an overridden hook
    /// would be silently ignored (and its different RNG consumption would
    /// change seeded results between the two paths).
    fn compress_hook(
        &mut self,
        _delta: &[f32],
        _z: ZParam,
        _sigma: f32,
        _rng: &mut Pcg64,
    ) -> Option<crate::compress::pack::PackedSigns> {
        None
    }

    /// Sync-safe view for concurrent per-client work, when the backend
    /// supports it.
    ///
    /// Backends whose per-client update is a pure function of `(client,
    /// params, rng)` — the analytic problems — return `Some`, and
    /// `fl::engine::RoundEngine` fans client tasks across worker threads.
    /// Stateful backends (the PJRT runtime with its executable cache and
    /// scratch buffers) keep the default `None` and run sequentially; either
    /// way the round results are bit-identical for every `parallelism`
    /// setting (see `ServerConfig::parallelism`).
    fn as_parallel(&self) -> Option<&dyn ParallelBackend> {
        None
    }
}

/// Shared-state per-client entry point used by the parallel round engine.
///
/// Implementors must be safe to call from many threads at once: `rng` is the
/// caller-owned per-(round, client) stream, so a correct implementation
/// draws randomness only from it and mutates nothing shared. `delta` and
/// `scratch` belong to the calling worker (its `RoundScratch`), making the
/// per-client fan-out allocation-free.
pub trait ParallelBackend: Sync {
    /// Exactly [`TrainBackend::local_update_into`], through a shared
    /// reference.
    #[allow(clippy::too_many_arguments)]
    fn local_update_shared_into(
        &self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
        delta: &mut [f32],
        scratch: &mut LocalScratch,
    ) -> f64;
}

/// Backend over an analytic problem. `stochastic` switches the gradient
/// oracle from full gradients (Fig. 1/2's setting) to single-sample
/// minibatches.
pub struct AnalyticBackend<P: AnalyticProblem> {
    pub problem: P,
    pub stochastic: bool,
    /// Initial iterate (the paper's §4.1 uses the zero vector).
    pub x0: Vec<f32>,
}

impl<P: AnalyticProblem> AnalyticBackend<P> {
    pub fn new(problem: P) -> Self {
        let d = problem.dim();
        AnalyticBackend { problem, stochastic: false, x0: vec![0.0; d] }
    }

    pub fn stochastic(mut self) -> Self {
        self.stochastic = true;
        self
    }

    /// The E-step local SGD body, writing `delta` into a caller-owned
    /// buffer and running the iterate/gradient loop out of `scratch` — zero
    /// heap allocation per client. Pure given `rng` (the problem is
    /// immutable data), which is what makes the parallel view below sound.
    #[allow(clippy::too_many_arguments)]
    fn local_update_into_impl(
        &self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
        delta: &mut [f32],
        scratch: &mut LocalScratch,
    ) -> f64 {
        let d = params.len();
        assert_eq!(delta.len(), d);
        let (x, g) = scratch.xg(d);
        x.copy_from_slice(params);
        for _ in 0..local_steps {
            self.problem.grad_into(
                client,
                x,
                g,
                if self.stochastic { Some(&mut *rng) } else { None },
            );
            tensor::axpy(-gamma, g, x);
        }
        // delta = (params - x_E) / gamma = sum of the local gradients.
        for ((dl, &p), &xe) in delta.iter_mut().zip(params).zip(x.iter()) {
            *dl = (p - xe) / gamma;
        }
        self.problem.objective(x)
    }
}

impl<P: AnalyticProblem> ParallelBackend for AnalyticBackend<P> {
    #[allow(clippy::too_many_arguments)]
    fn local_update_shared_into(
        &self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
        delta: &mut [f32],
        scratch: &mut LocalScratch,
    ) -> f64 {
        self.local_update_into_impl(client, params, local_steps, gamma, rng, delta, scratch)
    }
}

impl<P: AnalyticProblem> TrainBackend for AnalyticBackend<P> {
    fn dim(&self) -> usize {
        self.problem.dim()
    }

    fn num_clients(&self) -> usize {
        self.problem.num_clients()
    }

    fn init_params(&mut self) -> Vec<f32> {
        self.x0.clone()
    }

    fn local_update(
        &mut self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
    ) -> LocalOutcome {
        let mut delta = vec![0.0f32; params.len()];
        let mut scratch = LocalScratch::new();
        let mean_loss = self.local_update_into_impl(
            client,
            params,
            local_steps,
            gamma,
            rng,
            &mut delta,
            &mut scratch,
        );
        LocalOutcome { delta, mean_loss }
    }

    #[allow(clippy::too_many_arguments)]
    fn local_update_into(
        &mut self,
        client: usize,
        params: &[f32],
        local_steps: usize,
        gamma: f32,
        rng: &mut Pcg64,
        delta: &mut [f32],
        scratch: &mut LocalScratch,
    ) -> f64 {
        self.local_update_into_impl(client, params, local_steps, gamma, rng, delta, scratch)
    }

    fn as_parallel(&self) -> Option<&dyn ParallelBackend> {
        Some(self)
    }

    fn evaluate(&mut self, params: &[f32]) -> EvalResult {
        EvalResult {
            objective: self.problem.objective(params),
            accuracy: None,
            grad_norm_sq: Some(self.problem.grad_norm_sq(params)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problems::consensus::Consensus;

    #[test]
    fn delta_is_sum_of_gradients_single_step() {
        let p = Consensus::gaussian(3, 4, 1);
        let mut b = AnalyticBackend::new(p);
        let x = vec![0.5f32; 4];
        let mut rng = Pcg64::seeded(0);
        let out = b.local_update(1, &x, 1, 0.1, &mut rng);
        let mut g = vec![0.0f32; 4];
        b.problem.grad_into(1, &x, &mut g, None);
        for (a, w) in out.delta.iter().zip(&g) {
            assert!((a - w).abs() < 1e-5);
        }
    }

    #[test]
    fn multiple_steps_accumulate() {
        let p = Consensus::gaussian(2, 3, 2);
        let mut b = AnalyticBackend::new(p);
        let x = vec![1.0f32; 3];
        let mut rng = Pcg64::seeded(0);
        let gamma = 0.05f32;
        let e = 4usize;
        let out = b.local_update(0, &x, e, gamma, &mut rng);
        // Replay manually.
        let mut xi = x.clone();
        let mut g = vec![0.0f32; 3];
        let mut acc = vec![0.0f32; 3];
        for _ in 0..e {
            b.problem.grad_into(0, &xi, &mut g, None);
            tensor::axpy(1.0, &g, &mut acc);
            tensor::axpy(-gamma, &g, &mut xi);
        }
        for (a, w) in out.delta.iter().zip(&acc) {
            assert!((a - w).abs() < 1e-3, "{a} vs {w}");
        }
    }

    #[test]
    fn into_path_matches_allocating_path_bit_for_bit() {
        // The zero-alloc entry point must reproduce local_update exactly,
        // including stochastic-gradient RNG consumption and stale-scratch
        // reuse across clients.
        let p = Consensus::gaussian(4, 7, 9);
        let mut b = AnalyticBackend::new(p).stochastic();
        let x = vec![0.25f32; 7];
        let mut scratch = LocalScratch::new();
        let mut delta = vec![0.0f32; 7];
        for client in 0..4 {
            let mut ra = Pcg64::new(5, client as u64);
            let mut rb = ra.clone();
            let want = b.local_update(client, &x, 3, 0.1, &mut ra);
            let loss = b.local_update_into(client, &x, 3, 0.1, &mut rb, &mut delta, &mut scratch);
            assert_eq!(loss.to_bits(), want.mean_loss.to_bits(), "client={client}");
            for (a, w) in delta.iter().zip(&want.delta) {
                assert_eq!(a.to_bits(), w.to_bits(), "client={client}");
            }
            assert_eq!(ra.next_u64(), rb.next_u64());
        }
    }

    #[test]
    fn evaluate_reports_grad_norm() {
        let p = Consensus::gaussian(3, 4, 1);
        let mut b = AnalyticBackend::new(p);
        let opt = {
            let p2 = Consensus::gaussian(3, 4, 1);
            p2.optimum()
        };
        let r = b.evaluate(&opt);
        assert!(r.grad_norm_sq.unwrap() < 1e-10);
        assert!(r.accuracy.is_none());
    }
}
