//! The Plateau criterion (paper §4.4): adaptive noise-scale scheduling.
//!
//! Start from a small σ_init; whenever the objective has not improved for κ
//! consecutive communication rounds, multiply σ by β ∈ [1.5, 2]; stop
//! growing once σ ≥ σ_bound. The paper's Table 6 hyperparameters are
//! provided as presets.

/// Plateau-criterion hyperparameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlateauConfig {
    pub sigma_init: f32,
    pub sigma_bound: f32,
    /// Rounds without improvement before σ grows.
    pub kappa: usize,
    /// Multiplicative growth factor β.
    pub beta: f32,
}

impl PlateauConfig {
    /// Table 6, non-i.i.d. MNIST row.
    pub fn mnist() -> Self {
        PlateauConfig { sigma_init: 0.01, sigma_bound: 0.5, kappa: 30, beta: 1.5 }
    }

    /// Table 6, EMNIST row.
    pub fn emnist() -> Self {
        PlateauConfig { sigma_init: 0.0001, sigma_bound: 0.1, kappa: 10, beta: 2.0 }
    }

    /// Table 6, CIFAR-10 row.
    pub fn cifar() -> Self {
        PlateauConfig { sigma_init: 0.001, sigma_bound: 0.1, kappa: 200, beta: 1.5 }
    }
}

/// An exact capture of a [`PlateauController`]'s mutable state, for the
/// checkpoint/resume seam (`ckpt::`). `stall` is widened to `u64` so the
/// snapshot has a platform-independent wire width.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PlateauSnapshot {
    pub sigma: f32,
    pub best: f64,
    pub stall: u64,
}

/// Stateful controller: feed it the objective once per round, read σ back.
#[derive(Debug, Clone)]
pub struct PlateauController {
    cfg: PlateauConfig,
    sigma: f32,
    best: f64,
    stall: usize,
}

impl PlateauController {
    pub fn new(cfg: PlateauConfig) -> Self {
        assert!(cfg.sigma_init > 0.0 && cfg.sigma_bound >= cfg.sigma_init);
        assert!(cfg.beta > 1.0);
        PlateauController { cfg, sigma: cfg.sigma_init, best: f64::INFINITY, stall: 0 }
    }

    /// Current noise scale.
    pub fn sigma(&self) -> f32 {
        self.sigma
    }

    /// Capture the controller's exact mutable state for a checkpoint
    /// (the config itself is rebuilt from the spec on resume).
    pub fn snapshot(&self) -> PlateauSnapshot {
        PlateauSnapshot { sigma: self.sigma, best: self.best, stall: self.stall as u64 }
    }

    /// Restore a [`PlateauController::snapshot`] onto a freshly built
    /// controller: the restored controller continues the captured one's
    /// σ trajectory exactly.
    pub fn restore(&mut self, snap: &PlateauSnapshot) {
        self.sigma = snap.sigma;
        self.best = snap.best;
        self.stall = snap.stall as usize;
    }

    /// Observe this round's objective; returns the (possibly grown) σ.
    pub fn observe(&mut self, objective: f64) -> f32 {
        if objective < self.best {
            self.best = objective;
            self.stall = 0;
        } else {
            self.stall += 1;
            if self.stall >= self.cfg.kappa && self.sigma < self.cfg.sigma_bound {
                self.sigma = (self.sigma * self.cfg.beta).min(self.cfg.sigma_bound);
                self.stall = 0;
            }
        }
        self.sigma
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> PlateauConfig {
        PlateauConfig { sigma_init: 0.01, sigma_bound: 0.08, kappa: 3, beta: 2.0 }
    }

    #[test]
    fn grows_only_on_stall() {
        let mut c = PlateauController::new(cfg());
        // Improving objective: sigma stays.
        for i in 0..10 {
            assert_eq!(c.observe(10.0 - i as f64), 0.01);
        }
        // Stalled: after kappa rounds, sigma doubles.
        assert_eq!(c.observe(5.0), 0.01);
        assert_eq!(c.observe(5.0), 0.01);
        let s = c.observe(5.0);
        assert!((s - 0.02).abs() < 1e-9);
    }

    #[test]
    fn bounded_by_sigma_bound() {
        let mut c = PlateauController::new(cfg());
        for _ in 0..1000 {
            c.observe(1.0);
        }
        assert!(c.sigma() <= 0.08 + 1e-9);
        assert!((c.sigma() - 0.08).abs() < 1e-9);
    }

    #[test]
    fn sigma_is_monotone_nondecreasing() {
        let mut c = PlateauController::new(cfg());
        let mut prev = c.sigma();
        let mut rng = crate::rng::Pcg64::seeded(0);
        for _ in 0..500 {
            let s = c.observe(rng.uniform());
            assert!(s >= prev);
            prev = s;
        }
    }

    #[test]
    fn stall_counter_resets_after_growth() {
        let mut c = PlateauController::new(cfg());
        c.observe(1.0); // first observation improves over +inf
        for _ in 0..3 {
            c.observe(1.0); // kappa = 3 stalls -> growth on the last one
        }
        let s1 = c.sigma();
        assert!((s1 - 0.02).abs() < 1e-9);
        // Needs another kappa stalls before the next growth.
        c.observe(1.0);
        assert_eq!(c.sigma(), s1);
    }

    #[test]
    fn snapshot_restore_continues_the_sigma_trajectory() {
        // Drive one controller straight through 40 rounds; drive another to
        // round 17, snapshot, restore onto a fresh controller, and finish.
        // The σ streams must match exactly (including best/stall carryover).
        let objectives: Vec<f64> = (0..40).map(|i| if i < 5 { 10.0 - i as f64 } else { 5.0 }).collect();
        let mut whole = PlateauController::new(cfg());
        let reference: Vec<f32> = objectives.iter().map(|&o| whole.observe(o)).collect();

        let mut first = PlateauController::new(cfg());
        for &o in &objectives[..17] {
            first.observe(o);
        }
        let snap = first.snapshot();
        let mut resumed = PlateauController::new(cfg());
        resumed.restore(&snap);
        assert_eq!(resumed.sigma(), first.sigma());
        for (i, &o) in objectives.iter().enumerate().skip(17) {
            assert_eq!(resumed.observe(o), reference[i], "σ diverged at round {i}");
        }
        assert_eq!(resumed.snapshot(), whole.snapshot());
    }

    #[test]
    fn paper_presets_valid() {
        for cfg in [PlateauConfig::mnist(), PlateauConfig::emnist(), PlateauConfig::cifar()] {
            let _ = PlateauController::new(cfg);
            assert!(cfg.beta >= 1.5 && cfg.beta <= 2.0);
        }
    }
}
