//! The parallel round engine — Algorithm 1's per-round execution machinery.
//!
//! `server::run_experiment` owns *what* an experiment means; this module
//! owns the round loop and, in particular, *how* the per-client work inside
//! a round is executed:
//!
//! * each participant becomes a [`ClientTask`] — local update → uplink
//!   compression → lane fold — driven entirely by its pre-split `Pcg64`
//!   stream, so task execution order is irrelevant to the result;
//! * the round reduce is one seam for every compressor family: the
//!   algorithm's `compress::agg::Aggregator` streams each client's message
//!   into a per-lane `LaneAcc` the moment it is produced (votes *and*
//!   dense payloads — nothing is buffered per client), then folds the
//!   lanes into the round update on the coordinator;
//! * when the backend offers a [`ParallelBackend`] view, worker threads
//!   claim whole lanes off an atomic queue (at most `reduce_lanes` workers
//!   are useful) and process each lane's slots in increasing order.
//!
//! Reduction-topology contract: the aggregate is a pure function of the
//! participant slots and `ServerConfig::reduce_lanes` (L): slot `s` folds
//! into lane `s mod L` in increasing slot order, and lanes fold in lane
//! order. Vote counts are integers (exact in any order); dense f32 folds
//! are pinned by the topology. Hence the `RunResult` is **bit-identical**
//! for every `parallelism` value (tested below and in
//! `tests/integration_fl.rs`), and peak aggregation memory is
//! O(min(L, m)·d), never O(m·d). Stateful backends (PJRT) run on the
//! sequential path — same topology, same result — where the compression
//! hook may call back into the backend.
//!
//! Who participates each round is delegated to a [`ParticipationPolicy`]:
//! [`UniformPolicy`] reproduces the historical `clients_per_round` shuffle
//! bit-for-bit, and `sim::ScenarioPolicy` plans rounds through the
//! client-lifecycle simulator (deadlines, dropouts, byzantine clients).
//! Policies run sequentially on the coordinator before any task is
//! spawned, so they cannot break the parallelism contract.

use super::algorithms::{AlgorithmConfig, Compression, ServerOpt};
use super::backend::{LocalScratch, ParallelBackend, TrainBackend};
use super::metrics::{RoundRecord, RunResult};
use super::plateau::{PlateauController, PlateauSnapshot};
use super::server::{Participation, ServerConfig};
use crate::compress::agg::{
    AbsorbCtx, Aggregator, LaneAcc, ReduceStats, ReduceTopology, RemoteError, RemoteUpdate,
    Scratch, SignKernelHook,
};
use crate::compress::error_feedback::EfState;
use crate::compress::kernel;
use crate::compress::pack::PackedSigns;
use crate::compress::sign::SigmaRule;
use crate::rng::{Pcg64, ZParam};
use crate::sim::{ByzantineMode, ScenarioPolicy};
use crate::telemetry::{Clock, Phase, Stopwatch, Telemetry};
use crate::tensor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// What happened to one *selected* client by the time its round closed.
///
/// Only `Arrived` clients are aggregated; the other outcomes exist so
/// scenario drivers can report cohort attrition (and so `RoundRecord` can
/// count `arrived` vs `selected`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ClientOutcome {
    /// Report landed in time and was aggregated (arrival time, sim s).
    Arrived { at_s: f64 },
    /// Still mid-round when the round closed: a deadline miss, or an
    /// over-selected report discarded by an early close.
    Straggler { projected_s: f64 },
    /// Aborted mid-round (connection loss, app evicted, battery).
    DroppedOut { at_s: f64 },
    /// Unreachable when the cohort was drawn; never started.
    Unavailable,
}

/// One aggregated participant: the global client id plus the fault (if
/// any) the client applies to its own update before compressing. The fault
/// is applied inside the client task — a pure per-`(round, client)`
/// transform — so it composes with the parallelism contract.
#[derive(Debug, Clone, Copy)]
pub struct Participant {
    pub client: usize,
    pub fault: Option<ByzantineMode>,
}

/// A planned round: who reports (in deterministic aggregation order), what
/// happened to every selected client, and how long the round took in
/// simulated time.
#[derive(Debug, Clone)]
pub struct RoundPlan {
    /// Clients whose reports are aggregated, in reduce order.
    pub participants: Vec<Participant>,
    /// Every selected client with its outcome (superset of participants).
    pub outcomes: Vec<(usize, ClientOutcome)>,
    /// Selected clients that completed the model download before the round
    /// closed — the number the engine bills downlink traffic for.
    pub downloads: usize,
    /// Simulated duration of the round, seconds (0 for `UniformPolicy`).
    pub duration_s: f64,
}

/// Strategy deciding, per round, which clients participate. Implementors
/// must be deterministic given `(t, root)` — the engine calls this once per
/// round on the coordinator thread, before any client task runs.
pub trait ParticipationPolicy {
    fn plan_round(&mut self, t: usize, root: &Pcg64) -> RoundPlan;
}

/// The historical sampler: `m` of `n` clients uniformly without
/// replacement (everyone when `m == n`), every report arrives instantly.
/// Stream derivation (`root.split(2t + 1)`) is part of the reproducibility
/// contract — every seeded experiment in the repo depends on it.
pub struct UniformPolicy {
    pub n: usize,
    pub m: usize,
}

impl ParticipationPolicy for UniformPolicy {
    fn plan_round(&mut self, t: usize, root: &Pcg64) -> RoundPlan {
        let mut sample_rng = root.split(t as u64 * 2 + 1);
        let ids: Vec<usize> = if self.m == self.n {
            (0..self.n).collect()
        } else {
            sample_rng.sample_without_replacement(self.n, self.m)
        };
        RoundPlan {
            outcomes: ids.iter().map(|&c| (c, ClientOutcome::Arrived { at_s: 0.0 })).collect(),
            downloads: ids.len(),
            participants: ids
                .into_iter()
                .map(|client| Participant { client, fault: None })
                .collect(),
            duration_s: 0.0,
        }
    }
}

/// One client's unit of work for a round: the participant slot it fills
/// (which fixes the reduce order), the client id, and the pre-split RNG
/// stream. Everything else a worker needs is shared round state.
#[derive(Debug, Clone)]
pub struct ClientTask {
    /// Index into the round's participant list.
    pub pos: usize,
    /// Global client id.
    pub client: usize,
    /// The client's private PCG stream for this round.
    pub rng: Pcg64,
}

impl ClientTask {
    /// Build the task for participant slot `pos` of round `t`.
    ///
    /// The stream derivation is part of the reproducibility contract:
    /// changing it changes every seeded experiment in the repo.
    pub fn new(root: &Pcg64, t: usize, pos: usize, client: usize) -> ClientTask {
        let rng = root.split(((t as u64) << 20) ^ (client as u64) ^ 0x5eed);
        ClientTask { pos, client, rng }
    }
}

/// Per-worker, round-lifetime scratch: the client-update buffer the backend
/// fills, the backend's own local-step buffers, and the compression
/// scratch. One per worker, reused across every client and every round —
/// this pool is why the steady-state round loop performs **no per-client
/// heap allocation** (pinned by `tests/alloc_regression.rs` via a counting
/// global allocator).
#[derive(Debug)]
pub struct RoundScratch {
    /// The client's update direction `(x_start − x_E)/γ` for the task in
    /// flight; refilled by `local_update(_shared)_into` per client.
    delta: Vec<f32>,
    /// Iterate/gradient buffers for the backend's E-step loop.
    local: LocalScratch,
    /// Compression scratch (packed signs, dequantize buffer, top-k index).
    agg: Scratch,
}

impl RoundScratch {
    fn new(d: usize) -> RoundScratch {
        RoundScratch { delta: vec![0.0; d], local: LocalScratch::new(), agg: Scratch::new(d) }
    }
}

/// Adapter exposing the backend's AOT kernel route to the aggregation seam
/// (sequential path only — see `TrainBackend::compress_hook`).
struct BackendHook<'b> {
    backend: &'b mut dyn TrainBackend,
}

impl SignKernelHook for BackendHook<'_> {
    fn packed_sign(
        &mut self,
        delta: &[f32],
        z: ZParam,
        sigma: f32,
        rng: &mut Pcg64,
    ) -> Option<PackedSigns> {
        self.backend.compress_hook(delta, z, sigma, rng)
    }
}

/// Everything the round loop owns, captured at a round boundary: the
/// iterate, the server-optimizer state, the plateau controller, every
/// client's EF residual and the exact bit/record/time cursors. Per-round
/// RNG streams are *not* captured — they are pure splits of the root
/// (see [`RoundEngine::root`]), so a resumed round `t` derives the same
/// streams an uninterrupted run would (DESIGN.md §2.6).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineCkpt {
    /// The first round the resumed loop will execute.
    pub next_round: u64,
    /// The global iterate after round `next_round - 1`.
    pub params: Vec<f32>,
    pub momentum: Vec<f32>,
    pub adam_v: Vec<f32>,
    pub adam_t: u32,
    pub plateau: Option<PlateauSnapshot>,
    /// Per-client EF residuals (empty unless the algorithm uses EF).
    pub ef_residuals: Vec<Vec<f32>>,
    pub bits_up: u64,
    pub bits_down: u64,
    pub sim_time_s: f64,
    /// Records already evaluated this run (replayed into the resumed
    /// `RunResult` without re-firing observers).
    pub records: Vec<RoundRecord>,
}

/// The checkpoint seam threaded through both round loops (this engine's
/// [`RoundEngine::run_resumable`] and `service::ServiceHost::run_one`):
/// consulted once per completed round; on `true` the loop hands it a fresh
/// [`EngineCkpt`]. `store` failures must not unwind into the round loop —
/// implementors log and carry on.
pub trait CkptHook {
    /// Whether to capture after the round that makes `next_round` next.
    fn want(&mut self, next_round: u64) -> bool;
    /// Receive the capture (persist it, count it, ...).
    fn store(&mut self, ck: EngineCkpt);
    /// Transport-owned extra state — the service host delivers its sticky
    /// client→pid pins here immediately before [`CkptHook::store`]. The
    /// in-process engine never calls it; the default discards.
    fn store_pins(&mut self, _pins: Vec<(u64, u64)>) {}
}

/// The run's root generator for `seed` — the DESIGN.md §2.6 `(seed,
/// 0xa11ce)` derivation shared by the engine, the participant SDK and the
/// checkpoint layer. Everything else the round loop draws is a pure
/// [`Pcg64::split`] of this stream.
pub fn root_for_seed(seed: u64) -> Pcg64 {
    Pcg64::new(seed, 0xa11ce)
}

/// The round loop: server state + per-round client execution machinery.
pub struct RoundEngine<'a> {
    algo: &'a AlgorithmConfig,
    cfg: &'a ServerConfig,
    d: usize,
    n: usize,
    /// The algorithm's aggregation seam (stateless; shared by workers).
    agg: Box<dyn Aggregator>,
    // Server-optimizer state.
    momentum_buf: Vec<f32>,
    adam_v: Vec<f32>,
    adam_t: u32,
    plateau: Option<PlateauController>,
    /// Per-client EF residuals. The Mutex only satisfies the borrow checker
    /// across worker threads: distinct clients touch distinct entries, so
    /// there is never contention.
    ef: Vec<Mutex<EfState>>,
    /// Lane-sharded aggregation state, reused across rounds. Lanes are
    /// locked by the one worker that claims them — never contended.
    lanes: Vec<Mutex<LaneAcc>>,
    /// Per-worker round scratch (update/delta/sign-word buffers), reused
    /// across rounds.
    scratches: Vec<RoundScratch>,
    update: Vec<f32>,
    /// Downlink-compression packed-sign scratch.
    downlink_packed: PackedSigns,
    bits_up: u64,
    bits_down: u64,
    /// Round-timing source (`wall_ms`). [`Clock::from_env`] by default so
    /// CI smokes can pin it process-wide; override with
    /// [`RoundEngine::set_clock`].
    clock: Clock,
    /// Observability recorder; disabled (free) unless injected via
    /// [`RoundEngine::set_telemetry`]. Read-only with respect to the run:
    /// results are byte-identical either way.
    tele: Telemetry,
}

impl<'a> RoundEngine<'a> {
    /// `d` / `n`: the backend's parameter dimension and client count.
    pub fn new(algo: &'a AlgorithmConfig, cfg: &'a ServerConfig, d: usize, n: usize) -> Self {
        RoundEngine {
            agg: algo.compression.aggregator_robust(algo.client_lr, algo.robust),
            algo,
            cfg,
            d,
            n,
            momentum_buf: vec![0.0; d],
            adam_v: vec![0.0; d],
            adam_t: 0,
            plateau: None,
            ef: Vec::new(),
            lanes: Vec::new(),
            scratches: Vec::new(),
            update: vec![0.0; d],
            downlink_packed: PackedSigns::zeroed(d),
            bits_up: 0,
            bits_down: 0,
            clock: Clock::from_env(),
            tele: Telemetry::disabled(),
        }
    }

    /// Override the round-timing clock (tests and CI pin
    /// [`Clock::Fixed`]; the env default covers the CLI processes).
    pub fn set_clock(&mut self, clock: Clock) {
        self.clock = clock;
    }

    /// Attach a telemetry recorder. The engine only ever *writes* to it —
    /// phase spans, round/bit counters, eval gauges — so an enabled handle
    /// cannot perturb the seeded run.
    pub fn set_telemetry(&mut self, tele: Telemetry) {
        self.tele = tele;
    }

    /// Total f32s currently allocated across dense lane accumulators. The
    /// streamed reduce's high-water mark is O(min(reduce_lanes, m)·d) —
    /// never O(m·d) — which the regression tests pin through this.
    pub fn lane_dense_floats(&self) -> usize {
        self.lanes.iter().map(|l| l.lock().unwrap().dense_floats()).sum()
    }

    /// Run the full experiment (Algorithm 1 / Algorithm 2 round loop).
    pub fn run(&mut self, backend: &mut dyn TrainBackend) -> RunResult {
        self.run_observed(backend, &mut |_| {})
    }

    /// Like [`RoundEngine::run`], additionally streaming every evaluated
    /// [`RoundRecord`] to `on_record` as it is produced — the seam
    /// `api::Session` feeds its `RoundObserver`s from (a progress sink
    /// sees the experiment live, not after the fact).
    pub fn run_observed(
        &mut self,
        backend: &mut dyn TrainBackend,
        on_record: &mut dyn FnMut(&RoundRecord),
    ) -> RunResult {
        self.run_resumable(backend, on_record, None, None)
    }

    /// The full round loop with the checkpoint seam exposed: optionally
    /// start from a restored [`EngineCkpt`] (skipping its already-completed
    /// rounds; its records are replayed into the result without re-firing
    /// `on_record`), and optionally hand a [`CkptHook`] a fresh capture
    /// after any completed round it asks for. With `resume = None` and
    /// `ckpt = None` this is exactly [`RoundEngine::run_observed`].
    pub fn run_resumable(
        &mut self,
        backend: &mut dyn TrainBackend,
        on_record: &mut dyn FnMut(&RoundRecord),
        resume: Option<&EngineCkpt>,
        mut ckpt: Option<&mut dyn CkptHook>,
    ) -> RunResult {
        self.reset_run();
        let mut params = backend.init_params();
        assert_eq!(params.len(), self.d);
        let root = self.root();
        let mut policy = self.build_policy(&root);
        let mut records = Vec::new();
        let mut sim_time_s = 0.0f64;
        let mut start = 0usize;
        if let Some(ck) = resume {
            self.restore(ck);
            params.copy_from_slice(&ck.params);
            records = ck.records.clone();
            sim_time_s = ck.sim_time_s;
            start = ck.next_round as usize;
        }

        for t in start..self.cfg.rounds {
            let sw = self.clock.start();
            // 1. Participation: the policy decides who reports this round
            //    (and what happened to everyone else it selected).
            let plan = policy.plan_round(t, &root);
            let arrived = plan.participants.len();
            let selected = plan.outcomes.len();
            sim_time_s += plan.duration_s;
            self.bill_downlink(plan.downloads);

            // Effective sigma this round (plateau overrides the fixed value).
            let round_sigma = self.round_sigma();
            self.tele.round_begin(t as u64, round_sigma);

            // 2–5. Local updates + streamed compression + lane reduce +
            //    server step. When nobody reported (every selected client
            //    dropped, missed the deadline or was unreachable) the model
            //    simply doesn't move this round — and zero uplink is billed,
            //    because no aggregator tally exists.
            if arrived > 0 {
                let stats =
                    self.run_clients(backend, &root, t, &params, &plan.participants, round_sigma);
                debug_assert_eq!(stats.arrived as usize, arrived);
                self.apply_server_step(t, &root, &mut params, &stats);
            }

            // 7. Evaluation (inside the round span: `wall_ms` covers the
            //    full round, evaluation included — see `RoundRecord`).
            if self.should_eval(t) {
                let rec = self.eval_record(
                    backend,
                    t,
                    &params,
                    round_sigma,
                    &sw,
                    sim_time_s,
                    arrived as u32,
                    selected as u32,
                );
                on_record(&rec);
                records.push(rec);
            }
            self.tele.round_end(t as u64, arrived as u64, selected as u64, sw.elapsed_ms());

            // 8. Checkpoint seam: capture *after* the round is fully
            //    folded, stepped and recorded, so `next_round = t + 1`
            //    resumes exactly at the next plan. The final round is
            //    never captured — there is nothing left to resume.
            if let Some(hook) = ckpt.as_deref_mut() {
                let next = t as u64 + 1;
                if (next as usize) < self.cfg.rounds && hook.want(next) {
                    hook.store(self.capture(next, &params, sim_time_s, &records));
                }
            }
        }

        RunResult { algorithm: self.algo.name.clone(), records }
    }

    /// Capture everything the round loop owns into an [`EngineCkpt`].
    /// `next_round` is the first round a resumed loop will execute;
    /// `params`, `sim_time_s` and `records` are the loop-local state the
    /// engine does not hold itself.
    pub fn capture(
        &self,
        next_round: u64,
        params: &[f32],
        sim_time_s: f64,
        records: &[RoundRecord],
    ) -> EngineCkpt {
        EngineCkpt {
            next_round,
            params: params.to_vec(),
            momentum: self.momentum_buf.clone(),
            adam_v: self.adam_v.clone(),
            adam_t: self.adam_t,
            plateau: self.plateau.as_ref().map(|p| p.snapshot()),
            ef_residuals: self
                .ef
                .iter()
                .map(|e| e.lock().unwrap().residual().to_vec())
                .collect(),
            bits_up: self.bits_up,
            bits_down: self.bits_down,
            sim_time_s,
            records: records.to_vec(),
        }
    }

    /// Restore a capture onto a freshly [`RoundEngine::reset_run`] engine.
    /// Panics if the capture's shapes do not match this engine's — shape
    /// mismatches mean the caller skipped the spec-fingerprint check that
    /// `ckpt::Snapshot` enforces before any engine is built.
    pub fn restore(&mut self, ck: &EngineCkpt) {
        assert_eq!(ck.params.len(), self.d, "checkpoint dimension mismatch");
        assert_eq!(ck.momentum.len(), self.d, "checkpoint momentum mismatch");
        assert_eq!(ck.adam_v.len(), self.d, "checkpoint adam_v mismatch");
        assert_eq!(
            ck.ef_residuals.len(),
            self.ef.len(),
            "checkpoint EF client-count mismatch"
        );
        self.momentum_buf.copy_from_slice(&ck.momentum);
        self.adam_v.copy_from_slice(&ck.adam_v);
        self.adam_t = ck.adam_t;
        match (self.plateau.as_mut(), ck.plateau.as_ref()) {
            (Some(p), Some(snap)) => p.restore(snap),
            (None, None) => {}
            _ => panic!("checkpoint plateau presence mismatch"),
        }
        for (slot, residual) in self.ef.iter_mut().zip(&ck.ef_residuals) {
            assert_eq!(residual.len(), self.d, "checkpoint EF residual mismatch");
            *slot = Mutex::new(EfState::from_residual(residual.clone()));
        }
        self.bits_up = ck.bits_up;
        self.bits_down = ck.bits_down;
    }

    // --- The round loop, exploded into stages. ---------------------------
    //
    // `run_observed` above composes these in-process; the networked
    // coordinator (`service::ServiceHost`) composes the *same* stages
    // around remotely-submitted updates, which is what makes the loopback
    // service bit-identical to the engine by construction.

    /// Effective cohort size per round (`clients_per_round`, clamped to
    /// the population; the whole population when unset).
    pub fn clients_per_round(&self) -> usize {
        self.cfg.clients_per_round.unwrap_or(self.n).min(self.n)
    }

    /// The algorithm's display name (CSV series label).
    pub fn algorithm_name(&self) -> &str {
        &self.algo.name
    }

    /// Parameter dimension `d`.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// (Re)initialize all run-scoped state so the engine can be reused,
    /// and assert the run's preconditions.
    pub fn reset_run(&mut self) {
        let n = self.n;
        let m_per_round = self.clients_per_round();
        assert!(m_per_round >= 1);
        if matches!(self.algo.compression, Compression::ErrorFeedback) {
            let full = matches!(self.cfg.participation, Participation::Uniform)
                && m_per_round == n;
            assert!(
                full,
                "EF-SignSGD cannot track residuals under partial participation (paper §1.1)"
            );
        }
        self.momentum_buf.iter_mut().for_each(|v| *v = 0.0);
        self.adam_v.iter_mut().for_each(|v| *v = 0.0);
        self.adam_t = 0;
        self.plateau = self.cfg.plateau.map(PlateauController::new);
        self.ef = match self.algo.compression {
            Compression::ErrorFeedback => {
                (0..n).map(|_| Mutex::new(EfState::new(self.d))).collect()
            }
            _ => Vec::new(),
        };
        self.bits_up = 0;
        self.bits_down = 0;
    }

    /// The run's root RNG. The `(seed, 0xa11ce)` derivation is part of the
    /// reproducibility contract shared with every networked participant.
    pub fn root(&self) -> Pcg64 {
        root_for_seed(self.cfg.seed)
    }

    /// Build the participation policy for one run.
    pub fn build_policy(&self, root: &Pcg64) -> Box<dyn ParticipationPolicy> {
        match &self.cfg.participation {
            Participation::Uniform => {
                Box::new(UniformPolicy { n: self.n, m: self.clients_per_round() })
            }
            Participation::Simulated(sc) => {
                // The scheduler's transfer-size model reads the
                // aggregator's exact per-client wire cost.
                let up_bits = self.agg.nominal_client_bits(self.d);
                let down_bits = if self.cfg.downlink_sign.is_some() {
                    self.d as u64
                } else {
                    32 * self.d as u64
                };
                Box::new(ScenarioPolicy::new(
                    sc.clone(),
                    self.n,
                    self.algo.local_steps,
                    up_bits,
                    down_bits,
                    root,
                ))
            }
        }
    }

    /// Downlink accounting: bill only clients that actually finished
    /// downloading the model before the round closed (d bits per
    /// coordinate compressed, 32·d uncompressed) — not unreachable
    /// candidates, and not clients cut off mid-download.
    pub fn bill_downlink(&mut self, downloads: usize) {
        let down_per_client = if self.cfg.downlink_sign.is_some() {
            self.d
        } else {
            32 * self.d
        };
        let added = (downloads * down_per_client) as u64;
        self.bits_down += added;
        self.tele.add_bits_down(added);
    }

    /// Effective σ this round (plateau overrides the fixed value).
    pub fn round_sigma(&self) -> f32 {
        effective_sigma(self.algo, self.plateau.as_ref())
    }

    /// Open a round fed by remote submissions: reset the lane shards for a
    /// cohort of `m` arrivals and return the fold topology. The coordinator
    /// then folds each submission with [`RoundEngine::fold_remote_slot`]
    /// (slots in increasing order per lane, exactly like the worker path)
    /// and closes with [`RoundEngine::finish_remote_round`].
    pub fn begin_remote_round(&mut self, m: usize) -> ReduceTopology {
        let topo = ReduceTopology::new(self.cfg.reduce_lanes, m);
        let lanes_n = topo.lanes();
        while self.lanes.len() < lanes_n {
            self.lanes.push(Mutex::new(LaneAcc::new(self.d)));
        }
        for lane in self.lanes[..lanes_n].iter_mut() {
            lane.get_mut().unwrap().reset();
        }
        if self.scratches.is_empty() {
            self.scratches.push(RoundScratch::new(self.d));
        }
        topo
    }

    /// Validate one remote submission and fold it into its lane with the
    /// exact weights/tallies the in-process `absorb` would have used.
    pub fn fold_remote_slot(
        &mut self,
        topo: &ReduceTopology,
        slot: usize,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
    ) -> Result<(), RemoteError> {
        let lane = self.lanes[topo.lane_of(slot)].get_mut().unwrap();
        let out = self.agg.fold_remote(upd, loss, inv_m, lane, &mut self.scratches[0].agg);
        if out.is_ok() {
            self.tele.count_fold();
        }
        out
    }

    /// Close a remote round: fold the lanes (lane-index order) into the
    /// round update and return the seam's tallies.
    pub fn finish_remote_round(&mut self, topo: &ReduceTopology) -> ReduceStats {
        self.agg.reduce(&self.lanes[..topo.lanes()], &mut self.update)
    }

    /// Steps 3–6 of the round: bill uplink, apply the (optionally
    /// sign-compressed) aggregated update through the server optimizer, and
    /// feed the plateau controller. Call only when `stats.arrived > 0`.
    pub fn apply_server_step(
        &mut self,
        t: usize,
        root: &Pcg64,
        params: &mut [f32],
        stats: &ReduceStats,
    ) {
        let span = self.tele.span_start();
        // Uplink billing comes from the aggregator's tally: exact
        // wire bits of the messages actually absorbed.
        self.bits_up += stats.bits;
        self.tele.add_bits_up(stats.bits);

        let step_scale = match &self.algo.compression {
            // Alg. 2 applies η to the mean sign of *model diffs* (no γ).
            Compression::DpSign { .. } => self.algo.server_lr,
            // DP-FedAvg likewise averages model diffs directly.
            Compression::DpDense { .. } => self.algo.server_lr,
            // Alg. 1 line 15: η·γ·mean(Δ).
            _ => self.algo.server_lr * self.algo.client_lr,
        };
        // Optional downlink compression: broadcast the update itself
        // as a dequantized stochastic sign (applied server-side too,
        // so the global iterate equals what the clients reconstruct).
        // Fused kernel straight into the reusable packed buffer —
        // no clone of the update, no i8 detour.
        if let Some((z, sigma_d)) = self.cfg.downlink_sign {
            let mut drng = root.split((t as u64) | 0x4000_0000_0000_0000);
            kernel::stochastic_sign_packed(
                &self.update,
                z,
                sigma_d,
                &mut drng,
                &mut self.downlink_packed,
            );
            let scale = (z.eta() as f32) * sigma_d;
            self.downlink_packed.decode_scaled_into(scale, &mut self.update);
        }
        match self.algo.server_opt {
            ServerOpt::Sgd => tensor::axpy(-step_scale, &self.update, params),
            ServerOpt::Momentum(beta) => {
                // Server momentum: m ← β·m + agg; x ← x − scale·m.
                for (mb, &u) in self.momentum_buf.iter_mut().zip(&self.update) {
                    *mb = beta * *mb + u;
                }
                tensor::axpy(-step_scale, &self.momentum_buf, params);
            }
            ServerOpt::Adam { beta1, beta2, eps } => {
                // FedAdam (Reddi et al. '20) with bias correction.
                self.adam_t += 1;
                let bc1 = 1.0 - beta1.powi(self.adam_t as i32);
                let bc2 = 1.0 - beta2.powi(self.adam_t as i32);
                for ((p, mb), (vb, &u)) in params
                    .iter_mut()
                    .zip(self.momentum_buf.iter_mut())
                    .zip(self.adam_v.iter_mut().zip(&self.update))
                {
                    *mb = beta1 * *mb + (1.0 - beta1) * u;
                    *vb = beta2 * *vb + (1.0 - beta2) * u * u;
                    let mhat = *mb / bc1;
                    let vhat = *vb / bc2;
                    *p -= step_scale * mhat / (vhat.sqrt() + eps);
                }
            }
        }

        // Plateau feedback (mean loss over *arrived* clients, folded
        // lane-by-lane in the fixed lane order).
        let mean_local_loss = stats.loss_sum / stats.arrived as f64;
        if let Some(p) = self.plateau.as_mut() {
            p.observe(mean_local_loss);
        }
        self.tele.span_end(Phase::ServerStep, span, t as u64);
    }

    /// Whether round `t` is an evaluation round.
    pub fn should_eval(&self, t: usize) -> bool {
        t % self.cfg.eval_every == 0 || t + 1 == self.cfg.rounds
    }

    /// Evaluate the model and assemble the round's record.
    ///
    /// `sw` is the round stopwatch started before participation planning:
    /// `wall_ms` is read *after* the evaluation returns, so the record
    /// covers the full round — plan, client work, fold, server step and
    /// evaluation — identically in the in-process engine and the
    /// networked `ServiceHost` (see `RoundRecord::wall_ms`).
    #[allow(clippy::too_many_arguments)]
    pub fn eval_record(
        &self,
        backend: &mut dyn TrainBackend,
        t: usize,
        params: &[f32],
        round_sigma: f32,
        sw: &Stopwatch,
        sim_time_s: f64,
        arrived: u32,
        selected: u32,
    ) -> RoundRecord {
        let span = self.tele.span_start();
        let eval = backend.evaluate(params);
        self.tele.span_end(Phase::Eval, span, t as u64);
        self.tele.observe_eval(t as u64, eval.objective);
        RoundRecord {
            round: t,
            objective: eval.objective,
            accuracy: eval.accuracy,
            grad_norm_sq: eval.grad_norm_sq,
            bits_up: self.bits_up,
            bits_down: self.bits_down,
            sigma: round_sigma,
            wall_ms: sw.elapsed_ms(),
            sim_time_s,
            arrived,
            selected,
            degraded: false,
        }
    }

    /// Execute every participant's task for round `t` through the
    /// aggregation seam, then fold the lanes. Fills `self.update` with the
    /// aggregated round update and returns the seam's tallies.
    fn run_clients(
        &mut self,
        backend: &mut dyn TrainBackend,
        root: &Pcg64,
        t: usize,
        params: &[f32],
        participants: &[Participant],
        round_sigma: f32,
    ) -> ReduceStats {
        let m = participants.len();
        let inv_m = 1.0f32 / m as f32;
        let topo = ReduceTopology::new(self.cfg.reduce_lanes, m);
        let lanes_n = topo.lanes();

        // Reset round aggregation state (lazily grown, reused across rounds).
        while self.lanes.len() < lanes_n {
            self.lanes.push(Mutex::new(LaneAcc::new(self.d)));
        }
        for lane in self.lanes[..lanes_n].iter_mut() {
            lane.get_mut().unwrap().reset();
        }
        let threads = self.cfg.parallelism.max(1).min(lanes_n);
        while self.scratches.len() < threads {
            self.scratches.push(RoundScratch::new(self.d));
        }

        // Phase span: perturb + sign + pack + streamed in-lane fold (the
        // fused kernel path) across every participant.
        let span = self.tele.span_start();
        // The parallel path runs iff the backend is Sync-safe; which path
        // runs never depends on `parallelism`, so a given backend always
        // produces the same per-client messages.
        if backend.as_parallel().is_some() {
            let par = backend.as_parallel().unwrap();
            let next = AtomicUsize::new(0);
            let ctx = RoundCtx {
                par,
                agg: &*self.agg,
                algo: self.algo,
                topo,
                root,
                t,
                params,
                participants,
                round_sigma,
                inv_m,
                ef: &self.ef,
                lanes: &self.lanes[..lanes_n],
                next: &next,
            };
            if threads <= 1 {
                worker_loop(&ctx, &mut self.scratches[0]);
            } else {
                let ctx = &ctx;
                std::thread::scope(|s| {
                    for rs in self.scratches[..threads].iter_mut() {
                        s.spawn(move || worker_loop(ctx, rs));
                    }
                });
            }
        } else {
            self.run_clients_exclusive(
                backend,
                root,
                t,
                params,
                participants,
                round_sigma,
                inv_m,
                topo,
            );
        }

        self.tele.span_end(Phase::Clients, span, t as u64);
        self.tele.count_client_updates(m as u64);

        // Fixed-topology coordinator fold: lanes in lane-index order.
        let span = self.tele.span_start();
        let stats = self.agg.reduce(&self.lanes[..lanes_n], &mut self.update);
        self.tele.span_end(Phase::Fold, span, t as u64);
        stats
    }

    /// Sequential path for stateful backends; the compression hook may call
    /// back into the backend (the PJRT Pallas kernel route). Walking slots
    /// in natural order visits every lane's slots in increasing order, so
    /// the lane contents — and therefore the reduce — equal the parallel
    /// path's exactly.
    #[allow(clippy::too_many_arguments)]
    fn run_clients_exclusive(
        &mut self,
        backend: &mut dyn TrainBackend,
        root: &Pcg64,
        t: usize,
        params: &[f32],
        participants: &[Participant],
        round_sigma: f32,
        inv_m: f32,
        topo: ReduceTopology,
    ) {
        let RoundScratch { delta, local, agg: cscratch } = &mut self.scratches[0];
        let mut hook = BackendHook { backend };
        for (slot, part) in participants.iter().enumerate() {
            let mut task = ClientTask::new(root, t, slot, part.client);
            let mean_loss = hook.backend.local_update_into(
                part.client,
                params,
                self.algo.local_steps,
                self.algo.client_lr,
                &mut task.rng,
                delta,
                local,
            );
            if let Some(mode) = part.fault {
                mode.apply(delta);
            }
            let lane = self.lanes[topo.lane_of(slot)].get_mut().unwrap();
            self.agg.absorb(
                delta,
                mean_loss,
                AbsorbCtx {
                    rng: &mut task.rng,
                    round_sigma,
                    inv_m,
                    ef: self.ef.get(part.client),
                    hook: Some(&mut hook),
                },
                lane,
                cscratch,
            );
        }
    }
}

/// Shared, read-only round state for worker threads (Sync by construction:
/// every field is a shared reference to Sync data).
struct RoundCtx<'c> {
    par: &'c dyn ParallelBackend,
    agg: &'c dyn Aggregator,
    algo: &'c AlgorithmConfig,
    topo: ReduceTopology,
    root: &'c Pcg64,
    t: usize,
    params: &'c [f32],
    participants: &'c [Participant],
    round_sigma: f32,
    inv_m: f32,
    ef: &'c [Mutex<EfState>],
    lanes: &'c [Mutex<LaneAcc>],
    next: &'c AtomicUsize,
}

/// Worker body: claim the next lane off the shared queue, run its client
/// tasks in slot order, folding each message straight into the lane — no
/// per-client parking, no end-of-round buffer, and no per-client heap
/// allocation (everything lives in the worker's `RoundScratch`).
fn worker_loop(ctx: &RoundCtx<'_>, rs: &mut RoundScratch) {
    let RoundScratch { delta, local, agg: scratch } = rs;
    loop {
        let lane_i = ctx.next.fetch_add(1, Ordering::Relaxed);
        if lane_i >= ctx.topo.lanes() {
            break;
        }
        // Uncontended: each lane is claimed by exactly one worker.
        let mut lane = ctx.lanes[lane_i].lock().unwrap();
        for slot in ctx.topo.lane_slots(lane_i) {
            let part = ctx.participants[slot];
            let mut task = ClientTask::new(ctx.root, ctx.t, slot, part.client);
            let mean_loss = ctx.par.local_update_shared_into(
                part.client,
                ctx.params,
                ctx.algo.local_steps,
                ctx.algo.client_lr,
                &mut task.rng,
                delta,
                local,
            );
            // A byzantine fault corrupts the update direction *before*
            // compression: the attacker follows the wire format but lies
            // about its local result — exactly the threat model
            // majority-vote aggregation is claimed to absorb.
            if let Some(mode) = part.fault {
                mode.apply(delta);
            }
            ctx.agg.absorb(
                delta,
                mean_loss,
                AbsorbCtx {
                    rng: &mut task.rng,
                    round_sigma: ctx.round_sigma,
                    inv_m: ctx.inv_m,
                    ef: ctx.ef.get(part.client),
                    hook: None,
                },
                &mut lane,
                scratch,
            );
        }
    }
}

/// The σ actually applied this round: the plateau controller overrides a
/// fixed σ; input-dependent rules resolve per client inside the
/// aggregator's `absorb`.
pub(super) fn effective_sigma(
    algo: &AlgorithmConfig,
    plateau: Option<&PlateauController>,
) -> f32 {
    match (&algo.compression, plateau) {
        (Compression::ZSign { sigma: SigmaRule::Fixed(_), .. }, Some(p)) => p.sigma(),
        (Compression::ZSign { sigma: SigmaRule::Fixed(s), .. }, None) => *s,
        _ => 0.0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fl::backend::AnalyticBackend;
    use crate::fl::plateau::PlateauConfig;
    use crate::fl::server::run_experiment;
    use crate::problems::consensus::Consensus;
    use crate::rng::ZParam;

    fn run_with(
        algo: &AlgorithmConfig,
        parallelism: usize,
        clients_per_round: Option<usize>,
    ) -> RunResult {
        let mut b = AnalyticBackend::new(Consensus::gaussian(16, 37, 1234));
        let cfg = ServerConfig {
            rounds: 8,
            seed: 9,
            eval_every: 1,
            parallelism,
            clients_per_round,
            ..Default::default()
        };
        run_experiment(&mut b, algo, &cfg)
    }

    /// Byte-level equality over everything except the measured wall time.
    fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
        assert_eq!(a.algorithm, b.algorithm, "{what}");
        assert_eq!(a.records.len(), b.records.len(), "{what}");
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.round, y.round, "{what}");
            assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "{what} round {}", x.round);
            assert_eq!(x.accuracy.map(f64::to_bits), y.accuracy.map(f64::to_bits), "{what}");
            assert_eq!(
                x.grad_norm_sq.map(f64::to_bits),
                y.grad_norm_sq.map(f64::to_bits),
                "{what}"
            );
            assert_eq!(x.bits_up, y.bits_up, "{what}");
            assert_eq!(x.bits_down, y.bits_down, "{what}");
            assert_eq!(x.sigma.to_bits(), y.sigma.to_bits(), "{what}");
            assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{what}");
            assert_eq!(x.arrived, y.arrived, "{what}");
            assert_eq!(x.selected, y.selected, "{what}");
        }
    }

    fn all_compressors() -> Vec<AlgorithmConfig> {
        vec![
            AlgorithmConfig::gd().with_lrs(0.05, 1.0),
            AlgorithmConfig::fedavg(3).with_lrs(0.05, 1.0),
            AlgorithmConfig::signsgd().with_lrs(0.05, 1.0),
            AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0).with_lrs(0.05, 1.0),
            AlgorithmConfig::z_signsgd(ZParam::Inf, 2.0).with_lrs(0.05, 1.0),
            AlgorithmConfig::sto_signsgd().with_lrs(0.05, 1.0),
            AlgorithmConfig::ef_signsgd().with_lrs(0.05, 1.0),
            AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0),
            AlgorithmConfig::topk(0.25, 1).with_lrs(0.05, 1.0),
            AlgorithmConfig::sparse_sign(0.25, ZParam::Finite(1), 1.0, 1).with_lrs(0.05, 1.0),
            AlgorithmConfig::dp_signfedavg(0.5, 1.0, 2).with_lrs(0.05, 0.5),
            AlgorithmConfig::dp_fedavg(0.5, 1.0, 2).with_lrs(0.05, 0.5),
        ]
    }

    #[test]
    fn every_compressor_is_bit_exact_across_thread_counts() {
        // Every Compression variant the server tests cover, full
        // participation: parallelism must never change the result.
        for algo in &all_compressors() {
            let base = run_with(algo, 1, None);
            for par in [2usize, 8] {
                let run = run_with(algo, par, None);
                assert_identical(&base, &run, &format!("{} par={par}", algo.name));
            }
        }
    }

    #[test]
    fn multi_slot_lanes_are_bit_exact_across_thread_counts() {
        // reduce_lanes < m forces multi-slot lanes (the streamed fold with
        // in-lane ordering actually exercised); parallelism must still be
        // invisible, for every compressor family.
        for algo in &all_compressors() {
            let mk = |par: usize| {
                let mut b = AnalyticBackend::new(Consensus::gaussian(16, 37, 1234));
                let cfg = ServerConfig {
                    rounds: 6,
                    seed: 13,
                    eval_every: 1,
                    parallelism: par,
                    reduce_lanes: 3,
                    ..Default::default()
                };
                run_experiment(&mut b, algo, &cfg)
            };
            let base = mk(1);
            for par in [2usize, 3, 8] {
                assert_identical(&base, &mk(par), &format!("{} lanes=3 par={par}", algo.name));
            }
        }
    }

    #[test]
    fn reduce_lanes_is_part_of_the_topology_not_the_schedule() {
        // Different lane counts are *allowed* to produce different dense
        // trajectories (the fold tree changes, like changing the seed) but
        // each must be internally deterministic. Sign votes are integers,
        // so absent plateau feedback (the f64 loss fold IS lane-grouped)
        // the sign trajectory does not depend on the lane count either.
        let dense = AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0);
        let sign = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0).with_lrs(0.05, 1.0);
        let mk = |algo: &AlgorithmConfig, lanes: usize, par: usize| {
            let mut b = AnalyticBackend::new(Consensus::gaussian(16, 37, 1234));
            let cfg = ServerConfig {
                rounds: 6,
                seed: 21,
                eval_every: 1,
                parallelism: par,
                reduce_lanes: lanes,
                ..Default::default()
            };
            run_experiment(&mut b, algo, &cfg)
        };
        for lanes in [2usize, 7, 64] {
            assert_identical(
                &mk(&dense, lanes, 1),
                &mk(&dense, lanes, 8),
                &format!("qsgd lanes={lanes}"),
            );
            assert_identical(&mk(&sign, 64, 1), &mk(&sign, lanes, 4), "sign lane-count");
        }
    }

    #[test]
    fn partial_participation_is_bit_exact_across_thread_counts() {
        for algo in [
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0),
            AlgorithmConfig::qsgd(4).with_lrs(0.05, 1.0),
            AlgorithmConfig::topk(0.25, 1).with_lrs(0.05, 1.0),
        ] {
            let base = run_with(&algo, 1, Some(5));
            for par in [2usize, 8] {
                let run = run_with(&algo, par, Some(5));
                assert_identical(&base, &run, &format!("{} partial par={par}", algo.name));
            }
        }
    }

    #[test]
    fn server_optimizers_and_plateau_are_bit_exact() {
        // Momentum/Adam fold thread-count-sensitive sums into persistent
        // state; the plateau controller feeds the loss back into sigma. All
        // of it must stay identical under parallelism.
        let adam = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2)
            .with_lrs(0.05, 0.3)
            .with_server_adam();
        let momentum = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0)
            .with_lrs(0.05, 0.5)
            .with_momentum(0.9);
        for algo in [adam, momentum] {
            let mk = |par: usize| {
                let mut b = AnalyticBackend::new(Consensus::gaussian(12, 29, 5));
                let plateau =
                    PlateauConfig { sigma_init: 0.5, sigma_bound: 8.0, kappa: 3, beta: 2.0 };
                let cfg = ServerConfig {
                    rounds: 12,
                    seed: 4,
                    eval_every: 1,
                    parallelism: par,
                    plateau: Some(plateau),
                    downlink_sign: Some((ZParam::Finite(1), 0.5)),
                    ..Default::default()
                };
                run_experiment(&mut b, &algo, &cfg)
            };
            let base = mk(1);
            for par in [3usize, 8] {
                assert_identical(&base, &mk(par), &format!("{} par={par}", algo.name));
            }
        }
    }

    #[test]
    fn oversubscribed_parallelism_is_capped_and_exact() {
        // More threads than lanes must neither crash nor change results.
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let base = run_with(&algo, 1, Some(4));
        let wide = run_with(&algo, 64, Some(4));
        assert_identical(&base, &wide, "oversubscribed");
    }

    #[test]
    fn parallelism_zero_is_treated_as_one() {
        let algo = AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0);
        assert_identical(&run_with(&algo, 0, None), &run_with(&algo, 1, None), "par=0");
    }

    #[test]
    fn dense_high_water_is_lanes_not_cohort() {
        // 48 clients streamed through 4 lanes: dense aggregation state must
        // be exactly 4·d floats, not 48·d — the Θ(m·d) cliff is gone.
        let n = 48;
        let d = 37;
        for algo in [
            AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0),
            AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0),
        ] {
            let cfg = ServerConfig {
                rounds: 3,
                seed: 7,
                parallelism: 4,
                reduce_lanes: 4,
                ..Default::default()
            };
            let mut engine = RoundEngine::new(&algo, &cfg, d, n);
            let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, 3));
            engine.run(&mut b);
            assert_eq!(engine.lane_dense_floats(), 4 * d, "{}", algo.name);
        }
        // The sign family allocates no dense lane state at all.
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let cfg =
            ServerConfig { rounds: 3, seed: 7, parallelism: 4, ..Default::default() };
        let mut engine = RoundEngine::new(&algo, &cfg, d, n);
        let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, 3));
        engine.run(&mut b);
        assert_eq!(engine.lane_dense_floats(), 0);
    }

    #[test]
    fn bits_billing_comes_from_the_aggregator_tally() {
        // Per-round uplink billing pinned per family: sign = d bits/client,
        // QSGD(s=1) = 32 + 2d, dense = 32d — exactly what the aggregator
        // absorbed, scaled by actual arrivals.
        let n = 6;
        let d = 33;
        let rounds = 4;
        let cases: Vec<(AlgorithmConfig, u64)> = vec![
            (AlgorithmConfig::signsgd().with_lrs(0.01, 1.0), d as u64),
            (AlgorithmConfig::qsgd(1).with_lrs(0.01, 1.0), 32 + 2 * d as u64),
            (AlgorithmConfig::gd().with_lrs(0.01, 1.0), 32 * d as u64),
            (AlgorithmConfig::ef_signsgd().with_lrs(0.01, 1.0), 32 + d as u64),
        ];
        for (algo, per_client) in cases {
            let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, 17));
            let cfg = ServerConfig { rounds, seed: 1, eval_every: 1, ..Default::default() };
            let run = run_experiment(&mut b, &algo, &cfg);
            for rec in &run.records {
                let expect = per_client * n as u64 * (rec.round as u64 + 1);
                assert_eq!(rec.bits_up, expect, "{} round {}", algo.name, rec.round);
            }
        }
    }

    #[test]
    fn client_task_rng_depends_on_round_and_client() {
        let root = Pcg64::new(7, 0xa11ce);
        let mut a = ClientTask::new(&root, 0, 0, 3).rng;
        let mut b = ClientTask::new(&root, 1, 0, 3).rng;
        let mut c = ClientTask::new(&root, 0, 1, 4).rng;
        let (x, y, z) = (a.next_u64(), b.next_u64(), c.next_u64());
        assert_ne!(x, y);
        assert_ne!(x, z);
        // Same (round, client) => same stream, independent of slot position.
        let mut d = ClientTask::new(&root, 0, 9, 3).rng;
        assert_eq!(d.next_u64(), x);
    }

    fn scenario(byz_frac: f32) -> crate::sim::ScenarioConfig {
        crate::sim::ScenarioConfig {
            target_cohort: 6,
            overselect: 1.5,
            deadline_s: 0.6,
            round_latency_s: 0.1,
            dropout_prob: 0.2,
            byzantine_frac: byz_frac,
            byzantine_mode: crate::sim::ByzantineMode::SignFlip,
            fleet: crate::sim::FleetPreset::CrossDevice,
        }
    }

    fn run_sim_with(
        algo: &AlgorithmConfig,
        parallelism: usize,
        sc: crate::sim::ScenarioConfig,
    ) -> RunResult {
        let mut b = AnalyticBackend::new(Consensus::gaussian(24, 16, 77));
        let cfg = ServerConfig {
            rounds: 10,
            seed: 5,
            eval_every: 1,
            parallelism,
            participation: crate::fl::server::Participation::Simulated(sc),
            ..Default::default()
        };
        run_experiment(&mut b, algo, &cfg)
    }

    #[test]
    fn simulated_participation_is_bit_exact_across_thread_counts() {
        // Stragglers + dropouts + byzantine sign-flippers in the mix: the
        // lifecycle plan is coordinator-side and faults are per-task pure,
        // so the parallelism contract must survive the whole scenario.
        for algo in [
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0),
            AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0),
            AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0),
        ] {
            let base = run_sim_with(&algo, 1, scenario(0.25));
            for par in [2usize, 8] {
                let run = run_sim_with(&algo, par, scenario(0.25));
                assert_identical(&base, &run, &format!("sim {} par={par}", algo.name));
            }
        }
    }

    #[test]
    fn simulated_rounds_report_attrition_and_sim_time() {
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let run = run_sim_with(&algo, 1, scenario(0.0));
        // ceil(1.5 * 6) = 9 candidates per round; arrivals never exceed the
        // target and the simulated clock moves by >= latency every round.
        let mut prev_time = 0.0;
        for rec in &run.records {
            assert_eq!(rec.selected, 9, "round {}", rec.round);
            assert!(rec.arrived <= 6, "round {}", rec.round);
            assert!(rec.sim_time_s >= prev_time + 0.1, "round {}", rec.round);
            prev_time = rec.sim_time_s;
        }
    }

    #[test]
    fn impossible_deadline_freezes_the_model() {
        // Nobody can report in 1 µs: every round is empty and the iterate
        // must not move (no update, no plateau feedback, no uplink bits —
        // empty rounds bill zero because no aggregator tally exists).
        let mut sc = scenario(0.0);
        sc.deadline_s = 1e-6;
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let run = run_sim_with(&algo, 4, sc);
        let first = run.records.first().unwrap();
        assert_eq!(first.arrived, 0);
        assert_eq!(first.bits_up, 0);
        // Nobody even finished downloading, so no downlink is billed.
        assert_eq!(first.bits_down, 0);
        for rec in &run.records {
            assert_eq!(rec.objective.to_bits(), first.objective.to_bits());
        }
    }

    #[test]
    fn byzantine_clients_change_the_trajectory() {
        // 25% sign-flippers must actually flow through compression: the
        // run must differ from the byzantine-free run with the same seed.
        let algo = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0);
        let honest = run_sim_with(&algo, 1, scenario(0.0));
        let attacked = run_sim_with(&algo, 1, scenario(0.25));
        let last_h = honest.records.last().unwrap().objective;
        let last_a = attacked.records.last().unwrap().objective;
        assert_ne!(last_h.to_bits(), last_a.to_bits());
    }

    #[test]
    fn uniform_policy_reports_full_arrival() {
        let algo = AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0);
        let run = run_with(&algo, 1, Some(5));
        for rec in &run.records {
            assert_eq!(rec.arrived, 5);
            assert_eq!(rec.selected, 5);
            assert_eq!(rec.sim_time_s, 0.0);
        }
    }

    /// Test hook: capture exactly once, when `next_round == at`.
    struct CaptureAt {
        at: u64,
        taken: Option<EngineCkpt>,
    }

    impl CkptHook for CaptureAt {
        fn want(&mut self, next_round: u64) -> bool {
            next_round == self.at
        }
        fn store(&mut self, ck: EngineCkpt) {
            self.taken = Some(ck);
        }
    }

    #[test]
    fn snapshot_resume_is_bit_identical_mid_run() {
        // Kill-at-round-k in miniature: run to round k, capture, build a
        // fresh engine from the capture, and demand the stitched run equals
        // the uninterrupted one bit for bit — across the stateful server
        // paths (EF residuals, momentum + plateau + downlink compression,
        // Adam, scenario participation).
        let ef = AlgorithmConfig::ef_signsgd().with_lrs(0.05, 1.0);
        let plateau_momentum = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0)
            .with_lrs(0.05, 0.5)
            .with_momentum(0.9);
        let adam = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2)
            .with_lrs(0.05, 0.3)
            .with_server_adam();
        let cases: Vec<(AlgorithmConfig, ServerConfig)> = vec![
            (
                ef,
                ServerConfig { rounds: 10, seed: 4, eval_every: 1, ..Default::default() },
            ),
            (
                plateau_momentum,
                ServerConfig {
                    rounds: 10,
                    seed: 4,
                    eval_every: 1,
                    plateau: Some(PlateauConfig {
                        sigma_init: 0.5,
                        sigma_bound: 8.0,
                        kappa: 2,
                        beta: 2.0,
                    }),
                    downlink_sign: Some((ZParam::Finite(1), 0.5)),
                    parallelism: 4,
                    ..Default::default()
                },
            ),
            (
                adam,
                ServerConfig {
                    rounds: 10,
                    seed: 4,
                    eval_every: 2,
                    parallelism: 8,
                    participation: crate::fl::server::Participation::Simulated(scenario(0.25)),
                    ..Default::default()
                },
            ),
        ];
        for (algo, cfg) in &cases {
            let (n, d) = (24usize, 16usize);
            let mut b = AnalyticBackend::new(Consensus::gaussian(n, d, 77));
            let mut whole_engine = RoundEngine::new(algo, cfg, d, n);
            let whole = whole_engine.run(&mut b);

            for k in [1u64, 4, 7] {
                let mut hook = CaptureAt { at: k, taken: None };
                let mut b1 = AnalyticBackend::new(Consensus::gaussian(n, d, 77));
                let mut first = RoundEngine::new(algo, cfg, d, n);
                first.run_resumable(&mut b1, &mut |_| {}, None, Some(&mut hook));
                let ck = hook.taken.expect("hook captured");
                assert_eq!(ck.next_round, k);

                let mut b2 = AnalyticBackend::new(Consensus::gaussian(n, d, 77));
                let mut resumed = RoundEngine::new(algo, cfg, d, n);
                let run = resumed.run_resumable(&mut b2, &mut |_| {}, Some(&ck), None);
                assert_identical(&whole, &run, &format!("{} k={k}", algo.name));
            }
        }
    }

    #[test]
    fn resumed_run_fires_on_record_only_for_new_rounds() {
        // Replayed records land in the RunResult but must not re-fire the
        // observer seam — the files they fed were already written.
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 6, seed: 2, eval_every: 1, ..Default::default() };
        let mut hook = CaptureAt { at: 3, taken: None };
        let mut b1 = AnalyticBackend::new(Consensus::gaussian(6, 9, 1));
        let mut first = RoundEngine::new(&algo, &cfg, 9, 6);
        first.run_resumable(&mut b1, &mut |_| {}, None, Some(&mut hook));
        let ck = hook.taken.unwrap();

        let mut seen = Vec::new();
        let mut b2 = AnalyticBackend::new(Consensus::gaussian(6, 9, 1));
        let mut resumed = RoundEngine::new(&algo, &cfg, 9, 6);
        let run = resumed.run_resumable(
            &mut b2,
            &mut |r| seen.push(r.round),
            Some(&ck),
            None,
        );
        assert_eq!(seen, vec![3, 4, 5]);
        assert_eq!(run.records.len(), 6);
    }

    #[test]
    fn engine_is_reusable_across_runs() {
        // A second run on a fresh backend must match a fresh engine's run
        // (all run-scoped state is reinitialized).
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 5, seed: 11, parallelism: 4, ..Default::default() };
        let mut engine = RoundEngine::new(&algo, &cfg, 23, 6);
        let mut b1 = AnalyticBackend::new(Consensus::gaussian(6, 23, 3));
        let first = engine.run(&mut b1);
        let mut b2 = AnalyticBackend::new(Consensus::gaussian(6, 23, 3));
        let second = engine.run(&mut b2);
        assert_identical(&first, &second, "engine reuse");
    }

    #[test]
    fn fixed_clock_pins_wall_ms_on_every_record() {
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 1.0).with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 4, seed: 3, eval_every: 1, ..Default::default() };
        let mut engine = RoundEngine::new(&algo, &cfg, 23, 6);
        engine.set_clock(Clock::Fixed(7));
        let mut b = AnalyticBackend::new(Consensus::gaussian(6, 23, 3));
        let run = engine.run(&mut b);
        assert_eq!(run.records.len(), 4);
        for rec in &run.records {
            assert_eq!(rec.wall_ms, 7.0, "round {}", rec.round);
        }
    }

    #[test]
    fn telemetry_enabled_is_byte_identical_and_populates_the_registry() {
        let algo = AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0).with_lrs(0.05, 1.0);
        let cfg = ServerConfig {
            rounds: 6,
            seed: 9,
            eval_every: 1,
            parallelism: 4,
            ..Default::default()
        };
        let (n, d) = (8usize, 19usize);
        let mut quiet_engine = RoundEngine::new(&algo, &cfg, d, n);
        let mut b1 = AnalyticBackend::new(Consensus::gaussian(n, d, 2));
        let quiet = quiet_engine.run(&mut b1);

        let tele = Telemetry::with_capacity(256);
        let mut engine = RoundEngine::new(&algo, &cfg, d, n);
        engine.set_telemetry(tele.clone());
        let mut b2 = AnalyticBackend::new(Consensus::gaussian(n, d, 2));
        let watched = engine.run(&mut b2);

        // Recording must not perturb the run in any way.
        assert_identical(&quiet, &watched, "telemetry on/off");

        // And the registry must reflect exactly what the records say.
        let m = tele.metrics().unwrap();
        assert_eq!(m.rounds_total.get(), 6);
        assert_eq!(m.round_current.get(), 5.0);
        let last = watched.records.last().unwrap();
        assert_eq!(m.bits_up_total.get(), last.bits_up);
        assert_eq!(m.bits_down_total.get(), last.bits_down);
        assert_eq!(m.arrived_total.get(), 6 * n as u64);
        assert_eq!(m.client_updates_total.get(), 6 * n as u64);
        assert_eq!(m.objective.get(), last.objective);
        assert_eq!(m.sigma.get(), 2.0);
        for p in Phase::ALL {
            assert_eq!(m.phase_ms[p as usize].snapshot().count, 6, "{}", p.label());
        }
        assert_eq!(m.round_ms.snapshot().count, 6);
        assert!(!tele.events().is_empty());
        let text = tele.export_prometheus();
        assert!(text.contains("zsfa_rounds_total 6"));
    }

    /// Delegating backend whose `evaluate` sleeps, to pin what `wall_ms`
    /// covers.
    struct SlowEval<B: TrainBackend> {
        inner: B,
        sleep_ms: u64,
    }

    impl<B: TrainBackend> TrainBackend for SlowEval<B> {
        fn dim(&self) -> usize {
            self.inner.dim()
        }
        fn num_clients(&self) -> usize {
            self.inner.num_clients()
        }
        fn init_params(&mut self) -> Vec<f32> {
            self.inner.init_params()
        }
        fn local_update(
            &mut self,
            client: usize,
            params: &[f32],
            local_steps: usize,
            gamma: f32,
            rng: &mut Pcg64,
        ) -> crate::fl::backend::LocalOutcome {
            self.inner.local_update(client, params, local_steps, gamma, rng)
        }
        fn evaluate(&mut self, params: &[f32]) -> crate::fl::backend::EvalResult {
            std::thread::sleep(std::time::Duration::from_millis(self.sleep_ms));
            self.inner.evaluate(params)
        }
        fn as_parallel(&self) -> Option<&dyn ParallelBackend> {
            self.inner.as_parallel()
        }
    }

    #[test]
    fn wall_ms_covers_the_evaluation_phase() {
        // The doc/accounting contract on `RoundRecord::wall_ms`: the round
        // stopwatch is read *after* evaluation, so a slow evaluator must
        // show up in the record (generous margin to stay unflaky).
        let algo = AlgorithmConfig::gd().with_lrs(0.05, 1.0);
        let cfg = ServerConfig { rounds: 1, seed: 1, eval_every: 1, ..Default::default() };
        let mut engine = RoundEngine::new(&algo, &cfg, 11, 4);
        engine.set_clock(Clock::Monotonic);
        let mut b = SlowEval {
            inner: AnalyticBackend::new(Consensus::gaussian(4, 11, 8)),
            sleep_ms: 40,
        };
        let run = engine.run(&mut b);
        assert!(
            run.records[0].wall_ms >= 25.0,
            "wall_ms {} must include the 40 ms evaluation",
            run.records[0].wall_ms
        );
    }
}
