//! Named algorithm presets — every row of the paper's Table 2 and every
//! curve in Figures 1–17, expressed as `(compression, server optimizer)`
//! configurations over the shared round loop in [`super::server`].

use crate::compress::agg::{
    Aggregator, DenseAgg, DpDenseAgg, DpSignAgg, EfAgg, QsgdAgg, RobustRule, SparseSignAgg,
    TopKAgg, ZSignAgg,
};
use crate::compress::sign::SigmaRule;
use crate::rng::ZParam;

/// Which uplink compressor the clients apply.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Compression {
    /// Uncompressed f32 updates (FedAvg / distributed SGD / GD).
    None,
    /// The paper's stochastic sign `Sign(delta + σ·ξ_z)`.
    /// σ = 0 gives vanilla SignSGD; `SigmaRule::L2Norm` with `z = Inf` gives
    /// Sto-SignSGD (Safaryan–Richtárik).
    ZSign { z: ZParam, sigma: SigmaRule },
    /// EF-SignSGD (scaled sign + error feedback). Full participation only.
    ErrorFeedback,
    /// QSGD / FedPAQ unbiased quantizer with `s` levels.
    Qsgd { s: u32 },
    /// DP-SignFedAvg (Algorithm 2): clip the *model diff* to `clip`, add
    /// Gaussian noise `N(0, (noise_mult·clip)²)`, then sign. The server
    /// applies η·mean(signs) without the γ factor (matching Alg. 2 line 15).
    DpSign { clip: f32, noise_mult: f32 },
    /// Uncompressed DP-FedAvg baseline (clip + Gaussian noise, no sign).
    DpDense { clip: f32, noise_mult: f32 },
    /// Magnitude top-k sparsification (Qsparse-local-SGD-style baseline [8]).
    TopK { frac: f32 },
    /// Top-k support + stochastic sign of values — the paper conclusion's
    /// "sign + sparsification" combination.
    SparseSign { frac: f32, z: ZParam, sigma: f32 },
}

impl Compression {
    /// Does this compressor transmit packed signs (d bits)?
    pub fn is_sign(&self) -> bool {
        matches!(self, Compression::ZSign { .. } | Compression::DpSign { .. })
    }

    /// Build this family's server-side aggregation seam (see
    /// `compress::agg`): how one client's update is compressed and streamed
    /// into lane-sharded state, and how the lanes reduce into the round
    /// update. `client_lr` is γ for the families that compress the
    /// stepsize-scaled model diff (EF, the DP variants).
    pub fn aggregator(&self, client_lr: f32) -> Box<dyn Aggregator> {
        self.aggregator_robust(client_lr, RobustRule::None)
    }

    /// Like [`Compression::aggregator`], but with a Byzantine-robust vote
    /// reduction (see `compress::agg::RobustRule`). Only the packed-sign
    /// families carry a majority vote that can be trimmed; the dense and
    /// value-carrying compressors ignore the rule.
    pub fn aggregator_robust(&self, client_lr: f32, robust: RobustRule) -> Box<dyn Aggregator> {
        match *self {
            Compression::None => Box::new(DenseAgg),
            Compression::ZSign { z, sigma } => Box::new(ZSignAgg { z, sigma, robust }),
            Compression::ErrorFeedback => Box::new(EfAgg { client_lr }),
            Compression::Qsgd { s } => Box::new(QsgdAgg { s }),
            Compression::DpSign { clip, noise_mult } => {
                Box::new(DpSignAgg { clip, noise_mult, client_lr, robust })
            }
            Compression::DpDense { clip, noise_mult } => {
                Box::new(DpDenseAgg { clip, noise_mult, client_lr })
            }
            Compression::TopK { frac } => Box::new(TopKAgg { frac }),
            Compression::SparseSign { frac, z, sigma } => {
                Box::new(SparseSignAgg { frac, z, sigma })
            }
        }
    }
}

/// Server-side optimizer applied to the dequantized aggregate (the paper's
/// conclusion: the compressor composes with adaptive FL optimizers [41]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum ServerOpt {
    /// Plain step: x ← x − scale·agg.
    Sgd,
    /// Heavy-ball momentum (the "wM" baselines).
    Momentum(f32),
    /// FedAdam (Reddi et al. '20): first/second-moment adaptive step.
    Adam { beta1: f32, beta2: f32, eps: f32 },
}

/// A fully-specified algorithm: compression + stepsizes + server optimizer.
#[derive(Debug, Clone, PartialEq)]
pub struct AlgorithmConfig {
    /// Display name for logs/CSV (matches the paper's legend strings).
    pub name: String,
    pub compression: Compression,
    /// Client stepsize γ.
    pub client_lr: f32,
    /// Server stepsize η (Algorithm 1 line 15 applies η·γ; the theory sets
    /// η = η_z·σ, the experiments tune η directly — see §4).
    pub server_lr: f32,
    /// Server optimizer over the aggregated update.
    pub server_opt: ServerOpt,
    /// Local SGD steps per round E (E = 1 recovers z-SignSGD).
    pub local_steps: usize,
    /// Byzantine-robust reduction of the sign majority vote (sign families
    /// only; [`RobustRule::None`] reproduces the paper's plain mean).
    pub robust: RobustRule,
}

impl AlgorithmConfig {
    fn base(name: &str, compression: Compression) -> Self {
        AlgorithmConfig {
            name: name.to_string(),
            compression,
            client_lr: 0.01,
            server_lr: 1.0,
            server_opt: ServerOpt::Sgd,
            local_steps: 1,
            robust: RobustRule::None,
        }
    }

    // -- builders ---------------------------------------------------------

    pub fn with_lrs(mut self, client_lr: f32, server_lr: f32) -> Self {
        self.client_lr = client_lr;
        self.server_lr = server_lr;
        self
    }

    pub fn with_momentum(mut self, m: f32) -> Self {
        if m > 0.0 {
            self.server_opt = ServerOpt::Momentum(m);
            if !self.name.ends_with("wM") {
                self.name = format!("{}wM", self.name);
            }
        }
        self
    }

    /// FedAdam server optimizer (Reddi et al. '20 defaults).
    pub fn with_server_adam(mut self) -> Self {
        self.server_opt = ServerOpt::Adam { beta1: 0.9, beta2: 0.99, eps: 1e-3 };
        self.name = format!("{}+Adam", self.name);
        self
    }

    pub fn with_local_steps(mut self, e: usize) -> Self {
        assert!(e >= 1);
        self.local_steps = e;
        self
    }

    /// Byzantine-robust trimmed majority vote (sign families only).
    pub fn with_robust(mut self, robust: RobustRule) -> Self {
        self.robust = robust;
        self
    }

    // -- the paper's algorithms --------------------------------------------

    /// Uncompressed gradient descent / distributed SGD ([22] in Table 2).
    pub fn gd() -> Self {
        Self::base("GD", Compression::None)
    }

    /// Distributed SGD with server momentum (SGDwM, Fig. 3).
    pub fn sgdwm(momentum: f32) -> Self {
        Self::base("SGD", Compression::None).with_momentum(momentum)
    }

    /// Uncompressed FedAvg ([37]/[55]) with E local steps.
    pub fn fedavg(local_steps: usize) -> Self {
        Self::base("FedAvg", Compression::None).with_local_steps(local_steps)
    }

    /// Vanilla (noiseless) SignSGD [9] — diverges under heterogeneity (§1).
    pub fn signsgd() -> Self {
        Self::base(
            "SignSGD",
            Compression::ZSign { z: ZParam::Finite(1), sigma: SigmaRule::Fixed(0.0) },
        )
    }

    /// z-SignSGD (Algorithm 1 with E = 1): the paper's 1-SignSGD/∞-SignSGD.
    pub fn z_signsgd(z: ZParam, sigma: f32) -> Self {
        let name = format!("{z}-SignSGD");
        Self::base(&name, Compression::ZSign { z, sigma: SigmaRule::Fixed(sigma) })
    }

    /// z-SignFedAvg (Algorithm 1): the paper's headline algorithm.
    pub fn z_signfedavg(z: ZParam, sigma: f32, local_steps: usize) -> Self {
        let name = format!("{z}-SignFedAvg");
        Self::base(&name, Compression::ZSign { z, sigma: SigmaRule::Fixed(sigma) })
            .with_local_steps(local_steps)
    }

    /// Noiseless SignFedAvg ablation (Appendix D.2's "SignFedAvg").
    pub fn sign_fedavg(local_steps: usize) -> Self {
        Self::base(
            "SignFedAvg",
            Compression::ZSign { z: ZParam::Finite(1), sigma: SigmaRule::Fixed(0.0) },
        )
        .with_local_steps(local_steps)
    }

    /// Sto-SignSGD [43]: uniform noise with the input-dependent scale σ=‖x‖₂.
    pub fn sto_signsgd() -> Self {
        Self::base(
            "Sto-SignSGD",
            Compression::ZSign { z: ZParam::Inf, sigma: SigmaRule::L2Norm },
        )
    }

    /// EF-SignSGD [31] (with optional momentum — EF-SignSGDwM in Fig. 3).
    pub fn ef_signsgd() -> Self {
        Self::base("EF-SignSGD", Compression::ErrorFeedback)
    }

    /// QSGD [5] with s quantization levels.
    pub fn qsgd(s: u32) -> Self {
        Self::base(&format!("QSGD(s={s})"), Compression::Qsgd { s })
    }

    /// FedPAQ [42] = QSGD quantizer + E local steps.
    pub fn fedpaq(s: u32, local_steps: usize) -> Self {
        Self::base(&format!("FedPAQ(s={s})"), Compression::Qsgd { s })
            .with_local_steps(local_steps)
    }

    /// DP-SignFedAvg (Algorithm 2).
    pub fn dp_signfedavg(clip: f32, noise_mult: f32, local_steps: usize) -> Self {
        Self::base("DP-SignFedAvg", Compression::DpSign { clip, noise_mult })
            .with_local_steps(local_steps)
    }

    /// Uncompressed DP-FedAvg [21]/[28].
    pub fn dp_fedavg(clip: f32, noise_mult: f32, local_steps: usize) -> Self {
        Self::base("DP-FedAvg", Compression::DpDense { clip, noise_mult })
            .with_local_steps(local_steps)
    }

    /// Magnitude top-k baseline (Qsparse-local-SGD-flavoured [8]).
    pub fn topk(frac: f32, local_steps: usize) -> Self {
        Self::base(&format!("TopK({frac})"), Compression::TopK { frac })
            .with_local_steps(local_steps)
    }

    /// Sparsified stochastic sign — the conclusion's combination.
    pub fn sparse_sign(frac: f32, z: ZParam, sigma: f32, local_steps: usize) -> Self {
        Self::base(
            &format!("Sparse{z}-Sign({frac})"),
            Compression::SparseSign { frac, z, sigma },
        )
        .with_local_steps(local_steps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_paper_legends() {
        assert_eq!(AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.05).name, "1-SignSGD");
        assert_eq!(AlgorithmConfig::z_signsgd(ZParam::Inf, 0.05).name, "inf-SignSGD");
        assert_eq!(
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 0.01, 5).name,
            "1-SignFedAvg"
        );
        assert_eq!(AlgorithmConfig::sgdwm(0.9).name, "SGDwM");
        assert_eq!(AlgorithmConfig::ef_signsgd().with_momentum(0.9).name, "EF-SignSGDwM");
    }

    #[test]
    fn signsgd_is_zero_noise() {
        match AlgorithmConfig::signsgd().compression {
            Compression::ZSign { sigma: SigmaRule::Fixed(s), .. } => assert_eq!(s, 0.0),
            _ => panic!(),
        }
    }

    #[test]
    fn sto_sign_is_input_scaled_inf() {
        match AlgorithmConfig::sto_signsgd().compression {
            Compression::ZSign { z: ZParam::Inf, sigma: SigmaRule::L2Norm } => {}
            _ => panic!(),
        }
    }

    #[test]
    fn builders_compose() {
        let a = AlgorithmConfig::fedavg(10).with_lrs(0.1, 0.5).with_momentum(0.9);
        assert_eq!(a.local_steps, 10);
        assert_eq!(a.client_lr, 0.1);
        assert_eq!(a.server_lr, 0.5);
        assert_eq!(a.name, "FedAvgwM");
        assert_eq!(a.server_opt, ServerOpt::Momentum(0.9));
    }

    #[test]
    fn adam_builder() {
        let a = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 0.01, 5).with_server_adam();
        assert!(matches!(a.server_opt, ServerOpt::Adam { .. }));
        assert!(a.name.ends_with("+Adam"));
    }

    #[test]
    fn robust_builder_sets_the_rule() {
        let a = AlgorithmConfig::signsgd().with_robust(RobustRule::TrimmedMajority { frac: 0.1 });
        assert_eq!(a.robust, RobustRule::TrimmedMajority { frac: 0.1 });
        assert_eq!(AlgorithmConfig::signsgd().robust, RobustRule::None);
        // Dense families ignore the rule but still build an aggregator.
        let _ = AlgorithmConfig::gd().compression.aggregator_robust(0.01, a.robust);
    }

    #[test]
    fn sparse_builders() {
        let a = AlgorithmConfig::sparse_sign(0.05, ZParam::Inf, 0.1, 2);
        assert!(matches!(a.compression, Compression::SparseSign { .. }));
        assert!(!a.compression.is_sign()); // not the packed-sign wire path
        let b = AlgorithmConfig::topk(0.1, 1);
        assert!(matches!(b.compression, Compression::TopK { .. }));
    }
}
