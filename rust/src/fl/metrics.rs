//! Experiment metrics: per-round records, repeat aggregation (the paper's
//! "10 independent runs, mean ± std" protocol), and CSV output.

use crate::util::stats::Summary;
use std::io::Write;
use std::path::Path;

/// One communication round's measurements.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RoundRecord {
    pub round: usize,
    /// Global objective (train loss / f(x)).
    pub objective: f64,
    /// Test accuracy if the workload has one.
    pub accuracy: Option<f64>,
    /// ‖∇f(x)‖² when available (the paper's convergence metric).
    pub grad_norm_sq: Option<f64>,
    /// Cumulative uplink bits across all clients and rounds so far.
    pub bits_up: u64,
    /// Cumulative downlink bits (32·d per client unless downlink compression
    /// is enabled — see `ServerConfig::downlink_sign`).
    pub bits_down: u64,
    /// Noise scale σ in effect this round (tracks the plateau controller).
    pub sigma: f32,
    /// Milliseconds spent on the **full** round: participation planning,
    /// client work (in the networked service, the offer/collect window),
    /// lane/slot fold, server step and the evaluation itself — the
    /// stopwatch is read after `evaluate` returns. The in-process engine
    /// and `service::ServiceHost` time this identical span (pinned by
    /// engine/service tests). The source is the injectable
    /// `telemetry::Clock`: under `Clock::Fixed` (`ZSFA_FIXED_CLOCK`) every
    /// record carries the pinned value, so CI byte-diffs raw CSVs whole.
    pub wall_ms: f64,
    /// Cumulative *simulated* seconds (client-lifecycle scenarios; 0 under
    /// uniform participation, where rounds take no modeled time).
    pub sim_time_s: f64,
    /// Clients whose reports were aggregated this round.
    pub arrived: u32,
    /// Clients the coordinator selected this round (≥ `arrived`; the gap
    /// is stragglers + dropouts + unreachable devices).
    pub selected: u32,
    /// True when the networked service closed this round at the quorum
    /// deadline without every offered slot submitting (graceful
    /// degradation). Always false in the in-process engine, whose
    /// partial-participation semantics are modeled by `selected`/`arrived`
    /// instead.
    pub degraded: bool,
}

/// A complete run: algorithm name + its round records.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub algorithm: String,
    pub records: Vec<RoundRecord>,
}

impl RunResult {
    pub fn final_objective(&self) -> f64 {
        self.records.last().map(|r| r.objective).unwrap_or(f64::NAN)
    }

    pub fn final_accuracy(&self) -> Option<f64> {
        self.records.last().and_then(|r| r.accuracy)
    }

    pub fn total_bits(&self) -> u64 {
        self.records.last().map(|r| r.bits_up).unwrap_or(0)
    }
}

/// Mean ± std aggregation of repeated runs (per round index).
#[derive(Debug, Clone)]
pub struct Aggregated {
    pub algorithm: String,
    pub rounds: Vec<usize>,
    pub objective_mean: Vec<f64>,
    pub objective_std: Vec<f64>,
    pub accuracy_mean: Vec<f64>,
    pub accuracy_std: Vec<f64>,
    /// Mean cumulative uplink bits across repeats (rounded). Identical to
    /// every repeat's counter under uniform participation; under scenario
    /// participation arrivals — and therefore bits — vary per seed.
    pub bits_up: Vec<u64>,
    /// Mean cumulative simulated seconds across repeats (scenario runs).
    pub sim_time_mean: Vec<f64>,
}

/// Aggregate repeats; all runs must share round structure.
pub fn aggregate(runs: &[RunResult]) -> Aggregated {
    assert!(!runs.is_empty());
    let n_rounds = runs[0].records.len();
    assert!(runs.iter().all(|r| r.records.len() == n_rounds), "ragged repeats");
    let mut out = Aggregated {
        algorithm: runs[0].algorithm.clone(),
        rounds: Vec::with_capacity(n_rounds),
        objective_mean: Vec::new(),
        objective_std: Vec::new(),
        accuracy_mean: Vec::new(),
        accuracy_std: Vec::new(),
        bits_up: Vec::new(),
        sim_time_mean: Vec::new(),
    };
    for t in 0..n_rounds {
        let mut obj = Summary::new();
        let mut acc = Summary::new();
        let mut sim = Summary::new();
        let mut up = Summary::new();
        for r in runs {
            obj.push(r.records[t].objective);
            sim.push(r.records[t].sim_time_s);
            up.push(r.records[t].bits_up as f64);
            if let Some(a) = r.records[t].accuracy {
                acc.push(a);
            }
        }
        out.rounds.push(runs[0].records[t].round);
        out.objective_mean.push(obj.mean());
        out.objective_std.push(obj.std());
        out.accuracy_mean.push(if acc.count() > 0 { acc.mean() } else { f64::NAN });
        out.accuracy_std.push(if acc.count() > 0 { acc.std() } else { f64::NAN });
        out.bits_up.push(up.mean().round() as u64);
        out.sim_time_mean.push(sim.mean());
    }
    out
}

/// Filesystem-safe series file stem: the historical `save_series`
/// replacement rule, shared by `api::CsvSink` and anything else that names
/// per-series files, so file names can never drift between paths.
pub fn safe_series_name(label: &str) -> String {
    label.replace(['/', ' ', '(', ')', '=', ','], "_")
}

/// Write one aggregated series as CSV (`results/` convention: one file per
/// algorithm per figure).
pub fn write_csv(path: &Path, agg: &Aggregated) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(
        f,
        "round,objective_mean,objective_std,accuracy_mean,accuracy_std,bits_up,sim_time_s"
    )?;
    for t in 0..agg.rounds.len() {
        writeln!(
            f,
            "{},{},{},{},{},{},{}",
            agg.rounds[t],
            agg.objective_mean[t],
            agg.objective_std[t],
            agg.accuracy_mean[t],
            agg.accuracy_std[t],
            agg.bits_up[t],
            agg.sim_time_mean[t]
        )?;
    }
    Ok(())
}

/// Write raw per-run records as CSV (for debugging / EXPERIMENTS.md).
pub fn write_runs_csv(path: &Path, runs: &[RunResult]) -> std::io::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    writeln!(
        f,
        "run,round,objective,accuracy,grad_norm_sq,bits_up,bits_down,sigma,wall_ms,\
         sim_time_s,arrived,selected,degraded"
    )?;
    for (k, run) in runs.iter().enumerate() {
        for r in &run.records {
            writeln!(
                f,
                "{},{},{},{},{},{},{},{},{},{},{},{},{}",
                k,
                r.round,
                r.objective,
                r.accuracy.unwrap_or(f64::NAN),
                r.grad_norm_sq.unwrap_or(f64::NAN),
                r.bits_up,
                r.bits_down,
                r.sigma,
                r.wall_ms,
                r.sim_time_s,
                r.arrived,
                r.selected,
                r.degraded as u8
            )?;
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_run(name: &str, objs: &[f64]) -> RunResult {
        RunResult {
            algorithm: name.into(),
            records: objs
                .iter()
                .enumerate()
                .map(|(i, &o)| RoundRecord {
                    round: i,
                    objective: o,
                    accuracy: Some(1.0 - o),
                    grad_norm_sq: None,
                    bits_up: (i as u64 + 1) * 100,
                    bits_down: 0,
                    sigma: 0.0,
                    wall_ms: 0.0,
                    sim_time_s: (i as f64 + 1.0) * 2.0,
                    arrived: 4,
                    selected: 5,
                    degraded: false,
                })
                .collect(),
        }
    }

    #[test]
    fn aggregate_mean_std() {
        let runs = vec![mk_run("a", &[1.0, 0.5]), mk_run("a", &[3.0, 1.5])];
        let agg = aggregate(&runs);
        assert_eq!(agg.objective_mean, vec![2.0, 1.0]);
        // std of {1,3} = sqrt(2)
        assert!((agg.objective_std[0] - 2f64.sqrt()).abs() < 1e-12);
        assert_eq!(agg.bits_up, vec![100, 200]);
        assert_eq!(agg.sim_time_mean, vec![2.0, 4.0]);
    }

    #[test]
    fn csv_roundtrip_format() {
        let dir = std::env::temp_dir().join("zsfa_metrics_test");
        let path = dir.join("a.csv");
        let runs = vec![mk_run("a", &[1.0, 0.5])];
        write_csv(&path, &aggregate(&runs)).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.starts_with("round,"));
        assert_eq!(body.lines().count(), 3);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn safe_series_name_pinned() {
        // File names in archived results depend on this exact rule.
        assert_eq!(safe_series_name("QSGD(s=2)"), "QSGD_s_2_");
        assert_eq!(safe_series_name("z=1 E=5, a/b"), "z_1_E_5__a_b");
        assert_eq!(safe_series_name("1-SignSGD"), "1-SignSGD");
    }

    #[test]
    #[should_panic(expected = "ragged")]
    fn ragged_repeats_rejected() {
        let runs = vec![mk_run("a", &[1.0]), mk_run("a", &[1.0, 2.0])];
        aggregate(&runs);
    }
}
