//! Procedural image-classification generators (the dataset substitutions).
//!
//! Every class gets a *prototype*: a smooth random field built from `K`
//! seeded Gaussian bumps. A sample is its class prototype under a random
//! integer translation plus i.i.d. pixel noise. The task difficulty is
//! controlled by `pixel_noise` and `max_shift`; defaults are tuned so the
//! small CNN/MLP reach high accuracy in a few hundred federated rounds
//! (mirroring MNIST's "easy but non-trivial" regime), while by-label splits
//! remain extremely heterogeneous.

use super::Dataset;
use crate::rng::Pcg64;

/// Generator configuration for one synthetic dataset.
#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub num_classes: usize,
    pub shape: (usize, usize, usize), // (h, w, c)
    pub bumps_per_class: usize,
    pub pixel_noise: f32,
    pub max_shift: i32,
    pub seed: u64,
}

impl SynthSpec {
    /// MNIST stand-in: 10 classes, 28×28×1.
    pub fn mnist() -> Self {
        SynthSpec {
            num_classes: 10,
            shape: (28, 28, 1),
            bumps_per_class: 6,
            pixel_noise: 0.25,
            max_shift: 2,
            seed: 1001,
        }
    }

    /// EMNIST stand-in: 62 classes, 28×28×1.
    pub fn emnist() -> Self {
        SynthSpec { num_classes: 62, seed: 1002, ..SynthSpec::mnist() }
    }

    /// CIFAR-10 stand-in: 10 classes, 32×32×3.
    pub fn cifar() -> Self {
        SynthSpec {
            num_classes: 10,
            shape: (32, 32, 3),
            bumps_per_class: 8,
            pixel_noise: 0.35,
            max_shift: 3,
            seed: 1003,
        }
    }
}

/// The per-class prototype fields.
pub struct Prototypes {
    spec: SynthSpec,
    /// `num_classes` images of `h*w*c` pixels.
    fields: Vec<Vec<f32>>,
}

impl Prototypes {
    pub fn build(spec: SynthSpec) -> Self {
        let (h, w, c) = spec.shape;
        let mut rng = Pcg64::new(spec.seed, 77);
        let fields = (0..spec.num_classes)
            .map(|_| {
                let mut img = vec![0.0f32; h * w * c];
                for _ in 0..spec.bumps_per_class {
                    // Random bump: center, width, sign, channel.
                    let cy = rng.uniform_in(0.15, 0.85) * h as f64;
                    let cx = rng.uniform_in(0.15, 0.85) * w as f64;
                    let sw = rng.uniform_in(1.5, h as f64 / 4.0);
                    let amp = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 }
                        * rng.uniform_in(0.6, 1.2);
                    let ch = rng.below(c as u64) as usize;
                    for y in 0..h {
                        for x in 0..w {
                            let dy = y as f64 - cy;
                            let dx = x as f64 - cx;
                            let v = amp * (-(dy * dy + dx * dx) / (2.0 * sw * sw)).exp();
                            img[(y * w + x) * c + ch] += v as f32;
                        }
                    }
                }
                img
            })
            .collect();
        Prototypes { spec, fields }
    }

    /// Render one sample of class `label` into `out` (len `h*w*c`).
    pub fn render_into(&self, label: usize, rng: &mut Pcg64, out: &mut [f32]) {
        let (h, w, c) = self.spec.shape;
        assert_eq!(out.len(), h * w * c);
        let proto = &self.fields[label];
        let s = self.spec.max_shift;
        let dy = rng.below((2 * s + 1) as u64) as i32 - s;
        let dx = rng.below((2 * s + 1) as u64) as i32 - s;
        for y in 0..h as i32 {
            for x in 0..w as i32 {
                let sy = y - dy;
                let sx = x - dx;
                for ch in 0..c {
                    let base = if sy >= 0 && sy < h as i32 && sx >= 0 && sx < w as i32 {
                        proto[((sy as usize) * w + sx as usize) * c + ch]
                    } else {
                        0.0
                    };
                    out[((y as usize) * w + x as usize) * c + ch] =
                        base + self.spec.pixel_noise * rng.normal() as f32;
                }
            }
        }
    }

    /// Generate a dataset of `n` samples with (roughly) balanced classes.
    pub fn generate(&self, n: usize, seed: u64) -> Dataset {
        let (h, w, c) = self.spec.shape;
        let len = h * w * c;
        let mut rng = Pcg64::new(seed, 13);
        let mut x = vec![0.0f32; n * len];
        let mut y = vec![0i32; n];
        for i in 0..n {
            let label = i % self.spec.num_classes; // balanced by construction
            self.render_into(label, &mut rng, &mut x[i * len..(i + 1) * len]);
            y[i] = label as i32;
        }
        // Shuffle sample order (labels move with images).
        let mut order: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut order);
        let mut xs = vec![0.0f32; n * len];
        let mut ys = vec![0i32; n];
        for (new_i, &old_i) in order.iter().enumerate() {
            xs[new_i * len..(new_i + 1) * len].copy_from_slice(&x[old_i * len..(old_i + 1) * len]);
            ys[new_i] = y[old_i];
        }
        Dataset { x: xs, y: ys, n, shape: self.spec.shape, num_classes: self.spec.num_classes }
    }
}

/// Convenience: build train+test datasets for a spec.
pub fn train_test(spec: SynthSpec, n_train: usize, n_test: usize) -> (Dataset, Dataset) {
    let protos = Prototypes::build(spec);
    let train = protos.generate(n_train, 2001);
    let test = protos.generate(n_test, 2002);
    (train, test)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn balanced_classes() {
        let (train, _) = train_test(SynthSpec::mnist(), 200, 20);
        let h = train.class_histogram();
        assert_eq!(h.iter().sum::<usize>(), 200);
        assert!(h.iter().all(|&c| c == 20), "{h:?}");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = Prototypes::build(SynthSpec::mnist()).generate(50, 9);
        let b = Prototypes::build(SynthSpec::mnist()).generate(50, 9);
        assert_eq!(a.x, b.x);
        assert_eq!(a.y, b.y);
    }

    #[test]
    fn classes_are_distinguishable() {
        // Nearest-prototype classification on noiseless renders must beat
        // chance by a wide margin — otherwise the task is unlearnable and
        // the FL experiments are meaningless.
        let mut spec = SynthSpec::mnist();
        spec.pixel_noise = 0.25;
        let protos = Prototypes::build(spec.clone());
        let ds = protos.generate(200, 5);
        let len = ds.sample_len();
        let mut correct = 0usize;
        for i in 0..ds.n {
            let img = ds.image(i);
            let mut best = (f64::INFINITY, 0usize);
            for c in 0..spec.num_classes {
                let p = &protos.fields[c];
                let dist: f64 = img
                    .iter()
                    .zip(p)
                    .map(|(a, b)| ((a - b) as f64) * ((a - b) as f64))
                    .sum();
                if dist < best.0 {
                    best = (dist, c);
                }
            }
            if best.1 == ds.y[i] as usize {
                correct += 1;
            }
        }
        let acc = correct as f64 / ds.n as f64;
        assert!(acc > 0.8, "nearest-prototype acc={acc}, len={len}");
    }

    #[test]
    fn cifar_shape() {
        let (train, test) = train_test(SynthSpec::cifar(), 30, 10);
        assert_eq!(train.shape, (32, 32, 3));
        assert_eq!(train.sample_len(), 32 * 32 * 3);
        assert_eq!(test.n, 10);
    }

    #[test]
    fn emnist_has_62_classes() {
        let (train, _) = train_test(SynthSpec::emnist(), 124, 62);
        assert_eq!(train.num_classes, 62);
        assert_eq!(train.class_histogram().len(), 62);
    }
}
