//! Synthetic federated datasets + partitioners.
//!
//! The build environment has no MNIST/EMNIST/CIFAR files, so per the
//! substitution rule (DESIGN.md §3) we synthesize procedurally-generated
//! image-classification tasks with the same shapes, class counts and split
//! semantics as the paper's workloads:
//!
//! * each class gets a smooth random *prototype* image (a sum of seeded
//!   Gaussian bumps), and samples are prototypes under random translation
//!   plus pixel noise — a learnable task whose classes are visually
//!   distinct, so the paper's extreme "one label per client" split is
//!   genuinely heterogeneous;
//! * [`partition`] implements the paper's three splits: by-label (§4.2),
//!   symmetric Dirichlet(α) (§4.3 CIFAR) and iid shards (§4.3 EMNIST-style
//!   many-client sharding).

pub mod partition;
pub mod synth;

/// A dense in-memory classification dataset (NHWC, f32 in [0,1]-ish range).
#[derive(Debug, Clone)]
pub struct Dataset {
    /// Flattened images, `n * h * w * c`.
    pub x: Vec<f32>,
    /// Class labels in [0, num_classes).
    pub y: Vec<i32>,
    pub n: usize,
    pub shape: (usize, usize, usize), // (h, w, c)
    pub num_classes: usize,
}

impl Dataset {
    pub fn sample_len(&self) -> usize {
        let (h, w, c) = self.shape;
        h * w * c
    }

    /// Borrow sample `i` as a flat pixel slice.
    pub fn image(&self, i: usize) -> &[f32] {
        let l = self.sample_len();
        &self.x[i * l..(i + 1) * l]
    }

    /// Copy samples at `idx` into NHWC batch buffers.
    pub fn gather_into(&self, idx: &[usize], x_out: &mut [f32], y_out: &mut [i32]) {
        let l = self.sample_len();
        assert_eq!(x_out.len(), idx.len() * l);
        assert_eq!(y_out.len(), idx.len());
        for (k, &i) in idx.iter().enumerate() {
            x_out[k * l..(k + 1) * l].copy_from_slice(self.image(i));
            y_out[k] = self.y[i];
        }
    }

    /// Per-class sample counts (for partition diagnostics).
    pub fn class_histogram(&self) -> Vec<usize> {
        let mut h = vec![0usize; self.num_classes];
        for &y in &self.y {
            h[y as usize] += 1;
        }
        h
    }
}

/// A dataset plus the per-client index assignment produced by a partitioner.
#[derive(Debug, Clone)]
pub struct FederatedDataset {
    pub data: Dataset,
    /// `clients[i]` = indices into `data` owned by client i.
    pub clients: Vec<Vec<usize>>,
}

impl FederatedDataset {
    pub fn num_clients(&self) -> usize {
        self.clients.len()
    }

    /// Sample a training batch (with replacement — local datasets can be
    /// smaller than E·B) for `client` into the provided buffers.
    pub fn sample_batch(&self, client: usize, batch: usize,
                        rng: &mut crate::rng::Pcg64,
                        x_out: &mut [f32], y_out: &mut [i32]) {
        let idxs = &self.clients[client];
        assert!(!idxs.is_empty(), "client {client} has no data");
        let chosen: Vec<usize> =
            (0..batch).map(|_| idxs[rng.below(idxs.len() as u64) as usize]).collect();
        self.data.gather_into(&chosen, x_out, y_out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset {
            x: (0..2 * 4).map(|i| i as f32).collect(),
            y: vec![0, 1],
            n: 2,
            shape: (2, 2, 1),
            num_classes: 2,
        }
    }

    #[test]
    fn image_slicing() {
        let d = tiny();
        assert_eq!(d.image(0), &[0.0, 1.0, 2.0, 3.0]);
        assert_eq!(d.image(1), &[4.0, 5.0, 6.0, 7.0]);
    }

    #[test]
    fn gather_batches() {
        let d = tiny();
        let mut x = vec![0.0; 8];
        let mut y = vec![0; 2];
        d.gather_into(&[1, 0], &mut x, &mut y);
        assert_eq!(&x[..4], &[4.0, 5.0, 6.0, 7.0]);
        assert_eq!(y, vec![1, 0]);
    }

    #[test]
    fn histogram() {
        let d = tiny();
        assert_eq!(d.class_histogram(), vec![1, 1]);
    }
}
