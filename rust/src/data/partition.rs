//! Client partitioners: how training samples are assigned to clients.
//!
//! Mirrors the paper's three settings:
//! * [`by_label`] — §4.2's extreme non-iid split (client i holds only
//!   class i mod C);
//! * [`dirichlet`] — §4.3's CIFAR-10 split (each client's label
//!   distribution drawn from a symmetric Dirichlet(α));
//! * [`iid`] — uniform random shards (and the EMNIST many-client setting,
//!   where 3579 clients each hold a small shard).
//!
//! Invariant (property-tested): every sample is assigned to exactly one
//! client and no client is empty.

use super::{Dataset, FederatedDataset};
use crate::rng::Pcg64;

/// Extreme label split: client i receives all samples with label ≡ i (mod C).
/// Requires `n_clients >= num_classes` to be meaningful; with
/// `n_clients == num_classes` this is exactly the paper's §4.2 setting.
pub fn by_label(data: Dataset, n_clients: usize) -> FederatedDataset {
    assert!(n_clients >= 1);
    let c = data.num_classes;
    let mut clients: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    // Samples of class k rotate over clients {k, k+c, k+2c, ...}.
    let mut next_holder: Vec<usize> = (0..c).collect();
    for (i, &y) in data.y.iter().enumerate() {
        let k = y as usize;
        let holder = next_holder[k] % n_clients;
        clients[holder].push(i);
        // Advance to the next client that serves this class.
        next_holder[k] = if n_clients > c { next_holder[k] + c } else { next_holder[k] };
        if n_clients > c && next_holder[k] >= n_clients {
            next_holder[k] = k;
        }
    }
    FederatedDataset { data, clients }
}

/// iid shards: shuffle, split as evenly as possible.
pub fn iid(data: Dataset, n_clients: usize, seed: u64) -> FederatedDataset {
    assert!(n_clients >= 1 && n_clients <= data.n);
    let mut order: Vec<usize> = (0..data.n).collect();
    Pcg64::new(seed, 3).shuffle(&mut order);
    let mut clients: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for (k, idx) in order.into_iter().enumerate() {
        clients[k % n_clients].push(idx);
    }
    FederatedDataset { data, clients }
}

/// Symmetric Dirichlet(α) label skew (Reddi et al. '20 / the paper's §4.3):
/// for each class, the class's samples are distributed over clients with
/// proportions drawn from Dirichlet(α). Small α → near one-label clients;
/// α = 1 matches the paper's CIFAR-10 setting.
pub fn dirichlet(data: Dataset, n_clients: usize, alpha: f64, seed: u64) -> FederatedDataset {
    assert!(n_clients >= 1);
    assert!(alpha > 0.0);
    let mut rng = Pcg64::new(seed, 5);
    let c = data.num_classes;
    // Bucket sample indices per class.
    let mut per_class: Vec<Vec<usize>> = vec![Vec::new(); c];
    for (i, &y) in data.y.iter().enumerate() {
        per_class[y as usize].push(i);
    }
    let mut clients: Vec<Vec<usize>> = vec![Vec::new(); n_clients];
    for idxs in per_class.into_iter() {
        // Dirichlet via normalized Gammas.
        let mut props: Vec<f64> = (0..n_clients).map(|_| rng.gamma(alpha, 1.0)).collect();
        let total: f64 = props.iter().sum();
        props.iter_mut().for_each(|p| *p /= total);
        // Convert proportions to cumulative cut points over this class.
        let m = idxs.len();
        let mut cuts = Vec::with_capacity(n_clients);
        let mut acc = 0.0;
        for p in &props {
            acc += p;
            cuts.push((acc * m as f64).round() as usize);
        }
        *cuts.last_mut().unwrap() = m; // rounding-proof
        let mut start = 0usize;
        for (k, &end) in cuts.iter().enumerate() {
            let end = end.clamp(start, m);
            clients[k].extend_from_slice(&idxs[start..end]);
            start = end;
        }
    }
    // Guarantee non-empty clients: steal one sample from the largest client.
    for k in 0..n_clients {
        if clients[k].is_empty() {
            let donor = (0..n_clients).max_by_key(|&j| clients[j].len()).unwrap();
            assert!(clients[donor].len() > 1, "not enough samples for {n_clients} clients");
            let taken = clients[donor].pop().unwrap();
            clients[k].push(taken);
        }
    }
    FederatedDataset { data, clients }
}

/// Partition diagnostics: per-client label entropy (0 = single-label client).
pub fn mean_label_entropy(fed: &FederatedDataset) -> f64 {
    let c = fed.data.num_classes;
    let mut total = 0.0;
    for idxs in &fed.clients {
        let mut h = vec![0usize; c];
        for &i in idxs {
            h[fed.data.y[i] as usize] += 1;
        }
        let n = idxs.len() as f64;
        let mut ent = 0.0;
        for &cnt in &h {
            if cnt > 0 {
                let p = cnt as f64 / n;
                ent -= p * p.ln();
            }
        }
        total += ent;
    }
    total / fed.clients.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::{train_test, SynthSpec};
    use crate::testutil::{prop_check, PropConfig};

    fn check_exact_cover(fed: &FederatedDataset) {
        let mut seen = vec![false; fed.data.n];
        for idxs in &fed.clients {
            assert!(!idxs.is_empty(), "empty client");
            for &i in idxs {
                assert!(!seen[i], "sample {i} assigned twice");
                seen[i] = true;
            }
        }
        assert!(seen.iter().all(|&s| s), "unassigned samples");
    }

    #[test]
    fn by_label_single_class_clients() {
        let (train, _) = train_test(SynthSpec::mnist(), 200, 10);
        let fed = by_label(train, 10);
        check_exact_cover(&fed);
        for (i, idxs) in fed.clients.iter().enumerate() {
            assert!(idxs.iter().all(|&k| fed.data.y[k] as usize == i));
        }
        assert!(mean_label_entropy(&fed) < 1e-9);
    }

    #[test]
    fn iid_partition_covers() {
        let (train, _) = train_test(SynthSpec::mnist(), 103, 10);
        let fed = iid(train, 7, 1);
        check_exact_cover(&fed);
        // Near-even shard sizes.
        let sizes: Vec<usize> = fed.clients.iter().map(|c| c.len()).collect();
        assert!(sizes.iter().max().unwrap() - sizes.iter().min().unwrap() <= 1);
        // iid shards should have high label entropy.
        assert!(mean_label_entropy(&fed) > 1.5);
    }

    #[test]
    fn dirichlet_property_exact_cover() {
        let (train, _) = train_test(SynthSpec::mnist(), 300, 10);
        prop_check(
            PropConfig { cases: 25, max_size: 20, ..Default::default() },
            |rng, size| {
                let n_clients = 2 + size.min(18);
                let alpha = [0.1, 0.5, 1.0, 10.0][rng.below(4) as usize];
                (n_clients, alpha, rng.next_u64())
            },
            |&(n_clients, alpha, seed)| {
                let fed = dirichlet(train.clone(), n_clients, alpha, seed);
                let mut seen = vec![false; fed.data.n];
                for idxs in &fed.clients {
                    if idxs.is_empty() {
                        return Err("empty client".into());
                    }
                    for &i in idxs {
                        if seen[i] {
                            return Err(format!("sample {i} assigned twice"));
                        }
                        seen[i] = true;
                    }
                }
                if !seen.iter().all(|&s| s) {
                    return Err("unassigned samples".into());
                }
                Ok(())
            },
        );
    }

    #[test]
    fn dirichlet_alpha_controls_skew() {
        let (train, _) = train_test(SynthSpec::mnist(), 1000, 10);
        let skewed = mean_label_entropy(&dirichlet(train.clone(), 10, 0.05, 3));
        let uniform = mean_label_entropy(&dirichlet(train, 10, 100.0, 3));
        assert!(
            skewed < uniform - 0.5,
            "skewed={skewed} uniform={uniform}"
        );
    }

    #[test]
    fn by_label_more_clients_than_classes() {
        let (train, _) = train_test(SynthSpec::mnist(), 400, 10);
        let fed = by_label(train, 40);
        check_exact_cover(&fed);
        // Every client still holds exactly one label.
        for idxs in &fed.clients {
            let labels: std::collections::BTreeSet<i32> =
                idxs.iter().map(|&k| fed.data.y[k]).collect();
            assert_eq!(labels.len(), 1);
        }
    }
}
