//! 1-bit wire codec: sign vectors packed into `u64` words.
//!
//! The uplink of every sign-based algorithm is exactly `d` bits per client
//! per round (Table 2 of the paper). This module owns that wire format plus
//! the server-side *vote accumulator*: the FL server needs
//! `sum_i Sign_i[j]` over n clients for every coordinate j, which is
//! computed word-by-word with popcount-style bit tricks instead of
//! unpacking to bytes (see `benches/bench_aggregate.rs` for the payoff).
//!
//! Bit convention: bit = 1 encodes +1, bit = 0 encodes −1; coordinate `j`
//! lives at word `j / 64`, bit `j % 64`. Trailing bits of the last word are
//! zero (i.e. decode as −1) and are never read back because the logical
//! length is stored alongside.
//!
//! The three inner loops that dominate the server hot path — the carry-save
//! plane add, the plane→counts spill and the scaled sign decode — route
//! through the runtime-dispatched [`super::simd::SignKernels`] table
//! (AVX2 / NEON / scalar, `ZSFA_SIMD` override); every backend is pinned
//! bit-identical to the scalar reference by `tests/hotpath_exactness.rs`.

use super::simd;

/// Number of carry-save planes in [`VoteAccumulator`]: column counters
/// saturate at 2^PLANES − 1, which sets the spill batch. Fixed by the
/// SIMD spill kernels, which hard-code the 4-plane column expansion.
const VOTE_PLANES: usize = simd::PLANES;

/// A packed ±1 sign vector (`len` logical coordinates).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedSigns {
    words: Vec<u64>,
    len: usize,
}

impl PackedSigns {
    /// Pack from an i8 sign buffer (entries must be ±1; 0 is rejected in
    /// debug builds — the paper's Sign never emits 0).
    pub fn from_signs(signs: &[i8]) -> Self {
        let mut words = vec![0u64; signs.len().div_ceil(64)];
        for (j, &s) in signs.iter().enumerate() {
            debug_assert!(s == 1 || s == -1, "sign must be ±1, got {s}");
            if s > 0 {
                words[j / 64] |= 1u64 << (j % 64);
            }
        }
        PackedSigns { words, len: signs.len() }
    }

    /// Pack directly from the sign of an f32 buffer (Sign(x) with Sign(0)=+1).
    pub fn from_f32_signs(x: &[f32]) -> Self {
        let mut p = PackedSigns::zeroed(x.len());
        super::kernel::pack_f32_signs_into(x, &mut p);
        p
    }

    /// An all-(−1) buffer of `len` coordinates, intended for reuse through
    /// [`PackedSigns::reset_for`] by the fused kernels (`compress::kernel`).
    pub fn zeroed(len: usize) -> Self {
        PackedSigns { words: vec![0u64; len.div_ceil(64)], len }
    }

    /// Re-shape for `len` coordinates and zero every word. Allocates only
    /// when `len` grows past any previous capacity — the reuse seam that
    /// keeps per-client compression allocation-free in the round loop.
    pub fn reset_for(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(len.div_ceil(64), 0);
        self.len = len;
    }

    /// Mutable word access for the fused kernels. Invariant to uphold:
    /// trailing bits of the last word beyond `len` must stay zero.
    pub(crate) fn words_mut(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Build from u32 words as emitted by the AOT packed-compress artifact
    /// (`model.pack_signs_u32`): coordinate j lives at u32 word j/32, bit
    /// j%32. Trailing bits beyond `len` are masked to preserve the
    /// `count_plus` invariant even if the producer set them.
    pub fn from_u32_words(words32: &[u32], len: usize) -> Self {
        assert_eq!(words32.len(), len.div_ceil(32), "word count mismatch for len={len}");
        let mut words = vec![0u64; len.div_ceil(64)];
        for (k, &w32) in words32.iter().enumerate() {
            words[k / 2] |= (w32 as u64) << (32 * (k % 2));
        }
        // Mask trailing bits.
        if len % 64 != 0 {
            if let Some(last) = words.last_mut() {
                *last &= (1u64 << (len % 64)) - 1;
            }
        }
        PackedSigns { words, len }
    }

    /// Number of logical coordinates (== bits on the wire).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The backing words (trailing bits zero).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Sign of coordinate `j` as ±1.
    pub fn get(&self, j: usize) -> i8 {
        assert!(j < self.len);
        if self.words[j / 64] >> (j % 64) & 1 == 1 {
            1
        } else {
            -1
        }
    }

    /// Unpack into an i8 buffer.
    pub fn unpack_into(&self, out: &mut [i8]) {
        assert_eq!(out.len(), self.len);
        for (j, o) in out.iter_mut().enumerate() {
            *o = if self.words[j / 64] >> (j % 64) & 1 == 1 { 1 } else { -1 };
        }
    }

    /// Number of +1 entries (popcount over all words; trailing bits are 0).
    pub fn count_plus(&self) -> usize {
        self.words.iter().map(|w| w.count_ones() as usize).sum()
    }

    /// Write `±scale` per coordinate directly from the packed words —
    /// bit-identical to unpacking to i8 and multiplying (`scale * 1.0` is
    /// `scale`, `scale * -1.0` is the exact IEEE negation), without the i8
    /// round-trip.
    pub fn decode_scaled_into(&self, scale: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        simd::active().decode_scaled(&self.words, scale, out);
    }
}

/// Server-side sign-vote accumulator.
///
/// Accumulates `sum_i s_i[j]` (each `s_i[j] ∈ {−1,+1}`) for n clients,
/// Harley–Seal style: incoming packed words are folded into four bit-sliced
/// carry-save planes (`ones/twos/fours/eights` — 64 independent 4-bit
/// column counters per machine word, 8 SWAR ops per 64 votes), and the
/// planes spill into the exact per-coordinate `i32` counts only every
/// [`VoteAccumulator::SPILL_BATCH`] clients. That replaces the pre-CSA
/// per-client blanket decrement + set-bit walk (which touched the whole
/// 4·d-byte count buffer for every client) with d/8 bytes of plane traffic
/// per client plus an amortized expansion — see `benches/bench_aggregate.rs`
/// for the measured ratio. All arithmetic is exact integers, so spill
/// timing, shard merging and lane order can never change the result.
#[derive(Debug, Clone)]
pub struct VoteAccumulator {
    counts: Vec<i32>, // sum of ±1 votes per coordinate (spilled state)
    /// Carry-save planes: plane p holds bit p of each coordinate's count of
    /// still-unspilled +1 votes. Trailing bits beyond `len` stay zero
    /// because every absorbed `PackedSigns` keeps them zero.
    planes: [Vec<u64>; VOTE_PLANES],
    /// Clients folded into the planes since the last spill (≤ SPILL_BATCH).
    pending: u32,
    n: u32,
    len: usize,
}

impl VoteAccumulator {
    /// Clients per carry-save batch: 4 planes hold column counts up to 15.
    pub const SPILL_BATCH: u32 = (1 << VOTE_PLANES) - 1;

    pub fn new(len: usize) -> Self {
        let nw = len.div_ceil(64);
        VoteAccumulator {
            counts: vec![0; len],
            planes: std::array::from_fn(|_| vec![0u64; nw]),
            pending: 0,
            n: 0,
            len,
        }
    }

    pub fn reset(&mut self) {
        self.counts.iter_mut().for_each(|c| *c = 0);
        for p in self.planes.iter_mut() {
            p.iter_mut().for_each(|w| *w = 0);
        }
        self.pending = 0;
        self.n = 0;
    }

    pub fn num_votes(&self) -> u32 {
        self.n
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Add one client's packed signs: counts[j] += ±1.
    ///
    /// Carry-save add: ripple the incoming word through the planes
    /// (`sum = a ^ b`, `carry = a & b` per plane). With at most
    /// `SPILL_BATCH = 15` pending clients a column counter never exceeds
    /// 15, so no carry ever leaves the top plane before the spill.
    pub fn add(&mut self, signs: &PackedSigns) {
        assert_eq!(signs.len(), self.len, "vote length mismatch");
        simd::active().csa_add(&mut self.planes, &signs.words);
        self.pending += 1;
        self.n += 1;
        if self.pending == Self::SPILL_BATCH {
            self.spill();
        }
    }

    /// Expand `pending` clients' worth of planes into `counts`: a column
    /// with `plus` set bits contributes `2·plus − pending` (each of the
    /// `pending` votes is +1 or −1). Runs once per batch, so the blanket
    /// `− pending` replaces the old per-client blanket decrement.
    fn spill_planes_into(planes: &[Vec<u64>; VOTE_PLANES], pending: u32, counts: &mut [i32]) {
        simd::active().spill_counts(planes, pending, counts);
    }

    /// Spill the carry-save planes into the exact counts and clear them.
    fn spill(&mut self) {
        Self::spill_planes_into(&self.planes, self.pending, &mut self.counts);
        for p in self.planes.iter_mut() {
            p.iter_mut().for_each(|w| *w = 0);
        }
        self.pending = 0;
    }

    /// Fold another accumulator's votes into this one (shard reduction).
    ///
    /// The parallel round engine gives each worker thread its own shard and
    /// reduces them here; vote counts are integers, so the merge is exact
    /// and order-independent — the foundation of the engine's bit-exact
    /// determinism guarantee across thread counts. `other`'s unspilled
    /// planes are expanded on the fly without mutating it.
    pub fn merge(&mut self, other: &VoteAccumulator) {
        assert_eq!(other.len, self.len, "vote length mismatch");
        self.spill();
        for (c, &o) in self.counts.iter_mut().zip(&other.counts) {
            *c += o;
        }
        Self::spill_planes_into(&other.planes, other.pending, &mut self.counts);
        self.n += other.n;
    }

    /// The raw vote counts (`sum_i s_i[j]`); spills any pending batch first.
    pub fn counts(&mut self) -> &[i32] {
        self.spill();
        &self.counts
    }

    /// Write `scale * mean_vote[j]` into `out` — the server's dequantized
    /// aggregate `η_z σ · (1/n) Σ_i Sign(...)` (Algorithm 1, line 15 folds
    /// the η·γ stepsize into `scale`).
    pub fn mean_into(&mut self, scale: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        assert!(self.n > 0, "no votes accumulated");
        self.spill();
        let k = scale / self.n as f32;
        for (o, &c) in out.iter_mut().zip(&self.counts) {
            *o = k * c as f32;
        }
    }

    /// [`VoteAccumulator::mean_into`] with a coordinate-wise trimmed-count
    /// majority: each tally is soft-thresholded toward zero by `2·trim`
    /// before averaging, i.e. `c → sign(c)·max(0, |c| − 2·trim)`. One
    /// Byzantine voter can move a ±1 tally by at most 2, so `trim = k`
    /// exactly neutralizes any k sign-flipping clients on coordinates where
    /// the honest margin exceeds them (arXiv 2210.00665's robust one-bit
    /// aggregation, expressed on exact integer counts). `trim = 0` is
    /// bit-identical to `mean_into`.
    pub fn trimmed_mean_into(&mut self, trim: u32, scale: f32, out: &mut [f32]) {
        assert_eq!(out.len(), self.len);
        assert!(self.n > 0, "no votes accumulated");
        self.spill();
        let k = scale / self.n as f32;
        let cut = (trim as i64 * 2).min(i32::MAX as i64) as i32;
        for (o, &c) in out.iter_mut().zip(&self.counts) {
            let t = (c.abs().max(cut) - cut) * c.signum();
            *o = k * t as f32;
        }
    }

    /// Majority-vote signs (used by the SignSGD-with-majority-vote ablation;
    /// ties resolve to +1, consistent with Sign(0) = +1). Builds the packed
    /// words straight from the counts — no i8 round-trip.
    pub fn majority(&mut self) -> PackedSigns {
        self.spill();
        let mut out = PackedSigns::zeroed(self.len);
        for (w, chunk) in out.words.iter_mut().zip(self.counts.chunks(64)) {
            for (b, &c) in chunk.iter().enumerate() {
                *w |= ((c >= 0) as u64) << b;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn random_signs(rng: &mut Pcg64, d: usize) -> Vec<i8> {
        (0..d).map(|_| if rng.next_u64() & 1 == 0 { 1i8 } else { -1 }).collect()
    }

    #[test]
    fn roundtrip_exact() {
        let mut rng = Pcg64::seeded(1);
        for d in [0usize, 1, 63, 64, 65, 127, 128, 1000, 4096] {
            let s = random_signs(&mut rng, d);
            let p = PackedSigns::from_signs(&s);
            assert_eq!(p.len(), d);
            let mut out = vec![0i8; d];
            p.unpack_into(&mut out);
            assert_eq!(out, s, "d={d}");
        }
    }

    #[test]
    fn from_u32_words_matches_from_signs() {
        let mut rng = Pcg64::seeded(9);
        for d in [1usize, 31, 32, 33, 63, 64, 65, 257, 4096] {
            let s = random_signs(&mut rng, d);
            let want = PackedSigns::from_signs(&s);
            // Build the u32 view manually.
            let mut w32 = vec![0u32; d.div_ceil(32)];
            for (j, &v) in s.iter().enumerate() {
                if v > 0 {
                    w32[j / 32] |= 1 << (j % 32);
                }
            }
            let got = PackedSigns::from_u32_words(&w32, d);
            assert_eq!(got, want, "d={d}");
        }
    }

    #[test]
    fn from_u32_words_masks_trailing_garbage() {
        // Producer sets a trailing bit beyond len: it must be cleared.
        let got = PackedSigns::from_u32_words(&[0xffff_ffff], 3);
        assert_eq!(got.count_plus(), 3);
        assert_eq!(got.get(0), 1);
    }

    #[test]
    fn get_matches_unpack() {
        let mut rng = Pcg64::seeded(2);
        let s = random_signs(&mut rng, 257);
        let p = PackedSigns::from_signs(&s);
        for (j, &want) in s.iter().enumerate() {
            assert_eq!(p.get(j), want);
        }
    }

    #[test]
    fn from_f32_sign_zero_is_plus() {
        let p = PackedSigns::from_f32_signs(&[0.0, -0.0, -1.0, 2.0]);
        assert_eq!(p.get(0), 1);
        assert_eq!(p.get(1), 1); // -0.0 >= 0.0
        assert_eq!(p.get(2), -1);
        assert_eq!(p.get(3), 1);
    }

    #[test]
    fn vote_accumulator_matches_naive() {
        let mut rng = Pcg64::seeded(3);
        let d = 513;
        let n = 9;
        let mut acc = VoteAccumulator::new(d);
        let mut naive = vec![0i32; d];
        for _ in 0..n {
            let s = random_signs(&mut rng, d);
            for (j, &v) in s.iter().enumerate() {
                naive[j] += v as i32;
            }
            acc.add(&PackedSigns::from_signs(&s));
        }
        assert_eq!(acc.counts(), &naive[..]);
        assert_eq!(acc.num_votes(), n as u32);
    }

    #[test]
    fn csa_spill_batches_match_naive_counts() {
        // n sweeps through 3× the carry-save batch so adds cross several
        // spill boundaries; reads mid-batch must flush exactly.
        let b = VoteAccumulator::SPILL_BATCH as usize;
        let mut rng = Pcg64::seeded(71);
        for d in [1usize, 63, 64, 65, 127, 128, 1000] {
            let mut acc = VoteAccumulator::new(d);
            let mut naive = vec![0i32; d];
            for i in 1..=3 * b {
                let s = random_signs(&mut rng, d);
                for (c, &v) in naive.iter_mut().zip(&s) {
                    *c += v as i32;
                }
                acc.add(&PackedSigns::from_signs(&s));
                if i % 7 == 0 || i % b == 0 {
                    assert_eq!(acc.counts(), &naive[..], "d={d} after {i} adds");
                }
            }
            assert_eq!(acc.counts(), &naive[..], "d={d} final");
            assert_eq!(acc.num_votes(), (3 * b) as u32);
        }
    }

    #[test]
    fn trimmed_mean_zero_trim_is_bit_identical_to_mean() {
        let mut rng = Pcg64::seeded(44);
        let d = 257;
        let mut acc = VoteAccumulator::new(d);
        for _ in 0..9 {
            acc.add(&PackedSigns::from_signs(&random_signs(&mut rng, d)));
        }
        let mut want = vec![0.0f32; d];
        let mut got = vec![0.0f32; d];
        acc.mean_into(0.75, &mut want);
        acc.trimmed_mean_into(0, 0.75, &mut got);
        for (j, (w, g)) in want.iter().zip(&got).enumerate() {
            assert_eq!(w.to_bits(), g.to_bits(), "j={j}");
        }
    }

    #[test]
    fn trimmed_mean_soft_thresholds_the_tallies() {
        // 7 voters, per-coordinate tallies by construction: +7 (unanimous),
        // +1 (weak margin), -3, 0 is impossible with odd n so use -7.
        let d = 4;
        let votes: [[i8; 4]; 7] = [
            [1, 1, -1, -1],
            [1, 1, -1, -1],
            [1, -1, -1, -1],
            [1, -1, 1, -1],
            [1, -1, 1, -1],
            [1, 1, -1, -1],
            [1, 1, -1, -1],
        ];
        let mut acc = VoteAccumulator::new(d);
        for v in &votes {
            acc.add(&PackedSigns::from_signs(v));
        }
        assert_eq!(acc.counts(), &[7, 1, -3, -7]);
        let mut out = vec![0.0f32; d];
        // trim = 1 → cut 2: tallies shrink toward zero by 2, floored at 0.
        acc.trimmed_mean_into(1, 7.0, &mut out);
        assert_eq!(out, vec![5.0, 0.0, -1.0, -5.0]);
        // trim = 2 → cut 4 kills everything with |tally| <= 4.
        acc.trimmed_mean_into(2, 7.0, &mut out);
        assert_eq!(out, vec![3.0, 0.0, 0.0, -3.0]);
    }

    #[test]
    fn trimmed_mean_neutralizes_k_sign_flippers() {
        // d=1: 9 honest +1 votes plus k=2 flipped (-1) votes. The honest
        // margin is 9-2=7; trimming 2 recovers a strictly positive mean on
        // every coordinate the honest majority carries.
        let mut acc = VoteAccumulator::new(1);
        for _ in 0..9 {
            acc.add(&PackedSigns::from_signs(&[1]));
        }
        for _ in 0..2 {
            acc.add(&PackedSigns::from_signs(&[-1]));
        }
        let mut out = [0.0f32];
        acc.trimmed_mean_into(2, 11.0, &mut out);
        // tally 7, cut 4 → 3; the flippers' pull (and as much honest
        // signal) is clipped away, sign preserved.
        assert_eq!(out[0], 3.0);
    }

    #[test]
    fn merge_flushes_pending_batches_on_both_sides() {
        // Merge with unspilled planes on self *and* other must equal the
        // sequential accumulation (merge expands other without mutating it).
        let mut rng = Pcg64::seeded(72);
        let d = 130;
        let signs: Vec<PackedSigns> =
            (0..11).map(|_| PackedSigns::from_signs(&random_signs(&mut rng, d))).collect();
        let mut want = VoteAccumulator::new(d);
        for s in &signs {
            want.add(s);
        }
        let mut a = VoteAccumulator::new(d);
        let mut b = VoteAccumulator::new(d);
        for s in &signs[..4] {
            a.add(s); // 4 pending, below the spill batch
        }
        for s in &signs[4..] {
            b.add(s); // 7 pending
        }
        let b_counts_before: Vec<i32> = {
            let mut probe = b.clone();
            probe.counts().to_vec()
        };
        a.merge(&b);
        assert_eq!(a.counts(), want.counts());
        assert_eq!(a.num_votes(), 11);
        // `other` was not mutated by the merge.
        let mut b_after = b.clone();
        assert_eq!(b_after.counts(), &b_counts_before[..]);
    }

    #[test]
    fn decode_scaled_matches_unpack_multiply() {
        let mut rng = Pcg64::seeded(73);
        for d in [0usize, 1, 64, 65, 257] {
            for scale in [0.0f32, 1.5, -0.25] {
                let s = random_signs(&mut rng, d);
                let p = PackedSigns::from_signs(&s);
                let mut got = vec![0.0f32; d];
                p.decode_scaled_into(scale, &mut got);
                for (j, (&g, &si)) in got.iter().zip(&s).enumerate() {
                    let want = scale * si as f32;
                    assert_eq!(g.to_bits(), want.to_bits(), "d={d} scale={scale} j={j}");
                }
            }
        }
    }

    #[test]
    fn reset_for_reuses_and_zeroes() {
        let mut p = PackedSigns::from_signs(&[1, 1, 1]);
        assert_eq!(p.count_plus(), 3);
        p.reset_for(130);
        assert_eq!(p.len(), 130);
        assert_eq!(p.count_plus(), 0);
        assert_eq!(p.words().len(), 3);
        p.reset_for(2);
        assert_eq!(p.len(), 2);
        assert_eq!(p.words().len(), 1);
    }

    #[test]
    fn merge_matches_sequential_accumulation() {
        // Shard-merge over random sign vectors must equal one sequential
        // accumulator, for any split of the clients across shards.
        let mut rng = Pcg64::seeded(17);
        let d = 257;
        let n = 23;
        let signs: Vec<PackedSigns> =
            (0..n).map(|_| PackedSigns::from_signs(&random_signs(&mut rng, d))).collect();
        let mut sequential = VoteAccumulator::new(d);
        for s in &signs {
            sequential.add(s);
        }
        for shards in [1usize, 2, 5, 23] {
            let mut parts: Vec<VoteAccumulator> =
                (0..shards).map(|_| VoteAccumulator::new(d)).collect();
            for (i, s) in signs.iter().enumerate() {
                parts[i % shards].add(s);
            }
            let mut merged = VoteAccumulator::new(d);
            for p in &parts {
                merged.merge(p);
            }
            assert_eq!(merged.counts(), sequential.counts(), "shards={shards}");
            assert_eq!(merged.num_votes(), sequential.num_votes());
        }
    }

    #[test]
    fn merge_is_associative() {
        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c): integer counts make the shard merge
        // associative, which is what lets the aggregation seam fold lane
        // accumulators in any grouping without changing the result.
        let mut rng = Pcg64::seeded(41);
        let d = 129;
        let mk = |rng: &mut Pcg64, k: usize| {
            let mut acc = VoteAccumulator::new(d);
            for _ in 0..k {
                acc.add(&PackedSigns::from_signs(&random_signs(rng, d)));
            }
            acc
        };
        let (a, b, c) = (mk(&mut rng, 3), mk(&mut rng, 1), mk(&mut rng, 4));
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left.counts(), right.counts());
        assert_eq!(left.num_votes(), right.num_votes());
    }

    #[test]
    #[should_panic(expected = "vote length mismatch")]
    fn merge_rejects_length_mismatch() {
        let mut a = VoteAccumulator::new(4);
        let b = VoteAccumulator::new(5);
        a.merge(&b);
    }

    #[test]
    fn mean_into_scales() {
        let mut acc = VoteAccumulator::new(3);
        acc.add(&PackedSigns::from_signs(&[1, -1, 1]));
        acc.add(&PackedSigns::from_signs(&[1, -1, -1]));
        let mut out = vec![0.0f32; 3];
        acc.mean_into(2.0, &mut out);
        assert_eq!(out, [2.0, -2.0, 0.0]);
    }

    #[test]
    fn majority_ties_to_plus() {
        let mut acc = VoteAccumulator::new(2);
        acc.add(&PackedSigns::from_signs(&[1, -1]));
        acc.add(&PackedSigns::from_signs(&[-1, -1]));
        let m = acc.majority();
        assert_eq!(m.get(0), 1); // tie
        assert_eq!(m.get(1), -1);
    }

    #[test]
    fn count_plus() {
        let p = PackedSigns::from_signs(&[1, 1, -1, 1]);
        assert_eq!(p.count_plus(), 3);
    }

    #[test]
    fn reset_clears() {
        let mut acc = VoteAccumulator::new(4);
        acc.add(&PackedSigns::from_signs(&[1, 1, 1, 1]));
        acc.reset();
        assert_eq!(acc.num_votes(), 0);
        assert!(acc.counts().iter().all(|&c| c == 0));
    }
}
