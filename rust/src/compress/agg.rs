//! The unified streaming aggregation seam: one [`Aggregator`] per
//! compressor family, absorbing client messages *as they arrive* into
//! lane-sharded state and reducing through a fixed, parallelism-independent
//! topology.
//!
//! ## Why a seam
//!
//! The round reduce used to be two code paths: packed-sign votes streamed
//! through worker-sharded `VoteAccumulator`s, while every dense-family
//! compressor (None/QSGD/TopK/SparseSign/DP-dense/EF) buffered one decoded
//! vector **per client** until end-of-round so the f32 fold could run in
//! participant order — an O(m·d) high-water mark that caps cohort size.
//! This module replaces both with one abstraction: per-compressor
//! [`Aggregator`]s that fold each client's contribution into a
//! [`LaneAcc`] the moment it is produced, so peak aggregation memory is
//! O(L·d) for L = [`ReduceTopology`] lanes — independent of the cohort
//! size m.
//!
//! ## The reduction-topology contract
//!
//! The aggregate is a pure function of the participant slots and the lane
//! count L (`ServerConfig::reduce_lanes`), never of thread count or
//! scheduling:
//!
//! * slot `s` folds into lane `s mod L`;
//! * within a lane, contributions fold in increasing slot order (each lane
//!   is processed by exactly one worker, walking its slots in order);
//! * the coordinator folds lane accumulators in lane-index order.
//!
//! Sign-family votes are integer counts, so their merge is exact in *any*
//! order (associative + commutative — property-tested below). Dense f32
//! folds are order-sensitive, which is exactly what the fixed lane
//! topology pins down. When `m <= L` every lane holds one slot and the
//! fold degenerates to the historical slot-ordered reduce, bit for bit.

use super::error_feedback::EfState;
use super::kernel;
use super::pack::{PackedSigns, VoteAccumulator};
use super::qsgd::{bits_per_level, Qsgd};
use super::sign::SigmaRule;
use super::sparsify::{top_k_indices_into, SparseMessage, TopK};
use super::Message;
use crate::rng::{Pcg64, ZParam};
use crate::tensor;
use std::sync::Mutex;

/// The fixed reduce topology for one round: `L = min(reduce_lanes, m)`
/// lanes over `m` participant slots. Copyable round-scoped metadata.
#[derive(Debug, Clone, Copy)]
pub struct ReduceTopology {
    lanes: usize,
    m: usize,
}

impl ReduceTopology {
    pub fn new(reduce_lanes: usize, m: usize) -> ReduceTopology {
        ReduceTopology { lanes: reduce_lanes.max(1).min(m.max(1)), m }
    }

    /// Number of lanes L (also the maximum useful worker count).
    pub fn lanes(&self) -> usize {
        self.lanes
    }

    /// The lane slot `s` folds into.
    pub fn lane_of(&self, slot: usize) -> usize {
        slot % self.lanes
    }

    /// The slots of one lane, in the order they must fold.
    pub fn lane_slots(&self, lane: usize) -> impl Iterator<Item = usize> {
        debug_assert!(lane < self.lanes);
        (lane..self.m).step_by(self.lanes)
    }
}

/// One lane's accumulated state: the per-family fold target plus the
/// side-channel tallies (loss, exact wire bits, arrivals) that used to ride
/// per-client messages. Buffers are lazily allocated per family and reused
/// across rounds.
#[derive(Debug)]
pub struct LaneAcc {
    d: usize,
    votes: Option<VoteAccumulator>,
    dense: Option<Vec<f32>>,
    loss: f64,
    bits: u64,
    arrived: u32,
}

impl LaneAcc {
    pub fn new(d: usize) -> LaneAcc {
        LaneAcc { d, votes: None, dense: None, loss: 0.0, bits: 0, arrived: 0 }
    }

    /// Clear tallies and fold state, keeping allocations for reuse.
    pub fn reset(&mut self) {
        if let Some(v) = self.votes.as_mut() {
            v.reset();
        }
        if let Some(b) = self.dense.as_mut() {
            b.iter_mut().for_each(|x| *x = 0.0);
        }
        self.loss = 0.0;
        self.bits = 0;
        self.arrived = 0;
    }

    /// Fold one client's packed-sign vote (exact integer counts).
    pub fn add_signs(&mut self, signs: &PackedSigns, bits: u64, loss: f64) {
        self.votes.get_or_insert_with(|| VoteAccumulator::new(self.d)).add(signs);
        self.tally(bits, loss);
    }

    /// Fold one client's dense contribution: `lane += weight * v`.
    pub fn add_dense(&mut self, v: &[f32], weight: f32, bits: u64, loss: f64) {
        assert_eq!(v.len(), self.d, "dense contribution length mismatch");
        let acc = self.dense.get_or_insert_with(|| vec![0.0f32; self.d]);
        tensor::axpy(weight, v, acc);
        self.tally(bits, loss);
    }

    fn tally(&mut self, bits: u64, loss: f64) {
        self.loss += loss;
        self.bits += bits;
        self.arrived += 1;
    }

    pub fn bits(&self) -> u64 {
        self.bits
    }

    pub fn arrived(&self) -> u32 {
        self.arrived
    }

    /// f32s currently allocated for the dense fold (0 on the sign path) —
    /// the quantity the high-water regression tests pin to O(L·d).
    pub fn dense_floats(&self) -> usize {
        self.dense.as_ref().map_or(0, |b| b.len())
    }
}

/// Per-worker scratch reused across every client a worker processes: the
/// packed-sign buffer the fused kernels write into, the f32 dequantize
/// buffer the dense families fold from, and the top-k index buffer. With
/// these, **every** compressor family's `absorb` runs without a single
/// per-client heap allocation in steady state (regression-tested by
/// `tests/alloc_regression.rs`).
#[derive(Debug)]
pub struct Scratch {
    pub packed: PackedSigns,
    pub dense: Vec<f32>,
    pub idx: Vec<u32>,
}

impl Scratch {
    pub fn new(d: usize) -> Scratch {
        Scratch { packed: PackedSigns::zeroed(d), dense: vec![0.0f32; d], idx: Vec::new() }
    }
}

/// Backend-accelerated stochastic-sign compression (the PJRT Pallas kernel
/// route). Only honored on the engine's sequential path; `None` falls back
/// to the Rust reference compressor.
pub trait SignKernelHook {
    fn packed_sign(
        &mut self,
        delta: &[f32],
        z: ZParam,
        sigma: f32,
        rng: &mut Pcg64,
    ) -> Option<PackedSigns>;
}

/// Everything an [`Aggregator::absorb`] call may consult besides the
/// client's own update: the client's RNG stream, round-scoped scalars, the
/// client's EF residual (EF-SignSGD only) and the optional kernel hook.
pub struct AbsorbCtx<'a> {
    pub rng: &'a mut Pcg64,
    /// σ in effect this round (plateau controller included); per-client
    /// input-dependent rules resolve inside the aggregator.
    pub round_sigma: f32,
    /// 1/m for the round's arrived-participant count m.
    pub inv_m: f32,
    pub ef: Option<&'a Mutex<EfState>>,
    pub hook: Option<&'a mut dyn SignKernelHook>,
}

/// One client's update as it crosses the service wire: the framed
/// [`Message`] plus the EF scale sidecar (`EfMessage` is deliberately not a
/// wire `Message` variant, so the scaled-sign family ships its f32 scale
/// next to the sign frame — see `service::protocol`).
#[derive(Debug, Clone)]
pub struct RemoteUpdate {
    pub msg: Message,
    /// `Some(scale)` iff the family is EF-SignSGD.
    pub ef_scale: Option<f32>,
}

/// Client-side context for [`Aggregator::compress_remote`] — the
/// participant half of `absorb`: the same RNG stream and round scalars,
/// minus the lane state and the server-only kernel hook.
pub struct RemoteCtx<'a> {
    pub rng: &'a mut Pcg64,
    /// σ in effect this round, as published in the coordinator's offer.
    pub round_sigma: f32,
    /// The client's own EF residual (EF-SignSGD only).
    pub ef: Option<&'a Mutex<EfState>>,
}

/// Why a remote submission cannot be folded. A frame can pass the wire
/// checksum and still be unusable *for this round*: wrong compressor
/// family, wrong dimension, or internally inconsistent contents.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RemoteError {
    /// Message variant (or its parameters) do not match the aggregator.
    WrongFamily,
    /// Message dimension does not match the model dimension.
    DimMismatch,
    /// Message is self-inconsistent (index out of range, missing EF scale,
    /// wrong support size).
    Malformed,
}

impl std::fmt::Display for RemoteError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            RemoteError::WrongFamily => write!(f, "message family does not match aggregator"),
            RemoteError::DimMismatch => write!(f, "message dimension mismatch"),
            RemoteError::Malformed => write!(f, "malformed message contents"),
        }
    }
}

impl std::error::Error for RemoteError {}

/// What the coordinator learns from the lane fold: the exact tallies that
/// feed `RoundRecord` (bits from actual arrivals — an empty round bills
/// zero because `reduce` is never reached) and the loss fed back to the
/// plateau controller.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct ReduceStats {
    /// Sum of client losses, folded lane-by-lane in lane order.
    pub loss_sum: f64,
    /// Exact uplink bits across every absorbed message.
    pub bits: u64,
    /// Number of absorbed messages (cross-checked against the round plan).
    pub arrived: u32,
}

/// The aggregation seam both compressor families implement: compress one
/// client's update and fold it into a lane (`absorb`, called from worker
/// threads), then fold the lanes into the round update (`reduce`, called
/// once on the coordinator).
///
/// Implementations are stateless parameter structs (EF residuals stay with
/// the engine, keyed by client), so they are `Send + Sync` and shared by
/// every worker.
pub trait Aggregator: Send + Sync {
    /// Exact wire bits one client's message occupies at dimension `d`
    /// (fixed-rate formula; the scheduler's transfer-size model and the
    /// `net` billing helpers read this).
    fn nominal_client_bits(&self, d: usize) -> u64;

    /// Compress `delta` (the client's update direction, faults already
    /// applied) and fold it into `lane`. Pure in `(delta, loss, ctx.rng)`
    /// apart from the lane/EF state it updates — what makes lane dispatch
    /// order irrelevant. `delta` is a caller-owned scratch slice (the
    /// engine's per-worker `RoundScratch` buffer, refilled per client):
    /// implementations may clobber it freely but must not keep it.
    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    );

    /// Fold lanes `0..L` in lane order into `update` (the dequantized
    /// aggregate the server steps with). Must only be called after at
    /// least one `absorb`.
    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats;

    /// The participant half of `absorb`: compress `delta` into the wire
    /// message a networked client submits. Consumes `ctx.rng` exactly as
    /// `absorb` does, so a coordinator folding the result with
    /// [`Aggregator::fold_remote`] reproduces the in-process round bit for
    /// bit (pinned by the `remote_*` tests below).
    fn compress_remote(
        &self,
        delta: &mut [f32],
        ctx: RemoteCtx<'_>,
        scratch: &mut Scratch,
    ) -> RemoteUpdate;

    /// The coordinator half: validate a submitted [`RemoteUpdate`] against
    /// this aggregator/dimension and fold it into `lane` with the same
    /// weights and bit tallies `absorb` would have used.
    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) -> Result<(), RemoteError>;
}

/// Shared `fold_remote` validation for the packed-sign families.
fn fold_remote_signs(upd: &RemoteUpdate, loss: f64, lane: &mut LaneAcc) -> Result<(), RemoteError> {
    match &upd.msg {
        Message::Signs(p) => {
            if p.len() != lane.d {
                return Err(RemoteError::DimMismatch);
            }
            lane.add_signs(p, p.len() as u64, loss);
            Ok(())
        }
        _ => Err(RemoteError::WrongFamily),
    }
}

/// Shared `fold_remote` validation for uncompressed f32 payloads.
fn fold_remote_dense(
    upd: &RemoteUpdate,
    loss: f64,
    inv_m: f32,
    lane: &mut LaneAcc,
) -> Result<(), RemoteError> {
    match &upd.msg {
        Message::Dense(v) => {
            if v.len() != lane.d {
                return Err(RemoteError::DimMismatch);
            }
            lane.add_dense(v, inv_m, 32 * v.len() as u64, loss);
            Ok(())
        }
        _ => Err(RemoteError::WrongFamily),
    }
}

/// Validate a sparse submission and scatter it into `scratch.dense`
/// (zeroed first). `k_want` is the support size an honest client of this
/// configuration always sends.
fn scatter_sparse(
    upd: &RemoteUpdate,
    d: usize,
    k_want: usize,
    sign_coded: bool,
    scratch: &mut Scratch,
) -> Result<(), RemoteError> {
    let s = match &upd.msg {
        Message::Sparse(s) if s.sign_coded == sign_coded => s,
        Message::Sparse(_) => return Err(RemoteError::WrongFamily),
        _ => return Err(RemoteError::WrongFamily),
    };
    if s.dim != d {
        return Err(RemoteError::DimMismatch);
    }
    if s.idx.len() != k_want || s.vals.len() != s.idx.len() {
        return Err(RemoteError::Malformed);
    }
    if s.idx.iter().any(|&i| i as usize >= d) {
        return Err(RemoteError::Malformed);
    }
    scratch.dense.iter_mut().for_each(|v| *v = 0.0);
    for (&i, &v) in s.idx.iter().zip(&s.vals) {
        scratch.dense[i as usize] = v;
    }
    Ok(())
}

/// Byzantine-robust aggregation rule for the sign (vote-count) family.
/// Applied at reduce time on the exact merged integer tallies, so it is
/// deterministic and thread-count independent like everything else in the
/// vote path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RobustRule {
    /// Plain mean vote — the paper's Algorithm 1 server step.
    None,
    /// Coordinate-wise trimmed-count majority
    /// ([`VoteAccumulator::trimmed_mean_into`]): soft-threshold every tally
    /// toward zero by `2·⌊frac·n⌋`, neutralizing up to `⌊frac·n⌋`
    /// sign-flipping clients per coordinate (arXiv 2210.00665).
    TrimmedMajority {
        /// Fraction of the arriving cohort to trim, in `[0, 0.5)`.
        frac: f32,
    },
}

impl RobustRule {
    /// Votes to trim for a cohort of `n` arrivals.
    fn trim_for(&self, n: u32) -> u32 {
        match *self {
            RobustRule::None => 0,
            RobustRule::TrimmedMajority { frac } => (frac as f64 * n as f64).floor() as u32,
        }
    }
}

/// Lane fold for the sign family: merge lane vote shards (exact integer
/// counts, order-independent — lane order is used anyway) and write the
/// mean vote, optionally trimmed per [`RobustRule`]. The merged accumulator
/// is returned to lane 0 so its allocation is reused next round.
fn reduce_votes(lanes: &[Mutex<LaneAcc>], rule: RobustRule, update: &mut [f32]) -> ReduceStats {
    let mut stats = ReduceStats::default();
    let mut total: Option<VoteAccumulator> = None;
    for lane in lanes {
        let mut lane = lane.lock().unwrap();
        stats.loss_sum += lane.loss;
        stats.bits += lane.bits;
        stats.arrived += lane.arrived;
        if total.is_none() {
            total = lane.votes.take();
        } else if let (Some(t), Some(v)) = (total.as_mut(), lane.votes.as_ref()) {
            t.merge(v);
        }
    }
    let mut total = total.expect("sign reduce with no votes absorbed");
    match rule.trim_for(total.num_votes()) {
        // trim = 0 routes through the untrimmed kernel — bit-identical to
        // the pre-RobustRule behavior by construction.
        0 => total.mean_into(1.0, update),
        trim => total.trimmed_mean_into(trim, 1.0, update),
    }
    lanes[0].lock().unwrap().votes = Some(total);
    stats
}

/// Lane fold for the dense family: `update = Σ_lane lane.dense`, strictly
/// in lane-index order (per-client weights were applied at absorb time).
fn reduce_dense(lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
    let mut stats = ReduceStats::default();
    update.iter_mut().for_each(|u| *u = 0.0);
    for lane in lanes {
        let lane = lane.lock().unwrap();
        stats.loss_sum += lane.loss;
        stats.bits += lane.bits;
        stats.arrived += lane.arrived;
        if let Some(acc) = lane.dense.as_ref() {
            for (u, &a) in update.iter_mut().zip(acc) {
                *u += a;
            }
        }
    }
    stats
}

// ---------------------------------------------------------------------------
// Per-compressor implementations
// ---------------------------------------------------------------------------

/// Uncompressed f32 updates (FedAvg / distributed SGD / GD).
pub struct DenseAgg;

impl Aggregator for DenseAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        32 * d as u64
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        _scratch: &mut Scratch,
    ) {
        let bits = 32 * delta.len() as u64;
        lane.add_dense(delta, ctx.inv_m, bits, loss);
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_dense(lanes, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        _ctx: RemoteCtx<'_>,
        _scratch: &mut Scratch,
    ) -> RemoteUpdate {
        RemoteUpdate { msg: Message::Dense(delta.to_vec()), ef_scale: None }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
        lane: &mut LaneAcc,
        _scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        fold_remote_dense(upd, loss, inv_m, lane)
    }
}

/// The paper's stochastic sign `Sign(delta + σ·ξ_z)` — Algorithm 1's
/// packed-vote path (d bits per client).
pub struct ZSignAgg {
    pub z: ZParam,
    pub sigma: SigmaRule,
    pub robust: RobustRule,
}

impl Aggregator for ZSignAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        d as u64
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) {
        let AbsorbCtx { rng, round_sigma, hook, .. } = ctx;
        let s = match self.sigma {
            SigmaRule::Fixed(_) => round_sigma,
            SigmaRule::L2Norm => tensor::norm2(delta) as f32,
            SigmaRule::InfNorm => tensor::norm_inf(delta) as f32,
        };
        // Prefer the backend's AOT Pallas kernel (sequential path only);
        // fall back to the fused Rust kernel (one pass, bit-identical to
        // the scalar reference compressor, zero allocation).
        let hooked = hook.and_then(|h| h.packed_sign(delta, self.z, s, &mut *rng));
        match hooked {
            Some(packed) => lane.add_signs(&packed, delta.len() as u64, loss),
            None => {
                kernel::stochastic_sign_packed(delta, self.z, s, rng, &mut scratch.packed);
                lane.add_signs(&scratch.packed, delta.len() as u64, loss);
            }
        }
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_votes(lanes, self.robust, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        ctx: RemoteCtx<'_>,
        scratch: &mut Scratch,
    ) -> RemoteUpdate {
        // Same σ resolution and fused kernel as `absorb` (no hook on the
        // remote path — deployed clients run the Rust reference kernel).
        let s = match self.sigma {
            SigmaRule::Fixed(_) => ctx.round_sigma,
            SigmaRule::L2Norm => tensor::norm2(delta) as f32,
            SigmaRule::InfNorm => tensor::norm_inf(delta) as f32,
        };
        kernel::stochastic_sign_packed(delta, self.z, s, ctx.rng, &mut scratch.packed);
        RemoteUpdate { msg: Message::Signs(scratch.packed.clone()), ef_scale: None }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        _inv_m: f32,
        lane: &mut LaneAcc,
        _scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        fold_remote_signs(upd, loss, lane)
    }
}

/// EF-SignSGD: compress the stepsize-scaled update γ·Σg through the
/// client's residual state, then fold the decoded scaled sign.
pub struct EfAgg {
    pub client_lr: f32,
}

impl Aggregator for EfAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        // d sign bits + one f32 scale.
        32 + d as u64
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) {
        tensor::scale(self.client_lr, delta);
        // Fused residual step + dequantize — no wire message materialized.
        let bits = ctx
            .ef
            .expect("EF residual missing")
            .lock()
            .unwrap()
            .step_dequantized_into(delta, &mut scratch.dense);
        // Undo the γ scaling so the server step stays η·γ·agg.
        lane.add_dense(&scratch.dense, ctx.inv_m / self.client_lr, bits, loss);
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_dense(lanes, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        ctx: RemoteCtx<'_>,
        _scratch: &mut Scratch,
    ) -> RemoteUpdate {
        tensor::scale(self.client_lr, delta);
        let msg = ctx
            .ef
            .expect("EF residual missing")
            .lock()
            .unwrap()
            .step(delta);
        RemoteUpdate { msg: Message::Signs(msg.signs), ef_scale: Some(msg.scale) }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        let scale = upd.ef_scale.ok_or(RemoteError::Malformed)?;
        let p = match &upd.msg {
            Message::Signs(p) => p,
            _ => return Err(RemoteError::WrongFamily),
        };
        if p.len() != lane.d {
            return Err(RemoteError::DimMismatch);
        }
        // decode(msg) is bit-identical to the fused `step_dequantized_into`
        // output (pinned in `error_feedback`), so the fold matches `absorb`.
        p.decode_scaled_into(scale, &mut scratch.dense);
        lane.add_dense(&scratch.dense, inv_m / self.client_lr, 32 + p.len() as u64, loss);
        Ok(())
    }
}

/// QSGD / FedPAQ unbiased quantizer with `s` levels.
pub struct QsgdAgg {
    pub s: u32,
}

impl Aggregator for QsgdAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        32 + (d as u64) * (1 + bits_per_level(self.s))
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) {
        let bits = self.nominal_client_bits(delta.len());
        Qsgd::new(self.s).quantize_dequantize_into(delta, ctx.rng, &mut scratch.dense);
        lane.add_dense(&scratch.dense, ctx.inv_m, bits, loss);
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_dense(lanes, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        ctx: RemoteCtx<'_>,
        _scratch: &mut Scratch,
    ) -> RemoteUpdate {
        // `quantize` draws and rounds exactly like the fused
        // `quantize_dequantize_into` the in-process absorb uses (pinned by
        // `qsgd::fused_matches_quantize_decode`).
        let q = Qsgd::new(self.s).quantize(delta, ctx.rng);
        RemoteUpdate { msg: Message::Quantized(q), ef_scale: None }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        let q = match &upd.msg {
            Message::Quantized(q) => q,
            _ => return Err(RemoteError::WrongFamily),
        };
        if q.s != self.s {
            return Err(RemoteError::WrongFamily);
        }
        if q.levels.len() != lane.d {
            return Err(RemoteError::DimMismatch);
        }
        q.decode_into(&mut scratch.dense);
        lane.add_dense(&scratch.dense, inv_m, self.nominal_client_bits(lane.d), loss);
        Ok(())
    }
}

/// DP-SignFedAvg (Algorithm 2): clip the *model diff*, perturb, sign.
pub struct DpSignAgg {
    pub clip: f32,
    pub noise_mult: f32,
    pub client_lr: f32,
    pub robust: RobustRule,
}

impl Aggregator for DpSignAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        d as u64
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) {
        tensor::scale(self.client_lr, delta); // γ·Σg = x_{t-1} − x_E
        tensor::clip_l2(delta, self.clip as f64);
        let noise_std = self.noise_mult * self.clip;
        for v in delta.iter_mut() {
            *v += noise_std * ctx.rng.normal() as f32;
        }
        kernel::pack_f32_signs_into(delta, &mut scratch.packed);
        lane.add_signs(&scratch.packed, delta.len() as u64, loss);
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_votes(lanes, self.robust, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        ctx: RemoteCtx<'_>,
        scratch: &mut Scratch,
    ) -> RemoteUpdate {
        tensor::scale(self.client_lr, delta);
        tensor::clip_l2(delta, self.clip as f64);
        let noise_std = self.noise_mult * self.clip;
        for v in delta.iter_mut() {
            *v += noise_std * ctx.rng.normal() as f32;
        }
        kernel::pack_f32_signs_into(delta, &mut scratch.packed);
        RemoteUpdate { msg: Message::Signs(scratch.packed.clone()), ef_scale: None }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        _inv_m: f32,
        lane: &mut LaneAcc,
        _scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        fold_remote_signs(upd, loss, lane)
    }
}

/// Uncompressed DP-FedAvg baseline (clip + Gaussian noise, no sign).
pub struct DpDenseAgg {
    pub clip: f32,
    pub noise_mult: f32,
    pub client_lr: f32,
}

impl Aggregator for DpDenseAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        32 * d as u64
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        _scratch: &mut Scratch,
    ) {
        tensor::scale(self.client_lr, delta);
        tensor::clip_l2(delta, self.clip as f64);
        let noise_std = self.noise_mult * self.clip;
        for v in delta.iter_mut() {
            *v += noise_std * ctx.rng.normal() as f32;
        }
        let bits = 32 * delta.len() as u64;
        lane.add_dense(delta, ctx.inv_m, bits, loss);
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_dense(lanes, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        ctx: RemoteCtx<'_>,
        _scratch: &mut Scratch,
    ) -> RemoteUpdate {
        tensor::scale(self.client_lr, delta);
        tensor::clip_l2(delta, self.clip as f64);
        let noise_std = self.noise_mult * self.clip;
        for v in delta.iter_mut() {
            *v += noise_std * ctx.rng.normal() as f32;
        }
        RemoteUpdate { msg: Message::Dense(delta.to_vec()), ef_scale: None }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
        lane: &mut LaneAcc,
        _scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        fold_remote_dense(upd, loss, inv_m, lane)
    }
}

/// Magnitude top-k sparsification.
pub struct TopKAgg {
    pub frac: f32,
}

impl Aggregator for TopKAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        let k = TopK::new(self.frac).k_for(d) as u64;
        32 * k + 32 * k
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) {
        // Fused select + scatter: what decode(compress(delta)) produces,
        // without materializing the wire message.
        let k = TopK::new(self.frac).k_for(delta.len());
        top_k_indices_into(delta, k, &mut scratch.idx);
        scratch.dense.iter_mut().for_each(|v| *v = 0.0);
        for &i in &scratch.idx {
            scratch.dense[i as usize] = delta[i as usize];
        }
        let bits = self.nominal_client_bits(delta.len());
        lane.add_dense(&scratch.dense, ctx.inv_m, bits, loss);
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_dense(lanes, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        _ctx: RemoteCtx<'_>,
        scratch: &mut Scratch,
    ) -> RemoteUpdate {
        let k = TopK::new(self.frac).k_for(delta.len());
        top_k_indices_into(delta, k, &mut scratch.idx);
        let vals = scratch.idx.iter().map(|&i| delta[i as usize]).collect();
        RemoteUpdate {
            msg: Message::Sparse(SparseMessage {
                dim: delta.len(),
                idx: scratch.idx.clone(),
                vals,
                sign_coded: false,
            }),
            ef_scale: None,
        }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        let k = TopK::new(self.frac).k_for(lane.d);
        scatter_sparse(upd, lane.d, k, false, scratch)?;
        lane.add_dense(&scratch.dense, inv_m, self.nominal_client_bits(lane.d), loss);
        Ok(())
    }
}

/// Top-k support + stochastic sign of values — the paper conclusion's
/// "sign + sparsification" combination.
pub struct SparseSignAgg {
    pub frac: f32,
    pub z: ZParam,
    pub sigma: f32,
}

impl Aggregator for SparseSignAgg {
    fn nominal_client_bits(&self, d: usize) -> u64 {
        let k = TopK::new(self.frac).k_for(d) as u64;
        32 * k + k + 32
    }

    fn absorb(
        &self,
        delta: &mut [f32],
        loss: f64,
        ctx: AbsorbCtx<'_>,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) {
        // Fused select + stochastic-sign + scatter, RNG draws in the same
        // (sorted-support) order the wire compressor uses.
        let k = TopK::new(self.frac).k_for(delta.len());
        top_k_indices_into(delta, k, &mut scratch.idx);
        let scale = (scratch.idx.iter().map(|&i| delta[i as usize].abs() as f64).sum::<f64>()
            / k as f64) as f32;
        scratch.dense.iter_mut().for_each(|v| *v = 0.0);
        for &i in &scratch.idx {
            let v = delta[i as usize] as f64 + self.sigma as f64 * ctx.rng.z_noise(self.z);
            scratch.dense[i as usize] = if v >= 0.0 { scale } else { -scale };
        }
        let bits = self.nominal_client_bits(delta.len());
        lane.add_dense(&scratch.dense, ctx.inv_m, bits, loss);
    }

    fn reduce(&self, lanes: &[Mutex<LaneAcc>], update: &mut [f32]) -> ReduceStats {
        reduce_dense(lanes, update)
    }

    fn compress_remote(
        &self,
        delta: &mut [f32],
        ctx: RemoteCtx<'_>,
        scratch: &mut Scratch,
    ) -> RemoteUpdate {
        // Same sorted-support RNG draw order and scale arithmetic as
        // `absorb` (and as the `sparsify::SparseSign` wire compressor).
        let k = TopK::new(self.frac).k_for(delta.len());
        top_k_indices_into(delta, k, &mut scratch.idx);
        let scale = (scratch.idx.iter().map(|&i| delta[i as usize].abs() as f64).sum::<f64>()
            / k as f64) as f32;
        let vals = scratch
            .idx
            .iter()
            .map(|&i| {
                let v = delta[i as usize] as f64 + self.sigma as f64 * ctx.rng.z_noise(self.z);
                if v >= 0.0 {
                    scale
                } else {
                    -scale
                }
            })
            .collect();
        RemoteUpdate {
            msg: Message::Sparse(SparseMessage {
                dim: delta.len(),
                idx: scratch.idx.clone(),
                vals,
                sign_coded: true,
            }),
            ef_scale: None,
        }
    }

    fn fold_remote(
        &self,
        upd: &RemoteUpdate,
        loss: f64,
        inv_m: f32,
        lane: &mut LaneAcc,
        scratch: &mut Scratch,
    ) -> Result<(), RemoteError> {
        let k = TopK::new(self.frac).k_for(lane.d);
        scatter_sparse(upd, lane.d, k, true, scratch)?;
        lane.add_dense(&scratch.dense, inv_m, self.nominal_client_bits(lane.d), loss);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx(rng: &mut Pcg64) -> AbsorbCtx<'_> {
        AbsorbCtx { rng, round_sigma: 1.0, inv_m: 0.25, ef: None, hook: None }
    }

    fn mk_lanes(l: usize, d: usize) -> Vec<Mutex<LaneAcc>> {
        (0..l).map(|_| Mutex::new(LaneAcc::new(d))).collect()
    }

    fn random_delta(rng: &mut Pcg64, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect()
    }

    #[test]
    fn topology_partitions_all_slots_once() {
        for (lanes, m) in [(1usize, 7usize), (4, 7), (7, 7), (16, 7), (64, 1000)] {
            let topo = ReduceTopology::new(lanes, m);
            let mut seen = vec![0u32; m];
            for lane in 0..topo.lanes() {
                let mut prev = None;
                for s in topo.lane_slots(lane) {
                    assert_eq!(topo.lane_of(s), lane);
                    // In-lane order must be increasing (the fold order).
                    assert!(prev.map(|p| p < s).unwrap_or(true));
                    prev = Some(s);
                    seen[s] += 1;
                }
            }
            assert!(seen.iter().all(|&c| c == 1), "lanes={lanes} m={m}");
        }
    }

    #[test]
    fn topology_caps_lanes_at_cohort() {
        assert_eq!(ReduceTopology::new(64, 5).lanes(), 5);
        assert_eq!(ReduceTopology::new(4, 100).lanes(), 4);
        assert_eq!(ReduceTopology::new(0, 3).lanes(), 1); // 0 means 1
    }

    /// Sign votes are integer counts: the aggregate is invariant under any
    /// permutation of clients across slots/lanes (the "where claimed" part
    /// of the merge property — dense folds only claim lane-dispatch
    /// invariance, tested in `fl::engine`).
    #[test]
    fn sign_reduce_is_slot_permutation_invariant() {
        let d = 130;
        let m = 12;
        let agg = ZSignAgg {
            z: ZParam::Finite(1),
            sigma: SigmaRule::Fixed(1.0),
            robust: RobustRule::None,
        };
        let mut rng = Pcg64::seeded(5);
        // One fixed (delta, rng stream) per *client*; permuting slots
        // re-orders absorption but not any client's own randomness.
        let deltas: Vec<Vec<f32>> = (0..m).map(|_| random_delta(&mut rng, d)).collect();
        let run = |perm: &[usize], lanes_n: usize| {
            let lanes = mk_lanes(lanes_n, d);
            let topo = ReduceTopology::new(lanes_n, m);
            for lane in 0..topo.lanes() {
                for slot in topo.lane_slots(lane) {
                    let client = perm[slot];
                    let mut crng = Pcg64::new(77, client as u64);
                    let mut scratch = Scratch::new(d);
                    let mut delta = deltas[client].clone();
                    agg.absorb(
                        &mut delta,
                        client as f64,
                        ctx(&mut crng),
                        &mut lanes[lane].lock().unwrap(),
                        &mut scratch,
                    );
                }
            }
            let mut update = vec![0.0f32; d];
            let stats = agg.reduce(&lanes, &mut update);
            (update, stats)
        };
        let id: Vec<usize> = (0..m).collect();
        let (base, base_stats) = run(&id, 3);
        let mut perm = id.clone();
        perm.reverse();
        perm.swap(2, 7);
        for lanes_n in [1usize, 2, 5, 12] {
            let (u, stats) = run(&perm, lanes_n);
            let bits_eq = u.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits());
            assert!(bits_eq, "lanes={lanes_n}");
            assert_eq!(stats.bits, base_stats.bits);
            assert_eq!(stats.arrived, base_stats.arrived);
            // f64 loss sum over a permutation is NOT claimed bit-equal in
            // general; here it is exact (small integers), so check it too.
            assert_eq!(stats.loss_sum, base_stats.loss_sum);
        }
    }

    /// The dense reduce is a pure function of (slot contents, lane count):
    /// the order in which *lanes* are populated — i.e. which worker claims
    /// which lane, in any order — never changes the folded update.
    #[test]
    fn dense_reduce_is_lane_dispatch_invariant() {
        let d = 97;
        let m = 23;
        let lanes_n = 5;
        let agg = QsgdAgg { s: 2 };
        let mut rng = Pcg64::seeded(9);
        let deltas: Vec<Vec<f32>> = (0..m).map(|_| random_delta(&mut rng, d)).collect();
        let topo = ReduceTopology::new(lanes_n, m);
        let run = |lane_order: &[usize]| {
            let lanes = mk_lanes(topo.lanes(), d);
            let mut scratch = Scratch::new(d);
            for &lane in lane_order {
                for slot in topo.lane_slots(lane) {
                    let mut crng = Pcg64::new(3, slot as u64);
                    let mut delta = deltas[slot].clone();
                    agg.absorb(
                        &mut delta,
                        0.5 * slot as f64,
                        ctx(&mut crng),
                        &mut lanes[lane].lock().unwrap(),
                        &mut scratch,
                    );
                }
            }
            let mut update = vec![0.0f32; d];
            let stats = agg.reduce(&lanes, &mut update);
            (update, stats)
        };
        let (base, bstats) = run(&[0, 1, 2, 3, 4]);
        for order in [[4usize, 3, 2, 1, 0], [2, 0, 4, 1, 3]] {
            let (u, stats) = run(&order);
            assert!(u.iter().zip(&base).all(|(a, b)| a.to_bits() == b.to_bits()));
            assert_eq!(stats.loss_sum.to_bits(), bstats.loss_sum.to_bits());
            assert_eq!(stats.bits, bstats.bits);
        }
    }

    /// With one slot per lane (m <= L) the lane fold IS the historical
    /// slot-ordered fold, bit for bit.
    #[test]
    fn dense_reduce_matches_slot_ordered_fold_when_lanes_cover_slots() {
        let d = 61;
        let m = 8;
        let inv_m = 1.0f32 / m as f32;
        let mut rng = Pcg64::seeded(11);
        let deltas: Vec<Vec<f32>> = (0..m).map(|_| random_delta(&mut rng, d)).collect();
        // Historical reduce: acc += inv_m * v, slot order.
        let mut want = vec![0.0f32; d];
        for v in &deltas {
            tensor::axpy(inv_m, v, &mut want);
        }
        let agg = DenseAgg;
        let lanes = mk_lanes(m, d);
        let topo = ReduceTopology::new(64, m);
        assert_eq!(topo.lanes(), m);
        let mut scratch = Scratch::new(d);
        for slot in 0..m {
            let mut crng = Pcg64::new(1, slot as u64);
            let c = AbsorbCtx {
                rng: &mut crng,
                round_sigma: 0.0,
                inv_m,
                ef: None,
                hook: None,
            };
            let mut delta = deltas[slot].clone();
            agg.absorb(
                &mut delta,
                0.0,
                c,
                &mut lanes[topo.lane_of(slot)].lock().unwrap(),
                &mut scratch,
            );
        }
        let mut update = vec![0.0f32; d];
        agg.reduce(&lanes, &mut update);
        assert!(update.iter().zip(&want).all(|(a, b)| a.to_bits() == b.to_bits()));
    }

    /// The high-water regression: folding m dense clients through L lanes
    /// allocates exactly L·d floats of aggregation state — never Θ(m·d).
    #[test]
    fn dense_lane_memory_is_lanes_times_d_not_m_times_d() {
        let d = 128;
        let m = 64;
        let lanes_n = 4;
        let agg = DenseAgg;
        let lanes = mk_lanes(lanes_n, d);
        let topo = ReduceTopology::new(lanes_n, m);
        let mut scratch = Scratch::new(d);
        let mut rng = Pcg64::seeded(2);
        for slot in 0..m {
            let mut delta = random_delta(&mut rng, d);
            let mut crng = Pcg64::new(4, slot as u64);
            agg.absorb(
                &mut delta,
                0.0,
                ctx(&mut crng),
                &mut lanes[topo.lane_of(slot)].lock().unwrap(),
                &mut scratch,
            );
        }
        let total: usize = lanes.iter().map(|l| l.lock().unwrap().dense_floats()).sum();
        assert_eq!(total, lanes_n * d);
        assert!(total < m * d);
    }

    /// Sign lanes allocate no dense state at all.
    #[test]
    fn sign_lanes_allocate_no_dense_state() {
        let d = 96;
        let agg = ZSignAgg {
            z: ZParam::Finite(1),
            sigma: SigmaRule::Fixed(0.5),
            robust: RobustRule::None,
        };
        let lanes = mk_lanes(2, d);
        let mut scratch = Scratch::new(d);
        for slot in 0..6usize {
            let mut crng = Pcg64::new(8, slot as u64);
            let mut delta = random_delta(&mut crng.split(1), d);
            agg.absorb(
                &mut delta,
                0.0,
                ctx(&mut crng),
                &mut lanes[slot % 2].lock().unwrap(),
                &mut scratch,
            );
        }
        assert!(lanes.iter().all(|l| l.lock().unwrap().dense_floats() == 0));
    }

    /// Every fixed-rate aggregator's absorbed wire bits match its nominal
    /// formula — the single source the scheduler and the billing read.
    #[test]
    fn absorbed_bits_match_nominal_formula() {
        let d = 100;
        let aggs: Vec<Box<dyn Aggregator>> = vec![
            Box::new(DenseAgg),
            Box::new(ZSignAgg {
                z: ZParam::Finite(1),
                sigma: SigmaRule::Fixed(1.0),
                robust: RobustRule::None,
            }),
            Box::new(QsgdAgg { s: 1 }),
            Box::new(QsgdAgg { s: 4 }),
            Box::new(DpSignAgg {
                clip: 0.5,
                noise_mult: 1.0,
                client_lr: 0.1,
                robust: RobustRule::None,
            }),
            Box::new(DpDenseAgg { clip: 0.5, noise_mult: 1.0, client_lr: 0.1 }),
            Box::new(TopKAgg { frac: 0.1 }),
            Box::new(SparseSignAgg { frac: 0.1, z: ZParam::Finite(1), sigma: 1.0 }),
        ];
        for agg in &aggs {
            let lanes = mk_lanes(1, d);
            let mut scratch = Scratch::new(d);
            let mut rng = Pcg64::seeded(3);
            let mut delta = random_delta(&mut rng.split(9), d);
            agg.absorb(&mut delta, 0.0, ctx(&mut rng), &mut lanes[0].lock().unwrap(), &mut scratch);
            assert_eq!(lanes[0].lock().unwrap().bits(), agg.nominal_client_bits(d));
        }
        // EF separately (needs a residual).
        let ef_agg = EfAgg { client_lr: 0.1 };
        let ef = Mutex::new(EfState::new(d));
        let lanes = mk_lanes(1, d);
        let mut scratch = Scratch::new(d);
        let mut rng = Pcg64::seeded(4);
        let mut delta = random_delta(&mut rng.split(2), d);
        let c = AbsorbCtx {
            rng: &mut rng,
            round_sigma: 0.0,
            inv_m: 1.0,
            ef: Some(&ef),
            hook: None,
        };
        ef_agg.absorb(&mut delta, 0.0, c, &mut lanes[0].lock().unwrap(), &mut scratch);
        assert_eq!(lanes[0].lock().unwrap().bits(), ef_agg.nominal_client_bits(d));
    }

    /// The service seam's keystone: for every stateless family,
    /// `compress_remote` → wire encode/decode → `fold_remote` must
    /// reproduce the in-process `absorb` fold bit for bit — same reduce
    /// output, same loss/bits/arrived tallies.
    #[test]
    fn remote_fold_matches_absorb_for_every_family() {
        use crate::compress::wire;
        let d = 130;
        let m = 7;
        let inv_m = 1.0f32 / m as f32;
        let aggs: Vec<Box<dyn Aggregator>> = vec![
            Box::new(DenseAgg),
            Box::new(ZSignAgg {
                z: ZParam::Finite(1),
                sigma: SigmaRule::Fixed(1.0),
                robust: RobustRule::None,
            }),
            Box::new(ZSignAgg {
                z: ZParam::Inf,
                sigma: SigmaRule::L2Norm,
                robust: RobustRule::None,
            }),
            Box::new(QsgdAgg { s: 1 }),
            Box::new(QsgdAgg { s: 4 }),
            Box::new(DpSignAgg {
                clip: 0.5,
                noise_mult: 1.0,
                client_lr: 0.1,
                robust: RobustRule::None,
            }),
            Box::new(DpDenseAgg { clip: 0.5, noise_mult: 1.0, client_lr: 0.1 }),
            Box::new(TopKAgg { frac: 0.1 }),
            Box::new(SparseSignAgg { frac: 0.1, z: ZParam::Finite(1), sigma: 1.0 }),
        ];
        for (ai, agg) in aggs.iter().enumerate() {
            let topo = ReduceTopology::new(3, m);
            let mut data_rng = Pcg64::seeded(0x5e7 + ai as u64);
            let deltas: Vec<Vec<f32>> = (0..m).map(|_| random_delta(&mut data_rng, d)).collect();

            let lanes_a = mk_lanes(topo.lanes(), d);
            let mut scratch = Scratch::new(d);
            for slot in 0..m {
                let mut rng = Pcg64::new(42, slot as u64);
                let mut delta = deltas[slot].clone();
                let c = AbsorbCtx { rng: &mut rng, round_sigma: 0.7, inv_m, ef: None, hook: None };
                agg.absorb(
                    &mut delta,
                    slot as f64 * 0.25,
                    c,
                    &mut lanes_a[topo.lane_of(slot)].lock().unwrap(),
                    &mut scratch,
                );
            }
            let mut want = vec![0.0f32; d];
            let want_stats = agg.reduce(&lanes_a, &mut want);

            let lanes_b = mk_lanes(topo.lanes(), d);
            for slot in 0..m {
                let mut rng = Pcg64::new(42, slot as u64);
                let mut delta = deltas[slot].clone();
                let upd = agg.compress_remote(
                    &mut delta,
                    RemoteCtx { rng: &mut rng, round_sigma: 0.7, ef: None },
                    &mut scratch,
                );
                // Round-trip through the actual wire frame — exactly what a
                // networked coordinator decodes before folding.
                let msg = wire::decode(&wire::encode(&upd.msg)).unwrap();
                let upd = RemoteUpdate { msg, ef_scale: upd.ef_scale };
                agg.fold_remote(
                    &upd,
                    slot as f64 * 0.25,
                    inv_m,
                    &mut lanes_b[topo.lane_of(slot)].lock().unwrap(),
                    &mut scratch,
                )
                .unwrap();
            }
            let mut got = vec![0.0f32; d];
            let got_stats = agg.reduce(&lanes_b, &mut got);

            for (j, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(w.to_bits(), g.to_bits(), "agg #{ai} coord {j}");
            }
            assert_eq!(want_stats.loss_sum.to_bits(), got_stats.loss_sum.to_bits(), "agg #{ai}");
            assert_eq!(want_stats.bits, got_stats.bits, "agg #{ai}");
            assert_eq!(want_stats.arrived, got_stats.arrived, "agg #{ai}");
        }
    }

    /// EF-SignSGD: the remote path must track the per-client residual
    /// trajectory bit-for-bit across rounds (client-side state, server-side
    /// fold of the decoded scaled sign).
    #[test]
    fn remote_fold_matches_absorb_for_error_feedback() {
        use crate::compress::wire;
        let d = 67;
        let m = 3;
        let inv_m = 1.0f32 / m as f32;
        let agg = EfAgg { client_lr: 0.1 };
        let ef_a: Vec<Mutex<EfState>> = (0..m).map(|_| Mutex::new(EfState::new(d))).collect();
        let ef_b: Vec<Mutex<EfState>> = (0..m).map(|_| Mutex::new(EfState::new(d))).collect();
        for round in 0..5u64 {
            let topo = ReduceTopology::new(2, m);
            let mut data_rng = Pcg64::seeded(900 + round);
            let deltas: Vec<Vec<f32>> = (0..m).map(|_| random_delta(&mut data_rng, d)).collect();

            let lanes_a = mk_lanes(topo.lanes(), d);
            let mut scratch = Scratch::new(d);
            for slot in 0..m {
                let mut rng = Pcg64::new(7 + round, slot as u64);
                let mut delta = deltas[slot].clone();
                let c = AbsorbCtx {
                    rng: &mut rng,
                    round_sigma: 0.0,
                    inv_m,
                    ef: Some(&ef_a[slot]),
                    hook: None,
                };
                agg.absorb(
                    &mut delta,
                    0.5,
                    c,
                    &mut lanes_a[topo.lane_of(slot)].lock().unwrap(),
                    &mut scratch,
                );
            }
            let mut want = vec![0.0f32; d];
            let want_stats = agg.reduce(&lanes_a, &mut want);

            let lanes_b = mk_lanes(topo.lanes(), d);
            for slot in 0..m {
                let mut rng = Pcg64::new(7 + round, slot as u64);
                let mut delta = deltas[slot].clone();
                let upd = agg.compress_remote(
                    &mut delta,
                    RemoteCtx { rng: &mut rng, round_sigma: 0.0, ef: Some(&ef_b[slot]) },
                    &mut scratch,
                );
                let msg = wire::decode(&wire::encode(&upd.msg)).unwrap();
                let upd = RemoteUpdate { msg, ef_scale: upd.ef_scale };
                agg.fold_remote(
                    &upd,
                    0.5,
                    inv_m,
                    &mut lanes_b[topo.lane_of(slot)].lock().unwrap(),
                    &mut scratch,
                )
                .unwrap();
            }
            let mut got = vec![0.0f32; d];
            let got_stats = agg.reduce(&lanes_b, &mut got);

            for (j, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(w.to_bits(), g.to_bits(), "round {round} coord {j}");
            }
            assert_eq!(want_stats, got_stats, "round {round}");
            for slot in 0..m {
                let ra = ef_a[slot].lock().unwrap();
                let rb = ef_b[slot].lock().unwrap();
                for (a, b) in ra.residual().iter().zip(rb.residual()) {
                    assert_eq!(a.to_bits(), b.to_bits(), "round {round} slot {slot}");
                }
            }
        }
    }

    /// `fold_remote` rejects — never panics on — submissions that are
    /// valid frames but wrong for this round.
    #[test]
    fn fold_remote_validates_family_and_dimension() {
        let d = 40;
        let mut scratch = Scratch::new(d);
        let mk_lane = || LaneAcc::new(d);

        let sign = ZSignAgg {
            z: ZParam::Finite(1),
            sigma: SigmaRule::Fixed(1.0),
            robust: RobustRule::None,
        };
        let dense = DenseAgg;
        let qsgd = QsgdAgg { s: 2 };
        let topk = TopKAgg { frac: 0.1 };
        let ef = EfAgg { client_lr: 0.1 };

        let dense_msg = RemoteUpdate { msg: Message::Dense(vec![0.5; d]), ef_scale: None };
        let short_dense = RemoteUpdate { msg: Message::Dense(vec![0.5; d - 1]), ef_scale: None };
        let signs_msg = RemoteUpdate {
            msg: Message::Signs(PackedSigns::from_signs(&vec![1i8; d])),
            ef_scale: None,
        };
        let short_signs = RemoteUpdate {
            msg: Message::Signs(PackedSigns::from_signs(&vec![1i8; d - 3])),
            ef_scale: None,
        };

        // Family mismatches.
        assert_eq!(
            sign.fold_remote(&dense_msg, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::WrongFamily)
        );
        assert_eq!(
            dense.fold_remote(&signs_msg, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::WrongFamily)
        );
        assert_eq!(
            qsgd.fold_remote(&dense_msg, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::WrongFamily)
        );
        // QSGD level-count (s) mismatch is a family error too.
        let wrong_s = RemoteUpdate {
            msg: Message::Quantized(crate::compress::qsgd::Quantized {
                norm: 1.0,
                levels: vec![0; d],
                s: 7,
            }),
            ef_scale: None,
        };
        assert_eq!(
            qsgd.fold_remote(&wrong_s, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::WrongFamily)
        );

        // Dimension mismatches.
        assert_eq!(
            sign.fold_remote(&short_signs, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::DimMismatch)
        );
        assert_eq!(
            dense.fold_remote(&short_dense, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::DimMismatch)
        );

        // EF requires the scale sidecar.
        assert_eq!(
            ef.fold_remote(&signs_msg, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::Malformed)
        );

        // Sparse: out-of-range index and wrong support size.
        let bad_idx = RemoteUpdate {
            msg: Message::Sparse(SparseMessage {
                dim: d,
                idx: vec![0, 1, 2, (d as u32) + 5],
                vals: vec![1.0; 4],
                sign_coded: false,
            }),
            ef_scale: None,
        };
        assert_eq!(
            topk.fold_remote(&bad_idx, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::Malformed)
        );
        let wrong_k = RemoteUpdate {
            msg: Message::Sparse(SparseMessage {
                dim: d,
                idx: vec![0],
                vals: vec![1.0],
                sign_coded: false,
            }),
            ef_scale: None,
        };
        // k_for(0.1, 40) = 4, so a 1-element support is malformed.
        assert_eq!(
            topk.fold_remote(&wrong_k, 0.0, 1.0, &mut mk_lane(), &mut scratch),
            Err(RemoteError::Malformed)
        );
    }

    /// `reset` keeps allocations but clears all fold state and tallies.
    #[test]
    fn lane_reset_clears_state() {
        let d = 32;
        let agg = QsgdAgg { s: 2 };
        let lanes = mk_lanes(1, d);
        let mut scratch = Scratch::new(d);
        let mut rng = Pcg64::seeded(6);
        let mut delta = random_delta(&mut rng.split(7), d);
        agg.absorb(&mut delta, 1.5, ctx(&mut rng), &mut lanes[0].lock().unwrap(), &mut scratch);
        let mut lane = lanes[0].lock().unwrap();
        assert!(lane.bits() > 0 && lane.arrived() == 1);
        lane.reset();
        assert_eq!(lane.bits(), 0);
        assert_eq!(lane.arrived(), 0);
        assert_eq!(lane.loss, 0.0);
        assert_eq!(lane.dense_floats(), d); // allocation retained...
        assert!(lane.dense.as_ref().unwrap().iter().all(|&x| x == 0.0)); // ...but zeroed
    }
}
