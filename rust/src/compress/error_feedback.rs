//! EF-SignSGD: error feedback for the scaled sign compressor
//! (Karimireddy et al. '19; the paper's strongest sign-based baseline,
//! Fig. 3).
//!
//! Each client keeps a residual `e_i`. Per round, with local update `p`:
//!
//! ```text
//! u      = e_i + p                        (compensated update)
//! msg    = (‖u‖₁ / d) · Sign(u)           (the scaled-sign contraction)
//! e_i    = u − decode(msg)                (carry the compression error)
//! ```
//!
//! The scaled sign is a *contractive* compressor: ‖u − C(u)‖² ≤ (1−δ)‖u‖²
//! with δ = ‖u‖₁²/(d‖u‖₂²) — asserted as a property test below. The wire
//! cost is `d + 32` bits (signs + the f32 scale), matching the paper's
//! Table 2.
//!
//! As the paper notes (§1.1), EF cannot track residuals under partial
//! participation; `fl::algorithms` therefore only offers EF with full
//! participation and the server rejects the combination otherwise.

use super::pack::PackedSigns;
use crate::tensor;

/// Per-client error-feedback state.
#[derive(Debug, Clone)]
pub struct EfState {
    residual: Vec<f32>,
    /// Scratch: compensated update.
    u: Vec<f32>,
}

/// The EF message: scaled sign with its scalar.
#[derive(Debug, Clone)]
pub struct EfMessage {
    pub scale: f32, // ‖u‖₁ / d
    pub signs: PackedSigns,
}

impl EfMessage {
    pub fn bits_on_wire(&self) -> u64 {
        self.signs.len() as u64 + 32
    }

    pub fn decode_into(&self, out: &mut [f32]) {
        self.signs.decode_scaled_into(self.scale, out);
    }
}

impl EfState {
    pub fn new(dim: usize) -> Self {
        EfState { residual: vec![0.0; dim], u: vec![0.0; dim] }
    }

    /// Rebuild state from a checkpointed residual (`ckpt::`). The `u`
    /// buffer is pure scratch — it is fully overwritten before every read —
    /// so only the residual participates in the byte-identity contract.
    pub fn from_residual(residual: Vec<f32>) -> Self {
        let u = vec![0.0; residual.len()];
        EfState { residual, u }
    }

    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Compress `update` with error compensation; mutates the residual.
    pub fn step(&mut self, update: &[f32]) -> EfMessage {
        assert_eq!(update.len(), self.residual.len());
        let d = update.len();
        // u = residual + update
        for ((u, &r), &p) in self.u.iter_mut().zip(&self.residual).zip(update) {
            *u = r + p;
        }
        let scale = (tensor::norm_p(&self.u, 1.0) / d as f64) as f32;
        let signs = PackedSigns::from_f32_signs(&self.u);
        // residual = u - scale * sign(u)
        for (r, &u) in self.residual.iter_mut().zip(&self.u) {
            let s = if u >= 0.0 { 1.0 } else { -1.0 };
            *r = u - scale * s;
        }
        EfMessage { scale, signs }
    }

    /// Fused step + dequantize: update the residual and write `decode(msg)`
    /// straight into `out`, skipping the wire message entirely — the
    /// aggregation seam folds the decoded vector anyway. Returns the exact
    /// wire bits of the message that *would* have been sent (`d + 32`).
    /// Bit-identical to `step` + `EfMessage::decode_into` (pinned below):
    /// the decoded coordinate is `scale * (±1.0)`, exactly the product the
    /// residual update already computes.
    pub fn step_dequantized_into(&mut self, update: &[f32], out: &mut [f32]) -> u64 {
        assert_eq!(update.len(), self.residual.len());
        assert_eq!(out.len(), update.len());
        let d = update.len();
        // u = residual + update
        for ((u, &r), &p) in self.u.iter_mut().zip(&self.residual).zip(update) {
            *u = r + p;
        }
        let scale = (tensor::norm_p(&self.u, 1.0) / d as f64) as f32;
        // residual = u - scale * sign(u);  out = scale * sign(u)
        for ((r, o), &u) in self.residual.iter_mut().zip(out.iter_mut()).zip(&self.u) {
            let dec = scale * if u >= 0.0 { 1.0f32 } else { -1.0 };
            *o = dec;
            *r = u - dec;
        }
        d as u64 + 32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    #[test]
    fn residual_plus_message_telescopes() {
        // Invariant: decode(msg) + new_residual == old_residual + update.
        let mut rng = Pcg64::seeded(0);
        let d = 129;
        let mut ef = EfState::new(d);
        let mut out = vec![0.0f32; d];
        for step in 0..20 {
            let update: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let before: Vec<f32> = ef.residual().to_vec();
            let msg = ef.step(&update);
            msg.decode_into(&mut out);
            for j in 0..d {
                let lhs = out[j] + ef.residual()[j];
                let rhs = before[j] + update[j];
                assert!((lhs - rhs).abs() < 1e-5, "step={step} j={j}");
            }
        }
    }

    #[test]
    fn scaled_sign_is_contractive() {
        // ‖u − C(u)‖² ≤ (1 − ‖u‖₁²/(d‖u‖₂²)) ‖u‖² for all u ≠ 0.
        let mut rng = Pcg64::seeded(1);
        for d in [4usize, 64, 1000] {
            for _ in 0..20 {
                let u: Vec<f32> = (0..d).map(|_| (rng.normal() * 3.0) as f32).collect();
                let scale = (tensor::norm_p(&u, 1.0) / d as f64) as f32;
                let mut err = 0.0f64;
                for &ui in &u {
                    let s = if ui >= 0.0 { 1.0 } else { -1.0 };
                    err += (ui as f64 - (scale * s) as f64).powi(2);
                }
                let n1 = tensor::norm_p(&u, 1.0);
                let n2sq = tensor::norm2_sq(&u);
                let delta = n1 * n1 / (d as f64 * n2sq);
                assert!(err <= (1.0 - delta) * n2sq + 1e-6, "d={d}");
            }
        }
    }

    #[test]
    fn fused_step_matches_step_plus_decode() {
        // The seam's fused path: identical residual trajectory and decoded
        // vector, bit for bit, across multiple rounds of state.
        let mut rng = Pcg64::seeded(8);
        let d = 131;
        let mut ef_a = EfState::new(d);
        let mut ef_b = EfState::new(d);
        let mut dec_a = vec![0.0f32; d];
        let mut dec_b = vec![0.0f32; d];
        for step in 0..10 {
            let update: Vec<f32> = (0..d).map(|_| rng.normal() as f32).collect();
            let msg = ef_a.step(&update);
            msg.decode_into(&mut dec_a);
            let bits = ef_b.step_dequantized_into(&update, &mut dec_b);
            assert_eq!(bits, msg.bits_on_wire(), "step={step}");
            for j in 0..d {
                assert_eq!(dec_a[j].to_bits(), dec_b[j].to_bits(), "step={step} j={j}");
                assert_eq!(
                    ef_a.residual()[j].to_bits(),
                    ef_b.residual()[j].to_bits(),
                    "step={step} j={j}"
                );
            }
        }
    }

    #[test]
    fn from_residual_continues_the_trajectory_exactly() {
        // Run 12 rounds straight through; separately run 5, rebuild from the
        // captured residual, run the remaining 7. Residuals and decoded
        // messages must match bit for bit.
        let d = 67;
        let mut rng = Pcg64::seeded(3);
        let updates: Vec<Vec<f32>> =
            (0..12).map(|_| (0..d).map(|_| rng.normal() as f32).collect()).collect();
        let mut whole = EfState::new(d);
        let mut out_w = vec![0.0f32; d];
        let mut first = EfState::new(d);
        let mut out_r = vec![0.0f32; d];
        for u in &updates[..5] {
            whole.step_dequantized_into(u, &mut out_w);
            first.step_dequantized_into(u, &mut out_r);
        }
        let mut resumed = EfState::from_residual(first.residual().to_vec());
        for (step, u) in updates.iter().enumerate().skip(5) {
            whole.step_dequantized_into(u, &mut out_w);
            resumed.step_dequantized_into(u, &mut out_r);
            for j in 0..d {
                assert_eq!(out_w[j].to_bits(), out_r[j].to_bits(), "step={step} j={j}");
                assert_eq!(
                    whole.residual()[j].to_bits(),
                    resumed.residual()[j].to_bits(),
                    "step={step} j={j}"
                );
            }
        }
    }

    #[test]
    fn zero_update_zero_message() {
        let mut ef = EfState::new(8);
        let msg = ef.step(&[0.0; 8]);
        assert_eq!(msg.scale, 0.0);
        assert!(ef.residual().iter().all(|&r| r == 0.0));
    }

    #[test]
    fn wire_cost_is_d_plus_32() {
        let mut ef = EfState::new(100);
        let msg = ef.step(&[1.0; 100]);
        assert_eq!(msg.bits_on_wire(), 132);
    }
}
