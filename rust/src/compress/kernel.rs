//! Fused one-pass sign kernels: perturb → sign → pack without the i8 detour.
//!
//! The scalar reference path (`StochasticSign::compress_into` followed by
//! `PackedSigns::from_signs`) walks the coordinates twice and materializes a
//! d-byte i8 buffer between the walks. These kernels do the whole thing in
//! one pass — draw the z-noise for a 64-coordinate block, compare, and set
//! bits branchlessly straight into the packed `u64` words — with zero heap
//! allocation when the output buffer is reused via [`PackedSigns::reset_for`].
//!
//! ## The RNG stream contract
//!
//! The kernels are **bit-identical** to the scalar reference path, which
//! pins the contract both must obey:
//!
//! * exactly one z-noise value is drawn per coordinate, in coordinate
//!   order, from the client's own `Pcg64` stream (block filling via
//!   [`Pcg64::fill_z_noise_f64`] preserves the draw sequence, cached
//!   Gaussian spare included);
//! * the perturbation arithmetic is `x[j] as f64 + sigma as f64 * ξ[j]`
//!   with the sign taken as `>= 0.0`;
//! * `sigma == 0.0` draws nothing at all (the deterministic SignSGD path).
//!
//! Any change to either side breaks every seeded experiment in the repo;
//! `tests/hotpath_exactness.rs` pins the equivalence across boundary
//! lengths, all `ZParam` families and all `SigmaRule`s — with SIMD
//! dispatch forced off and on.
//!
//! ## SIMD dispatch
//!
//! The noise draws are inherently sequential (the stream contract above),
//! but the compare → sign-bit → word assembly over each block is pure data
//! parallelism. That inner loop — and the whole-slice pack — routes through
//! the runtime-dispatched [`super::simd::SignKernels`] table (AVX2 / NEON /
//! scalar, `ZSFA_SIMD` override), every backend of which is pinned
//! bit-identical to the scalar reference.

use super::pack::PackedSigns;
use super::simd;
use crate::rng::{Pcg64, ZParam};

/// Coordinates per noise block: one packed word, filled in one RNG call.
const BLOCK: usize = 64;

/// Fused `Sign(x + σ·ξ_z)` into a reusable packed buffer. Draws nothing
/// when `sigma == 0.0` (vanilla SignSGD), exactly like the scalar path.
pub fn stochastic_sign_packed(
    x: &[f32],
    z: ZParam,
    sigma: f32,
    rng: &mut Pcg64,
    out: &mut PackedSigns,
) {
    out.reset_for(x.len());
    let k = simd::active();
    if sigma == 0.0 {
        k.pack_words(x, out.words_mut());
        return;
    }
    let s = sigma as f64;
    let mut noise = [0.0f64; BLOCK];
    let words = out.words_mut();
    for (chunk, word) in x.chunks(BLOCK).zip(words.iter_mut()) {
        let nb = &mut noise[..chunk.len()];
        rng.fill_z_noise_f64(z, nb); // sequential: the RNG stream contract
        *word = k.sign_block(chunk, s, nb);
    }
}

/// Fused `Sign(x)` (Sign(0) = +1) into a reusable packed buffer — the
/// allocation-free equivalent of [`PackedSigns::from_f32_signs`].
pub fn pack_f32_signs_into(x: &[f32], out: &mut PackedSigns) {
    out.reset_for(x.len());
    simd::active().pack_words(x, out.words_mut());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::sign::{SigmaRule, StochasticSign};

    fn gen(rng: &mut Pcg64, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect()
    }

    #[test]
    fn fused_matches_scalar_reference_path() {
        // The in-module smoke version of the contract; the full matrix
        // (all SigmaRules × boundary lengths) lives in
        // tests/hotpath_exactness.rs.
        for z in [ZParam::Finite(1), ZParam::Finite(2), ZParam::Inf] {
            for sigma in [0.0f32, 0.8] {
                for d in [0usize, 1, 64, 65, 130] {
                    let mut data_rng = Pcg64::seeded(7);
                    let x = gen(&mut data_rng, d);
                    let mut ra = Pcg64::new(11, 3);
                    let mut rb = ra.clone();
                    let mut comp = StochasticSign::new(z, SigmaRule::Fixed(sigma));
                    let mut signs = vec![0i8; d];
                    comp.compress_into(&x, &mut ra, &mut signs);
                    let want = PackedSigns::from_signs(&signs);
                    let mut got = PackedSigns::zeroed(0);
                    stochastic_sign_packed(&x, z, sigma, &mut rb, &mut got);
                    assert_eq!(got, want, "z={z} sigma={sigma} d={d}");
                    assert_eq!(ra.next_u64(), rb.next_u64(), "z={z} sigma={sigma} d={d}");
                }
            }
        }
    }

    #[test]
    fn pack_f32_signs_into_matches_naive_pack() {
        // Compare against an independent i8-based pack (not from_f32_signs,
        // which now routes through this very kernel).
        let mut rng = Pcg64::seeded(5);
        let mut out = PackedSigns::zeroed(0);
        for d in [0usize, 1, 63, 64, 65, 200] {
            let x = gen(&mut rng, d);
            pack_f32_signs_into(&x, &mut out);
            let signs: Vec<i8> =
                x.iter().map(|&v| if v >= 0.0 { 1i8 } else { -1 }).collect();
            assert_eq!(out, PackedSigns::from_signs(&signs), "d={d}");
            assert_eq!(out.len(), d);
        }
    }
}
