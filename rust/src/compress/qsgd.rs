//! QSGD: the unbiased stochastic quantizer (Alistarh et al. '17),
//! Definition 2 in the paper's Appendix A — the baseline family of Fig. 16.
//!
//! `Q(x)[j] = ‖x‖₂ · Sign(x[j]) · ξ(x[j], s)` where `ξ` rounds `|x[j]|/‖x‖₂·s`
//! to one of the two neighbouring integer levels with probabilities chosen so
//! `E[Q(x)] = x`. Wire cost per coordinate: 1 sign bit + ⌈log2(s+1)⌉ level
//! bits (the paper's Table 2 approximates this as `s·d + 32`; we account
//! exactly, plus 32 bits for the norm).

use super::{Compressor, Message};
use crate::rng::Pcg64;
use crate::tensor;

/// A QSGD-quantized vector.
#[derive(Debug, Clone)]
pub struct Quantized {
    pub norm: f32,
    /// Per-coordinate signed level in [-s, s] (i16 is enough for s ≤ 2^15-1).
    pub levels: Vec<i16>,
    pub s: u32,
}

impl Quantized {
    pub fn bits_on_wire(&self) -> u64 {
        // 32-bit norm + per-coordinate (sign + level) bits.
        32 + (1 + bits_per_level(self.s)) * self.levels.len() as u64
    }

    /// Dequantize into `out`.
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.levels.len());
        let k = self.norm / self.s as f32;
        for (o, &l) in out.iter_mut().zip(&self.levels) {
            *o = k * l as f32;
        }
    }
}

/// Bits to encode a level index in [0, s].
pub fn bits_per_level(s: u32) -> u64 {
    (64 - (s as u64).leading_zeros() as u64).max(1)
}

/// The QSGD compressor with `s` quantization levels.
#[derive(Debug, Clone)]
pub struct Qsgd {
    pub s: u32,
}

impl Qsgd {
    pub fn new(s: u32) -> Self {
        assert!(s >= 1);
        Qsgd { s }
    }

    /// Quantize `x` (allocating). Unbiased: `E[decode(quantize(x))] = x`.
    pub fn quantize(&self, x: &[f32], rng: &mut Pcg64) -> Quantized {
        let norm = tensor::norm2(x) as f32;
        let mut levels = vec![0i16; x.len()];
        if norm > 0.0 {
            let s = self.s as f32;
            for (l, &xi) in levels.iter_mut().zip(x) {
                let r = xi.abs() / norm * s; // in [0, s]
                let lo = r.floor();
                let p_hi = (r - lo) as f64;
                let mut lvl = lo as i16;
                if rng.uniform() < p_hi {
                    lvl += 1;
                }
                *l = if xi >= 0.0 { lvl } else { -lvl };
            }
        }
        Quantized { norm, levels, s: self.s }
    }

    /// Fused quantize + dequantize into `out` — what the aggregation seam
    /// folds — without materializing the `Quantized` levels buffer. RNG
    /// draws and arithmetic are exactly `quantize` followed by
    /// `Quantized::decode_into` (pinned by `fused_matches_quantize_decode`),
    /// so the streamed round reduce stays bit-identical while dropping the
    /// per-client O(d) allocation.
    pub fn quantize_dequantize_into(&self, x: &[f32], rng: &mut Pcg64, out: &mut [f32]) {
        assert_eq!(out.len(), x.len());
        let norm = tensor::norm2(x) as f32;
        let k = norm / self.s as f32;
        if norm > 0.0 {
            let s = self.s as f32;
            for (o, &xi) in out.iter_mut().zip(x) {
                let r = xi.abs() / norm * s; // in [0, s]
                let lo = r.floor();
                let p_hi = (r - lo) as f64;
                let mut lvl = lo as i16;
                if rng.uniform() < p_hi {
                    lvl += 1;
                }
                let l = if xi >= 0.0 { lvl } else { -lvl };
                *o = k * l as f32;
            }
        } else {
            // quantize leaves all levels 0 and draws nothing; decode then
            // writes k·0 = +0.0 everywhere.
            out.iter_mut().for_each(|o| *o = 0.0);
        }
    }
}

impl Compressor for Qsgd {
    fn compress(&mut self, delta: &[f32], rng: &mut Pcg64) -> Message {
        Message::Quantized(self.quantize(delta, rng))
    }

    fn decode_into(&self, msg: &Message, out: &mut [f32]) {
        match msg {
            Message::Quantized(q) => q.decode_into(out),
            _ => panic!("Qsgd::decode_into on non-quantized message"),
        }
    }

    fn name(&self) -> String {
        format!("qsgd(s={})", self.s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_within_range() {
        let q = Qsgd::new(4);
        let mut rng = Pcg64::seeded(0);
        let mut x = vec![0.0f32; 1000];
        for xi in x.iter_mut() {
            *xi = rng.normal() as f32;
        }
        let quant = q.quantize(&x, &mut rng);
        assert!(quant.levels.iter().all(|&l| l.unsigned_abs() as u32 <= 4));
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        let q = Qsgd::new(2);
        let mut rng = Pcg64::seeded(1);
        let x = [0.6f32, -0.3, 0.1, 0.72];
        let reps = 50_000;
        let mut acc = [0.0f64; 4];
        let mut out = [0.0f32; 4];
        for _ in 0..reps {
            let quant = q.quantize(&x, &mut rng);
            quant.decode_into(&mut out);
            for (a, &o) in acc.iter_mut().zip(&out) {
                *a += o as f64;
            }
        }
        let norm = tensor::norm2(&x);
        for (j, &xj) in x.iter().enumerate() {
            let est = acc[j] / reps as f64;
            // MC std per coord <= norm/(s*sqrt(reps)).
            let tol = 5.0 * norm / (2.0 * (reps as f64).sqrt());
            assert!((est - xj as f64).abs() < tol, "j={j} est={est} want={xj}");
        }
    }

    #[test]
    fn variance_bound() {
        // QSGD guarantees E||Q(x)-x||^2 <= min(d/s^2, sqrt(d)/s)||x||^2.
        let d = 256;
        let s = 4u32;
        let q = Qsgd::new(s);
        let mut rng = Pcg64::seeded(2);
        let mut x = vec![0.0f32; d];
        for xi in x.iter_mut() {
            *xi = rng.normal() as f32;
        }
        let reps = 2000;
        let mut err = 0.0f64;
        let mut out = vec![0.0f32; d];
        for _ in 0..reps {
            q.quantize(&x, &mut rng).decode_into(&mut out);
            let mut e = 0.0;
            for (o, &xi) in out.iter().zip(&x) {
                e += (*o as f64 - xi as f64).powi(2);
            }
            err += e;
        }
        let mean_err = err / reps as f64;
        let bound = (d as f64 / (s * s) as f64).min((d as f64).sqrt() / s as f64)
            * tensor::norm2_sq(&x);
        assert!(mean_err <= bound * 1.05, "mean_err={mean_err} bound={bound}");
    }

    #[test]
    fn fused_matches_quantize_decode() {
        // The seam's fused path must equal quantize → decode bit for bit,
        // with identical RNG consumption.
        for s in [1u32, 2, 4, 15] {
            for d in [0usize, 1, 64, 65, 513] {
                let q = Qsgd::new(s);
                let mut data_rng = Pcg64::seeded(31);
                let x: Vec<f32> = (0..d).map(|_| data_rng.normal() as f32).collect();
                let mut ra = Pcg64::new(9, 1);
                let mut rb = ra.clone();
                let mut want = vec![0.0f32; d];
                q.quantize(&x, &mut ra).decode_into(&mut want);
                let mut got = vec![0.0f32; d];
                q.quantize_dequantize_into(&x, &mut rb, &mut got);
                for (j, (w, g)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(w.to_bits(), g.to_bits(), "s={s} d={d} j={j}");
                }
                assert_eq!(ra.next_u64(), rb.next_u64(), "s={s} d={d} rng state");
            }
        }
        // Zero vector: no draws, all +0.0.
        let q = Qsgd::new(2);
        let mut rng = Pcg64::seeded(4);
        let before = rng.clone().next_u64();
        let mut out = [1.0f32; 4];
        q.quantize_dequantize_into(&[0.0; 4], &mut rng, &mut out);
        assert!(out.iter().all(|o| o.to_bits() == 0.0f32.to_bits()));
        assert_eq!(rng.next_u64(), before);
    }

    #[test]
    fn zero_vector() {
        let q = Qsgd::new(1);
        let mut rng = Pcg64::seeded(3);
        let quant = q.quantize(&[0.0; 8], &mut rng);
        assert_eq!(quant.norm, 0.0);
        assert!(quant.levels.iter().all(|&l| l == 0));
        let mut out = [1.0f32; 8];
        quant.decode_into(&mut out);
        assert_eq!(out, [0.0; 8]);
    }

    #[test]
    fn wire_bits() {
        assert_eq!(bits_per_level(1), 1);
        assert_eq!(bits_per_level(2), 2);
        assert_eq!(bits_per_level(4), 3);
        assert_eq!(bits_per_level(8), 4);
        let q = Quantized { norm: 1.0, levels: vec![0; 100], s: 4 };
        assert_eq!(q.bits_on_wire(), 32 + 4 * 100);
    }
}
