//! Gradient sparsification + the sparsified stochastic sign.
//!
//! The paper's conclusion calls out that the stochastic sign compressor
//! "can be conveniently combined with ... gradient sparsification
//! techniques such as [30, 41, 8] to further improve the communication
//! efficiency". This module implements that combination:
//!
//! * [`TopK`] — classic magnitude top-k: k indices + k f32 values
//!   (k·(32+32) bits).
//! * [`SparseSign`] — top-k support + *stochastic sign* of the kept values
//!   with a single f32 magnitude scale: k·(32+1) + 32 bits. This is the
//!   conclusion's combo; the `sparse_sign` ablation bench compares both
//!   against dense signs at equal bit budgets.

use super::{Compressor, Message};
use crate::rng::{Pcg64, ZParam};

/// A sparse uplink payload: values at `idx`, zero elsewhere.
#[derive(Debug, Clone, PartialEq)]
pub struct SparseMessage {
    pub dim: usize,
    pub idx: Vec<u32>,
    /// Either raw values (TopK) or ±scale (SparseSign).
    pub vals: Vec<f32>,
    /// True when `vals` are ±scale (1 bit each on the wire + one shared f32).
    pub sign_coded: bool,
}

impl SparseMessage {
    pub fn bits_on_wire(&self) -> u64 {
        let k = self.idx.len() as u64;
        if self.sign_coded {
            32 * k + k + 32 // indices + sign bits + shared scale
        } else {
            32 * k + 32 * k // indices + f32 values
        }
    }

    /// Scatter into a dense buffer (overwrites).
    pub fn decode_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.dim);
        out.iter_mut().for_each(|v| *v = 0.0);
        for (&i, &v) in self.idx.iter().zip(&self.vals) {
            out[i as usize] = v;
        }
    }
}

/// Indices of the k largest-|x| entries (O(d) selection via partial sort).
pub fn top_k_indices(x: &[f32], k: usize) -> Vec<u32> {
    let mut idx = Vec::new();
    top_k_indices_into(x, k, &mut idx);
    idx
}

/// [`top_k_indices`] into a reusable buffer: after warm-up the selection
/// performs no heap allocation (the aggregation seam's per-worker scratch
/// reuses `idx` across clients and rounds).
pub fn top_k_indices_into(x: &[f32], k: usize, idx: &mut Vec<u32>) {
    let k = k.min(x.len());
    idx.clear();
    idx.extend(0..x.len() as u32);
    idx.select_nth_unstable_by(k.saturating_sub(1), |&a, &b| {
        x[b as usize]
            .abs()
            .partial_cmp(&x[a as usize].abs())
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    idx.truncate(k);
    idx.sort_unstable(); // deterministic order for the wire
}

/// Magnitude top-k compressor (k = ceil(frac·d)).
#[derive(Debug, Clone)]
pub struct TopK {
    pub frac: f32,
}

impl TopK {
    pub fn new(frac: f32) -> Self {
        assert!(frac > 0.0 && frac <= 1.0);
        TopK { frac }
    }

    pub fn k_for(&self, d: usize) -> usize {
        // The relative epsilon guards against f32 representation noise:
        // 0.05f32 * 200 = 10.0000001..., which must yield k = 10, not 11.
        (((self.frac as f64 * d as f64) * (1.0 - 1e-6)).ceil() as usize).clamp(1, d)
    }
}

impl Compressor for TopK {
    fn compress(&mut self, delta: &[f32], _rng: &mut Pcg64) -> Message {
        let k = self.k_for(delta.len());
        let idx = top_k_indices(delta, k);
        let vals = idx.iter().map(|&i| delta[i as usize]).collect();
        Message::Sparse(SparseMessage { dim: delta.len(), idx, vals, sign_coded: false })
    }

    fn decode_into(&self, msg: &Message, out: &mut [f32]) {
        match msg {
            Message::Sparse(s) => s.decode_into(out),
            _ => panic!("TopK::decode_into on non-sparse message"),
        }
    }

    fn name(&self) -> String {
        format!("topk({})", self.frac)
    }
}

/// Top-k support + stochastic sign of the kept values (the conclusion's
/// combination). The shared scale is the mean |value| over the support, so
/// the decoded message is `scale·Sign(v_i + σ·ξ_z)` at the kept indices.
#[derive(Debug, Clone)]
pub struct SparseSign {
    pub frac: f32,
    pub z: ZParam,
    pub sigma: f32,
}

impl SparseSign {
    pub fn new(frac: f32, z: ZParam, sigma: f32) -> Self {
        assert!(frac > 0.0 && frac <= 1.0);
        SparseSign { frac, z, sigma }
    }
}

impl Compressor for SparseSign {
    fn compress(&mut self, delta: &[f32], rng: &mut Pcg64) -> Message {
        let k = TopK::new(self.frac).k_for(delta.len());
        let idx = top_k_indices(delta, k);
        let scale = (idx.iter().map(|&i| delta[i as usize].abs() as f64).sum::<f64>()
            / k as f64) as f32;
        let vals = idx
            .iter()
            .map(|&i| {
                let v = delta[i as usize] as f64 + self.sigma as f64 * rng.z_noise(self.z);
                if v >= 0.0 {
                    scale
                } else {
                    -scale
                }
            })
            .collect();
        Message::Sparse(SparseMessage { dim: delta.len(), idx, vals, sign_coded: true })
    }

    fn decode_into(&self, msg: &Message, out: &mut [f32]) {
        match msg {
            Message::Sparse(s) => s.decode_into(out),
            _ => panic!("SparseSign::decode_into on non-sparse message"),
        }
    }

    fn name(&self) -> String {
        format!("sparse-sign({},{})", self.frac, self.sigma)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{gen_vec_f32, prop_check, PropConfig};

    #[test]
    fn top_k_picks_largest() {
        let x = [0.1f32, -5.0, 2.0, 0.0, -3.0];
        let idx = top_k_indices(&x, 2);
        assert_eq!(idx, vec![1, 4]);
        let idx = top_k_indices(&x, 5);
        assert_eq!(idx.len(), 5);
    }

    #[test]
    fn topk_roundtrip_preserves_kept_values() {
        let mut rng = Pcg64::seeded(0);
        let x = gen_vec_f32(&mut rng, 100, 2.0);
        let mut c = TopK::new(0.1);
        let msg = c.compress(&x, &mut rng);
        let mut out = vec![0.0f32; 100];
        c.decode_into(&msg, &mut out);
        let nonzero = out.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nonzero, 10);
        for (o, xi) in out.iter().zip(&x) {
            assert!(*o == 0.0 || o == xi);
        }
    }

    #[test]
    fn sparse_sign_vals_are_pm_scale() {
        let mut rng = Pcg64::seeded(1);
        let x = gen_vec_f32(&mut rng, 200, 1.0);
        let mut c = SparseSign::new(0.05, ZParam::Finite(1), 0.1);
        match c.compress(&x, &mut rng) {
            Message::Sparse(s) => {
                assert!(s.sign_coded);
                assert_eq!(s.idx.len(), 10);
                let scale = s.vals[0].abs();
                assert!(s.vals.iter().all(|v| (v.abs() - scale).abs() < 1e-6));
            }
            _ => panic!(),
        }
    }

    #[test]
    fn bits_accounting() {
        let s =
            SparseMessage { dim: 1000, idx: vec![1, 2], vals: vec![0.5, -0.5], sign_coded: true };
        assert_eq!(s.bits_on_wire(), 64 + 2 + 32);
        let t =
            SparseMessage { dim: 1000, idx: vec![1, 2], vals: vec![0.5, -0.5], sign_coded: false };
        assert_eq!(t.bits_on_wire(), 64 + 64);
    }

    #[test]
    fn sparse_sign_beats_dense_bits_at_same_k() {
        // frac = 1/33 ~ break-even vs dense 1-bit signs: below that it's cheaper.
        let d = 33_000usize;
        let mut rng = Pcg64::seeded(2);
        let x = gen_vec_f32(&mut rng, d, 1.0);
        let mut c = SparseSign::new(0.01, ZParam::Inf, 0.0);
        let bits = c.compress(&x, &mut rng).bits_on_wire();
        assert!(bits < d as u64, "sparse-sign {bits} vs dense sign {d}");
    }

    #[test]
    fn prop_topk_exact_cover_and_order() {
        prop_check(
            PropConfig { cases: 60, max_size: 2000, seed: 0x70b },
            |rng, size| {
                let d = size.max(2);
                let frac = [0.01f32, 0.1, 0.5, 1.0][rng.below(4) as usize];
                (gen_vec_f32(rng, d, 2.0), frac)
            },
            |(x, frac)| {
                let k = TopK::new(*frac).k_for(x.len());
                let idx = top_k_indices(x, k);
                if idx.len() != k {
                    return Err(format!("got {} indices, want {k}", idx.len()));
                }
                // Sorted, unique, in range.
                if !idx.windows(2).all(|w| w[0] < w[1]) {
                    return Err("indices not strictly sorted".into());
                }
                // Every kept |value| >= every dropped |value|.
                let kept_min = idx
                    .iter()
                    .map(|&i| x[i as usize].abs())
                    .fold(f32::INFINITY, f32::min);
                let dropped_max = (0..x.len() as u32)
                    .filter(|i| idx.binary_search(i).is_err())
                    .map(|i| x[i as usize].abs())
                    .fold(0.0f32, f32::max);
                if dropped_max > kept_min + 1e-6 {
                    return Err(format!("dropped {dropped_max} > kept {kept_min}"));
                }
                Ok(())
            },
        );
    }
}
