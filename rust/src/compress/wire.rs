//! Wire format: byte-level serialization of uplink messages.
//!
//! A deployed coordinator doesn't ship `Vec<i8>`s — it ships framed byte
//! buffers. This module defines the (little-endian) frame used by the
//! transport simulation in `net/` and asserts, in tests, that the frame
//! sizes match the *information-theoretic* bit accounting the figures use
//! (`Message::bits_on_wire`, up to the fixed header).
//!
//! Frame layout:
//!   [0]      u8   message tag (1 = signs, 2 = qsgd, 3 = dense)
//!   [1..9]   u64  coordinate count d
//!   payload  tag-specific (see below)
//!   [-4..]   u32  FNV-1a checksum of everything before it
//!
//! Sign payload: ceil(d/64) u64 words (exactly the `PackedSigns` backing).
//! QSGD payload: f32 norm, u32 s, then d levels bit-packed at
//!   (1 + ceil(log2(s+1))) bits each.
//! Dense payload: d f32s.

use super::pack::PackedSigns;
use super::qsgd::{bits_per_level, Quantized};
use super::Message;

const TAG_SIGNS: u8 = 1;
const TAG_QSGD: u8 = 2;
const TAG_DENSE: u8 = 3;
const TAG_SPARSE: u8 = 4;

/// FNV-1a over a byte slice.
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Serialization/deserialization errors.
#[derive(Debug, PartialEq, Eq)]
pub enum WireError {
    Truncated,
    BadChecksum,
    BadTag(u8),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadChecksum => write!(f, "checksum mismatch"),
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
        }
    }
}

impl std::error::Error for WireError {}

/// A little-endian bit writer (MSB-last within each byte).
struct BitWriter {
    bytes: Vec<u8>,
    bit: u32, // bits used in the last byte
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { bytes: Vec::new(), bit: 8 }
    }

    fn push(&mut self, value: u64, nbits: u32) {
        debug_assert!(nbits <= 64);
        for i in 0..nbits {
            if self.bit == 8 {
                self.bytes.push(0);
                self.bit = 0;
            }
            let b = ((value >> i) & 1) as u8;
            let last = self.bytes.len() - 1;
            self.bytes[last] |= b << self.bit;
            self.bit += 1;
        }
    }
}

/// Matching bit reader.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize, // absolute bit position
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    fn pull(&mut self, nbits: u32) -> Result<u64, WireError> {
        let mut v = 0u64;
        for i in 0..nbits {
            let byte = self.pos / 8;
            if byte >= self.bytes.len() {
                return Err(WireError::Truncated);
            }
            let bit = (self.bytes[byte] >> (self.pos % 8)) & 1;
            v |= (bit as u64) << i;
            self.pos += 1;
        }
        Ok(v)
    }
}

/// Serialize a message into a framed byte buffer.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Message::Signs(p) => {
            out.push(TAG_SIGNS);
            out.extend_from_slice(&(p.len() as u64).to_le_bytes());
            for w in p.words() {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        Message::Quantized(q) => {
            out.push(TAG_QSGD);
            out.extend_from_slice(&(q.levels.len() as u64).to_le_bytes());
            out.extend_from_slice(&q.norm.to_le_bytes());
            out.extend_from_slice(&q.s.to_le_bytes());
            let nbits = 1 + bits_per_level(q.s) as u32;
            let mut bw = BitWriter::new();
            for &l in &q.levels {
                let sign_bit = if l < 0 { 1u64 } else { 0 };
                let mag = l.unsigned_abs() as u64;
                bw.push(sign_bit | (mag << 1), nbits);
            }
            out.extend_from_slice(&bw.bytes);
        }
        Message::Dense(v) => {
            out.push(TAG_DENSE);
            out.extend_from_slice(&(v.len() as u64).to_le_bytes());
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Message::Sparse(s) => {
            out.push(TAG_SPARSE);
            out.extend_from_slice(&(s.dim as u64).to_le_bytes());
            out.extend_from_slice(&(s.idx.len() as u64).to_le_bytes());
            out.push(s.sign_coded as u8);
            for i in &s.idx {
                out.extend_from_slice(&i.to_le_bytes());
            }
            if s.sign_coded {
                // One shared scale + 1 bit per value.
                let scale = s.vals.first().map(|v| v.abs()).unwrap_or(0.0);
                out.extend_from_slice(&scale.to_le_bytes());
                let mut bw = BitWriter::new();
                for v in &s.vals {
                    bw.push((*v < 0.0) as u64, 1);
                }
                out.extend_from_slice(&bw.bytes);
            } else {
                for v in &s.vals {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    let ck = fnv1a(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Parse a framed byte buffer back into a message.
pub fn decode(bytes: &[u8]) -> Result<Message, WireError> {
    if bytes.len() < 13 {
        return Err(WireError::Truncated);
    }
    let (body, ck_bytes) = bytes.split_at(bytes.len() - 4);
    let ck = u32::from_le_bytes(ck_bytes.try_into().unwrap());
    if fnv1a(body) != ck {
        return Err(WireError::BadChecksum);
    }
    let tag = body[0];
    let d = u64::from_le_bytes(body[1..9].try_into().unwrap()) as usize;
    let payload = &body[9..];
    match tag {
        TAG_SIGNS => {
            let words = d.div_ceil(64);
            if payload.len() != words * 8 {
                return Err(WireError::Truncated);
            }
            let mut signs = vec![0i8; d];
            for (j, s) in signs.iter_mut().enumerate() {
                let w = u64::from_le_bytes(payload[j / 64 * 8..j / 64 * 8 + 8].try_into().unwrap());
                *s = if w >> (j % 64) & 1 == 1 { 1 } else { -1 };
            }
            Ok(Message::Signs(PackedSigns::from_signs(&signs)))
        }
        TAG_QSGD => {
            if payload.len() < 8 {
                return Err(WireError::Truncated);
            }
            let norm = f32::from_le_bytes(payload[0..4].try_into().unwrap());
            let s = u32::from_le_bytes(payload[4..8].try_into().unwrap());
            let nbits = 1 + bits_per_level(s) as u32;
            let mut br = BitReader::new(&payload[8..]);
            let mut levels = vec![0i16; d];
            for l in levels.iter_mut() {
                let v = br.pull(nbits)?;
                let mag = (v >> 1) as i16;
                *l = if v & 1 == 1 { -mag } else { mag };
            }
            Ok(Message::Quantized(Quantized { norm, levels, s }))
        }
        TAG_DENSE => {
            if payload.len() != d * 4 {
                return Err(WireError::Truncated);
            }
            let v = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Message::Dense(v))
        }
        TAG_SPARSE => {
            if payload.len() < 9 {
                return Err(WireError::Truncated);
            }
            let k = u64::from_le_bytes(payload[0..8].try_into().unwrap()) as usize;
            let sign_coded = payload[8] != 0;
            let mut pos = 9;
            if payload.len() < pos + 4 * k {
                return Err(WireError::Truncated);
            }
            let idx: Vec<u32> = (0..k)
                .map(|j| {
                    u32::from_le_bytes(payload[pos + 4 * j..pos + 4 * j + 4].try_into().unwrap())
                })
                .collect();
            pos += 4 * k;
            let vals: Vec<f32> = if sign_coded {
                if payload.len() < pos + 4 {
                    return Err(WireError::Truncated);
                }
                let scale = f32::from_le_bytes(payload[pos..pos + 4].try_into().unwrap());
                pos += 4;
                let mut br = BitReader::new(&payload[pos..]);
                (0..k)
                    .map(|_| br.pull(1).map(|b| if b == 1 { -scale } else { scale }))
                    .collect::<Result<_, _>>()?
            } else {
                if payload.len() < pos + 4 * k {
                    return Err(WireError::Truncated);
                }
                (0..k)
                    .map(|j| {
                        let raw = payload[pos + 4 * j..pos + 4 * j + 4].try_into().unwrap();
                        f32::from_le_bytes(raw)
                    })
                    .collect()
            };
            Ok(Message::Sparse(crate::compress::sparsify::SparseMessage {
                dim: d,
                idx,
                vals,
                sign_coded,
            }))
        }
        t => Err(WireError::BadTag(t)),
    }
}

/// Frame overhead in bits (tag + length + checksum).
pub const FRAME_OVERHEAD_BITS: u64 = 8 * 13;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::qsgd::Qsgd;
    use crate::compress::sign::StochasticSign;
    use crate::compress::Compressor;
    use crate::rng::Pcg64;
    use crate::testutil::{gen_vec_f32, prop_check, PropConfig};

    fn roundtrip(msg: &Message) -> Message {
        decode(&encode(msg)).unwrap()
    }

    #[test]
    fn signs_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        for d in [1usize, 63, 64, 65, 1000] {
            let x = gen_vec_f32(&mut rng, d, 1.0);
            let msg = StochasticSign::deterministic().compress(&x, &mut rng);
            match (&msg, &roundtrip(&msg)) {
                (Message::Signs(a), Message::Signs(b)) => assert_eq!(a, b, "d={d}"),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn qsgd_roundtrip() {
        let mut rng = Pcg64::seeded(2);
        for s in [1u32, 2, 4, 8, 100] {
            let x = gen_vec_f32(&mut rng, 257, 2.0);
            let msg = Qsgd::new(s).compress(&x, &mut rng);
            match (&msg, &roundtrip(&msg)) {
                (Message::Quantized(a), Message::Quantized(b)) => {
                    assert_eq!(a.norm, b.norm);
                    assert_eq!(a.s, b.s);
                    assert_eq!(a.levels, b.levels);
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn dense_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        match roundtrip(&Message::Dense(v.clone())) {
            Message::Dense(w) => assert_eq!(v, w),
            _ => panic!(),
        }
    }

    #[test]
    fn frame_size_matches_bit_accounting() {
        // Encoded length must equal ceil(bits_on_wire/8) + overhead + padding
        // (sign payload pads to whole u64 words; qsgd to whole bytes).
        let mut rng = Pcg64::seeded(3);
        let x = gen_vec_f32(&mut rng, 1000, 1.0);
        let sign_msg = StochasticSign::deterministic().compress(&x, &mut rng);
        let enc = encode(&sign_msg);
        let payload_bits = (enc.len() as u64) * 8 - FRAME_OVERHEAD_BITS;
        let ideal = sign_msg.bits_on_wire();
        assert!(payload_bits >= ideal && payload_bits < ideal + 64, "{payload_bits} vs {ideal}");

        let q_msg = Qsgd::new(4).compress(&x, &mut rng);
        let enc = encode(&q_msg);
        let payload_bits = (enc.len() as u64) * 8 - FRAME_OVERHEAD_BITS;
        // Quantized accounting includes 32 bits norm; frame adds 32-bit s.
        let ideal = q_msg.bits_on_wire() + 32;
        assert!(payload_bits >= ideal && payload_bits < ideal + 8, "{payload_bits} vs {ideal}");
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Pcg64::seeded(4);
        let x = gen_vec_f32(&mut rng, 100, 1.0);
        let mut enc = encode(&StochasticSign::deterministic().compress(&x, &mut rng));
        enc[10] ^= 0x40;
        assert_eq!(decode(&enc).unwrap_err(), WireError::BadChecksum);
        assert_eq!(decode(&enc[..5]).unwrap_err(), WireError::Truncated);
        assert_eq!(decode(&[]).unwrap_err(), WireError::Truncated);
    }

    #[test]
    fn bad_tag_rejected() {
        let mut frame = vec![9u8]; // bogus tag
        frame.extend_from_slice(&0u64.to_le_bytes());
        let ck = super::fnv1a(&frame);
        frame.extend_from_slice(&ck.to_le_bytes());
        assert_eq!(decode(&frame).unwrap_err(), WireError::BadTag(9));
    }

    #[test]
    fn sparse_roundtrip_both_codings() {
        use crate::compress::sparsify::{SparseSign, TopK};
        use crate::rng::ZParam;
        let mut rng = Pcg64::seeded(5);
        let x = gen_vec_f32(&mut rng, 500, 2.0);
        for msg in [
            TopK::new(0.05).compress(&x, &mut rng),
            SparseSign::new(0.05, ZParam::Finite(1), 0.2).compress(&x, &mut rng),
        ] {
            match (&msg, &roundtrip(&msg)) {
                (Message::Sparse(a), Message::Sparse(b)) => {
                    assert_eq!(a.idx, b.idx);
                    assert_eq!(a.dim, b.dim);
                    assert_eq!(a.sign_coded, b.sign_coded);
                    for (x, y) in a.vals.iter().zip(&b.vals) {
                        assert!((x - y).abs() < 1e-6);
                    }
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn prop_any_compressor_output_roundtrips() {
        prop_check(
            PropConfig { cases: 60, max_size: 2048, seed: 0x3173 },
            |rng, size| {
                let d = size.max(1);
                let x = gen_vec_f32(rng, d, 3.0);
                let which = rng.below(3);
                let seed = rng.next_u64();
                (x, which, seed)
            },
            |(x, which, seed)| {
                let mut rng = Pcg64::seeded(*seed);
                let msg = match which {
                    0 => StochasticSign::deterministic().compress(x, &mut rng),
                    1 => Qsgd::new(1 + (seed % 7) as u32).compress(x, &mut rng),
                    _ => Message::Dense(x.clone()),
                };
                let back = decode(&encode(&msg)).map_err(|e| e.to_string())?;
                match (&msg, &back) {
                    (Message::Signs(a), Message::Signs(b)) if a == b => Ok(()),
                    (Message::Quantized(a), Message::Quantized(b))
                        if a.levels == b.levels && a.norm == b.norm => Ok(()),
                    (Message::Dense(a), Message::Dense(b)) if a == b => Ok(()),
                    _ => Err("roundtrip mismatch".into()),
                }
            },
        );
    }
}
