//! Wire format: byte-level serialization of uplink messages.
//!
//! A deployed coordinator doesn't ship `Vec<i8>`s — it ships framed byte
//! buffers. This module defines the (little-endian) frame used by the
//! transport simulation in `net/` and asserts, in tests, that the frame
//! sizes match the *information-theoretic* bit accounting the figures use
//! (`Message::bits_on_wire`, up to the fixed header).
//!
//! Frame layout:
//!   [0]      u8   message tag (1 = signs, 2 = qsgd, 3 = dense)
//!   [1..9]   u64  coordinate count d
//!   payload  tag-specific (see below)
//!   [-4..]   u32  FNV-1a checksum of everything before it
//!
//! Sign payload: ceil(d/64) u64 words (exactly the `PackedSigns` backing).
//! QSGD payload: f32 norm, u32 s, then d levels bit-packed at
//!   (1 + ceil(log2(s+1))) bits each.
//! Dense payload: d f32s.

use super::pack::PackedSigns;
use super::qsgd::{bits_per_level, Quantized};
use super::Message;

const TAG_SIGNS: u8 = 1;
const TAG_QSGD: u8 = 2;
const TAG_DENSE: u8 = 3;
const TAG_SPARSE: u8 = 4;

/// FNV-1a over a byte slice.
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

/// Serialization/deserialization errors.
#[derive(Debug, PartialEq, Eq)]
pub enum WireError {
    Truncated,
    BadChecksum,
    BadTag(u8),
    /// Frame is well-sized and checksummed but its contents are
    /// unrepresentable (e.g. a sparse index outside the claimed dimension).
    Corrupt,
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Truncated => write!(f, "truncated frame"),
            WireError::BadChecksum => write!(f, "checksum mismatch"),
            WireError::BadTag(t) => write!(f, "unknown message tag {t}"),
            WireError::Corrupt => write!(f, "malformed frame contents"),
        }
    }
}

impl std::error::Error for WireError {}

/// A little-endian bit writer (MSB-last within each byte).
struct BitWriter {
    bytes: Vec<u8>,
    bit: u32, // bits used in the last byte
}

impl BitWriter {
    fn new() -> Self {
        BitWriter { bytes: Vec::new(), bit: 8 }
    }

    fn push(&mut self, value: u64, nbits: u32) {
        debug_assert!(nbits <= 64);
        for i in 0..nbits {
            if self.bit == 8 {
                self.bytes.push(0);
                self.bit = 0;
            }
            let b = ((value >> i) & 1) as u8;
            let last = self.bytes.len() - 1;
            self.bytes[last] |= b << self.bit;
            self.bit += 1;
        }
    }
}

/// Matching bit reader.
struct BitReader<'a> {
    bytes: &'a [u8],
    pos: usize, // absolute bit position
}

impl<'a> BitReader<'a> {
    fn new(bytes: &'a [u8]) -> Self {
        BitReader { bytes, pos: 0 }
    }

    fn pull(&mut self, nbits: u32) -> Result<u64, WireError> {
        let mut v = 0u64;
        for i in 0..nbits {
            let byte = self.pos / 8;
            if byte >= self.bytes.len() {
                return Err(WireError::Truncated);
            }
            let bit = (self.bytes[byte] >> (self.pos % 8)) & 1;
            v |= (bit as u64) << i;
            self.pos += 1;
        }
        Ok(v)
    }
}

/// Serialize a message into a framed byte buffer.
pub fn encode(msg: &Message) -> Vec<u8> {
    let mut out = Vec::new();
    match msg {
        Message::Signs(p) => {
            out.push(TAG_SIGNS);
            out.extend_from_slice(&(p.len() as u64).to_le_bytes());
            for w in p.words() {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
        Message::Quantized(q) => {
            out.push(TAG_QSGD);
            out.extend_from_slice(&(q.levels.len() as u64).to_le_bytes());
            out.extend_from_slice(&q.norm.to_le_bytes());
            out.extend_from_slice(&q.s.to_le_bytes());
            let nbits = 1 + bits_per_level(q.s) as u32;
            let mut bw = BitWriter::new();
            for &l in &q.levels {
                let sign_bit = if l < 0 { 1u64 } else { 0 };
                let mag = l.unsigned_abs() as u64;
                bw.push(sign_bit | (mag << 1), nbits);
            }
            out.extend_from_slice(&bw.bytes);
        }
        Message::Dense(v) => {
            out.push(TAG_DENSE);
            out.extend_from_slice(&(v.len() as u64).to_le_bytes());
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Message::Sparse(s) => {
            out.push(TAG_SPARSE);
            out.extend_from_slice(&(s.dim as u64).to_le_bytes());
            out.extend_from_slice(&(s.idx.len() as u64).to_le_bytes());
            out.push(s.sign_coded as u8);
            for i in &s.idx {
                out.extend_from_slice(&i.to_le_bytes());
            }
            if s.sign_coded {
                // One shared scale + 1 bit per value.
                let scale = s.vals.first().map(|v| v.abs()).unwrap_or(0.0);
                out.extend_from_slice(&scale.to_le_bytes());
                let mut bw = BitWriter::new();
                for v in &s.vals {
                    bw.push((*v < 0.0) as u64, 1);
                }
                out.extend_from_slice(&bw.bytes);
            } else {
                for v in &s.vals {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            }
        }
    }
    let ck = fnv1a(&out);
    out.extend_from_slice(&ck.to_le_bytes());
    out
}

/// Parse a framed byte buffer back into a message.
///
/// Hardened against adversarial frames: every length field is validated
/// against the actual payload size in wide (u128) arithmetic *before* any
/// allocation or slicing, so a hostile 2^64-element length can neither
/// overflow an offset computation nor make *this function* allocate
/// beyond O(payload). A frame that survives the checksum but lies about
/// its lengths is `Truncated`; one whose sparse indices fall outside the
/// claimed dimension is `Corrupt` (so `SparseMessage::decode_into` can
/// never scatter out of bounds). The sparse `dim` itself is metadata the
/// frame cannot prove — callers sizing dense buffers from it must still
/// bound it against their model dimension.
pub fn decode(bytes: &[u8]) -> Result<Message, WireError> {
    if bytes.len() < 13 {
        return Err(WireError::Truncated);
    }
    let (body, ck_bytes) = bytes.split_at(bytes.len() - 4);
    let ck = u32::from_le_bytes(ck_bytes.try_into().unwrap());
    if fnv1a(body) != ck {
        return Err(WireError::BadChecksum);
    }
    let tag = body[0];
    let d64 = u64::from_le_bytes(body[1..9].try_into().unwrap());
    let payload = &body[9..];
    let avail = payload.len() as u128;
    match tag {
        TAG_SIGNS => {
            // ceil(d/64) whole u64 words; validates d before the alloc.
            let words = (d64 as u128).div_ceil(64);
            if avail != words * 8 {
                return Err(WireError::Truncated);
            }
            let d = d64 as usize;
            let mut signs = vec![0i8; d];
            for (j, s) in signs.iter_mut().enumerate() {
                let w = u64::from_le_bytes(payload[j / 64 * 8..j / 64 * 8 + 8].try_into().unwrap());
                *s = if w >> (j % 64) & 1 == 1 { 1 } else { -1 };
            }
            Ok(Message::Signs(PackedSigns::from_signs(&signs)))
        }
        TAG_QSGD => {
            if payload.len() < 8 {
                return Err(WireError::Truncated);
            }
            let norm = f32::from_le_bytes(payload[0..4].try_into().unwrap());
            let s = u32::from_le_bytes(payload[4..8].try_into().unwrap());
            let nbits = 1 + bits_per_level(s) as u32;
            // d levels at nbits each must fit the remaining bytes (the
            // encoder pads to a whole byte, hence `>` not `!=`).
            if d64 as u128 * nbits as u128 > (avail - 8) * 8 {
                return Err(WireError::Truncated);
            }
            let mut br = BitReader::new(&payload[8..]);
            let mut levels = vec![0i16; d64 as usize];
            for l in levels.iter_mut() {
                let v = br.pull(nbits)?;
                let mag = (v >> 1) as i16;
                *l = if v & 1 == 1 { -mag } else { mag };
            }
            Ok(Message::Quantized(Quantized { norm, levels, s }))
        }
        TAG_DENSE => {
            if avail != d64 as u128 * 4 {
                return Err(WireError::Truncated);
            }
            let v = payload
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect();
            Ok(Message::Dense(v))
        }
        TAG_SPARSE => {
            if payload.len() < 9 {
                return Err(WireError::Truncated);
            }
            let k64 = u64::from_le_bytes(payload[0..8].try_into().unwrap());
            let sign_coded = payload[8] != 0;
            // Minimum size before touching any offset: k u32 indices plus
            // either (shared scale + k sign bits) or k f32 values.
            let need = if sign_coded {
                9 + k64 as u128 * 4 + 4 + (k64 as u128).div_ceil(8)
            } else {
                9 + k64 as u128 * 8
            };
            if need > avail || k64 as u128 > d64 as u128 {
                return Err(WireError::Truncated);
            }
            let k = k64 as usize;
            let mut pos = 9;
            let idx: Vec<u32> = (0..k)
                .map(|j| {
                    u32::from_le_bytes(payload[pos + 4 * j..pos + 4 * j + 4].try_into().unwrap())
                })
                .collect();
            if idx.iter().any(|&i| i as u64 >= d64) {
                return Err(WireError::Corrupt);
            }
            pos += 4 * k;
            let vals: Vec<f32> = if sign_coded {
                let scale = f32::from_le_bytes(payload[pos..pos + 4].try_into().unwrap());
                pos += 4;
                let mut br = BitReader::new(&payload[pos..]);
                (0..k)
                    .map(|_| br.pull(1).map(|b| if b == 1 { -scale } else { scale }))
                    .collect::<Result<_, _>>()?
            } else {
                (0..k)
                    .map(|j| {
                        let raw = payload[pos + 4 * j..pos + 4 * j + 4].try_into().unwrap();
                        f32::from_le_bytes(raw)
                    })
                    .collect()
            };
            Ok(Message::Sparse(crate::compress::sparsify::SparseMessage {
                dim: d64 as usize,
                idx,
                vals,
                sign_coded,
            }))
        }
        t => Err(WireError::BadTag(t)),
    }
}

/// Frame overhead in bits (tag + length + checksum).
pub const FRAME_OVERHEAD_BITS: u64 = 8 * 13;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::qsgd::Qsgd;
    use crate::compress::sign::StochasticSign;
    use crate::compress::Compressor;
    use crate::rng::Pcg64;
    use crate::testutil::{gen_vec_f32, prop_check, PropConfig};

    fn roundtrip(msg: &Message) -> Message {
        decode(&encode(msg)).unwrap()
    }

    #[test]
    fn signs_roundtrip() {
        let mut rng = Pcg64::seeded(1);
        for d in [1usize, 63, 64, 65, 1000] {
            let x = gen_vec_f32(&mut rng, d, 1.0);
            let msg = StochasticSign::deterministic().compress(&x, &mut rng);
            match (&msg, &roundtrip(&msg)) {
                (Message::Signs(a), Message::Signs(b)) => assert_eq!(a, b, "d={d}"),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn qsgd_roundtrip() {
        let mut rng = Pcg64::seeded(2);
        for s in [1u32, 2, 4, 8, 100] {
            let x = gen_vec_f32(&mut rng, 257, 2.0);
            let msg = Qsgd::new(s).compress(&x, &mut rng);
            match (&msg, &roundtrip(&msg)) {
                (Message::Quantized(a), Message::Quantized(b)) => {
                    assert_eq!(a.norm, b.norm);
                    assert_eq!(a.s, b.s);
                    assert_eq!(a.levels, b.levels);
                }
                _ => panic!(),
            }
        }
    }

    #[test]
    fn dense_roundtrip() {
        let v = vec![1.5f32, -2.25, 0.0, f32::MIN_POSITIVE];
        match roundtrip(&Message::Dense(v.clone())) {
            Message::Dense(w) => assert_eq!(v, w),
            _ => panic!(),
        }
    }

    #[test]
    fn frame_size_matches_bit_accounting() {
        // Encoded length must equal ceil(bits_on_wire/8) + overhead + padding
        // (sign payload pads to whole u64 words; qsgd to whole bytes).
        let mut rng = Pcg64::seeded(3);
        let x = gen_vec_f32(&mut rng, 1000, 1.0);
        let sign_msg = StochasticSign::deterministic().compress(&x, &mut rng);
        let enc = encode(&sign_msg);
        let payload_bits = (enc.len() as u64) * 8 - FRAME_OVERHEAD_BITS;
        let ideal = sign_msg.bits_on_wire();
        assert!(payload_bits >= ideal && payload_bits < ideal + 64, "{payload_bits} vs {ideal}");

        let q_msg = Qsgd::new(4).compress(&x, &mut rng);
        let enc = encode(&q_msg);
        let payload_bits = (enc.len() as u64) * 8 - FRAME_OVERHEAD_BITS;
        // Quantized accounting includes 32 bits norm; frame adds 32-bit s.
        let ideal = q_msg.bits_on_wire() + 32;
        assert!(payload_bits >= ideal && payload_bits < ideal + 8, "{payload_bits} vs {ideal}");
    }

    #[test]
    fn corruption_detected() {
        let mut rng = Pcg64::seeded(4);
        let x = gen_vec_f32(&mut rng, 100, 1.0);
        let mut enc = encode(&StochasticSign::deterministic().compress(&x, &mut rng));
        enc[10] ^= 0x40;
        assert_eq!(decode(&enc).unwrap_err(), WireError::BadChecksum);
        assert_eq!(decode(&enc[..5]).unwrap_err(), WireError::Truncated);
        assert_eq!(decode(&[]).unwrap_err(), WireError::Truncated);
    }

    #[test]
    fn bad_tag_rejected() {
        let mut frame = vec![9u8]; // bogus tag
        frame.extend_from_slice(&0u64.to_le_bytes());
        let ck = super::fnv1a(&frame);
        frame.extend_from_slice(&ck.to_le_bytes());
        assert_eq!(decode(&frame).unwrap_err(), WireError::BadTag(9));
    }

    #[test]
    fn sparse_roundtrip_both_codings() {
        use crate::compress::sparsify::{SparseSign, TopK};
        use crate::rng::ZParam;
        let mut rng = Pcg64::seeded(5);
        let x = gen_vec_f32(&mut rng, 500, 2.0);
        for msg in [
            TopK::new(0.05).compress(&x, &mut rng),
            SparseSign::new(0.05, ZParam::Finite(1), 0.2).compress(&x, &mut rng),
        ] {
            match (&msg, &roundtrip(&msg)) {
                (Message::Sparse(a), Message::Sparse(b)) => {
                    assert_eq!(a.idx, b.idx);
                    assert_eq!(a.dim, b.dim);
                    assert_eq!(a.sign_coded, b.sign_coded);
                    for (x, y) in a.vals.iter().zip(&b.vals) {
                        assert!((x - y).abs() < 1e-6);
                    }
                }
                _ => panic!(),
            }
        }
    }

    /// One valid frame per tag (signs, qsgd, dense, sparse sign-coded,
    /// sparse raw-valued) for the adversarial suites below.
    fn frames_of_every_tag() -> Vec<Vec<u8>> {
        use crate::compress::sparsify::{SparseSign, TopK};
        use crate::rng::ZParam;
        let mut rng = Pcg64::seeded(0xad5e_c0de);
        let x = gen_vec_f32(&mut rng, 130, 2.0);
        vec![
            encode(&StochasticSign::deterministic().compress(&x, &mut rng)),
            encode(&Qsgd::new(4).compress(&x, &mut rng)),
            encode(&Message::Dense(x.clone())),
            encode(&SparseSign::new(0.1, ZParam::Finite(1), 0.2).compress(&x, &mut rng)),
            encode(&TopK::new(0.1).compress(&x, &mut rng)),
        ]
    }

    /// Frame a raw body (tag + length + payload) with a valid checksum, so
    /// tests reach the per-tag validation rather than the checksum gate.
    fn frame_with_valid_checksum(body: &[u8]) -> Vec<u8> {
        let mut out = body.to_vec();
        out.extend_from_slice(&super::fnv1a(body).to_le_bytes());
        out
    }

    #[test]
    fn truncated_at_every_length_is_an_error() {
        // Every proper prefix of every tag's frame must decode to Err —
        // never a panic, never a bogus Ok.
        for frame in frames_of_every_tag() {
            for len in 0..frame.len() {
                assert!(
                    decode(&frame[..len]).is_err(),
                    "prefix {len}/{} of tag {} decoded",
                    frame.len(),
                    frame[0]
                );
            }
        }
    }

    #[test]
    fn every_single_byte_flip_is_detected() {
        // FNV-1a folds every byte, so any single-byte corruption —
        // including in the checksum itself — must surface as an error.
        // Covers all four tags (TAG_SPARSE in both value codings).
        for frame in frames_of_every_tag() {
            for pos in 0..frame.len() {
                for mask in [0x01u8, 0x80] {
                    let mut bad = frame.clone();
                    bad[pos] ^= mask;
                    assert!(
                        decode(&bad).is_err(),
                        "flip {mask:#x} at {pos} in tag {} went undetected",
                        frame[0]
                    );
                }
            }
        }
    }

    #[test]
    fn flipped_checksum_bytes_report_bad_checksum() {
        let frame = &frames_of_every_tag()[0];
        for back in 1..=4 {
            let mut bad = frame.clone();
            let pos = frame.len() - back;
            bad[pos] ^= 0xff;
            assert_eq!(decode(&bad).unwrap_err(), WireError::BadChecksum, "byte -{back}");
        }
    }

    #[test]
    fn unknown_tags_rejected_for_any_tag_byte() {
        for tag in [0u8, 5, 77, 255] {
            let mut body = vec![tag];
            body.extend_from_slice(&0u64.to_le_bytes());
            let frame = frame_with_valid_checksum(&body);
            assert_eq!(decode(&frame).unwrap_err(), WireError::BadTag(tag), "tag {tag}");
        }
    }

    #[test]
    fn length_field_overflow_cannot_allocate_or_wrap() {
        // d = u64::MAX with a tiny payload and a *valid* checksum: the
        // length validation must reject it before any offset arithmetic
        // (usize overflow) or allocation (OOM) can happen. (TAG_SPARSE's
        // second length field gets its own overflow test below.)
        for tag in [TAG_SIGNS, TAG_QSGD, TAG_DENSE] {
            for d in [u64::MAX, u64::MAX / 4, (u32::MAX as u64) + 1] {
                let mut body = vec![tag];
                body.extend_from_slice(&d.to_le_bytes());
                // Enough payload to pass the per-tag minimum-size checks.
                body.extend_from_slice(&[0u8; 16]);
                let frame = frame_with_valid_checksum(&body);
                assert_eq!(
                    decode(&frame).unwrap_err(),
                    WireError::Truncated,
                    "tag {tag} d {d}"
                );
            }
        }
    }

    #[test]
    fn sparse_count_field_overflow_rejected() {
        // TAG_SPARSE carries a second length (k): a hostile k near
        // u64::MAX must be caught by the wide-arithmetic size check, in
        // both value codings.
        for sign_coded in [0u8, 1] {
            for k in [u64::MAX, u64::MAX / 4 - 2, 1u64 << 62] {
                let mut body = vec![TAG_SPARSE];
                body.extend_from_slice(&1000u64.to_le_bytes()); // plausible d
                body.extend_from_slice(&k.to_le_bytes());
                body.push(sign_coded);
                body.extend_from_slice(&[0u8; 64]);
                let frame = frame_with_valid_checksum(&body);
                assert_eq!(
                    decode(&frame).unwrap_err(),
                    WireError::Truncated,
                    "sign_coded {sign_coded} k {k}"
                );
            }
        }
    }

    #[test]
    fn sparse_out_of_range_index_rejected() {
        // A checksummed frame claiming dim = 100 but carrying idx = 5000
        // must fail decode, or SparseMessage::decode_into would scatter
        // out of bounds in the consumer.
        let mut body = vec![TAG_SPARSE];
        body.extend_from_slice(&100u64.to_le_bytes()); // d = 100
        body.extend_from_slice(&1u64.to_le_bytes()); // k = 1
        body.push(0); // raw f32 coding
        body.extend_from_slice(&5000u32.to_le_bytes()); // idx out of range
        body.extend_from_slice(&1.5f32.to_le_bytes()); // value
        let frame = frame_with_valid_checksum(&body);
        assert_eq!(decode(&frame).unwrap_err(), WireError::Corrupt);
    }

    #[test]
    fn sparse_count_exceeding_dim_rejected() {
        // k > d is unrepresentable by any honest encoder (top-k of d
        // coordinates): a frame claiming it must not decode.
        let mut body = vec![TAG_SPARSE];
        body.extend_from_slice(&2u64.to_le_bytes()); // d = 2
        body.extend_from_slice(&3u64.to_le_bytes()); // k = 3 > d
        body.push(0);
        body.extend_from_slice(&[0u8; 24]); // 3 idx + 3 vals = 24 bytes
        let frame = frame_with_valid_checksum(&body);
        assert_eq!(decode(&frame).unwrap_err(), WireError::Truncated);
    }

    #[test]
    fn qsgd_undersized_bitstream_rejected() {
        // Claim d = 1000 levels but ship only 4 payload bytes of stream:
        // the bit-budget check must fire before the level alloc.
        let mut body = vec![TAG_QSGD];
        body.extend_from_slice(&1000u64.to_le_bytes());
        body.extend_from_slice(&1.0f32.to_le_bytes()); // norm
        body.extend_from_slice(&4u32.to_le_bytes()); // s
        body.extend_from_slice(&[0u8; 4]);
        let frame = frame_with_valid_checksum(&body);
        assert_eq!(decode(&frame).unwrap_err(), WireError::Truncated);
    }

    #[test]
    fn prop_any_compressor_output_roundtrips() {
        prop_check(
            PropConfig { cases: 60, max_size: 2048, seed: 0x3173 },
            |rng, size| {
                let d = size.max(1);
                let x = gen_vec_f32(rng, d, 3.0);
                let which = rng.below(3);
                let seed = rng.next_u64();
                (x, which, seed)
            },
            |(x, which, seed)| {
                let mut rng = Pcg64::seeded(*seed);
                let msg = match which {
                    0 => StochasticSign::deterministic().compress(x, &mut rng),
                    1 => Qsgd::new(1 + (seed % 7) as u32).compress(x, &mut rng),
                    _ => Message::Dense(x.clone()),
                };
                let back = decode(&encode(&msg)).map_err(|e| e.to_string())?;
                match (&msg, &back) {
                    (Message::Signs(a), Message::Signs(b)) if a == b => Ok(()),
                    (Message::Quantized(a), Message::Quantized(b))
                        if a.levels == b.levels && a.norm == b.norm => Ok(()),
                    (Message::Dense(a), Message::Dense(b)) if a == b => Ok(()),
                    _ => Err("roundtrip mismatch".into()),
                }
            },
        );
    }
}
