//! The paper's sign-compressor family (Section 2) on the Rust side.
//!
//! The XLA/Pallas path (`runtime::ModelRuntime::compress`) is the production
//! hot path for neural workloads; this module is the *reference
//! implementation* used by (a) the analytic-problem experiments (Fig. 1/2,
//! where there is no XLA graph at all), (b) the baseline algorithms that
//! compress quantities the artifacts don't model (EF residuals, momentum
//! buffers), and (c) the Rust↔Python cross-validation tests.
//!
//! Operators:
//! * [`StochasticSign`] — `Sign(x + σ·ξ_z)`, the z-SignFedAvg compressor.
//!   σ = 0 recovers vanilla SignSGD.
//! * [`InputScaledSign`] — Sto-SignSGD (Safaryan–Richtárik '21): uniform
//!   noise with the *input-dependent* scale σ = ‖x‖ (the paper shows this is
//!   exactly `∞-SignSGD` with σ = ‖x‖₂, and that the dimension-growing scale
//!   is what slows it down on high-d problems — Fig. 1/3).

use super::{pack::PackedSigns, Compressor, Message};
use crate::rng::{Pcg64, ZParam};
use crate::tensor;

/// How the noise scale σ is chosen per compression call.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SigmaRule {
    /// Fixed σ (a tunable hyperparameter; the paper's main setting).
    Fixed(f32),
    /// σ = ‖x‖₂ (Sto-SignSGD of Safaryan–Richtárik '21).
    L2Norm,
    /// σ = ‖x‖_∞ (ablation: the tightest scale satisfying Remark 1).
    InfNorm,
}

/// `Sign(x + σ·ξ_z)` with ξ_z i.i.d. from the z-distribution.
#[derive(Debug, Clone)]
pub struct StochasticSign {
    pub z: ZParam,
    pub sigma: SigmaRule,
    /// Effective σ of the most recent `compress` call (what the server must
    /// multiply by η_z when dequantizing; see `fl::server`).
    pub last_sigma: f32,
}

impl StochasticSign {
    pub fn new(z: ZParam, sigma: SigmaRule) -> Self {
        StochasticSign { z, sigma, last_sigma: 0.0 }
    }

    /// Vanilla (noiseless) SignSGD.
    pub fn deterministic() -> Self {
        StochasticSign::new(ZParam::Finite(1), SigmaRule::Fixed(0.0))
    }

    fn effective_sigma(&self, x: &[f32]) -> f32 {
        match self.sigma {
            SigmaRule::Fixed(s) => s,
            SigmaRule::L2Norm => tensor::norm2(x) as f32,
            SigmaRule::InfNorm => tensor::norm_inf(x) as f32,
        }
    }

    /// Compress into a reusable i8 buffer — the **scalar reference path**.
    ///
    /// The production hot path is the fused kernel
    /// (`compress::kernel::stochastic_sign_packed`), which must stay
    /// bit-identical to this loop *on every SIMD dispatch path* (this loop
    /// never dispatches — it is the fixed point the `compress::simd`
    /// backends are pinned against): one z-noise draw per coordinate in
    /// coordinate order, perturbation in f64, sign taken as `>= 0.0`, and
    /// no draws at all when σ = 0. `tests/hotpath_exactness.rs` pins the
    /// equivalence, so keep the two in lockstep when touching either.
    pub fn compress_into(&mut self, x: &[f32], rng: &mut Pcg64, out: &mut [i8]) {
        assert_eq!(x.len(), out.len());
        let sigma = self.effective_sigma(x);
        self.last_sigma = sigma;
        if sigma == 0.0 {
            tensor::sign_into(x, out);
            return;
        }
        let s = sigma as f64;
        for (o, &xi) in out.iter_mut().zip(x) {
            let perturbed = xi as f64 + s * rng.z_noise(self.z);
            *o = if perturbed >= 0.0 { 1 } else { -1 };
        }
    }
}

impl Compressor for StochasticSign {
    fn compress(&mut self, delta: &[f32], rng: &mut Pcg64) -> Message {
        let sigma = self.effective_sigma(delta);
        self.last_sigma = sigma;
        let mut packed = PackedSigns::zeroed(delta.len());
        super::kernel::stochastic_sign_packed(delta, self.z, sigma, rng, &mut packed);
        Message::Signs(packed)
    }

    fn decode_into(&self, msg: &Message, out: &mut [f32]) {
        // Dequantize a single message: η_z · σ · sign  (Lemma 1's estimator),
        // straight from the packed words — no i8 round-trip.
        let scale = (self.z.eta() as f32) * self.last_sigma;
        match msg {
            Message::Signs(p) => p.decode_scaled_into(scale, out),
            _ => panic!("StochasticSign::decode_into on non-sign message"),
        }
    }

    fn name(&self) -> String {
        match self.sigma {
            SigmaRule::Fixed(s) => format!("{}-sign(sigma={s})", self.z),
            SigmaRule::L2Norm => "sto-sign(|x|_2)".into(),
            SigmaRule::InfNorm => "sto-sign(|x|_inf)".into(),
        }
    }
}

/// Sto-SignSGD: `∞`-noise with σ = ‖x‖₂ (equivalently, the importance-sampled
/// stochastic sign of Safaryan–Richtárik; see paper Appendix A).
pub fn sto_sign() -> StochasticSign {
    StochasticSign::new(ZParam::Inf, SigmaRule::L2Norm)
}

/// Wrapper with a different display name for the algorithm tables.
pub type InputScaledSign = StochasticSign;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_sigma_is_deterministic_sign() {
        let mut c = StochasticSign::deterministic();
        let mut rng = Pcg64::seeded(0);
        let x = [1.5f32, -0.1, 0.0, -7.0];
        let mut out = [0i8; 4];
        c.compress_into(&x, &mut rng, &mut out);
        assert_eq!(out, [1, -1, 1, -1]);
    }

    #[test]
    fn large_sigma_flips_signs_sometimes() {
        let mut c = StochasticSign::new(ZParam::Finite(1), SigmaRule::Fixed(10.0));
        let mut rng = Pcg64::seeded(1);
        let x = vec![0.5f32; 10_000];
        let mut out = vec![0i8; 10_000];
        c.compress_into(&x, &mut rng, &mut out);
        let plus = out.iter().filter(|&&s| s == 1).count();
        // P[+1] = Phi(0.05) ≈ 0.52: both signs must appear in bulk.
        assert!(plus > 4_000 && plus < 6_500, "plus={plus}");
    }

    #[test]
    fn uniform_noise_respects_support() {
        // For z=inf with sigma < |x_j|, the sign can never flip (Remark 2).
        let mut c = StochasticSign::new(ZParam::Inf, SigmaRule::Fixed(0.5));
        let mut rng = Pcg64::seeded(2);
        let x = vec![1.0f32; 1000];
        let mut out = vec![0i8; 1000];
        c.compress_into(&x, &mut rng, &mut out);
        assert!(out.iter().all(|&s| s == 1));
    }

    #[test]
    fn asymptotic_unbiasedness_monte_carlo() {
        // eta_z * sigma * mean(sign) -> x for large sigma (Lemma 1), checked
        // for both z = 1 and z = inf.
        for z in [ZParam::Finite(1), ZParam::Inf] {
            let sigma = 50.0f32;
            let mut c = StochasticSign::new(z, SigmaRule::Fixed(sigma));
            let mut rng = Pcg64::seeded(3);
            let x = [3.0f32, -2.0, 0.5];
            let reps = 60_000;
            let mut acc = [0.0f64; 3];
            let mut out = [0i8; 3];
            for _ in 0..reps {
                c.compress_into(&x, &mut rng, &mut out);
                for (a, &s) in acc.iter_mut().zip(&out) {
                    *a += s as f64;
                }
            }
            let eta = z.eta();
            for (j, &xj) in x.iter().enumerate() {
                let est = eta * sigma as f64 * acc[j] / reps as f64;
                // MC std ≈ eta*sigma/sqrt(reps) ≈ 0.26; allow 4 sigma.
                assert!(
                    (est - xj as f64).abs() < 1.1,
                    "z={z} j={j} est={est} want={xj}"
                );
            }
        }
    }

    #[test]
    fn input_scaled_uses_l2_norm() {
        let mut c = sto_sign();
        let mut rng = Pcg64::seeded(4);
        let x = [3.0f32, 4.0];
        let mut out = [0i8; 2];
        c.compress_into(&x, &mut rng, &mut out);
        assert!((c.last_sigma - 5.0).abs() < 1e-6);
    }

    #[test]
    fn compressor_trait_bits() {
        let mut c = StochasticSign::deterministic();
        let mut rng = Pcg64::seeded(5);
        let msg = c.compress(&vec![1.0f32; 777], &mut rng);
        assert_eq!(msg.bits_on_wire(), 777);
    }

    #[test]
    fn decode_scales_by_eta_sigma() {
        let mut c = StochasticSign::new(ZParam::Inf, SigmaRule::Fixed(2.0));
        let mut rng = Pcg64::seeded(6);
        let x = [10.0f32, -10.0]; // |x| > sigma: signs deterministic
        let msg = c.compress(&x, &mut rng);
        let mut out = [0.0f32; 2];
        c.decode_into(&msg, &mut out);
        // eta_inf = 1, sigma = 2 -> ±2.
        assert_eq!(out, [2.0, -2.0]);
    }
}
