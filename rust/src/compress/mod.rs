//! Gradient-compression substrate.
//!
//! Everything a z-SignFedAvg coordinator (and its baselines) puts on the
//! wire lives here:
//!
//! * [`sign`] — the paper's stochastic sign family `C_z(x) = Sign(x + σ·ξ_z)`
//!   (Section 2), the deterministic SignSGD operator, and the
//!   input-dependent Sto-SignSGD operator of Safaryan–Richtárik '21.
//! * [`pack`] — the 1-bit wire codec (sign vector ↔ packed `u64` words) and
//!   the carry-save (Harley–Seal) bit-sliced vote accumulator used by the
//!   server hot path.
//! * [`kernel`] — the fused one-pass perturb→sign→pack client kernels
//!   (bit-identical to the scalar reference path in [`sign`]; see the RNG
//!   stream contract there and in DESIGN.md).
//! * [`simd`] — the runtime-dispatched kernel backends (AVX2 / NEON /
//!   scalar) behind the [`simd::SignKernels`] table that [`kernel`] and
//!   [`pack`] route their inner loops through; every backend is pinned
//!   bit-identical to the scalar reference (`ZSFA_SIMD` overrides
//!   dispatch for A/B debugging).
//! * [`qsgd`] — the unbiased stochastic quantizer of Alistarh et al. '17
//!   (Definition 2 in the paper's appendix), used by the QSGD/FedPAQ
//!   baselines of Appendix E.
//! * [`error_feedback`] — the EF-SignSGD residual state (Karimireddy et
//!   al. '19), the paper's strongest sign-based baseline.
//! * [`agg`] — the server-side aggregation seam: per-compressor
//!   [`agg::Aggregator`]s that stream client messages into lane-sharded
//!   state under a fixed, parallelism-independent reduction topology.
//!
//! The [`Compressor`] trait unifies them for the FL server; every message
//! reports its exact wire size so the accuracy-vs-bits figures (Fig. 3c,
//! Fig. 16) are byte-accurate.

pub mod agg;
pub mod error_feedback;
pub mod kernel;
pub mod pack;
pub mod qsgd;
pub mod sign;
pub mod simd;
pub mod sparsify;
pub mod wire;

use crate::rng::Pcg64;

/// A compressed client→server message plus its exact uplink cost.
#[derive(Debug, Clone)]
pub enum Message {
    /// Packed ±1 signs: `d` bits on the wire (one per coordinate).
    Signs(pack::PackedSigns),
    /// QSGD quantized vector: norm (32 bits) + per-coordinate sign+level.
    Quantized(qsgd::Quantized),
    /// Sparse payload (top-k / sparse-sign): indices + values or sign bits.
    Sparse(sparsify::SparseMessage),
    /// Uncompressed f32 vector: 32·d bits.
    Dense(Vec<f32>),
}

impl Message {
    /// Exact number of bits this message occupies on the uplink.
    pub fn bits_on_wire(&self) -> u64 {
        match self {
            Message::Signs(s) => s.len() as u64,
            Message::Quantized(q) => q.bits_on_wire(),
            Message::Sparse(s) => s.bits_on_wire(),
            Message::Dense(v) => 32 * v.len() as u64,
        }
    }
}

/// A (possibly stateful, possibly randomized) uplink compressor.
///
/// `compress` consumes the client's *update direction* (the paper compresses
/// `(x_{t-1} - x^i_{t-1,E}) / γ`, i.e. the accumulated gradient estimate) and
/// a per-client RNG stream; `decode_into` is the matching server-side
/// dequantizer used when aggregating a single message (the sign-vote fast
/// path in `fl::server` bypasses it).
pub trait Compressor: Send {
    fn compress(&mut self, delta: &[f32], rng: &mut Pcg64) -> Message;

    /// Dequantize `msg` into `out` (overwrites).
    fn decode_into(&self, msg: &Message, out: &mut [f32]);

    /// Human-readable name for logs/CSV.
    fn name(&self) -> String;
}

/// The identity "compressor" (uncompressed FedAvg / SGD baselines).
pub struct Identity;

impl Compressor for Identity {
    fn compress(&mut self, delta: &[f32], _rng: &mut Pcg64) -> Message {
        Message::Dense(delta.to_vec())
    }

    fn decode_into(&self, msg: &Message, out: &mut [f32]) {
        match msg {
            Message::Dense(v) => out.copy_from_slice(v),
            _ => panic!("Identity::decode_into on non-dense message"),
        }
    }

    fn name(&self) -> String {
        "identity".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_roundtrip_and_bits() {
        let mut c = Identity;
        let mut rng = Pcg64::seeded(0);
        let x = vec![1.0f32, -2.0, 3.5];
        let m = c.compress(&x, &mut rng);
        assert_eq!(m.bits_on_wire(), 96);
        let mut out = vec![0.0; 3];
        c.decode_into(&m, &mut out);
        assert_eq!(out, x);
    }
}
