//! The scalar reference backend: the exact loops every SIMD backend is
//! pinned against (and the dispatch fallback on CPUs without AVX2/NEON).
//!
//! These bodies are the PR 5 fused-kernel loops moved verbatim behind the
//! table seam — any edit here changes every seeded experiment in the repo,
//! so don't. The functions are `unsafe fn` only to match the dispatch-table
//! pointer type; they have no safety requirements of their own.

use super::PLANES;

/// Sign bits of one ≤64-coordinate block: bit b = `x[b] + s·noise[b] >= 0`.
///
/// # Safety
/// None — `unsafe fn` only for dispatch-table pointer compatibility.
pub(super) unsafe fn sign_block(x: &[f32], s: f64, noise: &[f64]) -> u64 {
    let mut w = 0u64;
    for (b, (&xi, &nz)) in x.iter().zip(noise.iter()).enumerate() {
        w |= ((xi as f64 + s * nz >= 0.0) as u64) << b;
    }
    w
}

/// Branchless sign-bit pack (`x[j] >= 0.0`, trailing bits stay zero).
///
/// # Safety
/// None — `unsafe fn` only for dispatch-table pointer compatibility.
pub(super) unsafe fn pack_words(x: &[f32], words: &mut [u64]) {
    for (chunk, word) in x.chunks(64).zip(words.iter_mut()) {
        let mut w = 0u64;
        for (b, &xi) in chunk.iter().enumerate() {
            w |= ((xi >= 0.0) as u64) << b;
        }
        *word = w;
    }
}

/// Carry-save add: ripple each incoming word through the planes
/// (`sum = a ^ b`, `carry = a & b`). With at most `SPILL_BATCH = 15`
/// pending clients a column counter never exceeds 15, so no carry ever
/// leaves the top plane before the spill (debug-asserted here; the SIMD
/// backends rely on the same invariant without the assert).
///
/// # Safety
/// None — `unsafe fn` only for dispatch-table pointer compatibility.
pub(super) unsafe fn csa_add(planes: &mut [Vec<u64>; PLANES], w: &[u64]) {
    for (wi, &word) in w.iter().enumerate() {
        let mut carry = word;
        for plane in planes.iter_mut() {
            let t = plane[wi];
            plane[wi] = t ^ carry;
            carry &= t;
        }
        debug_assert_eq!(carry, 0, "CSA overflow before spill");
    }
}

/// Expand the planes into exact counts: a column with `plus` set bits
/// contributes `2·plus − pending` (each pending vote is +1 or −1).
///
/// # Safety
/// None — `unsafe fn` only for dispatch-table pointer compatibility.
pub(super) unsafe fn spill_counts(planes: &[Vec<u64>; PLANES], pending: i32, counts: &mut [i32]) {
    for (wi, chunk) in counts.chunks_mut(64).enumerate() {
        let (p0, p1) = (planes[0][wi], planes[1][wi]);
        let (p2, p3) = (planes[2][wi], planes[3][wi]);
        for (b, c) in chunk.iter_mut().enumerate() {
            let plus =
                (p0 >> b & 1) + 2 * (p1 >> b & 1) + 4 * (p2 >> b & 1) + 8 * (p3 >> b & 1);
            *c += 2 * plus as i32 - pending;
        }
    }
}

/// Write `±scale` per coordinate from the packed words (exact IEEE copies
/// of `scale` / `-scale`).
///
/// # Safety
/// None — `unsafe fn` only for dispatch-table pointer compatibility.
pub(super) unsafe fn decode_scaled(words: &[u64], scale: f32, out: &mut [f32]) {
    for (chunk, &w) in out.chunks_mut(64).zip(words.iter()) {
        for (b, o) in chunk.iter_mut().enumerate() {
            *o = if w >> b & 1 == 1 { scale } else { -scale };
        }
    }
}
