//! Runtime-dispatched SIMD backends for the three hot kernels.
//!
//! The per-round cost of sign-based FL sits in three loops: the fused
//! perturb→sign→pack kernel (`compress::kernel`), the Harley–Seal
//! carry-save vote planes (`compress::pack::VoteAccumulator`) and the
//! scaled sign decode (`PackedSigns::decode_scaled_into`). This module
//! owns vectorized implementations of exactly those loops behind one
//! [`SignKernels`] dispatch table:
//!
//! * **AVX2** on `x86_64`, gated at runtime by `is_x86_feature_detected!`;
//! * **NEON** on `aarch64` (baseline there, still runtime-checked);
//! * the **scalar** reference everywhere else.
//!
//! Everything is stable Rust (`std::arch` intrinsics + `#[target_feature]`
//! functions coerced to `unsafe fn` pointers) — no `std::simd` nightly
//! dependency.
//!
//! ## The exactness contract
//!
//! Every backend is **bit-identical** to the scalar reference — same words,
//! same counts, same f32 bit patterns — so dispatch can never move a seeded
//! trajectory, a determinism byte-diff or a service CSV. Two rules make
//! that possible and must survive any future backend:
//!
//! * **Noise draws stay sequential; only compare/pack vectorizes.** The
//!   z-noise stream is drawn per 64-coordinate block by
//!   `Pcg64::fill_z_noise_f64` (the DESIGN.md §2.6 RNG stream contract)
//!   and the vector lanes only see the already-drawn buffer.
//! * **No arithmetic re-association.** The perturbation is computed as a
//!   separate multiply then add (`x + (σ·ξ)`), never an FMA — fused
//!   multiply-add rounds once instead of twice and would break
//!   bit-identity. Comparisons use ordered `>=` semantics (`_CMP_GE_OQ` /
//!   `vcgezq`), matching scalar `>= 0.0` for −0.0 and NaN; all other ops
//!   are integer/bitwise and exact by construction.
//!
//! `tests/hotpath_exactness.rs` pins every compiled backend against the
//! scalar table across unaligned-tail lengths, all `ZParam` families and
//! all `SigmaRule`s, and CI runs the whole suite twice (`ZSFA_SIMD=off`
//! and default dispatch).
//!
//! ## Dispatch
//!
//! The active table is resolved once, on first use, from the [`SIMD_ENV`]
//! environment variable (`ZSFA_SIMD=off|avx2|neon|auto`) falling back to
//! the best runtime-detected path. Because all paths are bit-identical,
//! re-pointing the dispatch mid-process ([`set_path`], used by benches and
//! the equivalence tests for A/B runs) is always behavior-preserving. The
//! selected path is surfaced as the `zsfa_simd_path` telemetry gauge, in
//! `zsfa run`/`serve`/`join` startup logging, and in the `BENCH_*.json`
//! headers so perf trajectories are comparable across machines.

#[cfg(target_arch = "x86_64")]
mod avx2;
#[cfg(target_arch = "aarch64")]
mod neon;
mod scalar;

use std::sync::atomic::{AtomicU8, Ordering};

/// Environment variable selecting the dispatch path
/// (`off`/`scalar` | `avx2` | `neon` | `auto`). Unset means `auto`.
pub const SIMD_ENV: &str = "ZSFA_SIMD";

/// Number of carry-save planes in `VoteAccumulator` — fixed here because
/// the spill kernels hard-code the 4-plane column expansion.
pub const PLANES: usize = 4;

/// A dispatchable kernel backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SimdPath {
    /// The scalar reference loops (always available, always correct).
    Scalar,
    /// 256-bit AVX2 lanes (x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON lanes (aarch64).
    Neon,
}

impl SimdPath {
    /// Stable lowercase label (telemetry gauge, bench headers, logs).
    pub fn label(self) -> &'static str {
        match self {
            SimdPath::Scalar => "scalar",
            SimdPath::Avx2 => "avx2",
            SimdPath::Neon => "neon",
        }
    }

    /// Parse a `ZSFA_SIMD` request; `None` for `auto`/unknown strings.
    fn parse(s: &str) -> Option<SimdPath> {
        match s {
            "off" | "scalar" => Some(SimdPath::Scalar),
            "avx2" => Some(SimdPath::Avx2),
            "neon" => Some(SimdPath::Neon),
            _ => None,
        }
    }
}

/// The dispatch table: one `unsafe fn` pointer per hot loop. `unsafe`
/// because the non-scalar entries compile with `#[target_feature]` and are
/// only sound on CPUs that have the feature — which is exactly what
/// installation via [`kernels_for`] guarantees, so the safe wrapper
/// methods below can call them.
pub struct SignKernels {
    path: SimdPath,
    sign_block_fn: unsafe fn(&[f32], f64, &[f64]) -> u64,
    pack_words_fn: unsafe fn(&[f32], &mut [u64]),
    csa_add_fn: unsafe fn(&mut [Vec<u64>; PLANES], &[u64]),
    spill_counts_fn: unsafe fn(&[Vec<u64>; PLANES], i32, &mut [i32]),
    decode_scaled_fn: unsafe fn(&[u64], f32, &mut [f32]),
}

impl SignKernels {
    /// Which backend this table is.
    pub fn path(&self) -> SimdPath {
        self.path
    }

    /// Stable label of this backend.
    pub fn label(&self) -> &'static str {
        self.path.label()
    }

    /// Threshold-compare one ≤64-coordinate block against its pre-drawn
    /// noise: bit `b` of the result is `x[b] + sigma·noise[b] >= 0.0`.
    /// Per-block (not whole-slice) because the noise draws interleave
    /// with the packing in the fused kernel's RNG stream.
    #[inline]
    pub fn sign_block(&self, x: &[f32], sigma: f64, noise: &[f64]) -> u64 {
        assert!(x.len() <= 64 && noise.len() == x.len());
        // SAFETY: table invariant — the pointer's target features were
        // runtime-detected before this table could be handed out.
        unsafe { (self.sign_block_fn)(x, sigma, noise) }
    }

    /// Pack `x[j] >= 0.0` sign bits into words (trailing bits zero;
    /// `words` must already be shaped: one word per 64 coordinates).
    #[inline]
    pub fn pack_words(&self, x: &[f32], words: &mut [u64]) {
        assert_eq!(words.len(), x.len().div_ceil(64));
        // SAFETY: see `sign_block`.
        unsafe { (self.pack_words_fn)(x, words) }
    }

    /// Carry-save add of one packed vote word-stream into the planes
    /// (`sum = a ^ b`, `carry = a & b` rippled through the 4 planes).
    #[inline]
    pub fn csa_add(&self, planes: &mut [Vec<u64>; PLANES], w: &[u64]) {
        assert!(planes.iter().all(|p| p.len() == w.len()));
        // SAFETY: see `sign_block`.
        unsafe { (self.csa_add_fn)(planes, w) }
    }

    /// Expand `pending` clients' worth of planes into the exact counts:
    /// a column with `plus` set bits contributes `2·plus − pending`.
    #[inline]
    pub fn spill_counts(&self, planes: &[Vec<u64>; PLANES], pending: u32, counts: &mut [i32]) {
        if pending == 0 {
            return;
        }
        assert!(planes.iter().all(|p| p.len() == counts.len().div_ceil(64)));
        // SAFETY: see `sign_block`.
        unsafe { (self.spill_counts_fn)(planes, pending as i32, counts) }
    }

    /// Write `±scale` per coordinate from packed sign words (exact IEEE
    /// copies of `scale` / `-scale`, bit-identical to the scalar decode).
    #[inline]
    pub fn decode_scaled(&self, words: &[u64], scale: f32, out: &mut [f32]) {
        assert_eq!(words.len(), out.len().div_ceil(64));
        // SAFETY: see `sign_block`.
        unsafe { (self.decode_scaled_fn)(words, scale, out) }
    }
}

static SCALAR: SignKernels = SignKernels {
    path: SimdPath::Scalar,
    sign_block_fn: scalar::sign_block,
    pack_words_fn: scalar::pack_words,
    csa_add_fn: scalar::csa_add,
    spill_counts_fn: scalar::spill_counts,
    decode_scaled_fn: scalar::decode_scaled,
};

#[cfg(target_arch = "x86_64")]
static AVX2: SignKernels = SignKernels {
    path: SimdPath::Avx2,
    sign_block_fn: avx2::sign_block,
    pack_words_fn: avx2::pack_words,
    csa_add_fn: avx2::csa_add,
    spill_counts_fn: avx2::spill_counts,
    decode_scaled_fn: avx2::decode_scaled,
};

#[cfg(target_arch = "aarch64")]
static NEON: SignKernels = SignKernels {
    path: SimdPath::Neon,
    sign_block_fn: neon::sign_block,
    pack_words_fn: neon::pack_words,
    csa_add_fn: neon::csa_add,
    spill_counts_fn: neon::spill_counts,
    decode_scaled_fn: neon::decode_scaled,
};

/// Atomic dispatch state: a `SimdPath` code, or `UNRESOLVED` before the
/// first use. Relaxed ordering is enough because every reachable value is
/// behavior-identical (the exactness contract) — a racing reader at worst
/// runs one call on a different-but-equal backend.
const UNRESOLVED: u8 = u8::MAX;
static ACTIVE: AtomicU8 = AtomicU8::new(UNRESOLVED);

fn code(p: SimdPath) -> u8 {
    match p {
        SimdPath::Scalar => 0,
        SimdPath::Avx2 => 1,
        SimdPath::Neon => 2,
    }
}

/// The backend table for `path`, if it is compiled in *and* the CPU has
/// the features it needs. `Scalar` always succeeds.
pub fn kernels_for(path: SimdPath) -> Option<&'static SignKernels> {
    match path {
        SimdPath::Scalar => Some(&SCALAR),
        #[cfg(target_arch = "x86_64")]
        SimdPath::Avx2 if is_x86_feature_detected!("avx2") => Some(&AVX2),
        #[cfg(target_arch = "aarch64")]
        SimdPath::Neon if std::arch::is_aarch64_feature_detected!("neon") => Some(&NEON),
        _ => None,
    }
}

/// The scalar reference table (the pin for every equivalence test).
pub fn scalar_kernels() -> &'static SignKernels {
    &SCALAR
}

/// Best backend this CPU supports, ignoring the env override.
pub fn detected_best() -> SimdPath {
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") {
        return SimdPath::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        return SimdPath::Neon;
    }
    SimdPath::Scalar
}

/// Every backend available on this CPU (scalar first). The equivalence
/// tests and the bench A/B rows iterate this.
pub fn available() -> Vec<SimdPath> {
    let mut v = vec![SimdPath::Scalar];
    let best = detected_best();
    if best != SimdPath::Scalar {
        v.push(best);
    }
    v
}

fn resolve() -> &'static SignKernels {
    let k = match std::env::var(SIMD_ENV) {
        Ok(v) => match SimdPath::parse(&v) {
            Some(p) => match kernels_for(p) {
                Some(k) => k,
                None => {
                    eprintln!(
                        "warning: {SIMD_ENV}={v} is not available on this CPU; \
                         using the scalar kernels"
                    );
                    &SCALAR
                }
            },
            None => {
                if !v.is_empty() && v != "auto" && v != "on" {
                    eprintln!(
                        "warning: {SIMD_ENV}={v} not recognized \
                         (expected off|avx2|neon|auto); auto-detecting"
                    );
                }
                kernels_for(detected_best()).unwrap_or(&SCALAR)
            }
        },
        Err(_) => kernels_for(detected_best()).unwrap_or(&SCALAR),
    };
    ACTIVE.store(code(k.path), Ordering::Relaxed);
    k
}

/// The active dispatch table. Resolved once (env override, then runtime
/// CPU detection); afterwards a single relaxed atomic load.
#[inline]
pub fn active() -> &'static SignKernels {
    match ACTIVE.load(Ordering::Relaxed) {
        0 => &SCALAR,
        #[cfg(target_arch = "x86_64")]
        1 => &AVX2,
        #[cfg(target_arch = "aarch64")]
        2 => &NEON,
        _ => resolve(),
    }
}

/// Re-point the dispatch at `path` (benches and equivalence tests A/B the
/// backends this way). Returns `false` — leaving dispatch unchanged — when
/// the backend isn't available on this CPU. Safe at any time because all
/// backends are bit-identical.
pub fn set_path(path: SimdPath) -> bool {
    match kernels_for(path) {
        Some(k) => {
            ACTIVE.store(code(k.path), Ordering::Relaxed);
            true
        }
        None => false,
    }
}

/// Detected CPU features relevant to the kernels, as `arch:flag+flag`
/// (bench JSON headers; makes BENCH trajectories comparable across
/// machines).
pub fn cpu_features() -> String {
    let mut feats: Vec<&str> = Vec::new();
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            feats.push("avx2");
        }
        if is_x86_feature_detected!("avx") {
            feats.push("avx");
        }
        if is_x86_feature_detected!("sse4.2") {
            feats.push("sse4.2");
        }
    }
    #[cfg(target_arch = "aarch64")]
    if std::arch::is_aarch64_feature_detected!("neon") {
        feats.push("neon");
    }
    if feats.is_empty() {
        feats.push("baseline");
    }
    format!("{}:{}", std::env::consts::ARCH, feats.join("+"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn gen_f32(rng: &mut Pcg64, d: usize) -> Vec<f32> {
        (0..d).map(|_| rng.uniform_in(-2.0, 2.0) as f32).collect()
    }

    fn gen_words(rng: &mut Pcg64, d: usize) -> Vec<u64> {
        let nw = d.div_ceil(64);
        let mut w: Vec<u64> = (0..nw).map(|_| rng.next_u64()).collect();
        if d % 64 != 0 {
            if let Some(last) = w.last_mut() {
                *last &= (1u64 << (d % 64)) - 1; // trailing bits zero
            }
        }
        w
    }

    /// Unaligned tails around every lane width the backends use.
    const DIMS: [usize; 11] = [0, 1, 63, 64, 65, 127, 128, 255, 256, 1000, 4099];

    #[test]
    fn labels_and_parse_roundtrip() {
        for p in [SimdPath::Scalar, SimdPath::Avx2, SimdPath::Neon] {
            assert_eq!(SimdPath::parse(p.label()), Some(p));
        }
        assert_eq!(SimdPath::parse("off"), Some(SimdPath::Scalar));
        assert_eq!(SimdPath::parse("auto"), None);
        assert_eq!(SimdPath::parse("bogus"), None);
    }

    #[test]
    fn scalar_is_always_available_and_settable() {
        assert!(kernels_for(SimdPath::Scalar).is_some());
        assert!(kernels_for(detected_best()).is_some());
        assert!(set_path(SimdPath::Scalar));
        assert_eq!(active().path(), SimdPath::Scalar);
        assert!(set_path(detected_best()));
        assert_eq!(active().path(), detected_best());
        assert_eq!(available()[0], SimdPath::Scalar);
    }

    #[test]
    fn cpu_features_names_the_arch() {
        let f = cpu_features();
        assert!(f.starts_with(std::env::consts::ARCH), "{f}");
        assert!(f.contains(':'), "{f}");
    }

    // Every compiled backend pinned bit-identical to the scalar table on
    // random data across unaligned tails. The full dispatch-level matrix
    // (all ZParams × SigmaRules through the fused kernel) lives in
    // tests/hotpath_exactness.rs; this is the table-level pin that runs
    // even when that harness is filtered out.
    #[test]
    fn all_backends_match_scalar_table() {
        let sc = scalar_kernels();
        for path in available() {
            let kt = kernels_for(path).unwrap();
            let mut rng = Pcg64::seeded(0xD15);
            for &d in &DIMS {
                let x = gen_f32(&mut rng, d);
                let noise: Vec<f64> = (0..d.min(64)).map(|_| rng.uniform_in(-1.0, 1.0)).collect();

                // sign_block on the first ≤64-coordinate block.
                let blk = &x[..d.min(64)];
                assert_eq!(
                    kt.sign_block(blk, 0.7, &noise),
                    sc.sign_block(blk, 0.7, &noise),
                    "sign_block {path:?} d={d}"
                );

                // pack_words over the whole slice.
                let nw = d.div_ceil(64);
                let (mut wa, mut wb) = (vec![0u64; nw], vec![0u64; nw]);
                kt.pack_words(&x, &mut wa);
                sc.pack_words(&x, &mut wb);
                assert_eq!(wa, wb, "pack_words {path:?} d={d}");

                // csa_add + spill_counts over a full pending batch.
                let mut pa: [Vec<u64>; PLANES] = std::array::from_fn(|_| vec![0u64; nw]);
                let mut pb: [Vec<u64>; PLANES] = std::array::from_fn(|_| vec![0u64; nw]);
                for _ in 0..15 {
                    let w = gen_words(&mut rng, d);
                    kt.csa_add(&mut pa, &w);
                    sc.csa_add(&mut pb, &w);
                }
                assert_eq!(pa, pb, "csa planes {path:?} d={d}");
                let (mut ca, mut cb) = (vec![0i32; d], vec![0i32; d]);
                kt.spill_counts(&pa, 15, &mut ca);
                sc.spill_counts(&pb, 15, &mut cb);
                assert_eq!(ca, cb, "spill {path:?} d={d}");

                // decode_scaled, f32 bit patterns compared exactly.
                let w = gen_words(&mut rng, d);
                let (mut oa, mut ob) = (vec![0.0f32; d], vec![0.0f32; d]);
                kt.decode_scaled(&w, 0.37, &mut oa);
                sc.decode_scaled(&w, 0.37, &mut ob);
                let bits = |v: &[f32]| v.iter().map(|f| f.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(&oa), bits(&ob), "decode {path:?} d={d}");
            }
        }
    }

    #[test]
    fn spill_with_zero_pending_is_a_no_op() {
        let planes: [Vec<u64>; PLANES] = std::array::from_fn(|_| vec![u64::MAX]);
        let mut counts = vec![7i32; 64];
        active().spill_counts(&planes, 0, &mut counts);
        assert!(counts.iter().all(|&c| c == 7));
    }
}
