//! AVX2 backend: 256-bit lanes for the three hot kernels, bit-identical
//! to `scalar.rs` by construction.
//!
//! Exactness notes (why each sequence can't drift from the scalar loops):
//!
//! * `sign_block` computes `x + (σ·ξ)` as `_mm256_mul_pd` then
//!   `_mm256_add_pd` — **never** an FMA, which rounds once instead of
//!   twice and would break bit-identity with the scalar `xi + s * nz`.
//!   `_mm256_cvtps_pd` (f32→f64 widening) is exact, and the
//!   `_CMP_GE_OQ` ordered compare matches scalar `>= 0.0` exactly:
//!   `-0.0 >= 0.0` is true, NaN compares false.
//! * `pack_words` / `csa_add` / `spill_counts` are pure bit/int ops —
//!   exact on any path.
//! * `decode_scaled` emits unmodified copies of `scale` / `-scale`
//!   (`_mm256_blendv_ps` selects, never computes), so every output f32
//!   is bit-identical to the scalar ternary.

use std::arch::x86_64::*;

use super::PLANES;

/// Per-lane bit weights for expanding one byte of a packed word into
/// eight 0/1 (or select-mask) lanes: lane k tests bit k.
#[inline(always)]
fn lane_bits() -> __m256i {
    // SAFETY: setr is a pure register constant; AVX is implied by every
    // caller's avx2 target feature.
    unsafe { _mm256_setr_epi32(1, 2, 4, 8, 16, 32, 64, 128) }
}

/// # Safety
/// Requires AVX2 (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn sign_block(x: &[f32], s: f64, noise: &[f64]) -> u64 {
    let sig = _mm256_set1_pd(s);
    let zero = _mm256_setzero_pd();
    let n = x.len();
    let mut w = 0u64;
    let mut i = 0usize;
    while i + 4 <= n {
        let xd = _mm256_cvtps_pd(_mm_loadu_ps(x.as_ptr().add(i)));
        let nz = _mm256_loadu_pd(noise.as_ptr().add(i));
        // Multiply then add — NOT fused — to match scalar rounding.
        let pert = _mm256_add_pd(xd, _mm256_mul_pd(sig, nz));
        let ge = _mm256_cmp_pd::<_CMP_GE_OQ>(pert, zero);
        w |= ((_mm256_movemask_pd(ge) as u32) as u64) << i;
        i += 4;
    }
    while i < n {
        w |= ((x[i] as f64 + s * noise[i] >= 0.0) as u64) << i;
        i += 1;
    }
    w
}

/// # Safety
/// Requires AVX2 (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn pack_words(x: &[f32], words: &mut [u64]) {
    let zero = _mm256_setzero_ps();
    let blocks = x.len() / 64;
    for (wi, word) in words.iter_mut().enumerate().take(blocks) {
        let base = wi * 64;
        let mut w = 0u64;
        let mut k = 0usize;
        while k < 64 {
            let ge = _mm256_cmp_ps::<_CMP_GE_OQ>(_mm256_loadu_ps(x.as_ptr().add(base + k)), zero);
            w |= ((_mm256_movemask_ps(ge) as u32) as u64) << k;
            k += 8;
        }
        *word = w;
    }
    // Partial last block: scalar, keeps trailing bits zero.
    let base = blocks * 64;
    if base < x.len() {
        let mut w = 0u64;
        for (b, &xi) in x[base..].iter().enumerate() {
            w |= ((xi >= 0.0) as u64) << b;
        }
        words[blocks] = w;
    }
}

/// # Safety
/// Requires AVX2 (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn csa_add(planes: &mut [Vec<u64>; PLANES], w: &[u64]) {
    let n = w.len();
    // Raw plane pointers so the 4-word vector body and the scalar tail can
    // share the loop structure; the borrows backing them end immediately.
    let pp: [*mut u64; PLANES] = std::array::from_fn(|k| planes[k].as_mut_ptr());
    let mut i = 0usize;
    while i + 4 <= n {
        let mut carry = _mm256_loadu_si256(w.as_ptr().add(i).cast());
        for &p in &pp {
            let t = _mm256_loadu_si256(p.add(i).cast_const().cast());
            _mm256_storeu_si256(p.add(i).cast(), _mm256_xor_si256(t, carry));
            carry = _mm256_and_si256(t, carry);
        }
        i += 4;
    }
    while i < n {
        let mut carry = w[i];
        for plane in planes.iter_mut() {
            let t = plane[i];
            plane[i] = t ^ carry;
            carry &= t;
        }
        i += 1;
    }
}

/// # Safety
/// Requires AVX2 (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn spill_counts(planes: &[Vec<u64>; PLANES], pending: i32, counts: &mut [i32]) {
    let bits = lane_bits();
    let pend = _mm256_set1_epi32(pending);
    // 0/1 per lane: broadcast one byte of a plane word, test lane k's bit.
    macro_rules! bits01 {
        ($byte:expr) => {{
            let b = _mm256_set1_epi32($byte);
            _mm256_srli_epi32::<31>(_mm256_cmpeq_epi32(_mm256_and_si256(b, bits), bits))
        }};
    }
    for (wi, chunk) in counts.chunks_mut(64).enumerate() {
        let (w0, w1) = (planes[0][wi], planes[1][wi]);
        let (w2, w3) = (planes[2][wi], planes[3][wi]);
        let groups = chunk.len() / 8;
        for g in 0..groups {
            let sh = 8 * g;
            let m0 = bits01!(((w0 >> sh) & 0xff) as i32);
            let m1 = bits01!(((w1 >> sh) & 0xff) as i32);
            let m2 = bits01!(((w2 >> sh) & 0xff) as i32);
            let m3 = bits01!(((w3 >> sh) & 0xff) as i32);
            let mut plus = m0;
            plus = _mm256_add_epi32(plus, _mm256_slli_epi32::<1>(m1));
            plus = _mm256_add_epi32(plus, _mm256_slli_epi32::<2>(m2));
            plus = _mm256_add_epi32(plus, _mm256_slli_epi32::<3>(m3));
            let delta = _mm256_sub_epi32(_mm256_slli_epi32::<1>(plus), pend);
            let ptr: *mut __m256i = chunk.as_mut_ptr().add(8 * g).cast();
            _mm256_storeu_si256(ptr, _mm256_add_epi32(_mm256_loadu_si256(ptr.cast_const()), delta));
        }
        for b in 8 * groups..chunk.len() {
            let plus =
                (w0 >> b & 1) + 2 * (w1 >> b & 1) + 4 * (w2 >> b & 1) + 8 * (w3 >> b & 1);
            chunk[b] += 2 * plus as i32 - pending;
        }
    }
}

/// # Safety
/// Requires AVX2 (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "avx2")]
pub(super) unsafe fn decode_scaled(words: &[u64], scale: f32, out: &mut [f32]) {
    let bits = lane_bits();
    let pos = _mm256_set1_ps(scale);
    let neg = _mm256_set1_ps(-scale);
    for (chunk, &w) in out.chunks_mut(64).zip(words.iter()) {
        let groups = chunk.len() / 8;
        for g in 0..groups {
            let b = _mm256_set1_epi32(((w >> (8 * g)) & 0xff) as i32);
            let mask = _mm256_cmpeq_epi32(_mm256_and_si256(b, bits), bits);
            // Pure lane select between exact ±scale copies — no arithmetic.
            let v = _mm256_blendv_ps(neg, pos, _mm256_castsi256_ps(mask));
            _mm256_storeu_ps(chunk.as_mut_ptr().add(8 * g), v);
        }
        for b in 8 * groups..chunk.len() {
            chunk[b] = if w >> b & 1 == 1 { scale } else { -scale };
        }
    }
}
