//! NEON backend (aarch64): 128-bit lanes for the three hot kernels,
//! bit-identical to `scalar.rs` by construction.
//!
//! The same exactness rules as the AVX2 backend apply: the perturbation is
//! `vmulq_f64` then `vaddq_f64` (never `vfmaq_f64` — fused rounding would
//! break bit-identity with the scalar `xi + s * nz`), `vcvt_f64_f32` is an
//! exact widening, `vcgezq` matches scalar `>= 0.0` (−0.0 true, NaN
//! false), and everything else is integer/bitwise or a pure lane select.

use std::arch::aarch64::*;

use super::PLANES;

/// Per-lane bit weights: lane k of a 4-lane u32 vector tests bit k.
const NIBBLE_BITS: [u32; 4] = [1, 2, 4, 8];

/// # Safety
/// Requires NEON (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "neon")]
pub(super) unsafe fn sign_block(x: &[f32], s: f64, noise: &[f64]) -> u64 {
    let sig = vdupq_n_f64(s);
    let n = x.len();
    let mut w = 0u64;
    let mut i = 0usize;
    while i + 2 <= n {
        let xd = vcvt_f64_f32(vld1_f32(x.as_ptr().add(i)));
        let nz = vld1q_f64(noise.as_ptr().add(i));
        // Multiply then add — NOT fused — to match scalar rounding.
        let pert = vaddq_f64(xd, vmulq_f64(sig, nz));
        let ge = vcgezq_f64(pert);
        w |= (vgetq_lane_u64::<0>(ge) & 1) << i;
        w |= (vgetq_lane_u64::<1>(ge) & 1) << (i + 1);
        i += 2;
    }
    if i < n {
        w |= ((x[i] as f64 + s * noise[i] >= 0.0) as u64) << i;
    }
    w
}

/// # Safety
/// Requires NEON (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "neon")]
pub(super) unsafe fn pack_words(x: &[f32], words: &mut [u64]) {
    let bits = vld1q_u32(NIBBLE_BITS.as_ptr());
    let blocks = x.len() / 64;
    for (wi, word) in words.iter_mut().enumerate().take(blocks) {
        let base = wi * 64;
        let mut w = 0u64;
        let mut k = 0usize;
        while k < 64 {
            let ge = vcgezq_f32(vld1q_f32(x.as_ptr().add(base + k)));
            // Horizontal sum of (ge & [1,2,4,8]) = the 4-bit sign nibble.
            let nib = vaddvq_u32(vandq_u32(ge, bits)) as u64;
            w |= nib << k;
            k += 4;
        }
        *word = w;
    }
    // Partial last block: scalar, keeps trailing bits zero.
    let base = blocks * 64;
    if base < x.len() {
        let mut w = 0u64;
        for (b, &xi) in x[base..].iter().enumerate() {
            w |= ((xi >= 0.0) as u64) << b;
        }
        words[blocks] = w;
    }
}

/// # Safety
/// Requires NEON (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "neon")]
pub(super) unsafe fn csa_add(planes: &mut [Vec<u64>; PLANES], w: &[u64]) {
    let n = w.len();
    let pp: [*mut u64; PLANES] = std::array::from_fn(|k| planes[k].as_mut_ptr());
    let mut i = 0usize;
    while i + 2 <= n {
        let mut carry = vld1q_u64(w.as_ptr().add(i));
        for &p in &pp {
            let t = vld1q_u64(p.add(i).cast_const());
            vst1q_u64(p.add(i), veorq_u64(t, carry));
            carry = vandq_u64(t, carry);
        }
        i += 2;
    }
    if i < n {
        let mut carry = w[i];
        for plane in planes.iter_mut() {
            let t = plane[i];
            plane[i] = t ^ carry;
            carry &= t;
        }
    }
}

/// # Safety
/// Requires NEON (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "neon")]
pub(super) unsafe fn spill_counts(planes: &[Vec<u64>; PLANES], pending: i32, counts: &mut [i32]) {
    let bits = vld1q_u32(NIBBLE_BITS.as_ptr());
    let pend = vdupq_n_s32(pending);
    for (wi, chunk) in counts.chunks_mut(64).enumerate() {
        let (w0, w1) = (planes[0][wi], planes[1][wi]);
        let (w2, w3) = (planes[2][wi], planes[3][wi]);
        let groups = chunk.len() / 4;
        for g in 0..groups {
            let sh = 4 * g;
            // 0/1 per lane: all-ones from vtstq, shifted down to bit 0.
            let m0 = vshrq_n_u32::<31>(vtstq_u32(vdupq_n_u32(((w0 >> sh) & 0xf) as u32), bits));
            let m1 = vshrq_n_u32::<31>(vtstq_u32(vdupq_n_u32(((w1 >> sh) & 0xf) as u32), bits));
            let m2 = vshrq_n_u32::<31>(vtstq_u32(vdupq_n_u32(((w2 >> sh) & 0xf) as u32), bits));
            let m3 = vshrq_n_u32::<31>(vtstq_u32(vdupq_n_u32(((w3 >> sh) & 0xf) as u32), bits));
            let mut plus = m0;
            plus = vaddq_u32(plus, vshlq_n_u32::<1>(m1));
            plus = vaddq_u32(plus, vshlq_n_u32::<2>(m2));
            plus = vaddq_u32(plus, vshlq_n_u32::<3>(m3));
            let delta = vsubq_s32(vreinterpretq_s32_u32(vshlq_n_u32::<1>(plus)), pend);
            let ptr = chunk.as_mut_ptr().add(4 * g);
            vst1q_s32(ptr, vaddq_s32(vld1q_s32(ptr.cast_const()), delta));
        }
        for b in 4 * groups..chunk.len() {
            let plus =
                (w0 >> b & 1) + 2 * (w1 >> b & 1) + 4 * (w2 >> b & 1) + 8 * (w3 >> b & 1);
            chunk[b] += 2 * plus as i32 - pending;
        }
    }
}

/// # Safety
/// Requires NEON (guaranteed by the dispatch table's runtime detection).
#[target_feature(enable = "neon")]
pub(super) unsafe fn decode_scaled(words: &[u64], scale: f32, out: &mut [f32]) {
    let bits = vld1q_u32(NIBBLE_BITS.as_ptr());
    let pos = vdupq_n_f32(scale);
    let neg = vdupq_n_f32(-scale);
    for (chunk, &w) in out.chunks_mut(64).zip(words.iter()) {
        let groups = chunk.len() / 4;
        for g in 0..groups {
            let mask = vtstq_u32(vdupq_n_u32(((w >> (4 * g)) & 0xf) as u32), bits);
            // Pure lane select between exact ±scale copies — no arithmetic.
            let v = vbslq_f32(mask, pos, neg);
            vst1q_f32(chunk.as_mut_ptr().add(4 * g), v);
        }
        for b in 4 * groups..chunk.len() {
            chunk[b] = if w >> b & 1 == 1 { scale } else { -scale };
        }
    }
}
