//! Layered experiment configuration.
//!
//! Format: flat `key = value` lines (a TOML subset — the vendor set has no
//! toml crate), `#` comments, strings unquoted or double-quoted. Values are
//! looked up typed, with defaults, and every key access is recorded so
//! `warn_unused` can flag typos in config files.
//!
//! Precedence: built-in defaults < config file < CLI `--key value`
//! overrides (`cli::Args::apply_overrides`).
//!
//! Typed accessors are fallible: a malformed value surfaces as an error
//! naming the key — never a panic (mirrors `cli::Args`).

use crate::error::{Error, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::path::Path;

/// A flat string→string config map with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct Config {
    values: BTreeMap<String, String>,
    accessed: std::cell::RefCell<BTreeSet<String>>,
}

impl Config {
    pub fn new() -> Self {
        Config::default()
    }

    /// Parse the `key = value` format.
    pub fn parse(body: &str) -> Result<Config, String> {
        let mut cfg = Config::new();
        for (lineno, raw) in body.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| format!("line {}: expected `key = value`", lineno + 1))?;
            let key = k.trim().to_string();
            if key.is_empty() {
                return Err(format!("line {}: empty key", lineno + 1));
            }
            let mut val = v.trim().to_string();
            if val.len() >= 2 && val.starts_with('"') && val.ends_with('"') {
                val = val[1..val.len() - 1].to_string();
            }
            cfg.values.insert(key, val);
        }
        Ok(cfg)
    }

    pub fn load(path: &Path) -> Result<Config, String> {
        let body =
            std::fs::read_to_string(path).map_err(|e| format!("reading {path:?}: {e}"))?;
        Self::parse(&body)
    }

    /// Set (CLI overrides).
    pub fn set(&mut self, key: &str, value: &str) {
        self.values.insert(key.to_string(), value.to_string());
    }

    /// Merge `other` on top of `self`.
    pub fn overlay(&mut self, other: &Config) {
        for (k, v) in &other.values {
            self.values.insert(k.clone(), v.clone());
        }
    }

    fn raw(&self, key: &str) -> Option<&str> {
        self.accessed.borrow_mut().insert(key.to_string());
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.raw(key).unwrap_or(default)
    }

    /// Parse one key's value, reporting the key on failure.
    fn parse_typed<T: std::str::FromStr>(&self, key: &str, what: &str) -> Result<Option<T>> {
        match self.raw(key) {
            None => Ok(None),
            Some(s) => match s.parse::<T>() {
                Ok(v) => Ok(Some(v)),
                Err(_) => Err(Error::msg(format!("config {key}: bad {what} {s:?}"))),
            },
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.parse_typed(key, "usize")?.unwrap_or(default))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.parse_typed(key, "u64")?.unwrap_or(default))
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        Ok(self.parse_typed(key, "f32")?.unwrap_or(default))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.parse_typed(key, "f64")?.unwrap_or(default))
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.raw(key) {
            None => Ok(default),
            Some("true" | "1" | "yes") => Ok(true),
            Some("false" | "0" | "no") => Ok(false),
            Some(other) => Err(Error::msg(format!("config {key}: bad bool {other:?}"))),
        }
    }

    /// The `parallelism` key shared by every experiment config: worker
    /// threads for per-client round work (`ServerConfig::parallelism`).
    pub fn parallelism_or(&self, default: usize) -> Result<usize> {
        self.usize_or("parallelism", default)
    }

    /// The `reduce_lanes` key (`--reduce-lanes` on the CLI): lanes of the
    /// fixed reduction topology (`ServerConfig::reduce_lanes`). Part of the
    /// reproducibility contract, like the seed.
    pub fn reduce_lanes_or(&self, default: usize) -> Result<usize> {
        // Accept both spellings: config files use `reduce_lanes`, CLI
        // overrides arrive as `reduce-lanes`.
        let d = self.usize_or("reduce_lanes", default)?;
        self.usize_or("reduce-lanes", d)
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        self.parse_typed(key, "usize")
    }

    /// Keys present in the file but never read (likely typos).
    pub fn unused_keys(&self) -> Vec<String> {
        let accessed = self.accessed.borrow();
        self.values.keys().filter(|k| !accessed.contains(*k)).cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_basic() {
        let c = Config::parse("a = 1\n# comment\nname = \"hello world\"\nlr=0.5\n").unwrap();
        assert_eq!(c.usize_or("a", 0).unwrap(), 1);
        assert_eq!(c.str_or("name", ""), "hello world");
        assert_eq!(c.f32_or("lr", 0.0).unwrap(), 0.5);
        assert_eq!(c.bool_or("missing", true).unwrap(), true);
    }

    #[test]
    fn overlay_precedence() {
        let mut base = Config::parse("a = 1\nb = 2").unwrap();
        let over = Config::parse("b = 3").unwrap();
        base.overlay(&over);
        assert_eq!(base.usize_or("a", 0).unwrap(), 1);
        assert_eq!(base.usize_or("b", 0).unwrap(), 3);
    }

    #[test]
    fn tracks_unused() {
        let c = Config::parse("used = 1\ntypo_key = 2").unwrap();
        let _ = c.usize_or("used", 0);
        assert_eq!(c.unused_keys(), vec!["typo_key".to_string()]);
    }

    #[test]
    fn rejects_bad_lines() {
        assert!(Config::parse("just a line").is_err());
        assert!(Config::parse("= 3").is_err());
    }

    #[test]
    fn typed_access_errors_on_garbage() {
        // These used to panic mid-run; now they surface as config errors.
        let c = Config::parse("n = zebra\nb = maybe\nf = 1..2").unwrap();
        let err = c.usize_or("n", 0).unwrap_err().to_string();
        assert!(err.contains("config n") && err.contains("zebra"), "{err}");
        assert!(c.bool_or("b", false).is_err());
        assert!(c.f32_or("f", 0.0).is_err());
        assert!(c.opt_usize("n").is_err());
        assert!(c.u64_or("n", 0).is_err());
    }
}
