//! Figure 16 (Appendix E): sign compression vs unbiased quantization —
//! 1-SignSGD vs QSGD(s) on non-iid MNIST, and 1-SignFedAvg vs FedPAQ(s) on
//! EMNIST/CIFAR, as accuracy-versus-uplink-bits curves.
//!
//! Paper settings: QSGD server stepsizes from Table 7 (0.01 for s=1, 0.05
//! for s=2/4); FedPAQ server stepsize 1 for all s. Expected shape: the sign
//! compressor dominates the low-precision regime (1–8 bits/coordinate);
//! QSGD needs s ≥ 4 to come close on MNIST.

use super::common::*;
use crate::api::{Dataset, ExperimentSpec, Session, WorkloadSpec};
use crate::cli::Args;
use crate::fl::AlgorithmConfig;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    let dataset = Dataset::parse(args.str_or("dataset", "mnist"))
        .ok_or_else(|| crate::anyhow!("--dataset mnist|emnist|cifar"))?;
    banner(&format!("Figure 16 — sign vs unbiased quantization on {dataset:?}"));
    let rounds = args.usize_or("rounds", 100)?;
    let repeats = args.usize_or("repeats", 2)?;
    let cpr = clients_per_round(dataset, args)?;

    let mut algos: Vec<AlgorithmConfig> = Vec::new();
    match dataset {
        Dataset::NoniidMnist => {
            // E = 1: QSGD vs 1-SignSGD (Table 7 row 1).
            algos.push(
                AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.05).with_lrs(0.01, 1.0),
            );
            for (s, lr) in [(1u32, 0.01f32), (2, 0.05), (4, 0.05)] {
                algos.push(AlgorithmConfig::qsgd(s).with_lrs(lr, 1.0));
            }
        }
        Dataset::Emnist | Dataset::Cifar => {
            let (client_lr, server_lr, sigma, e) = if dataset == Dataset::Emnist {
                (0.05f32, 0.03f32, 0.01f32, 5usize)
            } else {
                (0.1, 0.0032, 0.0005, 5)
            };
            algos.push(
                AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e)
                    .with_lrs(client_lr, server_lr),
            );
            for s in [1u32, 2, 4, 8] {
                // Table 7: FedPAQ server stepsize 1.
                algos.push(AlgorithmConfig::fedpaq(s, e).with_lrs(client_lr, 1.0));
            }
        }
    }

    let mut spec = ExperimentSpec::new(
        format!("fig16_{}", args.str_or("dataset", "mnist")),
        WorkloadSpec::Neural(neural_spec_from_args(dataset, args)?),
    )
    .rounds(rounds)
    .eval_every((rounds / 20).max(1))
    .repeats(repeats)
    .clients_per_round(cpr);
    for algo in algos {
        spec = spec.series(algo);
    }
    // The summary rows report accuracy *and* bits, so the bit-efficiency
    // ordering is visible directly in the console output.
    Session::console().run(&apply_execution_flags(spec, args)?)?;
    println!("\nShape check: at equal accuracy the sign rows should show ~s+1x fewer Mbit.");
    Ok(())
}
