//! Figure 16 (Appendix E): sign compression vs unbiased quantization —
//! 1-SignSGD vs QSGD(s) on non-iid MNIST, and 1-SignFedAvg vs FedPAQ(s) on
//! EMNIST/CIFAR, as accuracy-versus-uplink-bits curves.
//!
//! Paper settings: QSGD server stepsizes from Table 7 (0.01 for s=1, 0.05
//! for s=2/4); FedPAQ server stepsize 1 for all s. Expected shape: the sign
//! compressor dominates the low-precision regime (1–8 bits/coordinate);
//! QSGD needs s ≥ 4 to come close on MNIST.

use super::common::*;
use crate::cli::Args;
use crate::fl::server::ServerConfig;
use crate::fl::AlgorithmConfig;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    let workload = Workload::parse(args.str_or("dataset", "mnist"))
        .ok_or_else(|| crate::anyhow!("--dataset mnist|emnist|cifar"))?;
    banner(&format!("Figure 16 — sign vs unbiased quantization on {workload:?}"));
    let rounds = args.usize_or("rounds", 100);
    let repeats = args.usize_or("repeats", 2);
    let cpr = clients_per_round(workload, args);

    let mut algos: Vec<AlgorithmConfig> = Vec::new();
    match workload {
        Workload::NoniidMnist => {
            // E = 1: QSGD vs 1-SignSGD (Table 7 row 1).
            algos.push(
                AlgorithmConfig::z_signsgd(ZParam::Finite(1), 0.05).with_lrs(0.01, 1.0),
            );
            for (s, lr) in [(1u32, 0.01f32), (2, 0.05), (4, 0.05)] {
                algos.push(AlgorithmConfig::qsgd(s).with_lrs(lr, 1.0));
            }
        }
        Workload::Emnist | Workload::Cifar => {
            let (client_lr, server_lr, sigma, e) = if workload == Workload::Emnist {
                (0.05f32, 0.03f32, 0.01f32, 5usize)
            } else {
                (0.1, 0.0032, 0.0005, 5)
            };
            algos.push(
                AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e)
                    .with_lrs(client_lr, server_lr),
            );
            for s in [1u32, 2, 4, 8] {
                // Table 7: FedPAQ server stepsize 1.
                algos.push(AlgorithmConfig::fedpaq(s, e).with_lrs(client_lr, 1.0));
            }
        }
    }

    for algo in &algos {
        let cfg = ServerConfig {
            rounds,
            clients_per_round: cpr,
            eval_every: (rounds / 20).max(1),
            parallelism: args.parallelism_or(1),
            reduce_lanes: args.reduce_lanes_or(ServerConfig::default().reduce_lanes),
            ..Default::default()
        };
        let (agg, runs) = run_repeats(
            || build_xla_backend(workload, args).expect("backend"),
            algo,
            &cfg,
            repeats,
        );
        save_series(
            &format!("fig16_{}", args.str_or("dataset", "mnist")),
            &algo.name,
            &agg,
            &runs,
        );
        // Report accuracy *and* bits so the bit-efficiency ordering is visible
        // directly in the console output.
        print_summary_row(&algo.name, &agg);
    }
    println!("\nShape check: at equal accuracy the sign rows should show ~s+1x fewer Mbit.");
    Ok(())
}
