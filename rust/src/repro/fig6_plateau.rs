//! Figure 6 (+14/15): the Plateau criterion for adaptive noise scaling.
//!
//! Compares 1-SignSGD / 1-SignFedAvg with the tuned fixed σ against the
//! plateau-scheduled σ (Table 6 hyperparameters) on the three dataset
//! settings. Also emits the σ trajectory (Fig. 15).
//!
//! Expected shape: the plateau run converges more slowly mid-training (it
//! must discover the right σ) but reaches the same final objective as the
//! tuned fixed σ.
//!
//! Two specs share one output directory: the fixed-σ series and the
//! plateau series differ in `ExperimentSpec::plateau`, which is a
//! server-level knob, not a per-series one.

use super::common::*;
use crate::api::{Dataset, ExperimentSpec, Session, WorkloadSpec};
use crate::cli::Args;
use crate::fl::plateau::PlateauConfig;
use crate::fl::AlgorithmConfig;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    let dataset = Dataset::parse(args.str_or("dataset", "mnist"))
        .ok_or_else(|| crate::anyhow!("--dataset mnist|emnist|cifar"))?;
    banner(&format!("Figure 6 — Plateau criterion on {dataset:?}"));
    let rounds = args.usize_or("rounds", 120)?;
    let repeats = args.usize_or("repeats", 2)?;

    // Per-dataset tuned σ (from Fig. 3/5) and Table 6 plateau presets.
    let (fixed_sigma, plateau, client_lr, server_lr, e) = match dataset {
        Dataset::NoniidMnist => (0.05f32, PlateauConfig::mnist(), 0.01f32, 1.0f32, 1usize),
        Dataset::Emnist => (0.01, PlateauConfig::emnist(), 0.05, 0.03, 5),
        Dataset::Cifar => (0.0005, PlateauConfig::cifar(), 0.1, 0.0032, 5),
    };
    let cpr = clients_per_round(dataset, args)?;

    let fixed = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), fixed_sigma, e)
        .with_lrs(client_lr, server_lr);
    let adaptive = {
        let mut a = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), plateau.sigma_init, e)
            .with_lrs(client_lr, server_lr);
        a.name = format!("{}-plateau", a.name);
        a
    };

    let name = format!("fig6_{}", args.str_or("dataset", "mnist"));
    for (algo, use_plateau) in [(fixed, false), (adaptive, true)] {
        let mut spec = ExperimentSpec::new(
            name.clone(),
            WorkloadSpec::Neural(neural_spec_from_args(dataset, args)?),
        )
        .rounds(rounds)
        .eval_every((rounds / 20).max(1))
        .repeats(repeats)
        .clients_per_round(cpr)
        .series(algo);
        if use_plateau {
            spec = spec.plateau(plateau);
        }
        let result = Session::console().run(&apply_execution_flags(spec, args)?)?;
        if use_plateau {
            // Fig. 15: sigma trajectory of the first run.
            let sigmas: Vec<f32> =
                result.series[0].runs[0].records.iter().map(|r| r.sigma).collect();
            println!(
                "  sigma trajectory: start {:.4} -> end {:.4} ({} distinct values)",
                sigmas.first().unwrap(),
                sigmas.last().unwrap(),
                {
                    let mut v: Vec<_> = sigmas.iter().map(|s| s.to_bits()).collect();
                    v.dedup();
                    v.len()
                }
            );
        }
    }
    Ok(())
}
