//! Figure 3 (+ Fig. 7): z-SignSGD on extremely non-iid MNIST.
//!
//! Paper setting (§4.2): 10 clients, each holding exactly one digit, the
//! PyTorch-tutorial CNN, E = 1, full participation. Algorithms and tuned
//! hyperparameters from Table 3:
//!
//! | algorithm      | stepsize | momentum | noise |
//! | SGDwM          | 0.05     | 0.9      |   –   |
//! | EF-SignSGDwM   | 0.05     | 0.9      |   –   |
//! | Sto-SignSGDwM  | 0.01     | 0.9      |   –   |
//! | SignSGD        | 0.01     | 0        |  0    |
//! | 1-SignSGD      | 0.01     | 0        | 0.05  |
//! | ∞-SignSGD      | 0.01     | 0        | 0.05  |
//!
//! Outputs (CSV per algorithm): train loss + test accuracy per round and
//! accuracy vs cumulative uplink bits (Fig. 3a/3b/3c). `--sweep-sigma`
//! reproduces Fig. 7's noise-scale sweep instead.
//!
//! Expected shape: SignSGD plateaus low; 1-/∞-SignSGD ≈ SGDwM and clearly
//! above EF-SignSGDwM and Sto-SignSGDwM; in bits, the sign family dominates.

use super::common::*;
use crate::api::{Dataset, ExperimentSpec, Session, SweepSpec, WorkloadSpec};
use crate::cli::Args;
use crate::fl::AlgorithmConfig;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    if args.has("sweep-sigma") {
        return sweep_sigma(args);
    }
    banner("Figure 3 — non-iid MNIST (one digit per client)");
    let rounds = args.usize_or("rounds", 120)?;
    let repeats = args.usize_or("repeats", 2)?;
    let sigma = args.f32_or("sigma", 0.05)?;

    // Table 3 hyperparameters.
    let workload = WorkloadSpec::Neural(neural_spec_from_args(Dataset::NoniidMnist, args)?);
    let spec = apply_execution_flags(
        ExperimentSpec::new("fig3", workload)
            .rounds(rounds)
            .eval_every((rounds / 20).max(1))
            .repeats(repeats)
            .series(AlgorithmConfig::sgdwm(0.9).with_lrs(0.05, 1.0))
            .series(AlgorithmConfig::ef_signsgd().with_momentum(0.9).with_lrs(0.05, 1.0))
            .series(AlgorithmConfig::sto_signsgd().with_momentum(0.9).with_lrs(0.01, 1.0))
            .series(AlgorithmConfig::signsgd().with_lrs(0.01, 1.0))
            .series(AlgorithmConfig::z_signsgd(ZParam::Finite(1), sigma).with_lrs(0.01, 1.0))
            .series(AlgorithmConfig::z_signsgd(ZParam::Inf, sigma).with_lrs(0.01, 1.0)),
        args,
    )?;
    Session::console().run(&spec)?;
    println!("\nFig 3c (accuracy vs bits) comes from the bits_up column of the CSVs.");
    Ok(())
}

/// Fig. 7: 1-/∞-SignSGD under different noise scales on the same workload.
fn sweep_sigma(args: &Args) -> crate::error::Result<()> {
    banner("Figure 7 — noise-scale sweep on non-iid MNIST");
    let rounds = args.usize_or("rounds", 80)?;
    let repeats = args.usize_or("repeats", 2)?;
    let sigmas: Vec<f32> = args.list_or("sigmas", &[0.0, 0.01, 0.05, 0.1, 0.3, 0.5])?;
    for z in [ZParam::Finite(1), ZParam::Inf] {
        println!("\n-- z = {z} --");
        let workload =
            WorkloadSpec::Neural(neural_spec_from_args(Dataset::NoniidMnist, args)?);
        let spec = apply_execution_flags(
            ExperimentSpec::new(format!("fig7_z{z}"), workload)
                .rounds(rounds)
                .eval_every((rounds / 10).max(1))
                .repeats(repeats)
                .sweep(SweepSpec {
                    zs: vec![z],
                    local_steps: vec![1],
                    sigmas: sigmas.clone(),
                    client_lr: 0.01,
                    server_lr: 1.0,
                }),
            args,
        )?;
        Session::console().run(&spec)?;
    }
    Ok(())
}
