//! Figure 2: z-SignSGD under various noise scales — the bias/variance
//! trade-off after Theorem 1.
//!
//! Expected shape: σ = 0 (vanilla sign) stalls at a high plateau; small σ
//! converges fast to a mediocre floor; large σ converges more slowly but
//! reaches a lower objective; very large σ is dominated by the injected
//! variance. Both z = 1 and z = ∞ show the same trade-off.

use super::common::*;
use crate::cli::Args;
use crate::fl::backend::AnalyticBackend;
use crate::fl::server::ServerConfig;
use crate::fl::AlgorithmConfig;
use crate::problems::consensus::Consensus;
use crate::problems::AnalyticProblem;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    banner("Figure 2 — bias/variance trade-off over noise scales");
    let rounds = args.usize_or("rounds", 800);
    let repeats = args.usize_or("repeats", 5);
    let d = args.usize_or("dim", 1000);
    let n = args.usize_or("clients", 10);
    let lr = args.f32_or("lr", 0.01);
    let sigmas: Vec<f32> = args
        .flag("sigmas")
        .map(|s| s.split(',').map(|v| v.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![0.0, 0.3, 1.0, 3.0, 10.0, 30.0]);

    let f_star = Consensus::gaussian(n, d, 99).optimal_value().unwrap();
    println!("d = {d}, f* = {f_star:.6}");
    for z in [ZParam::Finite(1), ZParam::Inf] {
        println!("\n-- z = {z} --");
        for &sigma in &sigmas {
            let algo = AlgorithmConfig::z_signsgd(z, sigma).with_lrs(lr, 1.0);
            let cfg = ServerConfig {
                rounds,
                eval_every: (rounds / 100).max(1),
                parallelism: args.parallelism_or(1),
                reduce_lanes: args.reduce_lanes_or(ServerConfig::default().reduce_lanes),
                ..Default::default()
            };
            let (mut agg, runs) = run_repeats(
                || AnalyticBackend::new(Consensus::gaussian(n, d, 99)),
                &algo,
                &cfg,
                repeats,
            );
            for v in agg.objective_mean.iter_mut() {
                *v -= f_star;
            }
            save_series(&format!("fig2_z{z}"), &format!("sigma{sigma}"), &agg, &runs);
            print_summary_row(&format!("sigma = {sigma}"), &agg);
        }
    }
    println!("\nShape check: the final gap should first fall then rise with sigma");
    println!("(small sigma = bias floor, large sigma = variance floor — Theorem 1).");
    Ok(())
}
