//! Figure 2: z-SignSGD under various noise scales — the bias/variance
//! trade-off after Theorem 1.
//!
//! Expected shape: σ = 0 (vanilla sign) stalls at a high plateau; small σ
//! converges fast to a mediocre floor; large σ converges more slowly but
//! reaches a lower objective; very large σ is dominated by the injected
//! variance. Both z = 1 and z = ∞ show the same trade-off.
//!
//! The σ grid runs as an `api::SweepSpec` (one spec per z, so each z keeps
//! its own `fig2_z{z}` output directory, as always).

use super::common::*;
use crate::api::{ExperimentSpec, Session, SweepSpec, WorkloadSpec};
use crate::cli::Args;
use crate::problems::consensus::Consensus;
use crate::problems::AnalyticProblem;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    banner("Figure 2 — bias/variance trade-off over noise scales");
    let rounds = args.usize_or("rounds", 800)?;
    let repeats = args.usize_or("repeats", 5)?;
    let d = args.usize_or("dim", 1000)?;
    let n = args.usize_or("clients", 10)?;
    let lr = args.f32_or("lr", 0.01)?;
    let sigmas: Vec<f32> = args.list_or("sigmas", &[0.0, 0.3, 1.0, 3.0, 10.0, 30.0])?;

    let f_star = Consensus::gaussian(n, d, 99).optimal_value().unwrap();
    println!("d = {d}, f* = {f_star:.6}");
    for z in [ZParam::Finite(1), ZParam::Inf] {
        println!("\n-- z = {z} --");
        let spec = apply_execution_flags(
            ExperimentSpec::new(format!("fig2_z{z}"), WorkloadSpec::consensus(n, d, 99))
                .rounds(rounds)
                .eval_every((rounds / 100).max(1))
                .repeats(repeats)
                .subtract_optimal(true)
                .sweep(SweepSpec {
                    zs: vec![z],
                    local_steps: vec![1],
                    sigmas: sigmas.clone(),
                    client_lr: lr,
                    server_lr: 1.0,
                }),
            args,
        )?;
        Session::console().run(&spec)?;
    }
    println!("\nShape check: the final gap should first fall then rise with sigma");
    println!("(small sigma = bias floor, large sigma = variance floor — Theorem 1).");
    Ok(())
}
