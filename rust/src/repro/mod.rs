//! Reproduction drivers: one per paper figure/table (see DESIGN.md §5).

pub mod common;
pub mod fig1_consensus;
pub mod fig2_noise;
pub mod fig3_mnist;
pub mod fig5_fedavg;
pub mod fig6_plateau;
pub mod fig16_qsgd;
pub mod fig17_dp;
pub mod figx_scenarios;
pub mod table2_rates;
