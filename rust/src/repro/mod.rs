//! Reproduction drivers: one per paper figure/table (see DESIGN.md §8).
//!
//! Every driver is a thin `api::ExperimentSpec` factory executed through
//! `api::Session` (DESIGN.md §4.5) — none of them touch `ServerConfig`,
//! the repeat loop, or CSV plumbing directly (pinned by
//! `tests/integration_api.rs`).

pub mod common;
pub mod fig1_consensus;
pub mod fig2_noise;
pub mod fig3_mnist;
pub mod fig5_fedavg;
pub mod fig6_plateau;
pub mod fig16_qsgd;
pub mod fig17_dp;
pub mod figx_scenarios;
pub mod table2_rates;
