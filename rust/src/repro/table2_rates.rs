//! Tables 1/2: the convergence-rate / bits-per-round summary, plus an
//! *empirical* rate check.
//!
//! The analytic part re-prints the paper's comparison table (rates are
//! theorems, not measurements). The empirical part runs 1-SignSGD and
//! ∞-SignSGD with minibatch noise on a stochastic least-squares problem over
//! a grid of horizons τ and fits the slope of log E‖∇f‖² against log τ —
//! the fitted slope should be ≤ the paper's guaranteed −z/(2z+1) (faster is
//! fine: quadratics are benign; the check is that the *ordering* and
//! rough magnitudes hold and that vanilla SignSGD's curve flattens).

use super::common::{apply_execution_flags, banner};
use crate::api::{ExperimentSpec, Session, WorkloadSpec};
use crate::cli::Args;
use crate::fl::AlgorithmConfig;
use crate::rng::ZParam;
use crate::util::stats::ols_slope;

pub fn run(args: &Args) -> crate::error::Result<()> {
    banner("Table 2 — stochastic sign-based methods: rates & uplink bits");
    println!(
        "{:<22} {:>18} {:>16} {:>14} {:>13}",
        "algorithm", "rate (metric)", "bits/round", "linear speedup", "local steps"
    );
    let rows = [
        ("SGD [22]", "O(t^-1/2) (sq l2)", "32d", "yes", "no"),
        ("FedAvg [37,55]", "O(t^-1/2) (sq l2)", "32d", "yes", "yes"),
        ("EF-SignSGD [31]", "O(t^-1/2+d^2/t)", "d + 32", "no", "no"),
        ("Sto-SignSGD [43]", "O(t^-1/4) (l2)", "d", "no", "no"),
        ("Stoch-Sign [27]", "O(t^-1/4) (sq l2)", "d", "no", "no"),
        ("Noisy median [12]", "O(t^-1/4) (mixed)", "d", "no", "no"),
        ("QSGD [5]", "O(t^-1/2) (sq l2)", "~sd + 32", "yes", "no"),
        ("FedCOM [23]", "O(t^-1/2) (sq l2)", "~sd + 32", "yes", "yes"),
        ("1-SignFedAvg*", "O(t^-1/3) (sq l2)", "d", "yes", "yes"),
        ("inf-SignFedAvg*", "O(t^-1/2) (sq l2)", "d", "yes", "yes"),
    ];
    for (a, r, b, ls, e) in rows {
        println!("{a:<22} {r:>18} {b:>16} {ls:>14} {e:>13}");
    }
    println!("(* this work; t = total gradient queries tau)");

    empirical_rate_fit(args)
}

fn empirical_rate_fit(args: &Args) -> crate::error::Result<()> {
    banner("Empirical rate fit: log E min_t ||grad f||^2 vs log tau");
    let repeats = args.usize_or("repeats", 3)?;
    let horizons: Vec<usize> = args.list_or("horizons", &[100, 200, 400, 800, 1600])?;
    let algos = vec![
        ("GD-SGD", AlgorithmConfig::gd().with_lrs(0.02, 1.0)),
        (
            "1-SignSGD",
            AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0).with_lrs(0.02, 1.0),
        ),
        (
            "inf-SignSGD",
            AlgorithmConfig::z_signsgd(ZParam::Inf, 6.0).with_lrs(0.02, 1.0),
        ),
        ("SignSGD", AlgorithmConfig::signsgd().with_lrs(0.02, 1.0)),
    ];
    println!("{:<14} {:>12} {:>32}", "algorithm", "fitted slope", "min ||grad||^2 at tau grid");
    for (label, algo) in algos {
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        let mut mins = Vec::new();
        for &t in &horizons {
            let mut acc = 0.0f64;
            // This driver has always seeded repeat r with the bare r (not
            // the seed_for_repeat offset), so it pins seed r explicitly in
            // a single-repeat spec — reproduced numbers must not drift
            // across versions.
            for r in 0..repeats {
                let spec = apply_execution_flags(
                    ExperimentSpec::new(
                        format!("table2_tau{t}"),
                        WorkloadSpec::LeastSquares {
                            clients: 8,
                            dim: 50,
                            rows_per_client: 20,
                            heterogeneity: 0.5,
                            noise: 0.5,
                            problem_seed: 11,
                            stochastic: true,
                        },
                    )
                    .rounds(t)
                    .eval_every((t / 20).max(1))
                    .seed(r as u64)
                    .series(algo.clone()),
                    args,
                )?;
                // No sinks: the fitted-slope table below is the output.
                let result = Session::new().run(&spec)?;
                // "Best gradient norm so far" — the standard nonconvex
                // metric.
                let best = result.series[0].runs[0]
                    .records
                    .iter()
                    .filter_map(|rec| rec.grad_norm_sq)
                    .fold(f64::INFINITY, f64::min);
                acc += best;
            }
            let mean = acc / repeats as f64;
            xs.push((t as f64).ln());
            ys.push(mean.ln());
            mins.push(mean);
        }
        let slope = ols_slope(&xs, &ys);
        let minstr: Vec<String> = mins.iter().map(|m| format!("{m:.2e}")).collect();
        println!("{label:<14} {slope:>12.3} {:>32}", minstr.join(" "));
    }
    println!("\nShape check: GD and the stochastic-sign rows should show clearly");
    println!("negative slopes; vanilla SignSGD should flatten (bias floor).");
    Ok(())
}
