//! Scenario suite (`zsfa scenarios`): the client-lifecycle simulator's two
//! headline experiments, beyond anything in the paper's figure set.
//!
//! **Part A — lifecycle time-to-target.** FedAvg vs 1-SignFedAvg on a
//! high-dimensional consensus problem under the cross-device fleet:
//! over-selected cohorts, report deadlines, dropouts. The x-axis is
//! *simulated wall-clock* (`RoundRecord::sim_time_s`), where 1-bit uplinks
//! shrink the upload leg of every client's round.
//!
//! **Part B — byzantine robustness curves.** Final optimality gap vs
//! attacker fraction for both attack modes (`sign-flip`, `grad-negate`).
//! The headline: majority-vote sign aggregation degrades gracefully —
//! an attacker's vote is worth ±1 per coordinate no matter how hard it
//! lies — while the dense mean inherits the attacker's magnitude and, at
//! 10% gradient-negating clients with a 10× boost, turns the update
//! direction *ascending*. A third series runs the same sign method under
//! the trimmed-count majority rule (`RobustRule::TrimmedMajority`,
//! `--trim-frac`, default 0.2): trimming the most one-sided vote counts
//! buys extra headroom exactly where the plain vote starts to bend.
//!
//! All runs use analytic backends: no artifacts needed, `--parallelism`
//! fans clients out with bit-identical results. Scenario knobs are the
//! `--sim_*` flags (see `sim::ScenarioConfig::from_config`).

use super::common::*;
use crate::api::{CsvSink, ExperimentSpec, Session, WorkloadSpec};
use crate::cli::Args;
use crate::compress::agg::RobustRule;
use crate::fl::server::Participation;
use crate::fl::AlgorithmConfig;
use crate::problems::consensus::Consensus;
use crate::problems::AnalyticProblem;
use crate::rng::ZParam;
use crate::sim::{time_to_objective, ByzantineMode, ScenarioConfig};

pub fn run(args: &Args) -> crate::error::Result<()> {
    // Scenario knobs: defaults overridden by any --sim_* flag.
    let mut overrides = crate::config::Config::new();
    args.apply_overrides(&mut overrides);
    let base = ScenarioConfig::from_config(&overrides)?;

    lifecycle_time_to_target(args, &base)?;
    byzantine_robustness(args, &base)
}

/// Part A: stragglers, deadlines and dropouts — who wins on the simulated
/// clock.
fn lifecycle_time_to_target(args: &Args, base: &ScenarioConfig) -> crate::error::Result<()> {
    banner("Scenarios A — cross-device lifecycle: time-to-target");
    let rounds = args.usize_or("rounds", 300)?;
    let repeats = args.usize_or("repeats", 3)?;
    let n = args.usize_or("clients", 60)?;
    // Large d so the uplink leg is visible next to compute + latency.
    let d = args.usize_or("dim", 20_000)?;
    let e = args.usize_or("local-steps", 2)?;
    let sigma = args.f32_or("sigma", 2.0)?;
    let sc = ScenarioConfig { byzantine_frac: 0.0, ..base.clone() };
    println!(
        "  n={n} d={d} E={e}  fleet={:?} target={} overselect={} deadline={}s dropout={}",
        sc.fleet, sc.target_cohort, sc.overselect, sc.deadline_s, sc.dropout_prob
    );

    let f_star = Consensus::gaussian(n, d, 99).optimal_value().unwrap();
    let spec = apply_execution_flags(
        ExperimentSpec::new("scenarios_lifecycle", WorkloadSpec::consensus(n, d, 99))
            .rounds(rounds)
            .eval_every((rounds / 100).max(1))
            .seed(args.u64_or("seed", 0)?)
            .repeats(repeats)
            .participation(Participation::Simulated(sc))
            .subtract_optimal(true)
            .series(AlgorithmConfig::fedavg(e).with_lrs(0.05, 1.0))
            .series(AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e).with_lrs(0.05, 1.0)),
        args,
    )?;
    // CSV only: this driver prints its own time-to-target table.
    let result = Session::new().with(CsvSink::new()).run(&spec)?;

    for sr in &result.series {
        // Time to close 90% of the initial optimality gap, per repeat.
        let gap0 = sr.runs[0].records.first().map(|r| r.objective - f_star).unwrap_or(0.0);
        let target = f_star + 0.1 * gap0;
        let hits: Vec<f64> =
            sr.runs.iter().filter_map(|r| time_to_objective(r, target)).collect();
        let ttt = if hits.is_empty() {
            "      -".to_string()
        } else {
            format!("{:7.1}", hits.iter().sum::<f64>() / hits.len() as f64)
        };
        let last = sr.runs[0].records.last().unwrap();
        println!(
            "  {:<24} final gap {:>11.4e}   sim {:>7.1} s   to-90% {ttt} s   \
             arrivals {}/{} per round",
            sr.algorithm,
            sr.aggregated.objective_mean.last().unwrap(),
            last.sim_time_s,
            last.arrived,
            last.selected,
        );
    }
    println!("  (same rounds; the sign uplink shortens every simulated round)");
    Ok(())
}

/// Part B: robustness curves over the byzantine fraction. One spec per
/// (attack mode, fraction) — the scenario is a server-level knob — with
/// both algorithms as series.
fn byzantine_robustness(args: &Args, base: &ScenarioConfig) -> crate::error::Result<()> {
    banner("Scenarios B — byzantine robustness: final gap vs attacker fraction");
    let rounds = args.usize_or("byz-rounds", 400)?;
    let n = args.usize_or("clients", 60)?;
    let d = 200; // the attack story is about aggregation, not payload size
    let e = args.usize_or("local-steps", 2)?;
    let sigma = args.f32_or("sigma", 2.0)?;
    let repeats = args.usize_or("repeats", 3)?;
    let fracs = [0.0f32, 0.1, 0.2, 0.3];
    let trim = args.f32_or("trim-frac", 0.2)?;
    let mut trimmed = AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e)
        .with_lrs(0.05, 1.0)
        .with_robust(RobustRule::TrimmedMajority { frac: trim });
    trimmed.name = format!("1-signfedavg-trim{trim}");
    let algos = vec![
        AlgorithmConfig::fedavg(e).with_lrs(0.05, 1.0),
        AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e).with_lrs(0.05, 1.0),
        trimmed,
    ];

    // Both attack modes are swept; --sim_byzantine_boost (via a
    // gradnegate --sim_byzantine_mode) overrides the magnitude-attack
    // boost. The fraction axis is fixed — that *is* the sweep.
    let boost = match base.byzantine_mode {
        ByzantineMode::GradNegate { boost } => boost,
        ByzantineMode::SignFlip => 10.0,
    };
    for (label, mode) in [
        ("sign-flip".to_string(), ByzantineMode::SignFlip),
        (format!("grad-negate(x{boost})"), ByzantineMode::GradNegate { boost }),
    ] {
        println!("\n-- attack: {label} --");
        print!("  {:<24}", "algorithm");
        for f in fracs {
            let cell = format!("byz={f}");
            print!(" {cell:>12}");
        }
        println!("   degradation@10%");

        // gaps[algo][frac], filled one fraction (= one spec) at a time.
        let mut gaps: Vec<Vec<f64>> = vec![Vec::new(); algos.len()];
        for frac in fracs {
            let sc = ScenarioConfig {
                byzantine_frac: frac,
                byzantine_mode: mode,
                ..base.clone()
            };
            let safe = label.replace(['(', ')'], "_");
            let mut spec = ExperimentSpec::new(
                format!("scenarios_byz_{safe}"),
                WorkloadSpec::consensus(n, d, 99),
            )
            .rounds(rounds)
            .eval_every((rounds / 50).max(1))
            .seed(args.u64_or("seed", 0)?)
            .repeats(repeats)
            .participation(Participation::Simulated(sc))
            .subtract_optimal(true);
            for algo in &algos {
                let series_label = format!("{}_f{frac}", algo.name);
                spec = spec.series_labeled(series_label.clone(), series_label, algo.clone());
            }
            let result =
                Session::new().with(CsvSink::new()).run(&apply_execution_flags(spec, args)?)?;
            for (i, sr) in result.series.iter().enumerate() {
                gaps[i].push(*sr.aggregated.objective_mean.last().unwrap());
            }
        }
        for (i, algo) in algos.iter().enumerate() {
            print!("  {:<24}", algo.name);
            for g in &gaps[i] {
                print!(" {g:>12.4e}");
            }
            // Degradation: gap at 10% attackers relative to the byz-free
            // floor. Sign voting bounds each attacker to ±1 per coordinate,
            // so this ratio stays small; the dense mean does not.
            let deg = gaps[i][1] / gaps[i][0].max(1e-12);
            println!("   {deg:>12.2e}");
        }
    }
    println!(
        "\n  Majority-vote sign aggregation degrades more gracefully: an attacker's\n  \
         report is clipped to one vote per coordinate, while the dense mean\n  \
         inherits its (arbitrarily scaled) magnitude. The trimmed-count rule\n  \
         (trim {trim}) discards the most one-sided vote counts before the\n  \
         majority decision, trading a little byz-free accuracy for a flatter\n  \
         curve at high attacker fractions."
    );
    Ok(())
}
