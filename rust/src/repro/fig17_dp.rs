//! Figure 17 (Appendix F): differentially-private FL — DP-SignFedAvg
//! (Algorithm 2, 1 bit/coordinate) vs uncompressed DP-FedAvg, across privacy
//! budgets ε ∈ {1, 2, 4, 6, 8, 10}.
//!
//! The noise multiplier per ε is *calibrated with the RDP accountant* (the
//! paper's Table 8 workflow: subsampled Gaussian, q = 100/3579, T = 500,
//! δ = 1/n, clip C = 0.01). Server stepsizes from Table 8.
//!
//! Expected shape: DP-SignFedAvg tracks DP-FedAvg within a small gap at
//! every ε — the headline of Appendix F (sign compression is free
//! post-processing under DP).

use super::common::*;
use crate::api::{CsvSink, Dataset, ExperimentSpec, Session, WorkloadSpec};
use crate::cli::Args;
use crate::dp::calibrate_noise;
use crate::error::anyhow;
use crate::fl::AlgorithmConfig;

pub fn run(args: &Args) -> crate::error::Result<()> {
    banner("Figure 17 — DP-SignFedAvg vs DP-FedAvg on EMNIST");
    let dataset = Dataset::parse(args.str_or("dataset", "emnist"))
        .ok_or_else(|| anyhow!("--dataset mnist|emnist|cifar"))?;
    let rounds = args.usize_or("rounds", 100)?;
    let repeats = args.usize_or("repeats", 2)?;
    let clip = args.f32_or("clip", 0.01)?;
    let e = args.usize_or("local-steps", 5)?;
    let epsilons: Vec<f64> = args.list_or("epsilons", &[1.0, 2.0, 4.0, 6.0, 8.0, 10.0])?;
    let cpr = clients_per_round(dataset, args)?;
    let nspec = neural_spec_from_args(dataset, args)?;

    // Accounting uses the *actual* experiment's sampling rate and rounds.
    // The spec knows the population statically (partitioning always yields
    // exactly `clients` shards) — no need to build a probe backend.
    let n_clients = nspec.clients;
    let q = cpr.map(|m| m as f64 / n_clients as f64).unwrap_or(1.0);
    let delta = 1.0 / n_clients as f64;
    println!("accounting: q={q:.4}, T={rounds}, delta={delta:.2e}, clip={clip}");

    println!(
        "\n{:>6} {:>10} {:>22} {:>22}",
        "eps", "sigma", "DP-SignFedAvg acc", "DP-FedAvg acc"
    );
    for &eps in &epsilons {
        let noise_mult = calibrate_noise(q, rounds as u64, delta, eps) as f32;
        // Table 8 server stepsizes: 0.03–0.05 for sign, 1–5 for dense.
        let sign_lr = args.f32_or("sign-server-lr", if eps < 1.5 { 0.03 } else { 0.05 })?;
        let dense_lr = args.f32_or("dense-server-lr", if eps < 1.5 { 1.0 } else { 5.0 })?;
        let mut spec =
            ExperimentSpec::new("fig17", WorkloadSpec::Neural(nspec.clone()))
                .rounds(rounds)
                .eval_every((rounds / 10).max(1))
                .repeats(repeats)
                .clients_per_round(cpr);
        for algo in [
            AlgorithmConfig::dp_signfedavg(clip, noise_mult, e).with_lrs(0.05, sign_lr),
            AlgorithmConfig::dp_fedavg(clip, noise_mult, e).with_lrs(0.05, dense_lr),
        ] {
            let label = format!("{}_eps{eps}", algo.name);
            spec = spec.series_labeled(label.clone(), label, algo);
        }
        // CSV only: the ε table below is this driver's console output.
        let result =
            Session::new().with(CsvSink::new()).run(&apply_execution_flags(spec, args)?)?;
        let accs: Vec<f64> = result
            .series
            .iter()
            .map(|s| *s.aggregated.accuracy_mean.last().unwrap())
            .collect();
        println!(
            "{eps:>6.1} {noise_mult:>10.3} {:>21.2}% {:>21.2}%",
            100.0 * accs[0],
            100.0 * accs[1]
        );
    }
    println!("\nShape check: the two columns should stay within a few points of");
    println!("each other at every eps, with accuracy increasing in eps.");
    Ok(())
}
