//! Figure 1: the consensus problem under different problem dimensions.
//!
//! Paper setting (§4.1): 10 clients, `min_x (1/2n) Σ‖x − y_i‖²` with i.i.d.
//! standard-Gaussian targets, full gradients, stepsize 0.01, zero init,
//! d ∈ {10, 100, 1000, 10000}, 10 repeats. Algorithms: GD, SignSGD,
//! Sto-SignSGD [43], 1-SignSGD, ∞-SignSGD.
//!
//! Expected shape (paper Fig. 1): vanilla SignSGD stalls above the optimum;
//! 1-/∞-SignSGD track GD closely; Sto-SignSGD's input-dependent noise scale
//! (σ = ‖x‖₂, which grows with d) slows it down badly at high dimension.
//!
//! Also runs the §1 two-client counterexample, reporting the stall of
//! SignSGD and the σ-threshold of ∞-SignSGD (Theorem 2 / Remark 2).
//!
//! This driver is a thin spec factory: [`spec_for_dim`] is the preset, the
//! `api::Session` does the running.

use super::common::*;
use crate::api::{ExperimentSpec, Session, WorkloadSpec};
use crate::cli::Args;
use crate::fl::AlgorithmConfig;
use crate::problems::consensus::Consensus;
use crate::problems::AnalyticProblem;
use crate::rng::ZParam;

/// The Fig. 1 preset for one dimension `d`. `examples/quickstart.json` is
/// exactly `spec_for_dim(8, 50, 40, 2, 0.01, 3.0)` — pinned by an
/// integration test and byte-diffed against this driver by
/// `make spec-smoke`.
pub fn spec_for_dim(
    n: usize,
    d: usize,
    rounds: usize,
    repeats: usize,
    lr: f32,
    sigma: f32,
) -> ExperimentSpec {
    ExperimentSpec::new(format!("fig1_d{d}"), WorkloadSpec::consensus(n, d, 99))
        .rounds(rounds)
        .eval_every((rounds / 100).max(1))
        .repeats(repeats)
        .subtract_optimal(true)
        .series(AlgorithmConfig::gd().with_lrs(lr, 1.0))
        .series(AlgorithmConfig::signsgd().with_lrs(lr, 1.0))
        .series(AlgorithmConfig::sto_signsgd().with_lrs(lr, 1.0))
        .series(AlgorithmConfig::z_signsgd(ZParam::Finite(1), sigma).with_lrs(lr, 1.0))
        .series(AlgorithmConfig::z_signsgd(ZParam::Inf, sigma).with_lrs(lr, 1.0))
}

pub fn run(args: &Args) -> crate::error::Result<()> {
    banner("Figure 1 — consensus problem, varying dimension");
    let rounds = args.usize_or("rounds", 600)?;
    let repeats = args.usize_or("repeats", 5)?;
    let lr = args.f32_or("lr", 0.01)?;
    let sigma = args.f32_or("sigma", 3.0)?;
    let n = args.usize_or("clients", 10)?;
    let dims: Vec<usize> = if args.has("paper-scale") {
        vec![10, 100, 1000, 10000]
    } else {
        args.list_or("dims", &[10, 100, 1000, 10000])?
    };

    for &d in &dims {
        println!("\n-- dimension d = {d} --");
        let f_star = Consensus::gaussian(n, d, 99).optimal_value().unwrap();
        println!("  f* = {f_star:.6}");
        let spec = apply_execution_flags(spec_for_dim(n, d, rounds, repeats, lr, sigma), args)?;
        Session::console().run(&spec)?;
    }

    counterexample_report(args)
}

/// The §1 counterexample + Theorem 2's σ-threshold, printed as a table.
fn counterexample_report(args: &Args) -> crate::error::Result<()> {
    banner("§1 counterexample: min (x−A)² + (x+A)², A = 4, x0 = 2");
    let rounds = args.usize_or("rounds", 600)?;
    let a = 4.0f32;
    let cases: Vec<(String, AlgorithmConfig)> = vec![
        ("SignSGD (stalls)".into(), AlgorithmConfig::signsgd().with_lrs(0.01, 1.0)),
        (
            "inf-SignSGD sigma=1 < threshold (stalls)".into(),
            AlgorithmConfig::z_signsgd(ZParam::Inf, 1.0).with_lrs(0.01, 1.0),
        ),
        (
            "inf-SignSGD sigma=20 > threshold (converges)".into(),
            AlgorithmConfig::z_signsgd(ZParam::Inf, 20.0).with_lrs(0.05, 1.0),
        ),
        (
            "1-SignSGD sigma=5 (converges)".into(),
            AlgorithmConfig::z_signsgd(ZParam::Finite(1), 5.0).with_lrs(0.05, 1.0),
        ),
    ];
    for (label, algo) in cases {
        let spec = apply_execution_flags(
            ExperimentSpec::new(
                "fig1_counterexample",
                WorkloadSpec::Counterexample { a, x0: a / 2.0 },
            )
            .rounds(rounds)
            .eval_every((rounds / 50).max(1))
            .series(algo),
            args,
        )?;
        // No sinks: the report below is the output.
        let result = Session::new().run(&spec)?;
        let records = &result.series[0].runs[0].records;
        let first = records.first().unwrap().objective;
        let last = records.last().unwrap().objective;
        println!("  {label:<46} f: {first:>10.4} -> {last:>10.4}");
    }
    Ok(())
}
