//! Figure 1: the consensus problem under different problem dimensions.
//!
//! Paper setting (§4.1): 10 clients, `min_x (1/2n) Σ‖x − y_i‖²` with i.i.d.
//! standard-Gaussian targets, full gradients, stepsize 0.01, zero init,
//! d ∈ {10, 100, 1000, 10000}, 10 repeats. Algorithms: GD, SignSGD,
//! Sto-SignSGD [43], 1-SignSGD, ∞-SignSGD.
//!
//! Expected shape (paper Fig. 1): vanilla SignSGD stalls above the optimum;
//! 1-/∞-SignSGD track GD closely; Sto-SignSGD's input-dependent noise scale
//! (σ = ‖x‖₂, which grows with d) slows it down badly at high dimension.
//!
//! Also runs the §1 two-client counterexample, reporting the stall of
//! SignSGD and the σ-threshold of ∞-SignSGD (Theorem 2 / Remark 2).

use super::common::*;
use crate::cli::Args;
use crate::fl::backend::AnalyticBackend;
use crate::fl::server::ServerConfig;
use crate::fl::AlgorithmConfig;
use crate::problems::consensus::Consensus;
use crate::problems::AnalyticProblem;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    banner("Figure 1 — consensus problem, varying dimension");
    let rounds = args.usize_or("rounds", 600);
    let repeats = args.usize_or("repeats", 5);
    let lr = args.f32_or("lr", 0.01);
    let sigma = args.f32_or("sigma", 3.0);
    let n = args.usize_or("clients", 10);
    let dims: Vec<usize> = if args.has("paper-scale") {
        vec![10, 100, 1000, 10000]
    } else {
        args.flag("dims")
            .map(|s| s.split(',').map(|d| d.parse().unwrap()).collect())
            .unwrap_or_else(|| vec![10, 100, 1000, 10000])
    };

    for &d in &dims {
        println!("\n-- dimension d = {d} --");
        let algos = vec![
            AlgorithmConfig::gd().with_lrs(lr, 1.0),
            AlgorithmConfig::signsgd().with_lrs(lr, 1.0),
            AlgorithmConfig::sto_signsgd().with_lrs(lr, 1.0),
            AlgorithmConfig::z_signsgd(ZParam::Finite(1), sigma).with_lrs(lr, 1.0),
            AlgorithmConfig::z_signsgd(ZParam::Inf, sigma).with_lrs(lr, 1.0),
        ];
        let f_star = Consensus::gaussian(n, d, 99).optimal_value().unwrap();
        println!("  f* = {f_star:.6}");
        for algo in &algos {
            let cfg = ServerConfig {
                rounds,
                eval_every: (rounds / 100).max(1),
                parallelism: args.parallelism_or(1),
                reduce_lanes: args.reduce_lanes_or(ServerConfig::default().reduce_lanes),
                ..Default::default()
            };
            let (mut agg, runs) = run_repeats(
                || AnalyticBackend::new(Consensus::gaussian(n, d, 99)),
                algo,
                &cfg,
                repeats,
            );
            // Report the optimality gap, matching the paper's y-axis.
            for v in agg.objective_mean.iter_mut() {
                *v -= f_star;
            }
            save_series(&format!("fig1_d{d}"), &algo.name, &agg, &runs);
            print_summary_row(&algo.name, &agg);
        }
    }

    counterexample_report(args);
    Ok(())
}

/// The §1 counterexample + Theorem 2's σ-threshold, printed as a table.
fn counterexample_report(args: &Args) {
    banner("§1 counterexample: min (x−A)² + (x+A)², A = 4, x0 = 2");
    let rounds = args.usize_or("rounds", 600);
    let a = 4.0f32;
    let cases: Vec<(String, AlgorithmConfig)> = vec![
        ("SignSGD (stalls)".into(), AlgorithmConfig::signsgd().with_lrs(0.01, 1.0)),
        (
            "inf-SignSGD sigma=1 < threshold (stalls)".into(),
            AlgorithmConfig::z_signsgd(ZParam::Inf, 1.0).with_lrs(0.01, 1.0),
        ),
        (
            "inf-SignSGD sigma=20 > threshold (converges)".into(),
            AlgorithmConfig::z_signsgd(ZParam::Inf, 20.0).with_lrs(0.05, 1.0),
        ),
        (
            "1-SignSGD sigma=5 (converges)".into(),
            AlgorithmConfig::z_signsgd(ZParam::Finite(1), 5.0).with_lrs(0.05, 1.0),
        ),
    ];
    for (label, algo) in cases {
        let mut b = AnalyticBackend::new(Consensus::counterexample(a));
        b.x0 = vec![a / 2.0];
        let cfg = ServerConfig {
            rounds,
            eval_every: (rounds / 50).max(1),
            parallelism: args.parallelism_or(1),
            reduce_lanes: args.reduce_lanes_or(ServerConfig::default().reduce_lanes),
            ..Default::default()
        };
        let run = crate::fl::server::run_experiment(&mut b, &algo, &cfg);
        let first = run.records.first().unwrap().objective;
        let last = run.records.last().unwrap().objective;
        println!("  {label:<46} f: {first:>10.4} -> {last:>10.4}");
    }
}
