//! Shared driver plumbing: banners + CLI→spec helpers.
//!
//! The repeat loop, seed-offset convention, CSV writing and summary
//! printing that used to live here moved behind the experiment API
//! (`api::Session` + its observers); what remains is the translation from
//! command-line flags to spec fields that every driver shares.

use crate::api::{Dataset, ExperimentSpec, NeuralSpec};
use crate::cli::Args;
use crate::error::{anyhow, Result};
use std::path::PathBuf;

/// Markdown-style header for driver output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

/// Apply the execution knobs every driver exposes: `--parallelism` and
/// `--reduce-lanes`. Both are result-preserving for any fixed lane count
/// (the engine's determinism contract), so they ride on every spec without
/// changing what the experiment *is*.
pub fn apply_execution_flags(spec: ExperimentSpec, args: &Args) -> Result<ExperimentSpec> {
    let lanes_default = spec.reduce_lanes;
    let par_default = spec.parallelism;
    Ok(spec
        .parallelism(args.parallelism_or(par_default)?)
        .reduce_lanes(args.reduce_lanes_or(lanes_default)?))
}

/// Build the neural-workload spec from CLI flags (`--clients`,
/// `--train-samples`, `--test-samples`, `--paper-scale`, `--artifacts`),
/// falling back to the dataset's testbed defaults.
pub fn neural_spec_from_args(dataset: Dataset, args: &Args) -> Result<NeuralSpec> {
    let paper_scale = args.has("paper-scale");
    let (clients_d, _, train_d) = dataset.defaults(paper_scale);
    Ok(NeuralSpec {
        dataset,
        clients: args.usize_or("clients", clients_d)?,
        train_samples: args.usize_or("train-samples", train_d)?,
        test_samples: args.opt_usize("test-samples")?,
        paper_scale,
        artifacts: PathBuf::from(args.str_or("artifacts", "artifacts")),
    })
}

/// Clients-per-round for a workload (None = full participation):
/// `--clients-per-round N|all`, defaulting per dataset.
pub fn clients_per_round(dataset: Dataset, args: &Args) -> Result<Option<usize>> {
    let (_, default, _) = dataset.defaults(args.has("paper-scale"));
    Ok(match args.flag("clients-per-round") {
        Some("all") => None,
        Some(s) => Some(
            s.parse().map_err(|_| anyhow!("--clients-per-round: bad integer {s:?}"))?,
        ),
        None => default,
    })
}
