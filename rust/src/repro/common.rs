//! Shared driver plumbing: repeat runner, result table printing, CSV layout.

use crate::fl::backend::TrainBackend;
use crate::fl::metrics::{aggregate, write_csv, write_runs_csv, Aggregated, RunResult};
use crate::fl::server::{run_experiment, ServerConfig};
use crate::fl::AlgorithmConfig;
use std::path::{Path, PathBuf};

/// Run `repeats` independent seeds of one algorithm and aggregate.
///
/// `make_backend` is called once per repeat (backends can hold RNG-derived
/// state); the paper's protocol keeps the problem/dataset fixed and varies
/// only the algorithmic randomness, which is what the seed offset does.
pub fn run_repeats<B: TrainBackend>(
    mut make_backend: impl FnMut() -> B,
    algo: &AlgorithmConfig,
    cfg: &ServerConfig,
    repeats: usize,
) -> (Aggregated, Vec<RunResult>) {
    let mut runs = Vec::with_capacity(repeats);
    for r in 0..repeats {
        let mut backend = make_backend();
        let cfg_r = ServerConfig { seed: cfg.seed.wrapping_add(1000 * r as u64), ..cfg.clone() };
        runs.push(run_experiment(&mut backend, algo, &cfg_r));
    }
    (aggregate(&runs), runs)
}

/// Results directory (`results/<figure>/`).
pub fn results_dir(figure: &str) -> PathBuf {
    Path::new("results").join(figure)
}

/// Persist aggregated + raw CSVs for one algorithm series.
pub fn save_series(figure: &str, series: &str, agg: &Aggregated, runs: &[RunResult]) {
    let dir = results_dir(figure);
    let safe = series.replace(['/', ' ', '(', ')', '=', ','], "_");
    write_csv(&dir.join(format!("{safe}.csv")), agg).expect("writing csv");
    write_runs_csv(&dir.join(format!("{safe}_raw.csv")), runs).expect("writing raw csv");
}

/// Print a compact per-algorithm summary row.
pub fn print_summary_row(series: &str, agg: &Aggregated) {
    let last = agg.rounds.len() - 1;
    let acc = if agg.accuracy_mean[last].is_nan() {
        "      -".to_string()
    } else {
        format!("{:6.2}%", 100.0 * agg.accuracy_mean[last])
    };
    println!(
        "  {series:<28} final obj {:>12.6} ± {:>9.6}   acc {acc}   uplink {:>10.2} Mbit",
        agg.objective_mean[last],
        agg.objective_std[last],
        agg.bits_up[last] as f64 / 1e6,
    );
}

/// Markdown-style header for driver output.
pub fn banner(title: &str) {
    println!("\n=== {title} ===");
}

// ---------------------------------------------------------------------------
// Neural workloads: dataset + partition + PJRT backend wiring
// ---------------------------------------------------------------------------

use crate::cli::Args;
use crate::data::{partition, synth};
use crate::runtime::{ModelRuntime, XlaBackend};

/// A named neural workload preset (the paper's three dataset settings,
/// scaled to the 1-core testbed — see DESIGN.md §3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Workload {
    /// §4.2 non-iid MNIST: 10 clients, one label each, full participation.
    NoniidMnist,
    /// §4.3 EMNIST: many clients (iid shards), partial participation.
    Emnist,
    /// §4.3 CIFAR-10: Dirichlet(1) skew, 10/100 clients per round.
    Cifar,
}

impl Workload {
    pub fn parse(s: &str) -> Option<Workload> {
        match s {
            "mnist" | "noniid-mnist" => Some(Workload::NoniidMnist),
            "emnist" => Some(Workload::Emnist),
            "cifar" | "cifar10" => Some(Workload::Cifar),
            _ => None,
        }
    }

    pub fn model(self) -> &'static str {
        match self {
            Workload::NoniidMnist => "mnist_cnn",
            Workload::Emnist => "emnist_cnn",
            Workload::Cifar => "cifar_cnn",
        }
    }

    /// (default clients, default clients-per-round, default train size)
    /// Paper scale: EMNIST 3579 clients / 100 sampled; CIFAR 100 / 10.
    /// Defaults are scaled ~10× down to fit the testbed; `--paper-scale`
    /// restores the paper's counts.
    pub fn defaults(self, paper_scale: bool) -> (usize, Option<usize>, usize) {
        match (self, paper_scale) {
            (Workload::NoniidMnist, _) => (10, None, 2000),
            (Workload::Emnist, false) => (358, Some(10), 3580),
            (Workload::Emnist, true) => (3579, Some(100), 35790),
            (Workload::Cifar, false) => (100, Some(10), 2000),
            (Workload::Cifar, true) => (100, Some(10), 20000),
        }
    }
}

/// Build the PJRT-backed federated workload from CLI flags.
pub fn build_xla_backend(workload: Workload, args: &Args) -> crate::error::Result<XlaBackend> {
    let artifacts = Path::new(args.str_or("artifacts", "artifacts"));
    let runtime = ModelRuntime::open(artifacts, workload.model())?;
    let paper_scale = args.has("paper-scale");
    let (n_clients_d, _, n_train_d) = workload.defaults(paper_scale);
    let n_clients = args.usize_or("clients", n_clients_d);
    let n_train = args.usize_or("train-samples", n_train_d);
    let n_test = args.usize_or("test-samples", 2 * runtime.eval_batch);

    let spec = match workload {
        Workload::NoniidMnist => synth::SynthSpec::mnist(),
        Workload::Emnist => synth::SynthSpec::emnist(),
        Workload::Cifar => synth::SynthSpec::cifar(),
    };
    let (train, test) = synth::train_test(spec, n_train, n_test);
    let fed = match workload {
        Workload::NoniidMnist => partition::by_label(train, n_clients),
        Workload::Emnist => partition::iid(train, n_clients, 42),
        Workload::Cifar => partition::dirichlet(train, n_clients, 1.0, 42),
    };
    let init = runtime.load_init()?;
    Ok(XlaBackend::new(runtime, fed, test, init))
}

/// Clients-per-round default for a workload (None = full participation).
pub fn clients_per_round(workload: Workload, args: &Args) -> Option<usize> {
    let (_, default, _) = workload.defaults(args.has("paper-scale"));
    match args.flag("clients-per-round") {
        Some("all") => None,
        Some(s) => Some(s.parse().expect("--clients-per-round")),
        None => default,
    }
}
