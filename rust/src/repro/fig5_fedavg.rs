//! Figure 5 (CIFAR-10) / Figure 8 (EMNIST) + the Appendix D.2 σ×E sweeps
//! (Figures 9–13): z-SignFedAvg vs uncompressed FedAvg with multiple local
//! steps and partial client participation.
//!
//! Paper settings (§4.3, Tables 4/5): EMNIST — 3579 clients, 100 sampled
//! per round, client lr 0.05, server lr 0.03, σ = 0.01; CIFAR-10 — 100
//! clients Dirichlet(1), 10 sampled, client lr 0.1, server lr 0.0032,
//! σ = 0.0005. Both use the same CNN family; E ∈ {1, 5, 10}.
//!
//! Expected shape: both FedAvg and 1-SignFedAvg improve with E; 1-SignFedAvg
//! tracks (sometimes beats) FedAvg per round while using 32× fewer uplink
//! bits; 1- and ∞-SignFedAvg are nearly indistinguishable.

use super::common::*;
use crate::api::{Dataset, ExperimentSpec, Session, WorkloadSpec};
use crate::cli::Args;
use crate::fl::AlgorithmConfig;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    let dataset = Dataset::parse(args.str_or("dataset", "cifar"))
        .ok_or_else(|| crate::anyhow!("--dataset mnist|emnist|cifar"))?;
    if args.has("sweep") {
        return sweep_sigma_e(args, dataset);
    }
    banner(&format!("Figure 5/8 — FedAvg vs z-SignFedAvg on {dataset:?}"));
    let rounds = args.usize_or("rounds", 60)?;
    let repeats = args.usize_or("repeats", 1)?;
    let local_steps: Vec<usize> = args.list_or("local-steps", &[1, 5])?;
    // Table 4/5 hyperparameters.
    let (client_lr, server_lr, sigma) = match dataset {
        Dataset::Emnist => (
            args.f32_or("client-lr", 0.05)?,
            args.f32_or("server-lr", 0.03)?,
            args.f32_or("sigma", 0.01)?,
        ),
        _ => (
            args.f32_or("client-lr", 0.1)?,
            args.f32_or("server-lr", 0.0032)?,
            args.f32_or("sigma", 0.0005)?,
        ),
    };
    let cpr = clients_per_round(dataset, args)?;

    for &e in &local_steps {
        println!("\n-- E = {e} (clients/round: {cpr:?}) --");
        let mut spec = ExperimentSpec::new(
            format!("fig5_{}_e{e}", args.str_or("dataset", "cifar")),
            WorkloadSpec::Neural(neural_spec_from_args(dataset, args)?),
        )
        .rounds(rounds)
        .eval_every((rounds / 20).max(1))
        .repeats(repeats)
        .clients_per_round(cpr);
        for algo in [
            AlgorithmConfig::fedavg(e).with_lrs(client_lr, 1.0),
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e)
                .with_lrs(client_lr, server_lr),
            AlgorithmConfig::z_signfedavg(ZParam::Inf, sigma, e)
                .with_lrs(client_lr, server_lr),
            AlgorithmConfig::sign_fedavg(e).with_lrs(client_lr, server_lr),
        ] {
            let display = format!("{} (E={e})", algo.name);
            spec = spec.series_labeled(algo.name.clone(), display, algo);
        }
        Session::console().run(&apply_execution_flags(spec, args)?)?;
    }
    Ok(())
}

/// Figures 9–13: σ × E grid for z ∈ {1, ∞}. Expanded explicitly so the
/// historical `e{E}_sigma{σ}` file stems are preserved even for
/// single-element axes.
fn sweep_sigma_e(args: &Args, dataset: Dataset) -> crate::error::Result<()> {
    banner(&format!("Figures 9-13 — sigma x E sweep on {dataset:?}"));
    let rounds = args.usize_or("rounds", 60)?;
    let repeats = args.usize_or("repeats", 1)?;
    let sigmas: Vec<f32> = args.list_or("sigmas", &[0.0, 0.0005, 0.005, 0.05])?;
    let es: Vec<usize> = args.list_or("local-steps", &[1, 5])?;
    let (client_lr, server_lr) = match dataset {
        Dataset::Emnist => (0.05, 0.03),
        _ => (0.1, 0.0032),
    };
    let cpr = clients_per_round(dataset, args)?;
    for z in [ZParam::Finite(1), ZParam::Inf] {
        let mut spec = ExperimentSpec::new(
            format!("fig9_13_{}_z{z}", args.str_or("dataset", "cifar")),
            WorkloadSpec::Neural(neural_spec_from_args(dataset, args)?),
        )
        .rounds(rounds)
        .eval_every((rounds / 10).max(1))
        .repeats(repeats)
        .clients_per_round(cpr);
        for &e in &es {
            for &sigma in &sigmas {
                spec = spec.series_labeled(
                    format!("e{e}_sigma{sigma}"),
                    format!("z={z} E={e} sigma={sigma}"),
                    AlgorithmConfig::z_signfedavg(z, sigma, e).with_lrs(client_lr, server_lr),
                );
            }
        }
        Session::console().run(&apply_execution_flags(spec, args)?)?;
    }
    Ok(())
}
