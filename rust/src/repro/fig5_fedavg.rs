//! Figure 5 (CIFAR-10) / Figure 8 (EMNIST) + the Appendix D.2 σ×E sweeps
//! (Figures 9–13): z-SignFedAvg vs uncompressed FedAvg with multiple local
//! steps and partial client participation.
//!
//! Paper settings (§4.3, Tables 4/5): EMNIST — 3579 clients, 100 sampled
//! per round, client lr 0.05, server lr 0.03, σ = 0.01; CIFAR-10 — 100
//! clients Dirichlet(1), 10 sampled, client lr 0.1, server lr 0.0032,
//! σ = 0.0005. Both use the same CNN family; E ∈ {1, 5, 10}.
//!
//! Expected shape: both FedAvg and 1-SignFedAvg improve with E; 1-SignFedAvg
//! tracks (sometimes beats) FedAvg per round while using 32× fewer uplink
//! bits; 1- and ∞-SignFedAvg are nearly indistinguishable.

use super::common::*;
use crate::cli::Args;
use crate::fl::server::ServerConfig;
use crate::fl::AlgorithmConfig;
use crate::rng::ZParam;

pub fn run(args: &Args) -> crate::error::Result<()> {
    let workload = Workload::parse(args.str_or("dataset", "cifar"))
        .ok_or_else(|| crate::anyhow!("--dataset mnist|emnist|cifar"))?;
    if args.has("sweep") {
        return sweep_sigma_e(args, workload);
    }
    banner(&format!("Figure 5/8 — FedAvg vs z-SignFedAvg on {workload:?}"));
    let rounds = args.usize_or("rounds", 60);
    let repeats = args.usize_or("repeats", 1);
    let local_steps: Vec<usize> = args
        .flag("local-steps")
        .map(|s| s.split(',').map(|v| v.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1, 5]);
    // Table 4/5 hyperparameters.
    let (client_lr, server_lr, sigma) = match workload {
        Workload::Emnist => (
            args.f32_or("client-lr", 0.05),
            args.f32_or("server-lr", 0.03),
            args.f32_or("sigma", 0.01),
        ),
        _ => (
            args.f32_or("client-lr", 0.1),
            args.f32_or("server-lr", 0.0032),
            args.f32_or("sigma", 0.0005),
        ),
    };
    let cpr = clients_per_round(workload, args);

    for &e in &local_steps {
        println!("\n-- E = {e} (clients/round: {cpr:?}) --");
        let algos = vec![
            AlgorithmConfig::fedavg(e).with_lrs(client_lr, 1.0),
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), sigma, e)
                .with_lrs(client_lr, server_lr),
            AlgorithmConfig::z_signfedavg(ZParam::Inf, sigma, e)
                .with_lrs(client_lr, server_lr),
            AlgorithmConfig::sign_fedavg(e).with_lrs(client_lr, server_lr),
        ];
        for algo in &algos {
            let cfg = ServerConfig {
                rounds,
                clients_per_round: cpr,
                eval_every: (rounds / 20).max(1),
                parallelism: args.parallelism_or(1),
                reduce_lanes: args.reduce_lanes_or(ServerConfig::default().reduce_lanes),
                ..Default::default()
            };
            let (agg, runs) = run_repeats(
                || build_xla_backend(workload, args).expect("backend"),
                algo,
                &cfg,
                repeats,
            );
            save_series(
                &format!("fig5_{}_e{e}", args.str_or("dataset", "cifar")),
                &algo.name,
                &agg,
                &runs,
            );
            print_summary_row(&format!("{} (E={e})", algo.name), &agg);
        }
    }
    Ok(())
}

/// Figures 9–13: σ × E grid for z ∈ {1, ∞}.
fn sweep_sigma_e(args: &Args, workload: Workload) -> crate::error::Result<()> {
    banner(&format!("Figures 9-13 — sigma x E sweep on {workload:?}"));
    let rounds = args.usize_or("rounds", 60);
    let repeats = args.usize_or("repeats", 1);
    let sigmas: Vec<f32> = args
        .flag("sigmas")
        .map(|s| s.split(',').map(|v| v.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![0.0, 0.0005, 0.005, 0.05]);
    let es: Vec<usize> = args
        .flag("local-steps")
        .map(|s| s.split(',').map(|v| v.parse().unwrap()).collect())
        .unwrap_or_else(|| vec![1, 5]);
    let (client_lr, server_lr) = match workload {
        Workload::Emnist => (0.05, 0.03),
        _ => (0.1, 0.0032),
    };
    let cpr = clients_per_round(workload, args);
    for z in [ZParam::Finite(1), ZParam::Inf] {
        for &e in &es {
            for &sigma in &sigmas {
                let algo =
                    AlgorithmConfig::z_signfedavg(z, sigma, e).with_lrs(client_lr, server_lr);
                let cfg = ServerConfig {
                    rounds,
                    clients_per_round: cpr,
                    eval_every: (rounds / 10).max(1),
                    parallelism: args.parallelism_or(1),
                    reduce_lanes: args.reduce_lanes_or(ServerConfig::default().reduce_lanes),
                    ..Default::default()
                };
                let (agg, runs) = run_repeats(
                    || build_xla_backend(workload, args).expect("backend"),
                    &algo,
                    &cfg,
                    repeats,
                );
                save_series(
                    &format!("fig9_13_{}_z{z}", args.str_or("dataset", "cifar")),
                    &format!("e{e}_sigma{sigma}"),
                    &agg,
                    &runs,
                );
                print_summary_row(&format!("z={z} E={e} sigma={sigma}"), &agg);
            }
        }
    }
    Ok(())
}
