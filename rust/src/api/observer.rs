//! [`RoundObserver`]: the composable output seam of the experiment API.
//!
//! A [`super::session::Session`] drives one or more observers through every
//! run. The contract (pinned by `tests/integration_api.rs`):
//!
//! * `on_round` streams *during* the run — once per evaluated round record,
//!   in round order, for each repeat (the engine invokes it as the record
//!   is produced, so a progress sink sees a live experiment);
//! * `on_run_end` fires after each repeat, with that repeat's `RunResult`;
//! * `on_series_end` fires once per series, after all repeats, with the
//!   mean-±-std aggregate (post `subtract_optimal` shift) and the raw runs.
//!
//! Sinks provided here:
//!
//! * [`CsvSink`] — the historical `results/<experiment>/<series>.csv` +
//!   `<series>_raw.csv` layout, byte-identical to the pre-API drivers;
//! * [`ProgressSink`] — the historical one-line series summary;
//! * [`JsonlSink`] — a machine-readable event stream (one JSON per line);
//! * [`MemorySink`] — an in-memory collector (clone it, run, then `take()`).

use crate::fl::metrics::{
    safe_series_name, write_csv, write_runs_csv, Aggregated, RoundRecord, RunResult,
};
use crate::telemetry::{Phase, Telemetry};
use crate::util::json::Json;
use std::cell::RefCell;
use std::collections::BTreeMap;
use std::io::{Seek, SeekFrom, Write as _};
use std::path::{Path, PathBuf};
use std::rc::Rc;

/// What a sink knows about the series being run.
#[derive(Debug, Clone)]
pub struct SeriesCtx {
    /// Experiment name (= output subdirectory).
    pub experiment: String,
    /// CSV file stem (sanitized via `safe_series_name` at write time).
    pub label: String,
    /// Console display name.
    pub display: String,
    /// The algorithm's preset name.
    pub algorithm: String,
    /// Position in the expanded series list.
    pub index: usize,
    /// Expanded series count.
    pub total: usize,
    /// Root results directory (`ExperimentSpec::output.dir`).
    pub out_dir: PathBuf,
}

/// Observer of a session's progress. All methods default to no-ops so a
/// sink implements only what it needs.
pub trait RoundObserver {
    /// One evaluated round record, streamed while the run executes.
    fn on_round(&mut self, _ctx: &SeriesCtx, _repeat: usize, _rec: &RoundRecord) {}

    /// One repeat finished.
    fn on_run_end(&mut self, _ctx: &SeriesCtx, _repeat: usize, _run: &RunResult) {}

    /// All repeats of one series finished and were aggregated.
    fn on_series_end(&mut self, _ctx: &SeriesCtx, _agg: &Aggregated, _runs: &[RunResult]) {}

    /// Capture this observer's output-stream position for a checkpoint
    /// (`ckpt::Snapshot::observer_marks`). `None` — the default — means
    /// the sink needs no mark: it either holds no mid-run partial state
    /// ([`CsvSink`] writes whole files at series end) or cannot rewind.
    fn ckpt_mark(&mut self) -> Option<u64> {
        None
    }

    /// Rewind the output stream to a mark captured by
    /// [`RoundObserver::ckpt_mark`], discarding everything written after
    /// it (the partial rounds between the checkpoint and the crash), so a
    /// resumed session continues the stream byte-identically.
    fn ckpt_restore(&mut self, _mark: Option<u64>) -> std::io::Result<()> {
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// CSV
// ---------------------------------------------------------------------------

/// Writes the historical per-series CSV pair under
/// `<out_dir>/<experiment>/`: `<label>.csv` (aggregated) and
/// `<label>_raw.csv` (per-run records). Layout and naming are byte-
/// compatible with the pre-API `save_series` plumbing.
#[derive(Debug, Clone, Default)]
pub struct CsvSink;

impl CsvSink {
    pub fn new() -> CsvSink {
        CsvSink
    }
}

impl RoundObserver for CsvSink {
    fn on_series_end(&mut self, ctx: &SeriesCtx, agg: &Aggregated, runs: &[RunResult]) {
        let dir = ctx.out_dir.join(&ctx.experiment);
        let safe = safe_series_name(&ctx.label);
        write_csv(&dir.join(format!("{safe}.csv")), agg).expect("writing csv");
        write_runs_csv(&dir.join(format!("{safe}_raw.csv")), runs).expect("writing raw csv");
    }
}

// ---------------------------------------------------------------------------
// Console progress
// ---------------------------------------------------------------------------

/// Prints the historical compact per-series summary row.
#[derive(Debug, Clone, Default)]
pub struct ProgressSink;

impl ProgressSink {
    pub fn new() -> ProgressSink {
        ProgressSink
    }
}

impl RoundObserver for ProgressSink {
    fn on_series_end(&mut self, ctx: &SeriesCtx, agg: &Aggregated, _runs: &[RunResult]) {
        let last = agg.rounds.len() - 1;
        let acc = if agg.accuracy_mean[last].is_nan() {
            "      -".to_string()
        } else {
            format!("{:6.2}%", 100.0 * agg.accuracy_mean[last])
        };
        println!(
            "  {:<28} final obj {:>12.6} ± {:>9.6}   acc {acc}   uplink {:>10.2} Mbit",
            ctx.display,
            agg.objective_mean[last],
            agg.objective_std[last],
            agg.bits_up[last] as f64 / 1e6,
        );
    }
}

// ---------------------------------------------------------------------------
// JSONL event stream
// ---------------------------------------------------------------------------

/// Appends one compact JSON event per line: `round`, `run_end`,
/// `series_end`. Non-finite numbers are written as `null` so every line is
/// valid JSON.
///
/// With an attached telemetry handle ([`JsonlSink::with_telemetry`]),
/// `round` events carry four extra keys — `bits_down`, `phase_ms` (an
/// object with one entry per round phase), `selected` and `wall_ms` — the
/// structured-log counterpart of the Prometheus endpoint. The base schema
/// is unchanged, and pinned by `tests/jsonl_schema.rs` either way.
pub struct JsonlSink {
    out: std::io::BufWriter<std::fs::File>,
    tele: Telemetry,
}

impl JsonlSink {
    /// Create (truncate) the event stream at `path`.
    pub fn create(path: &Path) -> crate::error::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::File::create(path)?;
        Ok(JsonlSink { out: std::io::BufWriter::new(f), tele: Telemetry::disabled() })
    }

    /// Open the event stream at `path` for appending (the resume path:
    /// everything already on disk is kept; pair with
    /// [`RoundObserver::ckpt_restore`] to drop partial post-checkpoint
    /// lines first).
    pub fn append(path: &Path) -> crate::error::Result<JsonlSink> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let f = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlSink { out: std::io::BufWriter::new(f), tele: Telemetry::disabled() })
    }

    /// Extend `round` events with the telemetry keys (builder-style).
    pub fn with_telemetry(mut self, tele: Telemetry) -> JsonlSink {
        self.tele = tele;
        self
    }

    fn emit(&mut self, entries: Vec<(&str, Json)>) {
        let obj = Json::Obj(
            entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect::<BTreeMap<_, _>>(),
        );
        writeln!(self.out, "{}", obj.to_string_compact()).expect("writing jsonl event");
    }
}

/// A JSON number, or `null` when not finite (NaN/inf are not JSON).
fn jnum(x: f64) -> Json {
    if x.is_finite() {
        Json::Num(x)
    } else {
        Json::Null
    }
}

impl RoundObserver for JsonlSink {
    fn on_round(&mut self, ctx: &SeriesCtx, repeat: usize, rec: &RoundRecord) {
        let mut entries = vec![
            ("event", Json::Str("round".into())),
            ("experiment", Json::Str(ctx.experiment.clone())),
            ("series", Json::Str(ctx.label.clone())),
            ("repeat", Json::Num(repeat as f64)),
            ("round", Json::Num(rec.round as f64)),
            ("objective", jnum(rec.objective)),
            ("accuracy", rec.accuracy.map(jnum).unwrap_or(Json::Null)),
            ("bits_up", Json::Num(rec.bits_up as f64)),
            ("sigma", jnum(rec.sigma as f64)),
            ("sim_time_s", jnum(rec.sim_time_s)),
            ("arrived", Json::Num(rec.arrived as f64)),
        ];
        if self.tele.is_enabled() {
            let phases: BTreeMap<String, Json> = Phase::ALL
                .iter()
                .map(|&p| (p.label().to_string(), jnum(self.tele.phase_ms_last(p))))
                .collect();
            entries.push(("bits_down", Json::Num(rec.bits_down as f64)));
            entries.push(("phase_ms", Json::Obj(phases)));
            entries.push(("selected", Json::Num(rec.selected as f64)));
            entries.push(("wall_ms", jnum(rec.wall_ms)));
        }
        self.emit(entries);
    }

    fn on_run_end(&mut self, ctx: &SeriesCtx, repeat: usize, run: &RunResult) {
        self.emit(vec![
            ("event", Json::Str("run_end".into())),
            ("experiment", Json::Str(ctx.experiment.clone())),
            ("series", Json::Str(ctx.label.clone())),
            ("repeat", Json::Num(repeat as f64)),
            ("records", Json::Num(run.records.len() as f64)),
            ("final_objective", jnum(run.final_objective())),
        ]);
    }

    fn on_series_end(&mut self, ctx: &SeriesCtx, agg: &Aggregated, runs: &[RunResult]) {
        self.emit(vec![
            ("event", Json::Str("series_end".into())),
            ("experiment", Json::Str(ctx.experiment.clone())),
            ("series", Json::Str(ctx.label.clone())),
            ("repeats", Json::Num(runs.len() as f64)),
            ("final_objective_mean", jnum(*agg.objective_mean.last().unwrap())),
        ]);
        self.out.flush().expect("flushing jsonl events");
    }

    /// The mark is the flushed byte length of the stream: every event up
    /// to the checkpointed round is on disk and accounted.
    fn ckpt_mark(&mut self) -> Option<u64> {
        self.out.flush().ok()?;
        self.out.get_mut().stream_position().ok()
    }

    /// Truncate back to the mark. Writes after a truncate land at the new
    /// end in both write and append modes, so the resumed stream continues
    /// exactly where the checkpointed one left off.
    fn ckpt_restore(&mut self, mark: Option<u64>) -> std::io::Result<()> {
        if let Some(pos) = mark {
            self.out.flush()?;
            let f = self.out.get_mut();
            f.set_len(pos)?;
            f.seek(SeekFrom::Start(pos))?;
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// In-memory collector
// ---------------------------------------------------------------------------

/// One collected series (see [`MemorySink`]).
#[derive(Debug, Clone)]
pub struct CollectedSeries {
    pub label: String,
    pub algorithm: String,
    pub aggregated: Aggregated,
    pub runs: Vec<RunResult>,
}

/// Collects every finished series in memory. Clone the sink before handing
/// it to the session; the clones share storage, so `take()` on the
/// original returns what the session collected.
#[derive(Debug, Clone, Default)]
pub struct MemorySink {
    inner: Rc<RefCell<Vec<CollectedSeries>>>,
}

impl MemorySink {
    pub fn new() -> MemorySink {
        MemorySink::default()
    }

    /// Drain everything collected so far.
    pub fn take(&self) -> Vec<CollectedSeries> {
        self.inner.borrow_mut().drain(..).collect()
    }
}

impl RoundObserver for MemorySink {
    fn on_series_end(&mut self, ctx: &SeriesCtx, agg: &Aggregated, runs: &[RunResult]) {
        self.inner.borrow_mut().push(CollectedSeries {
            label: ctx.label.clone(),
            algorithm: ctx.algorithm.clone(),
            aggregated: agg.clone(),
            runs: runs.to_vec(),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_ctx(dir: &Path) -> SeriesCtx {
        SeriesCtx {
            experiment: "obs_test".into(),
            label: "series".into(),
            display: "series".into(),
            algorithm: "gd".into(),
            index: 0,
            total: 1,
            out_dir: dir.to_path_buf(),
        }
    }

    fn test_rec(round: usize) -> RoundRecord {
        RoundRecord {
            round,
            objective: 2.0 - round as f64 * 0.25,
            accuracy: None,
            grad_norm_sq: None,
            bits_up: 64 * (round as u64 + 1),
            bits_down: 0,
            sigma: 1.0,
            wall_ms: 0.0,
            sim_time_s: 0.0,
            arrived: 4,
            selected: 4,
            degraded: false,
        }
    }

    #[test]
    fn jsonl_crash_after_mark_resumes_byte_identical() {
        let dir = std::env::temp_dir().join(format!("zsfa_obs_t{}", std::process::id()));
        let ctx = test_ctx(&dir);

        // Reference: one uninterrupted stream of rounds 0..6.
        let ref_path = dir.join("ref.jsonl");
        let mut r = JsonlSink::create(&ref_path).unwrap();
        for t in 0..6 {
            r.on_round(&ctx, 0, &test_rec(t));
        }
        drop(r);

        // Crashed run: rounds 0..3 land, a checkpoint marks the stream,
        // then two post-checkpoint rounds are written before the "crash"
        // (drop without cleanup — the partial lines persist on disk).
        let crash_path = dir.join("crash.jsonl");
        let mut s = JsonlSink::create(&crash_path).unwrap();
        for t in 0..3 {
            s.on_round(&ctx, 0, &test_rec(t));
        }
        let mark = s.ckpt_mark();
        assert!(mark.unwrap() > 0);
        for t in 3..5 {
            s.on_round(&ctx, 0, &test_rec(t));
        }
        drop(s);

        // Resume: append-mode reopen keeps rounds 0..3, the restore
        // truncates the partial tail, and the replayed rounds 3..6 land
        // exactly where the uninterrupted stream put them.
        let mut s2 = JsonlSink::append(&crash_path).unwrap();
        s2.ckpt_restore(mark).unwrap();
        for t in 3..6 {
            s2.on_round(&ctx, 0, &test_rec(t));
        }
        drop(s2);

        let want = std::fs::read(&ref_path).unwrap();
        let got = std::fs::read(&crash_path).unwrap();
        assert_eq!(got, want, "resumed stream diverges from uninterrupted one");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn default_marks_are_none_and_restore_is_a_no_op() {
        let mut csv = CsvSink::new();
        assert_eq!(csv.ckpt_mark(), None);
        csv.ckpt_restore(Some(12345)).unwrap();
        let mut mem = MemorySink::new();
        assert_eq!(mem.ckpt_mark(), None);
    }
}
