//! The experiment API: one declarative run surface for every driver,
//! sweep and scenario (DESIGN.md §4.5).
//!
//! * [`spec`] — [`ExperimentSpec`]: a typed, validated, JSON-serializable
//!   description of a full experiment (workload, algorithm series/sweep,
//!   server knobs, participation scenario, repeats, output), with the
//!   builder API and the pinned [`seed_for_repeat`] convention.
//! * [`session`] — [`Session`]: expands the grid and executes it through
//!   the round engine, one repeat at a time, with bit-deterministic
//!   results for any `parallelism`.
//! * [`observer`] — [`RoundObserver`] and the composable sinks: CSV
//!   (byte-identical to the historical driver layout), JSONL events,
//!   console progress, in-memory collection.
//!
//! Every `repro::fig*` driver is a thin factory producing specs for this
//! API, and `zsfa run <spec.json>` executes any experiment — including
//! ones no driver ships — without recompiling.

pub mod observer;
pub mod session;
pub mod spec;

pub use observer::{
    CollectedSeries, CsvSink, JsonlSink, MemorySink, ProgressSink, RoundObserver, SeriesCtx,
};
pub use session::{SeriesResult, Session, SessionResult};
pub use spec::{
    seed_for_repeat, Dataset, ExperimentSpec, NeuralSpec, OutputSpec, SeriesSpec, SpecError,
    SweepSpec, TelemetrySpec, TransportSpec, WorkloadSpec,
};
