//! [`Session`]: executes an [`ExperimentSpec`] — grid expansion, backend
//! construction, the repeat loop with the [`super::spec::seed_for_repeat`]
//! convention, aggregation, and observer fan-out.
//!
//! Scheduling: series run sequentially through one scheduler; the
//! configured `parallelism` (worker threads inside the round engine) is
//! reused across every series and repeat, so a sweep never oversubscribes
//! the machine. Results are bit-identical for any `parallelism` value —
//! the engine's determinism contract — which is what lets `zsfa run
//! spec.json --parallelism 8` reproduce archived CSVs byte-for-byte.

use super::observer::{CsvSink, ProgressSink, RoundObserver, SeriesCtx};
use super::spec::{ExperimentSpec, NeuralSpec, TransportSpec, WorkloadSpec};
use crate::ckpt::{CheckpointPolicy, Snapshot};
use crate::data::{partition, synth};
use crate::error::{bail, Error, Result};
use crate::fl::backend::{AnalyticBackend, TrainBackend};
use crate::fl::engine::{root_for_seed, CkptHook, EngineCkpt};
use crate::fl::metrics::{aggregate, Aggregated, RunResult};
use crate::fl::server::run_experiment_resumable;
use crate::problems::consensus::Consensus;
use crate::problems::least_squares::LeastSquares;
use crate::rng::RngSnapshot;
use crate::runtime::{ModelRuntime, XlaBackend};
use crate::service::ServiceHost;
use crate::telemetry::Telemetry;
use std::cell::RefCell;
use std::path::PathBuf;

impl WorkloadSpec {
    /// Materialize a fresh backend for one repeat. Analytic workloads are
    /// rebuilt per repeat (cheap, and keeps the paper's protocol of a
    /// fixed problem with varying algorithmic randomness); neural
    /// workloads load the AOT artifacts (`make artifacts` first).
    pub fn build_backend(&self) -> Result<Box<dyn TrainBackend>> {
        match self {
            WorkloadSpec::Consensus { clients, dim, problem_seed } => Ok(Box::new(
                AnalyticBackend::new(Consensus::gaussian(*clients, *dim, *problem_seed)),
            )),
            WorkloadSpec::Counterexample { a, x0 } => {
                let mut b = AnalyticBackend::new(Consensus::counterexample(*a));
                b.x0 = vec![*x0];
                Ok(Box::new(b))
            }
            WorkloadSpec::LeastSquares {
                clients,
                dim,
                rows_per_client,
                heterogeneity,
                noise,
                problem_seed,
                stochastic,
            } => {
                let b = AnalyticBackend::new(LeastSquares::generate(
                    *clients,
                    *dim,
                    *rows_per_client,
                    *heterogeneity,
                    *noise,
                    *problem_seed,
                ));
                Ok(Box::new(if *stochastic { b.stochastic() } else { b }))
            }
            WorkloadSpec::Neural(n) => Ok(Box::new(build_neural_backend(n)?)),
        }
    }
}

/// The PJRT workload construction (formerly `repro::common::build_xla_backend`).
fn build_neural_backend(n: &NeuralSpec) -> Result<XlaBackend> {
    let runtime = ModelRuntime::open(&n.artifacts, n.dataset.model())?;
    let n_test = n.test_samples.unwrap_or(2 * runtime.eval_batch);

    let spec = match n.dataset {
        super::spec::Dataset::NoniidMnist => synth::SynthSpec::mnist(),
        super::spec::Dataset::Emnist => synth::SynthSpec::emnist(),
        super::spec::Dataset::Cifar => synth::SynthSpec::cifar(),
    };
    let (train, test) = synth::train_test(spec, n.train_samples, n_test);
    let fed = match n.dataset {
        super::spec::Dataset::NoniidMnist => partition::by_label(train, n.clients),
        super::spec::Dataset::Emnist => partition::iid(train, n.clients, 42),
        super::spec::Dataset::Cifar => partition::dirichlet(train, n.clients, 1.0, 42),
    };
    let init = runtime.load_init()?;
    Ok(XlaBackend::new(runtime, fed, test, init))
}

/// One series' outcome.
#[derive(Debug, Clone)]
pub struct SeriesResult {
    pub label: String,
    pub display: String,
    pub algorithm: String,
    /// Mean ± std across repeats (objective mean already shifted by the
    /// workload optimum when `output.subtract_optimal` is set).
    pub aggregated: Aggregated,
    /// The raw per-repeat runs (absolute objectives).
    pub runs: Vec<RunResult>,
}

/// Everything a session produced, in expanded-series order.
#[derive(Debug, Clone)]
pub struct SessionResult {
    pub series: Vec<SeriesResult>,
}

/// Executes specs through a set of composable observers. A bare
/// `Session::new()` runs silently and only returns the [`SessionResult`];
/// [`Session::console`] adds the historical driver behavior (CSV files +
/// one summary line per series).
#[derive(Default)]
pub struct Session {
    observers: Vec<Box<dyn RoundObserver>>,
    telemetry: Option<Telemetry>,
}

impl Session {
    /// A session with no observers.
    pub fn new() -> Session {
        Session::default()
    }

    /// The driver preset: CSV output + console progress.
    pub fn console() -> Session {
        Session::new().with(CsvSink::new()).with(ProgressSink::new())
    }

    /// Attach an observer (builder-style).
    pub fn with(mut self, observer: impl RoundObserver + 'static) -> Session {
        self.observers.push(Box::new(observer));
        self
    }

    /// Use an externally owned telemetry handle instead of building one
    /// from the spec's `telemetry` block (the CLI does this so the TCP
    /// metrics endpoint and the final dump share one registry).
    pub fn with_telemetry(mut self, tele: Telemetry) -> Session {
        self.telemetry = Some(tele);
        self
    }

    /// Validate and execute `spec`: every expanded series, `spec.repeats`
    /// repeats each (repeat `r` seeded by `spec.seed_for_repeat(r)`),
    /// streaming progress to the observers.
    pub fn run(&mut self, spec: &ExperimentSpec) -> Result<SessionResult> {
        self.run_inner(spec, &CheckpointPolicy::off(), None)
    }

    /// [`Session::run`] with crash-recovery snapshots: whenever `policy`
    /// fires at a round boundary, the full session state (iterate, RNG,
    /// EF residuals, completed runs, observer marks, coordinator pins) is
    /// written atomically to `policy.path_for(spec.name)`. A failed write
    /// warns and keeps running — checkpointing never aborts a session.
    pub fn run_with_checkpoints(
        &mut self,
        spec: &ExperimentSpec,
        policy: &CheckpointPolicy,
    ) -> Result<SessionResult> {
        self.run_inner(spec, policy, None)
    }

    /// Resume a session from a [`Snapshot`], continuing to take new
    /// checkpoints under `policy`. Refuses (with a
    /// [`crate::error::ErrorKind::Checkpoint`] error) when `spec` does not
    /// fingerprint-match the spec the snapshot was captured under.
    ///
    /// Series that finished before the snapshot are *not* re-run — their
    /// outputs are already on disk — so the returned [`SessionResult`]
    /// contains only the snapshot's series onward.
    pub fn resume(
        &mut self,
        spec: &ExperimentSpec,
        snap: &Snapshot,
        policy: &CheckpointPolicy,
    ) -> Result<SessionResult> {
        snap.check_spec(&spec.to_json())?;
        self.run_inner(spec, policy, Some(snap))
    }

    fn run_inner(
        &mut self,
        spec: &ExperimentSpec,
        policy: &CheckpointPolicy,
        resume: Option<&Snapshot>,
    ) -> Result<SessionResult> {
        if let Err(errs) = spec.validate() {
            let msgs: Vec<String> = errs.iter().map(|e| e.to_string()).collect();
            bail!("invalid experiment spec: {}", msgs.join("; "));
        }
        let f_star = if spec.output.subtract_optimal {
            // validate() guarantees the workload has one.
            spec.workload.optimal_value()
        } else {
            None
        };

        // One telemetry handle for the whole session: every series and
        // repeat records into the same registry. The session owner can
        // inject one; otherwise the spec's telemetry block decides.
        let tele = match &self.telemetry {
            Some(t) => t.clone(),
            None => spec.telemetry.handle(),
        };

        // Service transports share one host (and one participant cohort)
        // across every series and repeat; the engine path needs none.
        let mut host = match &spec.transport {
            TransportSpec::Engine => None,
            TransportSpec::Loopback => {
                let mut h = ServiceHost::loopback(spec, spec.parallelism.max(1));
                h.set_telemetry(tele.clone());
                Some(h)
            }
            TransportSpec::Tcp { addr, heartbeat_ms, round_deadline_ms, min_participants } => {
                let h = ServiceHost::tcp(
                    addr,
                    *heartbeat_ms,
                    *round_deadline_ms,
                    *min_participants,
                    &tele,
                )?;
                if let Some(bound) = h.local_addr() {
                    println!("serving rounds on {bound}");
                }
                Some(h)
            }
        };

        // Checkpoint/resume plumbing. On resume: bump the counter, roll
        // every observer back to its mark (truncating any lines written
        // after the snapshot), and re-seed the coordinator's sticky pins.
        policy.arm();
        let spec_json = spec.to_json();
        if let Some(snap) = resume {
            tele.count_resume();
            for (o, mark) in self.observers.iter_mut().zip(&snap.observer_marks) {
                o.ckpt_restore(*mark)
                    .map_err(|e| Error::checkpoint(format!("observer restore: {e}")))?;
            }
            if let Some(h) = host.as_ref() {
                h.restore_pins(&snap.pins);
            }
        }
        // The round callback and the checkpoint hook both need the
        // observers mid-run (records vs. marks) — hence the RefCell; the
        // two borrows never overlap in time.
        let observers = RefCell::new(&mut self.observers);

        let expanded = spec.expanded_series();
        let total = expanded.len();
        let mut out = Vec::with_capacity(total);
        for (index, s) in expanded.into_iter().enumerate() {
            if let Some(snap) = resume {
                if index < snap.series as usize {
                    // Finished before the snapshot; outputs already exist.
                    continue;
                }
            }
            let ctx = SeriesCtx {
                experiment: spec.name.clone(),
                label: s.label.clone(),
                display: s.display.clone(),
                algorithm: s.algorithm.name.clone(),
                index,
                total,
                out_dir: spec.output.dir.clone(),
            };
            let runs = RefCell::new(Vec::with_capacity(spec.repeats));
            let mut first_repeat = 0usize;
            let mut engine_resume: Option<&EngineCkpt> = None;
            if let Some(snap) = resume {
                if index == snap.series as usize {
                    // Completed repeats are adopted verbatim (their
                    // observer output predates the mark — don't re-fire
                    // on_run_end); the interrupted repeat restarts from
                    // the captured engine state.
                    for recs in &snap.completed_runs {
                        runs.borrow_mut().push(RunResult {
                            algorithm: s.algorithm.name.clone(),
                            records: recs.clone(),
                        });
                    }
                    first_repeat = snap.repeat as usize;
                    engine_resume = Some(&snap.engine);
                }
            }
            for repeat in first_repeat..spec.repeats {
                let mut backend = spec.workload.build_backend()?;
                let cfg = spec.server_config(repeat);
                // The engine checkpoint applies to the snapshot's repeat
                // only; later repeats start fresh.
                let this_resume = engine_resume.take();
                let mut on_round = |rec: &crate::fl::RoundRecord| {
                    for o in observers.borrow_mut().iter_mut() {
                        o.on_round(&ctx, repeat, rec);
                    }
                };
                let mut hook_store;
                let hook: Option<&mut dyn CkptHook> = if policy.is_off() {
                    None
                } else {
                    hook_store = SessionHook {
                        policy,
                        path: policy.path_for(&spec.name),
                        spec_json: &spec_json,
                        series: index as u32,
                        repeat: repeat as u32,
                        root: root_for_seed(cfg.seed).state_snapshot(),
                        runs: &runs,
                        observers: &observers,
                        pins: Vec::new(),
                        tele: tele.clone(),
                    };
                    Some(&mut hook_store)
                };
                let run = match host.as_mut() {
                    None => run_experiment_resumable(
                        backend.as_mut(),
                        &s.algorithm,
                        &cfg,
                        &tele,
                        &mut on_round,
                        this_resume,
                        hook,
                    ),
                    Some(h) => h.run_one_resumable(
                        backend.as_mut(),
                        &s.algorithm,
                        &cfg,
                        index as u32,
                        repeat as u32,
                        &mut on_round,
                        this_resume,
                        hook,
                    )?,
                };
                for o in observers.borrow_mut().iter_mut() {
                    o.on_run_end(&ctx, repeat, &run);
                }
                runs.borrow_mut().push(run);
            }
            let runs = runs.into_inner();
            let mut agg = aggregate(&runs);
            if let Some(f_star) = f_star {
                // Report optimality gaps like the historical drivers did:
                // the aggregated mean is shifted, the std and the raw runs
                // keep their absolute values.
                for v in agg.objective_mean.iter_mut() {
                    *v -= f_star;
                }
            }
            for o in observers.borrow_mut().iter_mut() {
                o.on_series_end(&ctx, &agg, &runs);
            }
            out.push(SeriesResult {
                label: s.label,
                display: s.display,
                algorithm: s.algorithm.name.clone(),
                aggregated: agg,
                runs,
            });
        }
        if let Some(mut h) = host {
            h.shutdown()?;
        }
        if let Some(path) = &spec.telemetry.dump_path {
            if tele.is_enabled() {
                if let Some(dir) = std::path::Path::new(path).parent() {
                    if !dir.as_os_str().is_empty() {
                        std::fs::create_dir_all(dir).ok();
                    }
                }
                std::fs::write(path, tele.export_prometheus())
                    .map_err(|e| crate::error::Error::msg(format!("dump metrics {path}: {e}")))?;
            }
        }
        Ok(SessionResult { series: out })
    }
}

/// The session's [`CkptHook`]: asks the policy when to snapshot, and on
/// each capture wraps the engine state with the session context (spec
/// fingerprint, series/repeat cursor, completed runs, observer marks,
/// coordinator pins) and writes it atomically. Write failures warn on
/// stderr and never abort the run — a broken checkpoint disk should not
/// kill an otherwise healthy session.
struct SessionHook<'a, 'b> {
    policy: &'a CheckpointPolicy,
    path: PathBuf,
    spec_json: &'a str,
    series: u32,
    repeat: u32,
    root: RngSnapshot,
    runs: &'a RefCell<Vec<RunResult>>,
    observers: &'a RefCell<&'b mut Vec<Box<dyn RoundObserver>>>,
    /// Coordinator pins pushed by the host just before `store` (empty on
    /// the engine path, which has no coordinator).
    pins: Vec<(u64, u64)>,
    tele: Telemetry,
}

impl CkptHook for SessionHook<'_, '_> {
    fn want(&mut self, next_round: u64) -> bool {
        self.policy.want(next_round)
    }

    fn store_pins(&mut self, pins: Vec<(u64, u64)>) {
        self.pins = pins;
    }

    fn store(&mut self, ck: EngineCkpt) {
        let marks: Vec<Option<u64>> =
            self.observers.borrow_mut().iter_mut().map(|o| o.ckpt_mark()).collect();
        let snap = Snapshot {
            spec_json: self.spec_json.to_string(),
            series: self.series,
            repeat: self.repeat,
            root: self.root,
            engine: ck,
            completed_runs: self.runs.borrow().iter().map(|r| r.records.clone()).collect(),
            pins: std::mem::take(&mut self.pins),
            observer_marks: marks,
        };
        match snap.write_atomic(&self.path) {
            Ok(()) => self.tele.count_checkpoint(),
            Err(e) => eprintln!("warning: checkpoint write failed: {e}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::spec::SweepSpec;
    use crate::fl::AlgorithmConfig;
    use crate::rng::ZParam;

    fn spec() -> ExperimentSpec {
        ExperimentSpec::new("session_test", WorkloadSpec::consensus(5, 8, 99))
            .rounds(20)
            .eval_every(5)
            .repeats(2)
            .series(AlgorithmConfig::gd().with_lrs(0.1, 1.0))
    }

    #[test]
    fn run_produces_one_result_per_expanded_series() {
        let s = spec().sweep(SweepSpec {
            zs: vec![ZParam::Finite(1)],
            local_steps: vec![1],
            sigmas: vec![0.5, 1.0],
            client_lr: 0.05,
            server_lr: 1.0,
        });
        let result = Session::new().run(&s).unwrap();
        assert_eq!(result.series.len(), 3);
        assert_eq!(result.series[0].label, "GD");
        assert_eq!(result.series[1].label, "sigma0.5");
        assert_eq!(result.series[2].label, "sigma1");
        for sr in &result.series {
            assert_eq!(sr.runs.len(), 2);
            // rounds 0, 5, 10, 15 and the forced final round 19.
            assert_eq!(sr.aggregated.rounds.len(), 5);
        }
    }

    #[test]
    fn session_repeats_match_manual_seed_offsets() {
        // The session's repeat loop must reproduce run_experiment with the
        // seed_for_repeat convention exactly.
        use crate::fl::server::{run_experiment, ServerConfig};
        let s = spec();
        let result = Session::new().run(&s).unwrap();
        for (r, run) in result.series[0].runs.iter().enumerate() {
            let mut b = AnalyticBackend::new(Consensus::gaussian(5, 8, 99));
            let cfg = ServerConfig {
                rounds: 20,
                eval_every: 5,
                seed: crate::api::spec::seed_for_repeat(0, r),
                ..Default::default()
            };
            let expected =
                run_experiment(&mut b, &AlgorithmConfig::gd().with_lrs(0.1, 1.0), &cfg);
            let got: Vec<f64> = run.records.iter().map(|rec| rec.objective).collect();
            let want: Vec<f64> = expected.records.iter().map(|rec| rec.objective).collect();
            assert_eq!(got, want, "repeat {r}");
        }
    }

    #[test]
    fn loopback_transport_session_is_bit_identical_to_engine_session() {
        // The full Session surface — series loop, repeat seeds, observers,
        // aggregation — must not care which transport ran the rounds.
        let want = Session::new().run(&spec()).unwrap();
        let got = Session::new()
            .run(&spec().transport(TransportSpec::Loopback).parallelism(4))
            .unwrap();
        assert_eq!(want.series.len(), got.series.len());
        for (a, b) in want.series.iter().zip(&got.series) {
            assert_eq!(a.runs.len(), b.runs.len());
            for (ra, rb) in a.runs.iter().zip(&b.runs) {
                let oa: Vec<u64> = ra.records.iter().map(|r| r.objective.to_bits()).collect();
                let ob: Vec<u64> = rb.records.iter().map(|r| r.objective.to_bits()).collect();
                assert_eq!(oa, ob, "{}", a.label);
                let ba: Vec<u64> = ra.records.iter().map(|r| r.bits_up).collect();
                let bb: Vec<u64> = rb.records.iter().map(|r| r.bits_up).collect();
                assert_eq!(ba, bb, "{}", a.label);
            }
        }
    }

    #[test]
    fn telemetry_enabled_session_is_bit_identical_and_dumps_metrics() {
        use crate::api::spec::TelemetrySpec;
        let want = Session::new().run(&spec()).unwrap();
        let dump = std::env::temp_dir().join("zsfa_session_tele_test").join("metrics.prom");
        let dump_str = dump.to_string_lossy().to_string();
        let tele_spec = TelemetrySpec {
            enabled: true,
            event_capacity: 512,
            dump_path: Some(dump_str.clone()),
        };
        for transport in [TransportSpec::Engine, TransportSpec::Loopback] {
            std::fs::remove_file(&dump).ok();
            let s = spec().transport(transport.clone()).telemetry(tele_spec.clone());
            let got = Session::new().run(&s).unwrap();
            for (a, b) in want.series.iter().zip(&got.series) {
                for (ra, rb) in a.runs.iter().zip(&b.runs) {
                    let oa: Vec<u64> =
                        ra.records.iter().map(|r| r.objective.to_bits()).collect();
                    let ob: Vec<u64> =
                        rb.records.iter().map(|r| r.objective.to_bits()).collect();
                    assert_eq!(oa, ob, "{transport:?} {}", a.label);
                }
            }
            let text = std::fs::read_to_string(&dump).unwrap();
            // 1 series × 2 repeats × 20 rounds.
            assert!(text.contains("zsfa_rounds_total 40"), "{transport:?}:\n{text}");
            assert!(text.contains("zsfa_bits_up_total"), "{transport:?}");
        }
        std::fs::remove_dir_all(dump.parent().unwrap()).ok();
    }

    #[test]
    fn injected_telemetry_handle_wins_over_the_spec() {
        let tele = crate::telemetry::Telemetry::with_capacity(64);
        Session::new().with_telemetry(tele.clone()).run(&spec()).unwrap();
        assert_eq!(tele.metrics().unwrap().rounds_total.get(), 40);
    }

    #[test]
    fn invalid_spec_is_refused_with_field_paths() {
        let bad = ExperimentSpec::new("x", WorkloadSpec::consensus(4, 4, 1)).rounds(0);
        let err = Session::new().run(&bad).unwrap_err().to_string();
        assert!(err.contains("invalid experiment spec"), "{err}");
        assert!(err.contains("rounds"), "{err}");
    }

    #[test]
    fn checkpointed_session_resumes_to_the_identical_result() {
        // Run with periodic checkpoints; the file left on disk is the
        // *last* capture (series 0, repeat 1, next_round 15). Resuming it
        // must reproduce the uninterrupted result exactly: repeat 0
        // adopted from completed_runs, repeat 1 re-run from round 15.
        let dir = std::env::temp_dir().join("zsfa_session_ckpt_test");
        std::fs::remove_dir_all(&dir).ok();
        let policy = CheckpointPolicy::every(&dir, 5);
        let want = Session::new().run_with_checkpoints(&spec(), &policy).unwrap();

        let path = policy.path_for("session_test");
        let snap = Snapshot::load(&path).unwrap();
        assert_eq!((snap.series, snap.repeat), (0, 1));
        assert_eq!(snap.engine.next_round, 15);
        assert_eq!(snap.completed_runs.len(), 1);

        let got = Session::new().resume(&spec(), &snap, &CheckpointPolicy::off()).unwrap();
        assert_eq!(got.series.len(), want.series.len());
        for (a, b) in want.series.iter().zip(&got.series) {
            assert_eq!(a.runs.len(), b.runs.len());
            for (ra, rb) in a.runs.iter().zip(&b.runs) {
                assert_eq!(ra.records.len(), rb.records.len());
                for (x, y) in ra.records.iter().zip(&rb.records) {
                    let (mut x, mut y) = (*x, *y);
                    x.wall_ms = 0.0;
                    y.wall_ms = 0.0;
                    assert_eq!(x, y, "{}", a.label);
                }
            }
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn resume_under_a_modified_spec_is_refused() {
        use crate::error::ErrorKind;
        let dir = std::env::temp_dir().join("zsfa_session_ckpt_refusal_test");
        std::fs::remove_dir_all(&dir).ok();
        let policy = CheckpointPolicy::every(&dir, 5);
        Session::new().run_with_checkpoints(&spec(), &policy).unwrap();
        let snap = Snapshot::load(&policy.path_for("session_test")).unwrap();
        let err = Session::new()
            .resume(&spec().rounds(21), &snap, &CheckpointPolicy::off())
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Checkpoint);
        assert!(err.to_string().contains("fingerprint"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn subtract_optimal_shifts_only_the_aggregated_mean() {
        use crate::problems::AnalyticProblem;
        let plain = Session::new().run(&spec()).unwrap();
        let shifted = Session::new().run(&spec().subtract_optimal(true)).unwrap();
        let f_star = Consensus::gaussian(5, 8, 99).optimal_value().unwrap();
        let a = &plain.series[0];
        let b = &shifted.series[0];
        for t in 0..a.aggregated.rounds.len() {
            let diff = a.aggregated.objective_mean[t] - b.aggregated.objective_mean[t];
            assert!((diff - f_star).abs() < 1e-12);
            assert_eq!(a.aggregated.objective_std[t], b.aggregated.objective_std[t]);
        }
        // Raw runs stay absolute.
        assert_eq!(
            a.runs[0].records[0].objective,
            b.runs[0].records[0].objective
        );
    }
}
