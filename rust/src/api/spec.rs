//! [`ExperimentSpec`]: the typed, validated, JSON-serializable description
//! of a full experiment — workload, algorithm series (explicit list and/or
//! a sweep grid), server knobs, participation scenario, repeats and output
//! layout.
//!
//! Design rules:
//!
//! * **One seam.** Everything a driver used to hand-roll (`ServerConfig`
//!   literals, the seed-offset repeat loop, CSV naming) is expressed here
//!   and executed by [`super::session::Session`]; drivers are thin spec
//!   factories.
//! * **Errors, not panics.** [`ExperimentSpec::validate`] returns
//!   structured [`SpecError`]s; JSON decoding reports the exact field path
//!   (`series[2].algorithm.compression.s`) and rejects unknown keys so
//!   typos cannot silently no-op. Keys starting with `_` are comments.
//! * **Lossless round-trip.** `from_json(to_json(spec)) == spec` for every
//!   compression family, `ZParam`, participation, plateau and sweep
//!   variant (pinned by `tests/integration_api.rs`). Floats are carried as
//!   JSON numbers (f32 → f64 widening is exact); seeds above 2^53 are the
//!   only values a JSON round-trip cannot represent.
//! * **The repeat-seed convention lives here.** [`seed_for_repeat`] is the
//!   single definition of "repeat r runs with seed base + 1000·r" that the
//!   paper-protocol repeat loop has always used; a pinned test keeps it
//!   from drifting.

use crate::compress::agg::RobustRule;
use crate::compress::sign::SigmaRule;
use crate::fl::algorithms::ServerOpt;
use crate::fl::plateau::PlateauConfig;
use crate::fl::server::{Participation, ServerConfig, DEFAULT_REDUCE_LANES};
use crate::fl::{AlgorithmConfig, Compression};
use crate::problems::consensus::Consensus;
use crate::problems::least_squares::LeastSquares;
use crate::problems::AnalyticProblem;
use crate::rng::ZParam;
use crate::sim::{ByzantineMode, FleetPreset, ScenarioConfig};
use crate::telemetry::Telemetry;
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::fmt;
use std::path::{Path, PathBuf};

// ---------------------------------------------------------------------------
// Seed convention
// ---------------------------------------------------------------------------

/// The repeat-seed convention: repeat `r` of an experiment with base seed
/// `base` runs with seed `base + 1000·r` (wrapping). The offset keeps the
/// per-round/per-client PCG streams of different repeats disjoint for any
/// realistic round count while staying human-readable in logs.
///
/// This is the *only* definition of the convention — `Session` and any
/// legacy path must call it — and it is pinned by a test so it can never
/// silently drift (CSV archives depend on it).
pub fn seed_for_repeat(base: u64, repeat: usize) -> u64 {
    base.wrapping_add((repeat as u64).wrapping_mul(1000))
}

// ---------------------------------------------------------------------------
// Errors
// ---------------------------------------------------------------------------

/// A structured spec problem: `at` is the field path (`"rounds"`,
/// `"series[2].algorithm.compression"`), `reason` the human-readable rule
/// that was violated.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SpecError {
    pub at: String,
    pub reason: String,
}

impl SpecError {
    pub fn new(at: impl Into<String>, reason: impl Into<String>) -> SpecError {
        SpecError { at: at.into(), reason: reason.into() }
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}: {}", self.at, self.reason)
    }
}

impl std::error::Error for SpecError {}

// ---------------------------------------------------------------------------
// Workloads
// ---------------------------------------------------------------------------

/// A named neural dataset preset (the paper's three settings, scaled to
/// the 1-core testbed — see DESIGN.md §3). Formerly `repro::common::Workload`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dataset {
    /// §4.2 non-iid MNIST: 10 clients, one label each, full participation.
    NoniidMnist,
    /// §4.3 EMNIST: many clients (iid shards), partial participation.
    Emnist,
    /// §4.3 CIFAR-10: Dirichlet(1) skew, 10/100 clients per round.
    Cifar,
}

impl Dataset {
    pub fn parse(s: &str) -> Option<Dataset> {
        match s {
            "mnist" | "noniid-mnist" => Some(Dataset::NoniidMnist),
            "emnist" => Some(Dataset::Emnist),
            "cifar" | "cifar10" => Some(Dataset::Cifar),
            _ => None,
        }
    }

    /// Canonical config/JSON key.
    pub fn key(self) -> &'static str {
        match self {
            Dataset::NoniidMnist => "mnist",
            Dataset::Emnist => "emnist",
            Dataset::Cifar => "cifar",
        }
    }

    pub fn model(self) -> &'static str {
        match self {
            Dataset::NoniidMnist => "mnist_cnn",
            Dataset::Emnist => "emnist_cnn",
            Dataset::Cifar => "cifar_cnn",
        }
    }

    /// (default clients, default clients-per-round, default train size)
    /// Paper scale: EMNIST 3579 clients / 100 sampled; CIFAR 100 / 10.
    /// Defaults are scaled ~10× down to fit the testbed; `paper_scale`
    /// restores the paper's counts.
    pub fn defaults(self, paper_scale: bool) -> (usize, Option<usize>, usize) {
        match (self, paper_scale) {
            (Dataset::NoniidMnist, _) => (10, None, 2000),
            (Dataset::Emnist, false) => (358, Some(10), 3580),
            (Dataset::Emnist, true) => (3579, Some(100), 35790),
            (Dataset::Cifar, false) => (100, Some(10), 2000),
            (Dataset::Cifar, true) => (100, Some(10), 20000),
        }
    }
}

/// A PJRT-backed neural workload: dataset preset + partition sizes +
/// artifact location. Built into an `XlaBackend` by the session
/// (`WorkloadSpec::build_backend`).
#[derive(Debug, Clone, PartialEq)]
pub struct NeuralSpec {
    pub dataset: Dataset,
    pub clients: usize,
    pub train_samples: usize,
    /// `None` → `2 × eval_batch` of the loaded model runtime.
    pub test_samples: Option<usize>,
    pub paper_scale: bool,
    pub artifacts: PathBuf,
}

/// The problem an experiment optimizes.
#[derive(Debug, Clone, PartialEq)]
pub enum WorkloadSpec {
    /// §4.1 consensus: `min_x (1/2n) Σ‖x − y_i‖²` with Gaussian targets.
    Consensus { clients: usize, dim: usize, problem_seed: u64 },
    /// The §1 two-client counterexample `min (x−A)² + (x+A)²`, scalar x.
    Counterexample { a: f32, x0: f32 },
    /// Heterogeneous stochastic least squares (Table 2's empirical fit).
    LeastSquares {
        clients: usize,
        dim: usize,
        rows_per_client: usize,
        heterogeneity: f32,
        noise: f32,
        problem_seed: u64,
        stochastic: bool,
    },
    /// AOT-compiled neural workload over PJRT (needs `make artifacts`).
    Neural(NeuralSpec),
}

impl WorkloadSpec {
    /// Shorthand for the most common analytic workload.
    pub fn consensus(clients: usize, dim: usize, problem_seed: u64) -> WorkloadSpec {
        WorkloadSpec::Consensus { clients, dim, problem_seed }
    }

    /// Client population size, when it is known without building a runtime.
    pub fn num_clients(&self) -> Option<usize> {
        match self {
            WorkloadSpec::Consensus { clients, .. } => Some(*clients),
            WorkloadSpec::Counterexample { .. } => Some(2),
            WorkloadSpec::LeastSquares { clients, .. } => Some(*clients),
            WorkloadSpec::Neural(n) => Some(n.clients),
        }
    }

    /// Closed-form optimal value, for the workloads that have one (the
    /// `subtract_optimal` output option reports optimality gaps).
    pub fn optimal_value(&self) -> Option<f64> {
        match self {
            WorkloadSpec::Consensus { clients, dim, problem_seed } => {
                Consensus::gaussian(*clients, *dim, *problem_seed).optimal_value()
            }
            WorkloadSpec::Counterexample { a, .. } => {
                Consensus::counterexample(*a).optimal_value()
            }
            WorkloadSpec::LeastSquares {
                clients,
                dim,
                rows_per_client,
                heterogeneity,
                noise,
                problem_seed,
                ..
            } => LeastSquares::generate(
                *clients,
                *dim,
                *rows_per_client,
                *heterogeneity,
                *noise,
                *problem_seed,
            )
            .optimal_value(),
            WorkloadSpec::Neural(_) => None,
        }
    }
}

// ---------------------------------------------------------------------------
// Series + sweep
// ---------------------------------------------------------------------------

/// One algorithm curve: `label` is the CSV file stem (sanitized at write
/// time), `display` the console name.
#[derive(Debug, Clone, PartialEq)]
pub struct SeriesSpec {
    pub label: String,
    pub display: String,
    pub algorithm: AlgorithmConfig,
}

/// A `z × local_steps × σ` cross-product over `z-SignFedAvg` — the paper's
/// Fig. 2/7/9–13 grids. Expansion appends to the explicit series list.
///
/// Labels follow the historical driver convention: an axis appears in the
/// CSV stem only when it actually varies (`sigma` always does), so a
/// σ-only sweep yields `sigma0.3`, a full grid `z1_e5_sigma0.3`.
#[derive(Debug, Clone, PartialEq)]
pub struct SweepSpec {
    pub zs: Vec<ZParam>,
    pub local_steps: Vec<usize>,
    pub sigmas: Vec<f32>,
    pub client_lr: f32,
    pub server_lr: f32,
}

impl SweepSpec {
    /// Expand the grid into labeled series (row-major: z, then E, then σ).
    pub fn expand(&self) -> Vec<SeriesSpec> {
        let mut out = Vec::new();
        for &z in &self.zs {
            for &e in &self.local_steps {
                for &sigma in &self.sigmas {
                    let mut label_parts = Vec::new();
                    let mut disp_parts = Vec::new();
                    if self.zs.len() > 1 {
                        label_parts.push(format!("z{z}"));
                        disp_parts.push(format!("z={z}"));
                    }
                    if self.local_steps.len() > 1 {
                        label_parts.push(format!("e{e}"));
                        disp_parts.push(format!("E={e}"));
                    }
                    label_parts.push(format!("sigma{sigma}"));
                    let sigma_disp = if disp_parts.is_empty() {
                        format!("sigma = {sigma}")
                    } else {
                        format!("sigma={sigma}")
                    };
                    disp_parts.push(sigma_disp);
                    out.push(SeriesSpec {
                        label: label_parts.join("_"),
                        display: disp_parts.join(" "),
                        algorithm: AlgorithmConfig::z_signfedavg(z, sigma, e)
                            .with_lrs(self.client_lr, self.server_lr),
                    });
                }
            }
        }
        out
    }
}

/// Where and how results are written.
#[derive(Debug, Clone, PartialEq)]
pub struct OutputSpec {
    /// Root results directory; series CSVs land in `<dir>/<name>/`.
    pub dir: PathBuf,
    /// Report optimality gaps: subtract the workload's closed-form optimum
    /// from the aggregated objective mean (the paper's y-axis for the
    /// analytic figures; raw per-run CSVs keep absolute objectives).
    pub subtract_optimal: bool,
}

impl Default for OutputSpec {
    fn default() -> Self {
        OutputSpec { dir: PathBuf::from("results"), subtract_optimal: false }
    }
}

/// How the experiment's rounds execute: in-process, or through the
/// networked coordinator/participant service (`service::ServiceHost`).
///
/// Every transport is bit-identical to [`TransportSpec::Engine`] when all
/// offered work is submitted (the loopback tests pin this); `Tcp` adds
/// real fault semantics — heartbeat expiry and a round deadline that turns
/// silent dropouts into partial rounds.
#[derive(Debug, Clone, PartialEq, Eq, Default)]
pub enum TransportSpec {
    /// The in-process `RoundEngine` (the historical path; the default).
    #[default]
    Engine,
    /// The service round loop over in-process participant threads
    /// (one per `parallelism`), exercising the full protocol codec.
    Loopback,
    /// Serve rounds over TCP; participants join with `zsfa join`.
    Tcp {
        /// Listen address, e.g. `"127.0.0.1:7070"` (`:0` picks a port).
        addr: String,
        /// Heartbeat interval; a peer silent for 3× this is expired.
        heartbeat_ms: u64,
        /// Rounds close at full submission or after this deadline.
        round_deadline_ms: u64,
        /// Peers that must rendezvous before the first round is offered.
        min_participants: usize,
    },
}

impl TransportSpec {
    /// A TCP transport with the default timing (500 ms heartbeats, 10 s
    /// round deadline, one required participant).
    pub fn tcp(addr: impl Into<String>) -> TransportSpec {
        TransportSpec::Tcp {
            addr: addr.into(),
            heartbeat_ms: 500,
            round_deadline_ms: 10_000,
            min_participants: 1,
        }
    }
}

/// Observability configuration (see [`crate::telemetry`]). Off by
/// default — and strictly read-only when on: enabling telemetry never
/// changes a single result byte (pinned by the session tests and
/// `make metrics-smoke`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TelemetrySpec {
    /// Record phase spans, the metrics registry and coordinator events.
    pub enabled: bool,
    /// Events retained by the in-memory ring (oldest overwritten).
    pub event_capacity: usize,
    /// Write the final Prometheus exposition text here when the session
    /// finishes (scrape-free capture for CI and one-shot runs).
    pub dump_path: Option<String>,
}

impl Default for TelemetrySpec {
    fn default() -> Self {
        TelemetrySpec { enabled: false, event_capacity: 4096, dump_path: None }
    }
}

impl TelemetrySpec {
    /// An enabled spec with the default ring capacity.
    pub fn on() -> TelemetrySpec {
        TelemetrySpec { enabled: true, ..TelemetrySpec::default() }
    }

    /// Build the runtime handle this spec describes.
    pub fn handle(&self) -> Telemetry {
        if self.enabled {
            Telemetry::with_capacity(self.event_capacity)
        } else {
            Telemetry::disabled()
        }
    }
}

// ---------------------------------------------------------------------------
// The spec
// ---------------------------------------------------------------------------

/// A complete, executable experiment description. Construct with
/// [`ExperimentSpec::new`] + builder methods, or [`ExperimentSpec::from_json`];
/// execute with [`super::session::Session::run`].
#[derive(Debug, Clone, PartialEq)]
pub struct ExperimentSpec {
    /// Experiment name = output subdirectory under `output.dir`.
    pub name: String,
    pub workload: WorkloadSpec,
    /// Explicit algorithm series.
    pub series: Vec<SeriesSpec>,
    /// Optional sweep grid, expanded after the explicit series.
    pub sweep: Option<SweepSpec>,
    /// Communication rounds T.
    pub rounds: usize,
    /// Evaluate every k rounds.
    pub eval_every: usize,
    /// Clients sampled per round (None = full participation; only
    /// consulted by uniform participation).
    pub clients_per_round: Option<usize>,
    /// Base seed; repeat `r` runs with [`seed_for_repeat`]`(seed, r)`.
    pub seed: u64,
    /// Independent repeats per series (the paper's mean ± std protocol).
    pub repeats: usize,
    /// Worker threads (bit-identical results for any value).
    pub parallelism: usize,
    /// Reduction-topology lanes (a reproducibility knob, like the seed).
    pub reduce_lanes: usize,
    /// Optional §4.4 plateau controller for the noise scale.
    pub plateau: Option<PlateauConfig>,
    /// Optional downlink sign compression `(z, σ_d)`.
    pub downlink_sign: Option<(ZParam, f32)>,
    /// Uniform sampling or the client-lifecycle scenario engine.
    pub participation: Participation,
    /// In-process engine, loopback service, or TCP service.
    pub transport: TransportSpec,
    /// Observability (off by default; read-only when on).
    pub telemetry: TelemetrySpec,
    pub output: OutputSpec,
}

impl ExperimentSpec {
    /// A spec with the historical driver defaults (they mirror
    /// `ServerConfig::default()`): 100 rounds, eval every round, seed 0,
    /// 1 repeat, uniform full participation, `results/` output.
    pub fn new(name: impl Into<String>, workload: WorkloadSpec) -> ExperimentSpec {
        ExperimentSpec {
            name: name.into(),
            workload,
            series: Vec::new(),
            sweep: None,
            rounds: 100,
            eval_every: 1,
            clients_per_round: None,
            seed: 0,
            repeats: 1,
            parallelism: 1,
            reduce_lanes: DEFAULT_REDUCE_LANES,
            plateau: None,
            downlink_sign: None,
            participation: Participation::Uniform,
            transport: TransportSpec::Engine,
            telemetry: TelemetrySpec::default(),
            output: OutputSpec::default(),
        }
    }

    // -- builder ----------------------------------------------------------

    pub fn rounds(mut self, rounds: usize) -> Self {
        self.rounds = rounds;
        self
    }

    pub fn eval_every(mut self, k: usize) -> Self {
        self.eval_every = k;
        self
    }

    pub fn clients_per_round(mut self, m: Option<usize>) -> Self {
        self.clients_per_round = m;
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn repeats(mut self, repeats: usize) -> Self {
        self.repeats = repeats;
        self
    }

    pub fn parallelism(mut self, workers: usize) -> Self {
        self.parallelism = workers;
        self
    }

    pub fn reduce_lanes(mut self, lanes: usize) -> Self {
        self.reduce_lanes = lanes;
        self
    }

    pub fn plateau(mut self, p: PlateauConfig) -> Self {
        self.plateau = Some(p);
        self
    }

    pub fn downlink_sign(mut self, z: ZParam, sigma: f32) -> Self {
        self.downlink_sign = Some((z, sigma));
        self
    }

    pub fn participation(mut self, p: Participation) -> Self {
        self.participation = p;
        self
    }

    pub fn transport(mut self, t: TransportSpec) -> Self {
        self.transport = t;
        self
    }

    pub fn telemetry(mut self, t: TelemetrySpec) -> Self {
        self.telemetry = t;
        self
    }

    /// Append a series labeled and displayed by the algorithm's name.
    pub fn series(self, algorithm: AlgorithmConfig) -> Self {
        let label = algorithm.name.clone();
        let display = algorithm.name.clone();
        self.series_labeled(label, display, algorithm)
    }

    /// Append a series with an explicit CSV stem and console name.
    pub fn series_labeled(
        mut self,
        label: impl Into<String>,
        display: impl Into<String>,
        algorithm: AlgorithmConfig,
    ) -> Self {
        self.series.push(SeriesSpec {
            label: label.into(),
            display: display.into(),
            algorithm,
        });
        self
    }

    pub fn sweep(mut self, sweep: SweepSpec) -> Self {
        self.sweep = Some(sweep);
        self
    }

    pub fn output_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.output.dir = dir.into();
        self
    }

    pub fn subtract_optimal(mut self, yes: bool) -> Self {
        self.output.subtract_optimal = yes;
        self
    }

    // -- execution views --------------------------------------------------

    /// The seed repeat `r` of this spec runs with (see [`seed_for_repeat`]).
    pub fn seed_for_repeat(&self, repeat: usize) -> u64 {
        seed_for_repeat(self.seed, repeat)
    }

    /// Explicit series followed by the expanded sweep grid.
    pub fn expanded_series(&self) -> Vec<SeriesSpec> {
        let mut out = self.series.clone();
        if let Some(sweep) = &self.sweep {
            out.extend(sweep.expand());
        }
        out
    }

    /// The engine configuration for repeat `r`. This is the only place a
    /// `ServerConfig` is materialized on the spec path.
    pub fn server_config(&self, repeat: usize) -> ServerConfig {
        ServerConfig {
            rounds: self.rounds,
            clients_per_round: self.clients_per_round,
            eval_every: self.eval_every,
            seed: self.seed_for_repeat(repeat),
            plateau: self.plateau,
            downlink_sign: self.downlink_sign,
            parallelism: self.parallelism,
            reduce_lanes: self.reduce_lanes,
            participation: self.participation.clone(),
        }
    }

    // -- validation -------------------------------------------------------

    /// Check every structural rule, returning all violations (never
    /// panics). `Session::run` refuses invalid specs.
    pub fn validate(&self) -> Result<(), Vec<SpecError>> {
        let mut errs: Vec<SpecError> = Vec::new();

        if self.name.is_empty() {
            errs.push(SpecError::new("name", "must be non-empty"));
        } else if self.name.contains('/') || self.name.contains('\\') || self.name.contains("..")
        {
            errs.push(SpecError::new(
                "name",
                format!("must not contain path separators (got {:?})", self.name),
            ));
        }
        if self.rounds == 0 {
            errs.push(SpecError::new("rounds", "must be >= 1"));
        }
        if self.eval_every == 0 {
            errs.push(SpecError::new("eval_every", "must be >= 1"));
        }
        if self.repeats == 0 {
            errs.push(SpecError::new("repeats", "must be >= 1"));
        }

        self.validate_workload(&mut errs);

        let expanded = self.expanded_series();
        if expanded.is_empty() {
            errs.push(SpecError::new(
                "series",
                "at least one series (or a non-empty sweep) is required",
            ));
        }
        let mut labels = std::collections::BTreeSet::new();
        for (i, s) in expanded.iter().enumerate() {
            // Series past the explicit list come from the sweep grid; a
            // `series[i]` path would point at a JSON element that does not
            // exist in the user's file.
            let at = if i < self.series.len() {
                format!("series[{i}]")
            } else {
                format!("sweep (expanded series {:?})", s.label)
            };
            if !labels.insert(s.label.clone()) {
                errs.push(SpecError::new(
                    at.clone(),
                    format!("duplicate label {:?} would overwrite its CSV", s.label),
                ));
            }
            self.validate_algorithm(&at, &s.algorithm, &mut errs);
        }
        if let Some(sweep) = &self.sweep {
            for (axis, empty) in [
                ("sweep.zs", sweep.zs.is_empty()),
                ("sweep.local_steps", sweep.local_steps.is_empty()),
                ("sweep.sigmas", sweep.sigmas.is_empty()),
            ] {
                if empty {
                    errs.push(SpecError::new(axis, "must be non-empty"));
                }
            }
            if sweep.local_steps.iter().any(|&e| e == 0) {
                errs.push(SpecError::new("sweep.local_steps", "entries must be >= 1"));
            }
        }

        if let Some(m) = self.clients_per_round {
            if m == 0 {
                errs.push(SpecError::new(
                    "clients_per_round",
                    "must be >= 1 (use null for full participation)",
                ));
            } else if let Some(n) = self.workload.num_clients() {
                if m > n {
                    errs.push(SpecError::new(
                        "clients_per_round",
                        format!("{m} exceeds the workload's {n} clients"),
                    ));
                }
            }
        }

        if let Some(p) = &self.plateau {
            // NaN must fail too, hence the explicit is_nan arms.
            if p.sigma_init <= 0.0 || p.sigma_init.is_nan() {
                errs.push(SpecError::new("plateau.sigma_init", "must be > 0"));
            }
            if p.sigma_bound < p.sigma_init || p.sigma_bound.is_nan() {
                errs.push(SpecError::new("plateau.sigma_bound", "must be >= sigma_init"));
            }
            if p.beta <= 1.0 || p.beta.is_nan() {
                errs.push(SpecError::new("plateau.beta", "must be > 1"));
            }
        }
        if let Some((_, sigma)) = self.downlink_sign {
            if !sigma.is_finite() || sigma < 0.0 {
                errs.push(SpecError::new("downlink_sign.sigma", "must be finite and >= 0"));
            }
        }
        if let Participation::Simulated(sc) = &self.participation {
            self.validate_scenario(sc, &mut errs);
        }
        if let TransportSpec::Tcp { addr, heartbeat_ms, round_deadline_ms, min_participants } =
            &self.transport
        {
            if addr.is_empty() {
                errs.push(SpecError::new("transport.addr", "must be non-empty"));
            }
            if *heartbeat_ms == 0 {
                errs.push(SpecError::new("transport.heartbeat_ms", "must be >= 1"));
            }
            if *round_deadline_ms == 0 {
                errs.push(SpecError::new("transport.round_deadline_ms", "must be >= 1"));
            }
            if *min_participants == 0 {
                errs.push(SpecError::new("transport.min_participants", "must be >= 1"));
            }
        }
        if self.telemetry.enabled && self.telemetry.event_capacity == 0 {
            errs.push(SpecError::new("telemetry.event_capacity", "must be >= 1 when enabled"));
        }
        if self.output.subtract_optimal && self.workload.optimal_value().is_none() {
            errs.push(SpecError::new(
                "output.subtract_optimal",
                "workload has no closed-form optimum",
            ));
        }

        if errs.is_empty() {
            Ok(())
        } else {
            Err(errs)
        }
    }

    fn validate_workload(&self, errs: &mut Vec<SpecError>) {
        let mut push = |at: &str, reason: &str| errs.push(SpecError::new(at, reason));
        match &self.workload {
            WorkloadSpec::Consensus { clients, dim, .. } => {
                if *clients == 0 {
                    push("workload.clients", "must be >= 1");
                }
                if *dim == 0 {
                    push("workload.dim", "must be >= 1");
                }
            }
            WorkloadSpec::Counterexample { a, .. } => {
                if !(a.is_finite() && *a > 0.0) {
                    push("workload.a", "must be finite and > 0");
                }
            }
            WorkloadSpec::LeastSquares { clients, dim, rows_per_client, .. } => {
                if *clients == 0 {
                    push("workload.clients", "must be >= 1");
                }
                if *dim == 0 {
                    push("workload.dim", "must be >= 1");
                }
                if *rows_per_client == 0 {
                    push("workload.rows_per_client", "must be >= 1");
                }
            }
            WorkloadSpec::Neural(n) => {
                if n.clients == 0 {
                    push("workload.clients", "must be >= 1");
                }
                if n.train_samples == 0 {
                    push("workload.train_samples", "must be >= 1");
                }
            }
        }
    }

    fn validate_algorithm(&self, at: &str, a: &AlgorithmConfig, errs: &mut Vec<SpecError>) {
        let mut push = |field: &str, reason: String| {
            errs.push(SpecError::new(format!("{at}.algorithm.{field}"), reason))
        };
        if a.local_steps == 0 {
            push("local_steps", "must be >= 1".into());
        }
        if !(a.client_lr.is_finite() && a.client_lr > 0.0) {
            push("client_lr", "must be finite and > 0".into());
        }
        if !a.server_lr.is_finite() {
            push("server_lr", "must be finite".into());
        }
        match a.compression {
            Compression::ZSign { sigma: SigmaRule::Fixed(s), .. } => {
                if !(s.is_finite() && s >= 0.0) {
                    push("compression.sigma", "fixed sigma must be finite and >= 0".into());
                }
            }
            Compression::Qsgd { s } => {
                if s == 0 {
                    push("compression.s", "QSGD needs >= 1 quantization level".into());
                }
            }
            Compression::TopK { frac } => {
                if !(frac > 0.0 && frac <= 1.0) {
                    push("compression.frac", "must be in (0, 1]".into());
                }
            }
            Compression::SparseSign { frac, sigma, .. } => {
                if !(frac > 0.0 && frac <= 1.0) {
                    push("compression.frac", "must be in (0, 1]".into());
                }
                if !(sigma.is_finite() && sigma >= 0.0) {
                    push("compression.sigma", "must be finite and >= 0".into());
                }
            }
            Compression::DpSign { clip, noise_mult }
            | Compression::DpDense { clip, noise_mult } => {
                if !(clip.is_finite() && clip > 0.0) {
                    push("compression.clip", "must be finite and > 0".into());
                }
                if !(noise_mult.is_finite() && noise_mult >= 0.0) {
                    push("compression.noise_mult", "must be finite and >= 0".into());
                }
            }
            Compression::ErrorFeedback => {
                // The engine asserts this (paper §1.1); surface it as a
                // SpecError instead of a panic. clients_per_round equal to
                // the whole population IS full participation (the engine
                // accepts it), so only a genuinely smaller cohort —
                // or an unknowable one — counts as partial.
                let partial_uniform = match (self.clients_per_round, self.workload.num_clients())
                {
                    (Some(m), Some(n)) => m < n,
                    (Some(_), None) => true,
                    (None, _) => false,
                };
                let partial = partial_uniform
                    || !matches!(self.participation, Participation::Uniform);
                if partial {
                    push(
                        "compression",
                        "EF-SignSGD requires full uniform participation \
                         (it tracks per-client residuals; paper §1.1)"
                            .into(),
                    );
                }
            }
            _ => {}
        }
    }

    fn validate_scenario(&self, sc: &ScenarioConfig, errs: &mut Vec<SpecError>) {
        let mut push = |at: &str, reason: &str| {
            errs.push(SpecError::new(format!("participation.{at}"), reason))
        };
        if sc.target_cohort == 0 {
            push("target_cohort", "must be >= 1");
        }
        if !(sc.overselect.is_finite() && sc.overselect >= 1.0) {
            push("overselect", "must be finite and >= 1");
        }
        if !(sc.deadline_s.is_finite() && sc.deadline_s > 0.0) {
            push("deadline_s", "must be finite and > 0");
        }
        if !(sc.round_latency_s.is_finite() && sc.round_latency_s >= 0.0) {
            push("round_latency_s", "must be finite and >= 0");
        }
        if !(0.0..=1.0).contains(&sc.dropout_prob) {
            push("dropout_prob", "must be in [0, 1]");
        }
        if !(0.0..=1.0).contains(&sc.byzantine_frac) {
            push("byzantine_frac", "must be in [0, 1]");
        }
        if let ByzantineMode::GradNegate { boost } = sc.byzantine_mode {
            if !(boost.is_finite() && boost > 0.0) {
                push("byzantine_mode.boost", "must be finite and > 0");
            }
        }
    }
}

// ---------------------------------------------------------------------------
// JSON codec
// ---------------------------------------------------------------------------

impl ExperimentSpec {
    /// Compact JSON serialization. [`ExperimentSpec::from_json`] restores
    /// it losslessly (f32 → f64 widening is exact; seeds above 2^53 are
    /// the only values JSON numbers cannot carry).
    pub fn to_json(&self) -> String {
        let mut m: BTreeMap<String, Json> = BTreeMap::new();
        m.insert("name".into(), jstr(&self.name));
        m.insert("workload".into(), workload_json(&self.workload));
        m.insert("rounds".into(), jus(self.rounds));
        m.insert("eval_every".into(), jus(self.eval_every));
        m.insert("seed".into(), jnum(self.seed as f64));
        m.insert("repeats".into(), jus(self.repeats));
        m.insert("parallelism".into(), jus(self.parallelism));
        m.insert("reduce_lanes".into(), jus(self.reduce_lanes));
        if let Some(cpr) = self.clients_per_round {
            m.insert("clients_per_round".into(), jus(cpr));
        }
        if let Some(p) = &self.plateau {
            m.insert("plateau".into(), plateau_json(p));
        }
        if let Some((z, s)) = self.downlink_sign {
            m.insert(
                "downlink_sign".into(),
                jobj(vec![("z", zparam_json(z)), ("sigma", jf32(s))]),
            );
        }
        m.insert("participation".into(), participation_json(&self.participation));
        // The default engine transport is omitted, keeping pre-service
        // spec files byte-identical through a round trip.
        if self.transport != TransportSpec::Engine {
            m.insert("transport".into(), transport_json(&self.transport));
        }
        // Likewise telemetry: the default (off) adds no key, so every
        // pre-telemetry spec file round-trips byte-identically.
        if self.telemetry != TelemetrySpec::default() {
            m.insert("telemetry".into(), telemetry_json(&self.telemetry));
        }
        if !self.series.is_empty() {
            m.insert("series".into(), Json::Arr(self.series.iter().map(series_json).collect()));
        }
        if let Some(sw) = &self.sweep {
            m.insert("sweep".into(), sweep_json(sw));
        }
        m.insert("output".into(), output_json(&self.output));
        Json::Obj(m).to_string_compact()
    }

    /// Parse a spec from JSON, reporting the exact field path on error.
    /// Unknown keys are rejected (typo safety); keys starting with `_`
    /// are comments.
    pub fn from_json(text: &str) -> Result<ExperimentSpec, SpecError> {
        let doc = Json::parse(text).map_err(|e| SpecError::new("json", e))?;
        let o = Obj::new(&doc, "")?;
        let name = o.req_str("name")?.to_string();
        let workload = workload_from(o.req("workload")?, "workload")?;
        let mut spec = ExperimentSpec::new(name, workload);
        spec.rounds = o.usize_or("rounds", spec.rounds)?;
        spec.eval_every = o.usize_or("eval_every", spec.eval_every)?;
        spec.seed = o.u64_or("seed", spec.seed)?;
        spec.repeats = o.usize_or("repeats", spec.repeats)?;
        spec.clients_per_round = o.opt_usize("clients_per_round")?;
        spec.parallelism = o.usize_or("parallelism", spec.parallelism)?;
        spec.reduce_lanes = o.usize_or("reduce_lanes", spec.reduce_lanes)?;
        if let Some(j) = o.get("plateau") {
            spec.plateau = Some(plateau_from(j, "plateau")?);
        }
        if let Some(j) = o.get("downlink_sign") {
            spec.downlink_sign = Some(downlink_from(j, "downlink_sign")?);
        }
        if let Some(j) = o.get("participation") {
            spec.participation = participation_from(j, "participation")?;
        }
        if let Some(j) = o.get("transport") {
            spec.transport = transport_from(j, "transport")?;
        }
        if let Some(j) = o.get("telemetry") {
            spec.telemetry = telemetry_from(j, "telemetry")?;
        }
        if let Some(j) = o.get("series") {
            let arr =
                j.as_arr().ok_or_else(|| SpecError::new("series", "expected an array"))?;
            for (i, sj) in arr.iter().enumerate() {
                spec.series.push(series_from(sj, &format!("series[{i}]"))?);
            }
        }
        if let Some(j) = o.get("sweep") {
            spec.sweep = Some(sweep_from(j, "sweep")?);
        }
        if let Some(j) = o.get("output") {
            spec.output = output_from(j, "output")?;
        }
        o.finish()?;
        Ok(spec)
    }

    /// Load a spec from a `.json` file (the `zsfa run <spec.json>` path).
    pub fn from_json_file(path: &Path) -> Result<ExperimentSpec, SpecError> {
        let text = std::fs::read_to_string(path).map_err(|e| {
            SpecError::new(path.display().to_string(), format!("cannot read spec: {e}"))
        })?;
        Self::from_json(&text)
    }
}

// -- writer helpers ---------------------------------------------------------

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

fn jnum(x: f64) -> Json {
    Json::Num(x)
}

fn jus(x: usize) -> Json {
    Json::Num(x as f64)
}

fn jf32(x: f32) -> Json {
    Json::Num(x as f64)
}

fn jobj(entries: Vec<(&str, Json)>) -> Json {
    Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

// -- strict object reader ---------------------------------------------------

/// A field-path-aware view of one JSON object: every access is recorded so
/// [`Obj::finish`] can reject unknown (likely misspelled) keys. Explicit
/// `null` counts as an absent field; keys starting with `_` are comments.
struct Obj<'a> {
    at: String,
    map: &'a BTreeMap<String, Json>,
    seen: std::cell::RefCell<std::collections::BTreeSet<String>>,
}

impl<'a> Obj<'a> {
    fn new(j: &'a Json, at: &str) -> Result<Obj<'a>, SpecError> {
        match j {
            Json::Obj(map) => Ok(Obj {
                at: at.to_string(),
                map,
                seen: std::cell::RefCell::new(std::collections::BTreeSet::new()),
            }),
            _ => Err(SpecError::new(at, "expected a JSON object")),
        }
    }

    fn path(&self, key: &str) -> String {
        if self.at.is_empty() {
            key.to_string()
        } else {
            format!("{}.{key}", self.at)
        }
    }

    fn get(&self, key: &str) -> Option<&'a Json> {
        self.seen.borrow_mut().insert(key.to_string());
        match self.map.get(key) {
            None | Some(Json::Null) => None,
            Some(v) => Some(v),
        }
    }

    fn req(&self, key: &str) -> Result<&'a Json, SpecError> {
        self.get(key)
            .ok_or_else(|| SpecError::new(self.path(key), "missing required field"))
    }

    fn req_str(&self, key: &str) -> Result<&'a str, SpecError> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| SpecError::new(self.path(key), "expected a string"))
    }

    fn str_or<'b>(&self, key: &str, default: &'b str) -> Result<&'b str, SpecError>
    where
        'a: 'b,
    {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_str()
                .ok_or_else(|| SpecError::new(self.path(key), "expected a string")),
        }
    }

    fn req_f64(&self, key: &str) -> Result<f64, SpecError> {
        self.req(key)?
            .as_f64()
            .ok_or_else(|| SpecError::new(self.path(key), "expected a number"))
    }

    fn req_f32(&self, key: &str) -> Result<f32, SpecError> {
        Ok(self.req_f64(key)? as f32)
    }

    fn f64_or(&self, key: &str, default: f64) -> Result<f64, SpecError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .ok_or_else(|| SpecError::new(self.path(key), "expected a number")),
        }
    }

    fn f32_or(&self, key: &str, default: f32) -> Result<f32, SpecError> {
        Ok(self.f64_or(key, default as f64)? as f32)
    }

    fn req_usize(&self, key: &str) -> Result<usize, SpecError> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| SpecError::new(self.path(key), "expected a non-negative integer"))
    }

    fn usize_or(&self, key: &str, default: usize) -> Result<usize, SpecError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.as_usize().ok_or_else(|| {
                SpecError::new(self.path(key), "expected a non-negative integer")
            }),
        }
    }

    fn opt_usize(&self, key: &str) -> Result<Option<usize>, SpecError> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v.as_usize().map(Some).ok_or_else(|| {
                SpecError::new(self.path(key), "expected a non-negative integer")
            }),
        }
    }

    fn u64_or(&self, key: &str, default: u64) -> Result<u64, SpecError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_f64()
                .filter(|n| *n >= 0.0 && n.fract() == 0.0)
                .map(|n| n as u64)
                .ok_or_else(|| {
                    SpecError::new(self.path(key), "expected a non-negative integer")
                }),
        }
    }

    fn bool_or(&self, key: &str, default: bool) -> Result<bool, SpecError> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .as_bool()
                .ok_or_else(|| SpecError::new(self.path(key), "expected a boolean")),
        }
    }

    fn finish(self) -> Result<(), SpecError> {
        let seen = self.seen.borrow();
        for k in self.map.keys() {
            if !k.starts_with('_') && !seen.contains(k) {
                return Err(SpecError::new(
                    self.path(k),
                    "unknown field (prefix a key with `_` for comments)",
                ));
            }
        }
        Ok(())
    }
}

// -- per-type encoders/decoders ---------------------------------------------

fn zparam_json(z: ZParam) -> Json {
    match z {
        ZParam::Inf => Json::Str("inf".into()),
        ZParam::Finite(k) => Json::Num(k as f64),
    }
}

fn zparam_from(j: &Json, at: &str) -> Result<ZParam, SpecError> {
    if let Some(s) = j.as_str() {
        if s == "inf" {
            return Ok(ZParam::Inf);
        }
        return Err(SpecError::new(at, format!("expected a z >= 1 or \"inf\" (got {s:?})")));
    }
    match j.as_f64() {
        Some(n) if n >= 1.0 && n.fract() == 0.0 && n <= u32::MAX as f64 => {
            Ok(ZParam::Finite(n as u32))
        }
        _ => Err(SpecError::new(at, "expected a z >= 1 or \"inf\"")),
    }
}

fn sigma_rule_json(r: SigmaRule) -> Json {
    match r {
        SigmaRule::Fixed(v) => jobj(vec![("rule", jstr("fixed")), ("value", jf32(v))]),
        SigmaRule::L2Norm => jobj(vec![("rule", jstr("l2norm"))]),
        SigmaRule::InfNorm => jobj(vec![("rule", jstr("infnorm"))]),
    }
}

fn sigma_rule_from(j: &Json, at: &str) -> Result<SigmaRule, SpecError> {
    let o = Obj::new(j, at)?;
    let rule = match o.req_str("rule")? {
        "fixed" => SigmaRule::Fixed(o.req_f32("value")?),
        "l2norm" => SigmaRule::L2Norm,
        "infnorm" => SigmaRule::InfNorm,
        other => {
            return Err(SpecError::new(o.path("rule"), format!("unknown sigma rule {other:?}")))
        }
    };
    o.finish()?;
    Ok(rule)
}

fn compression_json(c: &Compression) -> Json {
    match *c {
        Compression::None => jobj(vec![("kind", jstr("none"))]),
        Compression::ZSign { z, sigma } => jobj(vec![
            ("kind", jstr("zsign")),
            ("z", zparam_json(z)),
            ("sigma", sigma_rule_json(sigma)),
        ]),
        Compression::ErrorFeedback => jobj(vec![("kind", jstr("error_feedback"))]),
        Compression::Qsgd { s } => jobj(vec![("kind", jstr("qsgd")), ("s", jus(s as usize))]),
        Compression::DpSign { clip, noise_mult } => jobj(vec![
            ("kind", jstr("dp_sign")),
            ("clip", jf32(clip)),
            ("noise_mult", jf32(noise_mult)),
        ]),
        Compression::DpDense { clip, noise_mult } => jobj(vec![
            ("kind", jstr("dp_dense")),
            ("clip", jf32(clip)),
            ("noise_mult", jf32(noise_mult)),
        ]),
        Compression::TopK { frac } => {
            jobj(vec![("kind", jstr("topk")), ("frac", jf32(frac))])
        }
        Compression::SparseSign { frac, z, sigma } => jobj(vec![
            ("kind", jstr("sparse_sign")),
            ("frac", jf32(frac)),
            ("z", zparam_json(z)),
            ("sigma", jf32(sigma)),
        ]),
    }
}

fn compression_from(j: &Json, at: &str) -> Result<Compression, SpecError> {
    let o = Obj::new(j, at)?;
    let c = match o.req_str("kind")? {
        "none" => Compression::None,
        "zsign" => Compression::ZSign {
            z: zparam_from(o.req("z")?, &o.path("z"))?,
            sigma: sigma_rule_from(o.req("sigma")?, &o.path("sigma"))?,
        },
        "error_feedback" => Compression::ErrorFeedback,
        "qsgd" => {
            let s = o.req_usize("s")?;
            if s > u32::MAX as usize {
                return Err(SpecError::new(o.path("s"), "too many quantization levels"));
            }
            Compression::Qsgd { s: s as u32 }
        }
        "dp_sign" => Compression::DpSign {
            clip: o.req_f32("clip")?,
            noise_mult: o.req_f32("noise_mult")?,
        },
        "dp_dense" => Compression::DpDense {
            clip: o.req_f32("clip")?,
            noise_mult: o.req_f32("noise_mult")?,
        },
        "topk" => Compression::TopK { frac: o.req_f32("frac")? },
        "sparse_sign" => Compression::SparseSign {
            frac: o.req_f32("frac")?,
            z: zparam_from(o.req("z")?, &o.path("z"))?,
            sigma: o.req_f32("sigma")?,
        },
        other => {
            return Err(SpecError::new(
                o.path("kind"),
                format!("unknown compression kind {other:?}"),
            ))
        }
    };
    o.finish()?;
    Ok(c)
}

fn server_opt_json(s: &ServerOpt) -> Json {
    match *s {
        ServerOpt::Sgd => jobj(vec![("kind", jstr("sgd"))]),
        ServerOpt::Momentum(m) => {
            jobj(vec![("kind", jstr("momentum")), ("momentum", jf32(m))])
        }
        ServerOpt::Adam { beta1, beta2, eps } => jobj(vec![
            ("kind", jstr("adam")),
            ("beta1", jf32(beta1)),
            ("beta2", jf32(beta2)),
            ("eps", jf32(eps)),
        ]),
    }
}

fn server_opt_from(j: &Json, at: &str) -> Result<ServerOpt, SpecError> {
    let o = Obj::new(j, at)?;
    let s = match o.req_str("kind")? {
        "sgd" => ServerOpt::Sgd,
        "momentum" => ServerOpt::Momentum(o.req_f32("momentum")?),
        "adam" => ServerOpt::Adam {
            beta1: o.f32_or("beta1", 0.9)?,
            beta2: o.f32_or("beta2", 0.99)?,
            eps: o.f32_or("eps", 1e-3)?,
        },
        other => {
            return Err(SpecError::new(o.path("kind"), format!("unknown server_opt {other:?}")))
        }
    };
    o.finish()?;
    Ok(s)
}

fn robust_json(r: &RobustRule) -> Json {
    match *r {
        RobustRule::None => jobj(vec![("rule", jstr("none"))]),
        RobustRule::TrimmedMajority { frac } => {
            jobj(vec![("rule", jstr("trimmed_majority")), ("frac", jf32(frac))])
        }
    }
}

fn robust_from(j: &Json, at: &str) -> Result<RobustRule, SpecError> {
    let o = Obj::new(j, at)?;
    let r = match o.req_str("rule")? {
        "none" => RobustRule::None,
        "trimmed_majority" => {
            let frac = o.req_f32("frac")?;
            if !(0.0..0.5).contains(&frac) {
                return Err(SpecError::new(
                    o.path("frac"),
                    "trim fraction must be in [0, 0.5)",
                ));
            }
            RobustRule::TrimmedMajority { frac }
        }
        other => {
            return Err(SpecError::new(o.path("rule"), format!("unknown robust rule {other:?}")))
        }
    };
    o.finish()?;
    Ok(r)
}

fn algorithm_json(a: &AlgorithmConfig) -> Json {
    let mut v = vec![
        ("name", jstr(&a.name)),
        ("compression", compression_json(&a.compression)),
        ("client_lr", jf32(a.client_lr)),
        ("server_lr", jf32(a.server_lr)),
        ("server_opt", server_opt_json(&a.server_opt)),
        ("local_steps", jus(a.local_steps)),
    ];
    // Emitted only when set, so pre-existing spec JSON stays byte-stable.
    if a.robust != RobustRule::None {
        v.push(("robust", robust_json(&a.robust)));
    }
    jobj(v)
}

fn algorithm_from(j: &Json, at: &str) -> Result<AlgorithmConfig, SpecError> {
    let o = Obj::new(j, at)?;
    let a = AlgorithmConfig {
        name: o.req_str("name")?.to_string(),
        compression: compression_from(o.req("compression")?, &o.path("compression"))?,
        client_lr: o.f32_or("client_lr", 0.01)?,
        server_lr: o.f32_or("server_lr", 1.0)?,
        server_opt: match o.get("server_opt") {
            None => ServerOpt::Sgd,
            Some(v) => server_opt_from(v, &o.path("server_opt"))?,
        },
        local_steps: o.usize_or("local_steps", 1)?,
        robust: match o.get("robust") {
            None => RobustRule::None,
            Some(v) => robust_from(v, &o.path("robust"))?,
        },
    };
    o.finish()?;
    Ok(a)
}

fn series_json(s: &SeriesSpec) -> Json {
    let mut v = Vec::new();
    if s.label != s.algorithm.name {
        v.push(("label", jstr(&s.label)));
    }
    if s.display != s.label {
        v.push(("display", jstr(&s.display)));
    }
    v.push(("algorithm", algorithm_json(&s.algorithm)));
    jobj(v)
}

fn series_from(j: &Json, at: &str) -> Result<SeriesSpec, SpecError> {
    let o = Obj::new(j, at)?;
    let algorithm = algorithm_from(o.req("algorithm")?, &o.path("algorithm"))?;
    let label = o.str_or("label", &algorithm.name)?.to_string();
    let display = o.str_or("display", &label)?.to_string();
    o.finish()?;
    Ok(SeriesSpec { label, display, algorithm })
}

fn plateau_json(p: &PlateauConfig) -> Json {
    jobj(vec![
        ("sigma_init", jf32(p.sigma_init)),
        ("sigma_bound", jf32(p.sigma_bound)),
        ("kappa", jus(p.kappa)),
        ("beta", jf32(p.beta)),
    ])
}

fn plateau_from(j: &Json, at: &str) -> Result<PlateauConfig, SpecError> {
    let o = Obj::new(j, at)?;
    let p = PlateauConfig {
        sigma_init: o.req_f32("sigma_init")?,
        sigma_bound: o.req_f32("sigma_bound")?,
        kappa: o.req_usize("kappa")?,
        beta: o.req_f32("beta")?,
    };
    o.finish()?;
    Ok(p)
}

fn downlink_from(j: &Json, at: &str) -> Result<(ZParam, f32), SpecError> {
    let o = Obj::new(j, at)?;
    let z = zparam_from(o.req("z")?, &o.path("z"))?;
    let sigma = o.req_f32("sigma")?;
    o.finish()?;
    Ok((z, sigma))
}

fn byzantine_json(m: ByzantineMode) -> Json {
    match m {
        ByzantineMode::SignFlip => jobj(vec![("kind", jstr("signflip"))]),
        ByzantineMode::GradNegate { boost } => {
            jobj(vec![("kind", jstr("gradnegate")), ("boost", jf32(boost))])
        }
    }
}

fn byzantine_from(j: &Json, at: &str) -> Result<ByzantineMode, SpecError> {
    let o = Obj::new(j, at)?;
    let m = match o.req_str("kind")? {
        "signflip" | "sign-flip" => ByzantineMode::SignFlip,
        "gradnegate" | "grad-negate" => {
            ByzantineMode::GradNegate { boost: o.f32_or("boost", 10.0)? }
        }
        other => {
            return Err(SpecError::new(
                o.path("kind"),
                format!("unknown byzantine mode {other:?}"),
            ))
        }
    };
    o.finish()?;
    Ok(m)
}

fn participation_json(p: &Participation) -> Json {
    match p {
        Participation::Uniform => jobj(vec![("kind", jstr("uniform"))]),
        Participation::Simulated(sc) => jobj(vec![
            ("kind", jstr("simulated")),
            ("target_cohort", jus(sc.target_cohort)),
            ("overselect", jnum(sc.overselect)),
            ("deadline_s", jnum(sc.deadline_s)),
            ("round_latency_s", jnum(sc.round_latency_s)),
            ("dropout_prob", jf32(sc.dropout_prob)),
            ("byzantine_frac", jf32(sc.byzantine_frac)),
            ("byzantine_mode", byzantine_json(sc.byzantine_mode)),
            (
                "fleet",
                jstr(match sc.fleet {
                    FleetPreset::Uniform => "uniform",
                    FleetPreset::CrossDevice => "cross_device",
                }),
            ),
        ]),
    }
}

fn participation_from(j: &Json, at: &str) -> Result<Participation, SpecError> {
    let o = Obj::new(j, at)?;
    let p = match o.req_str("kind")? {
        "uniform" => Participation::Uniform,
        "simulated" => {
            let d = ScenarioConfig::default();
            let mode = match o.get("byzantine_mode") {
                None => d.byzantine_mode,
                Some(v) => byzantine_from(v, &o.path("byzantine_mode"))?,
            };
            let fleet_key = o.str_or("fleet", "cross_device")?;
            let fleet = FleetPreset::parse(fleet_key).ok_or_else(|| {
                SpecError::new(o.path("fleet"), format!("unknown fleet {fleet_key:?}"))
            })?;
            Participation::Simulated(ScenarioConfig {
                target_cohort: o.usize_or("target_cohort", d.target_cohort)?,
                overselect: o.f64_or("overselect", d.overselect)?,
                deadline_s: o.f64_or("deadline_s", d.deadline_s)?,
                round_latency_s: o.f64_or("round_latency_s", d.round_latency_s)?,
                dropout_prob: o.f32_or("dropout_prob", d.dropout_prob)?,
                byzantine_frac: o.f32_or("byzantine_frac", d.byzantine_frac)?,
                byzantine_mode: mode,
                fleet,
            })
        }
        other => {
            return Err(SpecError::new(
                o.path("kind"),
                format!("unknown participation kind {other:?}"),
            ))
        }
    };
    o.finish()?;
    Ok(p)
}

fn transport_json(t: &TransportSpec) -> Json {
    match t {
        TransportSpec::Engine => jobj(vec![("kind", jstr("engine"))]),
        TransportSpec::Loopback => jobj(vec![("kind", jstr("loopback"))]),
        TransportSpec::Tcp { addr, heartbeat_ms, round_deadline_ms, min_participants } => {
            jobj(vec![
                ("kind", jstr("tcp")),
                ("addr", jstr(addr)),
                ("heartbeat_ms", jnum(*heartbeat_ms as f64)),
                ("round_deadline_ms", jnum(*round_deadline_ms as f64)),
                ("min_participants", jus(*min_participants)),
            ])
        }
    }
}

fn transport_from(j: &Json, at: &str) -> Result<TransportSpec, SpecError> {
    let o = Obj::new(j, at)?;
    let t = match o.req_str("kind")? {
        "engine" => TransportSpec::Engine,
        "loopback" => TransportSpec::Loopback,
        "tcp" => {
            let TransportSpec::Tcp {
                heartbeat_ms: d_hb,
                round_deadline_ms: d_dl,
                min_participants: d_min,
                ..
            } = TransportSpec::tcp("")
            else {
                unreachable!()
            };
            TransportSpec::Tcp {
                addr: o.req_str("addr")?.to_string(),
                heartbeat_ms: o.u64_or("heartbeat_ms", d_hb)?,
                round_deadline_ms: o.u64_or("round_deadline_ms", d_dl)?,
                min_participants: o.usize_or("min_participants", d_min)?,
            }
        }
        other => {
            return Err(SpecError::new(
                o.path("kind"),
                format!("unknown transport kind {other:?}"),
            ))
        }
    };
    o.finish()?;
    Ok(t)
}

fn telemetry_json(t: &TelemetrySpec) -> Json {
    let mut v = vec![
        ("enabled", Json::Bool(t.enabled)),
        ("event_capacity", jus(t.event_capacity)),
    ];
    if let Some(p) = &t.dump_path {
        v.push(("dump_path", jstr(p)));
    }
    jobj(v)
}

fn telemetry_from(j: &Json, at: &str) -> Result<TelemetrySpec, SpecError> {
    let o = Obj::new(j, at)?;
    let d = TelemetrySpec::default();
    let dump_path = match o.get("dump_path") {
        None => None,
        Some(v) => Some(
            v.as_str()
                .map(str::to_string)
                .ok_or_else(|| SpecError::new(o.path("dump_path"), "expected a string"))?,
        ),
    };
    let t = TelemetrySpec {
        // A present-but-sparse telemetry block means "turn it on".
        enabled: o.bool_or("enabled", true)?,
        event_capacity: o.usize_or("event_capacity", d.event_capacity)?,
        dump_path,
    };
    o.finish()?;
    Ok(t)
}

fn workload_json(w: &WorkloadSpec) -> Json {
    match w {
        WorkloadSpec::Consensus { clients, dim, problem_seed } => jobj(vec![
            ("kind", jstr("consensus")),
            ("clients", jus(*clients)),
            ("dim", jus(*dim)),
            ("problem_seed", jnum(*problem_seed as f64)),
        ]),
        WorkloadSpec::Counterexample { a, x0 } => jobj(vec![
            ("kind", jstr("counterexample")),
            ("a", jf32(*a)),
            ("x0", jf32(*x0)),
        ]),
        WorkloadSpec::LeastSquares {
            clients,
            dim,
            rows_per_client,
            heterogeneity,
            noise,
            problem_seed,
            stochastic,
        } => jobj(vec![
            ("kind", jstr("least_squares")),
            ("clients", jus(*clients)),
            ("dim", jus(*dim)),
            ("rows_per_client", jus(*rows_per_client)),
            ("heterogeneity", jf32(*heterogeneity)),
            ("noise", jf32(*noise)),
            ("problem_seed", jnum(*problem_seed as f64)),
            ("stochastic", Json::Bool(*stochastic)),
        ]),
        WorkloadSpec::Neural(n) => {
            let mut v = vec![
                ("kind", jstr("neural")),
                ("dataset", jstr(n.dataset.key())),
                ("clients", jus(n.clients)),
                ("train_samples", jus(n.train_samples)),
                ("paper_scale", Json::Bool(n.paper_scale)),
                ("artifacts", jstr(&n.artifacts.to_string_lossy())),
            ];
            if let Some(t) = n.test_samples {
                v.push(("test_samples", jus(t)));
            }
            jobj(v)
        }
    }
}

fn workload_from(j: &Json, at: &str) -> Result<WorkloadSpec, SpecError> {
    let o = Obj::new(j, at)?;
    let w = match o.req_str("kind")? {
        "consensus" => WorkloadSpec::Consensus {
            clients: o.req_usize("clients")?,
            dim: o.req_usize("dim")?,
            problem_seed: o.u64_or("problem_seed", 99)?,
        },
        "counterexample" => WorkloadSpec::Counterexample {
            a: o.req_f32("a")?,
            x0: o.f32_or("x0", 0.0)?,
        },
        "least_squares" => WorkloadSpec::LeastSquares {
            clients: o.req_usize("clients")?,
            dim: o.req_usize("dim")?,
            rows_per_client: o.req_usize("rows_per_client")?,
            heterogeneity: o.f32_or("heterogeneity", 0.5)?,
            noise: o.f32_or("noise", 0.5)?,
            problem_seed: o.u64_or("problem_seed", 11)?,
            stochastic: o.bool_or("stochastic", true)?,
        },
        "neural" => {
            let key = o.req_str("dataset")?;
            let dataset = Dataset::parse(key).ok_or_else(|| {
                SpecError::new(o.path("dataset"), format!("unknown dataset {key:?}"))
            })?;
            let paper_scale = o.bool_or("paper_scale", false)?;
            let (clients_d, _, train_d) = dataset.defaults(paper_scale);
            WorkloadSpec::Neural(NeuralSpec {
                dataset,
                clients: o.usize_or("clients", clients_d)?,
                train_samples: o.usize_or("train_samples", train_d)?,
                test_samples: o.opt_usize("test_samples")?,
                paper_scale,
                artifacts: PathBuf::from(o.str_or("artifacts", "artifacts")?),
            })
        }
        other => {
            return Err(SpecError::new(
                o.path("kind"),
                format!("unknown workload kind {other:?}"),
            ))
        }
    };
    o.finish()?;
    Ok(w)
}

fn sweep_json(s: &SweepSpec) -> Json {
    jobj(vec![
        ("zs", Json::Arr(s.zs.iter().map(|&z| zparam_json(z)).collect())),
        ("local_steps", Json::Arr(s.local_steps.iter().map(|&e| jus(e)).collect())),
        ("sigmas", Json::Arr(s.sigmas.iter().map(|&v| jf32(v)).collect())),
        ("client_lr", jf32(s.client_lr)),
        ("server_lr", jf32(s.server_lr)),
    ])
}

fn sweep_from(j: &Json, at: &str) -> Result<SweepSpec, SpecError> {
    let o = Obj::new(j, at)?;
    let zs = match o.get("zs") {
        None => vec![ZParam::Finite(1)],
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| SpecError::new(o.path("zs"), "expected an array"))?;
            arr.iter()
                .enumerate()
                .map(|(i, zj)| zparam_from(zj, &format!("{}[{i}]", o.path("zs"))))
                .collect::<Result<Vec<_>, _>>()?
        }
    };
    let local_steps = match o.get("local_steps") {
        None => vec![1],
        Some(v) => {
            let arr = v
                .as_arr()
                .ok_or_else(|| SpecError::new(o.path("local_steps"), "expected an array"))?;
            arr.iter()
                .enumerate()
                .map(|(i, e)| {
                    e.as_usize().ok_or_else(|| {
                        SpecError::new(
                            format!("{}[{i}]", o.path("local_steps")),
                            "expected a non-negative integer",
                        )
                    })
                })
                .collect::<Result<Vec<_>, _>>()?
        }
    };
    let sigmas = {
        let v = o.req("sigmas")?;
        let arr = v
            .as_arr()
            .ok_or_else(|| SpecError::new(o.path("sigmas"), "expected an array"))?;
        arr.iter()
            .enumerate()
            .map(|(i, sj)| {
                sj.as_f64().map(|x| x as f32).ok_or_else(|| {
                    SpecError::new(format!("{}[{i}]", o.path("sigmas")), "expected a number")
                })
            })
            .collect::<Result<Vec<_>, _>>()?
    };
    let sweep = SweepSpec {
        zs,
        local_steps,
        sigmas,
        client_lr: o.f32_or("client_lr", 0.01)?,
        server_lr: o.f32_or("server_lr", 1.0)?,
    };
    o.finish()?;
    Ok(sweep)
}

fn output_json(o: &OutputSpec) -> Json {
    jobj(vec![
        ("dir", jstr(&o.dir.to_string_lossy())),
        ("subtract_optimal", Json::Bool(o.subtract_optimal)),
    ])
}

fn output_from(j: &Json, at: &str) -> Result<OutputSpec, SpecError> {
    let o = Obj::new(j, at)?;
    let out = OutputSpec {
        dir: PathBuf::from(o.str_or("dir", "results")?),
        subtract_optimal: o.bool_or("subtract_optimal", false)?,
    };
    o.finish()?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seed_for_repeat_convention_pinned() {
        // The historical repeat-seed offset: base + 1000·r. CSV archives
        // depend on it — do not change without a migration note.
        assert_eq!(seed_for_repeat(0, 0), 0);
        assert_eq!(seed_for_repeat(7, 3), 3007);
        assert_eq!(seed_for_repeat(42, 1), 1042);
        // Wraps instead of panicking at the edge.
        assert_eq!(seed_for_repeat(u64::MAX, 1), 999);
    }

    fn tiny_spec() -> ExperimentSpec {
        ExperimentSpec::new("t", WorkloadSpec::consensus(4, 8, 99))
            .rounds(10)
            .series(AlgorithmConfig::gd().with_lrs(0.1, 1.0))
    }

    #[test]
    fn builder_defaults_mirror_server_config_default() {
        let spec = tiny_spec();
        let cfg = spec.server_config(0);
        let d = ServerConfig::default();
        assert_eq!(cfg.eval_every, d.eval_every);
        assert_eq!(cfg.seed, d.seed);
        assert_eq!(cfg.parallelism, d.parallelism);
        assert_eq!(cfg.reduce_lanes, d.reduce_lanes);
        assert!(cfg.clients_per_round.is_none());
        assert_eq!(spec.server_config(2).seed, seed_for_repeat(0, 2));
    }

    #[test]
    fn sweep_expansion_labels_follow_driver_convention() {
        let one_axis = SweepSpec {
            zs: vec![ZParam::Finite(1)],
            local_steps: vec![1],
            sigmas: vec![0.0, 0.5],
            client_lr: 0.01,
            server_lr: 1.0,
        };
        let s = one_axis.expand();
        assert_eq!(s.len(), 2);
        assert_eq!(s[0].label, "sigma0");
        assert_eq!(s[1].label, "sigma0.5");
        assert_eq!(s[0].display, "sigma = 0");

        let grid = SweepSpec {
            zs: vec![ZParam::Finite(1), ZParam::Inf],
            local_steps: vec![1, 5],
            sigmas: vec![0.5],
            client_lr: 0.01,
            server_lr: 1.0,
        };
        let g = grid.expand();
        assert_eq!(g.len(), 4);
        assert_eq!(g[0].label, "z1_e1_sigma0.5");
        assert_eq!(g[3].label, "zinf_e5_sigma0.5");
        assert_eq!(g[3].display, "z=inf E=5 sigma=0.5");
        assert_eq!(g[3].algorithm.local_steps, 5);
    }

    #[test]
    fn validate_accepts_good_and_reports_bad() {
        assert!(tiny_spec().validate().is_ok());

        let bad = ExperimentSpec::new("", WorkloadSpec::consensus(0, 0, 1)).rounds(0);
        let errs = bad.validate().unwrap_err();
        let ats: Vec<&str> = errs.iter().map(|e| e.at.as_str()).collect();
        assert!(ats.contains(&"name"), "{ats:?}");
        assert!(ats.contains(&"rounds"), "{ats:?}");
        assert!(ats.contains(&"workload.clients"), "{ats:?}");
        assert!(ats.contains(&"series"), "{ats:?}");
    }

    #[test]
    fn validate_rejects_ef_partial_participation() {
        // The engine would panic on this (paper §1.1); the spec refuses it
        // with a structured error instead.
        let spec = ExperimentSpec::new("t", WorkloadSpec::consensus(8, 4, 99))
            .clients_per_round(Some(4))
            .series(AlgorithmConfig::ef_signsgd());
        let errs = spec.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.reason.contains("EF-SignSGD")), "{errs:?}");

        // clients_per_round == population IS full participation — the
        // engine accepts it, so the spec must too.
        let full = ExperimentSpec::new("t", WorkloadSpec::consensus(8, 4, 99))
            .clients_per_round(Some(8))
            .series(AlgorithmConfig::ef_signsgd());
        assert!(full.validate().is_ok(), "{:?}", full.validate());
    }

    #[test]
    fn validate_rejects_bad_sparse_sign_sigma() {
        for sigma in [-5.0f32, f32::NAN] {
            let spec = ExperimentSpec::new("t", WorkloadSpec::consensus(4, 8, 99))
                .series(AlgorithmConfig::sparse_sign(0.1, ZParam::Finite(1), sigma, 1));
            let errs = spec.validate().unwrap_err();
            assert!(
                errs.iter().any(|e| e.at.ends_with("compression.sigma")),
                "sigma={sigma}: {errs:?}"
            );
        }
    }

    #[test]
    fn validate_rejects_cohort_larger_than_population() {
        let spec = ExperimentSpec::new("t", WorkloadSpec::consensus(4, 4, 99))
            .clients_per_round(Some(9))
            .series(AlgorithmConfig::gd());
        let errs = spec.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.at == "clients_per_round"), "{errs:?}");
    }

    #[test]
    fn json_roundtrip_minimal() {
        let spec = tiny_spec();
        let back = ExperimentSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
    }

    #[test]
    fn json_rejects_unknown_keys_but_allows_comments() {
        let good = r#"{"name":"t","_note":"a comment",
            "workload":{"kind":"consensus","clients":2,"dim":2,"_why":"x"},
            "series":[{"algorithm":{"name":"GD","compression":{"kind":"none"}}}]}"#;
        assert!(ExperimentSpec::from_json(good).is_ok());
        let bad = good.replace("\"_note\"", "\"rouns\"");
        let err = ExperimentSpec::from_json(&bad).unwrap_err();
        assert!(err.reason.contains("unknown field"), "{err}");
        assert_eq!(err.at, "rouns");
    }

    #[test]
    fn json_reports_field_paths() {
        let doc = r#"{"name":"t","workload":{"kind":"consensus","clients":2,"dim":2},
            "series":[{"algorithm":{"name":"x",
                "compression":{"kind":"qsgd"}}}]}"#;
        let err = ExperimentSpec::from_json(doc).unwrap_err();
        assert_eq!(err.at, "series[0].algorithm.compression.s");
        assert!(err.reason.contains("missing"), "{err}");
    }

    #[test]
    fn zparam_json_forms() {
        assert_eq!(zparam_from(&Json::parse("3").unwrap(), "z").unwrap(), ZParam::Finite(3));
        assert_eq!(zparam_from(&Json::parse("\"inf\"").unwrap(), "z").unwrap(), ZParam::Inf);
        assert!(zparam_from(&Json::parse("0").unwrap(), "z").is_err());
        assert!(zparam_from(&Json::parse("1.5").unwrap(), "z").is_err());
    }

    #[test]
    fn robust_json_round_trips_and_default_is_absent() {
        // Pre-robust spec files must stay byte-compatible: RobustRule::None
        // adds no key, and loading such a file yields None.
        let plain = tiny_spec();
        assert!(!plain.to_json().contains("robust"));
        assert_eq!(
            ExperimentSpec::from_json(&plain.to_json()).unwrap().series[0].algorithm.robust,
            RobustRule::None
        );

        let trimmed = tiny_spec().series(
            AlgorithmConfig::signsgd().with_robust(RobustRule::TrimmedMajority { frac: 0.25 }),
        );
        let json = trimmed.to_json();
        assert!(json.contains("trimmed_majority"), "{json}");
        assert_eq!(ExperimentSpec::from_json(&json).unwrap(), trimmed);

        let bad_rule = json.replace("\"trimmed_majority\"", "\"krum\"");
        let err = ExperimentSpec::from_json(&bad_rule).unwrap_err();
        assert!(err.at.ends_with("robust.rule"), "{err}");

        let oob = json.replace("\"frac\":0.25", "\"frac\":0.5");
        assert_ne!(oob, json, "replace must have rewritten the fraction");
        let err = ExperimentSpec::from_json(&oob).unwrap_err();
        assert!(err.at.ends_with("robust.frac"), "{err}");
    }

    #[test]
    fn transport_json_round_trips_every_variant() {
        for t in [
            TransportSpec::Engine,
            TransportSpec::Loopback,
            TransportSpec::tcp("127.0.0.1:7070"),
            TransportSpec::Tcp {
                addr: "0.0.0.0:0".into(),
                heartbeat_ms: 250,
                round_deadline_ms: 60_000,
                min_participants: 4,
            },
        ] {
            let spec = tiny_spec().transport(t);
            let back = ExperimentSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec);
        }
    }

    #[test]
    fn default_engine_transport_is_absent_from_json() {
        // Pre-service spec files must stay byte-compatible: the default
        // transport adds no key, and loading such a file yields Engine.
        let spec = tiny_spec();
        assert!(!spec.to_json().contains("transport"));
        let back = ExperimentSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.transport, TransportSpec::Engine);
    }

    #[test]
    fn tcp_transport_fills_timing_defaults() {
        let spec = tiny_spec().transport(TransportSpec::tcp("127.0.0.1:7070"));
        let json = spec.to_json().replace(
            r#""heartbeat_ms":500,"kind":"tcp","min_participants":1,"round_deadline_ms":10000"#,
            r#""kind":"tcp""#,
        );
        assert_ne!(json, spec.to_json(), "replace must have stripped the timing keys");
        let back = ExperimentSpec::from_json(&json).unwrap();
        assert_eq!(back.transport, TransportSpec::tcp("127.0.0.1:7070"));
    }

    #[test]
    fn transport_rejects_unknown_kind_and_keys() {
        let spec = tiny_spec().transport(TransportSpec::Loopback);
        let bad_kind = spec.to_json().replace("\"loopback\"", "\"carrier-pigeon\"");
        let err = ExperimentSpec::from_json(&bad_kind).unwrap_err();
        assert_eq!(err.at, "transport.kind");
        let bad_key = spec
            .to_json()
            .replace("\"kind\":\"loopback\"", "\"kind\":\"loopback\",\"adr\":\"x\"");
        let err = ExperimentSpec::from_json(&bad_key).unwrap_err();
        assert_eq!(err.at, "transport.adr");
        assert!(err.reason.contains("unknown field"), "{err}");
    }

    #[test]
    fn telemetry_json_round_trips_and_default_is_absent() {
        // Pre-telemetry spec files must stay byte-compatible.
        let spec = tiny_spec();
        assert!(!spec.to_json().contains("telemetry"));
        assert_eq!(
            ExperimentSpec::from_json(&spec.to_json()).unwrap().telemetry,
            TelemetrySpec::default()
        );
        for t in [
            TelemetrySpec::on(),
            TelemetrySpec { enabled: true, event_capacity: 64, dump_path: None },
            TelemetrySpec {
                enabled: true,
                event_capacity: 4096,
                dump_path: Some("metrics.prom".into()),
            },
            TelemetrySpec { enabled: false, event_capacity: 128, dump_path: None },
        ] {
            let spec = tiny_spec().telemetry(t.clone());
            let back = ExperimentSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back, spec, "{t:?}");
        }
        // A bare block means "on with defaults".
        let sparse = tiny_spec().to_json().replace(
            "\"output\":",
            "\"telemetry\":{},\"output\":",
        );
        let back = ExperimentSpec::from_json(&sparse).unwrap();
        assert_eq!(back.telemetry, TelemetrySpec::on());
    }

    #[test]
    fn validate_rejects_zero_capacity_enabled_telemetry() {
        let spec = tiny_spec().telemetry(TelemetrySpec {
            enabled: true,
            event_capacity: 0,
            dump_path: None,
        });
        let errs = spec.validate().unwrap_err();
        assert!(errs.iter().any(|e| e.at == "telemetry.event_capacity"), "{errs:?}");
        // Disabled telemetry does not care about the capacity.
        let off = tiny_spec().telemetry(TelemetrySpec {
            enabled: false,
            event_capacity: 0,
            dump_path: None,
        });
        assert!(off.validate().is_ok());
    }

    #[test]
    fn telemetry_spec_builds_the_matching_handle() {
        assert!(!TelemetrySpec::default().handle().is_enabled());
        assert!(TelemetrySpec::on().handle().is_enabled());
    }

    #[test]
    fn validate_rejects_degenerate_tcp_transport() {
        let spec = tiny_spec().transport(TransportSpec::Tcp {
            addr: String::new(),
            heartbeat_ms: 0,
            round_deadline_ms: 0,
            min_participants: 0,
        });
        let errs = spec.validate().unwrap_err();
        for at in [
            "transport.addr",
            "transport.heartbeat_ms",
            "transport.round_deadline_ms",
            "transport.min_participants",
        ] {
            assert!(errs.iter().any(|e| e.at == at), "missing {at}: {errs:?}");
        }
    }
}
