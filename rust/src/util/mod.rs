//! Small shared utilities: a mini-JSON parser/writer (the vendor set has no
//! serde) and summary statistics.
//!
//! Round timing lives in [`crate::telemetry::clock`] — an injectable
//! [`crate::telemetry::Clock`] rather than a raw `Instant` wrapper, so CI
//! byte-diff smokes can pin a deterministic wall_ms.

pub mod json;
pub mod stats;
