//! Small shared utilities: a mini-JSON parser/writer (the vendor set has no
//! serde), summary statistics, and a wall-clock timer.

pub mod json;
pub mod stats;

use std::time::Instant;

/// Simple scope timer for coarse profiling in drivers.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.start.elapsed().as_secs_f64() * 1e3
    }
}
