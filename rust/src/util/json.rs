//! Minimal JSON parser + writer.
//!
//! The offline vendor set has no serde, and the only JSON documents the
//! coordinator touches are small, schema-known files (the artifact manifest
//! and result records), so a compact recursive-descent parser is the right
//! tool. Supports the full JSON grammar except `\u` surrogate pairs beyond
//! the BMP (unused by our documents).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(format!("trailing bytes at offset {}", p.i));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at offset {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at offset {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at offset {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at offset {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape".to_string())?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| "bad \\u escape".to_string())?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at offset {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passthrough).
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| "invalid utf-8".to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(format!("expected ',' or ']' at offset {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at offset {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_manifest_like() {
        let doc = r#"{
         "version": 1,
         "artifacts": [
          {"name": "m_train", "file": "m.hlo.txt",
           "inputs": [{"name": "params", "dtype": "float32", "shape": [100]}],
           "outputs": [{"dtype": "float32", "shape": []}],
           "meta": {"param_count": 100, "eta_z": 1.2533141373155003}}
         ]}"#;
        let j = Json::parse(doc).unwrap();
        let arts = j.get("artifacts").unwrap().as_arr().unwrap();
        assert_eq!(arts[0].get("name").unwrap().as_str(), Some("m_train"));
        let meta = arts[0].get("meta").unwrap();
        assert!((meta.get("eta_z").unwrap().as_f64().unwrap() - 1.2533).abs() < 1e-3);
        assert_eq!(meta.get("param_count").unwrap().as_usize(), Some(100));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"x"],"b":{"c":true,"d":null}}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string_compact();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
    }
}
