//! Summary statistics shared by the metrics pipeline and the bench harness.

/// Running mean/variance (Welford) plus min/max.
#[derive(Debug, Clone, Default)]
pub struct Summary {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Summary {
    pub fn new() -> Self {
        Summary { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Sample variance (n-1 denominator).
    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Percentile of a sample (linear interpolation); `q` in [0, 1].
/// Sorts a copy — fine for bench-sized samples.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty());
    assert!((0.0..=1.0).contains(&q));
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Ordinary-least-squares slope of y over x (used by the empirical
/// convergence-rate fit in the Table 2 driver).
pub fn ols_slope(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2);
    let n = x.len() as f64;
    let mx = x.iter().sum::<f64>() / n;
    let my = y.iter().sum::<f64>() / n;
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for (&xi, &yi) in x.iter().zip(y) {
        sxy += (xi - mx) * (yi - my);
        sxx += (xi - mx) * (xi - mx);
    }
    sxy / sxx
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        let mut s = Summary::new();
        for &x in &xs {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        let direct_var = xs.iter().map(|x| (x - 5.0) * (x - 5.0)).sum::<f64>() / 7.0;
        assert!((s.var() - direct_var).abs() < 1e-12);
        assert_eq!(s.min(), 2.0);
        assert_eq!(s.max(), 9.0);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 0.5), 3.0);
        assert_eq!(percentile(&xs, 1.0), 5.0);
        assert!((percentile(&xs, 0.25) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn slope_recovers_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 1.0).collect();
        assert!((ols_slope(&x, &y) - 3.0).abs() < 1e-12);
    }
}
