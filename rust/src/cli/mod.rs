//! Hand-rolled CLI argument parsing (the vendor set has no clap).
//!
//! Grammar: `zsfa <subcommand> [--flag] [--key value] [positional...]`.
//! `--key value` pairs double as config overrides (see `config::Config`).
//!
//! Typed accessors are fallible: a malformed value (`--rounds nope`,
//! `--local-steps 1,x`) surfaces as a clean CLI error naming the flag —
//! never a panic — so drivers propagate it with `?`.

use crate::error::{Error, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
}

/// Marker value for boolean flags given without a value.
const FLAG_TRUE: &str = "true";

impl Args {
    /// Parse from an iterator of raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare `--` not supported".into());
                }
                // `--key=value` or `--key value` or bare boolean flag.
                if let Some((k, v)) = key.split_once('=') {
                    args.flags.insert(k.to_string(), v.to_string());
                } else {
                    let takes_value =
                        it.peek().map(|n| !n.starts_with("--")).unwrap_or(false);
                    if takes_value {
                        args.flags.insert(key.to_string(), it.next().unwrap());
                    } else {
                        args.flags.insert(key.to_string(), FLAG_TRUE.to_string());
                    }
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.flag(key).unwrap_or(default)
    }

    /// Parse one flag's value, reporting the flag name on failure.
    fn parse_typed<T: std::str::FromStr>(&self, key: &str, what: &str) -> Result<Option<T>> {
        match self.flag(key) {
            None => Ok(None),
            Some(s) => match s.parse::<T>() {
                Ok(v) => Ok(Some(v)),
                Err(_) => Err(Error::msg(format!("--{key}: bad {what} {s:?}"))),
            },
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.parse_typed(key, "integer")?.unwrap_or(default))
    }

    pub fn f32_or(&self, key: &str, default: f32) -> Result<f32> {
        Ok(self.parse_typed(key, "float")?.unwrap_or(default))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        Ok(self.parse_typed(key, "float")?.unwrap_or(default))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> Result<u64> {
        Ok(self.parse_typed(key, "integer")?.unwrap_or(default))
    }

    pub fn opt_usize(&self, key: &str) -> Result<Option<usize>> {
        self.parse_typed(key, "integer")
    }

    /// A comma-separated list flag (`--dims 10,100,1000`); `default` when
    /// the flag is absent, an error naming the bad element otherwise.
    pub fn list_or<T: std::str::FromStr + Clone>(
        &self,
        key: &str,
        default: &[T],
    ) -> Result<Vec<T>> {
        match self.flag(key) {
            None => Ok(default.to_vec()),
            Some(s) => s
                .split(',')
                .map(|v| {
                    let v = v.trim();
                    v.parse::<T>()
                        .map_err(|_| Error::msg(format!("--{key}: bad list element {v:?}")))
                })
                .collect(),
        }
    }

    /// The `--parallelism` knob shared by every experiment driver: worker
    /// threads for per-client round work (`ServerConfig::parallelism`).
    /// Results are bit-identical for any value (see `fl::engine`).
    pub fn parallelism_or(&self, default: usize) -> Result<usize> {
        self.usize_or("parallelism", default)
    }

    /// The `--reduce-lanes` knob: lanes of the fixed reduction topology
    /// (`ServerConfig::reduce_lanes`). Part of the reproducibility
    /// contract, like the seed — NOT a performance-only knob.
    pub fn reduce_lanes_or(&self, default: usize) -> Result<usize> {
        self.usize_or("reduce-lanes", default)
    }

    /// Apply all `--key value` pairs as config overrides.
    pub fn apply_overrides(&self, cfg: &mut crate::config::Config) {
        for (k, v) in &self.flags {
            cfg.set(k, v);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_positional() {
        let a = parse("fig1 extra1 extra2");
        assert_eq!(a.subcommand.as_deref(), Some("fig1"));
        assert_eq!(a.positional, vec!["extra1", "extra2"]);
    }

    #[test]
    fn flags_with_values() {
        let a = parse("run --rounds 100 --sigma=0.05 --verbose --seed 7");
        assert_eq!(a.usize_or("rounds", 0).unwrap(), 100);
        assert_eq!(a.f32_or("sigma", 0.0).unwrap(), 0.05);
        assert_eq!(a.f64_or("sigma", 0.0).unwrap(), 0.05);
        assert_eq!(a.f64_or("missing", 2.5).unwrap(), 2.5);
        assert!(a.has("verbose"));
        assert_eq!(a.str_or("verbose", "false"), "true");
        assert_eq!(a.u64_or("seed", 0).unwrap(), 7);
        assert_eq!(a.opt_usize("rounds").unwrap(), Some(100));
        assert_eq!(a.opt_usize("missing").unwrap(), None);
    }

    #[test]
    fn parallelism_flag() {
        assert_eq!(parse("run --parallelism 8").parallelism_or(1).unwrap(), 8);
        assert_eq!(parse("run").parallelism_or(1).unwrap(), 1);
    }

    #[test]
    fn list_flag_parses_and_defaults() {
        let a = parse("fig1 --dims 10,100, 1000");
        // note: "100, 1000" arrives as one whitespace-joined value only in
        // shells; here the flag value is "10,100," + positional "1000".
        let a2 = parse("fig1 --dims 10,100,1000");
        assert_eq!(a2.list_or::<usize>("dims", &[1]).unwrap(), vec![10, 100, 1000]);
        assert_eq!(a2.list_or::<usize>("missing", &[7, 8]).unwrap(), vec![7, 8]);
        assert!(a.list_or::<usize>("dims", &[1]).is_err()); // trailing comma
    }

    // -- one test per bad-input case: these used to panic ------------------

    #[test]
    fn bad_integer_flag_is_an_error_not_a_panic() {
        let a = parse("fig5 --rounds nope");
        let err = a.usize_or("rounds", 1).unwrap_err().to_string();
        assert!(err.contains("--rounds") && err.contains("nope"), "{err}");
    }

    #[test]
    fn bad_u64_flag_is_an_error_not_a_panic() {
        let a = parse("run --seed -3");
        assert!(a.u64_or("seed", 0).is_err());
    }

    #[test]
    fn bad_float_flag_is_an_error_not_a_panic() {
        let a = parse("fig2 --sigma abc");
        let err = a.f32_or("sigma", 0.0).unwrap_err().to_string();
        assert!(err.contains("--sigma"), "{err}");
        assert!(parse("fig2 --lr x").f64_or("lr", 0.0).is_err());
    }

    #[test]
    fn bad_local_steps_list_is_an_error_not_a_panic() {
        // The fig5 `--local-steps` path that used to `.parse().unwrap()`.
        let a = parse("fig5 --local-steps 1,x,3");
        let err = a.list_or::<usize>("local-steps", &[1]).unwrap_err().to_string();
        assert!(err.contains("--local-steps") && err.contains("\"x\""), "{err}");
    }

    #[test]
    fn overrides_into_config() {
        let a = parse("run --rounds 5");
        let mut cfg = crate::config::Config::new();
        a.apply_overrides(&mut cfg);
        assert_eq!(cfg.usize_or("rounds", 0).unwrap(), 5);
    }

    #[test]
    fn boolean_flag_before_subcommand_value() {
        let a = parse("--dry-run fig1");
        // "fig1" is consumed as the value of --dry-run by the grammar; the
        // driver CLI always places the subcommand first, which avoids this.
        assert_eq!(a.str_or("dry-run", ""), "fig1");
    }
}
