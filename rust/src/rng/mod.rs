//! Deterministic random-number substrate.
//!
//! The offline vendor set has no `rand` crate, so the whole stochastic stack
//! is built here from scratch:
//!
//! * [`Pcg64`] — PCG-XSL-RR 128/64, the same generator family NumPy uses; a
//!   small, fast, statistically solid PRNG with cheap splittable streams
//!   (every client/round gets its own stream, so experiments are exactly
//!   reproducible regardless of thread scheduling).
//! * Gaussian sampling (Box–Muller), Gamma sampling (Marsaglia–Tsang with
//!   the alpha < 1 boost), and the paper's **z-distribution** sampler
//!   (Definition 1): if `G ~ Gamma(1/(2z), 2)` then `±G^{1/(2z)}` has density
//!   proportional to `exp(-t^{2z}/2)`.
//!
//! `z` is encoded as `ZParam`: `Finite(z)` or `Inf` (uniform on [-1, 1]).

/// Noise-family parameter `z` of the paper's z-distribution.
///
/// `Finite(1)` is the standard Gaussian; `Inf` is Uniform[-1,1] (Lemma 2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ZParam {
    Finite(u32),
    Inf,
}

impl ZParam {
    /// Dequantization constant `eta_z = 2^{1/(2z)} * Gamma(1 + 1/(2z))`.
    /// `eta_inf = 1`.
    pub fn eta(self) -> f64 {
        match self {
            ZParam::Inf => 1.0,
            ZParam::Finite(z) => {
                let inv = 1.0 / (2.0 * z as f64);
                2f64.powf(inv) * gamma_fn(1.0 + inv)
            }
        }
    }

    /// Parse "1", "2", ..., "inf".
    pub fn parse(s: &str) -> Option<ZParam> {
        match s {
            "inf" | "Inf" | "INF" => Some(ZParam::Inf),
            _ => s.parse::<u32>().ok().filter(|z| *z >= 1).map(ZParam::Finite),
        }
    }
}

impl std::fmt::Display for ZParam {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ZParam::Finite(z) => write!(f, "{z}"),
            ZParam::Inf => write!(f, "inf"),
        }
    }
}

/// Lanczos approximation of the Gamma function (g = 7, n = 9), |rel err| < 1e-13
/// over the range used here (arguments in (0, 3]).
pub fn gamma_fn(x: f64) -> f64 {
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma_fn(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = COEF[0];
        let t = x + G + 0.5;
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

/// PCG-XSL-RR 128/64 pseudo-random generator.
#[derive(Debug, Clone)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// Cached second Box–Muller variate.
    gauss_spare: Option<f64>,
}

/// An exact capture of a [`Pcg64`]'s internal state — the checkpoint/
/// resume seam (`ckpt::`). Besides the 128-bit LCG state and increment it
/// carries the polar-method spare cache: a generator snapshotted after an
/// odd number of [`Pcg64::normal`] draws holds half an accepted pair, and
/// dropping it would silently shift every subsequent Gaussian draw.
///
/// The spare is stored as raw `f64` bits so the round trip is exact (and
/// so the snapshot can derive `Eq`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RngSnapshot {
    pub state: u128,
    pub inc: u128,
    /// `f64::to_bits` of the cached second polar variate, when parked.
    pub gauss_spare: Option<u64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

/// splitmix64: the standard 64-bit finalizer used to derive child seeds.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Pcg64 {
    /// Seeded constructor; `stream` selects an independent sequence.
    pub fn new(seed: u64, stream: u64) -> Self {
        let inc = (((stream as u128) << 64 | 0xda3e_39cb_94b9_5bdb) << 1) | 1;
        let mut rng = Pcg64 { state: 0, inc, gauss_spare: None };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(seed as u128);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Convenience: default stream 0.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Capture the generator's exact state, polar spare cache included
    /// (see [`RngSnapshot`]).
    pub fn state_snapshot(&self) -> RngSnapshot {
        RngSnapshot {
            state: self.state,
            inc: self.inc,
            gauss_spare: self.gauss_spare.map(f64::to_bits),
        }
    }

    /// Rebuild a generator from [`Pcg64::state_snapshot`]: the restored
    /// generator continues the captured one's draw stream bit for bit,
    /// including a spare parked mid polar pair.
    pub fn restore(snap: &RngSnapshot) -> Pcg64 {
        Pcg64 {
            state: snap.state,
            inc: snap.inc,
            gauss_spare: snap.gauss_spare.map(f64::from_bits),
        }
    }

    /// Derive an independent child stream (e.g. per client, per round).
    ///
    /// Both the seed *and* the increment are derived through splitmix64 so
    /// children differ in state, not just in the PCG increment — two PCG
    /// streams started from the same state with different increments are
    /// visibly correlated (their states differ by a constant), which showed
    /// up as an n-fold inflation of the server's sign-vote variance before
    /// this was fixed (see `split_streams_uncorrelated`).
    pub fn split(&self, stream: u64) -> Pcg64 {
        let base = (self.state >> 64) as u64 ^ self.state as u64;
        let seed = splitmix64(base ^ splitmix64(stream));
        Pcg64::new(seed, splitmix64(seed ^ !stream))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n || lo >= (n.wrapping_neg() % n) {
                return (m >> 64) as u64;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Standard normal via the Marsaglia polar method (cached pair).
    ///
    /// §Perf note: the polar method replaces Box–Muller's sin/cos with one
    /// rejection loop (acceptance ≈ π/4) and a single ln/sqrt — measured
    /// ~1.9× faster on this testbed, and the normal sampler dominates the
    /// Rust-side z=1 compression path (`bench_compress: stoch_sign_z1`).
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        let (a, b) = self.normal_pair();
        self.gauss_spare = Some(b);
        a
    }

    /// One polar-method rejection loop: both variates of the accepted pair,
    /// bypassing the spare cache. The stream contract (`fill_normal_f64`)
    /// depends on this being *exactly* the arithmetic `normal` performs.
    fn normal_pair(&mut self) -> (f64, f64) {
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s >= 1.0 || s == 0.0 {
                continue;
            }
            let k = (-2.0 * s.ln() / s).sqrt();
            return (u * k, v * k);
        }
    }

    /// Fill `out` with standard normals, bit-identical to `out.len()`
    /// sequential [`Pcg64::normal`] calls *including* the spare-cache
    /// semantics: an incoming cached spare is emitted first, and an odd
    /// tail leaves its partner cached for the next draw. This is the z = 1
    /// block fast path of the fused sign kernel — it writes accepted pairs
    /// straight into the buffer instead of round-tripping every second
    /// variate through the `Option` cache.
    pub fn fill_normal_f64(&mut self, out: &mut [f64]) {
        if out.is_empty() {
            return;
        }
        let mut i = 0usize;
        if let Some(s) = self.gauss_spare.take() {
            out[0] = s;
            i = 1;
        }
        while i + 2 <= out.len() {
            let (a, b) = self.normal_pair();
            out[i] = a;
            out[i + 1] = b;
            i += 2;
        }
        if i < out.len() {
            let (a, b) = self.normal_pair();
            out[i] = a;
            self.gauss_spare = Some(b);
        }
    }

    /// Block-fill `out` with i.i.d. z-distribution noise in f64, bit-identical
    /// to `out.len()` sequential [`Pcg64::z_noise`] calls (one draw per slot,
    /// in slot order — the fused sign kernel's RNG stream contract). The
    /// dispatch on `z` is hoisted out of the per-coordinate loop, and z = 1
    /// routes through the paired normal filler.
    pub fn fill_z_noise_f64(&mut self, z: ZParam, out: &mut [f64]) {
        match z {
            ZParam::Inf => {
                for o in out.iter_mut() {
                    *o = self.uniform_in(-1.0, 1.0);
                }
            }
            ZParam::Finite(1) => self.fill_normal_f64(out),
            ZParam::Finite(z) => {
                let inv = 1.0 / (2.0 * z as f64);
                for o in out.iter_mut() {
                    let g = self.gamma(inv, 2.0);
                    let mag = g.powf(inv);
                    *o = if self.next_u64() & 1 == 0 { mag } else { -mag };
                }
            }
        }
    }

    /// Gamma(shape, scale) via Marsaglia–Tsang; shape may be < 1.
    pub fn gamma(&mut self, shape: f64, scale: f64) -> f64 {
        assert!(shape > 0.0 && scale > 0.0);
        if shape < 1.0 {
            // Boost: Gamma(a) = Gamma(a+1) * U^{1/a}.
            let u = loop {
                let u = self.uniform();
                if u > 0.0 {
                    break u;
                }
            };
            return self.gamma(shape + 1.0, scale) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let (x, v) = loop {
                let x = self.normal();
                let v = 1.0 + c * x;
                if v > 0.0 {
                    break (x, v * v * v);
                }
            };
            let u = self.uniform();
            if u < 1.0 - 0.0331 * x.powi(4) {
                return d * v * scale;
            }
            if u > 0.0 && u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln()) {
                return d * v * scale;
            }
        }
    }

    /// Sample one variate from the paper's z-distribution `p_z ∝ exp(-t^{2z}/2)`.
    pub fn z_noise(&mut self, z: ZParam) -> f64 {
        match z {
            ZParam::Inf => self.uniform_in(-1.0, 1.0),
            ZParam::Finite(1) => self.normal(),
            ZParam::Finite(z) => {
                let inv = 1.0 / (2.0 * z as f64);
                let g = self.gamma(inv, 2.0);
                let mag = g.powf(inv);
                if self.next_u64() & 1 == 0 {
                    mag
                } else {
                    -mag
                }
            }
        }
    }

    /// Fill a buffer with i.i.d. z-distribution noise.
    pub fn fill_z_noise(&mut self, z: ZParam, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = self.z_noise(z) as f32;
        }
    }

    /// Fill with i.i.d. standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for o in out.iter_mut() {
            *o = self.normal() as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::seeded(42);
        let mut b = Pcg64::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_uncorrelated() {
        // The regression behind the FL variance bug: children split from the
        // same parent must produce (empirically) uncorrelated normals.
        let root = Pcg64::seeded(123);
        let n = 20_000;
        let mut a = root.split(1);
        let mut b = root.split(2);
        let mut dot = 0.0f64;
        for _ in 0..n {
            dot += a.normal() * b.normal();
        }
        let corr = dot / n as f64;
        assert!(corr.abs() < 0.03, "cross-stream correlation {corr}");
        // And the variance of a 10-child mean must shrink like 1/10.
        let mut children: Vec<Pcg64> = (0..10).map(|i| root.split(100 + i)).collect();
        let mut var_acc = 0.0;
        for _ in 0..n {
            let m: f64 = children.iter_mut().map(|c| c.normal()).sum::<f64>() / 10.0;
            var_acc += m * m;
        }
        let var = var_acc / n as f64;
        assert!((var - 0.1).abs() < 0.02, "mean-of-10 variance {var} (want ~0.1)");
    }

    #[test]
    fn streams_are_distinct() {
        let mut a = Pcg64::new(42, 0);
        let mut b = Pcg64::new(42, 1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut rng = Pcg64::seeded(1);
        for _ in 0..10_000 {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_is_unbiased_smoke() {
        let mut rng = Pcg64::seeded(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            // Each bin ~ 10_000; allow 5 sigma.
            assert!((c as f64 - 10_000.0).abs() < 5.0 * (10_000.0f64 * 6.0 / 7.0).sqrt());
        }
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::seeded(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn gamma_moments() {
        let mut rng = Pcg64::seeded(11);
        for &(shape, scale) in &[(0.25, 2.0), (1.0, 1.0), (4.5, 0.5)] {
            let n = 100_000;
            let mut s = 0.0;
            for _ in 0..n {
                s += rng.gamma(shape, scale);
            }
            let mean = s / n as f64;
            let want = shape * scale;
            assert!(
                (mean - want).abs() < 0.05 * want.max(0.2),
                "shape={shape} mean={mean} want={want}"
            );
        }
    }

    #[test]
    fn z1_noise_is_standard_normal() {
        let mut rng = Pcg64::seeded(13);
        let n = 100_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let x = rng.z_noise(ZParam::Finite(1));
            s2 += x * x;
        }
        assert!((s2 / n as f64 - 1.0).abs() < 0.03);
    }

    #[test]
    fn zinf_noise_is_uniform_pm1() {
        let mut rng = Pcg64::seeded(17);
        let n = 100_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let x = rng.z_noise(ZParam::Inf);
            assert!((-1.0..=1.0).contains(&x));
            s2 += x * x;
        }
        // Var of U[-1,1] = 1/3.
        assert!((s2 / n as f64 - 1.0 / 3.0).abs() < 0.01);
    }

    #[test]
    fn general_z_noise_symmetric_and_bounded_spread() {
        let mut rng = Pcg64::seeded(19);
        let n = 100_000;
        let mut pos = 0usize;
        let mut m2 = 0.0;
        for _ in 0..n {
            let x = rng.z_noise(ZParam::Finite(3));
            if x >= 0.0 {
                pos += 1;
            }
            m2 += x * x;
        }
        let frac = pos as f64 / n as f64;
        assert!((frac - 0.5).abs() < 0.01, "frac={frac}");
        // As z grows the distribution approaches U[-1,1]: variance in (1/3, 1).
        let var = m2 / n as f64;
        assert!(var > 0.3 && var < 1.0, "var={var}");
    }

    #[test]
    fn eta_z_values() {
        // eta_1 = sqrt(pi/2)
        assert!((ZParam::Finite(1).eta() - (std::f64::consts::PI / 2.0).sqrt()).abs() < 1e-10);
        assert_eq!(ZParam::Inf.eta(), 1.0);
        // decreasing towards 1
        let mut prev = f64::INFINITY;
        for z in [1u32, 2, 3, 5, 10, 100] {
            let e = ZParam::Finite(z).eta();
            assert!(e < prev && e > 1.0);
            prev = e;
        }
    }

    #[test]
    fn gamma_fn_known_values() {
        assert!((gamma_fn(1.0) - 1.0).abs() < 1e-10);
        assert!((gamma_fn(2.0) - 1.0).abs() < 1e-10);
        assert!((gamma_fn(5.0) - 24.0).abs() < 1e-8);
        assert!((gamma_fn(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn sample_without_replacement_distinct() {
        let mut rng = Pcg64::seeded(23);
        for _ in 0..100 {
            let s = rng.sample_without_replacement(50, 10);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), 10);
            assert!(sorted.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn fill_z_noise_f64_matches_sequential_draws() {
        // The fused-kernel stream contract: block filling must consume and
        // produce the exact scalar draw sequence, for every z family, across
        // lengths that exercise the pair filler's odd/even tails and an
        // incoming cached spare.
        for z in [ZParam::Finite(1), ZParam::Finite(2), ZParam::Finite(3), ZParam::Inf] {
            for warmup in [0usize, 1] {
                for len in [0usize, 1, 2, 63, 64, 65, 127, 130] {
                    let mut a = Pcg64::seeded(99);
                    let mut b = Pcg64::seeded(99);
                    // An odd number of normal() warm-up draws parks a spare.
                    for _ in 0..warmup {
                        let (x, y) = (a.normal(), b.normal());
                        assert_eq!(x.to_bits(), y.to_bits());
                    }
                    let want: Vec<f64> = (0..len).map(|_| a.z_noise(z)).collect();
                    let mut got = vec![0.0f64; len];
                    b.fill_z_noise_f64(z, &mut got);
                    for (j, (w, g)) in want.iter().zip(&got).enumerate() {
                        let (wb, gb) = (w.to_bits(), g.to_bits());
                        assert_eq!(wb, gb, "z={z} warmup={warmup} len={len} j={j}");
                    }
                    // And the generators must be left in identical states
                    // (spare cache included).
                    assert_eq!(a.normal().to_bits(), b.normal().to_bits(), "z={z} len={len} state");
                    assert_eq!(a.next_u64(), b.next_u64());
                }
            }
        }
    }

    #[test]
    fn snapshot_restore_continues_the_stream_mid_polar_pair() {
        // A generator snapshotted after an odd number of normal() draws
        // holds a cached polar spare; the restored generator must emit
        // that exact spare first and then track the original bit for bit
        // (the checkpoint/resume divergence hazard the snapshot exists
        // to close).
        for warmup in [0usize, 1, 3] {
            let mut a = Pcg64::new(2024, 7);
            for _ in 0..warmup {
                a.normal();
            }
            let snap = a.state_snapshot();
            assert_eq!(snap.gauss_spare.is_some(), warmup % 2 == 1, "warmup={warmup}");
            let mut b = Pcg64::restore(&snap);
            for j in 0..64 {
                assert_eq!(a.normal().to_bits(), b.normal().to_bits(), "warmup={warmup} j={j}");
                assert_eq!(a.next_u64(), b.next_u64(), "warmup={warmup} j={j}");
                assert_eq!(a.uniform().to_bits(), b.uniform().to_bits(), "warmup={warmup} j={j}");
            }
            // The walked generators stay in identical states, so the
            // snapshot round trip is exact at any point of the stream.
            assert_eq!(a.state_snapshot(), b.state_snapshot(), "warmup={warmup}");
        }
    }

    #[test]
    fn zparam_parse() {
        assert_eq!(ZParam::parse("1"), Some(ZParam::Finite(1)));
        assert_eq!(ZParam::parse("inf"), Some(ZParam::Inf));
        assert_eq!(ZParam::parse("0"), None);
        assert_eq!(ZParam::parse("x"), None);
    }
}
