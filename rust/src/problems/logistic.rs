//! Regularized logistic regression on heterogeneous synthetic data — the
//! non-quadratic convex testbed (smooth, bounded gradients, so every
//! assumption A.1–A.4 holds with explicit constants).

use super::AnalyticProblem;
use crate::rng::Pcg64;

/// f_i(x) = (1/mᵢ) Σ_k log(1 + exp(−y_k·⟨a_k, x⟩)) + (λ/2)‖x‖².
pub struct Logistic {
    clients: Vec<ClientData>,
    dim: usize,
    lambda: f32,
}

struct ClientData {
    a: Vec<f32>, // m × d row-major
    y: Vec<f32>, // ±1 labels
    m: usize,
}

impl Logistic {
    /// Each client draws features around a client-specific center (label
    /// skew + covariate shift), giving genuinely heterogeneous `f_i`.
    pub fn generate(n: usize, dim: usize, rows_per_client: usize, heterogeneity: f32,
                    lambda: f32, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let w_true: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let clients = (0..n)
            .map(|_| {
                let center: Vec<f32> =
                    (0..dim).map(|_| heterogeneity * rng.normal() as f32).collect();
                let mut a = vec![0.0f32; rows_per_client * dim];
                for r in 0..rows_per_client {
                    for j in 0..dim {
                        a[r * dim + j] = center[j] + rng.normal() as f32;
                    }
                }
                let y: Vec<f32> = (0..rows_per_client)
                    .map(|r| {
                        let row = &a[r * dim..(r + 1) * dim];
                        let mut s = 0.0f64;
                        for (ai, wi) in row.iter().zip(&w_true) {
                            s += *ai as f64 * *wi as f64;
                        }
                        // Noisy labels: flip with prob sigmoid(-|s|)/2.
                        let p_correct = 1.0 / (1.0 + (-s.abs()).exp());
                        let label = if s >= 0.0 { 1.0 } else { -1.0 };
                        if rng.uniform() < 1.0 - p_correct {
                            -label
                        } else {
                            label
                        }
                    })
                    .collect();
                ClientData { a, y, m: rows_per_client }
            })
            .collect();
        Logistic { clients, dim, lambda }
    }

    fn margin(&self, i: usize, x: &[f32], row: usize) -> f64 {
        let c = &self.clients[i];
        let a = &c.a[row * self.dim..(row + 1) * self.dim];
        let mut s = 0.0f64;
        for (ai, xi) in a.iter().zip(x) {
            s += *ai as f64 * *xi as f64;
        }
        s * c.y[row] as f64
    }

    fn add_row_grad(&self, i: usize, x: &[f32], row: usize, w: f64, out: &mut [f32]) {
        let c = &self.clients[i];
        let m = self.margin(i, x, row);
        // d/dx log(1+exp(-m)) = -sigmoid(-m) * y * a
        let coef = -w * c.y[row] as f64 / (1.0 + m.exp());
        let a = &c.a[row * self.dim..(row + 1) * self.dim];
        for (o, &ai) in out.iter_mut().zip(a) {
            *o += (coef * ai as f64) as f32;
        }
    }
}

impl AnalyticProblem for Logistic {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_clients(&self) -> usize {
        self.clients.len()
    }

    fn grad_into(&self, client: usize, x: &[f32], out: &mut [f32], rng: Option<&mut Pcg64>) {
        let c = &self.clients[client];
        // Regularizer first.
        for (o, &xi) in out.iter_mut().zip(x) {
            *o = self.lambda * xi;
        }
        match rng {
            None => {
                for r in 0..c.m {
                    self.add_row_grad(client, x, r, 1.0 / c.m as f64, out);
                }
            }
            Some(rng) => {
                let r = rng.below(c.m as u64) as usize;
                self.add_row_grad(client, x, r, 1.0, out);
            }
        }
    }

    fn objective(&self, x: &[f32]) -> f64 {
        let n = self.clients.len() as f64;
        let reg = 0.5 * self.lambda as f64 * crate::tensor::norm2_sq(x);
        let mut f = 0.0;
        for i in 0..self.clients.len() {
            let c = &self.clients[i];
            let mut s = 0.0;
            for r in 0..c.m {
                let m = self.margin(i, x, r);
                // log(1+exp(-m)), numerically stable.
                s += if m > 0.0 { (-m).exp().ln_1p() } else { -m + m.exp().ln_1p() };
            }
            f += s / c.m as f64;
        }
        f / n + reg
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gradient_matches_fd() {
        let p = Logistic::generate(2, 5, 12, 1.0, 0.01, 5);
        let x = vec![0.3f32; 5];
        let mut g = vec![0.0f32; 5];
        let mut gi = vec![0.0f32; 5];
        for i in 0..2 {
            p.grad_into(i, &x, &mut gi, None);
            crate::tensor::axpy(0.5, &gi, &mut g);
        }
        let h = 1e-3;
        for j in 0..5 {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let fd = (p.objective(&xp) - p.objective(&xm)) / (2.0 * h as f64);
            assert!((fd - g[j] as f64).abs() < 1e-3, "j={j}");
        }
    }

    #[test]
    fn gd_decreases_objective() {
        let p = Logistic::generate(4, 10, 20, 0.5, 0.01, 9);
        let mut x = vec![0.0f32; 10];
        let f0 = p.objective(&x);
        let mut g = vec![0.0f32; 10];
        let mut gi = vec![0.0f32; 10];
        for _ in 0..50 {
            g.iter_mut().for_each(|v| *v = 0.0);
            for i in 0..4 {
                p.grad_into(i, &x, &mut gi, None);
                crate::tensor::axpy(0.25, &gi, &mut g);
            }
            crate::tensor::axpy(-0.5, &g, &mut x);
        }
        assert!(p.objective(&x) < f0 * 0.9);
    }

    #[test]
    fn objective_is_finite_for_large_x() {
        let p = Logistic::generate(2, 4, 8, 0.0, 0.0, 1);
        let x = vec![100.0f32; 4];
        assert!(p.objective(&x).is_finite());
    }
}
