//! The consensus problem of the paper's §4.1 and the §1 counterexample.
//!
//! `min_x (w/2n) Σ_i ‖x − y_i‖²` with targets `y_i`. The optimum is the mean
//! of the targets and `f* = (w/2n) Σ_i ‖ȳ − y_i‖²`, so convergence can be
//! measured exactly. `w = 2` with `y = {A, −A}` reproduces the paper's
//! divergence counterexample `min (x−A)² + (x+A)²` up to the 1/n average.

use super::AnalyticProblem;
use crate::rng::Pcg64;

/// Quadratic consensus: f_i(x) = (w/2)·‖x − y_i‖².
pub struct Consensus {
    targets: Vec<Vec<f32>>, // n × d
    weight: f32,
}

impl Consensus {
    pub fn new(targets: Vec<Vec<f32>>, weight: f32) -> Self {
        assert!(!targets.is_empty());
        let d = targets[0].len();
        assert!(targets.iter().all(|t| t.len() == d));
        Consensus { targets, weight }
    }

    /// The paper's §4.1 instance: n clients, i.i.d. standard Gaussian targets.
    pub fn gaussian(n: usize, dim: usize, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let targets = (0..n)
            .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
        Consensus::new(targets, 1.0)
    }

    /// The §1 counterexample: `min (x−A)² + (x+A)²` as two clients in 1-D.
    pub fn counterexample(a: f32) -> Self {
        Consensus::new(vec![vec![a], vec![-a]], 2.0)
    }

    /// The minimizer ȳ = mean of targets.
    pub fn optimum(&self) -> Vec<f32> {
        let n = self.targets.len();
        let d = self.targets[0].len();
        let mut m = vec![0.0f32; d];
        for t in &self.targets {
            for (mi, &ti) in m.iter_mut().zip(t) {
                *mi += ti / n as f32;
            }
        }
        m
    }
}

impl AnalyticProblem for Consensus {
    fn dim(&self) -> usize {
        self.targets[0].len()
    }

    fn num_clients(&self) -> usize {
        self.targets.len()
    }

    fn grad_into(&self, client: usize, x: &[f32], out: &mut [f32], _rng: Option<&mut Pcg64>) {
        // ∇f_i(x) = w·(x − y_i); the problem is deterministic (full gradient),
        // matching the paper's "no minibatch SGD" setting for Fig. 1/2.
        let y = &self.targets[client];
        for ((o, &xi), &yi) in out.iter_mut().zip(x).zip(y) {
            *o = self.weight * (xi - yi);
        }
    }

    fn objective(&self, x: &[f32]) -> f64 {
        let n = self.targets.len() as f64;
        let mut f = 0.0;
        for t in &self.targets {
            let mut s = 0.0f64;
            for (&xi, &ti) in x.iter().zip(t) {
                s += (xi as f64 - ti as f64).powi(2);
            }
            f += 0.5 * self.weight as f64 * s;
        }
        f / n
    }

    fn optimal_value(&self) -> Option<f64> {
        Some(self.objective(&self.optimum()))
    }
}

#[cfg(test)]
mod tests {
    use crate::tensor;
    use super::*;

    #[test]
    fn optimum_is_stationary() {
        let p = Consensus::gaussian(10, 50, 7);
        let opt = p.optimum();
        assert!(p.grad_norm_sq(&opt) < 1e-10);
    }

    #[test]
    fn objective_at_optimum_matches() {
        let p = Consensus::gaussian(5, 20, 3);
        let f_star = p.optimal_value().unwrap();
        // Any other point is worse.
        let mut x = p.optimum();
        x[0] += 1.0;
        assert!(p.objective(&x) > f_star);
    }

    #[test]
    fn gradient_is_correct_fd() {
        let p = Consensus::gaussian(3, 8, 1);
        let x: Vec<f32> = (0..8).map(|i| 0.1 * i as f32).collect();
        let mut g = vec![0.0f32; 8];
        // global gradient = mean of client gradients
        let mut gi = vec![0.0f32; 8];
        for i in 0..3 {
            p.grad_into(i, &x, &mut gi, None);
            tensor::axpy(1.0 / 3.0, &gi, &mut g);
        }
        let h = 1e-3;
        for j in 0..8 {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let fd = (p.objective(&xp) - p.objective(&xm)) / (2.0 * h as f64);
            assert!((fd - g[j] as f64).abs() < 1e-3, "j={j}");
        }
    }

    #[test]
    fn counterexample_gradients_cancel_in_sign() {
        // For x in (-A, A): Sign(∇f_1) + Sign(∇f_2) = 0 — the §1 stall.
        let p = Consensus::counterexample(4.0);
        let x = [1.0f32];
        let mut g1 = [0.0f32];
        let mut g2 = [0.0f32];
        p.grad_into(0, &x, &mut g1, None);
        p.grad_into(1, &x, &mut g2, None);
        assert!(g1[0] < 0.0 && g2[0] > 0.0);
        let s = |v: f32| if v >= 0.0 { 1 } else { -1 };
        assert_eq!(s(g1[0]) + s(g2[0]), 0);
    }
}
