//! Distributed least squares with controllable heterogeneity and minibatch
//! noise — the convex testbed for the stochastic-gradient assumptions
//! (A.1/Assumption 2) and for rate fits where a non-trivial curvature is
//! wanted (the consensus problem has identity Hessian).

use super::AnalyticProblem;
use crate::rng::Pcg64;

/// f_i(x) = (1/2mᵢ)‖A_i x − b_i‖²; rows of A_i are N(0, I), and
/// `heterogeneity` shifts each client's ground-truth solution.
pub struct LeastSquares {
    blocks: Vec<Block>,
    dim: usize,
}

struct Block {
    a: Vec<f32>, // m × d, row-major
    b: Vec<f32>, // m
    m: usize,
}

impl LeastSquares {
    pub fn generate(n: usize, dim: usize, rows_per_client: usize, heterogeneity: f32,
                    noise: f32, seed: u64) -> Self {
        let mut rng = Pcg64::seeded(seed);
        let x_shared: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        let blocks = (0..n)
            .map(|_| {
                // Client-specific ground truth: shared + heterogeneity * shift.
                let x_i: Vec<f32> = x_shared
                    .iter()
                    .map(|&s| s + heterogeneity * rng.normal() as f32)
                    .collect();
                let mut a = vec![0.0f32; rows_per_client * dim];
                rng.fill_normal(&mut a);
                let b: Vec<f32> = (0..rows_per_client)
                    .map(|r| {
                        let row = &a[r * dim..(r + 1) * dim];
                        let mut y = 0.0f64;
                        for (ai, xi) in row.iter().zip(&x_i) {
                            y += *ai as f64 * *xi as f64;
                        }
                        (y + noise as f64 * rng.normal()) as f32
                    })
                    .collect();
                Block { a, b, m: rows_per_client }
            })
            .collect();
        LeastSquares { blocks, dim }
    }

    fn residual(&self, i: usize, x: &[f32], row: usize) -> f64 {
        let blk = &self.blocks[i];
        let a = &blk.a[row * self.dim..(row + 1) * self.dim];
        let mut r = -(blk.b[row] as f64);
        for (ai, xi) in a.iter().zip(x) {
            r += *ai as f64 * *xi as f64;
        }
        r
    }
}

impl AnalyticProblem for LeastSquares {
    fn dim(&self) -> usize {
        self.dim
    }

    fn num_clients(&self) -> usize {
        self.blocks.len()
    }

    fn grad_into(&self, client: usize, x: &[f32], out: &mut [f32], rng: Option<&mut Pcg64>) {
        let blk = &self.blocks[client];
        out.iter_mut().for_each(|o| *o = 0.0);
        match rng {
            None => {
                // Full gradient: (1/m) Aᵀ(Ax − b).
                for r in 0..blk.m {
                    let res = self.residual(client, x, r) / blk.m as f64;
                    let a = &blk.a[r * self.dim..(r + 1) * self.dim];
                    for (o, &ai) in out.iter_mut().zip(a) {
                        *o += (res * ai as f64) as f32;
                    }
                }
            }
            Some(rng) => {
                // Single-row minibatch: unbiased with bounded variance (the
                // rows are Gaussian, so all moments in Assumption 2 exist).
                let r = rng.below(blk.m as u64) as usize;
                let res = self.residual(client, x, r);
                let a = &blk.a[r * self.dim..(r + 1) * self.dim];
                for (o, &ai) in out.iter_mut().zip(a) {
                    *o = (res * ai as f64) as f32;
                }
            }
        }
    }

    fn objective(&self, x: &[f32]) -> f64 {
        let n = self.blocks.len() as f64;
        let mut f = 0.0;
        for i in 0..self.blocks.len() {
            let blk = &self.blocks[i];
            let mut s = 0.0;
            for r in 0..blk.m {
                let res = self.residual(i, x, r);
                s += res * res;
            }
            f += 0.5 * s / blk.m as f64;
        }
        f / n
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_gradient_matches_fd() {
        let p = LeastSquares::generate(3, 6, 10, 0.5, 0.1, 42);
        let x = vec![0.2f32; 6];
        let mut g = vec![0.0f32; 6];
        let mut gi = vec![0.0f32; 6];
        for i in 0..3 {
            p.grad_into(i, &x, &mut gi, None);
            crate::tensor::axpy(1.0 / 3.0, &gi, &mut g);
        }
        let h = 1e-3;
        for j in 0..6 {
            let mut xp = x.clone();
            let mut xm = x.clone();
            xp[j] += h;
            xm[j] -= h;
            let fd = (p.objective(&xp) - p.objective(&xm)) / (2.0 * h as f64);
            assert!((fd - g[j] as f64).abs() < 2e-2 * (1.0 + fd.abs()), "j={j}: {fd} vs {}", g[j]);
        }
    }

    #[test]
    fn minibatch_gradient_is_unbiased() {
        let p = LeastSquares::generate(1, 4, 8, 0.0, 0.0, 7);
        let x = vec![0.1f32; 4];
        let mut full = vec![0.0f32; 4];
        p.grad_into(0, &x, &mut full, None);
        let mut rng = Pcg64::seeded(1);
        let reps = 40_000;
        let mut acc = vec![0.0f64; 4];
        let mut g = vec![0.0f32; 4];
        for _ in 0..reps {
            p.grad_into(0, &x, &mut g, Some(&mut rng));
            for (a, &gi) in acc.iter_mut().zip(&g) {
                *a += gi as f64;
            }
        }
        for j in 0..4 {
            let est = acc[j] / reps as f64;
            assert!((est - full[j] as f64).abs() < 0.05, "j={j}: {est} vs {}", full[j]);
        }
    }

    #[test]
    fn heterogeneity_changes_client_optima() {
        let p = LeastSquares::generate(2, 5, 30, 2.0, 0.0, 3);
        // Gradients at the same point should differ across clients.
        let x = vec![0.0f32; 5];
        let mut g0 = vec![0.0f32; 5];
        let mut g1 = vec![0.0f32; 5];
        p.grad_into(0, &x, &mut g0, None);
        p.grad_into(1, &x, &mut g1, None);
        let diff: f64 = g0.iter().zip(&g1).map(|(a, b)| (a - b).abs() as f64).sum();
        assert!(diff > 0.5, "diff={diff}");
    }
}
