//! Analytic distributed-optimization problems.
//!
//! The paper's §4.1 experiments (Fig. 1/2) and the §1 divergence
//! counterexample run on closed-form objectives where gradients are exact;
//! no XLA graph is involved. These problems also power the integration tests
//! and the empirical convergence-rate fits of the Table 2 driver, because
//! their optima are known exactly.

pub mod consensus;
pub mod least_squares;
pub mod logistic;

use crate::rng::Pcg64;

/// A distributed problem `f(x) = (1/n) Σ_i f_i(x)` with analytic gradients.
pub trait AnalyticProblem: Send + Sync {
    /// Problem dimension d.
    fn dim(&self) -> usize;

    /// Number of clients n.
    fn num_clients(&self) -> usize;

    /// Write ∇f_i(x) (or a minibatch estimate when `rng` is provided and the
    /// problem is stochastic) into `out`.
    fn grad_into(&self, client: usize, x: &[f32], out: &mut [f32], rng: Option<&mut Pcg64>);

    /// Global objective f(x).
    fn objective(&self, x: &[f32]) -> f64;

    /// Squared l2-norm of the global gradient ‖∇f(x)‖² (the paper's
    /// convergence metric).
    fn grad_norm_sq(&self, x: &[f32]) -> f64 {
        let d = self.dim();
        let n = self.num_clients();
        let mut g = vec![0.0f32; d];
        let mut gi = vec![0.0f32; d];
        for i in 0..n {
            self.grad_into(i, x, &mut gi, None);
            for (a, &b) in g.iter_mut().zip(&gi) {
                *a += b / n as f32;
            }
        }
        crate::tensor::norm2_sq(&g)
    }

    /// f* when known in closed form.
    fn optimal_value(&self) -> Option<f64> {
        None
    }
}
