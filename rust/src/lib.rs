//! # z-SignFedAvg
//!
//! A production-quality reproduction of *"z-SignFedAvg: A Unified Stochastic
//! Sign-based Compression for Federated Learning"* (Tang, Wang, Chang — AAAI
//! 2024) as a three-layer Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the federated-learning coordinator: round
//!   loop, client sampling, the 1-bit sign wire codec, vote aggregation,
//!   plateau noise-scale controller, DP accountant, metrics.
//! * **Layer 2 (`python/compile/model.py`)** — JAX model fwd/bwd + the
//!   compression entry points, AOT-lowered to HLO text.
//! * **Layer 1 (`python/compile/kernels/`)** — Pallas kernels for the
//!   stochastic-sign compressor and the fused SGD update.
//!
//! After `make artifacts`, the `zsfa` binary is self-contained: it loads the
//! HLO artifacts through PJRT (the `xla` crate) and never touches Python.
//!
//! Experiments are described by the typed, JSON-serializable
//! [`api::ExperimentSpec`] and executed by an observer-driven
//! [`api::Session`] (`zsfa run spec.json`); the `repro::fig*` drivers are
//! thin spec factories over the same seam. The same spec can run
//! networked: [`service`] hosts the round loop behind a coordinator state
//! machine with loopback/TCP transports (`zsfa serve` / `zsfa join`),
//! selected by the spec's [`api::TransportSpec`]. Long sessions are
//! crash-tolerant: [`ckpt`] snapshots the full round-loop state to a
//! checksummed binary file and `zsfa resume` recovers byte-identically.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every figure/table of the paper to a driver.

pub mod api;
pub mod bench;
pub mod cli;
pub mod ckpt;
pub mod compress;
pub mod config;
pub mod data;
pub mod dp;
pub mod error;
pub mod fl;
pub mod net;
pub mod problems;
pub mod repro;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod telemetry;
pub mod tensor;
pub mod testutil;
pub mod util;

pub use error::{Error, ErrorKind, Result};

/// Crate version (mirrors Cargo.toml).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
