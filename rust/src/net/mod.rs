//! Communication-time simulation.
//!
//! The paper's whole pitch is communication cost, so the drivers report a
//! *simulated wall-clock* axis alongside rounds and bits: given a link
//! model (uplink/downlink bandwidth + per-round latency) and the exact bit
//! counts the coordinator recorded, this module turns a run into a
//! time-to-accuracy series — the figure real FL deployments care about.
//!
//! The model is deliberately simple and standard (cf. FedScale-style
//! simulators): per round,
//!
//! ```text
//! t_round = latency
//!         + max_i(uplink_bits_i) / uplink_bps      (slowest uploader gates)
//!         + downlink_bits / downlink_bps
//!         + compute_time
//! ```
//!
//! With uniform client payloads (every algorithm here sends equal-size
//! messages per round), max_i = per-client bits.

use crate::fl::metrics::{RoundRecord, RunResult};

/// A symmetric-ish WAN link model.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Client upload bandwidth, bits/second.
    pub uplink_bps: f64,
    /// Server broadcast bandwidth per client, bits/second.
    pub downlink_bps: f64,
    /// Fixed per-round latency (connection setup + straggler slack), seconds.
    pub latency_s: f64,
    /// Client compute seconds per round (E local steps).
    pub compute_s: f64,
}

impl LinkModel {
    /// A typical cross-device FL profile: 10 Mbit/s up, 50 Mbit/s down,
    /// 300 ms round latency.
    pub fn cross_device() -> Self {
        LinkModel { uplink_bps: 10e6, downlink_bps: 50e6, latency_s: 0.3, compute_s: 0.5 }
    }

    /// A datacenter profile: 10 Gbit/s symmetric, 5 ms latency.
    pub fn datacenter() -> Self {
        LinkModel { uplink_bps: 10e9, downlink_bps: 10e9, latency_s: 0.005, compute_s: 0.1 }
    }
}

/// One simulated point: cumulative seconds + the record it corresponds to.
#[derive(Debug, Clone, Copy)]
pub struct TimedRecord {
    pub sim_time_s: f64,
    pub record: RoundRecord,
}

/// Replay a run through the link model.
///
/// `clients_per_round` must match the experiment (bits are totals across
/// participants; the model needs per-client payloads).
pub fn simulate_timeline(
    run: &RunResult,
    link: &LinkModel,
    clients_per_round: usize,
) -> Vec<TimedRecord> {
    assert!(clients_per_round >= 1);
    let mut out = Vec::with_capacity(run.records.len());
    let mut prev_up = 0u64;
    let mut prev_down = 0u64;
    let mut prev_round = 0usize;
    let mut t = 0.0f64;
    for rec in &run.records {
        // Bits accrued since the previous *evaluated* record, averaged over
        // the rounds in between (records may be sparse when eval_every > 1).
        let rounds = (rec.round + 1).saturating_sub(prev_round).max(1);
        let up_per_client_round =
            (rec.bits_up - prev_up) as f64 / (rounds * clients_per_round) as f64;
        let down_per_client_round =
            (rec.bits_down - prev_down) as f64 / (rounds * clients_per_round) as f64;
        let per_round = link.latency_s
            + up_per_client_round / link.uplink_bps
            + down_per_client_round / link.downlink_bps
            + link.compute_s;
        t += per_round * rounds as f64;
        prev_up = rec.bits_up;
        prev_down = rec.bits_down;
        prev_round = rec.round + 1;
        out.push(TimedRecord { sim_time_s: t, record: *rec });
    }
    out
}

/// Simulated seconds to first reach `target` accuracy (None if never).
pub fn time_to_accuracy(timeline: &[TimedRecord], target: f64) -> Option<f64> {
    timeline
        .iter()
        .find(|t| t.record.accuracy.map(|a| a >= target).unwrap_or(false))
        .map(|t| t.sim_time_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_run(bits_per_round_up: u64, bits_per_round_down: u64, accs: &[f64]) -> RunResult {
        RunResult {
            algorithm: "x".into(),
            records: accs
                .iter()
                .enumerate()
                .map(|(i, &a)| RoundRecord {
                    round: i,
                    objective: 1.0,
                    accuracy: Some(a),
                    grad_norm_sq: None,
                    bits_up: bits_per_round_up * (i as u64 + 1),
                    bits_down: bits_per_round_down * (i as u64 + 1),
                    sigma: 0.0,
                    wall_ms: 0.0,
                })
                .collect(),
        }
    }

    #[test]
    fn round_time_decomposes() {
        // 1 client, 1e6 bits up per round @1e6 bps = 1 s, latency 0.5, no
        // compute, downlink free.
        let link =
            LinkModel { uplink_bps: 1e6, downlink_bps: 1e12, latency_s: 0.5, compute_s: 0.0 };
        let run = mk_run(1_000_000, 0, &[0.1, 0.2, 0.3]);
        let tl = simulate_timeline(&run, &link, 1);
        assert!((tl[0].sim_time_s - 1.5).abs() < 1e-9);
        assert!((tl[2].sim_time_s - 4.5).abs() < 1e-9);
    }

    #[test]
    fn compression_wins_time_to_accuracy() {
        // Same accuracy trajectory, 32x fewer bits -> much earlier target hit
        // on a slow uplink.
        let link = LinkModel { uplink_bps: 1e6, downlink_bps: 1e9, latency_s: 0.0, compute_s: 0.0 };
        let accs = [0.1, 0.5, 0.9];
        let dense = simulate_timeline(&mk_run(32_000_000, 0, &accs), &link, 1);
        let signs = simulate_timeline(&mk_run(1_000_000, 0, &accs), &link, 1);
        let td = time_to_accuracy(&dense, 0.9).unwrap();
        let ts = time_to_accuracy(&signs, 0.9).unwrap();
        assert!((td / ts - 32.0).abs() < 1e-6, "{td} vs {ts}");
    }

    #[test]
    fn target_never_reached() {
        let link = LinkModel::cross_device();
        let tl = simulate_timeline(&mk_run(1000, 1000, &[0.1, 0.2]), &link, 1);
        assert!(time_to_accuracy(&tl, 0.99).is_none());
    }

    #[test]
    fn presets_sane() {
        assert!(LinkModel::cross_device().uplink_bps < LinkModel::datacenter().uplink_bps);
    }
}
