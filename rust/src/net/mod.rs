//! Communication-time replay of *finished* runs.
//!
//! The paper's whole pitch is communication cost, so the drivers report a
//! *simulated wall-clock* axis alongside rounds and bits: given a link
//! model (uplink/downlink bandwidth + per-round latency) and the exact bit
//! counts the coordinator recorded, this module turns a run into a
//! time-to-accuracy series — the figure real FL deployments care about.
//!
//! The model is standard (cf. FedScale-style simulators): per round,
//!
//! ```text
//! t_round = latency
//!         + max_i(uplink_bits_i) / uplink_bps      (slowest uploader gates)
//!         + downlink_bits / downlink_bps
//!         + compute_time
//! ```
//!
//! [`replay`] takes explicit **per-client** payloads ([`RoundLoad`]) and
//! finds the gating upload by draining a `sim::EventQueue` — the same
//! scheduler that plans live scenario rounds. Loads come from one of two
//! builders over the aggregator-recorded bit counters: [`uniform_loads`]
//! (an explicit uniform-payload assumption over `clients_per_round`) or
//! [`arrival_loads`] (bits divided across the clients that *actually
//! arrived* each record, billing empty rounds zero). The historical
//! `simulate_timeline` shim — which hard-wired the even split — is gone;
//! its callers route through `replay` directly.
//!
//! For rounds simulated *while they run* — heterogeneous devices, report
//! deadlines, dropouts — see `sim::ScenarioPolicy`; its timeline lands in
//! `RoundRecord::sim_time_s` directly and needs no replay.

use crate::fl::metrics::{RoundRecord, RunResult};
use crate::sim::EventQueue;

/// A symmetric-ish WAN link model.
#[derive(Debug, Clone, Copy)]
pub struct LinkModel {
    /// Client upload bandwidth, bits/second.
    pub uplink_bps: f64,
    /// Server broadcast bandwidth per client, bits/second.
    pub downlink_bps: f64,
    /// Fixed per-round latency (connection setup + straggler slack), seconds.
    pub latency_s: f64,
    /// Client compute seconds per round (E local steps).
    pub compute_s: f64,
}

impl LinkModel {
    /// A typical cross-device FL profile: 10 Mbit/s up, 50 Mbit/s down,
    /// 300 ms round latency.
    pub fn cross_device() -> Self {
        LinkModel { uplink_bps: 10e6, downlink_bps: 50e6, latency_s: 0.3, compute_s: 0.5 }
    }

    /// A datacenter profile: 10 Gbit/s symmetric, 5 ms latency.
    pub fn datacenter() -> Self {
        LinkModel { uplink_bps: 10e9, downlink_bps: 10e9, latency_s: 0.005, compute_s: 0.1 }
    }
}

/// One simulated point: cumulative seconds + the record it corresponds to.
#[derive(Debug, Clone, Copy)]
pub struct TimedRecord {
    pub sim_time_s: f64,
    pub record: RoundRecord,
}

/// Per-client payloads for the rounds one record covers.
///
/// Bits are `f64` because a record spanning several rounds (eval_every > 1)
/// carries *average* per-round payloads, which need not be whole bits.
#[derive(Debug, Clone)]
pub struct RoundLoad {
    /// Uplink bits per participating client; the slowest uploader gates
    /// the round. One entry per participant.
    pub up_bits: Vec<f64>,
    /// Broadcast bits each client downloads.
    pub down_bits: f64,
}

/// Replay a run through the link model with explicit per-client payloads —
/// `loads[i]` describes the rounds covered by `run.records[i]`.
///
/// The upload phase pushes every client's completion through the event
/// queue and takes the last arrival, so heterogeneous payloads are gated
/// by the slowest uploader instead of a (wrong) even split.
pub fn replay(run: &RunResult, link: &LinkModel, loads: &[RoundLoad]) -> Vec<TimedRecord> {
    assert_eq!(loads.len(), run.records.len(), "one RoundLoad per record");
    let mut out = Vec::with_capacity(run.records.len());
    let mut prev_round = 0usize;
    let mut t = 0.0f64;
    for (rec, load) in run.records.iter().zip(loads) {
        // Rounds since the previous *evaluated* record (records may be
        // sparse when eval_every > 1).
        let rounds = (rec.round + 1).saturating_sub(prev_round).max(1);
        let mut q: EventQueue<usize> = EventQueue::new();
        for (i, &bits) in load.up_bits.iter().enumerate() {
            q.schedule(bits / link.uplink_bps, i);
        }
        let mut upload_s = 0.0;
        while let Some((at, _)) = q.pop() {
            upload_s = at;
        }
        let per_round = link.latency_s
            + upload_s
            + load.down_bits / link.downlink_bps
            + link.compute_s;
        t += per_round * rounds as f64;
        prev_round = rec.round + 1;
        out.push(TimedRecord { sim_time_s: t, record: *rec });
    }
    out
}

/// Even-split [`RoundLoad`]s from a run's aggregate bit counters — the
/// uniform-payload assumption, stated explicitly.
pub fn uniform_loads(run: &RunResult, clients_per_round: usize) -> Vec<RoundLoad> {
    assert!(clients_per_round >= 1);
    let mut prev_up = 0u64;
    let mut prev_down = 0u64;
    let mut prev_round = 0usize;
    run.records
        .iter()
        .map(|rec| {
            let rounds = (rec.round + 1).saturating_sub(prev_round).max(1);
            let up = (rec.bits_up - prev_up) as f64 / (rounds * clients_per_round) as f64;
            let down = (rec.bits_down - prev_down) as f64 / (rounds * clients_per_round) as f64;
            prev_up = rec.bits_up;
            prev_down = rec.bits_down;
            prev_round = rec.round + 1;
            RoundLoad { up_bits: vec![up; clients_per_round], down_bits: down }
        })
        .collect()
}

/// [`RoundLoad`]s from the aggregator's recorded tallies: each record's
/// bit deltas are divided across the clients that **actually arrived**
/// (`RoundRecord::arrived`), so partial rounds bill their real cohort and
/// empty rounds bill zero transfer time (latency + compute only — the
/// record's counters still advance, the unattributable bits are simply not
/// charged as link time). Records spanning several rounds (eval_every > 1)
/// use the last round's arrival count as the per-round cohort — exact
/// under uniform participation, an approximation under scenarios.
pub fn arrival_loads(run: &RunResult) -> Vec<RoundLoad> {
    let mut prev_up = 0u64;
    let mut prev_down = 0u64;
    let mut prev_round = 0usize;
    run.records
        .iter()
        .map(|rec| {
            let rounds = (rec.round + 1).saturating_sub(prev_round).max(1);
            let up_delta = (rec.bits_up - prev_up) as f64 / rounds as f64;
            let down_delta = (rec.bits_down - prev_down) as f64 / rounds as f64;
            prev_up = rec.bits_up;
            prev_down = rec.bits_down;
            prev_round = rec.round + 1;
            let m = rec.arrived as usize;
            if m == 0 {
                // No per-client attribution exists; `down_bits` is a
                // *per-client* payload everywhere else, so billing the raw
                // cohort total here would inflate the round ~m-fold.
                RoundLoad { up_bits: Vec::new(), down_bits: 0.0 }
            } else {
                RoundLoad {
                    up_bits: vec![up_delta / m as f64; m],
                    down_bits: down_delta / m as f64,
                }
            }
        })
        .collect()
}

/// Simulated seconds to first reach `target` accuracy (None if never).
pub fn time_to_accuracy(timeline: &[TimedRecord], target: f64) -> Option<f64> {
    timeline
        .iter()
        .find(|t| t.record.accuracy.map(|a| a >= target).unwrap_or(false))
        .map(|t| t.sim_time_s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_run(bits_per_round_up: u64, bits_per_round_down: u64, accs: &[f64]) -> RunResult {
        RunResult {
            algorithm: "x".into(),
            records: accs
                .iter()
                .enumerate()
                .map(|(i, &a)| RoundRecord {
                    round: i,
                    objective: 1.0,
                    accuracy: Some(a),
                    grad_norm_sq: None,
                    bits_up: bits_per_round_up * (i as u64 + 1),
                    bits_down: bits_per_round_down * (i as u64 + 1),
                    sigma: 0.0,
                    wall_ms: 0.0,
                    sim_time_s: 0.0,
                    arrived: 1,
                    selected: 1,
                    degraded: false,
                })
                .collect(),
        }
    }

    #[test]
    fn round_time_decomposes() {
        // 1 client, 1e6 bits up per round @1e6 bps = 1 s, latency 0.5, no
        // compute, downlink free.
        let link =
            LinkModel { uplink_bps: 1e6, downlink_bps: 1e12, latency_s: 0.5, compute_s: 0.0 };
        let run = mk_run(1_000_000, 0, &[0.1, 0.2, 0.3]);
        let tl = replay(&run, &link, &uniform_loads(&run, 1));
        assert!((tl[0].sim_time_s - 1.5).abs() < 1e-9);
        assert!((tl[2].sim_time_s - 4.5).abs() < 1e-9);
    }

    #[test]
    fn compression_wins_time_to_accuracy() {
        // Same accuracy trajectory, 32x fewer bits -> much earlier target hit
        // on a slow uplink.
        let link = LinkModel { uplink_bps: 1e6, downlink_bps: 1e9, latency_s: 0.0, compute_s: 0.0 };
        let accs = [0.1, 0.5, 0.9];
        let dense_run = mk_run(32_000_000, 0, &accs);
        let sign_run = mk_run(1_000_000, 0, &accs);
        let dense = replay(&dense_run, &link, &uniform_loads(&dense_run, 1));
        let signs = replay(&sign_run, &link, &uniform_loads(&sign_run, 1));
        let td = time_to_accuracy(&dense, 0.9).unwrap();
        let ts = time_to_accuracy(&signs, 0.9).unwrap();
        assert!((td / ts - 32.0).abs() < 1e-6, "{td} vs {ts}");
    }

    #[test]
    fn heterogeneous_payloads_gate_on_slowest() {
        // 1 Mbit total over 4 clients @1 Mbit/s: the even split claims
        // 0.25 s/round, but a 750k/250k/0/0 split is gated at 0.75 s —
        // exactly the error the retired uniform-payload shim baked in.
        let link =
            LinkModel { uplink_bps: 1e6, downlink_bps: 1e12, latency_s: 0.0, compute_s: 0.0 };
        let run = mk_run(1_000_000, 0, &[0.5]);
        let even = replay(&run, &link, &uniform_loads(&run, 4));
        assert!((even[0].sim_time_s - 0.25).abs() < 1e-9);
        let loads =
            vec![RoundLoad { up_bits: vec![750_000.0, 250_000.0, 0.0, 0.0], down_bits: 0.0 }];
        let het = replay(&run, &link, &loads);
        assert!((het[0].sim_time_s - 0.75).abs() < 1e-9, "{}", het[0].sim_time_s);
    }

    #[test]
    fn arrival_loads_bill_actual_cohorts() {
        // Round 0: 4 arrivals, 1 Mbit total. Round 1: an empty round — no
        // uplink delta, zero clients to bill. Round 2: 2 arrivals, 1 Mbit.
        let mut run = mk_run(0, 1000, &[0.1, 0.2, 0.3]);
        run.records[0].arrived = 4;
        run.records[0].bits_up = 1_000_000;
        run.records[1].arrived = 0;
        run.records[1].bits_up = 1_000_000;
        run.records[2].arrived = 2;
        run.records[2].bits_up = 2_000_000;
        let loads = arrival_loads(&run);
        assert_eq!(loads[0].up_bits, vec![250_000.0; 4]);
        assert_eq!(loads[0].down_bits, 250.0); // 1000 bits over 4 clients
        assert!(loads[1].up_bits.is_empty()); // empty round bills zero...
        assert_eq!(loads[1].down_bits, 0.0); // ...in both directions
        assert_eq!(loads[2].up_bits, vec![500_000.0; 2]);
        // An empty round costs only latency + compute through replay.
        let link =
            LinkModel { uplink_bps: 1e6, downlink_bps: 1e12, latency_s: 0.5, compute_s: 0.0 };
        let tl = replay(&run, &link, &loads);
        assert!((tl[1].sim_time_s - tl[0].sim_time_s - 0.5).abs() < 1e-9);
    }

    #[test]
    fn target_never_reached() {
        let link = LinkModel::cross_device();
        let run = mk_run(1000, 1000, &[0.1, 0.2]);
        let tl = replay(&run, &link, &uniform_loads(&run, 1));
        assert!(time_to_accuracy(&tl, 0.99).is_none());
    }

    #[test]
    fn presets_sane() {
        assert!(LinkModel::cross_device().uplink_bps < LinkModel::datacenter().uplink_bps);
    }
}
