//! Differential-privacy substrate for DP-SignFedAvg (paper §3.4, Appendix F).
//!
//! * [`accountant`] — Rényi-DP accounting for the *subsampled Gaussian
//!   mechanism* (Mironov, Talwar, Zhang '19), RDP→(ε,δ) conversion, and
//!   noise calibration by bisection (this is how the paper's Table 8 maps
//!   privacy budgets ε ∈ {1,…,10} to noise scales).
//! * The mechanism itself (clip → Gaussian perturbation → sign) lives on the
//!   client path in `fl::server` (`Compression::DpSign` / `DpDense`),
//!   because sign compression is post-processing and costs no extra ε.

pub mod accountant;

pub use accountant::{calibrate_noise, eps_for_noise, RdpAccountant};
