//! RDP accountant for the subsampled Gaussian mechanism.
//!
//! Client-level DP with client subsampling (the paper's EMNIST setting:
//! q = 100/3579 clients per round, T = 500 rounds). Per round, each selected
//! client's clipped update is perturbed with `N(0, (σ·C)²)`; the sign is
//! post-processing and free.
//!
//! RDP of the *sampled* Gaussian at integer order α (Mironov et al. '19,
//! Thm. 5 upper bound / the binomial-expansion form used by TF-Privacy):
//!
//! ```text
//! ε(α) = 1/(α−1) · log Σ_{k=0}^{α} C(α,k)·(1−q)^{α−k}·q^k·exp(k(k−1)/(2σ²))
//! ```
//!
//! Composition over T rounds adds the per-round RDP; conversion to
//! approximate DP uses `ε = min_α [ ε_rdp(α) + log(1/δ)/(α−1) ]`.

/// Log of the binomial coefficient C(n, k) via lgamma.
fn log_binom(n: u64, k: u64) -> f64 {
    lgamma((n + 1) as f64) - lgamma((k + 1) as f64) - lgamma((n - k + 1) as f64)
}

/// Lanczos log-gamma (same coefficients as `rng::gamma_fn`, in log space to
/// stay finite for large arguments).
pub fn lgamma(x: f64) -> f64 {
    assert!(x > 0.0);
    const G: f64 = 7.0;
    const COEF: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_572e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        (std::f64::consts::PI / (std::f64::consts::PI * x).sin()).ln() - lgamma(1.0 - x)
    } else {
        let xm = x - 1.0;
        let mut a = COEF[0];
        let t = xm + G + 0.5;
        for (i, &c) in COEF.iter().enumerate().skip(1) {
            a += c / (xm + i as f64);
        }
        0.5 * (2.0 * std::f64::consts::PI).ln() + (xm + 0.5) * t.ln() - t + a.ln()
    }
}

/// Numerically-stable log-sum-exp.
fn logsumexp(xs: &[f64]) -> f64 {
    let m = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    if m.is_infinite() {
        return m;
    }
    m + xs.iter().map(|x| (x - m).exp()).sum::<f64>().ln()
}

/// Per-step RDP of the subsampled Gaussian at integer order `alpha`.
///
/// `q` — sampling probability; `noise_mult` — σ (noise stddev / clip norm).
pub fn rdp_sampled_gaussian(q: f64, noise_mult: f64, alpha: u64) -> f64 {
    assert!((0.0..=1.0).contains(&q));
    assert!(noise_mult > 0.0);
    assert!(alpha >= 2);
    if q == 0.0 {
        return 0.0;
    }
    if q == 1.0 {
        // Plain Gaussian: ε(α) = α/(2σ²).
        return alpha as f64 / (2.0 * noise_mult * noise_mult);
    }
    let log_q = q.ln();
    let log_1mq = (1.0 - q).ln_1p_safe();
    let terms: Vec<f64> = (0..=alpha)
        .map(|k| {
            log_binom(alpha, k)
                + (alpha - k) as f64 * log_1mq
                + k as f64 * log_q
                + (k as f64) * (k as f64 - 1.0) / (2.0 * noise_mult * noise_mult)
        })
        .collect();
    logsumexp(&terms) / (alpha as f64 - 1.0)
}

trait Ln1pSafe {
    fn ln_1p_safe(self) -> f64;
}

impl Ln1pSafe for f64 {
    /// ln(x) written as ln1p(x−1) for x near 1 (x = 1−q with small q).
    fn ln_1p_safe(self) -> f64 {
        (self - 1.0).ln_1p()
    }
}

/// Default RDP orders (matches the common accounting practice: a dense grid
/// of small integer orders plus a coarse tail).
pub fn default_orders() -> Vec<u64> {
    let mut o: Vec<u64> = (2..=64).collect();
    o.extend([72, 80, 96, 128, 192, 256, 384, 512]);
    o
}

/// Tracks composed RDP over rounds.
#[derive(Debug, Clone)]
pub struct RdpAccountant {
    pub orders: Vec<u64>,
    pub rdp: Vec<f64>,
}

impl RdpAccountant {
    pub fn new() -> Self {
        let orders = default_orders();
        let rdp = vec![0.0; orders.len()];
        RdpAccountant { orders, rdp }
    }

    /// Compose `steps` rounds of subsampled Gaussian (q, σ).
    pub fn compose(&mut self, q: f64, noise_mult: f64, steps: u64) {
        for (r, &a) in self.rdp.iter_mut().zip(&self.orders) {
            *r += steps as f64 * rdp_sampled_gaussian(q, noise_mult, a);
        }
    }

    /// Convert to (ε, δ): minimize over orders.
    pub fn epsilon(&self, delta: f64) -> f64 {
        assert!(delta > 0.0 && delta < 1.0);
        self.orders
            .iter()
            .zip(&self.rdp)
            .map(|(&a, &r)| r + (1.0 / delta).ln() / (a as f64 - 1.0))
            .fold(f64::INFINITY, f64::min)
    }
}

impl Default for RdpAccountant {
    fn default() -> Self {
        Self::new()
    }
}

/// ε spent by T rounds of (q, σ) subsampled Gaussian at a given δ.
pub fn eps_for_noise(q: f64, noise_mult: f64, steps: u64, delta: f64) -> f64 {
    let mut acc = RdpAccountant::new();
    acc.compose(q, noise_mult, steps);
    acc.epsilon(delta)
}

/// Calibrate the noise multiplier σ achieving `target_eps` at (q, T, δ) by
/// bisection (the paper's Table 8 workflow).
pub fn calibrate_noise(q: f64, steps: u64, delta: f64, target_eps: f64) -> f64 {
    assert!(target_eps > 0.0);
    let mut lo = 1e-2;
    let mut hi = 1e2;
    // Widen until bracketed.
    while eps_for_noise(q, hi, steps, delta) > target_eps {
        hi *= 2.0;
        assert!(hi < 1e6, "cannot reach eps={target_eps}");
    }
    while eps_for_noise(q, lo, steps, delta) < target_eps {
        lo /= 2.0;
        assert!(lo > 1e-8, "eps={target_eps} needs no noise");
    }
    for _ in 0..60 {
        let mid = 0.5 * (lo + hi);
        if eps_for_noise(q, mid, steps, delta) > target_eps {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lgamma_matches_gamma() {
        for x in [0.5f64, 1.0, 2.5, 10.0, 100.5] {
            let lg = lgamma(x);
            let direct = crate::rng::gamma_fn(x.min(30.0)).ln();
            if x <= 30.0 {
                assert!((lg - direct).abs() < 1e-8, "x={x}");
            }
            assert!(lg.is_finite());
        }
        // lgamma(171) would overflow Gamma in f64 but must stay finite.
        assert!(lgamma(500.0).is_finite());
    }

    #[test]
    fn full_batch_matches_plain_gaussian() {
        // q=1 reduces to the Gaussian mechanism's RDP α/(2σ²).
        for alpha in [2u64, 8, 32] {
            let got = rdp_sampled_gaussian(1.0, 1.5, alpha);
            let want = alpha as f64 / (2.0 * 1.5 * 1.5);
            assert!((got - want).abs() < 1e-12);
        }
    }

    #[test]
    fn subsampling_amplifies_privacy() {
        // Smaller q -> strictly less RDP at every order.
        for alpha in [2u64, 16, 64] {
            let e_small = rdp_sampled_gaussian(0.01, 1.0, alpha);
            let e_big = rdp_sampled_gaussian(0.5, 1.0, alpha);
            let e_full = rdp_sampled_gaussian(1.0, 1.0, alpha);
            assert!(e_small < e_big && e_big < e_full, "alpha={alpha}");
        }
    }

    #[test]
    fn epsilon_monotone_in_steps_and_noise() {
        let d = 1e-5;
        assert!(eps_for_noise(0.03, 1.0, 100, d) < eps_for_noise(0.03, 1.0, 1000, d));
        assert!(eps_for_noise(0.03, 2.0, 500, d) < eps_for_noise(0.03, 1.0, 500, d));
    }

    #[test]
    fn calibration_inverts_accounting() {
        let (q, t, delta) = (0.0279, 500, 1.0 / 3579.0);
        for target in [1.0f64, 4.0, 10.0] {
            let sigma = calibrate_noise(q, t, delta, target);
            let eps = eps_for_noise(q, sigma, t, delta);
            assert!((eps - target).abs() / target < 1e-3, "target={target} got={eps}");
        }
    }

    #[test]
    fn paper_table8_noise_scales_shape() {
        // Table 8: eps 1→σ≈2.77, 2→1.57, 4→1.02, 6→0.845, 8→0.75, 10→0.685
        // under the EMNIST setting (q=100/3579, T=500, δ=1/n). Our accountant
        // uses the same integer-order RDP bound, so the calibrated σ should
        // land in the same ballpark (within ~25%) and must preserve the
        // ordering/ratios.
        let (q, t, delta) = (100.0 / 3579.0, 500u64, 1.0 / 3579.0);
        let paper =
            [(1.0, 2.77), (2.0, 1.57), (4.0, 1.02), (6.0, 0.845), (8.0, 0.75), (10.0, 0.685)];
        let mut prev = f64::INFINITY;
        for (eps, sigma_paper) in paper {
            let sigma = calibrate_noise(q, t, delta, eps);
            assert!(sigma < prev, "sigma must decrease with eps");
            prev = sigma;
            let rel = (sigma - sigma_paper).abs() / sigma_paper;
            assert!(rel < 0.25, "eps={eps}: sigma={sigma:.3} paper={sigma_paper} rel={rel:.2}");
        }
    }

    #[test]
    fn accountant_composition_is_additive() {
        let mut a = RdpAccountant::new();
        a.compose(0.05, 1.2, 300);
        let mut b = RdpAccountant::new();
        b.compose(0.05, 1.2, 100);
        b.compose(0.05, 1.2, 200);
        for (x, y) in a.rdp.iter().zip(&b.rdp) {
            assert!((x - y).abs() <= 1e-12 * x.abs().max(1.0), "{x} vs {y}");
        }
    }
}
