//! The crate's one error surface (the vendor set has no `anyhow`).
//!
//! A drop-in subset of the anyhow API used by the drivers and the runtime:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`] macros and the
//! [`Context`] extension trait — plus a coarse [`ErrorKind`] so the places
//! that *do* need to branch (the service retry loop, spec validation
//! reporting, CLI exit paths) can, without growing a per-module error enum
//! zoo. `Session`, the coordinator/participant service and the CLI all
//! return this same type.
//!
//! The kind taxonomy is deliberately small:
//! * [`ErrorKind::Spec`] — an `ExperimentSpec` failed validation or JSON
//!   decoding (field-path messages from `api::spec`);
//! * [`ErrorKind::Protocol`] — a service message was malformed or a peer
//!   violated the coordinator grammar;
//! * [`ErrorKind::Timeout`] — a deadline expired (rendezvous patience,
//!   round deadline);
//! * [`ErrorKind::Checkpoint`] — a checkpoint snapshot failed to decode
//!   (corrupt/truncated/version-skewed) or a resume precondition was
//!   violated (spec-fingerprint mismatch);
//! * [`ErrorKind::Other`] — everything else, including every error
//!   converted from a std error type via `?`.

use std::fmt;

/// Coarse classification of an [`Error`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrorKind {
    /// Anything without a more specific classification.
    Other,
    /// Experiment-spec validation/decoding failure (field-path message).
    Spec,
    /// Service protocol violation (malformed frame, grammar breach).
    Protocol,
    /// A deadline expired.
    Timeout,
    /// A checkpoint snapshot failed to decode, or a resume precondition
    /// (spec fingerprint, format version) was violated.
    Checkpoint,
}

/// A human-readable error message with a coarse kind.
pub struct Error {
    kind: ErrorKind,
    msg: String,
}

impl Error {
    /// Build an `Other`-kind error from anything displayable.
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Other, msg: msg.to_string() }
    }

    /// A spec validation/decoding error.
    pub fn spec(msg: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Spec, msg: msg.to_string() }
    }

    /// A service protocol violation.
    pub fn protocol(msg: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Protocol, msg: msg.to_string() }
    }

    /// An expired deadline.
    pub fn timeout(msg: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Timeout, msg: msg.to_string() }
    }

    /// A checkpoint decode/resume failure.
    pub fn checkpoint(msg: impl fmt::Display) -> Error {
        Error { kind: ErrorKind::Checkpoint, msg: msg.to_string() }
    }

    pub fn kind(&self) -> ErrorKind {
        self.kind
    }

    /// Re-wrap with a message prefix, preserving the kind (the [`Context`]
    /// trait cannot — it accepts any `Display` error, so it defaults to
    /// `Other`; use this when the kind must survive).
    pub fn wrap(self, ctx: impl fmt::Display) -> Error {
        Error { kind: self.kind, msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `main` exits through the Debug impl: keep it readable.
        f.write_str(&self.msg)
    }
}

// `Error` deliberately does not implement `std::error::Error`, which keeps
// this blanket conversion coherent (the same trick anyhow uses): every
// std-error type works with `?` in a `Result<_, Error>` function.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Error {
        Error::msg(e)
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to an error, anyhow-style.
pub trait Context<T> {
    /// Wrap the error as `"{ctx}: {error}"`.
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;

    /// Like [`Context::context`], evaluating the message lazily.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format_args!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format_args!("{}: {e}", f())))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::error::Error::msg(::core::format_args!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::error::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::error::Error::msg(::core::format_args!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

pub use crate::{anyhow, bail};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        assert_eq!(anyhow!("plain").to_string(), "plain");
        let x = 3;
        assert_eq!(anyhow!("inline {x}").to_string(), "inline 3");
        assert_eq!(anyhow!("fmt {} {}", 1, "b").to_string(), "fmt 1 b");
        assert_eq!(anyhow!(String::from("owned")).to_string(), "owned");
    }

    #[test]
    fn bail_returns_early() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                bail!("boom {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "boom 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/path")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_wraps() {
        let r: std::result::Result<(), &str> = Err("inner");
        assert_eq!(r.context("outer").unwrap_err().to_string(), "outer: inner");
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.with_context(|| format!("round {}", 2)).unwrap_err();
        assert_eq!(e.to_string(), "round 2: inner");
    }

    #[test]
    fn debug_is_message() {
        assert_eq!(format!("{:?}", anyhow!("msg")), "msg");
    }

    #[test]
    fn kinds_classify_and_survive_wrap() {
        assert_eq!(anyhow!("x").kind(), ErrorKind::Other);
        assert_eq!(Error::spec("series[0].rounds: must be >= 1").kind(), ErrorKind::Spec);
        assert_eq!(Error::protocol("bad tag").kind(), ErrorKind::Protocol);
        assert_eq!(Error::checkpoint("fingerprint mismatch").kind(), ErrorKind::Checkpoint);
        assert_eq!(
            Error::checkpoint("truncated").wrap("resume").kind(),
            ErrorKind::Checkpoint
        );
        let t = Error::timeout("round deadline");
        assert_eq!(t.kind(), ErrorKind::Timeout);
        let wrapped = t.wrap("round 3");
        assert_eq!(wrapped.kind(), ErrorKind::Timeout);
        assert_eq!(wrapped.to_string(), "round 3: round deadline");
        // Context on a foreign error type defaults to Other.
        let r: std::result::Result<(), &str> = Err("inner");
        assert_eq!(r.context("c").unwrap_err().kind(), ErrorKind::Other);
    }
}
