//! Micro-benchmark harness (criterion substitute; the vendor set has no
//! criterion).
//!
//! Methodology mirrors criterion's core loop: warm-up phase, then `samples`
//! timed batches where the batch size is auto-scaled so each batch takes
//! ≥ `min_batch_time`; reports mean/median/p5/p95 per-iteration time and
//! derived throughput. Used by the `benches/*.rs` targets (built with
//! `harness = false`) and by the §Perf drivers.

use crate::util::stats::{percentile, Summary};
use std::time::Instant;

/// Harness configuration.
#[derive(Debug, Clone, Copy)]
pub struct BenchConfig {
    pub warmup_time_s: f64,
    pub samples: usize,
    pub min_batch_time_s: f64,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_time_s: 0.5, samples: 30, min_batch_time_s: 0.02 }
    }
}

impl BenchConfig {
    /// Tiny iteration budget for `make bench-smoke`: every registered bench
    /// executes end to end in CI (compiling alone doesn't catch bench rot),
    /// with timings that are meaningless but code paths that are real.
    pub fn smoke() -> BenchConfig {
        BenchConfig { warmup_time_s: 0.01, samples: 2, min_batch_time_s: 0.001 }
    }
}

/// True when the bench binary was invoked with `--smoke` (the
/// `make bench-smoke` contract): benches shrink their problem sizes and use
/// [`BenchConfig::smoke`] so the whole suite executes in seconds.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// One benchmark's results.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration seconds for each sample batch.
    pub per_iter_s: Vec<f64>,
    pub iters_total: u64,
}

impl BenchResult {
    pub fn mean_s(&self) -> f64 {
        let mut s = Summary::new();
        for &x in &self.per_iter_s {
            s.push(x);
        }
        s.mean()
    }

    pub fn median_s(&self) -> f64 {
        percentile(&self.per_iter_s, 0.5)
    }

    pub fn p95_s(&self) -> f64 {
        percentile(&self.per_iter_s, 0.95)
    }

    /// items/second given `items` processed per iteration.
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.median_s()
    }

    /// Human line like criterion's output.
    pub fn report(&self) -> String {
        format!(
            "{:<40} median {:>12}   mean {:>12}   p95 {:>12}   ({} iters)",
            self.name,
            fmt_time(self.median_s()),
            fmt_time(self.mean_s()),
            fmt_time(self.p95_s()),
            self.iters_total
        )
    }

    /// Report with an explicit throughput row.
    pub fn report_throughput(&self, items: f64, unit: &str) -> String {
        format!("{}   {:>10.2} M{}/s", self.report(), self.throughput(items) / 1e6, unit)
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

/// Run one benchmark. `f` is called once per iteration; use `std::hint::black_box`
/// inside to defeat DCE.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> BenchResult {
    // Warm-up + batch-size calibration.
    let warm_start = Instant::now();
    let mut iters_per_batch = 1u64;
    let mut calib = 0u64;
    while warm_start.elapsed().as_secs_f64() < cfg.warmup_time_s {
        f();
        calib += 1;
    }
    let per_iter_est = warm_start.elapsed().as_secs_f64() / calib.max(1) as f64;
    if per_iter_est < cfg.min_batch_time_s {
        iters_per_batch = (cfg.min_batch_time_s / per_iter_est).ceil() as u64;
    }

    let mut per_iter_s = Vec::with_capacity(cfg.samples);
    let mut iters_total = 0u64;
    for _ in 0..cfg.samples {
        let t0 = Instant::now();
        for _ in 0..iters_per_batch {
            f();
        }
        let dt = t0.elapsed().as_secs_f64();
        per_iter_s.push(dt / iters_per_batch as f64);
        iters_total += iters_per_batch;
    }
    BenchResult { name: name.to_string(), per_iter_s, iters_total }
}

/// Quick preset for cheap functions in CI.
pub fn quick(name: &str, f: impl FnMut()) -> BenchResult {
    bench(name, BenchConfig { warmup_time_s: 0.1, samples: 12, min_batch_time_s: 0.005 }, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_sleep_scale() {
        let r = bench(
            "sleep_1ms",
            BenchConfig { warmup_time_s: 0.02, samples: 5, min_batch_time_s: 0.001 },
            || std::thread::sleep(std::time::Duration::from_millis(1)),
        );
        let m = r.median_s();
        assert!(m > 0.8e-3 && m < 10e-3, "median={m}");
    }

    #[test]
    fn throughput_computation() {
        let r = BenchResult {
            name: "x".into(),
            per_iter_s: vec![0.001, 0.001, 0.001],
            iters_total: 3,
        };
        assert!((r.throughput(1000.0) - 1e6).abs() < 1.0);
    }

    #[test]
    fn fmt_time_units() {
        assert!(fmt_time(5e-9).contains("ns"));
        assert!(fmt_time(5e-6).contains("µs"));
        assert!(fmt_time(5e-3).contains("ms"));
        assert!(fmt_time(5.0).contains(" s"));
    }
}
