//! Flat-vector numeric substrate.
//!
//! Model parameters, deltas and gradients travel through the coordinator as
//! contiguous `f32` buffers (matching the flat `ravel_pytree` layout of the
//! L2 artifacts), so the server-side math is a handful of dense vector
//! primitives. All reductions accumulate in `f64` — with d up to 10^6 and
//! hundreds of rounds, f32 accumulation drift is observable in the metrics.

/// y += a * x  (the classic axpy).
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, xi) in y.iter_mut().zip(x) {
        *yi += a * xi;
    }
}

/// y = x (copy helper that asserts matching lengths).
pub fn assign(x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    y.copy_from_slice(x);
}

/// x *= a.
pub fn scale(a: f32, x: &mut [f32]) {
    for xi in x.iter_mut() {
        *xi *= a;
    }
}

/// out = x - y.
pub fn sub(x: &[f32], y: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len());
    debug_assert_eq!(x.len(), out.len());
    for ((o, xi), yi) in out.iter_mut().zip(x).zip(y) {
        *o = xi - yi;
    }
}

/// Dot product with f64 accumulation.
pub fn dot(x: &[f32], y: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), y.len());
    x.iter().zip(y).map(|(a, b)| *a as f64 * *b as f64).sum()
}

/// Squared l2 norm.
pub fn norm2_sq(x: &[f32]) -> f64 {
    x.iter().map(|a| (*a as f64) * (*a as f64)).sum()
}

/// l2 norm.
pub fn norm2(x: &[f32]) -> f64 {
    norm2_sq(x).sqrt()
}

/// l-infinity norm.
pub fn norm_inf(x: &[f32]) -> f64 {
    x.iter().fold(0.0f64, |m, a| m.max(a.abs() as f64))
}

/// lp norm, p >= 1. Used by the Lemma-1 bound checks (p = 4z+2).
pub fn norm_p(x: &[f32], p: f64) -> f64 {
    assert!(p >= 1.0);
    x.iter().map(|a| (a.abs() as f64).powf(p)).sum::<f64>().powf(1.0 / p)
}

/// Mean of a slice.
pub fn mean(x: &[f32]) -> f64 {
    if x.is_empty() {
        return 0.0;
    }
    x.iter().map(|a| *a as f64).sum::<f64>() / x.len() as f64
}

/// In-place l2-ball projection: x <- x / max(1, ||x||/c). Returns the factor.
/// This is the DP-SignFedAvg clipping step (Algorithm 2, line 11).
pub fn clip_l2(x: &mut [f32], c: f64) -> f64 {
    assert!(c > 0.0);
    let n = norm2(x);
    let factor = 1.0f64.max(n / c);
    if factor > 1.0 {
        let inv = (1.0 / factor) as f32;
        scale(inv, x);
    }
    factor
}

/// Elementwise paper-Sign (+1 for >= 0) into an i8 buffer.
pub fn sign_into(x: &[f32], out: &mut [i8]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, xi) in out.iter_mut().zip(x) {
        *o = if *xi >= 0.0 { 1 } else { -1 };
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn axpy_basic() {
        let x = [1.0, 2.0, 3.0];
        let mut y = [10.0, 10.0, 10.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 14.0, 16.0]);
    }

    #[test]
    fn norms() {
        let x = [3.0f32, -4.0];
        assert!((norm2(&x) - 5.0).abs() < 1e-12);
        assert!((norm_inf(&x) - 4.0).abs() < 1e-12);
        assert!((norm_p(&x, 1.0) - 7.0).abs() < 1e-9);
        // p=2 must agree with norm2
        assert!((norm_p(&x, 2.0) - 5.0).abs() < 1e-9);
    }

    #[test]
    fn clip_noop_inside_ball() {
        let mut x = [0.3f32, 0.4];
        let f = clip_l2(&mut x, 1.0);
        assert_eq!(f, 1.0);
        assert_eq!(x, [0.3, 0.4]);
    }

    #[test]
    fn clip_projects_onto_ball() {
        let mut x = [3.0f32, 4.0];
        clip_l2(&mut x, 1.0);
        assert!((norm2(&x) - 1.0).abs() < 1e-6);
        // Direction preserved.
        assert!((x[0] / x[1] - 0.75).abs() < 1e-6);
    }

    #[test]
    fn sign_of_zero_is_plus_one() {
        let x = [0.0f32, -0.0, 1.0, -1.0];
        let mut s = [0i8; 4];
        sign_into(&x, &mut s);
        // IEEE -0.0 >= 0.0 is true, so Sign(-0.0) = +1 as well.
        assert_eq!(s, [1, 1, 1, -1]);
    }

    #[test]
    fn f64_accumulation() {
        // 1e7 tiny values that would lose mass in f32 accumulation.
        let x = vec![1e-4f32; 10_000_000];
        let m = mean(&x);
        assert!((m - 1e-4).abs() < 1e-9, "m={m}");
    }
}
