//! The networked coordinator/participant service (DESIGN.md §5).
//!
//! The in-process [`RoundEngine`](crate::fl::engine::RoundEngine) runs
//! clients on a thread pool it owns. This module runs the *same round
//! stages* with the clients on the far side of a message protocol:
//!
//! * [`protocol`] — the request/reply envelope grammar (checksummed,
//!   adversarially validated);
//! * [`coordinator`] — the pure message-driven state machine
//!   (rendezvous → standby → round-in-progress → finished) that assigns
//!   slots, validates submissions at arrival, and tolerates late
//!   arrivals, dropouts, duplicates and heartbeat expiry;
//! * [`participant`] — the client SDK: pull a work order, run the local
//!   update, compress through the `Aggregator` seam, submit over the
//!   existing `compress::wire` format;
//! * [`transport`] — the seam between them: in-process loopback (the
//!   determinism substrate) and length-prefixed TCP over `std::net`,
//!   behind one [`Transport`] trait.
//!
//! [`ServiceHost`] is the server-side driver: it owns the engine's
//! server-side stages (participation planning, σ resolution, fold,
//! server step, evaluation) and feeds the client-side stages to remote
//! participants through a [`Coordinator`]. On loopback with full
//! submission the result is **bit-identical** to `RoundEngine::run` —
//! pinned by the tests at the bottom of this file for every compressor
//! family, at 1 and 8 participant threads, under uniform and simulated
//! (faulty) participation.

pub mod chaos;
pub mod coordinator;
pub mod participant;
pub mod protocol;
pub mod transport;

pub use chaos::{ChaosConfig, ChaosTransport, FaultPlan, RetryPolicy};
pub use coordinator::{CoordState, Coordinator, Submission};
pub use participant::Participant;
pub use transport::{LoopbackTransport, TcpServer, TcpTransport, Transport, MAX_FRAME_BYTES};

use crate::api::spec::ExperimentSpec;
use crate::error::{Error, Result};
use crate::fl::engine::{CkptHook, EngineCkpt, RoundEngine};
use crate::fl::{AlgorithmConfig, RoundRecord, RunResult, ServerConfig, TrainBackend};
use crate::telemetry::{Clock, Phase, Telemetry};
use std::thread::JoinHandle;
use std::time::Duration;

/// Server-side driver: the engine's round loop with the client stages
/// outsourced to networked participants.
///
/// Construct with [`ServiceHost::loopback`] (spawns in-process participant
/// threads; heartbeat expiry disabled, so the cohort is stable and every
/// round sees full submission — the bit-identical configuration) or
/// [`ServiceHost::tcp`] (binds a listener; real peers join with
/// `zsfa join`, heartbeats gate liveness, and the round deadline turns
/// silent dropouts into partial rounds).
pub struct ServiceHost {
    coord: Coordinator,
    server: Option<TcpServer>,
    round_deadline: Duration,
    join_patience: Duration,
    min_participants: usize,
    loopback: Vec<JoinHandle<Result<()>>>,
    clock: Clock,
    tele: Telemetry,
    /// EF-residual mirror shared with in-process participants (loopback
    /// only), so checkpoints capture the one piece of participant-owned
    /// trajectory state. TCP participants keep residuals private — they
    /// outlive a coordinator crash and reconnect with them intact.
    ef_vault: Option<participant::ResidualVault>,
}

impl ServiceHost {
    /// In-process service: `workers` participant threads over the loopback
    /// transport (full protocol codec, zero I/O).
    pub fn loopback(spec: &ExperimentSpec, workers: usize) -> ServiceHost {
        Self::loopback_chaos(spec, workers, None)
    }

    /// [`ServiceHost::loopback`] with a seeded fault plan injected between
    /// every in-process participant and the coordinator. Worker `w` runs
    /// under the deterministic plan `(cfg, seed + w)` with a fast retry
    /// policy, so the whole chaotic run replays from `seed` — and, because
    /// retries, duplicate suppression and idempotent re-pulls are invisible
    /// to the slot-ordered fold, stays byte-identical to the fault-free
    /// host (pinned by the chaos tests below and `make chaos-smoke`).
    pub fn loopback_chaos(
        spec: &ExperimentSpec,
        workers: usize,
        chaos: Option<(ChaosConfig, u64)>,
    ) -> ServiceHost {
        // heartbeat_ms = 0 disables expiry: a loopback participant cannot
        // silently vanish, and a stable roster keeps EF residual pins fixed.
        let coord = Coordinator::new(0);
        let vault: participant::ResidualVault = Default::default();
        let loopback = (0..workers.max(1))
            .map(|w| {
                let p = Participant::new(spec.clone()).with_vault(vault.clone());
                let coord = coord.clone();
                std::thread::spawn(move || match chaos {
                    Some((cfg, seed)) => {
                        let plan = FaultPlan::new(cfg, seed.wrapping_add(w as u64));
                        let mut p = p.with_retry(RetryPolicy::fast(plan.seed));
                        p.run(&mut ChaosTransport::new(LoopbackTransport::new(coord), plan))
                    }
                    None => {
                        let mut p = p;
                        p.run(&mut LoopbackTransport::new(coord))
                    }
                })
            })
            .collect();
        ServiceHost {
            coord,
            server: None,
            // Loopback participants always submit; the deadline is only a
            // backstop against a wedged participant thread.
            round_deadline: Duration::from_secs(600),
            join_patience: Duration::from_secs(60),
            min_participants: 1,
            loopback,
            clock: Clock::from_env(),
            tele: Telemetry::disabled(),
            ef_vault: Some(vault),
        }
    }

    /// Networked service: bind `addr` and wait for `min_participants`
    /// peers before the first round is offered. The telemetry handle is
    /// shared with the coordinator (protocol counters) and the TCP server
    /// (the `/metrics` HTTP endpoint); pass `Telemetry::disabled()` to
    /// serve without observability.
    pub fn tcp(
        addr: &str,
        heartbeat_ms: u64,
        round_deadline_ms: u64,
        min_participants: usize,
        tele: &Telemetry,
    ) -> Result<ServiceHost> {
        let coord = Coordinator::new(heartbeat_ms);
        coord.with_state(|st| st.set_telemetry(tele.clone()));
        let server = TcpServer::bind_with(addr, coord.clone(), tele.clone())?;
        Ok(ServiceHost {
            coord,
            server: Some(server),
            round_deadline: Duration::from_millis(round_deadline_ms),
            join_patience: Duration::from_secs(60),
            min_participants: min_participants.max(1),
            loopback: Vec::new(),
            clock: Clock::from_env(),
            tele: tele.clone(),
            ef_vault: None,
        })
    }

    /// Override the wall-clock source (`Clock::Fixed` pins every record's
    /// `wall_ms` — the CI byte-diff configuration). Defaults to
    /// [`Clock::from_env`].
    pub fn set_clock(&mut self, clock: Clock) {
        self.clock = clock;
    }

    /// Override the per-round submission deadline — the window each of the
    /// three degradation stages (full submission, reclaim grace, quorum
    /// settle) is allowed before the round closes partial.
    pub fn set_round_deadline(&mut self, deadline: Duration) {
        self.round_deadline = deadline;
    }

    /// Attach a telemetry recorder after construction (loopback hosts are
    /// built without one). Shared with the coordinator so protocol events
    /// (rendezvous, heartbeats, stale/duplicate submissions) are counted.
    pub fn set_telemetry(&mut self, tele: Telemetry) {
        self.coord.with_state(|st| st.set_telemetry(tele.clone()));
        self.tele = tele;
    }

    /// The bound TCP address, when serving TCP (resolves `:0` requests).
    pub fn local_addr(&self) -> Option<std::net::SocketAddr> {
        self.server.as_ref().map(|s| s.local_addr())
    }

    /// The coordinator's sticky client→pid pins, in deterministic order
    /// (for `ckpt::Snapshot::pins`).
    pub fn pins_snapshot(&self) -> Vec<(u64, u64)> {
        self.coord.with_state(|st| st.pins_snapshot())
    }

    /// Restore checkpointed pins onto the (possibly re-rendezvoused)
    /// cohort. Best-effort: pins whose holder never reconnects are stolen
    /// by live participants at `PullRound`.
    pub fn restore_pins(&self, pins: &[(u64, u64)]) {
        self.coord.with_state(|st| st.restore_pins(pins));
    }

    /// Run one (series, repeat) experiment through the service — the exact
    /// stage sequence of `RoundEngine::run_observed`, with the per-client
    /// work replaced by offer/submit through the coordinator.
    pub fn run_one(
        &mut self,
        backend: &mut dyn TrainBackend,
        algo: &AlgorithmConfig,
        cfg: &ServerConfig,
        series: u32,
        repeat: u32,
        on_record: &mut dyn FnMut(&RoundRecord),
    ) -> Result<RunResult> {
        self.run_one_resumable(backend, algo, cfg, series, repeat, on_record, None, None)
    }

    /// [`ServiceHost::run_one`] plus the checkpoint/resume seam — the
    /// service-side twin of `RoundEngine::run_resumable`. `resume`
    /// restarts at a captured round boundary (replayed records do not
    /// re-fire `on_record`); `hook` is offered a capture at every round
    /// boundary it asks for, after that round is fully folded, stepped and
    /// recorded. Participants reconnect through the ordinary rendezvous
    /// path; their only cross-round state — EF residuals — is mirrored
    /// through the loopback vault for in-process cohorts, while TCP
    /// participants outlive a coordinator crash and keep their own.
    #[allow(clippy::too_many_arguments)]
    pub fn run_one_resumable(
        &mut self,
        backend: &mut dyn TrainBackend,
        algo: &AlgorithmConfig,
        cfg: &ServerConfig,
        series: u32,
        repeat: u32,
        on_record: &mut dyn FnMut(&RoundRecord),
        resume: Option<&EngineCkpt>,
        mut hook: Option<&mut dyn CkptHook>,
    ) -> Result<RunResult> {
        let d = backend.dim();
        let n = backend.num_clients();
        let mut engine = RoundEngine::new(algo, cfg, d, n);
        // Share the host's telemetry and clock so ServerStep/Eval spans and
        // bit counters (recorded inside the engine's stage methods) land in
        // the same registry, and wall_ms uses the same injectable source.
        engine.set_telemetry(self.tele.clone());
        engine.set_clock(self.clock);
        engine.reset_run();
        let mut params = backend.init_params();
        let root = engine.root();
        let mut policy = engine.build_policy(&root);

        // Arm submission validation for this run's family, then wait for
        // the minimum cohort to rendezvous.
        self.coord.with_state(|st| {
            st.begin_run(algo.compression.aggregator_robust(algo.client_lr, algo.robust), d)
        });
        let min = self.min_participants;
        self.coord
            .wait_until(self.join_patience, |st| (st.roster_len() >= min).then_some(()))
            .ok_or_else(|| {
                Error::timeout(format!(
                    "fewer than {min} participants joined within {:?}",
                    self.join_patience
                ))
            })?;

        let mut records = Vec::new();
        let mut sim_time_s = 0.0f64;
        let mut start = 0usize;
        if let Some(ck) = resume {
            engine.restore(ck);
            params.copy_from_slice(&ck.params);
            records = ck.records.clone();
            sim_time_s = ck.sim_time_s;
            start = ck.next_round as usize;
            // Seed the loopback residual vault: in-process participants
            // adopt the checkpointed EF residuals on first touch. (TCP
            // participants survived the crash and still hold their own.)
            if let Some(vault) = &self.ef_vault {
                let mut v = vault.lock().unwrap();
                for (client, r) in ck.ef_residuals.iter().enumerate() {
                    v.insert((series, repeat, client as u64), r.clone());
                }
            }
        }
        for t in start..cfg.rounds {
            let sw = self.clock.start();
            // 1. Participation: planned server-side, exactly like the
            //    engine; the plan's faults ride along in the work orders.
            let plan = policy.plan_round(t, &root);
            let selected = plan.outcomes.len() as u32;
            sim_time_s += plan.duration_s;
            engine.bill_downlink(plan.downloads);
            let round_sigma = engine.round_sigma();
            self.tele.round_begin(t as u64, round_sigma);

            let mut arrived = 0u32;
            let mut degraded = false;
            if !plan.participants.is_empty() {
                // 2. Offer the round; participants pull slots and submit.
                // The Clients span is the offer→close window: remote local
                // updates (perturb + sign + pack) happen inside it.
                let span = self.tele.span_start();
                self.coord.with_state(|st| {
                    st.offer_round(
                        series,
                        repeat,
                        t as u64,
                        round_sigma,
                        &params,
                        &plan.participants,
                    )
                });
                // 3. Close at full submission; past the deadline, degrade
                //    gracefully in bounded stages: reclaim stalled slots so
                //    live peers can re-pull them, grant one grace window for
                //    the repairs, then settle for a quorum. A round closed
                //    short of full submission is the dropout semantics, not
                //    an error — but it is surfaced as degraded.
                self.coord
                    .wait_until(self.round_deadline, |st| st.round_complete().then_some(()));
                if !self.coord.with_state(|st| st.round_complete()) {
                    if self.coord.with_state(|st| st.reclaim_unsubmitted()) > 0 {
                        self.coord.wait_until(self.round_deadline, |st| {
                            st.round_complete().then_some(())
                        });
                    }
                    let quorum = self.min_participants.min(plan.participants.len());
                    if !self.coord.with_state(|st| st.round_complete()) {
                        self.coord.wait_until(self.round_deadline, |st| {
                            (st.submitted_count() >= quorum).then_some(())
                        });
                    }
                }
                let (subs, full) = self.coord.with_state(|st| {
                    let full = st.round_complete();
                    (st.close_round(), full)
                });
                degraded = !full;
                if degraded {
                    self.tele.round_degraded(t as u64);
                }
                self.tele.span_end(Phase::Clients, span, t as u64);

                // 4–6. Fold in slot order and step, exactly like the
                //    engine. Submissions were probe-validated at arrival,
                //    so a fold failure here is a coordinator bug.
                if !subs.is_empty() {
                    let m = subs.len();
                    arrived = m as u32;
                    let inv_m = 1.0f32 / m as f32;
                    let span = self.tele.span_start();
                    let topo = engine.begin_remote_round(m);
                    for (slot, sub) in subs.iter().enumerate() {
                        engine
                            .fold_remote_slot(&topo, slot, &sub.update, sub.loss, inv_m)
                            .map_err(|e| {
                                Error::protocol(format!(
                                    "round {t} slot {slot}: validated submission failed to fold \
                                     ({e:?})"
                                ))
                            })?;
                    }
                    let stats = engine.finish_remote_round(&topo);
                    self.tele.span_end(Phase::Fold, span, t as u64);
                    engine.apply_server_step(t, &root, &mut params, &stats);
                }
            }

            // 7. Evaluation. The stopwatch is read inside `eval_record`
            //    after `evaluate` returns, so wall_ms spans the full round
            //    (see `RoundRecord::wall_ms`) — same contract as the engine.
            if engine.should_eval(t) {
                let mut rec = engine.eval_record(
                    backend,
                    t,
                    &params,
                    round_sigma,
                    &sw,
                    sim_time_s,
                    arrived,
                    selected,
                );
                rec.degraded = degraded;
                on_record(&rec);
                records.push(rec);
            }
            self.tele.round_end(t as u64, arrived as u64, selected as u64, sw.elapsed_ms());
            if let Some(h) = hook.as_deref_mut() {
                let next = t as u64 + 1;
                if (next as usize) < cfg.rounds && h.want(next) {
                    let mut ck = engine.capture(next, &params, sim_time_s, &records);
                    // The engine-side EF table is inert on the service
                    // path — the live residuals are participant-owned and
                    // mirrored into the loopback vault at submit time.
                    if let Some(vault) = &self.ef_vault {
                        let v = vault.lock().unwrap();
                        for (client, r) in ck.ef_residuals.iter_mut().enumerate() {
                            if let Some(stored) = v.get(&(series, repeat, client as u64)) {
                                r.copy_from_slice(stored);
                            }
                        }
                    }
                    h.store_pins(self.pins_snapshot());
                    h.store(ck);
                }
            }
        }
        Ok(RunResult { algorithm: engine.algorithm_name().to_string(), records })
    }

    /// Enter the terminal phase, drain loopback participants (propagating
    /// the first participant error), and stop the TCP listener.
    pub fn shutdown(&mut self) -> Result<()> {
        self.coord.with_state(|st| st.finish());
        let mut first_err: Option<Error> = None;
        for h in self.loopback.drain(..) {
            let outcome = match h.join() {
                Ok(r) => r,
                Err(_) => Err(Error::msg("loopback participant thread panicked")),
            };
            if let Err(e) = outcome {
                if first_err.is_none() {
                    first_err = Some(e);
                }
            }
        }
        if let Some(mut s) = self.server.take() {
            s.shutdown();
        }
        match first_err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }
}

impl Drop for ServiceHost {
    fn drop(&mut self) {
        // Flip the terminal phase so participant threads drain even when
        // `shutdown` was never called (an error path dropped the host).
        self.coord.with_state(|st| st.finish());
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::spec::WorkloadSpec;
    use crate::fl::server::{run_experiment, Participation};
    use crate::rng::ZParam;
    use crate::sim::{ByzantineMode, FleetPreset, ScenarioConfig};
    use protocol::{
        PhaseReply, Reply, RendezvousReply, Request, RoundReply, SubmitReply, WorkOrder,
    };

    /// The engine test suite's identity check: every record field except
    /// wall-clock must match to the bit.
    fn assert_identical(a: &RunResult, b: &RunResult, what: &str) {
        assert_eq!(a.algorithm, b.algorithm, "{what}");
        assert_eq!(a.records.len(), b.records.len(), "{what}");
        for (x, y) in a.records.iter().zip(&b.records) {
            assert_eq!(x.round, y.round, "{what}");
            assert_eq!(x.objective.to_bits(), y.objective.to_bits(), "{what} round {}", x.round);
            assert_eq!(x.accuracy.map(f64::to_bits), y.accuracy.map(f64::to_bits), "{what}");
            assert_eq!(
                x.grad_norm_sq.map(f64::to_bits),
                y.grad_norm_sq.map(f64::to_bits),
                "{what}"
            );
            assert_eq!(x.bits_up, y.bits_up, "{what} round {}", x.round);
            assert_eq!(x.bits_down, y.bits_down, "{what}");
            assert_eq!(x.sigma.to_bits(), y.sigma.to_bits(), "{what}");
            assert_eq!(x.sim_time_s.to_bits(), y.sim_time_s.to_bits(), "{what}");
            assert_eq!(x.arrived, y.arrived, "{what} round {}", x.round);
            assert_eq!(x.selected, y.selected, "{what}");
            assert_eq!(x.degraded, y.degraded, "{what} round {}", x.round);
        }
    }

    fn engine_run(spec: &ExperimentSpec, series: usize, repeat: usize) -> RunResult {
        let mut backend = spec.workload.build_backend().unwrap();
        let algo = spec.expanded_series()[series].algorithm.clone();
        run_experiment(backend.as_mut(), &algo, &spec.server_config(repeat))
    }

    fn loopback_run(spec: &ExperimentSpec, workers: usize, series: u32, repeat: u32) -> RunResult {
        let mut host = ServiceHost::loopback(spec, workers);
        let mut backend = spec.workload.build_backend().unwrap();
        let algo = spec.expanded_series()[series as usize].algorithm.clone();
        let cfg = spec.server_config(repeat as usize);
        let run = host
            .run_one(backend.as_mut(), &algo, &cfg, series, repeat, &mut |_| {})
            .unwrap();
        host.shutdown().unwrap();
        run
    }

    fn families() -> Vec<AlgorithmConfig> {
        vec![
            AlgorithmConfig::gd().with_lrs(0.05, 1.0),
            AlgorithmConfig::fedavg(3).with_lrs(0.05, 1.0),
            AlgorithmConfig::signsgd().with_lrs(0.05, 1.0),
            AlgorithmConfig::z_signsgd(ZParam::Finite(1), 2.0).with_lrs(0.05, 1.0),
            AlgorithmConfig::z_signsgd(ZParam::Inf, 2.0).with_lrs(0.05, 1.0),
            AlgorithmConfig::sto_signsgd().with_lrs(0.05, 1.0),
            AlgorithmConfig::ef_signsgd().with_lrs(0.05, 1.0),
            AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0),
            AlgorithmConfig::topk(0.25, 1).with_lrs(0.05, 1.0),
            AlgorithmConfig::sparse_sign(0.25, ZParam::Finite(1), 1.0, 1).with_lrs(0.05, 1.0),
            AlgorithmConfig::dp_signfedavg(0.5, 1.0, 2).with_lrs(0.05, 0.5),
            AlgorithmConfig::dp_fedavg(0.5, 1.0, 2).with_lrs(0.05, 0.5),
        ]
    }

    #[test]
    fn loopback_service_is_bit_identical_to_engine_for_every_family() {
        // reduce_lanes = 3 < m forces multi-slot lanes, so slot-order
        // folding is actually exercised; 1 and 8 participant threads pin
        // the parallelism contract on the service path too.
        for algo in families() {
            let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(16, 37, 1234))
                .rounds(6)
                .seed(13)
                .reduce_lanes(3)
                .series(algo);
            let want = engine_run(&spec, 0, 0);
            for workers in [1usize, 8] {
                let got = loopback_run(&spec, workers, 0, 0);
                assert_identical(&want, &got, &format!("{} workers={workers}", want.algorithm));
            }
        }
    }

    #[test]
    fn loopback_service_is_bit_identical_under_partial_participation() {
        let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(20, 24, 99))
            .rounds(8)
            .seed(7)
            .clients_per_round(Some(5))
            .series(AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0));
        let want = engine_run(&spec, 0, 0);
        for workers in [1usize, 8] {
            let got = loopback_run(&spec, workers, 0, 0);
            assert_identical(&want, &got, &format!("partial workers={workers}"));
        }
    }

    #[test]
    fn loopback_service_is_bit_identical_under_simulated_faults() {
        // Stragglers, dropouts and byzantine sign-flippers: the lifecycle
        // plan (and its faults) is host-side, so the service must replay
        // the identical scenario — down to empty and partial rounds.
        let sc = ScenarioConfig {
            target_cohort: 6,
            overselect: 1.5,
            deadline_s: 0.6,
            round_latency_s: 0.1,
            dropout_prob: 0.2,
            byzantine_frac: 0.25,
            byzantine_mode: ByzantineMode::SignFlip,
            fleet: FleetPreset::CrossDevice,
        };
        for algo in [
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0),
            AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0),
            AlgorithmConfig::qsgd(2).with_lrs(0.05, 1.0),
        ] {
            let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(24, 16, 77))
                .rounds(10)
                .seed(5)
                .participation(Participation::Simulated(sc.clone()))
                .series(algo);
            let want = engine_run(&spec, 0, 0);
            for workers in [1usize, 8] {
                let got = loopback_run(&spec, workers, 0, 0);
                assert_identical(&want, &got, &format!("{} workers={workers}", want.algorithm));
            }
        }
    }

    #[test]
    fn fresh_loopback_host_resumes_bit_identical_even_with_ef_residuals() {
        // The crash-recovery story for in-process transports: run to a
        // round boundary, capture, throw the whole host (and its
        // participants) away, rebuild from the snapshot, finish. EF is the
        // hard case — the residuals are participant-owned, so this pins
        // the vault mirror/seed path; the pins restore keeps affinity.
        struct At(u64, Option<EngineCkpt>);
        impl CkptHook for At {
            fn want(&mut self, next_round: u64) -> bool {
                next_round == self.0
            }
            fn store(&mut self, ck: EngineCkpt) {
                self.1 = Some(ck);
            }
        }

        for algo in [
            AlgorithmConfig::ef_signsgd().with_lrs(0.05, 1.0),
            AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0),
        ] {
            let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(16, 37, 1234))
                .rounds(8)
                .seed(13)
                .reduce_lanes(3)
                .series(algo);
            let want = engine_run(&spec, 0, 0);
            let algo = spec.expanded_series()[0].algorithm.clone();
            let cfg = spec.server_config(0);

            let mut host = ServiceHost::loopback(&spec, 4);
            let mut backend = spec.workload.build_backend().unwrap();
            let mut hook = At(4, None);
            host.run_one_resumable(
                backend.as_mut(),
                &algo,
                &cfg,
                0,
                0,
                &mut |_| {},
                None,
                Some(&mut hook),
            )
            .unwrap();
            let pins = host.pins_snapshot();
            host.shutdown().unwrap();
            let ck = hook.1.expect("capture at round 4");
            assert_eq!(ck.next_round, 4);
            assert!(!pins.is_empty());

            let mut host2 = ServiceHost::loopback(&spec, 4);
            host2.restore_pins(&pins);
            let mut backend2 = spec.workload.build_backend().unwrap();
            let got = host2
                .run_one_resumable(
                    backend2.as_mut(),
                    &algo,
                    &cfg,
                    0,
                    0,
                    &mut |_| {},
                    Some(&ck),
                    None,
                )
                .unwrap();
            host2.shutdown().unwrap();
            assert_identical(&want, &got, &format!("{} resumed", want.algorithm));
        }
    }

    #[test]
    fn one_host_serves_multiple_series_and_repeats() {
        // Participants must rebuild their run context when the work order
        // names a new (series, repeat) — and stay bit-identical for each.
        let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(12, 19, 42))
            .rounds(5)
            .seed(3)
            .repeats(2)
            .series(AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0))
            .series(AlgorithmConfig::fedavg(2).with_lrs(0.05, 1.0));
        let mut host = ServiceHost::loopback(&spec, 3);
        for series in 0..2u32 {
            for repeat in 0..2u32 {
                let mut backend = spec.workload.build_backend().unwrap();
                let algo = spec.expanded_series()[series as usize].algorithm.clone();
                let cfg = spec.server_config(repeat as usize);
                let got = host
                    .run_one(backend.as_mut(), &algo, &cfg, series, repeat, &mut |_| {})
                    .unwrap();
                let want = engine_run(&spec, series as usize, repeat as usize);
                assert_identical(&want, &got, &format!("series={series} repeat={repeat}"));
            }
        }
        host.shutdown().unwrap();
    }

    #[test]
    fn fixed_clock_pins_service_wall_ms_and_telemetry_is_inert() {
        // Under Clock::Fixed every service record carries the pinned
        // wall_ms (the byte-diff CI configuration), and attaching a live
        // telemetry recorder changes nothing about the run itself.
        let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(12, 17, 321))
            .rounds(5)
            .seed(9)
            .series(AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0));
        let want = engine_run(&spec, 0, 0);

        let mut host = ServiceHost::loopback(&spec, 2);
        host.set_clock(Clock::Fixed(42));
        let tele = Telemetry::with_capacity(256);
        host.set_telemetry(tele.clone());
        let mut backend = spec.workload.build_backend().unwrap();
        let algo = spec.expanded_series()[0].algorithm.clone();
        let cfg = spec.server_config(0);
        let got = host.run_one(backend.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}).unwrap();
        host.shutdown().unwrap();

        assert_identical(&want, &got, "fixed-clock loopback");
        for r in &got.records {
            assert_eq!(r.wall_ms, 42.0, "round {}", r.round);
        }
        let m = tele.metrics().unwrap();
        assert_eq!(m.rounds_total.get(), 5);
        assert!(m.bits_up_total.get() > 0);
        assert!(m.folds_total.get() > 0);
        // Protocol counters: every loopback worker rendezvoused.
        let prom = tele.export_prometheus();
        assert!(prom.contains("zsfa_rounds_total 5"), "{prom}");
    }

    #[test]
    fn chaos_loopback_is_byte_identical_to_the_engine_for_every_family() {
        // The headline robustness pin: under an aggressive seeded fault
        // plan (drops, dupes, resets, corrupted frames and payloads,
        // delays) every family still produces records byte-identical to
        // the fault-free engine run, at 1 and 8 workers — retries, dedup
        // and idempotent re-pulls are invisible to the slot-ordered fold.
        let chaos = Some((ChaosConfig::aggressive(), 0xC4A05));
        for algo in families() {
            let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(16, 37, 1234))
                .rounds(4)
                .seed(13)
                .reduce_lanes(3)
                .series(algo);
            let want = engine_run(&spec, 0, 0);
            for workers in [1usize, 8] {
                let mut host = ServiceHost::loopback_chaos(&spec, workers, chaos);
                let mut backend = spec.workload.build_backend().unwrap();
                let algo = spec.expanded_series()[0].algorithm.clone();
                let cfg = spec.server_config(0);
                let got = host
                    .run_one(backend.as_mut(), &algo, &cfg, 0, 0, &mut |_| {})
                    .unwrap();
                host.shutdown().unwrap();
                assert_identical(
                    &want,
                    &got,
                    &format!("chaos {} workers={workers}", want.algorithm),
                );
            }
        }
    }

    #[test]
    fn chaos_tcp_service_is_byte_identical_to_the_engine_for_every_family() {
        for algo in families() {
            let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(10, 13, 2024))
                .rounds(3)
                .seed(11)
                .series(algo);
            let want = engine_run(&spec, 0, 0);
            let mut host =
                ServiceHost::tcp("127.0.0.1:0", 500, 30_000, 2, &Telemetry::disabled()).unwrap();
            let addr = host.local_addr().unwrap().to_string();
            let joiners: Vec<_> = (0..2u64)
                .map(|k| {
                    let spec = spec.clone();
                    let addr = addr.clone();
                    std::thread::spawn(move || -> Result<()> {
                        let inner = TcpTransport::connect(&addr, Duration::from_secs(10))?;
                        let plan = FaultPlan::new(ChaosConfig::aggressive(), 0xFEED + k);
                        let mut t = ChaosTransport::new(inner, plan);
                        Participant::new(spec)
                            .with_retry(RetryPolicy::fast(0xFEED + k))
                            .run(&mut t)
                    })
                })
                .collect();
            let mut backend = spec.workload.build_backend().unwrap();
            let algo = spec.expanded_series()[0].algorithm.clone();
            let cfg = spec.server_config(0);
            let got = host.run_one(backend.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}).unwrap();
            host.shutdown().unwrap();
            for j in joiners {
                j.join().unwrap().unwrap();
            }
            assert_identical(&want, &got, &format!("chaos tcp {}", want.algorithm));
        }
    }

    /// A hand-rolled peer that rendezvouses, pulls one work order, never
    /// submits it (the stalled straggler), signals `on_hold`, then
    /// heartbeats until the coordinator finishes.
    fn stalled_peer(
        addr: String,
        on_hold: std::sync::mpsc::Sender<()>,
    ) -> std::thread::JoinHandle<()> {
        std::thread::spawn(move || {
            let mut t = TcpTransport::connect(&addr, Duration::from_secs(10)).unwrap();
            let Reply::Rendezvous(RendezvousReply::Accept { pid }) =
                t.request(&Request::Rendezvous).unwrap()
            else {
                panic!("stalled peer refused")
            };
            loop {
                match t.request(&Request::PullRound { pid }).unwrap() {
                    Reply::Round(RoundReply::Work(_)) => break, // hold it forever
                    _ => {
                        if let Reply::Heartbeat(PhaseReply::Finished) =
                            t.request(&Request::Heartbeat { pid }).unwrap()
                        {
                            return; // round closed before we could stall
                        }
                        std::thread::sleep(Duration::from_millis(5));
                    }
                }
            }
            let _ = on_hold.send(());
            loop {
                match t.request(&Request::Heartbeat { pid }).unwrap() {
                    Reply::Heartbeat(PhaseReply::Finished) => return,
                    _ => std::thread::sleep(Duration::from_millis(5)),
                }
            }
        })
    }

    /// Execute one work order honestly — the exact client seam the
    /// participant SDK uses — and submit the result once.
    fn honest_submit(
        spec: &ExperimentSpec,
        t: &mut dyn Transport,
        pid: u64,
        w: &WorkOrder,
    ) -> SubmitReply {
        use crate::compress::agg::{RemoteCtx, Scratch};
        use crate::compress::wire;
        use crate::fl::backend::LocalScratch;
        use crate::fl::engine::{root_for_seed, ClientTask};

        let algo = spec.expanded_series()[w.series as usize].algorithm.clone();
        let mut backend = spec.workload.build_backend().unwrap();
        let d = backend.dim();
        let root = root_for_seed(spec.seed_for_repeat(w.repeat as usize));
        let mut task = ClientTask::new(&root, w.round as usize, 0, w.client as usize);
        let mut delta = vec![0.0f32; d];
        let mut local = LocalScratch::new();
        let loss = backend.local_update_into(
            w.client as usize,
            &w.params,
            algo.local_steps,
            algo.client_lr,
            &mut task.rng,
            &mut delta,
            &mut local,
        );
        if let Some(mode) = w.fault {
            mode.apply(&mut delta);
        }
        let agg = algo.compression.aggregator(algo.client_lr);
        let mut scratch = Scratch::new(d);
        let upd = agg.compress_remote(
            &mut delta,
            RemoteCtx { rng: &mut task.rng, round_sigma: w.sigma, ef: None },
            &mut scratch,
        );
        let req = Request::Submit {
            pid,
            round: w.round,
            slot: w.slot,
            loss,
            ef_scale: upd.ef_scale,
            payload: wire::encode(&upd.msg),
        };
        match t.request(&req).unwrap() {
            Reply::Submit(r) => r,
            other => panic!("unexpected reply to submit: {other:?}"),
        }
    }

    #[test]
    fn deadline_reclaim_lets_a_live_peer_repair_a_stalled_round() {
        // One peer pulls a slot and stalls; a real participant joins after
        // the stall is in place. At the round deadline the host reclaims
        // the stalled slot, the live participant re-pulls and repairs it
        // inside the grace window — the round closes *full*, is not marked
        // degraded, and stays byte-identical to the engine.
        let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(2, 8, 77))
            .rounds(1)
            .seed(3)
            .series(AlgorithmConfig::gd().with_lrs(0.05, 1.0));
        let mut host =
            ServiceHost::tcp("127.0.0.1:0", 0, 250, 1, &Telemetry::disabled()).unwrap();
        let addr = host.local_addr().unwrap().to_string();
        let (tx, rx) = std::sync::mpsc::channel();
        let stalled = stalled_peer(addr.clone(), tx);
        let live = {
            let spec = spec.clone();
            std::thread::spawn(move || -> Result<()> {
                rx.recv().expect("stall signal");
                let mut t = TcpTransport::connect(&addr, Duration::from_secs(10))?;
                Participant::new(spec).run(&mut t)
            })
        };
        let mut backend = spec.workload.build_backend().unwrap();
        let algo = spec.expanded_series()[0].algorithm.clone();
        let cfg = spec.server_config(0);
        let got = host.run_one(backend.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}).unwrap();
        host.shutdown().unwrap();
        stalled.join().unwrap();
        live.join().unwrap().unwrap();
        assert_eq!(got.records.len(), 1);
        assert!(!got.records[0].degraded, "repaired round must not be degraded");
        assert_eq!(got.records[0].arrived, 2);
        assert_identical(&engine_run(&spec, 0, 0), &got, "repaired round");
    }

    #[test]
    fn deadline_closes_a_degraded_round_at_quorum() {
        // Two peers, two slots: one submits its slot honestly and then only
        // heartbeats, the other stalls on its held slot. Nobody repairs the
        // reclaimed slot, so the host degrades gracefully: the round closes
        // at the deadline with the quorum's single submission and the
        // record is marked degraded.
        let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(2, 8, 55))
            .rounds(1)
            .seed(9)
            .series(AlgorithmConfig::gd().with_lrs(0.05, 1.0));
        let tele = Telemetry::with_capacity(64);
        let mut host = ServiceHost::tcp("127.0.0.1:0", 0, 250, 2, &tele).unwrap();
        let addr = host.local_addr().unwrap().to_string();
        let (tx, rx) = std::sync::mpsc::channel();
        let stalled = stalled_peer(addr.clone(), tx);
        let submitter = {
            let spec = spec.clone();
            std::thread::spawn(move || {
                let mut t = TcpTransport::connect(&addr, Duration::from_secs(10)).unwrap();
                let Reply::Rendezvous(RendezvousReply::Accept { pid }) =
                    t.request(&Request::Rendezvous).unwrap()
                else {
                    panic!("submitter refused")
                };
                let w = loop {
                    match t.request(&Request::PullRound { pid }).unwrap() {
                        Reply::Round(RoundReply::Work(w)) => break w,
                        _ => std::thread::sleep(Duration::from_millis(5)),
                    }
                };
                assert_eq!(honest_submit(&spec, &mut t, pid, &w), SubmitReply::Ok);
                loop {
                    match t.request(&Request::Heartbeat { pid }).unwrap() {
                        Reply::Heartbeat(PhaseReply::Finished) => return,
                        _ => std::thread::sleep(Duration::from_millis(5)),
                    }
                }
            })
        };
        let mut backend = spec.workload.build_backend().unwrap();
        let algo = spec.expanded_series()[0].algorithm.clone();
        let cfg = spec.server_config(0);
        let got = host.run_one(backend.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}).unwrap();
        host.shutdown().unwrap();
        let _ = rx.recv();
        stalled.join().unwrap();
        submitter.join().unwrap();
        assert_eq!(got.records.len(), 1);
        assert!(got.records[0].degraded, "quorum close must be surfaced as degraded");
        assert_eq!(got.records[0].arrived, 1);
        assert_eq!(got.records[0].selected, 2);
        let m = tele.metrics().unwrap();
        assert_eq!(m.degraded_rounds_total.get(), 1);
    }

    #[test]
    fn tcp_service_runs_end_to_end_and_matches_the_engine() {
        let spec = ExperimentSpec::new("svc", WorkloadSpec::consensus(10, 13, 2024))
            .rounds(4)
            .seed(11)
            .series(AlgorithmConfig::z_signfedavg(ZParam::Finite(1), 2.0, 2).with_lrs(0.05, 1.0));
        let mut host =
            ServiceHost::tcp("127.0.0.1:0", 500, 30_000, 2, &Telemetry::disabled()).unwrap();
        let addr = host.local_addr().unwrap().to_string();
        let joiners: Vec<_> = (0..2)
            .map(|_| {
                let spec = spec.clone();
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut t = TcpTransport::connect(&addr, Duration::from_secs(10)).unwrap();
                    Participant::new(spec).run(&mut t)
                })
            })
            .collect();
        let mut backend = spec.workload.build_backend().unwrap();
        let algo = spec.expanded_series()[0].algorithm.clone();
        let cfg = spec.server_config(0);
        let got = host.run_one(backend.as_mut(), &algo, &cfg, 0, 0, &mut |_| {}).unwrap();
        host.shutdown().unwrap();
        for j in joiners {
            j.join().unwrap().unwrap();
        }
        assert_identical(&engine_run(&spec, 0, 0), &got, "tcp");
    }
}
