//! The participant SDK: a networked client that executes the *same*
//! per-client work as the in-process round engine.
//!
//! A [`Participant`] is built from the very [`ExperimentSpec`] the
//! coordinator runs, which is how the two sides agree on everything the
//! protocol does not carry per message: the workload (and therefore the
//! local dataset partition), the algorithm of each series, and the
//! repeat-seed convention. Each [`protocol::WorkOrder`](super::protocol)
//! then pins the per-round scalars (round, σ, client, fault, params).
//!
//! Determinism: the client task RNG is derived from `(seed_for_repeat,
//! round, client)` — never from the slot or the participant — so *which*
//! participant serves a client cannot change the update it computes. That,
//! plus the coordinator folding submissions in slot order, is the whole
//! loopback-equals-engine argument.
//!
//! EF-SignSGD residuals live here, per client id, exactly like the
//! engine's per-client `EfState` table. The coordinator's sticky
//! client→participant pinning keeps a client on the participant that owns
//! its residual. For crash recovery the residuals are the one piece of
//! participant-owned trajectory state, so in-process participants can
//! share a [`ResidualVault`] with their host: every EF update is mirrored
//! into the vault (and seeded back from it), which is how a loopback
//! session's checkpoint captures residuals the host process could not
//! otherwise see. Remote (TCP) participants keep residuals private — they
//! outlive a coordinator crash and simply reconnect.

use super::chaos::RetryPolicy;
use super::protocol::{
    PhaseReply, Reply, RendezvousReply, Request, RoundReply, SubmitReply, WorkOrder,
};
use super::transport::Transport;
use crate::api::spec::{ExperimentSpec, SeriesSpec};
use crate::compress::agg::{Aggregator, RemoteCtx, Scratch};
use crate::compress::error_feedback::EfState;
use crate::compress::wire;
use crate::error::{Error, ErrorKind, Result};
use crate::fl::backend::{LocalScratch, TrainBackend};
use crate::fl::engine::ClientTask;
use crate::fl::{AlgorithmConfig, Compression};
use crate::rng::Pcg64;
use crate::telemetry::Telemetry;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Shared EF-residual mirror, keyed by `(series, repeat, client)`. The
/// host hands clones to its in-process participants so a checkpoint can
/// capture residuals (and a resume can seed them) without any protocol
/// traffic; see the module docs.
pub type ResidualVault = Arc<Mutex<HashMap<(u32, u32, u64), Vec<f32>>>>;

/// Everything scoped to one (series, repeat) run: the backend with this
/// repeat's data, the series' aggregator, the run's root RNG stream, the
/// EF residuals, and the reusable work buffers.
struct RunCtx {
    series: u32,
    repeat: u32,
    d: usize,
    backend: Box<dyn TrainBackend>,
    algo: AlgorithmConfig,
    agg: Box<dyn Aggregator>,
    root: Pcg64,
    /// Per-client EF residuals (EF-SignSGD only), keyed by client id.
    ef: HashMap<u64, Mutex<EfState>>,
    delta: Vec<f32>,
    local: LocalScratch,
    scratch: Scratch,
}

/// Default bound on how long a participant keeps retrying to rendezvous
/// before surfacing `ErrorKind::Timeout`.
pub const DEFAULT_RENDEZVOUS_PATIENCE: Duration = Duration::from_secs(60);

/// A service client: rendezvous, pull work, run the local update, submit —
/// until the coordinator reports `Finished`. Every request runs under the
/// participant's [`RetryPolicy`]: transient transport failures (timeouts,
/// resets, injected chaos) are retried with bounded deterministic backoff,
/// and the coordinator's `Duplicate`/`Stale` dedup makes the resulting
/// resubmissions idempotent.
pub struct Participant {
    spec: ExperimentSpec,
    series: Vec<SeriesSpec>,
    run: Option<RunCtx>,
    vault: Option<ResidualVault>,
    retry: RetryPolicy,
    rendezvous_patience: Duration,
    tele: Telemetry,
}

impl Participant {
    /// Build from the experiment spec both sides share.
    pub fn new(spec: ExperimentSpec) -> Participant {
        let series = spec.expanded_series();
        Participant {
            spec,
            series,
            run: None,
            vault: None,
            retry: RetryPolicy::default(),
            rendezvous_patience: DEFAULT_RENDEZVOUS_PATIENCE,
            tele: Telemetry::disabled(),
        }
    }

    /// Mirror EF residuals into (and seed them from) a host-shared vault
    /// (builder-style; in-process participants only).
    pub fn with_vault(mut self, vault: ResidualVault) -> Participant {
        self.vault = Some(vault);
        self
    }

    /// Override the request retry/backoff schedule (builder-style).
    pub fn with_retry(mut self, retry: RetryPolicy) -> Participant {
        self.retry = retry;
        self
    }

    /// Bound the rendezvous retry loop (builder-style).
    pub fn with_rendezvous_patience(mut self, patience: Duration) -> Participant {
        self.rendezvous_patience = patience;
        self
    }

    /// Count retries/timeouts into a telemetry registry (builder-style).
    pub fn with_telemetry(mut self, tele: &Telemetry) -> Participant {
        self.tele = tele.clone();
        self
    }

    /// Join the coordinator and work until it finishes. Returns `Ok(())`
    /// when the coordinator reports the terminal phase (or refuses the
    /// rendezvous because the run is already over).
    pub fn run(&mut self, transport: &mut dyn Transport) -> Result<()> {
        let (retry, patience, tele) =
            (self.retry, self.rendezvous_patience, self.tele.clone());
        let Some(mut pid) = rendezvous_retrying(transport, retry, patience, &tele)? else {
            return Ok(()); // Nothing left to join.
        };
        loop {
            match request_with_retry(transport, &Request::PullRound { pid }, retry, &tele)? {
                Reply::Round(RoundReply::Work(w)) => {
                    match self.execute(transport, pid, &w)? {
                        // Stale/Duplicate: the round closed (or the slot was
                        // stolen and re-filled) while we computed — drop the
                        // result and pull again.
                        SubmitReply::Ok | SubmitReply::Stale | SubmitReply::Duplicate => {}
                        // Our registration expired (heartbeat lapse): rejoin.
                        SubmitReply::Unknown => {
                            match rendezvous_retrying(transport, retry, patience, &tele)? {
                                Some(p) => pid = p,
                                None => return Ok(()),
                            }
                        }
                        // An honest participant whose resubmissions are all
                        // rejected as malformed means the two sides disagree
                        // about the spec — not something a retry can fix.
                        SubmitReply::Malformed => {
                            return Err(Error::protocol(
                                "coordinator rejected this participant's submission as \
                                 malformed (spec mismatch between coordinator and participant?)",
                            ))
                        }
                    }
                }
                Reply::Round(RoundReply::NoWork) => {
                    match request_with_retry(
                        transport,
                        &Request::Heartbeat { pid },
                        retry,
                        &tele,
                    )? {
                        Reply::Heartbeat(PhaseReply::Finished) => return Ok(()),
                        Reply::Heartbeat(PhaseReply::Unknown) => {
                            match rendezvous_retrying(transport, retry, patience, &tele)? {
                                Some(p) => pid = p,
                                None => return Ok(()),
                            }
                        }
                        Reply::Heartbeat(_) => transport.idle_wait(),
                        other => {
                            return Err(Error::protocol(format!(
                                "unexpected reply to heartbeat: {other:?}"
                            )))
                        }
                    }
                }
                other => {
                    return Err(Error::protocol(format!("unexpected reply to pull: {other:?}")))
                }
            }
        }
    }

    /// Run one work order — the client side of the engine's per-slot task:
    /// local update, fault, uplink compression — and submit the result.
    fn execute(
        &mut self,
        transport: &mut dyn Transport,
        pid: u64,
        w: &WorkOrder,
    ) -> Result<SubmitReply> {
        let vault = self.vault.clone();
        let (retry, tele) = (self.retry, self.tele.clone());
        let ctx = self.ensure_run(w.series, w.repeat)?;
        if w.params.len() != ctx.d {
            return Err(Error::protocol(format!(
                "work order carries {} params, the workload has dimension {}",
                w.params.len(),
                ctx.d
            )));
        }
        // The slot does not feed the stream derivation (`pos` is unused by
        // ClientTask::new), so any participant computes the same update.
        let mut task = ClientTask::new(&ctx.root, w.round as usize, 0, w.client as usize);
        let loss = ctx.backend.local_update_into(
            w.client as usize,
            &w.params,
            ctx.algo.local_steps,
            ctx.algo.client_lr,
            &mut task.rng,
            &mut ctx.delta,
            &mut ctx.local,
        );
        if let Some(mode) = w.fault {
            mode.apply(&mut ctx.delta);
        }
        let ef = match ctx.algo.compression {
            Compression::ErrorFeedback => {
                let (series, repeat, d) = (ctx.series, ctx.repeat, ctx.d);
                Some(&*ctx.ef.entry(w.client).or_insert_with(|| {
                    // First touch of this client: adopt a checkpointed
                    // residual from the vault when the host restored one.
                    let seeded = vault.as_ref().and_then(|v| {
                        v.lock().unwrap().get(&(series, repeat, w.client)).cloned()
                    });
                    Mutex::new(match seeded {
                        Some(r) if r.len() == d => EfState::from_residual(r),
                        _ => EfState::new(d),
                    })
                }))
            }
            _ => None,
        };
        let upd = ctx.agg.compress_remote(
            &mut ctx.delta,
            RemoteCtx { rng: &mut task.rng, round_sigma: w.sigma, ef },
            &mut ctx.scratch,
        );
        // Mirror the post-update residual before submitting: once the
        // coordinator has this round's submission, any checkpoint it takes
        // at the round boundary sees the matching residual.
        if let (Some(v), Some(ef)) = (vault.as_ref(), ef) {
            let key = (ctx.series, ctx.repeat, w.client);
            v.lock().unwrap().insert(key, ef.lock().unwrap().residual().to_vec());
        }
        // Built once and resubmitted verbatim: the EF residual has already
        // absorbed this round's update, so recompressing on a retry would
        // produce a different (wrong) payload.
        let req = Request::Submit {
            pid,
            round: w.round,
            slot: w.slot,
            loss,
            ef_scale: upd.ef_scale,
            payload: wire::encode(&upd.msg),
        };
        // `Malformed` from an honest participant is a frame corrupted in
        // flight (the chaos seam truncates payloads to exercise exactly
        // this): resubmit the identical bytes a bounded number of times
        // before concluding the two sides genuinely disagree.
        let mut resubmits = 0u32;
        loop {
            match request_with_retry(transport, &req, retry, &tele)? {
                Reply::Submit(SubmitReply::Malformed)
                    if resubmits + 1 < retry.max_attempts.max(1) =>
                {
                    resubmits += 1;
                    tele.count_retry();
                    retry.sleep(resubmits - 1);
                }
                Reply::Submit(r) => return Ok(r),
                other => {
                    return Err(Error::protocol(format!(
                        "unexpected reply to submit: {other:?}"
                    )))
                }
            }
        }
    }

    /// (Re)build the run context when the work order names a different
    /// (series, repeat) than the cached one — a fresh backend per repeat
    /// and the `seed_for_repeat` root, exactly like `api::Session`.
    fn ensure_run(&mut self, series: u32, repeat: u32) -> Result<&mut RunCtx> {
        let stale = self.run.as_ref().map(|c| (c.series, c.repeat)) != Some((series, repeat));
        if stale {
            let s = self.series.get(series as usize).ok_or_else(|| {
                Error::protocol(format!(
                    "work order names series {series}, the spec has {}",
                    self.series.len()
                ))
            })?;
            let algo = s.algorithm.clone();
            let backend = self
                .spec
                .workload
                .build_backend()
                .map_err(|e| e.wrap("participant backend"))?;
            let d = backend.dim();
            let seed = self.spec.seed_for_repeat(repeat as usize);
            self.run = Some(RunCtx {
                series,
                repeat,
                d,
                backend,
                agg: algo.compression.aggregator_robust(algo.client_lr, algo.robust),
                algo,
                // The engine's root derivation — shared contract.
                root: crate::fl::engine::root_for_seed(seed),
                ef: HashMap::new(),
                delta: vec![0.0; d],
                local: LocalScratch::new(),
                scratch: Scratch::new(d),
            });
        }
        Ok(self.run.as_mut().unwrap())
    }
}

/// One rendezvous attempt. `Ok(None)` means the coordinator already
/// finished (`Later`) and there is nothing to join.
fn rendezvous(transport: &mut dyn Transport) -> Result<Option<u64>> {
    match transport.request(&Request::Rendezvous)? {
        Reply::Rendezvous(RendezvousReply::Accept { pid }) => Ok(Some(pid)),
        Reply::Rendezvous(RendezvousReply::Later) => Ok(None),
        other => Err(Error::protocol(format!("unexpected reply to rendezvous: {other:?}"))),
    }
}

/// Rendezvous, retrying transient failures under `retry`'s backoff but
/// never past the `patience` deadline — a coordinator that stays
/// unreachable surfaces as `ErrorKind::Timeout` instead of looping forever.
pub fn rendezvous_retrying(
    transport: &mut dyn Transport,
    retry: RetryPolicy,
    patience: Duration,
    tele: &Telemetry,
) -> Result<Option<u64>> {
    let start = Instant::now();
    let mut attempt = 0u32;
    loop {
        match rendezvous(transport) {
            Ok(r) => return Ok(r),
            Err(e) => {
                if e.kind() == ErrorKind::Timeout {
                    tele.count_timeout();
                }
                if start.elapsed() >= patience {
                    return Err(Error::timeout(format!(
                        "rendezvous: no accept within {patience:?} (last error: {e})"
                    )));
                }
                tele.count_retry();
                retry.sleep(attempt);
                attempt += 1;
            }
        }
    }
}

/// Issue one request, retrying transient transport failures up to
/// `retry.max_attempts` total attempts with deterministic backoff. The
/// coordinator's idempotent request handling (re-pull returns the held
/// slot, duplicate submits answer `Duplicate`) is what makes blind
/// retransmission safe.
pub fn request_with_retry(
    transport: &mut dyn Transport,
    req: &Request,
    retry: RetryPolicy,
    tele: &Telemetry,
) -> Result<Reply> {
    let mut attempt = 0u32;
    loop {
        match transport.request(req) {
            Ok(reply) => return Ok(reply),
            Err(e) => {
                if e.kind() == ErrorKind::Timeout {
                    tele.count_timeout();
                }
                attempt += 1;
                if attempt >= retry.max_attempts.max(1) {
                    return Err(e.wrap(&format!("request failed after {attempt} attempts")));
                }
                tele.count_retry();
                retry.sleep(attempt - 1);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A transport that fails its first `fail` requests with a timeout,
    /// then answers every request with `Heartbeat(Standby)`.
    struct Flaky {
        fail: u32,
        calls: u32,
    }

    impl Transport for Flaky {
        fn request(&mut self, _req: &Request) -> Result<Reply> {
            self.calls += 1;
            if self.calls <= self.fail {
                Err(Error::timeout("flaky"))
            } else {
                Ok(Reply::Heartbeat(PhaseReply::Standby))
            }
        }
    }

    #[test]
    fn retry_rides_out_transient_failures() {
        let mut t = Flaky { fail: 3, calls: 0 };
        let retry = RetryPolicy::fast(1);
        let tele = Telemetry::disabled();
        let reply =
            request_with_retry(&mut t, &Request::Heartbeat { pid: 1 }, retry, &tele).unwrap();
        assert_eq!(reply, Reply::Heartbeat(PhaseReply::Standby));
        assert_eq!(t.calls, 4);
    }

    #[test]
    fn retry_budget_is_bounded() {
        let mut t = Flaky { fail: u32::MAX, calls: 0 };
        let retry = RetryPolicy::fast(1);
        let tele = Telemetry::disabled();
        let err = request_with_retry(&mut t, &Request::Heartbeat { pid: 1 }, retry, &tele)
            .unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Timeout, "wrap must preserve the kind");
        assert_eq!(t.calls, retry.max_attempts);
    }

    #[test]
    fn rendezvous_deadline_surfaces_as_timeout() {
        let mut t = Flaky { fail: u32::MAX, calls: 0 };
        let retry = RetryPolicy::fast(1);
        let tele = Telemetry::disabled();
        let err =
            rendezvous_retrying(&mut t, retry, Duration::from_millis(30), &tele).unwrap_err();
        assert_eq!(err.kind(), ErrorKind::Timeout);
        assert!(t.calls >= 2, "must have retried before the deadline");
    }
}
