//! The participant SDK: a networked client that executes the *same*
//! per-client work as the in-process round engine.
//!
//! A [`Participant`] is built from the very [`ExperimentSpec`] the
//! coordinator runs, which is how the two sides agree on everything the
//! protocol does not carry per message: the workload (and therefore the
//! local dataset partition), the algorithm of each series, and the
//! repeat-seed convention. Each [`protocol::WorkOrder`](super::protocol)
//! then pins the per-round scalars (round, σ, client, fault, params).
//!
//! Determinism: the client task RNG is derived from `(seed_for_repeat,
//! round, client)` — never from the slot or the participant — so *which*
//! participant serves a client cannot change the update it computes. That,
//! plus the coordinator folding submissions in slot order, is the whole
//! loopback-equals-engine argument.
//!
//! EF-SignSGD residuals live here, per client id, exactly like the
//! engine's per-client `EfState` table. The coordinator's sticky
//! client→participant pinning keeps a client on the participant that owns
//! its residual. For crash recovery the residuals are the one piece of
//! participant-owned trajectory state, so in-process participants can
//! share a [`ResidualVault`] with their host: every EF update is mirrored
//! into the vault (and seeded back from it), which is how a loopback
//! session's checkpoint captures residuals the host process could not
//! otherwise see. Remote (TCP) participants keep residuals private — they
//! outlive a coordinator crash and simply reconnect.

use super::protocol::{
    PhaseReply, Reply, RendezvousReply, Request, RoundReply, SubmitReply, WorkOrder,
};
use super::transport::Transport;
use crate::api::spec::{ExperimentSpec, SeriesSpec};
use crate::compress::agg::{Aggregator, RemoteCtx, Scratch};
use crate::compress::error_feedback::EfState;
use crate::compress::wire;
use crate::error::{Error, Result};
use crate::fl::backend::{LocalScratch, TrainBackend};
use crate::fl::engine::ClientTask;
use crate::fl::{AlgorithmConfig, Compression};
use crate::rng::Pcg64;
use std::collections::HashMap;
use std::sync::{Arc, Mutex};

/// Shared EF-residual mirror, keyed by `(series, repeat, client)`. The
/// host hands clones to its in-process participants so a checkpoint can
/// capture residuals (and a resume can seed them) without any protocol
/// traffic; see the module docs.
pub type ResidualVault = Arc<Mutex<HashMap<(u32, u32, u64), Vec<f32>>>>;

/// Everything scoped to one (series, repeat) run: the backend with this
/// repeat's data, the series' aggregator, the run's root RNG stream, the
/// EF residuals, and the reusable work buffers.
struct RunCtx {
    series: u32,
    repeat: u32,
    d: usize,
    backend: Box<dyn TrainBackend>,
    algo: AlgorithmConfig,
    agg: Box<dyn Aggregator>,
    root: Pcg64,
    /// Per-client EF residuals (EF-SignSGD only), keyed by client id.
    ef: HashMap<u64, Mutex<EfState>>,
    delta: Vec<f32>,
    local: LocalScratch,
    scratch: Scratch,
}

/// A service client: rendezvous, pull work, run the local update, submit —
/// until the coordinator reports `Finished`.
pub struct Participant {
    spec: ExperimentSpec,
    series: Vec<SeriesSpec>,
    run: Option<RunCtx>,
    vault: Option<ResidualVault>,
}

impl Participant {
    /// Build from the experiment spec both sides share.
    pub fn new(spec: ExperimentSpec) -> Participant {
        let series = spec.expanded_series();
        Participant { spec, series, run: None, vault: None }
    }

    /// Mirror EF residuals into (and seed them from) a host-shared vault
    /// (builder-style; in-process participants only).
    pub fn with_vault(mut self, vault: ResidualVault) -> Participant {
        self.vault = Some(vault);
        self
    }

    /// Join the coordinator and work until it finishes. Returns `Ok(())`
    /// when the coordinator reports the terminal phase (or refuses the
    /// rendezvous because the run is already over).
    pub fn run(&mut self, transport: &mut dyn Transport) -> Result<()> {
        let Some(mut pid) = rendezvous(transport)? else {
            return Ok(()); // Nothing left to join.
        };
        loop {
            match transport.request(&Request::PullRound { pid })? {
                Reply::Round(RoundReply::Work(w)) => {
                    match self.execute(transport, pid, &w)? {
                        // Stale/Duplicate: the round closed (or the slot was
                        // stolen and re-filled) while we computed — drop the
                        // result and pull again.
                        SubmitReply::Ok | SubmitReply::Stale | SubmitReply::Duplicate => {}
                        // Our registration expired (heartbeat lapse): rejoin.
                        SubmitReply::Unknown => match rendezvous(transport)? {
                            Some(p) => pid = p,
                            None => return Ok(()),
                        },
                        // An honest participant producing a malformed
                        // submission means the two sides disagree about the
                        // spec — not something a retry can fix.
                        SubmitReply::Malformed => {
                            return Err(Error::protocol(
                                "coordinator rejected this participant's submission as \
                                 malformed (spec mismatch between coordinator and participant?)",
                            ))
                        }
                    }
                }
                Reply::Round(RoundReply::NoWork) => {
                    match transport.request(&Request::Heartbeat { pid })? {
                        Reply::Heartbeat(PhaseReply::Finished) => return Ok(()),
                        Reply::Heartbeat(PhaseReply::Unknown) => match rendezvous(transport)? {
                            Some(p) => pid = p,
                            None => return Ok(()),
                        },
                        Reply::Heartbeat(_) => transport.idle_wait(),
                        other => {
                            return Err(Error::protocol(format!(
                                "unexpected reply to heartbeat: {other:?}"
                            )))
                        }
                    }
                }
                other => {
                    return Err(Error::protocol(format!("unexpected reply to pull: {other:?}")))
                }
            }
        }
    }

    /// Run one work order — the client side of the engine's per-slot task:
    /// local update, fault, uplink compression — and submit the result.
    fn execute(
        &mut self,
        transport: &mut dyn Transport,
        pid: u64,
        w: &WorkOrder,
    ) -> Result<SubmitReply> {
        let vault = self.vault.clone();
        let ctx = self.ensure_run(w.series, w.repeat)?;
        if w.params.len() != ctx.d {
            return Err(Error::protocol(format!(
                "work order carries {} params, the workload has dimension {}",
                w.params.len(),
                ctx.d
            )));
        }
        // The slot does not feed the stream derivation (`pos` is unused by
        // ClientTask::new), so any participant computes the same update.
        let mut task = ClientTask::new(&ctx.root, w.round as usize, 0, w.client as usize);
        let loss = ctx.backend.local_update_into(
            w.client as usize,
            &w.params,
            ctx.algo.local_steps,
            ctx.algo.client_lr,
            &mut task.rng,
            &mut ctx.delta,
            &mut ctx.local,
        );
        if let Some(mode) = w.fault {
            mode.apply(&mut ctx.delta);
        }
        let ef = match ctx.algo.compression {
            Compression::ErrorFeedback => {
                let (series, repeat, d) = (ctx.series, ctx.repeat, ctx.d);
                Some(&*ctx.ef.entry(w.client).or_insert_with(|| {
                    // First touch of this client: adopt a checkpointed
                    // residual from the vault when the host restored one.
                    let seeded = vault.as_ref().and_then(|v| {
                        v.lock().unwrap().get(&(series, repeat, w.client)).cloned()
                    });
                    Mutex::new(match seeded {
                        Some(r) if r.len() == d => EfState::from_residual(r),
                        _ => EfState::new(d),
                    })
                }))
            }
            _ => None,
        };
        let upd = ctx.agg.compress_remote(
            &mut ctx.delta,
            RemoteCtx { rng: &mut task.rng, round_sigma: w.sigma, ef },
            &mut ctx.scratch,
        );
        // Mirror the post-update residual before submitting: once the
        // coordinator has this round's submission, any checkpoint it takes
        // at the round boundary sees the matching residual.
        if let (Some(v), Some(ef)) = (vault.as_ref(), ef) {
            let key = (ctx.series, ctx.repeat, w.client);
            v.lock().unwrap().insert(key, ef.lock().unwrap().residual().to_vec());
        }
        let req = Request::Submit {
            pid,
            round: w.round,
            slot: w.slot,
            loss,
            ef_scale: upd.ef_scale,
            payload: wire::encode(&upd.msg),
        };
        match transport.request(&req)? {
            Reply::Submit(r) => Ok(r),
            other => Err(Error::protocol(format!("unexpected reply to submit: {other:?}"))),
        }
    }

    /// (Re)build the run context when the work order names a different
    /// (series, repeat) than the cached one — a fresh backend per repeat
    /// and the `seed_for_repeat` root, exactly like `api::Session`.
    fn ensure_run(&mut self, series: u32, repeat: u32) -> Result<&mut RunCtx> {
        let stale = self.run.as_ref().map(|c| (c.series, c.repeat)) != Some((series, repeat));
        if stale {
            let s = self.series.get(series as usize).ok_or_else(|| {
                Error::protocol(format!(
                    "work order names series {series}, the spec has {}",
                    self.series.len()
                ))
            })?;
            let algo = s.algorithm.clone();
            let backend = self
                .spec
                .workload
                .build_backend()
                .map_err(|e| e.wrap("participant backend"))?;
            let d = backend.dim();
            let seed = self.spec.seed_for_repeat(repeat as usize);
            self.run = Some(RunCtx {
                series,
                repeat,
                d,
                backend,
                agg: algo.compression.aggregator(algo.client_lr),
                algo,
                // The engine's root derivation — shared contract.
                root: crate::fl::engine::root_for_seed(seed),
                ef: HashMap::new(),
                delta: vec![0.0; d],
                local: LocalScratch::new(),
                scratch: Scratch::new(d),
            });
        }
        Ok(self.run.as_mut().unwrap())
    }
}

/// One rendezvous attempt. `Ok(None)` means the coordinator already
/// finished (`Later`) and there is nothing to join.
fn rendezvous(transport: &mut dyn Transport) -> Result<Option<u64>> {
    match transport.request(&Request::Rendezvous)? {
        Reply::Rendezvous(RendezvousReply::Accept { pid }) => Ok(Some(pid)),
        Reply::Rendezvous(RendezvousReply::Later) => Ok(None),
        other => Err(Error::protocol(format!("unexpected reply to rendezvous: {other:?}"))),
    }
}
