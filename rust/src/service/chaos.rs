//! Deterministic fault injection for the service transport layer.
//!
//! [`ChaosTransport`] wraps any [`Transport`] and injects faults — dropped
//! requests, dropped replies, duplicated deliveries, corrupted frames,
//! corrupted payloads, connection resets, delays — according to a seeded
//! [`FaultPlan`]. Every fault decision is a *pure function* of
//! `(seed, request_index)`, so a failing chaos run replays exactly from its
//! seed, and the schedule is identical whether requests are issued from one
//! thread or eight.
//!
//! RNG stream isolation: chaos draws come from dedicated Pcg64 streams
//! ([`CHAOS_STREAM`], [`RETRY_STREAM`]) keyed off the chaos seed, never off
//! the experiment seed — injecting faults can therefore never perturb the
//! experiment's own random streams, which is what makes the chaos
//! byte-identity pin (`service/mod.rs` tests, `make chaos-smoke`) possible.
//!
//! [`RetryPolicy`] is the client-side complement: bounded exponential
//! backoff with deterministic jitter, used by the participant loop to ride
//! out injected (or real) faults. The coordinator's `Duplicate`/`Stale`
//! dedup makes the resulting resubmissions idempotent.

use super::protocol::{encode_request, Reply, Request};
use super::transport::Transport;
use crate::error::{Error, Result};
use crate::rng::Pcg64;
use crate::telemetry::Telemetry;
use std::time::Duration;

/// Pcg64 stream selector for fault-schedule draws (xored with the request
/// index). An arbitrary constant, distinct from every experiment stream.
const CHAOS_STREAM: u64 = 0xC4A0_5BAD_F001_0001;

/// Pcg64 stream selector for backoff-jitter draws (xored with the attempt
/// number).
const RETRY_STREAM: u64 = 0xC4A0_5BAD_F001_0002;

/// Per-request fault probabilities. Each request draws one uniform and
/// walks these cumulatively, so the sum across categories must stay < 1
/// (the remainder is fault-free delivery).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosConfig {
    /// Request vanishes before the coordinator sees it.
    pub drop_request: f64,
    /// Request is delivered, the reply vanishes on the way back.
    pub drop_reply: f64,
    /// Request is delivered twice; the first reply is returned.
    pub duplicate_request: f64,
    /// The encoded request frame is truncated by one byte before sending
    /// (fails the envelope checksum — the transport connection is burned).
    pub corrupt_frame: f64,
    /// A `Submit`'s update payload is truncated by one byte but delivered
    /// (exercises the coordinator's `Malformed` reply path).
    pub corrupt_payload: f64,
    /// The connection is reset without delivering anything.
    pub reset: f64,
    /// Delivery is delayed by up to `max_delay_ms`.
    pub delay: f64,
    /// Upper bound (exclusive, in ms) on injected delays.
    pub max_delay_ms: u64,
}

impl ChaosConfig {
    /// No faults at all — `ChaosTransport` with this config is a pass-through.
    pub fn off() -> ChaosConfig {
        ChaosConfig {
            drop_request: 0.0,
            drop_reply: 0.0,
            duplicate_request: 0.0,
            corrupt_frame: 0.0,
            corrupt_payload: 0.0,
            reset: 0.0,
            delay: 0.0,
            max_delay_ms: 0,
        }
    }

    /// The aggressive preset the chaos byte-identity pins run under: about
    /// one request in three is faulted some way.
    pub fn aggressive() -> ChaosConfig {
        ChaosConfig {
            drop_request: 0.05,
            drop_reply: 0.05,
            duplicate_request: 0.05,
            corrupt_frame: 0.04,
            corrupt_payload: 0.04,
            reset: 0.05,
            delay: 0.08,
            max_delay_ms: 2,
        }
    }
}

/// The fault (if any) scheduled for one request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fault {
    None,
    DropRequest,
    DropReply,
    DuplicateRequest,
    CorruptFrame,
    CorruptPayload,
    Reset,
    Delay { ms: u64 },
}

/// A seeded fault schedule: `decision(i)` is a pure function of
/// `(seed, i)`, independent of call order, thread count, or wall clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultPlan {
    pub cfg: ChaosConfig,
    pub seed: u64,
}

impl FaultPlan {
    pub fn new(cfg: ChaosConfig, seed: u64) -> FaultPlan {
        FaultPlan { cfg, seed }
    }

    /// The fault scheduled for request number `index`.
    pub fn decision(&self, index: u64) -> Fault {
        let c = &self.cfg;
        let budget = c.drop_request
            + c.drop_reply
            + c.duplicate_request
            + c.corrupt_frame
            + c.corrupt_payload
            + c.reset
            + c.delay;
        if budget <= 0.0 {
            return Fault::None;
        }
        let mut rng = Pcg64::new(self.seed, CHAOS_STREAM ^ index);
        let u = rng.uniform();
        let mut edge = c.drop_request;
        if u < edge {
            return Fault::DropRequest;
        }
        edge += c.drop_reply;
        if u < edge {
            return Fault::DropReply;
        }
        edge += c.duplicate_request;
        if u < edge {
            return Fault::DuplicateRequest;
        }
        edge += c.corrupt_frame;
        if u < edge {
            return Fault::CorruptFrame;
        }
        edge += c.corrupt_payload;
        if u < edge {
            return Fault::CorruptPayload;
        }
        edge += c.reset;
        if u < edge {
            return Fault::Reset;
        }
        edge += c.delay;
        if u < edge {
            return Fault::Delay { ms: rng.below(c.max_delay_ms.max(1)) };
        }
        Fault::None
    }
}

/// Bounded exponential backoff with deterministic jitter. `backoff_ms` is a
/// pure function of `(seed, attempt)` — replays exactly, independent of
/// thread count.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts before a request chain gives up (>= 1).
    pub max_attempts: u32,
    /// Backoff before attempt 1 retries; doubles per attempt.
    pub base_ms: u64,
    /// Ceiling on the (pre-jitter) backoff.
    pub cap_ms: u64,
    /// Jitter stream seed — any fixed value keeps the schedule reproducible.
    pub seed: u64,
}

impl Default for RetryPolicy {
    /// The TCP default: ~8 attempts spanning a few seconds.
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 8, base_ms: 50, cap_ms: 2000, seed: 0x5eed }
    }
}

impl RetryPolicy {
    /// A fast schedule for tests and loopback chaos: generous attempt
    /// budget, millisecond-scale sleeps.
    pub fn fast(seed: u64) -> RetryPolicy {
        RetryPolicy { max_attempts: 10, base_ms: 1, cap_ms: 8, seed }
    }

    /// Backoff (ms) to sleep after failed attempt number `attempt`
    /// (0-based). Capped exponential with deterministic half-jitter:
    /// uniform in `[cap/2, cap)` of the attempt's exponential ceiling.
    pub fn backoff_ms(&self, attempt: u32) -> u64 {
        let exp = self.base_ms.saturating_mul(1u64 << attempt.min(20)).min(self.cap_ms);
        let half = exp / 2;
        let mut rng = Pcg64::new(self.seed, RETRY_STREAM ^ attempt as u64);
        let jitter = rng.below((exp - half).max(1));
        half + jitter
    }

    /// Sleep out the backoff for `attempt` (no-op when it lands on 0 ms).
    pub fn sleep(&self, attempt: u32) {
        let ms = self.backoff_ms(attempt);
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }
}

/// A [`Transport`] decorator that injects the faults scheduled by a
/// [`FaultPlan`], counting each injection into telemetry.
pub struct ChaosTransport<T: Transport> {
    inner: T,
    plan: FaultPlan,
    /// Number of `request` calls seen so far — the schedule index.
    index: u64,
    tele: Telemetry,
}

impl<T: Transport> ChaosTransport<T> {
    pub fn new(inner: T, plan: FaultPlan) -> ChaosTransport<T> {
        ChaosTransport { inner, plan, index: 0, tele: Telemetry::disabled() }
    }

    pub fn with_telemetry(mut self, tele: &Telemetry) -> ChaosTransport<T> {
        self.tele = tele.clone();
        self
    }
}

impl<T: Transport> Transport for ChaosTransport<T> {
    fn request(&mut self, req: &Request) -> Result<Reply> {
        let fault = self.plan.decision(self.index);
        self.index += 1;
        match fault {
            Fault::None => self.inner.request(req),
            Fault::Delay { ms } => {
                self.tele.count_fault_injected();
                std::thread::sleep(Duration::from_millis(ms));
                self.inner.request(req)
            }
            Fault::DropRequest => {
                self.tele.count_fault_injected();
                Err(Error::timeout("chaos: request dropped before delivery"))
            }
            Fault::DropReply => {
                self.tele.count_fault_injected();
                // Delivered — the coordinator acts on it — but the caller
                // never sees the reply, exactly like a reply frame lost on
                // the wire.
                let _ = self.inner.request(req);
                Err(Error::timeout("chaos: reply dropped after delivery"))
            }
            Fault::DuplicateRequest => match req {
                // A duplicated rendezvous would register a phantom peer;
                // real retransmission dupes happen after a pid exists, so
                // keep the schedule's slot but deliver once.
                Request::Rendezvous => self.inner.request(req),
                _ => {
                    self.tele.count_fault_injected();
                    let first = self.inner.request(req)?;
                    let _ = self.inner.request(req);
                    Ok(first)
                }
            },
            Fault::CorruptPayload => match req {
                Request::Submit { pid, round, slot, loss, ef_scale, payload }
                    if !payload.is_empty() =>
                {
                    self.tele.count_fault_injected();
                    // Truncate the inner wire frame by one byte: its own
                    // checksum fails on the coordinator, which answers
                    // `Malformed` — the participant must resubmit.
                    let bad = Request::Submit {
                        pid: *pid,
                        round: *round,
                        slot: *slot,
                        loss: *loss,
                        ef_scale: *ef_scale,
                        payload: payload[..payload.len() - 1].to_vec(),
                    };
                    self.inner.request(&bad)
                }
                // Nothing to corrupt on other requests — burn the frame
                // instead so the schedule slot still faults.
                _ => self.corrupt_frame(req),
            },
            Fault::CorruptFrame => self.corrupt_frame(req),
            Fault::Reset => {
                self.tele.count_fault_injected();
                self.inner.break_connection();
                Err(Error::protocol("chaos: connection reset"))
            }
        }
    }

    fn idle_wait(&mut self) {
        self.inner.idle_wait();
    }

    fn break_connection(&mut self) {
        self.inner.break_connection();
    }
}

impl<T: Transport> ChaosTransport<T> {
    /// Send the request with its envelope truncated by one byte (fails the
    /// envelope checksum), then burn the connection — the server drops a
    /// connection on an undecodable frame, so the client must reconnect.
    fn corrupt_frame(&mut self, req: &Request) -> Result<Reply> {
        self.tele.count_fault_injected();
        let mut frame = encode_request(req);
        frame.pop();
        let _ = self.inner.send_raw(&frame);
        self.inner.break_connection();
        Err(Error::protocol("chaos: corrupted request frame"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::service::coordinator::Coordinator;
    use crate::service::transport::LoopbackTransport;

    #[test]
    fn off_plan_schedules_no_faults() {
        let plan = FaultPlan::new(ChaosConfig::off(), 99);
        for i in 0..4096 {
            assert_eq!(plan.decision(i), Fault::None);
        }
    }

    #[test]
    fn aggressive_plan_hits_every_fault_kind() {
        let plan = FaultPlan::new(ChaosConfig::aggressive(), 7);
        let mut seen = [false; 7];
        for i in 0..10_000 {
            match plan.decision(i) {
                Fault::None => {}
                Fault::DropRequest => seen[0] = true,
                Fault::DropReply => seen[1] = true,
                Fault::DuplicateRequest => seen[2] = true,
                Fault::CorruptFrame => seen[3] = true,
                Fault::CorruptPayload => seen[4] = true,
                Fault::Reset => seen[5] = true,
                Fault::Delay { ms } => {
                    assert!(ms < 2);
                    seen[6] = true;
                }
            }
        }
        assert_eq!(seen, [true; 7]);
    }

    #[test]
    fn fault_schedule_is_bit_reproducible_across_parallelism() {
        // The headline determinism property: decision(i) computed from one
        // thread equals decision(i) computed from 8 threads racing over a
        // strided partition, for every i.
        let plan = FaultPlan::new(ChaosConfig::aggressive(), 0xDEAD_BEEF);
        let n = 4096u64;
        let sequential: Vec<Fault> = (0..n).map(|i| plan.decision(i)).collect();
        let mut parallel = vec![Fault::None; n as usize];
        std::thread::scope(|scope| {
            for (lane, chunk) in parallel.chunks_mut((n as usize).div_ceil(8)).enumerate() {
                let base = lane * (n as usize).div_ceil(8);
                scope.spawn(move || {
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        *slot = plan.decision((base + off) as u64);
                    }
                });
            }
        });
        assert_eq!(sequential, parallel);
    }

    #[test]
    fn backoff_is_bit_reproducible_across_parallelism() {
        let policy = RetryPolicy::fast(42);
        let sequential: Vec<u64> = (0..64).map(|a| policy.backoff_ms(a)).collect();
        let mut parallel = vec![0u64; 64];
        std::thread::scope(|scope| {
            for (lane, chunk) in parallel.chunks_mut(8).enumerate() {
                scope.spawn(move || {
                    for (off, slot) in chunk.iter_mut().enumerate() {
                        *slot = policy.backoff_ms((lane * 8 + off) as u32);
                    }
                });
            }
        });
        assert_eq!(sequential, parallel);
    }

    #[test]
    fn backoff_grows_to_the_cap_and_stays_bounded() {
        let policy = RetryPolicy::default();
        for a in 0..40 {
            let ms = policy.backoff_ms(a);
            assert!(ms <= policy.cap_ms, "attempt {a}: {ms} > cap");
        }
        // The late attempts sit in the top half of the cap.
        assert!(policy.backoff_ms(30) >= policy.cap_ms / 2);
    }

    #[test]
    fn corrupt_frame_burns_the_exchange_but_not_the_coordinator() {
        // Force a CorruptFrame on the very first request: the loopback
        // decode must reject the truncated envelope and the caller must see
        // an error, while a follow-up clean request still succeeds.
        let cfg = ChaosConfig { corrupt_frame: 1.0, ..ChaosConfig::off() };
        let coord = Coordinator::new(0);
        let inner = LoopbackTransport::new(coord);
        let mut t = ChaosTransport::new(inner, FaultPlan::new(cfg, 1));
        assert!(t.request(&Request::Rendezvous).is_err());
        // Exhaust the plan's influence by switching to an off plan: the
        // wrapped transport itself is unharmed.
        t.plan = FaultPlan::new(ChaosConfig::off(), 1);
        assert!(t.request(&Request::Rendezvous).is_ok());
    }

    #[test]
    fn dropped_request_surfaces_as_timeout() {
        let cfg = ChaosConfig { drop_request: 1.0, ..ChaosConfig::off() };
        let coord = Coordinator::new(0);
        let mut t = ChaosTransport::new(LoopbackTransport::new(coord), FaultPlan::new(cfg, 2));
        let err = t.request(&Request::Heartbeat { pid: 1 }).unwrap_err();
        assert_eq!(err.kind(), crate::error::ErrorKind::Timeout);
    }

    #[test]
    fn dropped_reply_still_reaches_the_coordinator() {
        // DropReply delivers the request: a rendezvous whose reply is
        // dropped still registers the peer, so the retry's second
        // rendezvous hands out pid 2, not pid 1.
        let cfg = ChaosConfig { drop_reply: 1.0, ..ChaosConfig::off() };
        let coord = Coordinator::new(0);
        let inner = LoopbackTransport::new(coord);
        let mut t = ChaosTransport::new(inner, FaultPlan::new(cfg, 3));
        assert!(t.request(&Request::Rendezvous).is_err());
        t.plan = FaultPlan::new(ChaosConfig::off(), 3);
        let reply = t.request(&Request::Rendezvous).unwrap();
        use crate::service::protocol::RendezvousReply;
        let Reply::Rendezvous(RendezvousReply::Accept { pid }) = reply else { panic!() };
        assert_eq!(pid, 2, "the dropped-reply rendezvous must have registered pid 1");
    }
}
